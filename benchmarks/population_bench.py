"""Population-scale simulator benchmark: churn structure + scan throughput.

Two claims ride this bench (DESIGN.md §15):

* **structural** — a fused server round with the population enabled (the
  ``launch.steps._packed_server_phase`` shape: stateless population
  round, participation rescale, churn-erase mask degraded through
  ``sanitize=True``) keeps the production round's exact memory
  discipline: 1 pack (fresh grads), 1 unpack (optimizer-facing g_t),
  ONE trace-time read of the packed gradient buffer, one fused kernel
  launch.  Population churn is elementwise math and a few
  O(``n_clients``) availability draws — never a second instrumented
  pass over the model.
* **throughput** — the packed cohort engine advances 1e5 (``--full``:
  1e6) virtual Gilbert–Elliott/diurnal clients through a compiled
  ``lax.scan`` with zero Python loops; the artifact records
  client-rounds/sec so a regression in the cohort state machine shows
  up as a number, not a feeling.

Emits CSV rows through ``benchmarks.run`` conventions and writes
benchmarks/artifacts/population_bench.json.  ``--smoke`` asserts the
structural counters on a tiny pytree and writes
benchmarks/artifacts/population_bench_smoke.json — wired into CI next to
``packed_bench --smoke`` and guarded by tools/check_bench_regression.py.

  PYTHONPATH=src python -m benchmarks.population_bench [--full | --smoke]
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.packed_bench import (_mk_engine, _server_state,
                                     _traced_counts, make_transformer_tree,
                                     timed_med)
from repro.core import faults, packing, population
from repro.core.population import PopulationConfig


def build_population_round(tree, pcfg: PopulationConfig):
    """The launch-path population round: stateless availability draw,
    participation rescale, churn-erase blocks degraded through the fused
    kernel's sanitize path — on persisted flat server state."""
    layout = packing.PackedLayout.from_tree(tree)
    eng = _mk_engine("packed", layout, warm=True, fused_stats=True)
    base_key = jax.random.PRNGKey(0x509)

    def pop_round(g_tree, gp_flat, age_flat, tstate, seed):
        ps = population.stateless_round(base_key, seed, pcfg)
        g_flat = layout.pack(g_tree)           # the only pack per round
        g_flat = faults.participation_scale(g_flat * (ps["n_t"]
                                                      / pcfg.participants),
                                            ps["n_t"])
        erase = faults.erase_with_outage(
            population.churn_erase_mask(
                jax.random.fold_in(jax.random.PRNGKey(seed), 0x509),
                layout.d_packed, ps["churn"], pcfg),
            ps["n_t"])
        g_t, age_next, stats = eng.select_and_merge(
            g_flat, gp_flat, age_flat, tstate=tstate, erase=erase,
            sanitize=True)
        g_t_tree = layout.unpack(g_t, cast=False)
        return (g_t_tree, g_t.astype(jnp.bfloat16),
                age_next.astype(jnp.int8), stats["tstate"])

    return jax.jit(pop_round), layout


def bench_round(n_layers, d_model, vocab, repeats=3):
    """Structural counters + wall-clock of the population-enabled fused
    round vs the same round with the population off (sanitize baseline)."""
    tree = make_transformer_tree(n_layers, d_model, vocab)
    g_prev, age = _server_state(tree)
    pcfg = PopulationConfig(n_clients=100_000, cohort_size=4096,
                            participants=16, avail=0.9, mode="diurnal",
                            period=96, depth=0.1)
    pop_fn, layout = build_population_round(tree, pcfg)
    from benchmarks.packed_bench import build_chaos_fn
    _, sanitize_fn, _ = build_chaos_fn(tree)

    gp_flat = layout.pack(g_prev).astype(jnp.bfloat16)
    age_flat = layout.pack_age(age).astype(jnp.int8)
    ts0 = packing.init_threshold_state()
    seed0 = jnp.int32(0)

    calls, *copies, reads = _traced_counts(pop_fn, tree, gp_flat, age_flat,
                                           ts0, seed0)
    res = {"d_valid": layout.d_valid, "d_packed": layout.d_packed,
           "population_n_clients": pcfg.n_clients,
           "fused_calls_population": calls,
           "copies_population": tuple(copies),
           "g_reads_population": reads}

    us, _ = timed_med(lambda: jax.block_until_ready(
        pop_fn(tree, gp_flat, age_flat, ts0, seed0)), repeats=repeats)
    res["population_us"] = us
    us, _ = timed_med(lambda: jax.block_until_ready(
        sanitize_fn(tree, gp_flat, age_flat, ts0)), repeats=repeats)
    res["sanitize_us"] = us
    # population overhead vs the sanitize round it extends: the stateless
    # availability draw is O(n_clients) uniforms — a simulation-only cost
    # (recorded, not guarded: shared-runner denominators swing)
    res["population_vs_sanitize"] = res["sanitize_us"] / res["population_us"]
    return res


def bench_scan(n_clients, rounds=64, repeats=3):
    """Client-rounds/sec of the compiled population scan."""
    cfg = PopulationConfig(n_clients=n_clients,
                           cohort_size=min(n_clients, 4096),
                           participants=16, avail=0.9, mode="ge",
                           burst=8.0)
    key = jax.random.PRNGKey(0)
    jax.block_until_ready(
        population.population_scan_jit(cfg, rounds, key))   # compile
    ts = []
    for _ in range(max(repeats, 3)):
        t0 = time.perf_counter()
        jax.block_until_ready(
            population.population_scan_jit(cfg, rounds, key))
        ts.append(time.perf_counter() - t0)
    sec = float(np.median(ts))
    return {"n_clients": n_clients, "rounds": rounds, "scan_s": sec,
            "client_rounds_per_s": n_clients * rounds / sec}


def run(fast: bool = True):
    res = bench_round(*((12, 192, 8192) if fast else (24, 320, 32000)))
    scans = [bench_scan(100_000)]
    if not fast:
        scans.append(bench_scan(1_000_000))
    res["scans"] = scans
    rows = [("population/round", res["population_us"],
             f"vs_sanitize={res['population_vs_sanitize']:.2f}x "
             f"reads={res['g_reads_population']}")]
    for s in scans:
        rows.append((f"population/scan_{s['n_clients']}",
                     s["scan_s"] * 1e6,
                     f"client_rounds_per_s={s['client_rounds_per_s']:.3g}"))
    detail = {**res,
              "note": "population = the launch-path fused round with the "
                      "stateless population enabled (availability draw + "
                      "participation rescale + churn-erase blocks through "
                      "sanitize); structural counters guarded by "
                      "tools/check_bench_regression.py, the "
                      "population_vs_sanitize ratio recorded only (the "
                      "O(n_clients) uniform draw is a simulation cost and "
                      "the shared-runner denominator swings); scan_* = "
                      "compiled lax.scan over the packed cohort grid, "
                      "client_rounds_per_s is the throughput headline"}
    out_dir = os.path.join(os.path.dirname(__file__), "artifacts")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "population_bench.json"), "w") as f:
        json.dump(detail, f, indent=1)
    return rows, detail


def smoke() -> dict:
    """CI gate: the population-enabled round keeps the production memory
    discipline — exactly 1 pack, 1 unpack, ONE trace-time read of the
    packed gradient buffer, one fused kernel launch — and the 1e5-client
    compiled scan completes.  No wall-clock assertions (see
    packed_bench.smoke for why)."""
    res = bench_round(2, 32, 256, repeats=1)
    assert res["fused_calls_population"] == 1, res
    assert res["copies_population"] == (1, 1), res
    assert res["g_reads_population"] == 1, res
    scan = bench_scan(100_000, rounds=32, repeats=1)
    assert np.isfinite(scan["client_rounds_per_s"])
    res["scans"] = [scan]
    out_dir = os.path.join(os.path.dirname(__file__), "artifacts")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "population_bench_smoke.json"),
              "w") as f:
        json.dump(res, f, indent=1)
    print(json.dumps(res, indent=1))
    print(f"[population_bench --smoke] OK: population round = "
          f"{res['g_reads_population']} read of g, "
          f"{res['copies_population']} (pack, unpack) copies, "
          f"{res['fused_calls_population']} fused call; 1e5-client scan "
          f"at {scan['client_rounds_per_s']:.3g} client-rounds/s")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    rows, detail = run(fast=not args.full)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(json.dumps(detail, indent=1))


if __name__ == "__main__":
    main()
