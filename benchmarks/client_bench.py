"""Streaming client-aggregation benchmark: live-buffer bytes + throughput.

Two claims ride this bench (DESIGN.md §17):

* **structural / memory** — the FL trainer's client phase is a
  ``lax.scan`` over cohort chunks: with ``client_chunk = C < N`` the
  traced round holds NO (N, d) float32 intermediate, the largest live
  client-side gradient buffer is O(C * d) (read off the jaxpr's avals,
  machine-independent), there is exactly ONE streaming accumulation pass
  per traced round (``trainer.CLIENT_STREAM_PASSES``), and the packed
  server phase keeps its one instrumented read of the persisted gradient
  buffer (``packing.G_READS``) with the streaming fold in front of it.
* **throughput** — clients/sec of the compiled round at N >= 512, per
  chunk size, so a chunking regression shows up as a number.

The problem is sized so the DATA stays small relative to the gradient
matrix the dense path materialises: a linear regression with weight
(8, m) has d = 8 m gradient coordinates but only 8 + m floats per sample,
so at N = 512, d = 2048 the historical (N, d) buffer dominates every
other live array and the jaxpr max-bytes metric isolates it cleanly.

Writes benchmarks/artifacts/client_bench.json (``--smoke``:
client_bench_smoke.json, with the structural counters asserted) — wired
into CI next to ``packed_bench --smoke`` and guarded by
tools/check_bench_regression.py.  The committed baseline
benchmarks/BENCH_clients.json records a full run.

  PYTHONPATH=src python -m benchmarks.client_bench [--smoke]
"""

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import oac, packing
from repro.fl import trainer as fl_trainer
from repro.fl.trainer import FLConfig

_CH = oac.ChannelConfig(fading="rayleigh", mean=1.0, noise_std=0.1)


def make_problem(n_clients: int, m: int, h: int = 1, b: int = 2,
                 seed: int = 0):
    """Linear regression with weight (8, m): d = 8 m gradient coordinates
    per client, 8 + m floats per sample."""
    rng = np.random.default_rng(seed)
    params0 = {"w": jnp.asarray(rng.normal(size=(8, m)).astype("f4"))}
    xs = jnp.asarray(rng.normal(size=(n_clients, h, b, 8)).astype("f4"))
    ys = jnp.asarray(rng.normal(size=(n_clients, h, b, m)).astype("f4"))

    def loss_fn(p, x, y):
        return 0.5 * jnp.mean((x @ p["w"] - y) ** 2)

    return params0, loss_fn, xs, ys


def _fl(n_clients: int, chunk, backend: str = "exact") -> FLConfig:
    return FLConfig(n_clients=n_clients, local_steps=1, batch_size=2,
                    local_lr=0.05, global_lr=0.05, rounds=1,
                    compression_ratio=0.1, channel=_CH, backend=backend,
                    client_chunk=chunk, seed=0)


def _build(fl: FLConfig, m: int):
    params0, loss_fn, xs, ys = make_problem(fl.n_clients, m)
    state, unravel = fl_trainer.init_server(params0, fl)
    d = state.w.shape[0]
    step = fl_trainer.make_fl_step(fl, unravel, loss_fn, d)
    args = (jax.random.PRNGKey(0), state.w, state.g, state.age,
            state.sel_count, xs, ys, state.residual, state.theta,
            state.ctrl)
    return step, args, d


def _walk_avals(jaxpr, out):
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                out.append(aval)
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (list, tuple)) else (p,)):
                inner = getattr(sub, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    _walk_avals(inner, out)
                elif hasattr(sub, "eqns"):
                    _walk_avals(sub, out)
    return out


def trace_metrics(fl: FLConfig, m: int) -> dict:
    """One fresh trace of the round: (max live client-matrix bytes,
    count of (N, d) f32 avals, stream passes, packed-g reads)."""
    step, args, d = _build(fl, m)
    passes0 = fl_trainer.CLIENT_STREAM_PASSES
    reads0 = packing.G_READS
    closed = jax.make_jaxpr(step)(*args)
    passes = fl_trainer.CLIENT_STREAM_PASSES - passes0
    reads = packing.G_READS - reads0
    avals = _walk_avals(closed.jaxpr, [])
    mats = [a for a in avals
            if len(a.shape) == 2 and a.shape[1] == d
            and a.dtype == jnp.float32]
    max_bytes = max((int(a.shape[0]) * d * 4 for a in mats), default=0)
    nd_live = sum(1 for a in mats if a.shape[0] == fl.n_clients)
    return {"d": d, "max_live_matrix_bytes": max_bytes,
            "nd_live": nd_live, "stream_passes": passes, "g_reads": reads}


def bench_throughput(fl: FLConfig, m: int, rounds: int = 8,
                     repeats: int = 3) -> float:
    """Clients/sec of the compiled round (median over repeats)."""
    step, args, _ = _build(fl, m)
    jstep = jax.jit(step)
    jax.block_until_ready(jstep(*args))          # compile
    ts = []
    for _ in range(max(repeats, 3)):
        t0 = time.perf_counter()
        for _ in range(rounds):
            jax.block_until_ready(jstep(*args))
        ts.append(time.perf_counter() - t0)
    sec = float(np.median(ts))
    return fl.n_clients * rounds / sec


def run(n_clients: int, m: int, chunks, throughput: bool = True) -> dict:
    d = 8 * m
    res = {"n_clients": n_clients, "d": d, "chunks": list(chunks),
           "live_bytes": {}, "clients_per_s": {}}
    for c in chunks:
        tm = trace_metrics(_fl(n_clients, c), m)
        res["live_bytes"][str(c)] = tm["max_live_matrix_bytes"]
        if c == chunks[0]:                       # smallest chunk
            res["client_nd_live"] = tm["nd_live"]
            res["client_stream_passes"] = tm["stream_passes"]
        if throughput:
            res["clients_per_s"][str(c)] = bench_throughput(
                _fl(n_clients, c), m)
    dense = trace_metrics(_fl(n_clients, None), m)
    res["live_bytes"]["dense"] = dense["max_live_matrix_bytes"]
    # the headline: the chunked round's largest live client matrix scales
    # with C, not N (the dense fold pays the full (N, d) buffer)
    res["live_scaling"] = (res["live_bytes"][str(chunks[0])]
                           / max(res["live_bytes"]["dense"], 1))
    packed = trace_metrics(_fl(n_clients, chunks[0], backend="packed"), m)
    res["g_reads_fl_packed"] = packed["g_reads"]
    return res


def check(res: dict, chunks) -> None:
    n = res["n_clients"]
    assert res["client_stream_passes"] == 1, res
    assert res["client_nd_live"] == 0, res
    assert res["g_reads_fl_packed"] == 1, res
    c0 = chunks[0]
    # O(C * d) with one-chunk slack for scan double-buffering
    assert res["live_bytes"][str(c0)] <= 2 * c0 * res["d"] * 4, res
    assert res["live_bytes"]["dense"] >= n * res["d"] * 4, res
    assert res["live_scaling"] <= 2 * c0 / n + 1e-9, res


def _write(res: dict, name: str) -> None:
    out_dir = os.path.join(os.path.dirname(__file__), "artifacts")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(res, f, indent=1)


def smoke() -> dict:
    """CI gate: one streaming accumulation pass per traced round, no live
    (N, d) gradient matrix with C < N, the packed server phase keeps its
    single instrumented read of the persisted gradient buffer, and the
    largest live client matrix is O(C * d).  Trace-level only — no
    wall-clock assertions (shared runners)."""
    chunks = (8,)
    res = run(n_clients=64, m=32, chunks=chunks, throughput=False)
    check(res, chunks)
    _write(res, "client_bench_smoke.json")
    print(json.dumps(res, indent=1))
    print(f"[client_bench --smoke] OK: {res['client_stream_passes']} "
          f"stream pass, {res['client_nd_live']} live (N, d) buffers, "
          f"g_reads(packed)={res['g_reads_fl_packed']}, live bytes "
          f"C=8: {res['live_bytes']['8']} vs dense "
          f"{res['live_bytes']['dense']}")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    chunks = (8, 64, 512)
    res = run(n_clients=512, m=256, chunks=chunks)
    check(res, chunks)
    _write(res, "client_bench.json")
    for c in chunks:
        print(f"client/chunk_{c},{res['live_bytes'][str(c)]},"
              f"clients_per_s={res['clients_per_s'][str(c)]:.3g}")
    print(json.dumps(res, indent=1))


if __name__ == "__main__":
    main()
