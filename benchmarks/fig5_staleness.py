"""Fig. 5 — staleness statistics: (a) average AoU per round, (b) per-entry
participation frequency after the run."""

import time

import numpy as np

from benchmarks.common import make_task, run_policy

POLICIES = ("fairk", "topk", "agetopk", "toprand", "roundrobin")


def run(fast: bool = True):
    rounds = 100 if fast else 300
    task = make_task(fast=fast)
    rows, detail = [], {}
    for policy in POLICIES:
        t0 = time.perf_counter()
        h = run_policy(task, policy, rounds)
        us = (time.perf_counter() - t0) / rounds * 1e6
        tail = np.mean(h["mean_aou"][rounds // 2:])
        never = float((h["sel_count"] == 0).mean())
        gini_src = np.sort(h["sel_count"])
        lorenz = np.cumsum(gini_src) / max(gini_src.sum(), 1)
        gini = float(1 - 2 * lorenz.mean())
        detail[policy] = {"mean_aou_curve": h["mean_aou"],
                          "mean_aou_tail": float(tail),
                          "frac_never_selected": never,
                          "participation_gini": gini}
        rows.append((f"fig5/{policy}", us,
                     f"meanAoU={tail:.1f};never={never:.2f};gini={gini:.2f}"))
    return rows, detail
