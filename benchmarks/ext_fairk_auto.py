"""Beyond-paper extension — FAIR-k-auto: adapt the magnitude share k_M/k
online from the measured gradient concentration (Gini of |g_t|, checked
every 10 rounds).

Motivation: Fig. 4's two synthetic regimes show the optimal k_M/k depends on
the gradient spectrum (flat -> low k_M; heavy-tailed -> high k_M).  The
controller removes that last tuning knob: it matches the best fixed setting
in both regimes without knowing which one it is in."""

import time

from benchmarks.common import make_task
from repro.core.oac import ChannelConfig
from repro.fl import FLConfig, train


def run(fast: bool = True):
    rounds = 120 if fast else 400
    task = make_task(fast=fast)
    rows, detail = [], {}
    for policy, kmf in (("fairk", 0.75), ("fairk", 0.25),
                        ("fairk_auto", 0.5)):
        fl = FLConfig(n_clients=task.n_clients, local_steps=5, batch_size=20,
                      local_lr=0.05, global_lr=0.05, rounds=rounds,
                      policy=policy, k_m_frac=kmf, compression_ratio=0.1,
                      channel=ChannelConfig(fading="rayleigh", mean=1.0,
                                            noise_std=0.1))
        t0 = time.perf_counter()
        h = train(fl, task.params0, task.loss_fn,
                  lambda t: task.sample_round(t), eval_fn=task.eval_fn,
                  eval_every=rounds)
        us = (time.perf_counter() - t0) / rounds * 1e6
        tag = f"{policy}_km{kmf}"
        path = sorted(set(h.get("km_frac", [])))
        detail[tag] = {"acc": h["acc"][-1], "km_path": path}
        rows.append((f"ext/fairk_auto/{tag}", us,
                     f"acc={h['acc'][-1]:.3f};km_path={path}"))
    return rows, detail
