"""Beyond-paper extension — FAIR-k-auto: adapt the magnitude share k_M/k
online, fully in-graph (core/controller.py, DESIGN.md §12).

Motivation: Fig. 4's two synthetic regimes show the optimal k_M/k depends on
the gradient spectrum (flat -> low k_M; heavy-tailed -> high k_M).  The
controller removes that last tuning knob by regulating the measured
staleness quantile against the Lemma-1 stationary prediction — a sticky
spectrum starves the age stage (staler than predicted -> lower k_M), a
well-mixed one doesn't (fresher -> higher k_M).  Unlike the historical
host-side Gini heuristic it costs zero device syncs and zero recompiles:
the split rides as traced controller state through ONE compiled step."""

import time

from benchmarks.common import make_task
from repro.core.oac import ChannelConfig
from repro.fl import FLConfig, train


def run(fast: bool = True):
    rounds = 120 if fast else 400
    task = make_task(fast=fast)
    rows, detail = [], {}
    for policy, kmf in (("fairk", 0.75), ("fairk", 0.25),
                        ("fairk_auto", 0.5)):
        fl = FLConfig(n_clients=task.n_clients, local_steps=5, batch_size=20,
                      local_lr=0.05, global_lr=0.05, rounds=rounds,
                      policy=policy, k_m_frac=kmf, compression_ratio=0.1,
                      channel=ChannelConfig(fading="rayleigh", mean=1.0,
                                            noise_std=0.1))
        t0 = time.perf_counter()
        h = train(fl, task.params0, task.loss_fn,
                  lambda t: task.sample_round(t), eval_fn=task.eval_fn,
                  eval_every=rounds)
        us = (time.perf_counter() - t0) / rounds * 1e6
        tag = f"{policy}_km{kmf}"
        km = h.get("km_frac", [])
        path = {"start": round(km[0], 3), "end": round(km[-1], 3),
                "min": round(min(km), 3), "max": round(max(km), 3)}
        detail[tag] = {"acc": h["acc"][-1], "km_path": path}
        rows.append((f"ext/fairk_auto/{tag}", us,
                     f"acc={h['acc'][-1]:.3f};"
                     f"km={path['start']}->{path['end']}"))
    return rows, detail
