"""Fig. 9 — prototype path: one-bit (sign + FSK majority vote) transport at
rho = 20%, FAIR-k vs baselines, on the EMNIST-like task (the paper's
prototype trains a 109k-param CNN on EMNIST letters; we reduce image size
and rounds for the CPU budget — see DESIGN.md §7)."""

import time

from benchmarks.common import make_task, run_policy
from repro.core.oac import ChannelConfig


def run(fast: bool = True):
    rounds = 80 if fast else 300
    task = make_task(fast=fast, n_classes=26, model="mlp")
    channel = ChannelConfig(fading="none", mean=1.0, noise_std=2.0)
    rows, detail = [], {}
    for policy in ("fairk", "topk", "toprand"):
        t0 = time.perf_counter()
        h = run_policy(task, policy, rounds, rho=0.2, one_bit=True,
                       lr=0.003, channel=channel)
        us = (time.perf_counter() - t0) / rounds * 1e6
        detail[policy] = h["acc"][-1]
        rows.append((f"fig9/onebit/{policy}", us,
                     f"acc={h['acc'][-1]:.3f}"))
    return rows, detail
