"""Fig. 7 — effect of the local-iteration count H: training still converges
at large H (the paper's fine-grained L_g/L_h analysis predicts tolerance to
long local periods), and FAIR-k stays ahead of Top-k throughout."""

import time

from benchmarks.common import make_task, run_policy


def run(fast: bool = True):
    rounds = 80 if fast else 400
    hs = (1, 5, 10) if fast else (1, 5, 20)
    task = make_task(fast=fast)
    rows, detail = [], {}
    for h_steps in hs:
        for policy in ("fairk", "topk"):
            t0 = time.perf_counter()
            h = run_policy(task, policy, rounds, local_steps=h_steps)
            us = (time.perf_counter() - t0) / rounds * 1e6
            detail[f"H{h_steps}/{policy}"] = h["acc"][-1]
            rows.append((f"fig7/H{h_steps}/{policy}", us,
                         f"acc={h['acc'][-1]:.3f}"))
    return rows, detail
