"""Kernel micro-benchmarks: XLA-ref wall time on CPU (the deployable perf
numbers are TPU-side; interpret-mode timings are correctness-path only)."""

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.kernels import ops


def run(fast: bool = True):
    d = 1 << 20 if fast else 1 << 24
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=d).astype("f4"))
    g_old = jnp.asarray(rng.normal(size=d).astype("f4"))
    age = jnp.asarray(rng.integers(0, 40, d).astype("f4"))
    mask = jnp.asarray((rng.random(d) < 0.1).astype("f4"))
    votes = jnp.asarray(np.sign(rng.normal(size=(50, 1 << 14))).astype("f4"))

    rows = []
    us, _ = timed(lambda: jax.block_until_ready(
        ops.block_topk(x, 4096, 16, mode="ref")))
    rows.append(("kernels/block_topk_ref", us, f"d={d}"))
    us, _ = timed(lambda: jax.block_until_ready(
        ops.two_stage_topk(x, k=d // 100, mode="ref")))
    rows.append(("kernels/two_stage_topk_ref", us, f"k={d//100}"))
    us, _ = timed(lambda: jax.block_until_ready(
        ops.aou_merge(x, g_old, age, mask, mode="ref")))
    rows.append(("kernels/aou_merge_ref", us,
                 f"bytes={4*4*d}"))
    us, _ = timed(lambda: jax.block_until_ready(
        ops.sign_mv(votes, mode="ref")))
    rows.append(("kernels/sign_mv_ref", us, f"votes={votes.shape}"))
    tm = jnp.float32(1.2)
    ta = jnp.float32(30.0)
    us, _ = timed(lambda: jax.block_until_ready(
        ops.fairk_update(x, g_old, age, tm, ta, mode="ref")))
    rows.append(("kernels/fairk_update_ref", us, f"d={d}"))
    # exact top-k baseline for context
    us, _ = timed(lambda: jax.block_until_ready(
        jax.lax.top_k(jnp.abs(x), d // 100)))
    rows.append(("kernels/exact_topk_baseline", us, f"k={d//100}"))
    return rows, {}
