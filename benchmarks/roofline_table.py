"""Roofline table — reads the dry-run artifacts produced by
``repro.launch.dryrun`` and summarizes the three-term roofline per
(arch x shape x mesh).  Run the dry-run sweep first:

  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load_artifacts():
    out = {}
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            out[os.path.basename(path)[:-5]] = json.load(f)
    return out


def run(fast: bool = True):
    arts = load_artifacts()
    rows, detail = [], {}
    for tag, art in arts.items():
        r = art.get("roofline", {})
        if not r:
            continue
        dom = r["dominant"]
        rows.append((
            f"roofline/{tag}",
            art.get("compile_s", 0.0) * 1e6,
            f"dom={dom};step_ms={r['step_time_s']*1e3:.2f};"
            f"comp_ms={r['compute_s']*1e3:.2f};mem_ms={r['memory_s']*1e3:.2f};"
            f"coll_ms={r['collective_s']*1e3:.2f};"
            f"useful={r['usefulness']:.2f};"
            f"hbm_gb={art['memory']['per_device_total']/2**30:.1f}",
        ))
        detail[tag] = r
    if not rows:
        rows.append(("roofline/no_artifacts", 0.0,
                     "run repro.launch.dryrun first"))
    return rows, detail
