"""Table I — empirical smoothness constants: the conventional per-client
L-tilde^2 vs the fine-grained L_g^2 (global) and L_h^2 (heterogeneity),
across Dirichlet levels.  The paper's point: L_tilde >> L_g >> L_h, and
L_tilde grows sharply as data gets more non-iid."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lipschitz import estimate_constants
from repro.data import partition, synthetic
from repro.models import cnn


def run(fast: bool = True):
    n_clients = 8 if fast else 20
    spec = synthetic.DatasetSpec("lip", (12, 12, 1), 6, 4000, 100,
                                 noise_std=1.0, sparsity=0.1)
    (xtr, ytr), _ = synthetic.make_dataset(spec, seed=0)
    rows, detail = [], {}
    for dir_alpha in ((0.1, 0.3, 1.0) if fast else (0.1, 0.3, 0.5, 1.0)):
        parts = partition.dirichlet_partition(ytr, n_clients, dir_alpha,
                                              seed=0)
        params = cnn.init_mlp_classifier(jax.random.PRNGKey(0), 144, 6,
                                         hidden=(32,))
        subsets = [(jnp.asarray(xtr[p[:300]]), jnp.asarray(ytr[p[:300]]))
                   for p in parts]

        @jax.jit
        def client_grad(p, x, y):
            return jax.grad(
                lambda q: cnn.softmax_xent(cnn.mlp_classifier(q, x), y))(p)

        def grad_fn(p, n):
            x, y = subsets[n]
            return client_grad(p, x, y)

        t0 = time.perf_counter()
        consts = estimate_constants(jax.random.PRNGKey(1), params, grad_fn,
                                    n_clients, n_pairs=4 if fast else 8)
        us = (time.perf_counter() - t0) * 1e6
        detail[str(dir_alpha)] = consts
        rows.append((f"table1/dir_{dir_alpha}", us,
                     f"Lt2={consts['L_tilde2']:.2f};Lg2={consts['L_g2']:.2f};"
                     f"Lh2={consts['L_h2']:.2f}"))
    return rows, detail
