"""Benchmark orchestrator — one module per paper table/figure plus the
kernel micro-benchmarks and the dry-run roofline table.

Prints ``name,us_per_call,derived`` CSV rows (stdout) and writes the full
detail payload to benchmarks/artifacts/results.json.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig4,fig5]
"""

import argparse
import json
import os
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import (engine_bench, ext_error_feedback, ext_fairk_auto,
                        fig3_aou, fig4_convergence, fig5_staleness,
                        fig6_km_ratio, fig7_local_epochs, fig9_prototype,
                        kernels_bench, packed_bench, roofline_table,
                        table1_lipschitz)

MODULES = {
    "fig3": fig3_aou, "fig4": fig4_convergence, "fig5": fig5_staleness,
    "fig6": fig6_km_ratio, "fig7": fig7_local_epochs,
    "table1": table1_lipschitz, "fig9": fig9_prototype,
    "kernels": kernels_bench, "roofline": roofline_table,
    "engine": engine_bench, "packed": packed_bench,
    "ext_ef": ext_error_feedback, "ext_auto": ext_fairk_auto,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (slow on CPU)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of " + ",".join(MODULES))
    args = ap.parse_args()
    selected = ([m.strip() for m in args.only.split(",") if m.strip()]
                or list(MODULES))

    print("name,us_per_call,derived")
    details, failures = {}, []
    for name in selected:
        mod = MODULES[name]
        t0 = time.time()
        try:
            rows, detail = mod.run(fast=not args.full)
        except Exception as e:
            failures.append((name, repr(e)))
            traceback.print_exc()
            continue
        details[name] = detail
        for row in rows:
            print(f"{row[0]},{row[1]:.1f},{row[2]}", flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)

    out = os.path.join(os.path.dirname(__file__), "artifacts")
    os.makedirs(out, exist_ok=True)
    with open(os.path.join(out, "results.json"), "w") as f:
        json.dump(details, f, indent=1)
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
