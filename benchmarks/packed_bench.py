"""Packed server phase benchmark: per-leaf loop vs ONE fused FAIR-k pass.

Times the three server-phase execution strategies on a transformer-scale
parameter pytree (per-layer leaves, torch-style — the worst case for the
per-leaf loop):

* ``per_leaf``    — the historical path: one sampled-quantile estimation +
  one ``fairk_update`` launch per parameter leaf (~100 of each per step).
* ``packed``      — core.packing: pack (g, g_prev, age) into lane-aligned
  flat buffers, ONE quantile estimation + ONE fused pass for the whole
  model, unpack.
* ``packed_warm`` — packed with warm-start thresholds on a steady-state
  round: the strided-sample quantile pass is skipped entirely (lax.cond on
  the carried threshold state).
* ``persisted``   — the launch.steps production shape: g_prev / age (and
  the EF residual) live as flat buffers ACROSS rounds, so a steady-state
  round packs exactly ONE tree (the fresh grads) and unpacks exactly ONE
  (g_t for the optimizer) — zero re-pack copies of the carried state.
  This is the pre-fused-stats production path: its round still pays 3
  trace-time reads of the packed gradient buffer (quantile bootstrap +
  fused kernel + masked count pass).
* ``persisted_ef`` — persisted plus the fused kernel's residual
  (error-feedback) stage.
* ``persisted_warm`` — persisted on a steady-state round whose lax.cond
  skips the quantile pass at runtime (the count passes remain).
* ``fused_stats``  — the one-HBM-pass round (DESIGN.md §11): counts and
  threshold-re-estimation histograms emitted from inside the kernel, so
  the steady-state round traces exactly ONE read of the gradient buffer
  and even trust-region re-estimation rounds never re-read it.
* ``adaptive``     — fused_stats plus the in-graph budget controller
  (core/controller.py, DESIGN.md §12): the k_M/k split rides as traced
  controller state and the update runs inside the same compiled round.
  Still ONE read of g, and — asserted by the controller's trace counter —
  ONE compilation across arbitrarily many k_m_frac operating points.
* ``async``        — the ``--async-agg`` double-buffered round
  (DESIGN.md §13): the straggler share of the fresh grads is deferred
  into the carried ``shadow`` buffer, last round's deferred share merges
  in its place with ``straggler_lag`` rounds of extra age, and the
  optimizer consumes LAST round's merged gradient (``pending``).  The
  optimizer-facing unpack therefore depends only on carried state — the
  round's pack + fused kernel sits off the optimizer's critical path,
  and ``overlap_ratio`` measures the wall-clock fraction of the round
  that overlap can hide.  Still 1 pack, 1 unpack, ONE read of g.
* ``sanitize``     — the graceful-degradation round's PRODUCTION shape
  (DESIGN.md §14): non-finite masking armed inside the fused launch, no
  simulated faults.  ``sanitize_vs_fused`` is the <=5%
  robustness-overhead claim: the masking is a few elementwise ops riding
  the one kernel pass, not a second pass.
* ``chaos``        — the same round under the in-graph fault harness:
  per-round NaN/Inf corruption of the aggregated uplink plus
  block-granular deep-fade erasures, degraded through ``sanitize=True``.
  The injection's full-buffer PRNG draws are a simulation-only cost
  (dominant on CPU-XLA, cheap on TPU) — structurally the round still
  pays 1 pack, 1 unpack, ONE read of g.
* ``channel``      — the wireless fading round (DESIGN.md §16): the
  carried per-block AR(1) fading chain advances in-graph, truncation
  outages erase through the same sanitize path, the CSI misalignment
  factor is one elementwise multiply — same 1-pack/1-unpack/1-read
  discipline.

Emits CSV rows through ``benchmarks.run`` and writes
benchmarks/artifacts/packed_bench.json.  ``--smoke`` runs a tiny pytree and
asserts the structural claims (packed traces exactly ONE fused update vs
one per leaf; the persisted path performs ZERO re-pack copies of
g_prev/age per steady-state round; the fused_stats round traces exactly
ONE read of the packed gradient buffer vs 3; the adaptive round keeps the
one-read invariant and never recompiles across split changes) — wired
into CI, which also guards the measured speedup ratios against
benchmarks/BENCH_packed.json (tools/check_bench_regression.py).

  PYTHONPATH=src python -m benchmarks.packed_bench [--full | --smoke]
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.core import channel, controller, faults, packing
from repro.core.engine import EngineConfig, SelectionEngine, index_jitter
from repro.kernels import ops


def timed_med(fn, repeats=3):
    """Median-of-N single-round timing (us).  The per-round variants
    differ by tens of ms on a ~100 ms base; a mean over back-to-back runs
    lets one co-tenant hiccup swamp the ratio, the median does not."""
    out = fn()                                  # warmup / compile
    ts = []
    for _ in range(max(repeats, 5)):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6, out


def make_transformer_tree(n_layers: int, d_model: int, vocab: int,
                          seed: int = 0):
    """Per-layer transformer pytree (unstacked leaves — the per-leaf loop's
    worst case and the layout's target shape)."""
    rng = np.random.default_rng(seed)
    ff = 4 * d_model

    def arr(*shape):
        return jnp.asarray(rng.standard_normal(shape).astype("f4"))

    tree = {"embed": arr(vocab, d_model), "head": arr(d_model, vocab),
            "final_norm": arr(d_model)}
    for i in range(n_layers):
        tree[f"layer_{i:02d}"] = {
            "wq": arr(d_model, d_model), "wk": arr(d_model, d_model),
            "wv": arr(d_model, d_model), "wo": arr(d_model, d_model),
            "wu": arr(d_model, ff), "wd": arr(ff, d_model),
            "norm1": arr(d_model), "norm2": arr(d_model),
        }
    return tree


def _server_state(tree, seed=1):
    rng = np.random.default_rng(seed)
    g_prev = jax.tree.map(
        lambda p: jnp.asarray(rng.standard_normal(p.shape).astype("f4")),
        tree)
    age = jax.tree.map(
        lambda p: jnp.asarray(rng.integers(0, 40, p.shape).astype("i1")),
        tree)
    return g_prev, age


def _mk_engine(backend, d_or_layout, *, warm=False, rho=0.1,
               fused_stats=False):
    cfg = EngineConfig(policy="fairk", backend=backend, rho=rho,
                       k_m_frac=0.75, warm_start=warm,
                       fused_stats=fused_stats)
    if backend == "packed":
        return SelectionEngine(cfg, d_or_layout.d_packed,
                               layout=d_or_layout)
    return SelectionEngine(cfg, d_or_layout)


def build_per_leaf_fn(tree):
    """The historical update_phase: per-leaf threshold engines."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    engines = [_mk_engine("threshold", int(np.prod(l.shape)))
               for l in leaves]

    def per_leaf(g_tree, gp_tree, age_tree):
        gs = treedef.flatten_up_to(g_tree)
        gps = treedef.flatten_up_to(gp_tree)
        ages = treedef.flatten_up_to(age_tree)
        out_g, out_age = [], []
        for eng, g, gp, ag in zip(engines, gs, gps, ages):
            g_t, age_next, _ = eng.select_and_merge(
                g.reshape(-1), gp.reshape(-1).astype(jnp.float32),
                ag.reshape(-1).astype(jnp.float32))
            out_g.append(g_t.reshape(g.shape))
            out_age.append(age_next.reshape(g.shape).astype(jnp.int8))
        return (jax.tree_util.tree_unflatten(treedef, out_g),
                jax.tree_util.tree_unflatten(treedef, out_age))

    return jax.jit(per_leaf), len(leaves)


def build_packed_fn(tree, *, warm):
    layout = packing.PackedLayout.from_tree(tree)
    eng = _mk_engine("packed", layout, warm=warm)

    def packed(g_tree, gp_tree, age_tree, tstate):
        g_t, age_tree_out, stats = eng.select_and_merge_tree(
            g_tree, gp_tree, age_tree, tstate=tstate)
        return (g_t,
                jax.tree.map(lambda x: x.astype(jnp.int8), age_tree_out),
                stats["tstate"])

    return jax.jit(packed), layout, eng


def build_persisted_fn(tree, *, warm, error_feedback=False,
                       fused_stats=False):
    """The launch.steps._packed_server_phase shape: carried state is FLAT
    (g_prev bf16, age int8, optional EF residual f32) — only the fresh
    grads are packed, only the optimizer-facing g_t is unpacked.
    ``fused_stats=True`` is the one-HBM-pass round (counts + histograms
    out of the kernel, thresholds re-estimated from the carried state)."""
    layout = packing.PackedLayout.from_tree(tree)
    eng = _mk_engine("packed", layout, warm=warm, fused_stats=fused_stats)

    def persisted(g_tree, gp_flat, age_flat, res_flat, tstate):
        g_flat = layout.pack(g_tree)           # the only pack per round
        g_t, age_next, stats = eng.select_and_merge(
            g_flat, gp_flat, age_flat, tstate=tstate, residual=res_flat)
        g_t_tree = layout.unpack(g_t, cast=False)   # optimizer-facing tree
        return (g_t_tree, g_t.astype(jnp.bfloat16),
                age_next.astype(jnp.int8),
                stats.get("residual"), stats["tstate"])

    def flat_state(gp_tree, age_tree):
        gp = layout.pack(gp_tree).astype(jnp.bfloat16)
        ag = layout.pack_age(age_tree).astype(jnp.int8)
        res = (jnp.zeros((layout.d_packed,), jnp.float32)
               if error_feedback else None)
        return gp, ag, res

    return jax.jit(persisted), flat_state, layout


def build_adaptive_fn(tree, *, rho=0.1):
    """The adaptive-``k_m`` production round: the persisted fused-stats
    shape plus the in-graph BudgetController — the split comes off the
    carried controller state and the controller update rides the same
    compiled round (launch.steps._packed_server_phase with
    ``adaptive_km``)."""
    layout = packing.PackedLayout.from_tree(tree)
    eng = _mk_engine("packed", layout, warm=True, rho=rho, fused_stats=True)
    bc = controller.BudgetController(rho=rho)

    def adaptive(g_tree, gp_flat, age_flat, tstate, cvec):
        cs = controller.controller_state_from_vec(cvec)
        g_flat = layout.pack(g_tree)           # the only pack per round
        g_t, age_next, stats = eng.select_and_merge(
            g_flat, gp_flat, age_flat, tstate=tstate,
            k_m_frac=cs["k_m_frac"])
        cs = bc.update(cs, stats["age_hist"], stats["mag_hist"])
        g_t_tree = layout.unpack(g_t, cast=False)
        return (g_t_tree, g_t.astype(jnp.bfloat16),
                age_next.astype(jnp.int8), stats["tstate"],
                controller.controller_state_to_vec(cs))

    return jax.jit(adaptive), layout


def build_async_fn(tree, *, rho=0.1, straggler_frac=0.25, straggler_lag=1):
    """The ``--async-agg`` production round (DESIGN.md §13): the
    double-buffered launch.steps._packed_server_phase shape on top of the
    fused-stats engine.  The straggler share of the fresh grads defers
    into the carried ``shadow`` buffer, last round's deferred share merges
    in its place carrying ``straggler_lag`` rounds of extra age, and the
    optimizer-facing unpack reads the carried ``pending`` buffer — it
    depends on NOTHING this round computed, which is what makes the round
    overlappable with the next round's client compute."""
    layout = packing.PackedLayout.from_tree(tree)
    eng = _mk_engine("packed", layout, warm=True, rho=rho, fused_stats=True)

    def async_round(g_tree, gp_flat, age_flat, tstate, shadow, pending):
        g_flat = layout.pack(g_tree)           # the only pack per round
        strag = (index_jitter(layout.d_packed)
                 < straggler_frac).astype(jnp.float32)
        new_shadow = (g_flat * strag).astype(jnp.bfloat16)
        g_flat = (g_flat * (1.0 - strag) + shadow.astype(jnp.float32))
        g_t, age_next, stats = eng.select_and_merge(
            g_flat, gp_flat, age_flat, tstate=tstate,
            age_lag=straggler_lag)
        out_tree = layout.unpack(pending.astype(jnp.float32), cast=False)
        return (out_tree, g_t.astype(jnp.bfloat16),
                age_next.astype(jnp.int8), stats["tstate"],
                new_shadow, g_t.astype(jnp.bfloat16))

    def critical_path(pending):
        # exactly the slice of the round the optimizer must wait for
        return layout.unpack(pending.astype(jnp.float32), cast=False)

    return jax.jit(async_round), jax.jit(critical_path), layout


def build_chaos_fn(tree, *, rho=0.1, fade=0.05, nan_rate=1e-4):
    """The graceful-degradation round (DESIGN.md §14): the fused-stats
    production shape with the fault channels ON — per-round NaN/Inf
    corruption of the aggregated uplink plus block-granular deep-fade
    erasures, degraded through ``sanitize=True`` so poisoned coordinates
    are masked out of BOTH selection stages in the same kernel pass
    ('unsent': age climbs, EF mass rides through).  The structural claim
    is that robustness is free at the memory level: corruption/erasure
    injection is elementwise math on the packed buffer — not an extra
    instrumented read — and the sanitize masking rides the one fused
    kernel launch, so the chaos round keeps the sync round's exact
    1-pack/1-unpack/1-read discipline."""
    layout = packing.PackedLayout.from_tree(tree)
    eng = _mk_engine("packed", layout, warm=True, rho=rho, fused_stats=True)
    fcfg = faults.FaultConfig(fade=fade, nan_rate=nan_rate)

    def chaos_round(g_tree, gp_flat, age_flat, tstate, key):
        g_flat = layout.pack(g_tree)           # the only pack per round
        k_c, k_f = jax.random.split(key)
        g_flat = faults.corrupt(g_flat, k_c, fcfg)
        erase = faults.fade_mask(k_f, layout.d_packed, fcfg)
        g_t, age_next, stats = eng.select_and_merge(
            g_flat, gp_flat, age_flat, tstate=tstate, erase=erase,
            sanitize=True)
        g_t_tree = layout.unpack(g_t, cast=False)
        return (g_t_tree, g_t.astype(jnp.bfloat16),
                age_next.astype(jnp.int8), stats["tstate"])

    def sanitize_round(g_tree, gp_flat, age_flat, tstate):
        # the PRODUCTION cost of robustness: sanitize masking armed, no
        # simulated faults injected (a real deployment's faults arrive in
        # the uplink itself — the corrupt/fade draws above are the chaos
        # harness's cost, paid only when simulating)
        g_flat = layout.pack(g_tree)
        g_t, age_next, stats = eng.select_and_merge(
            g_flat, gp_flat, age_flat, tstate=tstate, sanitize=True)
        g_t_tree = layout.unpack(g_t, cast=False)
        return (g_t_tree, g_t.astype(jnp.bfloat16),
                age_next.astype(jnp.int8), stats["tstate"])

    return jax.jit(chaos_round), jax.jit(sanitize_round), layout


def build_channel_fn(tree, *, rho=0.1, pmax=10.0, gmin=0.3, csi_err=0.05):
    """The wireless fading round (DESIGN.md §16): the fused-stats
    production shape with the truncated-channel-inversion layer ON — the
    carried per-block AR(1) fading chain advances in-graph, deep-outage
    blocks erase through the same ``sanitize=True`` path the fault
    harness uses, and the CSI misalignment factor rides the packed buffer
    as one elementwise multiply.  The structural claim mirrors the chaos
    round's: the channel is elementwise math plus a tiny ``(2 n_blocks,)``
    carried chain — not an extra instrumented read of g, not an extra
    tree copy, not a second kernel launch."""
    layout = packing.PackedLayout.from_tree(tree)
    eng = _mk_engine("packed", layout, warm=True, rho=rho, fused_stats=True)
    ccfg = channel.ChannelConfig(n_clients=16, pmax=pmax, gmin=gmin,
                                 csi_err=csi_err, rho_f=0.5)

    def channel_round(g_tree, gp_flat, age_flat, tstate, fad, key):
        g_flat = layout.pack(g_tree)           # the only pack per round
        k_f, k_c = jax.random.split(key)
        new_fad, erase = channel.block_outage(fad, k_f, layout.d_packed,
                                              ccfg)
        g_flat = g_flat * channel.csi_block_factor(k_c, layout.d_packed,
                                                   ccfg)
        g_t, age_next, stats = eng.select_and_merge(
            g_flat, gp_flat, age_flat, tstate=tstate, erase=erase,
            sanitize=True)
        g_t_tree = layout.unpack(g_t, cast=False)
        return (g_t_tree, g_t.astype(jnp.bfloat16),
                age_next.astype(jnp.int8), stats["tstate"], new_fad)

    fad0 = channel.init_block_fading(channel.n_blocks(layout.d_packed,
                                                      ccfg))
    return jax.jit(channel_round), fad0, layout


def _traced_counts(fn, *args):
    """(fused launches, packs, unpacks, g reads) ONE trace of ``fn``
    records — the structural packed-vs-per-leaf, persisted-state and
    one-HBM-pass claims, independent of timers.  Counted in a single
    ``eval_shape`` because a second trace with the same signature hits the
    jit cache and never re-runs the Python body (so its counters would
    read zero)."""
    before = (ops.FAIRK_UPDATE_CALLS, packing.PACK_CALLS,
              packing.UNPACK_CALLS, packing.G_READS)
    jax.eval_shape(fn, *args)
    return (ops.FAIRK_UPDATE_CALLS - before[0],
            packing.PACK_CALLS - before[1],
            packing.UNPACK_CALLS - before[2],
            packing.G_READS - before[3])


def bench_tree(n_layers, d_model, vocab, repeats=3):
    tree = make_transformer_tree(n_layers, d_model, vocab)
    g_prev, age = _server_state(tree)
    per_leaf_fn, n_leaves = build_per_leaf_fn(tree)
    packed_fn, layout, eng = build_packed_fn(tree, warm=False)
    warm_fn, _, _ = build_packed_fn(tree, warm=True)
    persisted_fn, flat_state, _ = build_persisted_fn(tree, warm=False)
    persisted_warm_fn, _, _ = build_persisted_fn(tree, warm=True)
    persisted_ef_fn, flat_state_ef, _ = build_persisted_fn(
        tree, warm=False, error_feedback=True)
    fused_fn, _, _ = build_persisted_fn(tree, warm=True, fused_stats=True)
    adaptive_fn, _ = build_adaptive_fn(tree)
    async_fn, async_crit_fn, _ = build_async_fn(tree)
    chaos_fn, sanitize_fn, _ = build_chaos_fn(tree)
    channel_fn, fad0, _ = build_channel_fn(tree)

    ts0 = packing.init_threshold_state()
    gp_flat, age_flat, _ = flat_state(g_prev, age)
    _, _, res_flat = flat_state_ef(g_prev, age)
    calls_per_leaf, _, _, _ = _traced_counts(per_leaf_fn, tree, g_prev, age)
    # per-round tree copies: the PR-2 re-pack path packs 3 trees + unpacks
    # 2; the persisted path packs 1 (fresh grads) + unpacks 1 (g_t) — the
    # carried g_prev/age (and EF residual) are NEVER re-packed
    calls_packed, *copies_packed, _ = _traced_counts(packed_fn, tree,
                                                     g_prev, age, ts0)
    # trace-time reads of the packed gradient buffer per round: the
    # pre-fused path pays 3 (quantile bootstrap + fused kernel + masked
    # count pass); the fused-stats round pays exactly 1 (the kernel)
    _, *copies_persisted, reads_persisted = _traced_counts(
        persisted_fn, tree, gp_flat, age_flat, None, ts0)
    _, *copies_persisted_ef, _ = _traced_counts(
        persisted_ef_fn, tree, gp_flat, age_flat, res_flat, ts0)
    _, *copies_fused, reads_fused = _traced_counts(
        fused_fn, tree, gp_flat, age_flat, None, ts0)
    # the adaptive round: count its reads at trace time, then EXECUTE the
    # same jitted function at several k_m_frac operating points — the
    # controller's trace counter must advance exactly once (the split is
    # data; changing it can never recompile)
    cvec0 = controller.controller_state_to_vec(
        controller.init_controller_state(0.75))
    traces_before = controller.UPDATE_TRACES
    _, *copies_adaptive, reads_adaptive = _traced_counts(
        adaptive_fn, tree, gp_flat, age_flat, ts0, cvec0)
    for frac in (0.25, 0.5, 0.9):
        cv = controller.controller_state_to_vec(
            controller.init_controller_state(frac))
        cv = jax.block_until_ready(
            adaptive_fn(tree, gp_flat, age_flat, ts0, cv))[4]
    adaptive_traces = controller.UPDATE_TRACES - traces_before
    # the async double-buffered round: same copy/read discipline as the
    # sync fused round — the shadow mixing is plain elementwise math, not
    # a re-read of the instrumented gradient buffer, and the pending swap
    # replaces (not adds to) the optimizer-facing unpack
    calls_async, *copies_async, reads_async = _traced_counts(
        async_fn, tree, gp_flat, age_flat, ts0, gp_flat, gp_flat)
    # the chaos round: corruption + fade injection and the sanitize
    # masking all ride the single fused launch — faults cost no extra
    # instrumented read of g and no extra tree copies
    chaos_key = jax.random.PRNGKey(7)
    calls_chaos, *copies_chaos, reads_chaos = _traced_counts(
        chaos_fn, tree, gp_flat, age_flat, ts0, chaos_key)
    calls_san, *copies_san, reads_san = _traced_counts(
        sanitize_fn, tree, gp_flat, age_flat, ts0)
    # the wireless channel round: fading advance, block outage erasure
    # and the CSI multiply all ride the single fused launch — the channel
    # costs no extra instrumented read of g and no extra tree copies
    chan_key = jax.random.PRNGKey(9)
    calls_chan, *copies_chan, reads_chan = _traced_counts(
        channel_fn, tree, gp_flat, age_flat, ts0, fad0, chan_key)

    res = {"n_leaves": n_leaves, "d_valid": layout.d_valid,
           "d_packed": layout.d_packed, "k": eng.budgets()[0],
           "fused_calls_per_leaf": calls_per_leaf,
           "fused_calls_packed": calls_packed,
           "copies_packed": tuple(copies_packed),
           "copies_persisted": tuple(copies_persisted),
           "copies_persisted_ef": tuple(copies_persisted_ef),
           "copies_fused_stats": tuple(copies_fused),
           "copies_adaptive": tuple(copies_adaptive),
           "g_reads_persisted": reads_persisted,
           "g_reads_fused_stats": reads_fused,
           "g_reads_adaptive": reads_adaptive,
           "adaptive_traces": adaptive_traces,
           "fused_calls_async": calls_async,
           "copies_async": tuple(copies_async),
           "g_reads_async": reads_async,
           "fused_calls_chaos": calls_chaos,
           "copies_chaos": tuple(copies_chaos),
           "g_reads_chaos": reads_chaos,
           "fused_calls_sanitize": calls_san,
           "copies_sanitize": tuple(copies_san),
           "g_reads_sanitize": reads_san,
           "fused_calls_channel": calls_chan,
           "copies_channel": tuple(copies_chan),
           "g_reads_channel": reads_chan}

    us, _ = timed(lambda: jax.block_until_ready(
        per_leaf_fn(tree, g_prev, age)), repeats=repeats)
    res["per_leaf_us"] = us
    us, (g_t, age_next, ts1) = timed_med(lambda: jax.block_until_ready(
        packed_fn(tree, g_prev, age, ts0)), repeats=repeats)
    res["packed_us"] = us
    us, _ = timed_med(lambda: jax.block_until_ready(
        persisted_fn(tree, gp_flat, age_flat, None, ts0)), repeats=repeats)
    res["persisted_us"] = us
    us, _ = timed_med(lambda: jax.block_until_ready(
        persisted_ef_fn(tree, gp_flat, age_flat, res_flat, ts0)),
        repeats=repeats)
    res["persisted_ef_us"] = us
    # steady-state warm round: a carried state whose counts track the
    # budget and whose prediction streak is established — the lax.cond
    # takes the warm branch and the quantile pass never executes
    k = res["k"]
    ts_warm = dict(ts1, n_sel=jnp.float32(k),
                   n_sel_m=jnp.float32(round(0.75 * k)),
                   init=jnp.float32(1.0), streak=jnp.float32(10.0))
    us, _ = timed_med(lambda: jax.block_until_ready(
        warm_fn(tree, g_prev, age, ts_warm)), repeats=repeats)
    res["packed_warm_us"] = us
    us, _ = timed_med(lambda: jax.block_until_ready(
        persisted_warm_fn(tree, gp_flat, age_flat, None, ts_warm)),
        repeats=repeats)
    res["persisted_warm_us"] = us
    # fused-stats steady state: same warm carried state, but with the
    # kernel-emitted histograms attached (what a real fused round carries)
    # — trust-tripped rounds cost the SAME program (hist re-estimation is
    # scalar work), so one number covers warm AND re-estimation rounds
    _, _, _, _, ts_f = fused_fn(tree, gp_flat, age_flat, None, ts0)
    ts_fused = dict(ts_f, n_sel=jnp.float32(k),
                    n_sel_m=jnp.float32(round(0.75 * k)),
                    init=jnp.float32(1.0), streak=jnp.float32(10.0))
    us, _ = timed_med(lambda: jax.block_until_ready(
        fused_fn(tree, gp_flat, age_flat, None, ts_fused)),
        repeats=repeats)
    res["fused_stats_us"] = us
    # adaptive steady state: the warm fused round plus the in-graph
    # controller — cv carries a settled (init=1, EMA'd) controller state
    # from the executions above, so the timed program is the production
    # shape
    us, _ = timed_med(lambda: jax.block_until_ready(
        adaptive_fn(tree, gp_flat, age_flat, ts_fused, cv)),
        repeats=repeats)
    res["adaptive_us"] = us
    # async steady state: the same warm fused round plus the double
    # buffer (shadow/pending ride as bf16 flats — gp_flat stands in for
    # both, their values do not change the program).  The critical path
    # is timed separately: the optimizer only ever waits on the pending
    # unpack, everything else can overlap the next round's client compute
    us, _ = timed_med(lambda: jax.block_until_ready(
        async_fn(tree, gp_flat, age_flat, ts_fused, gp_flat, gp_flat)),
        repeats=repeats)
    res["async_us"] = us
    us, _ = timed(lambda: jax.block_until_ready(async_crit_fn(gp_flat)),
                  repeats=max(repeats, 5))
    res["async_critical_path_us"] = us
    # chaos steady state: the fused round with the fault channels on —
    # the sanitize overhead claim (DESIGN.md §14) is that degradation
    # costs a few elementwise ops riding the same program, not a second
    # pass, so chaos_vs_fused should sit near 1.0
    us, _ = timed_med(lambda: jax.block_until_ready(
        chaos_fn(tree, gp_flat, age_flat, ts_fused, chaos_key)),
        repeats=repeats)
    res["chaos_us"] = us
    us, _ = timed_med(lambda: jax.block_until_ready(
        sanitize_fn(tree, gp_flat, age_flat, ts_fused)),
        repeats=repeats)
    res["sanitize_us"] = us
    # wireless channel steady state: the fused round with the fading
    # layer on — like chaos_vs_fused, the ratio is recorded for the
    # artifact, the structural counters are what CI guards
    us, _ = timed_med(lambda: jax.block_until_ready(
        channel_fn(tree, gp_flat, age_flat, ts_fused, fad0, chan_key)),
        repeats=repeats)
    res["channel_us"] = us
    res["speedup_packed"] = res["per_leaf_us"] / res["packed_us"]
    res["speedup_warm"] = res["per_leaf_us"] / res["packed_warm_us"]
    res["warm_vs_cold"] = res["packed_us"] / res["packed_warm_us"]
    res["speedup_persisted"] = res["per_leaf_us"] / res["persisted_us"]
    res["persisted_vs_repack"] = res["packed_us"] / res["persisted_us"]
    # the headline fused-stats ratios: vs the pre-fused production round
    # (persisted, 3 reads: the cost the current path pays on every
    # bootstrap / trust-region re-estimation round — the fused path never
    # pays it again) and vs the pre-fused packed steady state
    res["speedup_fused_stats"] = res["persisted_us"] / res["fused_stats_us"]
    res["fused_vs_packed_warm"] = (res["packed_warm_us"]
                                   / res["fused_stats_us"])
    res["fused_vs_persisted_warm"] = (res["persisted_warm_us"]
                                      / res["fused_stats_us"])
    # controller overhead: the adaptive round vs the fused steady-state
    # round it extends — a ~1.0 ratio of near-identical programs, so it
    # travels across runner hardware and is safe to guard
    res["adaptive_vs_fused"] = res["fused_stats_us"] / res["adaptive_us"]
    # wall-clock round-overlap ratio (the tentpole's headline number):
    # the fraction of the async round the double buffer removes from the
    # optimizer's critical path — everything except the pending unpack
    # can run behind the next round's client compute
    res["overlap_ratio"] = (1.0 - res["async_critical_path_us"]
                            / res["async_us"])
    res["async_vs_fused"] = res["fused_stats_us"] / res["async_us"]
    # sanitize/fault overhead: the chaos round vs the fused steady-state
    # round it extends — like adaptive_vs_fused this compares
    # near-identical programs, kept in the artifact for the record (the
    # acceptance target is >= ~0.95, i.e. <= ~5% overhead) but NOT
    # guarded: the shared-runner denominator swings too much for a gate.
    # sanitize_vs_fused is the <=5% production-overhead claim (masking
    # armed, no injected faults — ~1.0); chaos_vs_fused/chaos_vs_async
    # include the chaos harness's per-round PRNG draws over the full
    # packed buffer, a simulation-only cost that dominates on CPU-XLA
    res["sanitize_vs_fused"] = res["fused_stats_us"] / res["sanitize_us"]
    res["chaos_vs_fused"] = res["fused_stats_us"] / res["chaos_us"]
    res["chaos_vs_async"] = res["async_us"] / res["chaos_us"]
    res["channel_vs_fused"] = res["fused_stats_us"] / res["channel_us"]

    # isolate the threshold stage: sampled quantile pass (bootstrap branch)
    # vs warm correction (a handful of scalar flops) — the work the warm
    # path eliminates on steady-state rounds
    warm_eng = _mk_engine("packed", layout, warm=True)
    g_buf = layout.pack(tree)
    age_buf = layout.pack_age(age)
    thr = jax.jit(lambda g, ag, ts:
                  warm_eng._packed_thresholds(g, ag, ts)[:2])
    us, _ = timed(lambda: jax.block_until_ready(
        thr(g_buf, age_buf, ts0)), repeats=max(repeats, 5))
    res["theta_bootstrap_us"] = us
    us, _ = timed(lambda: jax.block_until_ready(
        thr(g_buf, age_buf, ts_warm)), repeats=max(repeats, 5))
    res["theta_warm_us"] = us
    res["quantile_pass_eliminated_x"] = (res["theta_bootstrap_us"]
                                         / max(res["theta_warm_us"], 1e-9))
    return res


def run(fast: bool = True):
    shape = (12, 192, 8192) if fast else (24, 320, 32000)
    res = bench_tree(*shape)
    rows = [
        ("packed/per_leaf", res["per_leaf_us"],
         f"leaves={res['n_leaves']}"),
        ("packed/fused", res["packed_us"],
         f"speedup={res['speedup_packed']:.2f}x"),
        ("packed/fused_warm", res["packed_warm_us"],
         f"speedup={res['speedup_warm']:.2f}x"),
        ("packed/persisted", res["persisted_us"],
         f"vs_repack={res['persisted_vs_repack']:.2f}x"),
        ("packed/persisted_ef", res["persisted_ef_us"],
         f"copies={res['copies_persisted_ef']}"),
        ("packed/fused_stats", res["fused_stats_us"],
         f"vs_packed_warm={res['fused_vs_packed_warm']:.2f}x "
         f"vs_reestimation={res['speedup_fused_stats']:.2f}x "
         f"reads={res['g_reads_fused_stats']}"),
        ("packed/adaptive", res["adaptive_us"],
         f"vs_fused={res['adaptive_vs_fused']:.2f}x "
         f"reads={res['g_reads_adaptive']} "
         f"traces={res['adaptive_traces']}"),
        ("packed/async", res["async_us"],
         f"overlap={res['overlap_ratio']:.3f} "
         f"crit_us={res['async_critical_path_us']:.1f} "
         f"reads={res['g_reads_async']}"),
        ("packed/sanitize", res["sanitize_us"],
         f"vs_fused={res['sanitize_vs_fused']:.2f}x "
         f"reads={res['g_reads_sanitize']}"),
        ("packed/chaos", res["chaos_us"],
         f"vs_fused={res['chaos_vs_fused']:.2f}x "
         f"vs_async={res['chaos_vs_async']:.2f}x "
         f"reads={res['g_reads_chaos']}"),
        ("packed/channel", res["channel_us"],
         f"vs_fused={res['channel_vs_fused']:.2f}x "
         f"reads={res['g_reads_channel']}"),
    ]
    detail = {"tree": {"n_layers": shape[0], "d_model": shape[1],
                       "vocab": shape[2]}, **res,
              "note": "per_leaf = historical per-leaf loop; packed = one "
                      "fused pass (core.packing, re-packs state trees); "
                      "packed_warm = packed + warm-start thresholds "
                      "(steady-state round, no quantile pass); persisted = "
                      "flat g_prev/age carried across rounds (1 pack + 1 "
                      "unpack per round); persisted_ef = + the fused "
                      "kernel's residual (error-feedback) stage; "
                      "fused_stats = the one-HBM-pass round (counts + "
                      "histograms out of the kernel; re-estimation never "
                      "re-reads g).  Ratios: fused_vs_packed_warm = the "
                      "headline steady-state comparison vs the packed "
                      "BACKEND round as it ships today (warm re-pack "
                      "path); speedup_fused_stats = vs the persisted "
                      "round WITH its bootstrap, the 3-read cost the "
                      "pre-fused path pays on every cold / trust-region "
                      "re-estimation round; fused_vs_persisted_warm = "
                      "warm-round-to-warm-round (on CPU-XLA the count "
                      "passes partially fuse, so this ratio is modest "
                      "here — on TPU they are real extra HBM passes; the "
                      "structural 3-reads-to-1 claim is asserted at "
                      "trace level by --smoke either way); adaptive = "
                      "fused_stats + the in-graph k_M/k budget controller "
                      "(adaptive_vs_fused ~ 1: the controller is a few "
                      "hundred scalar flops riding the same round; "
                      "adaptive_traces = compilations observed across a "
                      "multi-split execution sweep, asserted == 1 by "
                      "--smoke); async = the --async-agg double-buffered "
                      "round (DESIGN.md §13): same 1-pack/1-unpack/1-read "
                      "discipline, the optimizer consumes the carried "
                      "pending buffer, so overlap_ratio = the wall-clock "
                      "fraction of the round off the optimizer's critical "
                      "path (guarded against the committed baseline); "
                      "sanitize = the graceful-degradation round's "
                      "PRODUCTION shape (DESIGN.md §14): non-finite "
                      "masking armed inside the fused launch, no "
                      "simulated faults — sanitize_vs_fused is the <=5% "
                      "robustness-overhead claim (~1.0); chaos = the "
                      "same round under the in-graph fault harness "
                      "(per-round NaN/Inf corruption + deep-fade "
                      "erasures), whose full-buffer PRNG draws are a "
                      "simulation-only cost that dominates on CPU-XLA — "
                      "structural counters guarded for both, ratios "
                      "recorded only (the shared-runner denominator "
                      "swings too much for a gate)"}
    out_dir = os.path.join(os.path.dirname(__file__), "artifacts")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "packed_bench.json"), "w") as f:
        json.dump(detail, f, indent=1)
    return rows, detail


def smoke() -> dict:
    """CI gate: structural claims on a tiny pytree (seconds, not minutes).

    Asserts (a) the packed server phase traces EXACTLY ONE fused update vs
    one per leaf for the loop, (b) the persisted path performs ZERO
    re-pack copies of the carried state per steady-state round — exactly
    1 pack (the fresh grads) and 1 unpack (the optimizer-facing g_t),
    vs 3 packs + 2 unpacks on the re-pack path — and (c) the fused-stats
    round traces EXACTLY ONE read of the packed gradient buffer (the
    kernel itself) where the pre-fused round traces 3 (quantile bootstrap
    + kernel + masked count pass), and (d) the async double-buffered round
    keeps all three invariants while its optimizer-facing critical path
    stays a strict sub-interval of the round.
    Deliberately NO wall-clock assertion:
    a single timing sample at tiny sizes is scheduler noise on shared
    runners — the speedup claim is checked against the committed baseline
    ratios by tools/check_bench_regression.py."""
    res = bench_tree(2, 32, 256, repeats=1)
    assert res["fused_calls_packed"] == 1, res
    assert res["fused_calls_per_leaf"] == res["n_leaves"], res
    assert res["copies_packed"] == (3, 2), res        # the PR-2 re-pack path
    assert res["copies_persisted"] == (1, 1), res     # zero state re-packs
    assert res["copies_persisted_ef"] == (1, 1), res  # EF adds no copies
    assert res["copies_fused_stats"] == (1, 1), res
    # the tentpole claim: ONE trace-time read of g per steady-state round
    assert res["g_reads_fused_stats"] == 1, res
    assert res["g_reads_persisted"] == 3, res         # what it replaces
    # the adaptive-controller claims: the split rides as data — the round
    # still reads g exactly once, adds no tree copies, and the SAME
    # compiled program served every k_m_frac operating point (one trace
    # of the controller body across the multi-split execution sweep)
    assert res["g_reads_adaptive"] == 1, res
    assert res["copies_adaptive"] == (1, 1), res
    assert res["adaptive_traces"] == 1, res
    # the async double-buffer claims: the shadow mixing is not a g
    # re-read, the pending swap replaces (not adds to) the unpack, and
    # the optimizer's critical path is a strict sub-interval of the round
    assert res["fused_calls_async"] == 1, res
    assert res["copies_async"] == (1, 1), res
    assert res["g_reads_async"] == 1, res
    assert 0.0 < res["overlap_ratio"] < 1.0, res
    # the chaos-round claims (DESIGN.md §14): corruption/fade injection
    # is elementwise math on the packed buffer and the sanitize masking
    # rides the one fused launch — faults add no instrumented read of g,
    # no extra tree copies, no extra kernel call
    assert res["fused_calls_chaos"] == 1, res
    assert res["copies_chaos"] == (1, 1), res
    assert res["g_reads_chaos"] == 1, res
    assert res["fused_calls_sanitize"] == 1, res
    assert res["copies_sanitize"] == (1, 1), res
    assert res["g_reads_sanitize"] == 1, res
    # the wireless-channel claims (DESIGN.md §16): the AR(1) fading
    # advance, the truncation-outage erasure and the CSI multiply all
    # ride the one fused launch — a channel-on round keeps the sync
    # round's exact 1-pack/1-unpack/1-read discipline
    assert res["fused_calls_channel"] == 1, res
    assert res["copies_channel"] == (1, 1), res
    assert res["g_reads_channel"] == 1, res
    out_dir = os.path.join(os.path.dirname(__file__), "artifacts")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "packed_bench_smoke.json"), "w") as f:
        json.dump(res, f, indent=1)
    print(json.dumps(res, indent=1))
    print(f"[packed_bench --smoke] OK: 1 fused call vs "
          f"{res['n_leaves']} per-leaf; persisted round = "
          f"{res['copies_persisted']} (pack, unpack) tree copies; "
          f"fused-stats round = {res['g_reads_fused_stats']} read of g "
          f"vs {res['g_reads_persisted']}; adaptive round = "
          f"{res['g_reads_adaptive']} read, {res['adaptive_traces']} "
          f"compilation across k_m_frac changes; async round = "
          f"{res['g_reads_async']} read, {res['copies_async']} copies, "
          f"overlap_ratio={res['overlap_ratio']:.3f}; chaos round = "
          f"{res['g_reads_chaos']} read, {res['copies_chaos']} copies "
          f"under injected faults; channel round = "
          f"{res['g_reads_channel']} read, {res['copies_channel']} "
          f"copies under wireless fading")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    rows, detail = run(fast=not args.full)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(json.dumps({k: v for k, v in detail.items() if k != "tree"},
                     indent=1))


if __name__ == "__main__":
    main()
