"""Fig. 6 — FAIR-k quality vs the magnitude share k_M/k.

k_M/k = 1 is Top-k, k_M/k = 0 is Round-Robin; the paper's claim is a wide
stable plateau (no delicate tuning needed).

Routed through the vmapped ``fl.sweep`` grid (ROADMAP item): the whole
k_M/k curve — every ratio x every seed — runs as ONE compiled program
(rank-based FAIR-k with the magnitude budget as a traced per-lane scalar)
instead of one sequential FL simulation per ratio.  The grid also carries
``fairk_auto`` lanes: the in-graph budget controller (core/controller.py)
picks its own split per round, and the plateau claim extends to it — the
adaptive curve must land on the plateau, not below it.  Per the DESIGN.md
§7 data gate the claim is *relative*: interior ratios must not be worse
than the k_M/k = 1 / = 0 endpoints (the plateau), measured by final loss
on the synthetic heterogeneous-quadratic scenario."""

import time

import numpy as np

from repro.fl.sweep import SweepConfig, run_sweep

RATIOS = (0.0, 0.25, 0.5, 0.75, 1.0)


def run(fast: bool = True):
    rounds = 120 if fast else 600
    n_seeds = 4 if fast else 8
    cfg = SweepConfig(d=2048, n_clients=16, rho=0.2, rounds=rounds)
    t0 = time.perf_counter()
    # static ratio lanes AND adaptive-controller lanes, one compiled grid
    out = run_sweep(cfg, policies=("fairk", "fairk_auto"),
                    k_m_fracs=RATIOS, n_seeds=n_seeds)
    total_us = (time.perf_counter() - t0) * 1e6
    # mean final loss per ratio across seeds (labels: (policy, frac, seed))
    finals, adaptive, km_final = {}, [], []
    for i, (pol, frac, _) in enumerate(out["labels"]):
        if pol == "fairk_auto":
            # adaptive lanes start at every ratio — the controller must
            # find the plateau from ANY initial split
            adaptive.append(float(out["loss"][i, -1]))
            km_final.append(float(out["km_frac"][i, -1]))
        else:
            finals.setdefault(frac, []).append(float(out["loss"][i, -1]))
    n_grid = len(out["labels"])
    rows, detail = [], {"rounds": rounds, "n_seeds": n_seeds,
                        "grid_points": n_grid,
                        "grid_total_us": total_us}
    for frac in sorted(finals):
        loss = float(np.mean(finals[frac]))
        detail[str(frac)] = loss
        rows.append((f"fig6/km_ratio_{frac:.2f}", total_us / n_grid,
                     f"loss={loss:.4f}"))
    loss_ad = float(np.mean(adaptive))
    detail["adaptive"] = {"loss": loss_ad,
                          "km_final": float(np.mean(km_final))}
    rows.append(("fig6/km_adaptive", total_us / n_grid,
                 f"loss={loss_ad:.4f};km_final={np.mean(km_final):.2f}"))
    return rows, detail
