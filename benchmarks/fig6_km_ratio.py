"""Fig. 6 — FAIR-k test accuracy vs the magnitude share k_M/k.

k_M/k = 1 is Top-k, k_M/k = 0 is Round-Robin; the paper's claim is a wide
stable plateau (no delicate tuning needed)."""

import time

from benchmarks.common import make_task, run_policy

RATIOS = (0.0, 0.25, 0.5, 0.75, 1.0)


def run(fast: bool = True):
    rounds = 120 if fast else 600
    task = make_task(fast=fast)
    rows, detail = [], {}
    for r in RATIOS:
        t0 = time.perf_counter()
        h = run_policy(task, "fairk", rounds, k_m_frac=r)
        us = (time.perf_counter() - t0) / rounds * 1e6
        detail[str(r)] = h["acc"][-1]
        rows.append((f"fig6/km_ratio_{r:.2f}", us,
                     f"acc={h['acc'][-1]:.3f}"))
    return rows, detail
