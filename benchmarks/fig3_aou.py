"""Fig. 3 — AoU distribution: Lemma 1 analysis vs simulation.

Paper parameters: k=80, rho=0.1 (d=800), k_M/k=0.75, k_0/k_M=0.25."""

import time

import numpy as np

from repro.core import markov


def run(fast: bool = True):
    chain = markov.FairKChain(d=800, k=80, k_m=60, k0=15)
    t0 = time.perf_counter()
    support, pmf = markov.aou_distribution(chain)
    analysis_us = (time.perf_counter() - t0) * 1e6
    rounds = 2000 if fast else 10000
    emp_ex = markov.simulate_aou(chain, rounds=rounds, seed=0, mode="exchange")
    emp_ar = markov.simulate_aou(chain, rounds=rounds, seed=0, mode="ar")
    tv_ex = 0.5 * np.abs(pmf - emp_ex).sum()
    tv_ar = 0.5 * np.abs(pmf - emp_ar).sum()
    e_tau = float((support * pmf).sum())
    rows = [
        ("fig3/aou_analysis", analysis_us,
         f"E[tau]={e_tau:.2f};T={chain.max_staleness}"),
        ("fig3/tv_vs_exchange_sim", analysis_us, f"TV={tv_ex:.4f}"),
        ("fig3/tv_vs_ar_sim", analysis_us, f"TV={tv_ar:.4f}"),
    ]
    detail = {"support": support.tolist(), "pmf": pmf.tolist(),
              "empirical_exchange": emp_ex.tolist(),
              "empirical_ar": emp_ar.tolist(), "E_tau": e_tau,
              "tv_exchange": float(tv_ex), "tv_ar": float(tv_ar)}
    return rows, detail
