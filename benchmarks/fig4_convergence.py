"""Fig. 4 — test accuracy vs communication rounds for FAIR-k and the
baselines (Top-k, AgeTop-k, TopRand), plus Round-Robin for reference.

Two synthetic regimes exercise both ends of the magnitude/freshness
trade-off (see EXPERIMENTS.md §Fig4): the sparse-signal classification task
(freshness matters; Top-k collapses) and a power-law-curvature regression
(magnitude matters; Round-Robin diverges).  FAIR-k is the only policy that
is strong in both — the paper's robustness claim."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, make_task, run_policy
from repro.core.oac import ChannelConfig
from repro.fl import FLConfig, train

POLICIES = ("fairk", "topk", "agetopk", "toprand", "roundrobin")


def _powerlaw_regression(policies, rounds, n_clients=16, d_feat=1500):
    rng = np.random.default_rng(0)
    scales = (np.arange(1, d_feat + 1) ** -0.8).astype(np.float32)
    w_star = rng.normal(size=d_feat).astype(np.float32)
    data = []
    for _ in range(n_clients):
        X = rng.normal(size=(80, d_feat)).astype(np.float32) * scales
        data.append((X, X @ w_star + 0.05 * rng.normal(size=80).astype("f4")))
    Xte = rng.normal(size=(400, d_feat)).astype(np.float32) * scales
    yte = Xte @ w_star
    params0 = {"w": jnp.zeros((d_feat,), jnp.float32)}

    def loss_fn(p, x, y):
        return jnp.mean((x @ p["w"] - y) ** 2)

    def eval_fn(p):
        resid = Xte @ np.asarray(p["w"]) - yte
        return {"acc": 1.0 - float(np.mean(resid**2) / np.mean(yte**2))}

    def sample_round(t):
        r = np.random.default_rng(300 + t)
        idx = r.integers(0, 80, (n_clients, 5, 20))
        xs = np.stack([data[i][0][idx[i]] for i in range(n_clients)])
        ys = np.stack([data[i][1][idx[i]] for i in range(n_clients)])
        return xs, ys

    out = {}
    for policy in policies:
        fl = FLConfig(n_clients=n_clients, local_steps=5, batch_size=20,
                      rounds=rounds, policy=policy, compression_ratio=0.05,
                      local_lr=0.02, global_lr=0.02,
                      channel=ChannelConfig(fading="rayleigh", mean=1.0,
                                            noise_std=0.05))
        h = train(fl, params0, loss_fn, sample_round, eval_fn=eval_fn,
                  eval_every=rounds)
        out[policy] = h["acc"][-1]
    return out


def run(fast: bool = True):
    rounds = 120 if fast else 600
    task = make_task(fast=fast)
    rows, detail = [], {"classification": {}, "powerlaw_r2": {}}
    for policy in POLICIES:
        t0 = time.perf_counter()
        h = run_policy(task, policy, rounds, eval_every=max(rounds // 4, 1))
        us = (time.perf_counter() - t0) / rounds * 1e6
        detail["classification"][policy] = {"rounds": h["round"],
                                            "acc": h["acc"]}
        rows.append((f"fig4/classification/{policy}", us,
                     f"acc={h['acc'][-1]:.3f}"))
    r2 = _powerlaw_regression(POLICIES, rounds=min(rounds, 200))
    detail["powerlaw_r2"] = r2
    for policy, v in r2.items():
        rows.append((f"fig4/powerlaw/{policy}", 0.0,
                     f"R2={max(v, -9.99):.3f}"))
    return rows, detail
