"""Shared harness for the paper-figure benchmarks.

Default ("fast") settings are CPU-budget-reduced versions of the paper's
setups (documented per benchmark); pass --full for closer-to-paper scale.
All benchmarks report *relative* policy behaviour — the paper's actual
claims — on the synthetic datasets (DESIGN.md §7 data gate).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.oac import ChannelConfig
from repro.data import partition, synthetic
from repro.fl import FLConfig, train
from repro.models import cnn


@dataclasses.dataclass
class FLTask:
    params0: object
    loss_fn: Callable
    eval_fn: Callable
    sample_round: Callable
    n_clients: int
    d: int


def make_task(fast: bool = True, seed: int = 0, model: str = "mlp",
              sparsity: float = 0.08, n_classes: int = 10,
              dir_alpha: float = 0.3) -> FLTask:
    """Synthetic CIFAR-stand-in classification task (paper Sec. V-A setup,
    reduced: the paper uses ResNet-18/CIFAR on GPU; we use an MLP/CNN on
    16x16 synthetic images, N=20 (fast) / 50 (full) clients, Dir(0.3))."""
    n_clients = 20 if fast else 50
    img = (16, 16, 1) if fast else (24, 24, 3)
    spec = synthetic.DatasetSpec("bench", img, n_classes,
                                 8_000 if fast else 24_000, 1_000,
                                 noise_std=1.0, sparsity=sparsity)
    (xtr, ytr), (xte, yte) = synthetic.make_dataset(spec, seed=seed)
    parts = partition.dirichlet_partition(ytr, n_clients, dir_alpha,
                                          seed=seed)
    key = jax.random.PRNGKey(seed)
    dim = int(np.prod(img))
    if model == "cnn":
        params0 = cnn.init_prototype_cnn(key, img, n_classes,
                                         widths=(12, 16, 24), fc_width=48)
        apply_fn = cnn.prototype_cnn
    else:
        params0 = cnn.init_mlp_classifier(key, dim, n_classes, hidden=(64,))
        apply_fn = cnn.mlp_classifier

    def loss_fn(p, x, y):
        return cnn.softmax_xent(apply_fn(p, x), y)

    xte_j, yte_j = jnp.asarray(xte), jnp.asarray(yte)

    @jax.jit
    def eval_fn(p):
        return {"acc": cnn.accuracy(apply_fn(p, xte_j), yte_j)}

    def sample_round(t, steps=5):
        return partition.client_batches(xtr, ytr, parts, 20, steps,
                                        seed=seed * 7919 + t)

    return FLTask(params0, loss_fn, eval_fn, sample_round, n_clients,
                  cnn.param_count(params0))


PAPER_CHANNEL = ChannelConfig(fading="rayleigh", mean=1.0, noise_std=0.1)


def run_policy(task: FLTask, policy: str, rounds: int, *, rho: float = 0.1,
               k_m_frac: float = 0.75, local_steps: int = 5,
               lr: float = 0.05, one_bit: bool = False,
               channel: ChannelConfig = PAPER_CHANNEL,
               eval_every: int = 0) -> Dict:
    fl = FLConfig(n_clients=task.n_clients, local_steps=local_steps,
                  batch_size=20, local_lr=lr, global_lr=lr, rounds=rounds,
                  policy=policy, compression_ratio=rho, k_m_frac=k_m_frac,
                  channel=channel, one_bit=one_bit)
    return train(fl, task.params0, task.loss_fn,
                 lambda t: task.sample_round(t, steps=local_steps),
                 eval_fn=task.eval_fn,
                 eval_every=eval_every or rounds)


def timed(fn: Callable, *args, repeats: int = 3, **kw) -> Tuple[float, object]:
    out = fn(*args, **kw)            # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6, out


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
