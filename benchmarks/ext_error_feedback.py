"""Beyond-paper extension — error feedback (EF-SGD, Stich et al. 2018) on
the unsent gradient mass, composed with the selection policies.

Finding: EF is *complementary* to FAIR-k (it restores the magnitude lost to
sparsification: +2-3 acc points) but cannot rescue Top-k — EF fixes what is
*sent*, not what is *selected*; starved coordinates stay starved.  Timeliness
(the paper's contribution) and error compensation address orthogonal error
terms."""

import time

from benchmarks.common import make_task, run_policy
from repro.core.oac import ChannelConfig
from repro.fl import FLConfig, train


def run(fast: bool = True):
    rounds = 120 if fast else 400
    task = make_task(fast=fast)
    rows, detail = [], {}
    for policy in ("fairk", "topk", "toprand"):
        for ef in (False, True):
            fl = FLConfig(n_clients=task.n_clients, local_steps=5,
                          batch_size=20, local_lr=0.05, global_lr=0.05,
                          rounds=rounds, policy=policy,
                          compression_ratio=0.1, error_feedback=ef,
                          channel=ChannelConfig(fading="rayleigh", mean=1.0,
                                                noise_std=0.1))
            t0 = time.perf_counter()
            h = train(fl, task.params0, task.loss_fn,
                      lambda t: task.sample_round(t),
                      eval_fn=task.eval_fn, eval_every=rounds)
            us = (time.perf_counter() - t0) / rounds * 1e6
            tag = f"{policy}{'+ef' if ef else ''}"
            detail[tag] = h["acc"][-1]
            rows.append((f"ext/error_feedback/{tag}", us,
                         f"acc={h['acc'][-1]:.3f}"))
    return rows, detail
