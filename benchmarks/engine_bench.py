"""SelectionEngine backend benchmark: exact (lax.top_k) vs threshold
(sampled-quantile + fused update) wall-clock across model sizes.

The threshold backend is the d >= 1e8 production route — this bench
measures where it starts paying on this host.  Emits CSV rows through
``benchmarks.run`` and writes a standalone JSON artifact
(benchmarks/artifacts/engine_bench.json) with the per-size timings.

  PYTHONPATH=src python -m benchmarks.engine_bench [--full]

fast: d in {1e5, 1e6, 1e7};  --full adds 1e8 (needs ~4 GB RAM).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.core.engine import EngineConfig, SelectionEngine

FAST_SIZES = (100_000, 1_000_000, 10_000_000)
FULL_SIZES = FAST_SIZES + (100_000_000,)


def _bench_one(d: int, rho: float = 0.1, k_m_frac: float = 0.75):
    rng = np.random.default_rng(d % 7919)
    g = jnp.asarray(rng.standard_normal(d).astype("f4"))
    g_prev = jnp.asarray(rng.standard_normal(d).astype("f4"))
    age = jnp.asarray(rng.integers(0, 40, d).astype("f4"))

    res = {"d": d, "rho": rho, "k_m_frac": k_m_frac}
    for backend in ("exact", "threshold"):
        eng = SelectionEngine(
            EngineConfig(policy="fairk", backend=backend, rho=rho,
                         k_m_frac=k_m_frac), d)
        fn = jax.jit(lambda a, b, c, e=eng: e.select_and_merge(a, b, c)[:2])
        us, (g_t, age_next) = timed(
            lambda: jax.block_until_ready(fn(g, g_prev, age)))
        res[backend + "_us"] = us
        res[backend + "_gbps"] = 5 * 4 * d / (us * 1e-6) / 1e9  # 3 in + 2 out
    res["speedup_threshold"] = res["exact_us"] / res["threshold_us"]
    return res


def run(fast: bool = True):
    sizes = FAST_SIZES if fast else FULL_SIZES
    rows, per_size = [], []
    for d in sizes:
        r = _bench_one(d)
        per_size.append(r)
        rows.append((f"engine/exact_d{d:.0e}".replace("+0", ""),
                     r["exact_us"], f"gbps={r['exact_gbps']:.2f}"))
        rows.append((f"engine/threshold_d{d:.0e}".replace("+0", ""),
                     r["threshold_us"],
                     f"speedup={r['speedup_threshold']:.2f}x"))
    detail = {"sizes": per_size,
              "note": "threshold = sampled-quantile theta + fused update; "
                      "exact = lax.top_k index policies (fairk)"}
    out_dir = os.path.join(os.path.dirname(__file__), "artifacts")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "engine_bench.json"), "w") as f:
        json.dump(detail, f, indent=1)
    return rows, detail


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    rows, detail = run(fast=not args.full)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(json.dumps(detail["sizes"], indent=1))


if __name__ == "__main__":
    main()
