#!/usr/bin/env python3
"""Docs gate: smoke-execute the README's quickstart commands.

Extracts every fenced ```bash block from README.md and runs each command
line from the repo root, so the quickstart can never rot.  Conventions:

* lines ending with ``[ci-skip]`` (inside a trailing comment) are listed
  but not executed — for heavy entry points documented alongside the
  quickstart;
* comment-only and blank lines are ignored;
* a non-zero exit from any executed command fails the gate.

  python tools/check_readme.py [--readme README.md] [--timeout 1200]
"""

import argparse
import re
import subprocess
import sys
import time
from pathlib import Path

BASH_BLOCK = re.compile(r"```bash\n(.*?)```", re.DOTALL)


def extract_commands(readme_text):
    """(command, skipped) pairs, in document order."""
    out = []
    for block in BASH_BLOCK.findall(readme_text):
        for raw in block.splitlines():
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            out.append((line, "[ci-skip]" in line))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--readme", default="README.md")
    ap.add_argument("--timeout", type=int, default=1200,
                    help="per-command timeout (seconds)")
    ap.add_argument("--list", action="store_true",
                    help="print the extracted commands and exit")
    args = ap.parse_args()

    root = Path(__file__).resolve().parent.parent
    commands = extract_commands((root / args.readme).read_text())
    if not commands:
        print(f"[check_readme] FAIL: no bash blocks found in {args.readme}")
        return 1
    if args.list:
        for cmd, skipped in commands:
            print(("skip " if skipped else "run  ") + cmd)
        return 0

    failures = []
    for cmd, skipped in commands:
        if skipped:
            print(f"[check_readme] skip: {cmd}")
            continue
        print(f"[check_readme] run : {cmd}", flush=True)
        t0 = time.time()
        proc = subprocess.run(["bash", "-c", cmd], cwd=root,
                              timeout=args.timeout,
                              capture_output=True, text=True)
        dt = time.time() - t0
        if proc.returncode != 0:
            failures.append(cmd)
            print(f"[check_readme] FAIL ({dt:.0f}s, rc={proc.returncode}):"
                  f"\n--- stdout ---\n{proc.stdout[-2000:]}"
                  f"\n--- stderr ---\n{proc.stderr[-2000:]}")
        else:
            print(f"[check_readme] OK   ({dt:.0f}s)")
    if failures:
        print(f"[check_readme] {len(failures)} quickstart command(s) "
              f"broken:")
        for c in failures:
            print("  ", c)
        return 1
    n_run = sum(1 for _, s in commands if not s)
    print(f"[check_readme] all {n_run} executed command(s) OK "
          f"({len(commands) - n_run} ci-skip)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
