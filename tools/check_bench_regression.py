"""Benchmark-regression gate for the packed server phase (CI).

Compares the freshly produced ``benchmarks/artifacts/packed_bench.json``
against the committed baseline ``benchmarks/BENCH_packed.json`` and fails
when

* any structural counter broke — the fused-stats steady-state round must
  trace exactly ONE read of the packed gradient buffer (vs 3 on the
  pre-fused path), one fused kernel launch, and (1 pack, 1 unpack) tree
  copies; the async double-buffered round must keep the same discipline
  (the shadow/pending buffers are carried state, never re-packed); or
* a guarded speedup RATIO regressed by more than ``--tol`` (default 15%)
  relative to the baseline.  Ratios — not absolute wall-clock — are
  compared because CI runners and the baseline machine differ in speed;
  a ratio is the machine-portable statement "variant A costs X times
  variant B on the same box".  Refresh the baseline (commit the artifact
  of a quiet-machine run) when the guarded set or the bench itself
  changes materially.

  PYTHONPATH=src python -m benchmarks.packed_bench          # artifact
  python tools/check_bench_regression.py [--tol 0.15]
"""

import argparse
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARTIFACT = os.path.join(ROOT, "benchmarks", "artifacts",
                        "packed_bench.json")
BASELINE = os.path.join(ROOT, "benchmarks", "BENCH_packed.json")
POPULATION_ARTIFACT = os.path.join(ROOT, "benchmarks", "artifacts",
                                   "population_bench.json")
CLIENT_ARTIFACT = os.path.join(ROOT, "benchmarks", "artifacts",
                               "client_bench.json")
CLIENT_SMOKE_ARTIFACT = os.path.join(ROOT, "benchmarks", "artifacts",
                                     "client_bench_smoke.json")

# structural counters: exact match required
STRUCTURAL = {
    "g_reads_fused_stats": 1,       # ONE read of g per fused round
    "g_reads_persisted": 3,         # what the pre-fused path pays
    "fused_calls_packed": 1,
    "copies_fused_stats": [1, 1],
    "copies_persisted": [1, 1],
    # the adaptive-budget controller round (DESIGN.md §12): still one
    # read of g, no extra tree copies, and ONE compilation observed
    # across a multi-k_m_frac execution sweep (the split rides as data)
    "g_reads_adaptive": 1,
    "copies_adaptive": [1, 1],
    "adaptive_traces": 1,
    # the --async-agg double-buffered round (DESIGN.md §13): the shadow
    # mixing is plain elementwise math (not a g re-read) and the pending
    # swap replaces — not adds to — the optimizer-facing unpack, so the
    # async round keeps the sync round's copy/read discipline exactly
    "g_reads_async": 1,
    "copies_async": [1, 1],
    "fused_calls_async": 1,
    # the graceful-degradation rounds (DESIGN.md §14): non-finite
    # sanitize masking rides the one fused launch, and the chaos
    # harness's corruption/fade injection is elementwise math on the
    # packed buffer — robustness costs no extra instrumented read of g,
    # no extra tree copies, no extra kernel call
    "g_reads_sanitize": 1,
    "copies_sanitize": [1, 1],
    "fused_calls_sanitize": 1,
    "g_reads_chaos": 1,
    "copies_chaos": [1, 1],
    "fused_calls_chaos": 1,
    # the wireless fading round (DESIGN.md §16): the carried AR(1) block
    # chain, the truncation-outage erasure and the CSI multiply all ride
    # the one fused sanitize launch — the channel layer costs no extra
    # instrumented read of g, no extra tree copies, no extra kernel call
    "g_reads_channel": 1,
    "copies_channel": [1, 1],
    "fused_calls_channel": 1,
}

# the population-scale round (DESIGN.md §15): the stateless availability
# draw, participation rescale and churn-erase blocks all ride the one
# fused sanitize launch — population churn costs no extra instrumented
# read of g, no extra tree copies, no extra kernel call.  Checked from
# benchmarks/artifacts/population_bench.json when present (strict), with
# a warning when the population bench did not run.  Structural only — no
# ratio guard: the O(n_clients) availability draw is a simulation cost
# whose wall-clock share swings with the runner.
STRUCTURAL_POPULATION = {
    "g_reads_population": 1,
    "copies_population": [1, 1],
    "fused_calls_population": 1,
}

# the streaming client aggregation (DESIGN.md §17): the FL trainer's
# client phase is a lax.scan over cohort chunks — exactly ONE streaming
# accumulation pass per traced round, NO live (N, d) float32 gradient
# matrix when client_chunk < N, and the packed server phase downstream
# keeps its single instrumented read of the persisted gradient buffer.
# Checked from benchmarks/artifacts/client_bench.json (or the --smoke
# artifact) when present (strict), with a warning when the client bench
# did not run.  Structural only — the clients/sec throughput and the
# live-byte scaling live in the artifact / BENCH_clients.json for the
# record (the byte counts are also asserted inside the bench itself).
STRUCTURAL_CLIENTS = {
    "client_stream_passes": 1,
    "client_nd_live": 0,
    "g_reads_fl_packed": 1,
}

# speedup ratios guarded against the committed baseline (lower = worse).
# Only the fused-round ratios are guarded: they compare near-identical
# program shapes on the same box, so they travel across runner hardware.
# The per-leaf-loop ratios (speedup_packed ~6x, speedup_persisted ~9x)
# are dominated by Python-dispatch/fusion behavior that varies wildly
# between machines — they stay in the artifact for the record but would
# make the gate flaky if guarded.
GUARDED_RATIOS = (
    "fused_vs_packed_warm",         # fused round vs current packed-backend
                                    # steady state (the >= 1.5x claim)
    "speedup_fused_stats",          # fused round vs persisted re-estimation
                                    # (3-read) round
    "overlap_ratio",                # async round: wall-clock fraction off
                                    # the optimizer's critical path — the
                                    # double buffer's raison d'être; a drop
                                    # means the pending unpack grew or the
                                    # round picked up critical-path work
)
# adaptive_vs_fused (controller overhead, ~1.0) stays in the artifact for
# the record but is NOT guarded: back-to-back runs on the baseline box
# swing it 0.84-1.25 (the fused-round denominator itself moves ±25% under
# co-tenancy), so a 15% gate would flake.  The controller round's real
# acceptance criteria are structural and guarded exactly above:
# one read of g, (1, 1) tree copies, one compilation across k_m changes.


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get(
                        "BENCH_REGRESSION_TOLERANCE", 0.15)),
                    help="allowed relative regression of each guarded "
                         "ratio vs the baseline (default 0.15)")
    ap.add_argument("--artifact", default=ARTIFACT)
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--population-artifact", default=POPULATION_ARTIFACT)
    ap.add_argument("--client-artifact", default=CLIENT_ARTIFACT)
    args = ap.parse_args()

    with open(args.artifact) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    failures = []
    for key, want in STRUCTURAL.items():
        got = cur.get(key)
        if isinstance(want, list):
            ok = got is not None and list(got) == want
        else:
            ok = got == want
        if not ok:
            failures.append(f"STRUCTURAL {key}: expected {want}, got {got}")

    if os.path.exists(args.population_artifact):
        with open(args.population_artifact) as f:
            pop = json.load(f)
        for key, want in STRUCTURAL_POPULATION.items():
            got = pop.get(key)
            ok = (got is not None and list(got) == want
                  if isinstance(want, list) else got == want)
            if not ok:
                failures.append(
                    f"STRUCTURAL (population) {key}: expected {want}, "
                    f"got {got}")
    else:
        print(f"[bench-regression] WARNING: no population artifact at "
              f"{args.population_artifact} — population structural "
              f"counters not checked (run benchmarks.population_bench)")

    client_path = args.client_artifact
    if not os.path.exists(client_path) and os.path.exists(
            CLIENT_SMOKE_ARTIFACT):
        client_path = CLIENT_SMOKE_ARTIFACT
    if os.path.exists(client_path):
        with open(client_path) as f:
            cli = json.load(f)
        for key, want in STRUCTURAL_CLIENTS.items():
            got = cli.get(key)
            ok = (got is not None and list(got) == want
                  if isinstance(want, list) else got == want)
            if not ok:
                failures.append(
                    f"STRUCTURAL (clients) {key}: expected {want}, "
                    f"got {got}")
    else:
        print(f"[bench-regression] WARNING: no client artifact at "
              f"{client_path} — streaming-aggregation structural "
              f"counters not checked (run benchmarks.client_bench)")
    for key in GUARDED_RATIOS:
        b, c = base.get(key), cur.get(key)
        if b is None or c is None:
            failures.append(f"RATIO {key}: missing (baseline={b}, "
                            f"current={c})")
            continue
        floor = b * (1.0 - args.tol)
        status = "OK" if c >= floor else "REGRESSED"
        print(f"[bench-regression] {key}: current={c:.3f} "
              f"baseline={b:.3f} floor={floor:.3f} {status}")
        if c < floor:
            failures.append(f"RATIO {key}: {c:.3f} < {floor:.3f} "
                            f"(baseline {b:.3f} - {args.tol:.0%})")

    if failures:
        print("\n[bench-regression] FAILED:")
        for msg in failures:
            print("  -", msg)
        return 1
    print(f"[bench-regression] OK: structural counters intact, "
          f"{len(GUARDED_RATIOS)} ratios within {args.tol:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
