"""Over-the-air computation channel model and gradient aggregation.

Implements the analog multiple-access channel of paper Sec. III-A:

    ǧ_t = (1/N) ( Σ_n h_{n,t} ǧ_{n,t} + ξ_t )                     (Eq. 7)
    g_t = (1/N) Σ_n h_{n,t} S_t ∘ g_{n,t} + (1 − S_t) ∘ g_{t−1} + ξ̃_t   (Eq. 8)

Fading ``h_{n,t}`` is i.i.d. across clients and rounds with mean ``mu_c`` and
variance ``sigma_c**2`` (default: Rayleigh with mean 1, the paper's setting).
Noise ``ξ_t`` has i.i.d. zero-mean entries with variance ``sigma_z**2``.

Only the ``k`` *selected* coordinates ride the channel — the vector that is
actually transmitted/aggregated is the compacted ``(k,)`` vector, matching
the physical waveform budget.  This is also what the sharded trainer
all-reduces, so the collective volume is ``k`` not ``d``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

_RAYLEIGH_MEAN = math.sqrt(math.pi / 2.0)  # mean of Rayleigh(sigma=1)


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """Wireless channel parameters (paper Sec. III-A / V-A)."""

    fading: str = "rayleigh"          # "rayleigh" | "gaussian" | "none"
    mean: float = 1.0                 # mu_c
    std: float = 0.0                  # sigma_c; for rayleigh derived from mean
    noise_std: float = 0.0            # sigma_z   (paper sims use 1.0)

    def __post_init__(self):
        # unknown modes used to fall through sigma_c2 to 0.0 (silently
        # treated as a deterministic channel) and only blow up much later
        # at sample time; a rayleigh std was silently ignored (sigma_c is
        # derived from the mean) — both are config bugs, reject them here
        if self.fading not in ("rayleigh", "gaussian", "none"):
            raise ValueError(
                f"fading must be rayleigh|gaussian|none, got "
                f"{self.fading!r}")
        if self.fading == "rayleigh" and self.std != 0.0:
            raise ValueError(
                f"rayleigh fading derives sigma_c from the mean "
                f"(sigma_c^2 = mean^2 (4 - pi) / pi) — std={self.std} "
                f"would be silently ignored; leave std=0 or use "
                f"fading='gaussian'")

    @property
    def mu_c(self) -> float:
        return self.mean

    @property
    def sigma_c2(self) -> float:
        if self.fading == "rayleigh":
            # Rayleigh scaled to mean mu_c: sigma = mu_c / sqrt(pi/2),
            # var = (4 - pi)/2 * sigma^2 = mu_c^2 (4 - pi) / pi.
            return self.mean**2 * (4.0 - math.pi) / math.pi
        if self.fading == "gaussian":
            return self.std**2
        return 0.0


NOISELESS = ChannelConfig(fading="none", mean=1.0, noise_std=0.0)
PAPER_DEFAULT = ChannelConfig(fading="rayleigh", mean=1.0, noise_std=1.0)


def sample_fading(key: Array, n_clients: int, cfg: ChannelConfig) -> Array:
    """Draw h_{n,t} for all clients for one round, shape (n_clients,)."""
    if cfg.fading == "none":
        return jnp.full((n_clients,), cfg.mean, jnp.float32)
    if cfg.fading == "rayleigh":
        scale = cfg.mean / _RAYLEIGH_MEAN
        return jax.random.rayleigh(key, scale, shape=(n_clients,),
                                   dtype=jnp.float32)
    if cfg.fading == "gaussian":
        return cfg.mean + cfg.std * jax.random.normal(key, (n_clients,), jnp.float32)
    raise ValueError(f"unknown fading model {cfg.fading!r}")


def oac_aggregate(key: Array, client_values: Array, cfg: ChannelConfig,
                  fading: Optional[Array] = None) -> Array:
    """Eq. (7): superpose N compacted client vectors through the channel.

    Args:
      key: PRNG key for fading + noise.
      client_values: (N, k) — each client's compacted selected gradient.
      cfg: channel parameters.
      fading: optional pre-drawn (N,) fading (e.g. shared across tensors of
        the same round, as a single radio frame carries all of them).
    Returns:
      (k,) aggregated, distorted mean gradient.
    """
    n, _ = client_values.shape
    key_h, key_z = jax.random.split(key)
    h = sample_fading(key_h, n, cfg) if fading is None else fading
    superposed = jnp.einsum("n,nk->k", h, client_values)
    return finish_aggregate(key_z, superposed, n, cfg)


def finish_aggregate(key_z: Array, superposed: Array, n_clients: int,
                     cfg: ChannelConfig) -> Array:
    """Receiver tail of Eq. (7) for a PRE-SUPERPOSED (k,) row: channel
    noise + the 1/N normalisation.

    The streaming client aggregation (fl/trainer.py) folds each chunk's
    faded partial sum ``Σ_{n ∈ chunk} h_n ǧ_n`` into one (k,) accumulator
    — the (N, k) compacted matrix is never live — and lands here, exactly
    where ``oac_aggregate`` lands after its dense einsum."""
    if cfg.noise_std > 0.0:
        superposed = superposed + cfg.noise_std * jax.random.normal(
            key_z, superposed.shape, superposed.dtype)
    return superposed / n_clients


def reconstruct(g_prev: Array, idx: Array, agg_values: Array) -> Array:
    """Eq. (8): refresh the selected coordinates, keep the stale rest."""
    return g_prev.at[idx].set(agg_values)


def oac_round(key: Array, g_prev: Array, idx: Array, client_grads: Array,
              cfg: ChannelConfig) -> Tuple[Array, Array]:
    """One full uplink round over dense client gradients.

    Args:
      g_prev: (d,) last reconstructed global gradient.
      idx: (k,) selected coordinates (shared mask S_t in index form).
      client_grads: (N, d) dense accumulated local gradients.
    Returns:
      (g_t, agg_k): the reconstructed (d,) gradient and the raw (k,) OAC sum.
    """
    compacted = client_grads[:, idx]                       # (N, k) — ǧ_{n,t}
    agg = oac_aggregate(key, compacted, cfg)               # Eq. (7)
    return reconstruct(g_prev, idx, agg), agg              # Eq. (8)
