"""Named per-round PRNG key discipline for the FL round builders.

``fl/trainer.py`` and ``fl/sweep.py`` each grew an 8-branch ladder of
``jax.random.split(key, n)`` calls — one branch per chaos × population ×
wireless combination — because every combination must keep its HISTORICAL
split count (a different count permutes every downstream draw and breaks
bit-exact trajectories).  This module is that ladder, written once as
data: a combination maps to an ordered tuple of key NAMES, and
``split_named`` hands back a name -> key dict from one
``jax.random.split(key, len(names))``.

The ordering rules both ladders obeyed (verified against every historical
branch, pinned by the golden-trajectory tests):

* the caller's base keys come first, in caller order (trainer:
  ``("sel", "ch")``; sweep: ``("pol", "h", "z")``);
* chaos appends ``av`` (availability chain) — EXCEPT in the sweep, where
  population lanes replace the iid dropout draw (``av_with_pop=False``)
  — then ``fd`` (fade mask) and ``nz`` (corruption);
* population appends ``pop`` (cohort draw) and ``er`` (churn erase);
* wireless appends ``fad`` (AR(1) fading step) and ``csi`` (CSI draw).

``split(key, 2)`` is the same primitive as the historical bare
``jax.random.split(key)``, so the no-flags base case is bit-exact too.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax

Array = jax.Array


def round_key_names(*, base: Tuple[str, ...], chaos: bool = False,
                    pop: bool = False, wl: bool = False,
                    av_with_pop: bool = True) -> Tuple[str, ...]:
    """Ordered key names for one round of a chaos/pop/wl combination."""
    names = list(base)
    if chaos and (av_with_pop or not pop):
        names.append("av")
    if chaos:
        names += ["fd", "nz"]
    if pop:
        names += ["pop", "er"]
    if wl:
        names += ["fad", "csi"]
    return tuple(names)


def split_named(key: Array, names: Tuple[str, ...]) -> Dict[str, Array]:
    """ONE ``jax.random.split(key, len(names))`` -> {name: subkey}."""
    keys = jax.random.split(key, len(names))
    return {name: keys[i] for i, name in enumerate(names)}
