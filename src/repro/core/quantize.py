"""One-bit gradient transport for the SDR prototype path (paper Sec. V-B).

The hardware prototype sends Sign(ǧ_{n,t}) via frequency-shift keying and the
server recovers each coordinate with a non-coherent majority vote (FSK-MV,
ref. [50]).  We model the digital essence of that pipeline:

    vote_n  = sign(ǧ_{n,t})                        (client, 1 bit/coordinate)
    energy  = Σ_n vote_n + noise                   (superposed FSK energies)
    ǧ_t     = sign(energy)                         (majority vote)

and the server applies a fixed-magnitude update on the selected entries.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def one_bit(x: Array) -> Array:
    """Client-side quantizer; sign with 0 mapped to +1 (a carrier is always sent)."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def fsk_majority_vote(key: Array, votes: Array, noise_std: float = 0.0) -> Array:
    """Server-side non-coherent majority vote over (N, k) one-bit votes."""
    return fsk_majority_from_energy(key, votes.sum(axis=0),
                                    noise_std=noise_std)


def fsk_majority_from_energy(key: Array, energy: Array,
                             noise_std: float = 0.0) -> Array:
    """Majority vote over a PRE-REDUCED (k,) vote-energy row (the
    superposed FSK energies Σ_n vote_n).  The streaming client fold
    accumulates the vote sum chunk by chunk — the (N, k) vote matrix is
    never live — and finishes here: noise on the energy, then the sign."""
    if noise_std > 0.0:
        energy = energy + noise_std * jax.random.normal(key, energy.shape,
                                                        energy.dtype)
    return jnp.where(energy >= 0, 1.0, -1.0).astype(energy.dtype)


def one_bit_round(key: Array, g_prev: Array, idx: Array, client_grads: Array,
                  noise_std: float = 0.0) -> Array:
    """One-bit variant of core.oac.oac_round: majority-vote signs on the
    selected coordinates, stale values elsewhere (used by Fig. 9 benchmark)."""
    votes = one_bit(client_grads[:, idx])            # (N, k)
    agg_sign = fsk_majority_vote(key, votes, noise_std)
    return g_prev.at[idx].set(agg_sign)
