"""Age-of-Update (AoU) bookkeeping — paper Eq. (10) and Fig. 5 statistics."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.packing import AGE_CAP

Array = jax.Array


def init_age(d: int) -> Array:
    """A_0 = 0 (paper Alg. 1 input)."""
    return jnp.zeros((d,), jnp.float32)


def update_age(age: Array, mask: Array) -> Array:
    """Eq. (10):  A_{t+1} = (A_t + 1) ∘ (1 − S_t), clipped at ``AGE_CAP``
    (the int8 server state would otherwise wrap past 127 under async lag
    plus extended local training)."""
    return jnp.minimum((age + 1.0) * (1.0 - mask), AGE_CAP)


def update_age_by_indices(age: Array, idx: Array) -> Array:
    """Index-form of Eq. (10): increment everywhere (clipped at
    ``AGE_CAP``), zero the selected."""
    return jnp.minimum(age + 1.0, AGE_CAP).at[idx].set(0.0)


def max_staleness(d: int, k: int, k_m: int) -> int:
    """Lemma 1's support bound  T = (d − k_M) / k_A  (ceil for non-divisible)."""
    k_a = k - k_m
    if k_a <= 0:
        raise ValueError("max staleness is unbounded when k_a = 0 (pure Top-k)")
    return -(-(d - k_m) // k_a)


def age_stats(age: Array) -> Dict[str, Array]:
    """Summary statistics used for the Fig. 5a comparison."""
    return {
        "mean": jnp.mean(age),
        "max": jnp.max(age),
        "p50": jnp.percentile(age, 50.0),
        "p99": jnp.percentile(age, 99.0),
    }
