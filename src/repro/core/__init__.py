"""Core library: the paper's contribution (FAIR-k + OAC aggregation) and its
analysis toolkit (Markov staleness model, smoothness-constant estimation)."""

from repro.core import (aou, channel, engine, lipschitz, markov, oac,
                        quantize, selection)
from repro.core.aou import init_age, max_staleness, update_age, update_age_by_indices
from repro.core.engine import (BACKENDS, EngineConfig, SelectionEngine,
                               make_engine)
from repro.core.markov import (FairKChain, aou_distribution, expected_staleness,
                               simulate_aou, steady_state, transition_matrix)
from repro.core.oac import NOISELESS, PAPER_DEFAULT, ChannelConfig, oac_round
from repro.core.selection import (POLICIES, age_top_k_indices, fair_k_indices,
                                  fair_k_mask, mask_from_indices, rand_k_indices,
                                  round_robin_indices, select_indices,
                                  top_k_indices, top_rand_indices)

__all__ = [
    "aou", "channel", "engine", "lipschitz", "markov", "oac", "quantize",
    "selection",
    "BACKENDS", "EngineConfig", "SelectionEngine", "make_engine",
    "init_age", "max_staleness", "update_age", "update_age_by_indices",
    "FairKChain", "aou_distribution", "expected_staleness", "simulate_aou",
    "steady_state", "transition_matrix",
    "NOISELESS", "PAPER_DEFAULT", "ChannelConfig", "oac_round",
    "POLICIES", "age_top_k_indices", "fair_k_indices", "fair_k_mask",
    "mask_from_indices", "rand_k_indices", "round_robin_indices",
    "select_indices", "top_k_indices", "top_rand_indices",
]
