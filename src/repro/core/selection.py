"""Parameter-selection policies for OAC-FL (paper Sec. III-B).

Every policy consumes the *server-side* state — the last reconstructed
global gradient ``g`` and the Age-of-Update vector ``age`` — and returns
either a dense 0/1 mask of shape ``(d,)`` or an index vector of exactly
``k`` coordinates.  Both forms are jit-compatible with static ``k``/``k_m``.

Policies implemented (paper Sec. V-A baselines):

* ``fair_k``      — the paper's contribution, Eq. (11).
* ``top_k``       — magnitude-only (``fair_k`` with ``k_m = k``).
* ``round_robin`` — age-only (``fair_k`` with ``k_m = 0``).
* ``top_rand``    — Top-``k_M`` + uniform random among the rest [17].
* ``age_top_k``   — AgeTop-k [47]: top ``r ≥ k`` by magnitude, then the
  ``k`` oldest among those candidates.
* ``rand_k``      — uniform random ``k`` (sanity baseline).

Note on Eq. (11): we use the *post-update* age in the age stage (see
DESIGN.md §1 "Algorithm-fidelity note"); pass the pre-update age explicitly
if the literal variant is wanted — the functions are pure in their inputs.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

# ages are >= 0; -1 can never win a top-k.  Kept a python float: a jnp
# constant here would initialize the jax backend at import time and lock the
# device count before launch/dryrun.py can set XLA_FLAGS.
_EXCLUDED_AGE = -1.0


# ---------------------------------------------------------------------------
# mask/index helpers
# ---------------------------------------------------------------------------

def mask_from_indices(idx: Array, d: int) -> Array:
    """Dense float32 0/1 mask from an index vector."""
    return jnp.zeros((d,), jnp.float32).at[idx].set(1.0)


def _top_indices(score: Array, k: int) -> Array:
    """Indices of the ``k`` largest entries of ``score`` (deterministic)."""
    _, idx = jax.lax.top_k(score, k)
    return idx


# ---------------------------------------------------------------------------
# policies — index form (exactly k indices, order: [magnitude stage, age stage])
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "k_m"))
def fair_k_indices(g: Array, age: Array, *, k: int, k_m: int) -> Array:
    """FAIR-k, Eq. (11):  Top(g, k_M)  ∪  Top(age ∘ (1 − Top(g, k_M)), k_A).

    Args:
      g:   last reconstructed global gradient, shape (d,).
      age: AoU vector, shape (d,), entries >= 0.
      k:   total selection budget (number of orthogonal waveforms).
      k_m: magnitude-stage budget, 0 <= k_m <= k.
    Returns:
      int32 index vector of shape (k,); the first ``k_m`` entries are the
      magnitude picks, the remaining ``k − k_m`` the age picks.
    """
    d = g.shape[0]
    if not 0 <= k_m <= k <= d:
        raise ValueError(f"need 0 <= k_m <= k <= d, got k_m={k_m} k={k} d={d}")
    k_a = k - k_m
    if k_m == 0:
        return _top_indices(age.astype(jnp.float32), k)
    idx_m = _top_indices(jnp.abs(g), k_m)
    if k_a == 0:
        return idx_m
    # exclude the magnitude picks from the age stage
    age_f = age.astype(jnp.float32).at[idx_m].set(_EXCLUDED_AGE)
    idx_a = _top_indices(age_f, k_a)
    return jnp.concatenate([idx_m, idx_a])


@functools.partial(jax.jit, static_argnames=("k",))
def top_k_indices(g: Array, *, k: int) -> Array:
    return _top_indices(jnp.abs(g), k)


@functools.partial(jax.jit, static_argnames=("k",))
def round_robin_indices(age: Array, *, k: int) -> Array:
    """Pure age-priority selection (FAIR-k with k_m = 0).

    ``lax.top_k`` breaks ties toward lower indices, so with all-equal initial
    ages the schedule cycles deterministically through the coordinates —
    i.e. classic round robin.
    """
    return _top_indices(age.astype(jnp.float32), k)


@functools.partial(jax.jit, static_argnames=("k", "k_m"))
def top_rand_indices(key: Array, g: Array, *, k: int, k_m: int) -> Array:
    """TopRand [17]: Top-``k_M`` by magnitude + uniform random k_A of the rest."""
    d = g.shape[0]
    k_a = k - k_m
    idx_m = _top_indices(jnp.abs(g), k_m) if k_m > 0 else jnp.zeros((0,), jnp.int32)
    if k_a == 0:
        return idx_m
    # random scores; exclude magnitude picks by forcing their score below all
    score = jax.random.uniform(key, (d,), jnp.float32, minval=0.0, maxval=1.0)
    if k_m > 0:
        score = score.at[idx_m].set(-1.0)
    idx_a = _top_indices(score, k_a)
    return jnp.concatenate([idx_m, idx_a]) if k_m > 0 else idx_a


@functools.partial(jax.jit, static_argnames=("k", "r"))
def age_top_k_indices(g: Array, age: Array, *, k: int, r: int) -> Array:
    """AgeTop-k [47]: restrict to the top-``r`` magnitudes (r >= k), then pick
    the ``k`` oldest among them (magnitude as tie-break via index order)."""
    if r < k:
        raise ValueError(f"AgeTop-k needs r >= k, got r={r} k={k}")
    idx_r = _top_indices(jnp.abs(g), r)                  # candidates
    cand_age = age.astype(jnp.float32)[idx_r]
    _, pos = jax.lax.top_k(cand_age, k)                  # oldest k among them
    return idx_r[pos]


@functools.partial(jax.jit, static_argnames=("d", "k"))
def rand_k_indices(key: Array, d: int, *, k: int) -> Array:
    score = jax.random.uniform(key, (d,), jnp.float32)
    return _top_indices(score, k)


# ---------------------------------------------------------------------------
# policies — dense mask form
# ---------------------------------------------------------------------------

def fair_k_mask(g: Array, age: Array, *, k: int, k_m: int) -> Array:
    return mask_from_indices(fair_k_indices(g, age, k=k, k_m=k_m), g.shape[0])


def top_k_mask(g: Array, *, k: int) -> Array:
    return mask_from_indices(top_k_indices(g, k=k), g.shape[0])


def round_robin_mask(age: Array, *, k: int) -> Array:
    return mask_from_indices(round_robin_indices(age, k=k), age.shape[0])


def top_rand_mask(key: Array, g: Array, *, k: int, k_m: int) -> Array:
    return mask_from_indices(top_rand_indices(key, g, k=k, k_m=k_m), g.shape[0])


def age_top_k_mask(g: Array, age: Array, *, k: int, r: int) -> Array:
    return mask_from_indices(age_top_k_indices(g, age, k=k, r=r), g.shape[0])


def rand_k_mask(key: Array, d: int, *, k: int) -> Array:
    return mask_from_indices(rand_k_indices(key, d, k=k), d)


# ---------------------------------------------------------------------------
# policy registry (string-driven, used by the FL trainer and launch CLI)
# ---------------------------------------------------------------------------

POLICIES = ("fairk", "topk", "roundrobin", "toprand", "agetopk", "randk")


def select_indices(policy: str, key: Array, g: Array, age: Array, *,
                   k: int, k_m: int, r: int) -> Array:
    """Uniform entry point: returns exactly ``k`` selected indices."""
    if policy == "fairk":
        return fair_k_indices(g, age, k=k, k_m=k_m)
    if policy == "topk":
        return top_k_indices(g, k=k)
    if policy == "roundrobin":
        return round_robin_indices(age, k=k)
    if policy == "toprand":
        return top_rand_indices(key, g, k=k, k_m=k_m)
    if policy == "agetopk":
        return age_top_k_indices(g, age, k=k, r=r)
    if policy == "randk":
        return rand_k_indices(key, g.shape[0], k=k)
    raise ValueError(f"unknown policy {policy!r}; choose from {POLICIES}")
