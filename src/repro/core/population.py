"""Population-scale client simulator (DESIGN.md §15).

``core.faults`` carries one Gilbert–Elliott availability chain per
*compute* client — fine for the FL sim's handful of vmapped clients,
useless for the paper's "millions of users" scenario where the server
samples a small participant cohort per round out of a huge, churning
population.  This module scales the chain to 1e5–1e6 virtual clients in
ONE compiled program:

* **packed cohort state** — per-client availability lives in a single
  ``(n_cohorts, cohort_size)`` int8 array (1 = up, 0 = down, -1 = pad);
  the chain transition is a vmapped-over-cohorts elementwise state
  machine, so a million clients advance in one fused op and the whole
  trajectory scans (``population_scan``) with zero Python loops.
* **three availability modes** — ``iid`` (memoryless Bernoulli at the
  stationary rate), ``ge`` (Gilbert–Elliott bursts: mean down-dwell
  ``burst`` rounds, same algebra as ``faults.ge_probs``), and
  ``diurnal`` (a sinusoidal availability rate — the day/night wave —
  whose time-average is pinned at ``avail`` so the stationary staleness
  prediction still composes).
* **cohort-layout determinism** — every per-client uniform is drawn as
  ONE flat counter-based ``(n_clients,)`` vector and then padded +
  reshaped into the cohort grid, so the same seed produces bit-identical
  availability traces whatever ``cohort_size`` the host picked.  (A
  per-cohort ``fold_in`` key would re-shuffle the stream whenever the
  batch shape changed.)
* **per-round participation** — the server samples ``participants``
  clients uniformly (with replacement) from the live population; the
  round's stats report the realized participation ``n_t`` (feeding
  ``faults.participation_scale``), the mid-round *churn* fraction
  (participants whose chain transitions down during the round — their
  partially-transmitted symbol blocks erase at ``exposure``), the
  straggler share (a static per-client Knuth-hash propensity — the
  population-driven replacement for the launch path's fixed
  coordinate-hash pattern) and the live population size.

Staleness composition (paper Sec. IV-B): a mid-round vanish erases each
symbol block of the aggregate independently with probability
``exposure * churn`` (clients interleave their uplink across the round,
so a client lost halfway takes out a random ~``exposure`` of its
blocks), and a TOTAL outage of the sampled cohort erases the round
outright.  Both are per-round-independent refresh blockers, so the
stationary post-update AoU pmf is the participation-thinned Lemma-1 law
``markov.thinned_aou_distribution(chain, cfg.thin)`` — exposed as
``markov.population_aou_distribution`` and validated by
``tests/test_population.py`` against the empirical histogram on the
exact AND packed backends.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

PAD = -1                               # cohort-grid pad sentinel (int8)


@dataclasses.dataclass(frozen=True)
class PopulationConfig:
    """A virtual client population.  Hashable (jit-static) and all-static:
    every traced quantity derives from (state, key, round index)."""
    n_clients: int = 100_000       # virtual population size
    cohort_size: int = 4096        # clients per packed cohort row
    participants: int = 8          # M: clients sampled per round (with
                                   # replacement — at population scale the
                                   # collision probability is negligible)
    avail: float = 0.9             # stationary per-client availability
    mode: str = "iid"              # iid | ge | diurnal
    burst: float = 8.0             # mean down-state dwell in rounds
                                   # (mode="ge" only)
    period: int = 96               # diurnal cycle length in rounds
    depth: float = 0.1             # diurnal swing: the availability rate
                                   # oscillates in avail * (1 ± depth);
                                   # avail * (1 + depth) <= 1 keeps the
                                   # time-average exactly at ``avail``
    slow_frac: float = 0.0         # straggler propensity: the static
                                   # fraction of clients whose uplink
                                   # lands one aggregation late
    exposure: float = 0.5          # fraction of a mid-round vanisher's
                                   # symbol blocks lost (uplink exposure
                                   # at the expected vanish time)
    erase_block: int = 64          # coordinates per churn-erasure block
                                   # (one OFDM symbol group's worth)

    def __post_init__(self):
        if self.n_clients < 1:
            raise ValueError(f"n_clients must be >= 1, got {self.n_clients}")
        if self.cohort_size < 1:
            raise ValueError(
                f"cohort_size must be >= 1, got {self.cohort_size}")
        if not 1 <= self.participants <= self.n_clients:
            raise ValueError(
                f"participants must be in [1, n_clients={self.n_clients}], "
                f"got {self.participants}")
        if not 0.0 < self.avail <= 1.0:
            raise ValueError(f"avail must be in (0, 1], got {self.avail}")
        if self.mode not in ("iid", "ge", "diurnal"):
            raise ValueError(
                f"mode must be iid|ge|diurnal, got {self.mode!r}")
        if self.mode == "ge":
            if self.burst < 1.0:
                raise ValueError(
                    f"burst must be >= 1 round, got {self.burst}")
            # p_gb = (1 - avail) / (avail * burst) must be a probability:
            # very unavailable populations need dwells at least as long as
            # the down/up odds (the mirror of faults.FaultConfig's
            # feasibility bound)
            need = (1.0 - self.avail) / self.avail
            if self.burst < need:
                raise ValueError(
                    f"infeasible Gilbert–Elliott chain: avail={self.avail} "
                    f"needs burst >= (1-avail)/avail = {need:.3f}, got "
                    f"{self.burst} (the up->down rate would exceed 1)")
        if self.mode == "diurnal":
            if self.period < 2:
                raise ValueError(
                    f"period must be >= 2 rounds, got {self.period}")
            if not 0.0 <= self.depth:
                raise ValueError(f"depth must be >= 0, got {self.depth}")
            if self.avail * (1.0 + self.depth) > 1.0 + 1e-9:
                raise ValueError(
                    f"diurnal peak avail*(1+depth) = "
                    f"{self.avail * (1.0 + self.depth):.3f} > 1 — the "
                    "clipped wave would shift the time-average off "
                    f"avail={self.avail}; lower depth")
        if not 0.0 <= self.slow_frac < 1.0:
            raise ValueError(
                f"slow_frac must be in [0, 1), got {self.slow_frac}")
        if not 0.0 < self.exposure <= 1.0:
            raise ValueError(
                f"exposure must be in (0, 1], got {self.exposure}")
        if self.erase_block < 1:
            raise ValueError(
                f"erase_block must be >= 1, got {self.erase_block}")

    @property
    def n_cohorts(self) -> int:
        return -(-self.n_clients // self.cohort_size)

    @property
    def n_padded(self) -> int:
        return self.n_cohorts * self.cohort_size

    @property
    def vanish_rate(self) -> float:
        """Stationary per-round P(up -> down) of one client's chain — the
        rate at which a round-start participant churns mid-round.

        iid: 1 - avail (the next state is an independent draw).  ge: the
        up->down rate (1-avail)/(avail*burst) — bursts make an *up* client
        stickier, so mid-round churn FALLS as burst grows even though the
        stationary availability is pinned.  diurnal: time-average of the
        instantaneous rate 1 - a(t), which the zero-mean sinusoid keeps at
        1 - avail."""
        if self.mode == "ge":
            return (1.0 - self.avail) / (self.avail * self.burst)
        return 1.0 - self.avail

    @property
    def thin(self) -> float:
        """Effective per-round refresh-blocking probability for the
        participation-thinned Lemma-1 law (``markov.
        population_aou_distribution``) and the controller setpoint:
        mid-round churn erasure (``exposure * vanish_rate`` per block)
        plus the total-outage term (all ``participants`` sampled clients
        down at once erases the whole round)."""
        outage = (1.0 - self.avail) ** self.participants
        return min(0.99, self.exposure * self.vanish_rate + outage)


# ---------------------------------------------------------------------------
# chain algebra
# ---------------------------------------------------------------------------

def transition_probs(cfg: PopulationConfig) -> Tuple[float, float]:
    """Static (p_gb, p_bg) for the memory-bearing modes.  iid is the
    memoryless special case; diurnal rates are time-varying — use
    ``availability_rate`` instead."""
    if cfg.mode == "ge":
        p_bg = 1.0 / cfg.burst
        return (1.0 - cfg.avail) / cfg.avail * p_bg, p_bg
    # iid / diurnal-at-mean: next state independent of current state
    return 1.0 - cfg.avail, cfg.avail


def availability_rate(cfg: PopulationConfig, t) -> Array:
    """Instantaneous availability rate a(t) — constant except in diurnal
    mode, where it rides a sinusoid of period ``cfg.period`` whose
    time-average is exactly ``cfg.avail``."""
    if cfg.mode != "diurnal":
        return jnp.float32(cfg.avail)
    phase = 2.0 * jnp.pi * jnp.asarray(t, jnp.float32) / float(cfg.period)
    return jnp.float32(cfg.avail) * (1.0 + cfg.depth * jnp.sin(phase))


def client_jitter(ids: Array) -> Array:
    """Static per-client propensity in [0, 1) — the same Knuth
    multiplicative hash the kernels use for coordinate jitter, applied to
    client ids: reproducible, trace-static, no carried state."""
    h = ids.astype(jnp.uint32) * jnp.uint32(2654435761)
    return h.astype(jnp.float32) * jnp.float32(2.0 ** -32)


def _flat_uniform(key: Array, cfg: PopulationConfig) -> Array:
    """(n_cohorts, cohort_size) uniforms whose first ``n_clients`` values
    (flattened) depend ONLY on ``key`` — never on the cohort layout.  The
    draw is one flat counter-based ``(n_clients,)`` vector; pads fill
    with 2.0 (an impossible uniform, and ``>= p`` for every probability,
    so a pad's "transition" is the no-op branch even before masking)."""
    u = jax.random.uniform(key, (cfg.n_clients,), jnp.float32)
    pad = cfg.n_padded - cfg.n_clients
    if pad:
        u = jnp.concatenate([u, jnp.full((pad,), 2.0, jnp.float32)])
    return u.reshape(cfg.n_cohorts, cfg.cohort_size)


def _cohort_step(avail_c: Array, u_c: Array, p_gb, p_bg) -> Array:
    """One chain transition for one cohort row — elementwise where-ops
    only, vmapped over the cohort axis by ``population_step``."""
    up = avail_c == 1
    valid = avail_c >= 0
    nxt = jnp.where(up, u_c >= p_gb, u_c < p_bg).astype(jnp.int8)
    return jnp.where(valid, nxt, avail_c)


# ---------------------------------------------------------------------------
# packed population state
# ---------------------------------------------------------------------------

def init_population_state(key: Array, cfg: PopulationConfig
                          ) -> Dict[str, Array]:
    """Stationary-law initial state: ``avail`` is the packed
    (n_cohorts, cohort_size) int8 grid (1 up / 0 down / -1 pad), ``t``
    the round counter driving the diurnal phase."""
    u = _flat_uniform(key, cfg)
    avail = (u < availability_rate(cfg, 0)).astype(jnp.int8)
    avail = jnp.where(u > 1.0, jnp.int8(PAD), avail)
    return {"avail": avail, "t": jnp.int32(0)}


def population_step(state: Dict[str, Array], key: Array,
                    cfg: PopulationConfig) -> Dict[str, Array]:
    """Advance every chain one round: one flat uniform draw, one vmapped
    elementwise transition over the cohort axis.  Diurnal mode derives
    its (traced) rates from the carried round counter."""
    if cfg.mode == "diurnal":
        a = availability_rate(cfg, state["t"])
        p_gb, p_bg = 1.0 - a, a
    else:
        p_gb, p_bg = transition_probs(cfg)
    u = _flat_uniform(key, cfg)
    avail = jax.vmap(_cohort_step, in_axes=(0, 0, None, None))(
        state["avail"], u, p_gb, p_bg)
    return {"avail": avail, "t": state["t"] + 1}


def _participation_stats(avail_now: Array, avail_next: Array, key: Array,
                         cfg: PopulationConfig) -> Dict[str, Array]:
    """Sample the round's cohort and summarize it.  ``part`` gates the
    OAC superposition slot-by-slot; ``churn`` is the fraction of the
    realized participants whose chain transitions down mid-round (their
    blocks erase at ``exposure``); ``slow_share`` feeds the launch path's
    ``age_lag`` straggler machinery."""
    flat_now = avail_now.reshape(-1)
    flat_next = avail_next.reshape(-1)
    ids = jax.random.randint(key, (cfg.participants,), 0, cfg.n_clients)
    part = (flat_now[ids] == 1).astype(jnp.float32)
    n_t = part.sum()
    vanish = part * (flat_next[ids] == 0).astype(jnp.float32)
    slow = part * (client_jitter(ids) < cfg.slow_frac).astype(jnp.float32)
    denom = jnp.maximum(n_t, 1.0)
    return {"part": part, "n_t": n_t,
            "churn": vanish.sum() / denom,
            "slow": slow, "slow_share": slow.sum() / denom,
            "n_avail": (flat_now == 1).sum().astype(jnp.float32)}


def population_round(state: Dict[str, Array], key: Array,
                     cfg: PopulationConfig
                     ) -> Tuple[Dict[str, Array], Dict[str, Array]]:
    """One full population round: sample participants from the current
    state, advance every chain, and couple mid-round churn to the actual
    transitions (a participant "vanishes mid-round" exactly when its
    chain lands down at the round boundary).  Returns (state', stats)."""
    key_t, key_p = jax.random.split(key)
    nxt = population_step(state, key_t, cfg)
    stats = _participation_stats(state["avail"], nxt["avail"], key_p, cfg)
    stats["rate"] = availability_rate(cfg, state["t"])
    return nxt, stats


def stateless_round(base_key: Array, t, cfg: PopulationConfig
                    ) -> Dict[str, Array]:
    """Memoryless population round for the launch path (iid | diurnal).

    Both modes draw the next state independently of the current one, so
    no chain state needs to ride the (checkpointed, sharded) server
    state: round r's availability is a pure counter-based function of
    ``(base_key, r)``, which makes round t's "next" grid bit-identical
    to round t+1's "current" grid by construction — the stateless
    trajectory IS a lawful chain trajectory.  Gilbert–Elliott mode has
    memory and must carry ``init_population_state``/``population_round``
    state instead."""
    if cfg.mode == "ge":
        raise ValueError(
            "stateless_round supports the memoryless modes (iid, diurnal); "
            "Gilbert–Elliott bursts carry chain state — use "
            "init_population_state / population_round")
    t = jnp.asarray(t, jnp.int32)
    key_avail = jax.random.fold_in(base_key, 0xA)
    key_part = jax.random.fold_in(base_key, 0xB)
    u_now = jax.random.uniform(jax.random.fold_in(key_avail, t),
                               (cfg.n_clients,), jnp.float32)
    u_next = jax.random.uniform(jax.random.fold_in(key_avail, t + 1),
                                (cfg.n_clients,), jnp.float32)
    avail_now = (u_now < availability_rate(cfg, t)).astype(jnp.int8)
    avail_next = (u_next < availability_rate(cfg, t + 1)).astype(jnp.int8)
    stats = _participation_stats(avail_now, avail_next,
                                 jax.random.fold_in(key_part, t), cfg)
    stats["rate"] = availability_rate(cfg, t)
    return stats


# ---------------------------------------------------------------------------
# round-level effects
# ---------------------------------------------------------------------------

def churn_erase_mask(key: Array, d: int, churn: Array,
                     cfg: PopulationConfig) -> Array:
    """(d,) f32 erasure mask (1.0 = erased) from mid-round churn: each
    ``erase_block``-coordinate symbol group of the aggregate erases
    independently with (traced) probability ``exposure * churn`` —
    clients interleave their uplink across the round, so a vanisher's
    loss lands on a random ~``exposure`` share of blocks, independent
    across blocks once averaged over the cohort.  Same block semantics
    as ``faults.fade_mask`` with a traced rate."""
    nb = -(-d // cfg.erase_block)
    p = jnp.clip(jnp.asarray(churn, jnp.float32) * cfg.exposure, 0.0, 1.0)
    hit = jax.random.uniform(key, (nb,)) < p
    return jnp.repeat(hit.astype(jnp.float32), cfg.erase_block)[:d]


def population_scan(cfg: PopulationConfig, rounds: int, key: Array
                    ) -> Tuple[Dict[str, Array], Dict[str, Array]]:
    """Whole-trajectory availability scan in ONE compiled program — the
    1e5-client smoke and the diurnal-wave diagnostics.  Returns the final
    state and per-round traces of (n_avail, n_t, churn, slow_share,
    rate)."""
    key_init, key_run = jax.random.split(key)
    state0 = init_population_state(key_init, cfg)

    def body(state, key_r):
        nxt, ps = population_round(state, key_r, cfg)
        return nxt, {k: ps[k] for k in ("n_avail", "n_t", "churn",
                                        "slow_share", "rate")}

    return jax.lax.scan(body, state0, jax.random.split(key_run, rounds))


population_scan_jit = jax.jit(population_scan,
                              static_argnames=("cfg", "rounds"))
