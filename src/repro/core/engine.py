"""Unified SelectionEngine: one API over the three FAIR-k execution paths.

The paper's selection rule (Eq. 11) and server update (Eq. 8-10) exist at
three operating points in this repo, historically implemented three times:

* ``exact``      — index-form ``lax.top_k`` policies (``core.selection``),
  the paper-faithful simulation path.  Exact budget (k indices), supports
  all six policies, cost O(d log d) — fine to d ~ 1e7.
* ``threshold``  — sampled-quantile thresholds θ_M / θ_A plus the fused
  ``fairk_update`` Pallas kernel: one HBM pass over (g, g_prev, age), no
  sort.  Approximate budget (|selected| ≈ k), FAIR-k-family policies only,
  the d ~ 1e8-1e9 single-device production route.
* ``sharded``    — the threshold math inside ``shard_map``: every device
  updates its local shard with locally estimated thresholds, zero extra
  collectives.  The multi-device production route (launch.steps).

``SelectionEngine`` puts all three behind ``select_and_merge(g, g_prev,
age)`` -> ``(g_t, age', stats)`` so trainers, benchmarks and tests can swap
backends without touching call sites, and so cross-backend parity is
testable (see tests/test_engine.py): with ``exact_theta=True`` the
threshold/sharded backends compute order-statistic thresholds that select
*identical* coordinates to ``exact`` on tie-free inputs.

Semantics (all backends):
  selection scores the first argument ``g`` (the production server scores
  the fresh aggregate; the paper's trainer scores g_{t-1} — pass whichever
  the algorithm calls for), fresh values come from ``g``, stale values from
  ``g_prev``, and the AoU vector advances by Eq. (10) capped at
  ``AGE_CAP`` (the fused kernel's staleness clip).

Error feedback & one-bit (all backends, not just exact):
  ``select_and_merge(..., residual=...)`` folds the error-feedback
  accumulator back pre-selection — the score and the transmitted values
  become ``g + residual`` — and returns the updated accumulator in
  ``stats["residual"]`` (unsent mass on unselected coordinates,
  quantization error on selected ones).  On the threshold/packed backends
  the residual stage rides the SAME fused kernel pass
  (``kernels.fairk_ef_update``).  ``fresh=...`` decouples the transmitted
  values from the score source — the one-bit FSK-MV route passes the
  majority-vote sign vector (``kernels.sign_mv``) as ``fresh`` while
  scoring the vote energy.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import packing, selection

Array = jax.Array

BACKENDS = ("exact", "threshold", "sharded", "packed")

# FAIR-k-family policies expressible as (θ_M, θ_A) thresholds; the other
# three (toprand / agetopk / randk) need index arithmetic -> exact only.
THRESHOLD_POLICIES = ("fairk", "topk", "roundrobin")

# staleness clip baked into the fused kernel (kernels/fairk_update.py);
# canonical definition lives next to the int8/pad protocol in
# core.packing — re-exported here because every trainer imports it from
# the engine
AGE_CAP = packing.AGE_CAP


# ---------------------------------------------------------------------------
# threshold building blocks (promoted from launch/steps.py)
# ---------------------------------------------------------------------------

def jitter_from_ids(ids) -> Array:
    """Deterministic per-coordinate jitter in [0, 1): Knuth hash of the
    coordinate index.  THE canonical host-side formula — must stay
    bit-identical to the in-kernel recomputation in kernels/fairk_update.py
    and its oracle in kernels/ref.py (tie-break parity depends on it)."""
    u = jnp.asarray(ids).astype(jnp.uint32)
    return (u * jnp.uint32(2654435761) % jnp.uint32(1 << 24)
            ).astype(jnp.float32) / float(1 << 24)


def index_jitter(n: int, offset=0) -> Array:
    """Jitter for coordinates [offset, offset + n) — breaks integer-age
    ties without an extra input.  ``offset`` (static or traced) is the
    global index of the first local coordinate, so shards hash the same ids
    as the unsharded path."""
    return jitter_from_ids(jax.lax.iota(jnp.uint32, n)
                           + jnp.asarray(offset, jnp.uint32))


def strided_sample(x: Array, cap: int) -> Array:
    n = x.shape[0]
    stride = max(1, n // cap)
    return x[::stride]


def thresholds_from_samples(mag_s: Array, age_eff_s: Array, *, rho: float,
                            k_m_frac) -> Tuple[Array, Array]:
    """(θ_M, θ_A) quantiles from pre-drawn samples of |g| and jittered age.

    θ_M ≈ the (1 − ρ·k_m_frac) quantile of |g|; θ_A sizes the age stage to
    the residual budget over the whole vector (the complement correction is
    the (1 − ρ_M) denominator).  ``k_m_frac`` may be a traced scalar (the
    adaptive-budget controller, core/controller.py): the degenerate-stage
    short-circuits then become ``where``s on quantiles computed either
    way — same values, data-dependent instead of trace-dependent."""
    rho_m = rho * k_m_frac
    if isinstance(rho_m, (int, float)):
        rho_a = (rho - rho_m) / max(1.0 - rho_m, 1e-6)
        theta_m = (jnp.quantile(mag_s, 1.0 - rho_m)
                   if rho_m > 0.0 else jnp.float32(jnp.inf))
        theta_a = (jnp.quantile(age_eff_s, 1.0 - rho_a)
                   if rho_a > 0.0 else jnp.float32(jnp.inf))
        return theta_m.astype(jnp.float32), theta_a.astype(jnp.float32)
    rho_m = jnp.asarray(rho_m, jnp.float32)
    rho_a = (rho - rho_m) / jnp.maximum(1.0 - rho_m, 1e-6)
    theta_m = jnp.where(rho_m > 0.0,
                        jnp.quantile(mag_s, jnp.clip(1.0 - rho_m, 0.0, 1.0)),
                        jnp.inf)
    theta_a = jnp.where(rho_a > 0.0,
                        jnp.quantile(age_eff_s,
                                     jnp.clip(1.0 - rho_a, 0.0, 1.0)),
                        jnp.inf)
    return theta_m.astype(jnp.float32), theta_a.astype(jnp.float32)


def sampled_thresholds(g: Array, age: Array, *, rho: float, k_m_frac,
                       sample_cap: int,
                       sample_ids: Optional[Array] = None,
                       residual: Optional[Array] = None,
                       sanitize: bool = False
                       ) -> Tuple[Array, Array]:
    """(θ_M, θ_A) from strided-sample quantiles (no global sort).

    This is a read pass over (a strided sample of) the gradient buffer —
    on the fused-stats path it is replaced by ``packing.hist_thresholds``
    over the kernel-emitted histograms, and the trace counter below is
    what ``packed_bench --smoke`` uses to prove the replacement.

    ``sample_ids`` (static int32 positions, e.g. ``PackedLayout.sample_ids``)
    restricts the sample to those coordinates — REQUIRED on packed buffers,
    where pad zeros in the sample would bias θ_M low (jitter still hashes
    the true buffer positions so ties break identically to the kernel).

    ``residual`` (error feedback) folds into the magnitude statistic:
    θ_M is estimated on ``|g + residual|`` — the residual is sampled at the
    same positions and added sample-wise, so no d-length effective-gradient
    temp is materialised for the estimate.

    ``sanitize`` (static) demotes non-finite sample scores to magnitude 0
    and age −1 — they land at the bottom of both order statistics, so a
    corrupted coordinate can only *tighten* the estimated thresholds,
    never poison them with NaN (a single NaN sample makes
    ``jnp.quantile`` return NaN, which would zero the entire round)."""
    packing.G_READS += 1
    age32 = age.astype(jnp.float32)
    if sample_ids is None:
        g_s = strided_sample(g.astype(jnp.float32), sample_cap)
        if residual is not None:
            g_s = g_s + strided_sample(residual.astype(jnp.float32),
                                       sample_cap)
        age_s = strided_sample(age32 + index_jitter(g.shape[0]), sample_cap)
    else:
        ids = jnp.asarray(sample_ids)
        g_s = g[ids].astype(jnp.float32)
        if residual is not None:
            g_s = g_s + residual[ids].astype(jnp.float32)
        age_s = age32[ids] + jitter_from_ids(ids)
    if sanitize:
        fin_s = jnp.isfinite(g_s)
        g_s = jnp.where(fin_s, g_s, 0.0)
        age_s = jnp.where(fin_s, age_s, -1.0)
    return thresholds_from_samples(jnp.abs(g_s), age_s, rho=rho,
                                   k_m_frac=k_m_frac)


def exact_thresholds(g: Array, age: Array, *, k: int, k_m: int,
                     sanitize: bool = False) -> Tuple[Array, Array]:
    """Order-statistic (θ_M, θ_A) that reproduce exact FAIR-k on tie-free
    inputs: θ_M sits strictly between the k_m-th and (k_m+1)-th largest
    |g|, θ_A between the k_a-th and (k_a+1)-th largest jittered age *among
    the magnitude-stage complement*.  O(d log d) — parity/testing path.
    ``sanitize`` demotes non-finite scores to magnitude −1 / age −inf so
    they rank below every real coordinate in both stages."""
    packing.G_READS += 1
    d = g.shape[0]
    k_a = k - k_m
    g32 = g.astype(jnp.float32)
    mag = jnp.abs(g32)
    fin = None
    if sanitize:
        fin = jnp.isfinite(g32)
        mag = jnp.where(fin, mag, -1.0)
    if k_m == 0:
        theta_m = jnp.float32(jnp.inf)
        mask_m = jnp.zeros((d,), bool)
    else:
        vals = jax.lax.top_k(mag, min(k_m + 1, d))[0]
        edge = vals[-1] if k_m >= d else vals[k_m]
        theta_m = (vals[k_m - 1] + edge) / 2.0
        mask_m = mag >= theta_m
    if k_a == 0:
        return theta_m, jnp.float32(jnp.inf)
    age_eff = age.astype(jnp.float32) + index_jitter(d)
    rest = jnp.where(mask_m, -jnp.inf, age_eff)
    if fin is not None:
        rest = jnp.where(fin, rest, -jnp.inf)
    vals = jax.lax.top_k(rest, min(k_a + 1, d))[0]
    edge = vals[-1] if k_a >= d else vals[k_a]
    theta_a = (vals[k_a - 1] + edge) / 2.0
    return theta_m, theta_a


def exact_thresholds_dynamic(g: Array, age: Array, *, k: int, k_m,
                             sanitize: bool = False
                             ) -> Tuple[Array, Array]:
    """``exact_thresholds`` with a *traced* magnitude budget ``k_m``
    (int32 in [0, k]; ``k`` stays static — the adaptive controller only
    moves the split).  Identical thresholds to the static version at the
    same ``k_m``: both read the midpoints between the ranked order
    statistics, here gathered at a dynamic rank out of one static
    ``top_k(·, k + 1)`` whose leading values match the static call's."""
    packing.G_READS += 1
    d = g.shape[0]
    kk = min(k + 1, d)
    km = jnp.clip(jnp.asarray(k_m, jnp.int32), 0, k)
    g32 = g.astype(jnp.float32)
    mag = jnp.abs(g32)
    fin = None
    if sanitize:
        fin = jnp.isfinite(g32)
        mag = jnp.where(fin, mag, -1.0)
    vals = jax.lax.top_k(mag, kk)[0]
    hi = vals[jnp.maximum(km - 1, 0)]
    edge = vals[jnp.minimum(km, kk - 1)]
    theta_m = jnp.where(km == 0, jnp.inf, (hi + edge) / 2.0
                        ).astype(jnp.float32)
    mask_m = mag >= theta_m
    k_a = k - km
    age_eff = age.astype(jnp.float32) + index_jitter(d)
    rest = jnp.where(mask_m, -jnp.inf, age_eff)
    if fin is not None:
        rest = jnp.where(fin, rest, -jnp.inf)
    avals = jax.lax.top_k(rest, kk)[0]
    ahi = avals[jnp.maximum(k_a - 1, 0)]
    aedge = avals[jnp.minimum(k_a, kk - 1)]
    theta_a = jnp.where(k_a == 0, jnp.inf, (ahi + aedge) / 2.0
                        ).astype(jnp.float32)
    return theta_m, theta_a


# ---------------------------------------------------------------------------
# rank-based FAIR-k: the traced-k_m mask form (shared with fl/sweep.py)
# ---------------------------------------------------------------------------

def rank_desc(x: Array) -> Array:
    """rank[i] = number of entries strictly ranked above x[i] (descending,
    ties toward lower index — matching ``lax.top_k``)."""
    d = x.shape[0]
    order = jnp.argsort(-x, stable=True)
    return jnp.zeros((d,), jnp.int32).at[order].set(
        jnp.arange(d, dtype=jnp.int32))


def fair_k_masks_dynamic(score: Array, age: Array, k: int, k_m
                         ) -> Tuple[Array, Array]:
    """Rank-based FAIR-k (Eq. 11) with a *traced* magnitude budget ``k_m``:
    (mask, mask_m) float32, exactly ``k`` ones in ``mask``.  The exact
    index policies concatenate top-k vectors of static lengths, so a
    traced split selects by rank instead —

        mask_M = rank(score)        < k_m
        mask_A = rank(age ⊙ ¬mask_M) < k − k_m

    — the identical coordinate set (rank and top-k agree on tie-free
    inputs; ties break toward lower index in both).  ``score`` is the
    magnitude-stage statistic (|g| for FAIR-k, random for Rand-k)."""
    mask_m = rank_desc(score) < k_m
    # age stage on the complement; -1 can never win (ages are >= 0) and
    # the index tie-break mirrors lax.top_k via the stable argsort
    age_rest = jnp.where(mask_m, -1.0, age.astype(jnp.float32))
    mask_a = rank_desc(age_rest) < (k - k_m)
    return ((mask_m | mask_a).astype(jnp.float32),
            mask_m.astype(jnp.float32))


def fair_k_mask_dynamic(score: Array, age: Array, k: int, k_m) -> Array:
    """The combined FAIR-k mask of ``fair_k_masks_dynamic`` (the form the
    vmapped sweep grid consumes)."""
    return fair_k_masks_dynamic(score, age, k, k_m)[0]


def traced_km(k: int, k_m_frac) -> Array:
    """``k_m = round(k_m_frac · k)`` as traced int32 — THE rounding/clip
    convention of the traced-split stack (the engine backends, the FL
    trainer's exact-adaptive route and the sweep lanes all call this one
    function, so the bit-exact traced==static parity can never drift)."""
    return jnp.round(jnp.clip(jnp.asarray(k_m_frac, jnp.float32),
                              0.0, 1.0) * k).astype(jnp.int32)


def threshold_mask(g: Array, age: Array, theta_m: Array, theta_a: Array,
                   index_offset=0) -> Tuple[Array, Array]:
    """Dense float32 (mask, mask_m) for the two-stage threshold rule —
    the jnp mirror of the fused kernel's in-register mask.  When applied to
    a shard, pass the shard's global start index as ``index_offset`` so the
    age jitter matches the unsharded selection."""
    mag = jnp.abs(g.astype(jnp.float32))
    mask_m = mag >= theta_m
    age_eff = age.astype(jnp.float32) + index_jitter(g.shape[0],
                                                     index_offset)
    mask_a = (age_eff >= theta_a) & (~mask_m)
    return (mask_m | mask_a).astype(jnp.float32), mask_m.astype(jnp.float32)


def eff_score(g: Array, residual: Optional[Array]) -> Array:
    """The error-feedback fold ``score = g + residual`` in f32 — THE
    formula the fused kernel recomputes per block (kernels/fairk_update.py);
    every host-side use must stay bit-identical to it."""
    g32 = g.astype(jnp.float32)
    return g32 if residual is None else g32 + residual.astype(jnp.float32)


def masked_merge(fresh: Array, g_prev: Array, age: Array, mask: Array
                 ) -> Tuple[Array, Array]:
    """Eq. (8) stale merge + Eq. (10) AoU update (mask form, f32 out)."""
    keep = 1.0 - mask
    g_t = mask * fresh.astype(jnp.float32) + keep * g_prev.astype(jnp.float32)
    age_next = jnp.minimum((age.astype(jnp.float32) + 1.0) * keep, AGE_CAP)
    return g_t, age_next


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Backend-independent FAIR-k settings.

    Budgets derive from (rho, k_m_frac, r_frac) unless (k, k_m, r) are
    given explicitly.  ``exact_theta`` switches the threshold/sharded
    backends from sampled quantiles to order-statistic thresholds (parity
    mode); ``global_thresholds`` makes the sharded backend estimate one
    (θ_M, θ_A) pair on the full vector instead of per shard."""
    policy: str = "fairk"
    backend: str = "exact"
    rho: float = 0.1
    k_m_frac: float = 0.75
    r_frac: float = 1.5                  # AgeTop-k candidate ratio r / k
    k: Optional[int] = None
    k_m: Optional[int] = None
    r: Optional[int] = None
    sample_cap: int = 65536              # quantile sample size
    exact_theta: bool = False
    global_thresholds: bool = False
    noise_std: float = 0.0               # channel noise on fresh coords
    n_clients: int = 1                   # N in Eq. (7) (noise / N scaling)
    kernel_mode: Optional[str] = None    # None auto | pallas | interpret | ref
    # -- fused selection statistics -----------------------------------------
    # Emit n_sel / n_sel_m and the magnitude/age histograms from INSIDE the
    # fused kernel (ops.fairk_stats_update) instead of recomputing them as
    # extra read passes, and — with warm_start — re-estimate thresholds
    # from the carried histograms (packing.hist_thresholds) instead of the
    # sampled-quantile bootstrap whenever the trust region trips.  The
    # fused kernel becomes the ONLY read of the gradient buffer per round;
    # the very first round (no histogram yet) transmits everything once
    # (θ = 0) and self-heals from the realised statistics.  Off by default:
    # the legacy two-pass accounting bootstraps from the CURRENT round's
    # quantiles, which round-0-sensitive callers may prefer.
    fused_stats: bool = False
    # -- packed backend only ------------------------------------------------
    warm_start: bool = False             # carry (θ, counts) across rounds and
                                         # skip the quantile pass when warm
    warm_alpha: float = 0.5              # budget-correction exponent
    warm_clip: float = 2.0               # per-round correction factor bound
    warm_tol: float = 0.25               # trust region: re-run the quantile
                                         # pass when |n_sel - k| > tol * k
    warm_streak: int = 3                 # on-track rounds required before
                                         # carried thresholds are trusted
    # psum/pmean axes for threshold + count reduction when the packed path
    # runs inside shard_map (launch.steps): one tiny scalar collective makes
    # (θ_M, θ_A) globally consistent across shards
    reduce_axes: Tuple[str, ...] = ()


class SelectionEngine:
    """One ``select_and_merge`` over the exact / threshold / sharded paths.

    Construct once per (d, config); all methods are pure jit-compatible
    functions of their array arguments.  ``mesh`` is only required for the
    sharded backend (the flat vector is sharded across *all* mesh axes);
    ``layout`` (a ``core.packing.PackedLayout``) only for the packed backend,
    whose buffers are ``(layout.d_packed,)`` with budgets drawn against the
    ``layout.d_valid`` real coordinates."""

    def __init__(self, cfg: EngineConfig, d: int, mesh=None,
                 layout: Optional[packing.PackedLayout] = None):
        if cfg.backend not in BACKENDS:
            raise ValueError(f"unknown backend {cfg.backend!r}; "
                             f"choose from {BACKENDS}")
        if cfg.policy not in selection.POLICIES:
            raise ValueError(f"unknown policy {cfg.policy!r}; "
                             f"choose from {selection.POLICIES}")
        if cfg.backend != "exact" and cfg.policy not in THRESHOLD_POLICIES:
            raise ValueError(
                f"policy {cfg.policy!r} needs index arithmetic — only "
                f"{THRESHOLD_POLICIES} run on the {cfg.backend!r} backend")
        if cfg.backend == "sharded":
            if mesh is None:
                raise ValueError("sharded backend needs a mesh")
            n_dev = _mesh_size(mesh)
            if d % n_dev:
                raise ValueError(f"d={d} not divisible by {n_dev} devices")
        if cfg.backend == "packed":
            if layout is None:
                raise ValueError("packed backend needs a PackedLayout")
            if d != layout.d_packed:
                raise ValueError(f"d={d} != layout.d_packed="
                                 f"{layout.d_packed}")
        self.cfg = cfg
        self.d = d
        self.mesh = mesh
        self.layout = layout
        # budgets target the REAL coordinates (pads are dead weight)
        self.d_budget = layout.d_valid if layout is not None else d
        self._sample_ids = (jnp.asarray(layout.sample_ids(cfg.sample_cap))
                            if layout is not None else None)

    # -- budgets ------------------------------------------------------------

    def budgets(self) -> Tuple[int, int, int]:
        """(k, k_M, r) with the Remark-1 policy specialisations applied."""
        cfg = self.cfg
        k = (cfg.k if cfg.k is not None
             else max(2, round(cfg.rho * self.d_budget)))
        k_m = (cfg.k_m if cfg.k_m is not None
               else int(round(cfg.k_m_frac * k)))
        if cfg.policy == "topk":
            k_m = k
        if cfg.policy == "roundrobin":
            k_m = 0
        r = cfg.r if cfg.r is not None else max(k, round(cfg.r_frac * k))
        return k, k_m, r

    def _rho_parts(self) -> Tuple[float, float]:
        k, k_m, _ = self.budgets()
        return k / self.d_budget, (k_m / k if k else 0.0)

    def _km_traced(self, k_m_frac) -> Array:
        """Traced magnitude budget: ``k`` stays static (the controller
        only moves the split), ``k_m = round(k_m_frac · k)`` rides as
        int32 data — changing it can never trigger a recompile."""
        return traced_km(self.budgets()[0], k_m_frac)

    def _km_frac_eff(self, km: Array) -> Array:
        """The realised split ``k_m/k`` of a traced budget — mirrors the
        static ``_rho_parts`` rounding so traced and static runs at the
        same nominal fraction derive identical thresholds."""
        k, _, _ = self.budgets()
        return km.astype(jnp.float32) / k if k else jnp.float32(0.0)

    # -- selection ----------------------------------------------------------

    def select(self, key: Optional[Array], g: Array, age: Array) -> Array:
        """Exact index-form selection (all six policies): (k,) int32."""
        k, k_m, r = self.budgets()
        if key is None:
            if self.cfg.policy in ("toprand", "randk"):
                raise ValueError(f"policy {self.cfg.policy!r} needs a PRNG key")
            key = jax.random.PRNGKey(0)
        return selection.select_indices(self.cfg.policy, key, g, age,
                                        k=k, k_m=k_m, r=r)

    def thresholds(self, g: Array, age: Array,
                   residual: Optional[Array] = None,
                   k_m_frac=None, sanitize: bool = False
                   ) -> Tuple[Array, Array]:
        """(θ_M, θ_A) per config (order-statistic or sampled-quantile).
        ``residual`` folds into the magnitude statistic (score = g + res);
        ``k_m_frac`` (optional traced scalar) overrides the static split;
        ``sanitize`` keeps non-finite scores out of both estimates."""
        k, k_m, _ = self.budgets()
        if k_m_frac is None:
            if self.cfg.exact_theta:
                return exact_thresholds(eff_score(g, residual), age,
                                        k=k, k_m=k_m, sanitize=sanitize)
            rho, km_frac = self._rho_parts()
            return sampled_thresholds(g, age, rho=rho, k_m_frac=km_frac,
                                      sample_cap=self.cfg.sample_cap,
                                      residual=residual, sanitize=sanitize)
        km = self._km_traced(k_m_frac)
        if self.cfg.exact_theta:
            return exact_thresholds_dynamic(eff_score(g, residual), age,
                                            k=k, k_m=km, sanitize=sanitize)
        rho, _ = self._rho_parts()
        return sampled_thresholds(g, age, rho=rho,
                                  k_m_frac=self._km_frac_eff(km),
                                  sample_cap=self.cfg.sample_cap,
                                  residual=residual, sanitize=sanitize)

    # -- fused server phase -------------------------------------------------

    def select_and_merge(self, g: Array, g_prev: Array, age: Array, *,
                         key: Optional[Array] = None,
                         tstate: Optional[Dict[str, Array]] = None,
                         residual: Optional[Array] = None,
                         fresh: Optional[Array] = None,
                         k_m_frac=None,
                         age_lag: Optional[int] = None,
                         erase: Optional[Array] = None,
                         sanitize: bool = False
                         ) -> Tuple[Array, Array, Dict[str, Any]]:
        """One server phase: select on ``g``, merge fresh ``g`` over stale
        ``g_prev`` (Eq. 8), advance AoU (Eq. 10).  Returns f32
        ``(g_t, age', stats)``; stats holds the selection artefacts
        (count, thresholds, and — exact backend — the index vector).

        ``tstate`` (packed backend with ``warm_start=True`` only) is the
        carried threshold state from ``packing.init_threshold_state``; the
        successor state is returned in ``stats["tstate"]``.

        ``residual`` (error feedback, any backend): the accumulator folds
        back pre-selection — score and transmitted values become
        ``g + residual`` — and ``stats["residual"]`` carries the successor
        ``score - mask * sent`` (on the threshold/packed backends this is
        a pad-aware stage of the same fused kernel pass).

        ``fresh`` (one-bit FSK-MV, exact/threshold/packed): transmitted
        values when they differ from the score source — pass the
        ``kernels.sign_mv`` majority-vote signs while scoring the vote
        energy in ``g``.

        With ``fused_stats=True`` the stats additionally carry
        ``n_sel_m`` and the ``mag_hist`` / ``age_hist`` selection
        histograms on every backend (emitted by the fused kernel on
        threshold/packed, psum'd per-shard partials on sharded, jnp on
        exact), and ``tstate`` is honoured by the sharded backend too —
        its per-shard thresholds then warm-start from last round's
        reduced statistics instead of bootstrapping every round.

        ``k_m_frac`` (optional, any backend): a *traced* magnitude split
        overriding the static ``cfg.k_m_frac`` — the adaptive budget
        controller (core/controller.py) feeds its live split through
        here.  ``k`` stays static; only the stage split rides as data, so
        per-round ``k_m_frac`` changes never recompile.  FAIR-k only (the
        Remark-1 policies pin the split; the other three need index
        arithmetic with static stage sizes).

        ``age_lag`` (optional STATIC int, any backend): async-aggregation
        staleness accounting.  The just-selected coordinates' post-update
        age becomes ``age_lag`` instead of 0 (their deferred OAC
        contribution lands that many rounds late —
        ``packing.shift_selected_age``), and the emitted/carried age
        histogram is shifted to match, so θ_A re-estimation and the
        budget controller observe the true distribution.  Counts, noise
        masking and the returned ``stats["sel_mask"]`` (added only in
        this mode — the ``age' == 0`` convention no longer identifies the
        selected set downstream) all use the PRE-shift selection.
        ``age_lag in (None, 0)`` traces the unchanged synchronous
        program — bit-exact with today's trajectory.

        ``sanitize`` (STATIC bool, any backend): graceful degradation
        under fault injection (core/faults.py).  Non-finite score
        coordinates are excluded from BOTH selection stages — they are
        semantically "unsent": the merge keeps the stale value, age keeps
        climbing, the error-feedback residual passes through unchanged,
        and the emitted statistics (counts + histograms) never see them.
        ``sanitize=False`` (the default) traces the historical program
        bit-exactly — the guard predicate IS the pad-validity predicate,
        so off-mode costs nothing.

        ``erase`` (optional float mask, requires ``sanitize=True``):
        deep-fade block erasures on the aggregated OAC signal.  Erased
        coordinates (``erase > 0``) are demoted to NaN *before* selection
        so the sanitize stage treats them exactly like corrupted
        gradients — one degradation path for both fault channels.  Fold
        round outages (realised participation ``N_t == 0``) in with
        ``faults.erase_with_outage``: a fully-erased round degrades to
        the age-increment-only no-op round."""
        if age_lag is not None:
            if int(age_lag) < 0:
                raise ValueError(f"age_lag must be >= 0, got {age_lag}")
            age_lag = int(age_lag) or None        # 0 == synchronous
        if g.shape != (self.d,):
            raise ValueError(f"expected shape ({self.d},), got {g.shape}")
        if self.cfg.noise_std > 0.0 and key is None:
            raise ValueError("noise_std > 0 needs a PRNG key (identical "
                             "noise every round is not a channel)")
        if k_m_frac is not None and self.cfg.policy != "fairk":
            raise ValueError(
                f"traced k_m_frac adapts the FAIR-k split only — policy "
                f"{self.cfg.policy!r} pins or ignores it")
        if erase is not None and not sanitize:
            raise ValueError("erase needs sanitize=True — erased "
                             "coordinates degrade through the NaN path")
        if sanitize and self.cfg.policy not in THRESHOLD_POLICIES:
            raise ValueError(
                f"sanitize runs selection in threshold/rank form — policy "
                f"{self.cfg.policy!r} needs index arithmetic; choose from "
                f"{THRESHOLD_POLICIES}")
        if erase is not None:
            # one degradation path for both fault channels: erased
            # coordinates become NaN scores and ride the sanitize stage
            g = jnp.where(jnp.asarray(erase) > 0.0, jnp.float32(jnp.nan),
                          g.astype(jnp.float32))
        backend = self.cfg.backend
        if backend == "exact":
            return self._exact_update(g, g_prev, age, key, residual, fresh,
                                      k_m_frac, age_lag, sanitize)
        if backend == "threshold":
            return self._threshold_update(g, g_prev, age, key, residual,
                                          fresh, k_m_frac, age_lag, sanitize)
        if backend == "packed":
            return self._packed_update(g, g_prev, age, key, tstate,
                                       residual, fresh, k_m_frac, age_lag,
                                       sanitize)
        return self._sharded_update(g, g_prev, age, key, residual, fresh,
                                    tstate, k_m_frac, age_lag, sanitize)

    def _noisy(self, fresh: Array, key: Optional[Array]) -> Array:
        cfg = self.cfg
        if key is None or cfg.noise_std <= 0.0:
            return fresh.astype(jnp.float32)
        noise = (cfg.noise_std / cfg.n_clients) * jax.random.normal(
            key, fresh.shape, jnp.float32)
        return fresh.astype(jnp.float32) + noise

    def _exact_update(self, g, g_prev, age, key, residual=None, fresh=None,
                      k_m_frac=None, age_lag=None, sanitize=False):
        k, k_m, _ = self.budgets()
        key_sel = key_noise = None
        if key is not None:
            key_sel, key_noise = jax.random.split(key)
        score = eff_score(g, residual)
        fin = mask_m_s = None
        if sanitize:
            # rank-form selection on demoted statistics: non-finite
            # coordinates rank below every healthy one in both stages
            # (magnitude −1, age −1), and the final AND keeps them out
            # even when the budget exceeds the healthy coordinate count —
            # they stay "unsent" (stale value kept, age climbing)
            fin = jnp.isfinite(score)
            score = jnp.where(fin, score, 0.0)
            km = self._km_traced(k_m_frac) if k_m_frac is not None else k_m
            mag_eff = jnp.where(fin, jnp.abs(score), -1.0)
            age_eff = jnp.where(fin, age.astype(jnp.float32), -1.0)
            mask, mask_m_s = fair_k_masks_dynamic(mag_eff, age_eff, k, km)
            finf = fin.astype(jnp.float32)
            mask = mask * finf
            mask_m_s = mask_m_s * finf
            stats = {"n_selected": mask.sum(), "k": k}
            if k_m_frac is not None:
                stats["k_m"] = km
        elif k_m_frac is None:
            idx = self.select(key_sel, score, age)
            mask = selection.mask_from_indices(idx, self.d)
            stats = {"idx": idx, "n_selected": jnp.float32(k), "k": k}
        else:
            # traced split: the index-form top-k concatenation has static
            # stage lengths, so select by RANK instead — the identical
            # coordinate set (ties toward lower index in both)
            km = self._km_traced(k_m_frac)
            k_m = km.astype(jnp.float32)
            mask, _ = fair_k_masks_dynamic(jnp.abs(score), age, k, km)
            stats = {"n_selected": jnp.float32(k), "k": k, "k_m": km}
        sent = score if fresh is None else fresh.astype(jnp.float32)
        if sanitize and fresh is not None:
            sent = jnp.where(jnp.isfinite(sent), sent, 0.0)
        g_t, age_next = masked_merge(self._noisy(sent, key_noise), g_prev,
                                     age, mask)
        if age_lag is not None:
            # async mode: selected coordinates carry their delivery lag
            # forward; the histograms below bin the shifted ages directly
            age_next = packing.shift_selected_age(age_next, age_lag)
            stats["sel_mask"] = mask
        if self.cfg.fused_stats:
            # the index-form FAIR-k magnitude stage selects exactly k_M
            # coordinates; the histograms come from the same jnp helper
            # the kernel oracle uses, so they are bit-comparable to the
            # threshold/packed backends' kernel-emitted ones
            from repro.kernels import ref    # deferred: kernels import core
            hist_valid = age.astype(jnp.float32) >= 0.0
            if fin is not None:
                hist_valid = hist_valid & fin
            mag_hist, age_hist = ref.strided_hists_ref(
                score, age_next, hist_valid, packing.hist_stride(self.d))
            n_sel_m = (mask_m_s.sum() if mask_m_s is not None
                       else jnp.asarray(k_m, jnp.float32))
            stats |= {"n_sel_m": n_sel_m,
                      "mag_hist": mag_hist, "age_hist": age_hist}
        if residual is not None:
            # noise-free accounting (the channel error is not observable by
            # the clients) — identical formula to the fused kernel's stage;
            # sanitized-out coordinates keep their old residual
            res_next = score - mask * sent
            if fin is not None:
                res_next = jnp.where(fin, res_next,
                                     residual.astype(jnp.float32))
            stats["residual"] = res_next
        return g_t, age_next, stats

    def _threshold_update(self, g, g_prev, age, key, residual=None,
                          fresh=None, k_m_frac=None, age_lag=None,
                          sanitize=False):
        from repro.kernels import ops          # deferred: kernels import core
        k, _, _ = self.budgets()
        theta_m, theta_a = self.thresholds(g, age, residual=residual,
                                           k_m_frac=k_m_frac,
                                           sanitize=sanitize)
        if self.cfg.fused_stats:
            g_t, age_next, res_next, kstats = ops.fairk_stats_update(
                g, g_prev, age, theta_m, theta_a, residual=residual,
                fresh=fresh, mode=self.cfg.kernel_mode, sanitize=sanitize)
            n_sel = kstats["n_sel"]
            extra = {"n_sel_m": kstats["n_sel_m"],
                     "mag_hist": kstats["mag_hist"],
                     "age_hist": kstats["age_hist"]}
        else:
            g_t, age_next, res_next = ops.fairk_ef_update(
                g, g_prev, age, theta_m, theta_a, residual=residual,
                fresh=fresh, mode=self.cfg.kernel_mode, sanitize=sanitize)
            # selected coordinates are exactly the age-reset ones (Eq. 10)
            n_sel = (age_next == 0.0).astype(jnp.float32).sum()
            extra = {}
        if self.cfg.noise_std > 0.0:
            # selection saw the clean aggregate; the channel perturbs only
            # the fresh (transmitted) coordinates — one extra masked pass on
            # top of the fused kernel, equivalent to merging g + noise
            sel = (age_next == 0.0).astype(jnp.float32)
            g_t = g_t + sel * (self.cfg.noise_std / self.cfg.n_clients) * \
                jax.random.normal(key, g.shape, jnp.float32)
        stats = {"theta_m": theta_m, "theta_a": theta_a,
                 "n_selected": n_sel, "k": k, **extra}
        if age_lag is not None:
            # async: counts/noise above used the pre-shift selection (the
            # kernel's age' == 0 convention); the carried buffer and the
            # emitted histogram record the delivery lag
            stats["sel_mask"] = (age_next == 0.0).astype(jnp.float32)
            age_next = packing.shift_selected_age(age_next, age_lag)
            if "age_hist" in stats:
                stats["age_hist"] = packing.shift_age_hist(
                    stats["age_hist"], age_lag)
        if res_next is not None:
            stats["residual"] = res_next
        return g_t, age_next, stats

    def _stats_thresholds(self, tstate, k_m_frac=None
                          ) -> Tuple[Array, Array, Array]:
        """(θ_M, θ_A, streak') from the carried statistics ALONE — zero
        reads of the gradient buffer (the fused-stats steady state).

        Warm branch: last round's thresholds with the budget-tracking
        correction, once the prediction streak is established.  Otherwise
        (trust region tripped, cold-start drift, or the very first
        rounds): thresholds re-estimated from the kernel-emitted
        histograms (``packing.hist_thresholds``) — the replacement for
        the sampled-quantile bootstrap pass.  Both branches are a handful
        of scalar/128-bin flops, so a plain ``where`` suffices where the
        legacy path needed ``lax.cond`` to dodge the quantile pass.
        ``k_m_frac`` (traced) reroutes every budget reference through the
        live split — the adaptive controller's round costs the SAME
        scalar program."""
        cfg = self.cfg
        k, k_m, _ = self.budgets()
        rho, km_frac = self._rho_parts()
        if k_m_frac is not None:
            k_m = self._km_traced(k_m_frac)
            km_frac = self._km_frac_eff(k_m)
        hist_tm, hist_ta = packing.hist_thresholds(
            tstate["mag_hist"], tstate["age_hist"], rho=rho,
            k_m_frac=km_frac)
        pred_tm, pred_ta = packing.warm_corrected_thresholds(
            tstate, k=k, k_m=k_m, alpha=cfg.warm_alpha, clip=cfg.warm_clip)
        on_track = self._on_track(tstate, k)
        use_warm = on_track & (tstate["streak"] >= cfg.warm_streak)
        tm = jnp.where(use_warm, pred_tm, hist_tm)
        ta = jnp.where(use_warm, pred_ta, hist_ta)
        # streak: the warm predictor must keep agreeing with the
        # hist-measured thresholds (same gates as the legacy sampled path)
        streak = self._streak_update(tstate, on_track, tm, ta, pred_tm,
                                     pred_ta)
        return tm, ta, streak

    def _on_track(self, tstate, k) -> Array:
        """Trust gate 1: last round's realised count stayed inside the
        budget tolerance (shared by the fused and legacy warm paths)."""
        return ((tstate["init"] > 0.0)
                & (jnp.abs(tstate["n_sel"] - k) <= self.cfg.warm_tol * k))

    def _streak_update(self, tstate, on_track, tm, ta, pred_tm, pred_ta
                       ) -> Array:
        """Trust gate 2: the warm predictor must keep agreeing with the
        measured thresholds (sampled quantiles on the legacy path, the
        histogram estimates on the fused path) — ONE formula so the two
        paths can never drift apart."""
        both = lambda a, b: jnp.isinf(a) & jnp.isinf(b)
        ratio_tol = 1.0 + self.cfg.warm_tol
        pred_ok = (
            (both(ta, pred_ta) | (jnp.abs(ta - pred_ta) <= 0.75))
            & (both(tm, pred_tm)
               | ((pred_tm <= tm * ratio_tol) & (pred_tm * ratio_tol >= tm))))
        return jnp.where(on_track & pred_ok, tstate["streak"] + 1.0, 0.0)

    def _packed_thresholds(self, g, age, tstate, residual=None,
                           k_m_frac=None, sanitize=False):
        """(θ_M, θ_A, streak') for a packed buffer: pad-excluding sampled
        quantiles, or — when warm — last round's thresholds with the
        budget-tracking correction (no quantile pass at all on steady-state
        rounds, via lax.cond).  With ``fused_stats`` the bootstrap itself
        disappears from the trace: re-estimation runs on the carried
        in-kernel histograms (``_stats_thresholds``).  ``residual`` folds
        into the magnitude statistic (score = g + residual; pads carry
        residual 0).  ``k_m_frac`` (traced) replaces the static split in
        every branch."""
        cfg = self.cfg
        k, k_m, _ = self.budgets()
        streak = jnp.float32(0.0)
        if cfg.exact_theta:
            # pads (|g|=0, age=PAD_AGE+jitter < 0) can never enter either
            # top-k, so the order statistics are those of the valid coords
            if k_m_frac is not None:
                return (*exact_thresholds_dynamic(
                    eff_score(g, residual), age, k=k,
                    k_m=self._km_traced(k_m_frac),
                    sanitize=sanitize), streak)
            return (*exact_thresholds(eff_score(g, residual), age,
                                      k=k, k_m=k_m,
                                      sanitize=sanitize), streak)
        if cfg.fused_stats and cfg.warm_start and tstate is not None:
            return self._stats_thresholds(tstate, k_m_frac)
        rho, km_frac = self._rho_parts()
        if k_m_frac is not None:
            k_m = self._km_traced(k_m_frac)
            km_frac = self._km_frac_eff(k_m)

        def bootstrap(_):
            tm, ta = sampled_thresholds(
                g, age, rho=rho, k_m_frac=km_frac,
                sample_cap=cfg.sample_cap, sample_ids=self._sample_ids,
                residual=residual, sanitize=sanitize)
            if cfg.reduce_axes:
                tm = jax.lax.pmean(tm, cfg.reduce_axes)
                ta = jax.lax.pmean(ta, cfg.reduce_axes)
            return tm, ta

        if not (cfg.warm_start and tstate is not None):
            return (*bootstrap(None), streak)

        # trust region, two gates:
        #  * on_track — last round's realised count stayed inside the budget
        #    tolerance;
        #  * streak — the warm predictor must have AGREED with the sampled
        #    quantiles for ``warm_streak`` consecutive bootstrap rounds.
        #    During drift (the cold-start transient: every unselected age
        #    advances together for ~1/rho rounds) the sampled θ_A moves ~1
        #    age unit per round while the predictor is near-constant, so the
        #    streak never builds and every round bootstraps — which is the
        #    correct (and self-healing) behaviour.  Once the age histogram
        #    is stationary, predictions match, the streak builds, and the
        #    quantile pass stops executing (lax.cond).
        pred_tm, pred_ta = packing.warm_corrected_thresholds(
            tstate, k=k, k_m=k_m, alpha=cfg.warm_alpha, clip=cfg.warm_clip)
        on_track = self._on_track(tstate, k)
        use_warm = on_track & (tstate["streak"] >= cfg.warm_streak)
        tm, ta = jax.lax.cond(use_warm, lambda _: (pred_tm, pred_ta),
                              bootstrap, None)
        streak = self._streak_update(tstate, on_track, tm, ta, pred_tm,
                                     pred_ta)
        return tm, ta, streak

    def _packed_update(self, g, g_prev, age, key, tstate, residual=None,
                       fresh=None, k_m_frac=None, age_lag=None,
                       sanitize=False):
        """One fused FAIR-k pass over the whole packed pytree buffer.

        Exactly one quantile estimation (or none: warm rounds correct the
        carried thresholds, and with ``fused_stats`` even re-estimation
        runs on the kernel-emitted histograms) and exactly one
        ``fairk_update`` launch for the entire model — vs one of each per
        leaf on the historical per-leaf path.  The residual
        (error-feedback) stage, the one-bit ``fresh`` values and (with
        ``fused_stats``) the counts/histogram statistics all ride the
        same fused pass, so the steady-state round reads the gradient
        buffer exactly once."""
        from repro.kernels import ops          # deferred: kernels import core
        cfg = self.cfg
        k, _, _ = self.budgets()
        theta_m, theta_a, streak = self._packed_thresholds(g, age, tstate,
                                                           residual,
                                                           k_m_frac,
                                                           sanitize)
        if cfg.fused_stats:
            # counts AND histograms come out of the kernel itself — the
            # fused launch is the only read of (g, residual) this round
            g_t, age_next, res_next, kstats = ops.fairk_stats_update(
                g, g_prev, age, theta_m, theta_a, residual=residual,
                fresh=fresh, mode=cfg.kernel_mode, sanitize=sanitize)
            n_sel, n_sel_m = kstats["n_sel"], kstats["n_sel_m"]
            mag_hist, age_hist = kstats["mag_hist"], kstats["age_hist"]
        else:
            g_t, age_next, res_next = ops.fairk_ef_update(
                g, g_prev, age, theta_m, theta_a, residual=residual,
                fresh=fresh, mode=cfg.kernel_mode, sanitize=sanitize)
            # legacy two-pass accounting: selected coordinates are exactly
            # the age-reset ones (Eq. 10; pads keep the negative sentinel
            # so they never count), and the magnitude-stage count re-reads
            # (g, residual) — the extra pass fused_stats eliminates
            packing.G_READS += 1
            sel = (age_next == 0.0).astype(jnp.float32)
            n_sel = sel.sum()
            n_sel_m = (sel * (jnp.abs(eff_score(g, residual))
                              >= theta_m)).sum()
            mag_hist = age_hist = None
        if cfg.reduce_axes:
            # per-shard mean keeps counts comparable to the local budgets
            # (and the carried tstate identical on every shard)
            n_sel = jax.lax.pmean(n_sel, cfg.reduce_axes)
            n_sel_m = jax.lax.pmean(n_sel_m, cfg.reduce_axes)
            if mag_hist is not None:
                mag_hist = jax.lax.pmean(mag_hist, cfg.reduce_axes)
                age_hist = jax.lax.pmean(age_hist, cfg.reduce_axes)
        if sanitize and mag_hist is not None and tstate is not None:
            # graceful degradation under a fully-erased round (total
            # channel outage, realised participation 0, or an all-corrupt
            # aggregate): every coordinate is sanitized away, so the
            # kernel emits EMPTY histograms — re-estimating thresholds
            # from those would read as "nothing left to select" (θ = 0,
            # the cold-start convention) and fire a spurious full-refresh
            # round right after the outage.  Substitute the exact truth
            # instead: nothing was refreshed, so this round's post-update
            # age histogram is last round's shifted up one bin, and the
            # magnitude mass was merely unobserved (carry it).  Partial
            # erasures keep the kernel's measurement bit-exactly.
            keep = (age_hist.sum() <= 0.0) & (tstate["init"] > 0.0)
            mag_hist = jnp.where(keep, tstate["mag_hist"], mag_hist)
            age_hist = jnp.where(
                keep, packing.advance_age_hist(tstate["age_hist"]),
                age_hist)
        if cfg.noise_std > 0.0:
            sel = (age_next == 0.0).astype(jnp.float32)
            g_t = g_t + sel * (cfg.noise_std / cfg.n_clients) * \
                jax.random.normal(key, g.shape, jnp.float32)
        sel_mask = None
        if age_lag is not None:
            # async: counts/noise above used the pre-shift selection; the
            # carried age buffer and histogram record the delivery lag
            # (bin-0 mass moves to bin ``age_lag`` — identical to binning
            # the shifted ages, since the shift only touches age == 0)
            sel_mask = (age_next == 0.0).astype(jnp.float32)
            age_next = packing.shift_selected_age(age_next, age_lag)
            if age_hist is not None:
                age_hist = packing.shift_age_hist(age_hist, age_lag)
        tstate_next = {"theta_m": theta_m, "theta_a": theta_a,
                       "n_sel_m": n_sel_m, "n_sel": n_sel,
                       "init": jnp.float32(1.0), "streak": streak,
                       "mag_hist": (mag_hist if mag_hist is not None else
                                    jnp.zeros((packing.STATS_MAG_BINS,),
                                              jnp.float32)),
                       "age_hist": (age_hist if age_hist is not None else
                                    jnp.zeros((packing.STATS_AGE_BINS,),
                                              jnp.float32))}
        stats = {"theta_m": theta_m, "theta_a": theta_a,
                 "n_selected": n_sel, "k": k, "tstate": tstate_next}
        if mag_hist is not None:
            stats |= {"n_sel_m": n_sel_m, "mag_hist": mag_hist,
                      "age_hist": age_hist}
        if sel_mask is not None:
            stats["sel_mask"] = sel_mask
        if res_next is not None:
            stats["residual"] = res_next
        return g_t, age_next, stats

    def select_and_merge_tree(self, g_tree, g_prev_tree, age_tree, *,
                              key: Optional[Array] = None,
                              tstate: Optional[Dict[str, Array]] = None,
                              residual: Optional[Array] = None,
                              k_m_frac=None, sanitize: bool = False):
        """Pytree façade over the packed backend: pack (g, g_prev, age),
        run the single fused pass, unpack ``(g_t, age')`` back to the tree
        structure (leaf dtypes from the layout).  Returns
        ``(g_t_tree, age_tree', stats)``.  ``residual`` is a FLAT packed
        ``(d_packed,)`` buffer (persist it across rounds — re-packing it
        from a tree every step would defeat error feedback's one-pass
        cost); its successor stays flat in ``stats["residual"]``."""
        lay = self.layout
        if lay is None:
            raise ValueError("select_and_merge_tree needs the packed "
                             "backend (construct with layout=...)")
        g = lay.pack(g_tree)
        gp = lay.pack(g_prev_tree)
        ag = lay.pack_age(age_tree)
        g_t, age_next, stats = self._packed_update(g, gp, ag, key, tstate,
                                                   residual,
                                                   k_m_frac=k_m_frac,
                                                   sanitize=sanitize)
        return lay.unpack(g_t, cast=False), lay.unpack(age_next,
                                                       cast=False), stats

    def _sharded_update(self, g, g_prev, age, key, residual=None,
                        fresh=None, tstate=None, k_m_frac=None,
                        age_lag=None, sanitize=False):
        cfg = self.cfg
        mesh = self.mesh
        axes = tuple(mesh.axis_names)
        k, _, _ = self.budgets()
        rho, km_frac = self._rho_parts()
        vec = P(axes)
        if fresh is not None:
            raise ValueError("the sharded backend has no decoupled one-bit "
                             "fresh path — route one_bit through the "
                             "exact/threshold/packed backends")
        has_res = residual is not None
        fused = cfg.fused_stats
        # traced split: the replicated scalar rides into shard_map as an
        # operand so the per-shard bootstrap sizes its quantiles from the
        # live value (the warm/global branches consume it outside)
        dyn_km = k_m_frac is not None
        kmf_op = (self._km_frac_eff(self._km_traced(k_m_frac)) if dyn_km
                  else jnp.float32(km_frac))
        # warm sharded rounds: the threshold decision consumes only the
        # carried (replicated) statistics — psum'd per-shard partials from
        # last round — so it runs OUTSIDE shard_map and the historical
        # every-round per-shard bootstrap disappears entirely.  The
        # resulting (θ_M, θ_A) are globally consistent by construction.
        warm = fused and cfg.warm_start and tstate is not None
        use_global = cfg.global_thresholds or cfg.exact_theta
        streak = jnp.float32(0.0)
        if warm:
            theta_m, theta_a, streak = self._stats_thresholds(tstate,
                                                              k_m_frac)
        elif use_global:
            theta_m, theta_a = self.thresholds(g, age, residual=residual,
                                               k_m_frac=k_m_frac,
                                               sanitize=sanitize)
        else:
            theta_m = theta_a = jnp.float32(0.0)    # placeholder, unused
        per_shard_boot = not (warm or use_global)
        n_local = g.shape[0] // _mesh_size(mesh)
        stride = packing.hist_stride(self.d)
        # per-block partials only sum to the unsharded sample when the
        # shard length is a stride multiple; else fall back to local
        # stride 1 (counts stay exact either way — only hist sample
        # density changes, and hist thresholds are scale-free)
        if n_local % stride:
            stride = 1

        def shard_phase(g_l, gp_l, age_l, res_l, tm, ta, kmf_l, key_l):
            my = 0
            for ax in axes:
                my = my * mesh.shape[ax] + jax.lax.axis_index(ax)
            score = eff_score(g_l, res_l if has_res else None)
            fin = None
            if sanitize:
                # local graceful degradation, no extra collectives: the
                # cleaned score keeps 0 * NaN out of the merge and the
                # finite AND keeps corrupted coordinates unselected
                fin = jnp.isfinite(score)
                score = jnp.where(fin, score, 0.0)
            if per_shard_boot:
                tm, ta = sampled_thresholds(
                    score, age_l, rho=rho,
                    k_m_frac=kmf_l if dyn_km else km_frac,
                    sample_cap=cfg.sample_cap)
            # jitter hashes GLOBAL coordinate ids (my * n_local offset) so
            # the mask is the one the unsharded backends would compute
            mask, mask_m = threshold_mask(score, age_l, tm, ta,
                                          index_offset=my * g_l.shape[0])
            if fin is not None:
                finf = fin.astype(jnp.float32)
                mask = mask * finf
                mask_m = mask_m * finf
            fresh_l = score.astype(jnp.float32)
            if cfg.noise_std > 0.0:
                kk = jax.random.fold_in(key_l, my)
                fresh_l = fresh_l + (cfg.noise_std / cfg.n_clients) * \
                    jax.random.normal(kk, g_l.shape, jnp.float32)
            g_t, age_next = masked_merge(fresh_l, gp_l, age_l, mask)
            if age_lag is not None:
                # async: the local shard's carried ages record the
                # delivery lag BEFORE the histograms bin them, so the
                # psum'd partials come out naturally shifted
                age_next = packing.shift_selected_age(age_next, age_lag)
            if has_res:
                res_next = score - mask * score
                if fin is not None:
                    # sanitized-out coordinates keep their old residual
                    res_next = jnp.where(fin, res_next,
                                         res_l.astype(jnp.float32))
            else:
                res_next = jnp.zeros((), jnp.float32)
            n_sel = jax.lax.psum(mask.sum(), axes)
            if fused:
                from repro.kernels import ref      # deferred import
                hist_valid = age_l >= 0.0
                if fin is not None:
                    hist_valid = hist_valid & fin
                mh_l, ah_l = ref.strided_hists_ref(
                    score, age_next, hist_valid, stride)
                part = (jax.lax.psum(mask_m.sum(), axes),
                        jax.lax.psum(mh_l, axes), jax.lax.psum(ah_l, axes))
            else:
                part = (jnp.zeros((), jnp.float32),
                        jnp.zeros((packing.STATS_MAG_BINS,), jnp.float32),
                        jnp.zeros((packing.STATS_AGE_BINS,), jnp.float32))
            sel_out = mask if age_lag is not None else jnp.zeros(
                (), jnp.float32)
            return g_t, age_next, res_next, n_sel, part, sel_out

        fn = compat.shard_map(
            shard_phase, mesh,
            in_specs=(vec, vec, vec, vec if has_res else P(), P(), P(),
                      P(), P()),
            out_specs=(vec, vec, vec if has_res else P(), P(),
                       (P(), P(), P()),
                       vec if age_lag is not None else P()))
        if key is None:
            key = jax.random.PRNGKey(0)
        res_in = residual if has_res else jnp.zeros((), jnp.float32)
        g_t, age_next, res_next, n_sel, part, sel_mask = fn(
            g, g_prev, age, res_in, theta_m, theta_a, kmf_op, key)
        n_sel_m, mag_hist, age_hist = part
        stats = {"n_selected": n_sel, "k": k}
        if age_lag is not None:
            stats["sel_mask"] = sel_mask
        if use_global or warm:
            stats |= {"theta_m": theta_m, "theta_a": theta_a}
        if fused:
            stats |= {"n_sel_m": n_sel_m, "mag_hist": mag_hist,
                      "age_hist": age_hist}
            stats["tstate"] = {
                "theta_m": theta_m, "theta_a": theta_a, "n_sel_m": n_sel_m,
                "n_sel": n_sel, "init": jnp.float32(1.0), "streak": streak,
                "mag_hist": mag_hist, "age_hist": age_hist}
        if has_res:
            stats["residual"] = res_next
        return g_t, age_next, stats


def _mesh_size(mesh) -> int:
    n = 1
    for ax in mesh.axis_names:
        n *= mesh.shape[ax]
    return n


def make_engine(policy: str = "fairk", backend: str = "exact", *,
                d: Optional[int] = None, mesh=None,
                layout: Optional[packing.PackedLayout] = None,
                **cfg_kw) -> SelectionEngine:
    """Convenience constructor mirroring the string-driven policy registry.
    ``d`` may be omitted when ``layout`` pins it (= ``layout.d_packed``)."""
    if d is None:
        if layout is None:
            raise ValueError("make_engine needs d (or a layout)")
        d = layout.d_packed
    return SelectionEngine(EngineConfig(policy=policy, backend=backend,
                                        **cfg_kw), d, mesh=mesh,
                           layout=layout)
