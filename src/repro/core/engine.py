"""Unified SelectionEngine: one API over the three FAIR-k execution paths.

The paper's selection rule (Eq. 11) and server update (Eq. 8-10) exist at
three operating points in this repo, historically implemented three times:

* ``exact``      — index-form ``lax.top_k`` policies (``core.selection``),
  the paper-faithful simulation path.  Exact budget (k indices), supports
  all six policies, cost O(d log d) — fine to d ~ 1e7.
* ``threshold``  — sampled-quantile thresholds θ_M / θ_A plus the fused
  ``fairk_update`` Pallas kernel: one HBM pass over (g, g_prev, age), no
  sort.  Approximate budget (|selected| ≈ k), FAIR-k-family policies only,
  the d ~ 1e8-1e9 single-device production route.
* ``sharded``    — the threshold math inside ``shard_map``: every device
  updates its local shard with locally estimated thresholds, zero extra
  collectives.  The multi-device production route (launch.steps).

``SelectionEngine`` puts all three behind ``select_and_merge(g, g_prev,
age)`` -> ``(g_t, age', stats)`` so trainers, benchmarks and tests can swap
backends without touching call sites, and so cross-backend parity is
testable (see tests/test_engine.py): with ``exact_theta=True`` the
threshold/sharded backends compute order-statistic thresholds that select
*identical* coordinates to ``exact`` on tie-free inputs.

Semantics (all backends):
  selection scores the first argument ``g`` (the production server scores
  the fresh aggregate; the paper's trainer scores g_{t-1} — pass whichever
  the algorithm calls for), fresh values come from ``g``, stale values from
  ``g_prev``, and the AoU vector advances by Eq. (10) capped at
  ``AGE_CAP`` (the fused kernel's staleness clip).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import selection

Array = jax.Array

BACKENDS = ("exact", "threshold", "sharded")

# FAIR-k-family policies expressible as (θ_M, θ_A) thresholds; the other
# three (toprand / agetopk / randk) need index arithmetic -> exact only.
THRESHOLD_POLICIES = ("fairk", "topk", "roundrobin")

# staleness clip baked into the fused kernel (kernels/fairk_update.py);
# int8 server state in launch.steps needs age < 127
AGE_CAP = 120.0


# ---------------------------------------------------------------------------
# threshold building blocks (promoted from launch/steps.py)
# ---------------------------------------------------------------------------

def index_jitter(n: int, offset=0) -> Array:
    """Deterministic per-coordinate jitter in [0, 1) (Knuth hash of the
    *global* coordinate index) — breaks integer-age ties without an extra
    input.  ``offset`` (static or traced) is the global index of the first
    local coordinate, so shards hash the same ids as the unsharded path.
    Must stay bit-identical to the fused kernel's in-kernel recomputation."""
    i = jax.lax.iota(jnp.uint32, n) + jnp.asarray(offset, jnp.uint32)
    return (i * jnp.uint32(2654435761) % jnp.uint32(1 << 24)
            ).astype(jnp.float32) / float(1 << 24)


def strided_sample(x: Array, cap: int) -> Array:
    n = x.shape[0]
    stride = max(1, n // cap)
    return x[::stride]


def sampled_thresholds(g: Array, age: Array, *, rho: float, k_m_frac: float,
                       sample_cap: int) -> Tuple[Array, Array]:
    """(θ_M, θ_A) from strided-sample quantiles (no global sort).

    θ_M ≈ the (1 − ρ·k_m_frac) quantile of |g|; θ_A sizes the age stage to
    the residual budget over the whole vector (the complement correction is
    the (1 − ρ_M) denominator)."""
    rho_m = rho * k_m_frac
    rho_a = (rho - rho_m) / max(1.0 - rho_m, 1e-6)
    mag = jnp.abs(g.astype(jnp.float32))
    age_eff = age.astype(jnp.float32) + index_jitter(g.shape[0])
    theta_m = (jnp.quantile(strided_sample(mag, sample_cap), 1.0 - rho_m)
               if rho_m > 0.0 else jnp.float32(jnp.inf))
    theta_a = (jnp.quantile(strided_sample(age_eff, sample_cap), 1.0 - rho_a)
               if rho_a > 0.0 else jnp.float32(jnp.inf))
    return theta_m.astype(jnp.float32), theta_a.astype(jnp.float32)


def exact_thresholds(g: Array, age: Array, *, k: int, k_m: int
                     ) -> Tuple[Array, Array]:
    """Order-statistic (θ_M, θ_A) that reproduce exact FAIR-k on tie-free
    inputs: θ_M sits strictly between the k_m-th and (k_m+1)-th largest
    |g|, θ_A between the k_a-th and (k_a+1)-th largest jittered age *among
    the magnitude-stage complement*.  O(d log d) — parity/testing path."""
    d = g.shape[0]
    k_a = k - k_m
    mag = jnp.abs(g.astype(jnp.float32))
    if k_m == 0:
        theta_m = jnp.float32(jnp.inf)
        mask_m = jnp.zeros((d,), bool)
    else:
        vals = jax.lax.top_k(mag, min(k_m + 1, d))[0]
        edge = vals[-1] if k_m >= d else vals[k_m]
        theta_m = (vals[k_m - 1] + edge) / 2.0
        mask_m = mag >= theta_m
    if k_a == 0:
        return theta_m, jnp.float32(jnp.inf)
    age_eff = age.astype(jnp.float32) + index_jitter(d)
    rest = jnp.where(mask_m, -jnp.inf, age_eff)
    vals = jax.lax.top_k(rest, min(k_a + 1, d))[0]
    edge = vals[-1] if k_a >= d else vals[k_a]
    theta_a = (vals[k_a - 1] + edge) / 2.0
    return theta_m, theta_a


def threshold_mask(g: Array, age: Array, theta_m: Array, theta_a: Array,
                   index_offset=0) -> Tuple[Array, Array]:
    """Dense float32 (mask, mask_m) for the two-stage threshold rule —
    the jnp mirror of the fused kernel's in-register mask.  When applied to
    a shard, pass the shard's global start index as ``index_offset`` so the
    age jitter matches the unsharded selection."""
    mag = jnp.abs(g.astype(jnp.float32))
    mask_m = mag >= theta_m
    age_eff = age.astype(jnp.float32) + index_jitter(g.shape[0],
                                                     index_offset)
    mask_a = (age_eff >= theta_a) & (~mask_m)
    return (mask_m | mask_a).astype(jnp.float32), mask_m.astype(jnp.float32)


def masked_merge(fresh: Array, g_prev: Array, age: Array, mask: Array
                 ) -> Tuple[Array, Array]:
    """Eq. (8) stale merge + Eq. (10) AoU update (mask form, f32 out)."""
    keep = 1.0 - mask
    g_t = mask * fresh.astype(jnp.float32) + keep * g_prev.astype(jnp.float32)
    age_next = jnp.minimum((age.astype(jnp.float32) + 1.0) * keep, AGE_CAP)
    return g_t, age_next


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Backend-independent FAIR-k settings.

    Budgets derive from (rho, k_m_frac, r_frac) unless (k, k_m, r) are
    given explicitly.  ``exact_theta`` switches the threshold/sharded
    backends from sampled quantiles to order-statistic thresholds (parity
    mode); ``global_thresholds`` makes the sharded backend estimate one
    (θ_M, θ_A) pair on the full vector instead of per shard."""
    policy: str = "fairk"
    backend: str = "exact"
    rho: float = 0.1
    k_m_frac: float = 0.75
    r_frac: float = 1.5                  # AgeTop-k candidate ratio r / k
    k: Optional[int] = None
    k_m: Optional[int] = None
    r: Optional[int] = None
    sample_cap: int = 65536              # quantile sample size
    exact_theta: bool = False
    global_thresholds: bool = False
    noise_std: float = 0.0               # channel noise on fresh coords
    n_clients: int = 1                   # N in Eq. (7) (noise / N scaling)
    kernel_mode: Optional[str] = None    # None auto | pallas | interpret | ref


class SelectionEngine:
    """One ``select_and_merge`` over the exact / threshold / sharded paths.

    Construct once per (d, config); all methods are pure jit-compatible
    functions of their array arguments.  ``mesh`` is only required for the
    sharded backend (the flat vector is sharded across *all* mesh axes)."""

    def __init__(self, cfg: EngineConfig, d: int, mesh=None):
        if cfg.backend not in BACKENDS:
            raise ValueError(f"unknown backend {cfg.backend!r}; "
                             f"choose from {BACKENDS}")
        if cfg.policy not in selection.POLICIES:
            raise ValueError(f"unknown policy {cfg.policy!r}; "
                             f"choose from {selection.POLICIES}")
        if cfg.backend != "exact" and cfg.policy not in THRESHOLD_POLICIES:
            raise ValueError(
                f"policy {cfg.policy!r} needs index arithmetic — only "
                f"{THRESHOLD_POLICIES} run on the {cfg.backend!r} backend")
        if cfg.backend == "sharded":
            if mesh is None:
                raise ValueError("sharded backend needs a mesh")
            n_dev = _mesh_size(mesh)
            if d % n_dev:
                raise ValueError(f"d={d} not divisible by {n_dev} devices")
        self.cfg = cfg
        self.d = d
        self.mesh = mesh

    # -- budgets ------------------------------------------------------------

    def budgets(self) -> Tuple[int, int, int]:
        """(k, k_M, r) with the Remark-1 policy specialisations applied."""
        cfg = self.cfg
        k = cfg.k if cfg.k is not None else max(2, round(cfg.rho * self.d))
        k_m = (cfg.k_m if cfg.k_m is not None
               else int(round(cfg.k_m_frac * k)))
        if cfg.policy == "topk":
            k_m = k
        if cfg.policy == "roundrobin":
            k_m = 0
        r = cfg.r if cfg.r is not None else max(k, round(cfg.r_frac * k))
        return k, k_m, r

    def _rho_parts(self) -> Tuple[float, float]:
        k, k_m, _ = self.budgets()
        return k / self.d, (k_m / k if k else 0.0)

    # -- selection ----------------------------------------------------------

    def select(self, key: Optional[Array], g: Array, age: Array) -> Array:
        """Exact index-form selection (all six policies): (k,) int32."""
        k, k_m, r = self.budgets()
        if key is None:
            if self.cfg.policy in ("toprand", "randk"):
                raise ValueError(f"policy {self.cfg.policy!r} needs a PRNG key")
            key = jax.random.PRNGKey(0)
        return selection.select_indices(self.cfg.policy, key, g, age,
                                        k=k, k_m=k_m, r=r)

    def thresholds(self, g: Array, age: Array) -> Tuple[Array, Array]:
        """(θ_M, θ_A) per config (order-statistic or sampled-quantile)."""
        k, k_m, _ = self.budgets()
        if self.cfg.exact_theta:
            return exact_thresholds(g, age, k=k, k_m=k_m)
        rho, km_frac = self._rho_parts()
        return sampled_thresholds(g, age, rho=rho, k_m_frac=km_frac,
                                  sample_cap=self.cfg.sample_cap)

    # -- fused server phase -------------------------------------------------

    def select_and_merge(self, g: Array, g_prev: Array, age: Array, *,
                         key: Optional[Array] = None
                         ) -> Tuple[Array, Array, Dict[str, Any]]:
        """One server phase: select on ``g``, merge fresh ``g`` over stale
        ``g_prev`` (Eq. 8), advance AoU (Eq. 10).  Returns f32
        ``(g_t, age', stats)``; stats holds the selection artefacts
        (count, thresholds, and — exact backend — the index vector)."""
        if g.shape != (self.d,):
            raise ValueError(f"expected shape ({self.d},), got {g.shape}")
        if self.cfg.noise_std > 0.0 and key is None:
            raise ValueError("noise_std > 0 needs a PRNG key (identical "
                             "noise every round is not a channel)")
        backend = self.cfg.backend
        if backend == "exact":
            return self._exact_update(g, g_prev, age, key)
        if backend == "threshold":
            return self._threshold_update(g, g_prev, age, key)
        return self._sharded_update(g, g_prev, age, key)

    def _noisy(self, fresh: Array, key: Optional[Array]) -> Array:
        cfg = self.cfg
        if key is None or cfg.noise_std <= 0.0:
            return fresh.astype(jnp.float32)
        noise = (cfg.noise_std / cfg.n_clients) * jax.random.normal(
            key, fresh.shape, jnp.float32)
        return fresh.astype(jnp.float32) + noise

    def _exact_update(self, g, g_prev, age, key):
        k, _, _ = self.budgets()
        key_sel = key_noise = None
        if key is not None:
            key_sel, key_noise = jax.random.split(key)
        idx = self.select(key_sel, g, age)
        mask = selection.mask_from_indices(idx, self.d)
        g_t, age_next = masked_merge(self._noisy(g, key_noise), g_prev, age,
                                     mask)
        stats = {"idx": idx, "n_selected": jnp.float32(k), "k": k}
        return g_t, age_next, stats

    def _threshold_update(self, g, g_prev, age, key):
        from repro.kernels import ops          # deferred: kernels import core
        k, _, _ = self.budgets()
        theta_m, theta_a = self.thresholds(g, age)
        g_t, age_next = ops.fairk_update(g, g_prev, age, theta_m, theta_a,
                                         mode=self.cfg.kernel_mode)
        # selected coordinates are exactly the age-reset ones (Eq. 10)
        sel = (age_next == 0.0).astype(jnp.float32)
        n_sel = sel.sum()
        if self.cfg.noise_std > 0.0:
            # selection saw the clean aggregate; the channel perturbs only
            # the fresh (transmitted) coordinates — one extra masked pass on
            # top of the fused kernel, equivalent to merging g + noise
            g_t = g_t + sel * (self.cfg.noise_std / self.cfg.n_clients) * \
                jax.random.normal(key, g.shape, jnp.float32)
        stats = {"theta_m": theta_m, "theta_a": theta_a,
                 "n_selected": n_sel, "k": k}
        return g_t, age_next, stats

    def _sharded_update(self, g, g_prev, age, key):
        cfg = self.cfg
        mesh = self.mesh
        axes = tuple(mesh.axis_names)
        k, _, _ = self.budgets()
        rho, km_frac = self._rho_parts()
        vec = P(axes)
        use_global = cfg.global_thresholds or cfg.exact_theta
        if use_global:
            theta_m, theta_a = self.thresholds(g, age)
        else:
            theta_m = theta_a = jnp.float32(0.0)    # placeholder, unused

        def shard_phase(g_l, gp_l, age_l, tm, ta, key_l):
            my = 0
            for ax in axes:
                my = my * mesh.shape[ax] + jax.lax.axis_index(ax)
            if not use_global:
                tm, ta = sampled_thresholds(
                    g_l, age_l, rho=rho, k_m_frac=km_frac,
                    sample_cap=cfg.sample_cap)
            # jitter hashes GLOBAL coordinate ids (my * n_local offset) so
            # the mask is the one the unsharded backends would compute
            mask, _ = threshold_mask(g_l, age_l, tm, ta,
                                     index_offset=my * g_l.shape[0])
            fresh = g_l.astype(jnp.float32)
            if cfg.noise_std > 0.0:
                kk = jax.random.fold_in(key_l, my)
                fresh = fresh + (cfg.noise_std / cfg.n_clients) * \
                    jax.random.normal(kk, g_l.shape, jnp.float32)
            g_t, age_next = masked_merge(fresh, gp_l, age_l, mask)
            return g_t, age_next, jax.lax.psum(mask.sum(), axes)

        fn = compat.shard_map(
            shard_phase, mesh,
            in_specs=(vec, vec, vec, P(), P(), P()),
            out_specs=(vec, vec, P()))
        if key is None:
            key = jax.random.PRNGKey(0)
        g_t, age_next, n_sel = fn(g, g_prev, age, theta_m, theta_a, key)
        stats = {"n_selected": n_sel, "k": k}
        if use_global:
            stats |= {"theta_m": theta_m, "theta_a": theta_a}
        return g_t, age_next, stats


def _mesh_size(mesh) -> int:
    n = 1
    for ax in mesh.axis_names:
        n *= mesh.shape[ax]
    return n


def make_engine(policy: str = "fairk", backend: str = "exact", *, d: int,
                mesh=None, **cfg_kw) -> SelectionEngine:
    """Convenience constructor mirroring the string-driven policy registry."""
    return SelectionEngine(EngineConfig(policy=policy, backend=backend,
                                        **cfg_kw), d, mesh=mesh)
