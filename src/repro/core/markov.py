"""Markov-chain staleness analysis of FAIR-k (paper Sec. IV-B, Lemma 1).

States are the positions of a coordinate in the ascending-AoU order,
0-indexed here (paper uses 1-indexed): state 0..k_a-1 = the AoU-refreshed
set I_A, state k_a..k-1 = the magnitude-refreshed set I_M, state k..d-1 =
unselected coordinates ordered by age.  Per the paper, the two "fresh"
blocks are collapsed onto their first positions (state 0 and state k_a).

The exchange model: each round, k_0 coordinates swap between I_M and its
complement; p1 = k0/k_M is the leave-probability, p2 = k0/(d − k_M) the
join-probability (Eq. 15).  Transitions of a generic coordinate follow the
three cases of Sec. IV-B; step lengths are capped at ell <= min(k0, n_older)
(footnote 2) and rows are re-normalized.

Everything here is plain numpy float64 — it is analysis code, not a
training-path component.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

# scipy is not installed in this container; implement the binomial pmf
# directly (log-space, numerically stable).


def _binom_pmf(n: int, p: float, ells: np.ndarray) -> np.ndarray:
    """Binomial(n, p) pmf evaluated at integer array ``ells`` (log-space)."""
    ells = np.asarray(ells, dtype=np.int64)
    if n == 0:
        return (ells == 0).astype(np.float64)
    from math import lgamma, log
    logc = (lgamma(n + 1)
            - np.array([lgamma(e + 1) for e in ells])
            - np.array([lgamma(n - e + 1) for e in ells]))
    if p <= 0.0:
        return (ells == 0).astype(np.float64)
    if p >= 1.0:
        return (ells == n).astype(np.float64)
    logp = logc + ells * log(p) + (n - ells) * log(1.0 - p)
    return np.exp(logp)


@dataclasses.dataclass(frozen=True)
class FairKChain:
    d: int
    k: int
    k_m: int
    k0: int

    @property
    def k_a(self) -> int:
        return self.k - self.k_m

    @property
    def p1(self) -> float:
        return self.k0 / self.k_m

    @property
    def p2(self) -> float:
        return self.k0 / (self.d - self.k_m)

    @property
    def max_staleness(self) -> int:
        return -(-(self.d - self.k_m) // self.k_a)

    def __post_init__(self):
        if not (0 < self.k_m < self.k <= self.d // 2):
            raise ValueError(
                "need 0 < k_m < k <= d/2 (paper restricts rho <= 50% and the "
                f"chain needs both stages), got d={self.d} k={self.k} k_m={self.k_m}")
        if not 0 < self.k0 < self.k_m:
            raise ValueError(f"need 0 < k0 < k_m, got k0={self.k0} k_m={self.k_m}")


def transition_matrix(chain: FairKChain) -> np.ndarray:
    """The d x d position-transition matrix P of Sec. IV-B (0-indexed)."""
    d, k, k_m, k_a = chain.d, chain.k, chain.k_m, chain.k_a
    p1, p2, k0 = chain.p1, chain.p2, chain.k0
    P = np.zeros((d, d), np.float64)

    # case 1: freshly AoU-selected block (paper i <= k_a)
    for i in range(k_a):
        P[i, k_a] = p2          # pulled into Top-k_M next round
        P[i, k] = 1.0 - p2      # otherwise starts ageing at the bottom

    # case 2: freshly magnitude-selected block (paper k_a+1 <= i <= k)
    for i in range(k_a, k):
        P[i, k_a] = 1.0 - p1    # sticky: stays in I_M
        P[i, k] = p1            # leaves I_M, starts ageing

    # case 3: ageing coordinates (paper i >= k+1)
    for i in range(k, d):
        n_older = d - 1 - i                      # coordinates older than i
        P[i, k_a] = p2                           # magnitude-selected
        ell_cap = min(k0, n_older)               # footnote 2
        ells = np.arange(0, ell_cap + 1)
        pmf = _binom_pmf(n_older, p2, ells)
        # ell of the older coordinates get magnitude-selected
        for ell, q in zip(ells, pmf):
            stays_prob = (1.0 - p2) * q
            remaining_older = n_older - ell
            if remaining_older < k_a:
                # fewer than k_a coordinates remain older -> i is among the
                # k_a oldest -> AoU stage resets it (paper transition i -> 1)
                P[i, 0] += stays_prob
            else:
                j = i + k_a + ell                # paper: i -> i + k_a + ell
                j = min(j, d - 1)                # clamp (paper normalizes)
                P[i, j] += stays_prob

    # footnote 2: normalize each row over its (truncated) support
    P /= P.sum(axis=1, keepdims=True)
    return P


def steady_state(P: np.ndarray, tol: float = 1e-12, iters: int = 200000
                 ) -> np.ndarray:
    """Solve pi = pi P (Eq. 16) by power iteration."""
    d = P.shape[0]
    pi = np.full(d, 1.0 / d)
    for _ in range(iters):
        nxt = pi @ P
        if np.abs(nxt - pi).sum() < tol:
            pi = nxt
            break
        pi = nxt
    return pi / pi.sum()


def aou_distribution(chain: FairKChain) -> Tuple[np.ndarray, np.ndarray]:
    """Lemma 1: the pmf of the staleness tau.

    Returns (support, pmf) where support = [0, 1, ..., T].  tau = l means the
    coordinate waits l rounds between consecutive refreshes, i.e. from state
    i it first re-enters state 0 or state k_a after l+1 transitions.
    """
    P = transition_matrix(chain)
    pi = steady_state(P)
    d, k_a = chain.d, chain.k_a
    T = chain.max_staleness

    # P with the two absorbing columns zeroed (paper: P_(1, k_a+1))
    P0 = P.copy()
    P0[:, 0] = 0.0
    P0[:, k_a] = 0.0

    pmf = np.zeros(T + 1)
    M = np.eye(d)                  # P0^l, starting at l = 0
    for l in range(T + 1):
        hit = M @ P                # reach a fresh state on the (l+1)-th step
        pmf[l] = float(pi @ (hit[:, 0] + hit[:, k_a]))
        M = M @ P0
    # numerical truncation: renormalize over the finite support
    pmf = np.clip(pmf, 0.0, None)
    pmf /= pmf.sum()
    return np.arange(T + 1), pmf


def expected_staleness(chain: FairKChain) -> float:
    support, pmf = aou_distribution(chain)
    return float((support * pmf).sum())


def shift_pmf(support: np.ndarray, pmf: np.ndarray, lag: int
              ) -> Tuple[np.ndarray, np.ndarray]:
    """Translate a pmf by a deterministic nonnegative integer delay:
    ``P[A = a] -> P[A = a - lag]`` on support ``support + lag``.  The
    distribution-level primitive behind ``shifted_aou_distribution``;
    commutes exactly with ``thin_pmf`` (a constant offset passes through
    a convolution)."""
    if lag < 0:
        raise ValueError(f"lag must be >= 0, got {lag}")
    return np.asarray(support) + lag, np.asarray(pmf, np.float64)


def thin_pmf(support: np.ndarray, pmf: np.ndarray, thin: float,
             tail_mass: float = 1e-9) -> Tuple[np.ndarray, np.ndarray]:
    """Convolve a pmf with an independent ``Geom(thin)`` delay
    (``P[D = j] = (1 - thin) thin^j``, mean ``thin / (1 - thin)``) — the
    distribution-level primitive behind ``thinned_aou_distribution``.

    Requires a contiguous integer support starting at ``support[0]`` (the
    convolution is index-based); the geometric tail is truncated once its
    remaining mass drops below ``tail_mass`` and the result renormalized.
    ``thin = 0`` returns the inputs unchanged.
    """
    if not 0.0 <= thin < 1.0:
        raise ValueError(f"thin must be in [0, 1), got {thin}")
    support = np.asarray(support)
    pmf = np.asarray(pmf, np.float64)
    if thin == 0.0:
        return support, pmf
    # geometric tail length: (1-p) p^j summed beyond J is p^(J+1)
    J = max(1, int(np.ceil(np.log(tail_mass) / np.log(thin))))
    delays = (1.0 - thin) * thin ** np.arange(J + 1)
    out = np.convolve(pmf, delays)
    out = np.clip(out, 0.0, None)
    out /= out.sum()
    return int(support[0]) + np.arange(len(out)), out


def shifted_aou_distribution(chain: FairKChain, lag: int
                             ) -> Tuple[np.ndarray, np.ndarray]:
    """Lemma 1 under async aggregation with a constant delivery lag.

    When every selected coordinate's contribution lands ``lag`` rounds
    late, its post-update age restarts at ``lag`` instead of 0 while the
    inter-refresh dynamics (the position chain of Sec. IV-B) are
    unchanged — the selection itself still scores the carried buffer the
    same way.  The stationary post-update AoU pmf is therefore exactly
    the synchronous Lemma-1 pmf translated by ``lag``:
    ``P[A = a] = pmf_sync[a - lag]`` on support ``[lag, T + lag]``.
    """
    return shift_pmf(*aou_distribution(chain), lag)


def thinned_aou_distribution(chain: FairKChain, thin: float,
                             tail_mass: float = 1e-9
                             ) -> Tuple[np.ndarray, np.ndarray]:
    """Lemma 1 under participation thinning (fault channels).

    When each round's refresh of a selected coordinate is independently
    *blocked* with probability ``thin`` — a deep-fade erasure or a
    corrupted (non-finite) uplink that the sanitize stage masks out — the
    coordinate stays semantically "unsent": its age keeps climbing and its
    mass stays in the EF residual, exactly as if the refresh were delayed.
    Because FAIR-k re-selects the now-even-staler coordinate with at least
    the age-stage priority it already had, the delay until the refresh
    actually lands is (approximately, in the well-mixed exchange regime)
    geometric: ``D ~ Geom(thin)``, ``P[D = j] = (1 - thin) thin^j``.

    The post-update stationary AoU is then the synchronous Lemma-1 age
    plus an independent geometric delay — a convolution rather than the
    deterministic translation of ``shifted_aou_distribution``:

        P[A = a] = sum_j (1 - thin) thin^j * pmf_sync[a - j]

    with mean shift ``thin / (1 - thin)`` (the constant offset
    ``BudgetController(..., thin=...)`` absorbs).  ``thin = 0`` returns
    the synchronous pmf unchanged.  The geometric tail is truncated once
    its remaining mass drops below ``tail_mass`` and renormalized.
    """
    return thin_pmf(*aou_distribution(chain), thin, tail_mass=tail_mass)


def population_thin(avail: float, vanish_rate: float, participants: int,
                    exposure: float = 0.5) -> float:
    """Effective per-round refresh-blocking probability of a churning
    population (DESIGN.md §15): mid-round churn erases each symbol block
    of the aggregate with probability ``exposure * vanish_rate`` (a
    participant whose chain transitions down mid-round loses a random
    ~``exposure`` share of its interleaved uplink blocks), and a TOTAL
    outage of the sampled cohort — all ``participants`` clients down at
    once — erases the round outright with probability
    ``(1 - avail)^participants``.  Both channels block a selected
    coordinate's refresh independently per round, which is exactly the
    thinning model of ``thinned_aou_distribution``.

    Mirrors ``population.PopulationConfig.thin`` (kept numerically
    identical so the analysis side needs no jax import).
    """
    if not 0.0 < avail <= 1.0:
        raise ValueError(f"avail must be in (0, 1], got {avail}")
    if not 0.0 <= vanish_rate <= 1.0:
        raise ValueError(
            f"vanish_rate must be in [0, 1], got {vanish_rate}")
    if participants < 1:
        raise ValueError(f"participants must be >= 1, got {participants}")
    if not 0.0 < exposure <= 1.0:
        raise ValueError(f"exposure must be in (0, 1], got {exposure}")
    outage = (1.0 - avail) ** participants
    return min(0.99, exposure * vanish_rate + outage)


def population_aou_distribution(chain: FairKChain, avail: float,
                                vanish_rate: float, participants: int,
                                exposure: float = 0.5,
                                tail_mass: float = 1e-9
                                ) -> Tuple[np.ndarray, np.ndarray]:
    """Lemma 1 under population churn: the participation-thinned
    stationary post-update AoU pmf, with the thinning probability derived
    from the population's stationary availability (``population_thin``).
    This is the Sec. IV prediction the population validation suite
    (``tests/test_population.py``) checks the empirical histogram against
    on the exact and packed backends.
    """
    thin = population_thin(avail, vanish_rate, participants,
                           exposure=exposure)
    return thinned_aou_distribution(chain, thin, tail_mass=tail_mass)


def truncation_thin(pmax: float, gmin: float, gains) -> float:
    """Per-round refresh-blocking probability under truncated channel
    inversion (DESIGN.md §16): client ``n``'s instantaneous gain is
    ``G_n = L_n X_n`` with ``X_n ~ Exp(1)`` (Rayleigh power fading) and
    ``L_n`` its static path gain; the client is truncated out of the
    superposition when ``G_n`` falls below the effective threshold
    ``g_eff = max(gmin, 1/pmax)`` (inverting a weaker gain would exceed
    the power budget), so its stationary outage probability is
    ``q_n = 1 - exp(-g_eff / L_n)``.  Partial outages renormalize over
    the survivors (like dropout, they barely thin); only a TOTAL outage
    — every client truncated at once — blocks a selected coordinate's
    refresh, so the thinning rate of ``thinned_aou_distribution`` is
    ``prod_n q_n``.

    Mirrors ``channel.ChannelConfig.thin`` (kept numerically identical
    so the analysis side needs no jax import).
    """
    if not (pmax > 0.0 and np.isfinite(pmax)):
        raise ValueError(f"pmax must be a finite positive power budget, "
                         f"got {pmax}")
    if gmin < 0.0:
        raise ValueError(f"gmin must be >= 0, got {gmin}")
    gains = np.asarray(gains, np.float64)
    if gains.ndim != 1 or gains.size < 1:
        raise ValueError(f"gains must be a non-empty 1-D path-gain "
                         f"vector, got shape {gains.shape}")
    if not np.all(gains > 0.0):
        raise ValueError("path gains must be strictly positive")
    g_eff = max(gmin, 1.0 / pmax)
    outage = -np.expm1(-g_eff / gains)
    return min(0.99, float(np.prod(outage)))


def channel_aou_distribution(chain: FairKChain, pmax: float, gmin: float,
                             gains, extra_thin: float = 0.0,
                             tail_mass: float = 1e-9
                             ) -> Tuple[np.ndarray, np.ndarray]:
    """Lemma 1 under truncated channel inversion: the stationary
    post-update AoU pmf thinned at ``truncation_thin(pmax, gmin, gains)``.

    ``extra_thin`` composes an independent second blocking channel —
    population churn (``population_thin``), deep fades — with the
    truncation outage: the per-round blocking probability of two
    independent blockers is ``1 - (1 - t_trunc)(1 - extra_thin)``.  This
    is the Sec. IV prediction the channel validation suite
    (``tests/test_channel.py``) checks the empirical histogram against
    on the exact and packed backends.
    """
    if not 0.0 <= extra_thin < 1.0:
        raise ValueError(
            f"extra_thin must be in [0, 1), got {extra_thin}")
    t = truncation_thin(pmax, gmin, gains)
    thin = min(0.99, 1.0 - (1.0 - t) * (1.0 - extra_thin))
    return thinned_aou_distribution(chain, thin, tail_mass=tail_mass)


def simulate_aou(chain: FairKChain, rounds: int, seed: int = 0,
                 mode: str = "exchange", momentum: float = 0.9,
                 burn_in: int = 200) -> np.ndarray:
    """Empirical AoU distribution under FAIR-k selection (Fig. 3 check).

    Lemma 1 characterizes the *time-averaged* distribution of A_{t,i} over a
    typical coordinate at a typical (stationary) round, so we histogram the
    full post-update age vector every round after a burn-in.

    Modes for the magnitude dynamics:
      * ``"exchange"`` — the Sec. IV-B exchange model itself: each round k0
        uniformly chosen members of the Top-k_M set swap with k0 uniformly
        chosen outsiders.  Matches the analytic assumptions exactly.
      * ``"ar"`` — AR(1) gradient magnitudes (persistence ~= ``momentum``);
        the actual Top-k_M of |g| is used.  Shows robustness of the analysis
        to the simplifying exchange assumption.
    """
    rng = np.random.default_rng(seed)
    d, k, k_m, k_a, k0 = chain.d, chain.k, chain.k_m, chain.k_a, chain.k0
    age = np.zeros(d, dtype=np.int64)
    counts = np.zeros(chain.max_staleness + 2)
    if mode == "exchange":
        in_m = np.zeros(d, dtype=bool)
        in_m[rng.choice(d, k_m, replace=False)] = True
    else:
        mag = np.abs(rng.normal(size=d))
    for t in range(rounds + burn_in):
        if mode == "exchange":
            leave = rng.choice(np.flatnonzero(in_m), k0, replace=False)
            join = rng.choice(np.flatnonzero(~in_m), k0, replace=False)
            in_m[leave] = False
            in_m[join] = True
            idx_m = np.flatnonzero(in_m)
        elif mode == "ar":
            mag = momentum * mag + (1 - momentum) * np.abs(rng.normal(size=d))
            idx_m = np.argpartition(-mag, k_m)[:k_m]
        else:
            raise ValueError(f"unknown mode {mode!r}")
        masked_age = age.astype(np.float64)
        masked_age[idx_m] = -1.0
        idx_a = np.argpartition(-masked_age, k_a)[:k_a]
        sel = np.concatenate([idx_m, idx_a])
        age += 1
        age[sel] = 0
        if t >= burn_in:
            clipped = np.clip(age, 0, len(counts) - 1)
            counts += np.bincount(clipped, minlength=len(counts))
    pmf = counts[: chain.max_staleness + 1]
    s = pmf.sum()
    return pmf / s if s > 0 else pmf
