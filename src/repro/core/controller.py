"""In-graph adaptive budget controller: the age histogram drives k_M online.

The paper's Sec. V-A shows the magnitude/timeliness split ``k_M/k`` is THE
knob trading freshness against importance — and its Sec. IV-B Markov
analysis (Lemma 1, ``core.markov``) predicts exactly what the staleness
distribution SHOULD look like for a given split.  Since PR 4 the fused
server kernel emits the empirical staleness pmf every round for free (the
``age_hist`` row of ``ops.fairk_stats_update``), so closing the loop
costs a few hundred scalar flops:

    measure   the empirical staleness quantile from the EMA'd age
              histogram (the finite-sample π of Lemma 1),
    predict   the stationary quantile Lemma 1 assigns to the CURRENT
              split (a static per-(ρ, k_M/k) table, interpolated in-graph
              over the traced ``k_m_frac``),
    correct   ``k_m_frac`` by a clipped, damped proportional step: staler
              than the model predicts (a sticky magnitude stage is
              starving the age stage) -> shift budget to the age stage;
              fresher -> spend it on magnitude.

Everything is traced: the controller state rides in the server state
pytree, the update runs INSIDE the compiled round, and the engine
consumes ``k_m_frac`` as a traced value (``SelectionEngine.
select_and_merge(..., k_m_frac=...)``), so adaptation costs zero host
syncs and zero recompiles — unlike the historical ``fairk_auto`` path,
which device-synced the full gradient for a host-side Gini statistic and
cached one recompiled step per discrete k_M level.

Following the age-aware partial-update line (Du et al., "Age-Aware
Partial Gradient Update Strategy for Federated Learning Over the Air";
Elshazly & Arafa's edge-blind age-aware aggregation — PAPERS.md), the
controller only consumes statistics the server already observes.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing

Array = jax.Array

# trace-time counter: how many controller updates a program traces.  The
# no-recompile acceptance claim (``packed_bench --smoke``) executes one
# jitted adaptive round at several k_m_frac operating points and asserts
# this advanced exactly ONCE — the split rides as data, never as a new
# compilation.
UPDATE_TRACES = 0


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Adaptive-``k_m_frac`` control law settings.

    The regulated quantity is the ``target_quantile`` of the staleness
    pmf; its setpoint is either the Lemma-1 stationary prediction for the
    current split (``target_age=None`` — the self-calibrating default) or
    a fixed age in rounds.  ``gain``/``max_step``/``damping`` shape the
    clipped proportional step on ``k_m_frac``; ``ema`` smooths the
    per-round histograms before the quantile is read off."""
    target_quantile: float = 0.9   # which staleness quantile to regulate
    target_age: Optional[float] = None  # rounds; None -> Lemma-1 table
    gain: float = 0.15             # proportional gain on the relative error
    max_step: float = 0.02         # |Δk_m_frac| bound per actuation
    damping: float = 0.5           # step EMA (limit-cycle suppression)
    deadband: float = 0.1          # relative error below which no step is
                                   # taken (the plateau of Sec. V-A makes
                                   # parking anywhere inside it free)
    period: int = 5                # rounds between actuations: the
                                   # staleness quantile answers a split
                                   # change only ~1/ρ_A rounds later, so
                                   # stepping every round overshoots into
                                   # a rail-to-rail limit cycle — the EMA
                                   # keeps integrating every round either
                                   # way
    ema: float = 0.9               # histogram EMA decay
    min_frac: float = 0.05         # k_m_frac clamp (both stages stay alive)
    max_frac: float = 0.95
    k0_frac: float = 0.25          # assumed exchange rate k_0/k_M (Sec. IV-B)
    chain_d: int = 128             # scaled Lemma-1 chain size (staleness is
                                   # scale-free in (ρ, k_M/k), Sec. IV-B)
    table_points: int = 7          # k_m_frac grid of the target table


# controller state: a dict pytree carried across rounds next to the
# threshold state.  ``k_m_frac`` is the live split (what the engine
# consumes as its traced magnitude budget), ``prev_step`` the damped step
# memory, ``init`` flips to 1 after the first observed histogram (the
# controller never steps off a round-0 full-refresh histogram), ``tick``
# counts rounds since the last actuation, and ``age_ema``/``mag_ema``
# the EMA'd in-kernel histograms.  Convention: ``mag_ema`` tracks the
# kernel-emitted |score| histogram and ONLY it — call sites without a
# fused kernel pass (the exact FL route, the sweep lanes) pass
# ``mag_hist=None`` and leave it untouched.  The control law reads only
# ``age_ema``; the magnitude EMA rides along as the spectrum diagnostic
# (and the hook for concentration-aware targets) at zero extra cost —
# the kernel emits the histogram either way.
CTRL_SCALAR_FIELDS = ("k_m_frac", "prev_step", "init", "tick")
CONTROLLER_STATE_SIZE = (len(CTRL_SCALAR_FIELDS)
                         + packing.STATS_AGE_BINS + packing.STATS_MAG_BINS)


def init_controller_state(k_m_frac=0.75) -> Dict[str, Array]:
    z = jnp.float32(0.0)
    return {"k_m_frac": jnp.asarray(k_m_frac, jnp.float32),
            "prev_step": z, "init": z, "tick": z,
            "age_ema": jnp.zeros((packing.STATS_AGE_BINS,), jnp.float32),
            "mag_ema": jnp.zeros((packing.STATS_MAG_BINS,), jnp.float32)}


def controller_state_to_vec(cs: Dict[str, Array]) -> Array:
    """(CONTROLLER_STATE_SIZE,) f32 encoding — scalars, then the two EMA
    histograms — for server-state dicts that want one flat array (the
    launch trainer persists and checkpoints it this way)."""
    scalars = jnp.stack([jnp.asarray(cs[f], jnp.float32)
                         for f in CTRL_SCALAR_FIELDS])
    return jnp.concatenate([scalars, cs["age_ema"], cs["mag_ema"]]
                           ).astype(jnp.float32)


def controller_state_from_vec(vec: Array) -> Dict[str, Array]:
    ns = len(CTRL_SCALAR_FIELDS)
    cs = {f: vec[i] for i, f in enumerate(CTRL_SCALAR_FIELDS)}
    cs["age_ema"] = vec[ns:ns + packing.STATS_AGE_BINS]
    cs["mag_ema"] = vec[ns + packing.STATS_AGE_BINS:CONTROLLER_STATE_SIZE]
    return cs


# ---------------------------------------------------------------------------
# staleness pmf / quantile from the in-kernel age histogram
# ---------------------------------------------------------------------------

def staleness_pmf(age_hist: Array) -> Array:
    """Empirical staleness pmf over the unit age bins — the finite-sample
    counterpart of Lemma 1's stationary π (the histogram is already binned
    on the chain's state space, ``docs/REPRODUCTION.md``)."""
    h = jnp.asarray(age_hist, jnp.float32)
    return h / jnp.maximum(h.sum(), 1.0)


def pmf_quantile(pmf: Array, q: float) -> Array:
    """Inverse cdf of a unit-bin pmf at ``q``, linearly interpolated inside
    the cut bin (the same sub-unit convention ``packing.hist_thresholds``
    uses for θ_A — within an integer atom the index jitter is uniform)."""
    pmf = jnp.asarray(pmf, jnp.float32)
    cdf = jnp.cumsum(pmf)
    b = jnp.clip(jnp.sum((cdf < q).astype(jnp.float32)),
                 0.0, pmf.shape[0] - 1).astype(jnp.int32)
    prev = jnp.where(b > 0, cdf[jnp.maximum(b - 1, 0)], 0.0)
    frac = jnp.clip((q - prev) / jnp.maximum(pmf[b], 1e-9), 0.0, 1.0)
    return b.astype(jnp.float32) + frac


# ---------------------------------------------------------------------------
# Lemma-1 target table (static, built once per (ρ, config) at trace time)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=256)
def _lemma1_quantile(d: int, k: int, k_m: int, k0: int, q: float) -> float:
    """Stationary staleness quantile of the Sec. IV-B chain (cached — the
    table rebuild on re-traces must not re-run the power iteration)."""
    from repro.core import markov                  # analysis-only import
    chain = markov.FairKChain(d=d, k=k, k_m=k_m, k0=k0)
    support, pmf = markov.aou_distribution(chain)
    cum = np.cumsum(pmf)
    idx = int((cum < q).sum())
    idx = min(idx, len(pmf) - 1)
    prev = float(cum[idx - 1]) if idx > 0 else 0.0
    frac = float(np.clip((q - prev) / max(float(pmf[idx]), 1e-12), 0.0, 1.0))
    return float(support[idx]) + frac


def lemma1_target_table(cfg: ControllerConfig, rho: float
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """(fracs, target quantiles): Lemma 1 evaluated on a scaled-down chain
    at each ``k_m_frac`` grid point.  Staleness in rounds depends on the
    RATIOS (ρ, k_M/k, k_0/k_M), not on d — e.g. the support bound
    T = ⌈(d − k_M)/(k − k_M)⌉ ≈ (1 − ρ·f)/(ρ(1 − f)) — so a small chain
    prices the target for any model size.

    Validity bounds: the chain needs ρ ≤ 0.5 (the paper's own restriction
    — larger ρ is priced AT 0.5) and at least 2 magnitude slots per grid
    point, so the chain dimension grows as ~20/ρ (capped at 256 to bound
    the power-iteration cost).  Below ρ ≈ 0.08 the low-``k_m_frac`` grid
    points quantise coarsely (k_m_c pinned at 2) and the interpolated
    setpoint is approximate there — pin ``target_age`` explicitly when
    regulating a very sparse budget at an extreme split."""
    d_c = int(min(256, max(cfg.chain_d, round(20.0 / max(rho, 1e-3)))))
    k_c = int(np.clip(round(rho * d_c), 3, d_c // 2))
    fracs = np.linspace(cfg.min_frac, cfg.max_frac, cfg.table_points)
    targets = []
    for f in fracs:
        k_m_c = int(np.clip(round(f * k_c), 2, k_c - 1))
        k0_c = int(np.clip(round(cfg.k0_frac * k_m_c), 1, k_m_c - 1))
        t = _lemma1_quantile(d_c, k_c, k_m_c, k0_c, cfg.target_quantile)
        targets.append(min(t, packing.STATS_AGE_BINS - 2.0))
    return fracs.astype(np.float32), np.asarray(targets, np.float32)


# ---------------------------------------------------------------------------
# the controller
# ---------------------------------------------------------------------------

class BudgetController:
    """Clipped proportional regulation of ``k_m_frac`` on the staleness
    quantile.  Construct once per (ρ, config) — the Lemma-1 target table
    is static data baked at build time; ``update`` is a pure traced
    function of ``(state, age_hist, mag_hist)``."""

    def __init__(self, cfg: ControllerConfig = ControllerConfig(), *,
                 rho: float, age_offset: float = 0.0, thin: float = 0.0):
        self.cfg = cfg
        self.rho = float(rho)
        # async-aggregation mode: every selected coordinate's age restarts
        # at the delivery lag instead of 0, so the whole stationary pmf —
        # and with it every quantile — shifts right by the lag
        # (``markov.shifted_aou_distribution``).  Raising the setpoint by
        # the same constant makes the controller regulate the sync-
        # equivalent freshness instead of fighting the uplink delay.
        # Participation thinning (fault channels, ``core.faults``) shifts
        # the mean by the geometric-delay expectation thin/(1 - thin)
        # (``markov.thinned_aou_distribution``) — same absorption pattern,
        # so the controller does not fight churn it cannot fix.
        if not 0.0 <= thin < 1.0:
            raise ValueError(f"thin must be in [0, 1), got {thin}")
        self.age_offset = float(age_offset) + (thin / (1.0 - thin)
                                               if thin else 0.0)
        if cfg.target_age is None:
            fracs, targets = lemma1_target_table(cfg, self.rho)
            self._fracs = jnp.asarray(fracs)
            self._targets = jnp.asarray(targets)
        else:
            self._fracs = self._targets = None

    def init_state(self, k_m_frac=0.75) -> Dict[str, Array]:
        return init_controller_state(k_m_frac)

    def target_for(self, k_m_frac: Array) -> Array:
        """Setpoint for the regulated staleness quantile at the current
        split: the Lemma-1 stationary prediction (in-graph interpolation
        over the static table, so the setpoint moves WITH the traced
        split) or the fixed ``target_age`` — plus the async
        ``age_offset`` (0.0 in synchronous mode: value-identical)."""
        if self.cfg.target_age is not None:
            return jnp.float32(self.cfg.target_age + self.age_offset)
        tgt = jnp.interp(jnp.asarray(k_m_frac, jnp.float32),
                         self._fracs, self._targets)
        return tgt + self.age_offset if self.age_offset else tgt

    def update(self, state: Dict[str, Array], age_hist: Array,
               mag_hist: Optional[Array] = None) -> Dict[str, Array]:
        """One in-graph controller step from this round's kernel-emitted
        histograms.  Staler than the setpoint -> negative step (more age
        budget); fresher -> positive (more magnitude budget).  The step is
        clipped at ``max_step`` and EMA-damped; the very first observation
        only seeds the histogram EMA (a round-0 full-refresh histogram —
        everything at age 0 — must not slam the split to ``max_frac``)."""
        global UPDATE_TRACES
        UPDATE_TRACES += 1
        cfg = self.cfg
        seen = state["init"] > 0.0
        a_new = jnp.asarray(age_hist, jnp.float32)
        age_ema = jnp.where(seen, cfg.ema * state["age_ema"]
                            + (1.0 - cfg.ema) * a_new, a_new)
        if mag_hist is not None:
            m_new = jnp.asarray(mag_hist, jnp.float32)
            mag_ema = jnp.where(seen, cfg.ema * state["mag_ema"]
                                + (1.0 - cfg.ema) * m_new, m_new)
        else:
            mag_ema = state["mag_ema"]
        q_meas = pmf_quantile(staleness_pmf(age_ema), cfg.target_quantile)
        q_tgt = self.target_for(state["k_m_frac"])
        err = (q_meas - q_tgt) / jnp.maximum(q_tgt, 1.0)
        # deadband: inside the Sec. V-A plateau every split is free, so a
        # small relative error buys nothing but actuation noise
        err = jnp.sign(err) * jnp.maximum(jnp.abs(err) - cfg.deadband, 0.0)
        tick = state["tick"] + 1.0
        act = seen & (age_ema.sum() > 0.0) & (tick >= cfg.period)
        raw = jnp.clip(-cfg.gain * err, -cfg.max_step, cfg.max_step)
        step = cfg.damping * state["prev_step"] + (1.0 - cfg.damping) * raw
        step = jnp.where(act, step, 0.0)
        k_m_frac = jnp.clip(state["k_m_frac"] + step,
                            cfg.min_frac, cfg.max_frac)
        return {"k_m_frac": k_m_frac,
                "prev_step": jnp.where(act, step, state["prev_step"]),
                "init": jnp.float32(1.0),
                "tick": jnp.where(act, 0.0, tick),
                "age_ema": age_ema, "mag_ema": mag_ema}
