"""Geometric wireless channel: path loss, correlated Rayleigh fading,
truncated channel inversion and imperfect CSI (DESIGN.md §16).

``core.oac`` implements the paper's idealized Sec. III-A channel — iid
scalar fading per client per round plus additive Gaussian noise.  Real
OAC lives on a *geometric* channel (the AirFL signal-processing survey's
impairment list): clients sit at different distances from the server, so
their large-scale path gains differ by orders of magnitude; small-scale
Rayleigh fading is *temporally correlated* (a deep fade lasts several
rounds); and the transmitters run **truncated channel inversion** power
control — a client inverts its instantaneous channel so its contribution
arrives coherently aligned, but when the gain falls below the truncation
threshold the required power would exceed the budget and the client sits
the round out.  This module makes all three a TRACED part of the round,
degrading through the engine's existing ``erase``/``sanitize`` path:

* **static deployment geometry** — per-client large-scale path gains
  from a log-distance model with optional log-normal shadowing.  Clients
  sit on a deterministic distance grid in ``[near, 1]`` (normalized cell
  radius) and shadowing draws from ``numpy.default_rng(geo_seed)``, so
  the gains are a pure function of the config — the analysis side
  (``markov.truncation_thin``) and the controller setpoint see exactly
  the gains the simulation uses, no carried state, no jax import.
* **Gauss–Markov Rayleigh block fading** — each client's small-scale
  coefficient is a complex AR(1) chain
  ``f_t = rho_f f_{t-1} + sqrt(1 - rho_f^2) w_t`` with ``w_t ~ CN(0,1)``,
  carried in the fault-state / server-state dict exactly like the
  Gilbert–Elliott availability chains.  The stationary law is
  ``CN(0, 1)`` for any ``rho_f``, so the gain ``|f|^2`` stays Exp(1) and
  the stationary outage probability is closed-form; ``rho_f = 0`` is the
  classical memoryless block-fading special case.
* **truncated channel inversion** — client ``n`` transmits iff its
  instantaneous gain ``G_n = L_n |f_n|^2`` clears the effective
  threshold ``g_eff = max(gmin, 1/pmax)`` (inverting a gain below
  ``1/pmax`` would need more than the power budget; ``gmin`` is the
  designed truncation point).  Survivors arrive coherently (coefficient
  1 after inversion), the aggregate rescales by the realised
  participation, and a TOTAL outage — every client truncated at once —
  erases the round through ``faults.erase_with_outage``: truncated
  coordinates merge stale and age up, semantically "unsent", never
  NaN-poisoning thresholds.  Per-client outage is
  ``q_n = 1 - exp(-g_eff / L_n)`` (Exp(1) fading), so the per-round
  refresh-blocking probability is ``thin = prod_n q_n`` — the Lemma-1
  thinning rate ``markov.truncation_thin`` mirrors and
  ``BudgetController(..., thin=...)`` absorbs.
* **imperfect CSI** — the inversion uses an ESTIMATED channel, so a
  residual multiplicative misalignment ``1 + sigma_e e_n`` survives on
  each surviving client (``csi_weights``): structured distortion
  proportional to the client gradients themselves, not iid additive
  noise.  The one-bit and EF routes ride it unchanged and the
  divergence watchdog guards against a blow-up.

The launch path's pre-aggregated gradient has no per-client axis, so it
carries the *aggregate-equivalent* form: one AR(1) fading chain per
``block``-coordinate symbol group (``init_block_fading`` persisted in
the server state, checkpoint-migratable because the cold start is a
deterministic stationary draw), with the per-block truncation threshold
calibrated so the marginal erasure probability is exactly ``cfg.thin``
— same stationary staleness law, temporal correlation preserved, state
``2 d / block`` floats.  ``block_erase_mask`` is the single
block-granular erasure primitive; ``faults.fade_mask`` is a thin alias
over it (bit-exact with the pre-PR-9 ``fold_in(0xFADE)`` traces).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

_SQRT_HALF = math.sqrt(0.5)     # CN(0, 1): each real component is N(0, 1/2)
FADING_INIT_KEY = 0xFAD         # fixed PRNGKey for the launch path's
                                # stationary cold-start fading draw — the
                                # checkpoint codec re-synthesizes the
                                # identical state when migrating a
                                # pre-channel checkpoint


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """A geometric wireless deployment.  Hashable (jit-static) and
    all-static: the path gains derive deterministically from the config,
    every traced quantity derives from (state, key)."""
    n_clients: int = 16        # clients in the deployment (must match the
                               # trainer/sweep N — validated at wiring)
    pmax: float = 10.0         # per-client transmit power budget: inverting
                               # a gain below 1/pmax is infeasible
    gmin: float = 0.05         # designed truncation threshold on the
                               # instantaneous gain G_n = L_n |f_n|^2
    rho_f: float = 0.0         # Gauss–Markov AR(1) fading correlation in
                               # [0, 1); 0 = memoryless block fading
    csi_err: float = 0.0       # sigma_e: residual channel-estimation error
                               # std — multiplicative misalignment on each
                               # surviving client's contribution
    pl_exp: float = 3.0        # log-distance path-loss exponent
    shadow_db: float = 0.0     # log-normal shadowing std in dB (static
                               # per run, drawn from geo_seed)
    near: float = 0.1          # nearest client's normalized distance: the
                               # deterministic deployment grid spans
                               # [near, 1] of the cell radius
    geo_seed: int = 0          # shadowing draw seed (numpy, trace-static)
    block: int = 128           # coordinates per fading block on the
                               # launch path's aggregate-equivalent chain
                               # (one OFDM symbol group's worth)

    def __post_init__(self):
        if self.n_clients < 1:
            raise ValueError(
                f"n_clients must be >= 1, got {self.n_clients}")
        if not (self.pmax > 0.0 and math.isfinite(self.pmax)):
            raise ValueError(
                f"pmax must be a finite positive power budget, got "
                f"{self.pmax}")
        if self.gmin < 0.0:
            raise ValueError(f"gmin must be >= 0, got {self.gmin}")
        if not 0.0 <= self.rho_f < 1.0:
            raise ValueError(
                f"rho_f must be in [0, 1) (rho_f = 1 would freeze the "
                f"fading chain), got {self.rho_f}")
        if self.csi_err < 0.0:
            raise ValueError(f"csi_err must be >= 0, got {self.csi_err}")
        if self.pl_exp < 0.0:
            raise ValueError(f"pl_exp must be >= 0, got {self.pl_exp}")
        if self.shadow_db < 0.0:
            raise ValueError(
                f"shadow_db must be >= 0, got {self.shadow_db}")
        if not 0.0 < self.near <= 1.0:
            raise ValueError(
                f"near must be in (0, 1] (normalized cell radius), got "
                f"{self.near}")
        if self.block < 1:
            raise ValueError(f"block must be >= 1, got {self.block}")

    @property
    def g_eff(self) -> float:
        """Effective truncation threshold: the designed ``gmin`` or the
        power-budget floor ``1/pmax``, whichever binds."""
        return max(self.gmin, 1.0 / self.pmax)

    @property
    def gains(self) -> np.ndarray:
        """(n_clients,) float64 static large-scale path gains, normalized
        to 1 at the cell edge: log-distance loss ``-10 pl_exp log10(r)``
        dB plus ``shadow_db``-scaled log-normal shadowing.  Deterministic
        per config — the deployment grid is fixed and the shadowing rng
        is seeded by ``geo_seed``."""
        n = self.n_clients
        dist = self.near + (1.0 - self.near) * (np.arange(n) + 0.5) / n
        gain_db = -10.0 * self.pl_exp * np.log10(dist)
        if self.shadow_db > 0.0:
            rng = np.random.default_rng(self.geo_seed)
            gain_db = gain_db + self.shadow_db * rng.standard_normal(n)
        return 10.0 ** (gain_db / 10.0)

    @property
    def outage(self) -> np.ndarray:
        """(n_clients,) stationary per-client truncation-outage
        probability ``q_n = 1 - exp(-g_eff / L_n)`` (Exp(1) Rayleigh
        power fading scaled by the static path gain)."""
        return -np.expm1(-self.g_eff / self.gains)

    @property
    def thin(self) -> float:
        """Per-round refresh-blocking probability for the Lemma-1
        thinning law and the controller setpoint: a refresh is blocked
        exactly when EVERY client is truncated at once (partial outages
        renormalize over the survivors, total outage erases the round).
        Mirrors ``markov.truncation_thin`` (kept numerically identical
        so the analysis side needs no jax import)."""
        return min(0.99, float(np.prod(self.outage)))


# ---------------------------------------------------------------------------
# block-granular erasure primitive (shared with faults.fade_mask)
# ---------------------------------------------------------------------------

def expand_block_mask(hit: Array, d: int, block: int) -> Array:
    """Expand a per-block boolean hit vector into the (d,) f32 erasure
    mask (1.0 = erased) every sanitize-path consumer expects — the single
    block→coordinate expansion faults and channel truncation share."""
    return jnp.repeat(hit.astype(jnp.float32), block)[:d]


def block_erase_mask(key: Array, d: int, p, block: int) -> Array:
    """(d,) f32 erasure mask at ``block``-coordinate granularity: each
    symbol group erases independently with probability ``p`` (static or
    traced).  ``faults.fade_mask`` is a thin alias over this draw, so
    the pre-PR-9 iid deep-fade traces stay bit-exact."""
    nb = -(-d // block)
    hit = jax.random.uniform(key, (nb,)) < p
    return expand_block_mask(hit, d, block)


# ---------------------------------------------------------------------------
# per-client fading chain (trainer / sweep paths)
# ---------------------------------------------------------------------------

def _stationary_fading(key: Array, shape: Tuple[int, ...]) -> Array:
    """CN(0, 1) stationary draw stored as a trailing (..., 2) real/imag
    pair of N(0, 1/2) components — ``|f|^2`` is Exp(1)."""
    return jnp.float32(_SQRT_HALF) * jax.random.normal(
        key, shape + (2,), jnp.float32)


def fading_step(fad: Array, key: Array, rho_f: float) -> Array:
    """One Gauss–Markov AR(1) transition of a complex fading array:
    ``f' = rho_f f + sqrt(1 - rho_f^2) w`` with ``w ~ CN(0, 1)`` —
    elementwise only, so it vmaps over sweep lanes and scans over rounds
    without recompiling.  Preserves the CN(0, 1) stationary law."""
    w = _stationary_fading(key, fad.shape[:-1])
    return (jnp.float32(rho_f) * fad
            + jnp.float32(math.sqrt(1.0 - rho_f * rho_f)) * w)


def init_channel_state(key: Array, cfg: ChannelConfig) -> Dict[str, Array]:
    """Stationary-law initial per-client fading state: ``fad`` is the
    (n_clients, 2) complex AR(1) chain (real/imag components)."""
    return {"fad": _stationary_fading(key, (cfg.n_clients,))}


def channel_round(state: Dict[str, Array], key: Array, cfg: ChannelConfig
                  ) -> Tuple[Dict[str, Array], Dict[str, Array]]:
    """Advance every client's fading chain one round and apply truncated
    channel inversion.  Returns ``(state', stats)`` with ``sent`` the
    (n_clients,) f32 participation gate (1.0 = transmits: gain cleared
    ``g_eff``), ``n_sent`` the realised count feeding
    ``faults.participation_scale``, and ``gain`` the instantaneous
    ``G_n = L_n |f_n|^2`` for telemetry."""
    fad = fading_step(state["fad"], key, cfg.rho_f)
    x = jnp.sum(fad * fad, axis=-1)                      # |f|^2 ~ Exp(1)
    gain = jnp.asarray(cfg.gains, jnp.float32) * x
    sent = (gain >= jnp.float32(cfg.g_eff)).astype(jnp.float32)
    return {"fad": fad}, {"sent": sent, "n_sent": sent.sum(),
                          "gain": gain}


def csi_weights(key: Array, n_clients: int, cfg: ChannelConfig) -> Array:
    """(n_clients,) multiplicative residual-misalignment factors
    ``1 + sigma_e e_n``: the inversion used an estimated channel, so each
    surviving contribution arrives scaled by a client-specific error —
    structured distortion proportional to the gradients themselves.
    ``csi_err = 0`` returns exact ones (no trace of the draw)."""
    if cfg.csi_err <= 0.0:
        return jnp.ones((n_clients,), jnp.float32)
    return 1.0 + jnp.float32(cfg.csi_err) * jax.random.normal(
        key, (n_clients,), jnp.float32)


# ---------------------------------------------------------------------------
# aggregate-equivalent per-block chain (launch path)
# ---------------------------------------------------------------------------

def n_blocks(d: int, cfg: ChannelConfig) -> int:
    """Fading blocks covering a (d,) buffer at ``cfg.block`` granularity."""
    return -(-d // cfg.block)


def init_block_fading(nb: int) -> Array:
    """(2 * nb,) f32 flat stationary per-block fading for the launch
    path's persisted server state.  The draw uses the FIXED
    ``FADING_INIT_KEY`` — a pure function of the shape — so checkpoint
    migration of a pre-channel checkpoint re-synthesizes the exact state
    a cold start would carry (a lawful stationary start; zeros would be
    a full-outage state, NOT the stationary fading law)."""
    return _stationary_fading(jax.random.PRNGKey(FADING_INIT_KEY),
                              (nb,)).reshape(-1)


def block_outage(fad_flat: Array, key: Array, d: int, cfg: ChannelConfig
                 ) -> Tuple[Array, Array]:
    """One launch-path channel round on the aggregate: advance the
    per-block AR(1) chain and erase every block whose Exp(1) gain falls
    below the threshold calibrated to the composed truncation-outage
    probability (``P(X < -log(1 - thin)) = thin``), so the marginal
    erasure rate matches the per-client law exactly while the AR(1)
    state preserves the temporal outage correlation.  Elementwise math
    only — never an extra read of the packed gradient buffer.  Returns
    ``(fad_flat', erase_mask)``."""
    nb = n_blocks(d, cfg)
    fad = fading_step(fad_flat.reshape(nb, 2), key, cfg.rho_f)
    x = jnp.sum(fad * fad, axis=-1)                      # Exp(1) block gain
    thr = jnp.float32(-math.log1p(-cfg.thin))
    return fad.reshape(-1), expand_block_mask(x < thr, d, cfg.block)


def csi_block_factor(key: Array, d: int, cfg: ChannelConfig) -> Array:
    """(d,) multiplicative CSI-misalignment factor for the launch path's
    pre-aggregated gradient: per fading block,
    ``1 + sigma_e / sqrt(N) eps_b`` — the aggregate of N independent
    per-client misalignments.  ``csi_err = 0`` returns exact ones."""
    if cfg.csi_err <= 0.0:
        return jnp.ones((d,), jnp.float32)
    nb = n_blocks(d, cfg)
    eps = jax.random.normal(key, (nb,), jnp.float32)
    scale = cfg.csi_err / math.sqrt(cfg.n_clients)
    return jnp.repeat(1.0 + jnp.float32(scale) * eps, cfg.block)[:d]
