"""Empirical estimation of the smoothness constants of paper Table I.

Three quantities, estimated by sampling perturbation pairs around a model:

* ``L_tilde^2`` — the *conventional* per-client smoothness
  ``max_n ||∇f_n(w) − ∇f_n(v)||² / ||w − v||²`` (Assumption of [39], [40]).
* ``L_g^2``     — global smoothness, Assumption 1:
  ``||∇f(w) − ∇f(v)||² / ||w − v||²``.
* ``L_h^2``     — heterogeneity-driven pseudo-Lipschitz constant,
  Assumption 2: ``||(1/N)Σ_n ∇f_n(w_n) − ∇f(w̄)||² / ((1/N)Σ_n ||w_n − w̄||²)``.

Estimates are suprema over sampled pairs, as in the paper's empirical table.
"""

from __future__ import annotations

from typing import Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

Array = jax.Array
Params = object  # pytree
GradFn = Callable[[Params, int], Params]  # (params, client_id) -> grad pytree


def _flat(tree) -> Array:
    return ravel_pytree(tree)[0]


def estimate_constants(key: Array, params: Params, grad_fn: GradFn,
                       n_clients: int, n_pairs: int = 8,
                       perturb_scale: float = 0.05) -> Dict[str, float]:
    """Estimate (L_tilde^2, L_g^2, L_h^2) around ``params``.

    ``grad_fn(params, n)`` must return client ``n``'s full-batch local
    gradient; the global gradient is the client average (Eq. 1).
    """
    flat0, unravel = ravel_pytree(params)
    d = flat0.shape[0]

    def grads_all(flat_w: Array) -> Array:
        w = unravel(flat_w)
        return jnp.stack([_flat(grad_fn(w, n)) for n in range(n_clients)])

    l_tilde2 = 0.0
    l_g2 = 0.0
    l_h2 = 0.0
    for i in range(n_pairs):
        key, k1, k2 = jax.random.split(key, 3)
        delta = perturb_scale * jax.random.normal(k1, (d,))
        w_a, w_b = flat0, flat0 + delta
        ga, gb = grads_all(w_a), grads_all(w_b)               # (N, d)
        dn2 = float(jnp.sum(delta**2))
        # conventional per-client constant
        per_client = jnp.sum((ga - gb) ** 2, axis=1) / dn2
        l_tilde2 = max(l_tilde2, float(per_client.max()))
        # global constant (Assumption 1)
        l_g2 = max(l_g2, float(jnp.sum((ga.mean(0) - gb.mean(0)) ** 2) / dn2))
        # heterogeneity constant (Assumption 2): per-client models w_n
        noise = perturb_scale * jax.random.normal(k2, (n_clients, d))
        w_n = flat0[None, :] + noise
        w_bar = w_n.mean(axis=0)
        g_mix = jnp.stack([_flat(grad_fn(unravel(w_n[n]), n))
                           for n in range(n_clients)]).mean(axis=0)
        g_bar = jnp.stack([_flat(grad_fn(unravel(w_bar), n))
                           for n in range(n_clients)]).mean(axis=0)
        denom = float(jnp.mean(jnp.sum((w_n - w_bar[None, :]) ** 2, axis=1)))
        l_h2 = max(l_h2, float(jnp.sum((g_mix - g_bar) ** 2)) / max(denom, 1e-12))
    return {"L_tilde2": l_tilde2, "L_g2": l_g2, "L_h2": l_h2}
