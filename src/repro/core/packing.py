"""Packed server state: the whole parameter pytree as one flat buffer.

The production server phase historically ran Eq. (8)-(11) leaf by leaf:
~100+ quantile estimations and ``fairk_update`` launches per step, each an
extra HBM round-trip, with per-leaf thresholds that skew the global FAIR-k
budget toward small leaves (a 256-element norm vector gets the same rho as
the embedding table).  ``PackedLayout`` lays every leaf into ONE contiguous
lane-aligned flat buffer per server-state dtype (g f32 / g_prev bf16 / age
int8 share the same offsets), so the server phase becomes a single fused
pass over the entire model with globally consistent (theta_M, theta_A).

Layout.  Each leaf occupies ``[offset, offset + size)`` with ``pad`` dead
coordinates after it so the next leaf starts lane-aligned (multiple of
``lane``, default 256 — the fused kernel's minimum tile).  The block table
is static Python data (built from abstract shapes at trace time), so
pack/unpack lower to reshapes + concatenate / static slices — no gathers.

Padding protocol.  Pad coordinates carry ``g = 0`` and ``age = PAD_AGE``
(= -1, int8-safe).  Real ages are always >= 0, so ``age < 0`` identifies
padding everywhere downstream:

* the fused kernel (``kernels.fairk_update``) refuses to select pad
  coordinates and leaves their age at the sentinel (round-trip stable),
* threshold estimation samples only valid coordinates
  (``PackedLayout.sample_ids`` — pad zeros would bias theta_M low),
* ``n_selected`` statistics count only valid coordinates (selected
  coordinates are exactly the ``age' == 0`` ones, and padding can never
  reach age 0).

Warm-start thresholds.  ``ThresholdState`` carries last round's
(theta_M, theta_A, n_sel_m, n_sel); on steady-state rounds the engine
multiplicatively corrects the carried thresholds toward the budget instead
of re-estimating quantiles (see ``warm_corrected_thresholds``), skipping
the strided-sample quantile pass entirely.
"""

from __future__ import annotations

import dataclasses
from math import prod
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# Age sentinel marking pad coordinates.  Real AoU values are >= 0; -1 fits
# int8 server state and survives the f32 round-trip through the kernel.
PAD_AGE = -1.0

# Staleness clip applied by EVERY age update (the fused kernel, its ref
# oracle, core.aou, the engine's masked merge and the sweep lanes).  The
# int8 server state stores ages directly, so any increment past 127 would
# wrap NEGATIVE and collide with the PAD_AGE sentinel — corrupting both
# pad detection and the unit-bin age histogram.  120 leaves headroom for
# async lag shifts (``shift_selected_age``) to add a few rounds on top of
# an already-capped age without ever reaching the int8 edge.
AGE_CAP = 120.0

LANE = 256          # minimum alignment: the fused kernel's 1-D tile quantum

# trace-time counters: how many pack / unpack tree copies a program traces.
# The persisted-server-state smoke (benchmarks/packed_bench.py --smoke)
# asserts a steady-state round packs exactly ONE tree (the fresh grads) and
# never re-packs g_prev / age from trees — the buffers persist flat.
PACK_CALLS = 0
UNPACK_CALLS = 0

# trace-time counter: how many full read passes over the packed gradient
# buffer a program traces.  Incremented by every primitive that streams the
# whole (or a strided sample of the) gradient buffer from HBM: the fused
# ``fairk_update`` launches (kernels/ops.py), the sampled-quantile /
# order-statistic threshold estimators (core/engine.py) and the legacy
# two-pass count accounting.  The fused-statistics smoke
# (``packed_bench --smoke``) asserts a steady-state round traces exactly
# ONE such read (the kernel itself) vs 3 on the pre-fused path.
G_READS = 0


@dataclasses.dataclass(frozen=True)
class BlockEntry:
    """One leaf's slot in the packed buffer (static metadata)."""
    index: int                  # position in the flattened leaf list
    offset: int                 # start in the packed buffer (lane-aligned)
    size: int                   # number of real coordinates
    pad: int                    # dead coordinates after the leaf
    shape: Tuple[int, ...]
    dtype: Any


class PackedLayout:
    """Static packed layout for a pytree of arrays.

    Construct once from abstract (or concrete) leaves; all methods are pure
    functions of static metadata plus their array arguments, so they are
    jit/shard_map-safe and build-once-per-trace is free.
    """

    def __init__(self, treedef, entries: List[BlockEntry], lane: int = LANE):
        self.treedef = treedef
        self.table: Tuple[BlockEntry, ...] = tuple(entries)
        self.lane = lane
        last = entries[-1] if entries else None
        self.d_packed = (last.offset + last.size + last.pad) if last else 0
        self.d_valid = sum(e.size for e in entries)
        self.n_leaves = len(entries)

    # -- construction -------------------------------------------------------

    @classmethod
    def from_tree(cls, tree: Any, lane: int = LANE) -> "PackedLayout":
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        entries, offset = [], 0
        for i, leaf in enumerate(leaves):
            size = int(prod(leaf.shape)) if leaf.shape else 1
            padded = -(-size // lane) * lane
            entries.append(BlockEntry(i, offset, size, padded - size,
                                      tuple(leaf.shape),
                                      jnp.dtype(leaf.dtype)))
            offset += padded
        return cls(treedef, entries, lane)

    # -- pack / unpack ------------------------------------------------------

    def pack(self, tree: Any, dtype=jnp.float32, fill: float = 0.0) -> Array:
        """Tree -> (d_packed,) flat buffer: ONE concatenate over reshaped
        leaves with constant fill segments interleaved at the pad slots
        (measured ~6x faster than per-leaf ``jnp.pad`` on CPU XLA — one
        write pass over the buffer either way, but pad lowers poorly)."""
        global PACK_CALLS
        PACK_CALLS += 1
        leaves = self.treedef.flatten_up_to(tree)
        parts = []
        for e, leaf in zip(self.table, leaves):
            parts.append(jnp.asarray(leaf).reshape(-1).astype(dtype))
            if e.pad:
                parts.append(jnp.full((e.pad,), fill, dtype))
        if len(parts) == 1:
            return parts[0]
        return jnp.concatenate(parts)

    def pack_age(self, tree: Any, dtype=jnp.float32) -> Array:
        """Age tree -> flat buffer with PAD_AGE sentinel in the pads."""
        return self.pack(tree, dtype=dtype, fill=PAD_AGE)

    def unpack(self, flat: Array, cast: bool = True) -> Any:
        """(d_packed,) buffer -> tree of original shapes (static slices)."""
        global UNPACK_CALLS
        UNPACK_CALLS += 1
        out = []
        for e in self.table:
            leaf = jax.lax.slice(flat, (e.offset,), (e.offset + e.size,))
            leaf = leaf.reshape(e.shape)
            out.append(leaf.astype(e.dtype) if cast else leaf)
        return jax.tree_util.tree_unflatten(self.treedef, out)

    # -- pad bookkeeping ----------------------------------------------------

    def valid_mask(self) -> Array:
        """(d_packed,) bool — True on real coordinates (static constant)."""
        mask = np.zeros((self.d_packed,), bool)
        for e in self.table:
            mask[e.offset:e.offset + e.size] = True
        return jnp.asarray(mask)

    def init_age(self, dtype=jnp.int8) -> Array:
        """Fresh age buffer: 0 on valid coordinates, PAD_AGE in the pads."""
        age = np.full((self.d_packed,), PAD_AGE, np.float32)
        for e in self.table:
            age[e.offset:e.offset + e.size] = 0.0
        return jnp.asarray(age).astype(dtype)

    def sample_ids(self, cap: int) -> np.ndarray:
        """Packed positions of an even strided sample over VALID coordinates
        only (static int32).  This is the pad-excluding replacement for
        ``engine.strided_sample`` on packed buffers: pad zeros in the sample
        would bias theta_M low and overshoot the budget."""
        valid = np.concatenate(
            [np.arange(e.offset, e.offset + e.size, dtype=np.int64)
             for e in self.table]) if self.table else np.zeros(0, np.int64)
        stride = max(1, self.d_valid // max(1, cap))
        return valid[::stride].astype(np.int32)


# ---------------------------------------------------------------------------
# in-kernel selection statistics: histogram spec
# ---------------------------------------------------------------------------

# The fused kernel (kernels/fairk_update.py) emits, besides the selected
# counts, two small histograms per round — the raw material for
# re-estimating (θ_M, θ_A) WITHOUT re-reading the gradient buffer:
#
#   * magnitude histogram — |score| on quarter-octave log2 bins: bin b
#     covers log2|x| in [(b + MAG_LO_OCT·MAG_BINS_PER_OCT)/MAG_BINS_PER_OCT
#     + ...), i.e. 2^-24 .. 2^8 over 128 bins.  Out-of-range magnitudes
#     clamp to the end bins.
#   * age histogram — the POST-update AoU on unit integer bins (ages are
#     integers ≤ AGE_CAP = 120 < 128, so the binning is exact).  The
#     post-update vector IS the next round's input age distribution, so a
#     θ_A estimated from it has no staleness lag; within an integer atom
#     the index jitter is sub-uniform, which is what the fractional
#     interpolation in ``hist_thresholds`` assumes.
#
# Histograms are computed on a deterministic strided sample (every
# ``hist_stride(d)``-th coordinate — the same discipline the quantile
# bootstrap uses via ``strided_sample``) with pad coordinates carrying
# weight zero.  The stride is a power of two ≤ LANE so it divides every
# lane-aligned kernel block: the per-block partial histograms then sum
# bit-exactly to the single-pass histogram the ref oracle computes.
STATS_MAG_BINS = 128
STATS_AGE_BINS = 128
MAG_BINS_PER_OCT = 4.0
MAG_LO_OCT = -24.0           # bin 0 lower edge = 2^MAG_LO_OCT
STATS_SAMPLE_CAP = 1 << 15   # target histogram sample count


def hist_stride(d: int) -> int:
    """Power-of-two sample stride ≤ LANE for a d-coordinate buffer."""
    stride = 1
    while stride < LANE and d // (2 * stride) >= STATS_SAMPLE_CAP:
        stride *= 2
    return stride


def mag_bin(mag: Array) -> Array:
    """f32 magnitude -> f32 bin index in [0, STATS_MAG_BINS) (clip before
    any integer cast: log2(0) = -inf must land in bin 0, not wrap)."""
    raw = jnp.floor(MAG_BINS_PER_OCT * jnp.log2(mag)
                    - MAG_BINS_PER_OCT * MAG_LO_OCT)
    return jnp.clip(raw, 0.0, STATS_MAG_BINS - 1)


def age_bin(age: Array) -> Array:
    """f32 age -> f32 unit bin index (exact for integer ages ≤ AGE_CAP)."""
    return jnp.clip(jnp.floor(age), 0.0, STATS_AGE_BINS - 1)


# ---------------------------------------------------------------------------
# async-aggregation age bookkeeping (double-buffered server rounds)
# ---------------------------------------------------------------------------

def shift_selected_age(age_next: Array, lag) -> Array:
    """Record async delivery lag on the just-selected coordinates.

    In async-aggregation mode a selected coordinate's contribution lands
    ``lag`` rounds after it was produced, so instead of resetting to 0 its
    post-update age is ``lag`` — i.e. the carried age buffer remembers the
    staleness the deferred uplink added.  Must be applied to the POST-merge
    age vector (where selected coordinates are exactly the ``age == 0``
    ones): unselected ages are untouched, pads (age < 0) pass through, and
    the result stays clipped at ``AGE_CAP``.  ``lag = 0`` is the identity.
    """
    a = jnp.asarray(age_next, jnp.float32)
    sel = (a == 0.0).astype(jnp.float32)
    return jnp.minimum(a + sel * jnp.asarray(lag, jnp.float32), AGE_CAP)


def shift_age_hist(age_hist: Array, lag: int) -> Array:
    """The histogram counterpart of ``shift_selected_age``: move the
    selected (bin 0) mass to bin ``lag``.  Keeps the carried/emitted age
    histogram consistent with the shifted age buffer, so θ_A re-estimation
    and the budget controller see the true post-update distribution.
    ``lag = 0`` is an exact identity."""
    if lag <= 0:
        return age_hist
    h = jnp.asarray(age_hist, jnp.float32)
    b = min(int(lag), STATS_AGE_BINS - 1)
    return h.at[b].add(h[0]).at[0].set(0.0)


def advance_age_hist(age_hist: Array) -> Array:
    """Shift EVERY bin of an age histogram up by one — the exact
    post-update histogram of a round on which no coordinate was refreshed
    (total channel outage / realised participation 0: all valid ages
    advance together).  Top-bin mass folds onto itself, mirroring the
    ``age_bin`` clip at ``STATS_AGE_BINS - 1``."""
    h = jnp.asarray(age_hist, jnp.float32)
    return jnp.zeros_like(h).at[1:].set(h[:-1]).at[-1].add(h[-1])


def _tail_cut(hist: Array, target: Array) -> Tuple[Array, Array]:
    """Where the top-``target`` mass of ``hist`` ends: (bin index int32,
    fraction of that bin taken from its top, in [0, 1])."""
    suffix = jnp.cumsum(hist[::-1])[::-1]                  # S_b = Σ_{b'>=b}
    suffix_next = jnp.concatenate([suffix[1:],
                                   jnp.zeros((1,), jnp.float32)])
    # S is non-increasing: S_b >= target holds exactly for b <= b*
    bstar = jnp.clip(jnp.sum((suffix >= target).astype(jnp.float32)) - 1.0,
                     0.0, hist.shape[0] - 1).astype(jnp.int32)
    need = target - suffix_next[bstar]
    frac = jnp.clip(need / jnp.maximum(hist[bstar], 1.0), 0.0, 1.0)
    return bstar, frac


def _hist_theta_m(mag_hist: Array, rho_m) -> Array:
    """Finite-stage θ_M from the magnitude histogram (log-linear
    interpolation inside the cut bin; empty histogram -> 0)."""
    total_m = jnp.sum(mag_hist)
    b, frac = _tail_cut(mag_hist, rho_m * total_m)
    log2_lo = (b.astype(jnp.float32)
               + MAG_LO_OCT * MAG_BINS_PER_OCT) / MAG_BINS_PER_OCT
    return jnp.where(total_m > 0.0,
                     jnp.exp2(log2_lo + (1.0 - frac) / MAG_BINS_PER_OCT),
                     0.0).astype(jnp.float32)


def _hist_theta_a(age_hist: Array, rho_a) -> Array:
    """Finite-stage θ_A from the age histogram (linear inside the unit
    atom; empty histogram -> 0)."""
    total_a = jnp.sum(age_hist)
    b, frac = _tail_cut(age_hist, rho_a * total_a)
    return jnp.where(total_a > 0.0, b.astype(jnp.float32) + 1.0 - frac,
                     0.0).astype(jnp.float32)


def hist_thresholds(mag_hist: Array, age_hist: Array, *, rho: float,
                    k_m_frac) -> Tuple[Array, Array]:
    """(θ_M, θ_A) from the in-kernel histograms — the re-estimation path
    that replaces the sampled-quantile bootstrap (zero reads of g).

    Mirrors ``engine.thresholds_from_samples``: θ_M cuts the top
    ρ·k_m_frac of the magnitude mass (log-linear interpolation inside the
    cut bin), θ_A the top ρ_A = (ρ − ρ_M)/(1 − ρ_M) of the age mass
    (linear within the unit atom — the sub-unit index jitter is what the
    threshold compares against).  An EMPTY histogram (the very first
    round: nothing has been emitted yet) yields θ = 0 for an active stage
    — a full-refresh round that transmits everything once, after which the
    realised histogram takes over.  Degenerate stage budgets give θ = inf
    exactly like the sampled path.

    ``k_m_frac`` may be a *traced* scalar (the adaptive budget
    controller): the same estimator with the degenerate-stage
    short-circuits as ``where``s on data."""
    rho_m = rho * k_m_frac
    if isinstance(rho_m, (int, float)):
        rho_a = (rho - rho_m) / max(1.0 - rho_m, 1e-6)
        theta_m = (_hist_theta_m(mag_hist, rho_m) if rho_m > 0.0
                   else jnp.float32(jnp.inf))
        theta_a = (_hist_theta_a(age_hist, rho_a) if rho_a > 0.0
                   else jnp.float32(jnp.inf))
        return theta_m, theta_a
    rho_m = jnp.asarray(rho_m, jnp.float32)
    rho_a = (rho - rho_m) / jnp.maximum(1.0 - rho_m, 1e-6)
    theta_m = jnp.where(rho_m > 0.0, _hist_theta_m(mag_hist, rho_m),
                        jnp.inf).astype(jnp.float32)
    theta_a = jnp.where(rho_a > 0.0, _hist_theta_a(age_hist, rho_a),
                        jnp.inf).astype(jnp.float32)
    return theta_m, theta_a


# ---------------------------------------------------------------------------
# warm-start threshold state
# ---------------------------------------------------------------------------

# dict-pytree threshold state: carried across rounds by trainers.
#   theta_m / theta_a : thresholds used last round
#   n_sel_m / n_sel   : last round's magnitude-stage / total selected counts
#                       (emitted by the fused kernel on the fused-stats
#                       path; a separate masked pass on the legacy path)
#   init              : 0.0 until the first round has run
#   streak            : consecutive rounds whose count tracked the budget —
#                       the engine only trusts warm thresholds after a few
#                       (cold-start cohorts fail the streak and fall back
#                       to re-estimation: sampled quantiles on the legacy
#                       path, the carried histograms on the fused path)
#   mag_hist/age_hist : last round's in-kernel histograms (zeros until a
#                       fused-stats round has emitted them)
def init_threshold_state() -> Dict[str, Array]:
    z = jnp.float32(0.0)
    return {"theta_m": z, "theta_a": z, "n_sel_m": z, "n_sel": z,
            "init": z, "streak": z,
            "mag_hist": jnp.zeros((STATS_MAG_BINS,), jnp.float32),
            "age_hist": jnp.zeros((STATS_AGE_BINS,), jnp.float32)}


THRESHOLD_STATE_FIELDS = ("theta_m", "theta_a", "n_sel_m", "n_sel",
                          "init", "streak")
THRESHOLD_STATE_SIZE = (len(THRESHOLD_STATE_FIELDS)
                        + STATS_MAG_BINS + STATS_AGE_BINS)


def threshold_state_to_vec(ts: Dict[str, Array]) -> Array:
    """(THRESHOLD_STATE_SIZE,) f32 encoding — the six scalars followed by
    the two histograms — for server-state dicts that want one array."""
    scalars = jnp.stack([ts[f] for f in THRESHOLD_STATE_FIELDS])
    return jnp.concatenate([
        scalars, ts["mag_hist"], ts["age_hist"]]).astype(jnp.float32)


def threshold_state_from_vec(vec: Array) -> Dict[str, Array]:
    ns = len(THRESHOLD_STATE_FIELDS)
    ts = {f: vec[i] for i, f in enumerate(THRESHOLD_STATE_FIELDS)}
    if vec.shape[0] >= THRESHOLD_STATE_SIZE:       # scalar-only legacy vecs
        ts["mag_hist"] = vec[ns:ns + STATS_MAG_BINS]
        ts["age_hist"] = vec[ns + STATS_MAG_BINS:THRESHOLD_STATE_SIZE]
    else:
        ts["mag_hist"] = jnp.zeros((STATS_MAG_BINS,), jnp.float32)
        ts["age_hist"] = jnp.zeros((STATS_AGE_BINS,), jnp.float32)
    return ts


# ---------------------------------------------------------------------------
# layout (de)serialisation — checkpointing the packed server buffers
# ---------------------------------------------------------------------------

def layout_to_meta(layout: "PackedLayout") -> Dict[str, Any]:
    """JSON-serialisable description of the block table (no treedef — the
    restoring process rebuilds the layout from its own param tree and
    verifies compatibility with ``layout_matches``)."""
    return {
        "lane": layout.lane,
        "d_packed": layout.d_packed,
        "d_valid": layout.d_valid,
        "entries": [[e.offset, e.size, e.pad, list(e.shape),
                     str(np.dtype(e.dtype))] for e in layout.table],
    }


def layout_matches(layout: "PackedLayout", meta: Dict[str, Any]) -> bool:
    """True when ``layout`` describes the same buffer geometry as a saved
    ``layout_to_meta`` dict (offsets, sizes, pads, shapes and dtypes)."""
    if (layout.lane != meta["lane"] or layout.d_packed != meta["d_packed"]
            or layout.d_valid != meta["d_valid"]
            or len(layout.table) != len(meta["entries"])):
        return False
    for e, m in zip(layout.table, meta["entries"]):
        if [e.offset, e.size, e.pad, list(e.shape),
                str(np.dtype(e.dtype))] != m:
            return False
    return True


def warm_corrected_thresholds(ts: Dict[str, Array], *, k: int, k_m,
                              alpha: float = 0.5, clip: float = 2.0,
                              max_age_step: float = 0.5
                              ) -> Tuple[Array, Array]:
    """Budget-tracking correction of carried thresholds (one per stage).

    Stage M (multiplicative): |g| is a smooth, scale-free distribution, so
    if last round's magnitude stage selected n_m against a budget of k_m the
    threshold moves by ``(n_m / k_m) ** alpha`` (clipped to [1/clip, clip]):
    overshoot raises theta_M (selects less), undershoot lowers it.

    Stage A (additive, bounded): integer ages make the age distribution a
    staircase — atoms of O(k_a) coordinates one age unit apart, interpolated
    only by the sub-unit index jitter.  A multiplicative step of a few
    percent at theta_A ~ 10 crosses a WHOLE atom and overshoots the budget
    by thousands (which resets the atom, re-synchronizes the distribution,
    and sustains a limit cycle).  Instead theta_A moves additively by at
    most ``max_age_step`` (< 1 atom) per round, scaled by the relative
    budget error with the stationary slope estimate of ~k_a coordinates per
    age unit.  In steady state the age histogram is stationary (inflow at
    the top equals the k_a eaten), so the fixed point is a CONSTANT
    theta_A; cold-start cohort transients exceed what a bounded step can
    track and are handled by the engine's trust region (quantile
    re-bootstrap), which is exactly the fallback the sampled path provides.

    Remark-1 degenerate stages (k_m = 0 or k_a = 0 => theta = inf) pass
    through untouched.

    ``k_m`` may be a *traced* value (the adaptive budget controller):
    the identical corrections with the degenerate-stage branches as
    ``where``s on data.
    """
    if isinstance(k_m, (int, np.integer)):
        k_a = k - k_m
        if k_m > 0:
            f_m = jnp.clip((jnp.maximum(ts["n_sel_m"], 1.0) / k_m) ** alpha,
                           1.0 / clip, clip)
            theta_m = jnp.where(jnp.isinf(ts["theta_m"]), ts["theta_m"],
                                ts["theta_m"] * f_m)
        else:
            theta_m = jnp.float32(jnp.inf)
        if k_a > 0:
            n_a = ts["n_sel"] - ts["n_sel_m"]
            step = jnp.clip((n_a - k_a) / k_a, -1.0, 1.0) * max_age_step
            theta_a = jnp.where(jnp.isinf(ts["theta_a"]), ts["theta_a"],
                                ts["theta_a"] + step)
        else:
            theta_a = jnp.float32(jnp.inf)
        return jnp.asarray(theta_m, jnp.float32), jnp.asarray(theta_a,
                                                              jnp.float32)
    k_m_f = jnp.asarray(k_m, jnp.float32)
    k_a_f = k - k_m_f
    f_m = jnp.clip((jnp.maximum(ts["n_sel_m"], 1.0)
                    / jnp.maximum(k_m_f, 1.0)) ** alpha, 1.0 / clip, clip)
    theta_m = jnp.where(
        k_m_f > 0.0,
        jnp.where(jnp.isinf(ts["theta_m"]), ts["theta_m"],
                  ts["theta_m"] * f_m),
        jnp.inf)
    n_a = ts["n_sel"] - ts["n_sel_m"]
    step = jnp.clip((n_a - k_a_f) / jnp.maximum(k_a_f, 1.0),
                    -1.0, 1.0) * max_age_step
    theta_a = jnp.where(
        k_a_f > 0.0,
        jnp.where(jnp.isinf(ts["theta_a"]), ts["theta_a"],
                  ts["theta_a"] + step),
        jnp.inf)
    return (jnp.asarray(theta_m, jnp.float32),
            jnp.asarray(theta_a, jnp.float32))
