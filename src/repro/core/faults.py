"""In-graph fault injection + graceful degradation (DESIGN.md §14).

The paper's convergence result (Theorem 1) assumes fixed participation
and well-behaved iid channel noise; at population scale dropout waves,
deep fades, corrupted uplinks and crashed hosts are the steady state.
This module makes churn a TRACED part of the round — every fault channel
is an elementwise function of (state, key), so ``FLConfig.scan_rounds``
and the vmapped sweep grid inherit it with zero recompiles — and FAIR-k's
staleness machinery absorbs the damage: a missed or masked update is just
"one more round of age" (the age-aware partial-update line, PAPERS.md
arXiv:2504.01357 / 2602.02469).

Fault channels
--------------
* **client dropout** — per-client availability as a two-state
  Gilbert-Elliott Markov process (good <-> bad); ``burst`` sets the mean
  bad-state dwell so outages can be bursty, the default is the iid
  Bernoulli special case.  The chain algebra mirrors ``core.markov``'s
  treatment: the stationary bad-state mass equals ``dropout``.
* **deep-fade erasures** — block-granular erasure of the *aggregated*
  signal (a faded OFDM symbol group takes out its whole block of
  coordinates, paper Sec. II channel model).  Erased coordinates are
  semantically "unsent": the sanitize stage of ``engine.select_and_merge``
  keeps them out of selection, their mass stays in the EF residual, their
  age keeps climbing.
* **NaN/Inf corruption** — per-coordinate non-finite contamination of the
  fresh gradient (a crashed host's garbage uplink).  Same degradation
  semantics as an erasure; never silently zeroed.

The realized participation count ``N_t`` is traced, never a Python int;
``participation_scale`` is the single guarded 1/N helper (``N_t == 0``
degrades the round to a bit-exact age-increment-only no-op).

Divergence watchdog
-------------------
``watchdog_step`` is the pure state machine behind ``fl/trainer.py``'s
guard: EMA'd loss / update-norm baselines, a trip on any non-finite or
``spike``x-EMA observation, a cooldown window that tightens ``k_m`` (a
smaller, more magnitude-selective budget while recovering).  The rollback
itself is a ``tree_select`` of the live state against an in-graph shadow
snapshot — the caller owns the snapshot cadence.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import channel as channel_mod

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Per-round fault-channel rates.  All-zero (the default) is the
    off mode: no fault state is carried, no fault ops are traced, and
    every trace is bit-exact with the fault-free build."""
    dropout: float = 0.0        # stationary per-client unavailability
    burst: Optional[float] = None  # mean bad-state dwell in rounds
                                # (Gilbert-Elliott); None = iid Bernoulli
    fade: float = 0.0           # per-block deep-fade erasure probability
                                # on the aggregated signal
    fade_block: int = 128       # coordinates per fade block (one OFDM
                                # symbol group's worth)
    nan_rate: float = 0.0       # per-coordinate non-finite corruption
                                # probability on the fresh gradient

    def __post_init__(self):
        if not 0.0 <= self.dropout < 1.0:
            raise ValueError(f"dropout must be in [0, 1), got {self.dropout}")
        if not 0.0 <= self.fade < 1.0:
            raise ValueError(f"fade must be in [0, 1), got {self.fade}")
        if not 0.0 <= self.nan_rate < 1.0:
            raise ValueError(
                f"nan_rate must be in [0, 1), got {self.nan_rate}")
        if self.burst is not None and self.burst < 1.0:
            raise ValueError(f"burst must be >= 1 round, got {self.burst}")
        if self.burst is not None and self.dropout > 0.0:
            # stationarity pins p_gb = dropout/(1-dropout) * (1/burst); a
            # dwell shorter than the bad/good odds would need p_gb > 1 —
            # ge_probs used to clamp silently, leaving pi_bad < dropout
            need = self.dropout / (1.0 - self.dropout)
            if self.burst < need:
                raise ValueError(
                    f"infeasible Gilbert-Elliott chain: dropout="
                    f"{self.dropout} needs burst >= dropout/(1-dropout) = "
                    f"{need:.3f}, got {self.burst} (the good->bad rate "
                    "would exceed 1 and the stationary dropout could not "
                    "be met)")
        if self.fade_block < 1:
            raise ValueError(f"fade_block must be >= 1, got {self.fade_block}")

    @property
    def enabled(self) -> bool:
        return (self.dropout > 0.0 or self.fade > 0.0
                or self.nan_rate > 0.0)

    @property
    def thin(self) -> float:
        """Effective per-round refresh-blocking probability for the
        Lemma-1 thinning model (``markov.thinned_aou_distribution``) and
        the controller setpoint (``BudgetController(..., thin=...)``).

        Dropout barely thins: the OAC superposition re-normalizes over
        the survivors and the selection budget refills from them, so only
        a TOTAL outage (all N clients down at once) blocks a refresh —
        negligible at the configured rates.  The dominant channels are
        the post-aggregation ones that the sanitize stage masks out of
        selection coordinate-by-coordinate: fade erasure + corruption."""
        return min(0.99, self.fade + self.nan_rate)


# ---------------------------------------------------------------------------
# client availability: Gilbert-Elliott two-state chain
# ---------------------------------------------------------------------------

def ge_probs(cfg: FaultConfig) -> Tuple[float, float]:
    """(p_gb, p_bg): good->bad and bad->good transition probabilities.

    Stationarity pins ``pi_bad = p_gb / (p_gb + p_bg) = dropout``;
    ``burst`` pins the mean bad dwell ``1 / p_bg``.  ``burst=None`` is
    the iid special case (next state independent of current state):
    ``p_gb = dropout``, ``p_bg = 1 - dropout``."""
    if cfg.dropout <= 0.0:
        return 0.0, 1.0
    if cfg.burst is None:
        return cfg.dropout, 1.0 - cfg.dropout
    p_bg = 1.0 / cfg.burst
    p_gb = min(1.0, cfg.dropout / (1.0 - cfg.dropout) * p_bg)
    return p_gb, p_bg


def init_avail_state(key: Array, n_clients: int,
                     cfg: FaultConfig) -> Array:
    """(n_clients,) f32 availability drawn from the stationary law
    (1.0 = available).  All-ones when dropout is off."""
    if cfg.dropout <= 0.0:
        return jnp.ones((n_clients,), jnp.float32)
    u = jax.random.uniform(key, (n_clients,))
    return (u >= cfg.dropout).astype(jnp.float32)


def avail_step(avail: Array, key: Array, cfg: FaultConfig) -> Array:
    """One Gilbert-Elliott transition of the availability vector —
    elementwise where-ops only, so it vmaps over populations and scans
    over rounds without recompiling."""
    p_gb, p_bg = ge_probs(cfg)
    u = jax.random.uniform(key, avail.shape)
    good = avail > 0.5
    nxt = jnp.where(good, u >= p_gb, u < p_bg)
    return nxt.astype(jnp.float32)


# ---------------------------------------------------------------------------
# per-round fault channels
# ---------------------------------------------------------------------------

def participation_scale(total: Array, n_t: Array) -> Array:
    """The single guarded 1/N rescale: ``total / N_t`` with a traced
    denominator that may be zero.  ``N_t == 0`` returns exact zeros (the
    round degrades to an age-increment-only no-op) instead of Inf/NaN
    poisoning the merge."""
    n_t = jnp.asarray(n_t, jnp.float32)
    scaled = total / jnp.maximum(n_t, 1.0)
    return jnp.where(n_t > 0.0, scaled, jnp.zeros_like(scaled))


def fade_mask(key: Array, d: int, cfg: FaultConfig) -> Array:
    """(d,) f32 erasure mask (1.0 = erased) at fade-block granularity: a
    deep fade takes out a whole block of ``fade_block`` consecutive
    coordinates of the aggregated signal.  A thin alias over the channel
    module's block-erasure primitive, so faults and channel truncation
    share one erasure code path — same draw (``uniform(nb) < p`` + block
    repeat), bit-exact with the pre-channel traces."""
    if cfg.fade <= 0.0:
        return jnp.zeros((d,), jnp.float32)
    return channel_mod.block_erase_mask(key, d, cfg.fade, cfg.fade_block)


def corrupt(g: Array, key: Array, cfg: FaultConfig) -> Array:
    """Non-finite contamination of the fresh gradient: each coordinate
    independently becomes NaN or +/-Inf with probability ``nan_rate``
    (half NaN, a quarter each signed Inf — all three species must
    survive the sanitize stage)."""
    if cfg.nan_rate <= 0.0:
        return g
    u = jax.random.uniform(key, g.shape)
    garbage = jnp.where(u < 0.5 * cfg.nan_rate, jnp.nan,
                        jnp.where(u < 0.75 * cfg.nan_rate, jnp.inf,
                                  -jnp.inf))
    return jnp.where(u < cfg.nan_rate, garbage.astype(g.dtype), g)


def erase_with_outage(erase: Array, n_t: Array) -> Array:
    """Fold a total-outage round into the erasure mask: when the realized
    participation ``N_t`` is zero there IS no aggregate, so every
    coordinate is erased and the sanitized merge degrades to the exact
    age-increment-only no-op round."""
    out = (jnp.asarray(n_t, jnp.float32) <= 0.0).astype(jnp.float32)
    return jnp.maximum(erase, out)


# ---------------------------------------------------------------------------
# rollback + divergence watchdog
# ---------------------------------------------------------------------------

def tree_select(pred: Array, on_true: Any, on_false: Any) -> Any:
    """Elementwise ``where(pred, a, b)`` over matching pytrees — the
    in-graph rollback primitive (no host sync, no recompile)."""
    return jax.tree.map(
        lambda a, b: jnp.where(pred, a, b.astype(a.dtype)
                               if hasattr(b, "dtype") else b),
        on_true, on_false)


@dataclasses.dataclass(frozen=True)
class WatchdogConfig:
    """Divergence-watchdog settings (EMA'd loss / update-norm guard)."""
    spike: float = 2.0     # trip when an observation exceeds spike x EMA
    ema: float = 0.9       # baseline EMA decay
    warmup: int = 5        # observations before the spike guard arms
                           # (non-finite trips immediately)
    cooldown: int = 10     # rounds of tightened k_m after a trip
    tighten: float = 0.5   # k_m_frac multiplier during cooldown: spend
                           # the budget on the magnitude stage while the
                           # trajectory recovers

    def __post_init__(self):
        if self.spike <= 1.0:
            raise ValueError(f"spike must be > 1, got {self.spike}")
        if not 0.0 < self.tighten <= 1.0:
            raise ValueError(f"tighten must be in (0, 1], got {self.tighten}")


WATCHDOG_FIELDS = ("ema_loss", "ema_norm", "obs", "cooldown", "trips")


def init_watchdog_state() -> Dict[str, Array]:
    z = jnp.float32(0.0)
    return {f: z for f in WATCHDOG_FIELDS}


def watchdog_step(cfg: WatchdogConfig, state: Dict[str, Array],
                  loss: Array, unorm: Array
                  ) -> Tuple[Dict[str, Array], Array, Array]:
    """One watchdog transition.  Returns ``(state', trip, k_scale)``:

    * ``trip`` — bool scalar; the caller rolls (params, server state)
      back to its shadow snapshot via ``tree_select(trip, snap, live)``;
    * ``k_scale`` — ``tighten`` while the cooldown window is open, else
      1.0; multiply into the traced ``k_m_frac``.

    A trip fires on any non-finite observation (immediately, even during
    warmup) or, once ``warmup`` healthy observations have seeded the
    baselines, on an observation above ``spike`` x its EMA.  Tripped
    observations never enter the EMA — the spike must not poison the
    baseline it is judged against — and do not advance the warmup
    counter."""
    loss = jnp.asarray(loss, jnp.float32)
    unorm = jnp.asarray(unorm, jnp.float32)
    finite = jnp.isfinite(loss) & jnp.isfinite(unorm)
    armed = state["obs"] >= float(cfg.warmup)
    spiked = ((loss > cfg.spike * state["ema_loss"])
              | (unorm > cfg.spike * state["ema_norm"]))
    trip = ~finite | (armed & spiked)
    first = state["obs"] == 0.0
    upd = lambda ema, x: jnp.where(
        trip, ema, jnp.where(first, x, cfg.ema * ema + (1.0 - cfg.ema) * x))
    cool = jnp.where(trip, jnp.float32(cfg.cooldown),
                     jnp.maximum(state["cooldown"] - 1.0, 0.0))
    new = {"ema_loss": upd(state["ema_loss"], loss),
           "ema_norm": upd(state["ema_norm"], unorm),
           "obs": jnp.where(trip, state["obs"], state["obs"] + 1.0),
           "cooldown": cool,
           "trips": state["trips"] + trip.astype(jnp.float32)}
    k_scale = jnp.where(cool > 0.0, jnp.float32(cfg.tighten),
                        jnp.float32(1.0))
    return new, trip, k_scale
