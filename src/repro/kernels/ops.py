"""Dispatching wrappers for the Pallas kernels.

On TPU the real ``pl.pallas_call`` kernels run; elsewhere (this CPU
container) the kernels execute in ``interpret=True`` mode when explicitly
requested (tests) or fall through to the pure-jnp oracles in ``ref.py``
(fast XLA path, used by benchmarks and the dry-run)."""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.packing import PAD_AGE
from repro.kernels import ref
from repro.kernels.aou_merge import aou_merge_pallas
from repro.kernels.block_topk import block_topk_pallas
from repro.kernels.fairk_update import (STATS_AGE_OFF, STATS_MAG_OFF,
                                        STATS_N_SEL, STATS_N_SEL_M,
                                        fairk_ef_update_pallas,
                                        fairk_stats_update_pallas)
from repro.kernels.sign_mv import sign_from_energy_pallas, sign_mv_pallas

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def block_topk(x: Array, block_size: int = 4096, m: int = 16,
               mode: Optional[str] = None) -> Tuple[Array, Array]:
    """mode: None (auto) | "pallas" | "interpret" | "ref"."""
    mode = mode or ("pallas" if _on_tpu() else "ref")
    if mode == "ref":
        return ref.block_topk_ref(x, block_size, m)
    return block_topk_pallas(x, block_size, m, interpret=(mode == "interpret"))


def aou_merge(g_new: Array, g_old: Array, age: Array, mask: Array,
              mode: Optional[str] = None) -> Tuple[Array, Array]:
    mode = mode or ("pallas" if _on_tpu() else "ref")
    if mode == "ref":
        return ref.aou_merge_ref(g_new, g_old, age, mask)
    return aou_merge_pallas(g_new, g_old, age, mask,
                            interpret=(mode == "interpret"))


def sign_mv(votes: Array, noise: Optional[Array] = None,
            mode: Optional[str] = None) -> Tuple[Array, Array]:
    """FSK majority vote over (N, k) one-bit client values ->
    ``(signs, energy)``, both (k,).

    ``noise`` (optional, (k,)) perturbs the superposed vote energy before
    the sign — the Sec. V-B channel on the one-bit uplink.  ``energy`` is
    that (noisy) superposition itself: the one-bit routes score selection
    on |energy| (consensus strength), and emitting it from the same
    reduction removes the second full pass over the (N, k) vote matrix
    callers used to pay."""
    mode = mode or ("pallas" if _on_tpu() else "ref")
    if mode == "ref":
        return ref.sign_mv_ref(votes, noise)
    # largest lane-multiple block <= 2048 that tiles k exactly — a huge
    # non-2048-aligned k (e.g. a whole packed buffer from the one-bit
    # update_phase) must NOT degenerate to a single (n, k) VMEM tile
    n, k = votes.shape
    for block in (2048, 1024, 512, 256, 128):
        if k % block == 0:
            break
    else:
        block = k
    return sign_mv_pallas(votes, noise, block_k=block,
                          interpret=(mode == "interpret"))


def sign_from_energy(energy: Array, noise: Optional[Array] = None,
                     mode: Optional[str] = None) -> Tuple[Array, Array]:
    """Majority stage of ``sign_mv`` for a PRE-REDUCED (k,) vote-energy
    row -> ``(signs, energy')``.

    The streaming client aggregation (fl/trainer.py) folds each client
    chunk's partial vote sum into one (k,) accumulator — the (N, k) vote
    matrix is never materialised — and finishes here: optional channel
    noise on the superposed energy, then the non-coherent sign."""
    mode = mode or ("pallas" if _on_tpu() else "ref")
    if mode == "ref":
        return ref.sign_from_energy_ref(energy, noise)
    k = energy.shape[0]
    for block in (2048, 1024, 512, 256, 128):
        if k % block == 0:
            break
    else:
        block = k
    return sign_from_energy_pallas(energy, noise, block_k=block,
                                   interpret=(mode == "interpret"))


def global_topk_from_candidates(vals: Array, idxs: Array, k: int
                                ) -> Tuple[Array, Array]:
    """Second stage of two-stage top-k: global top-k over the (nb, m)
    candidate pool produced by ``block_topk``.  Exact whenever every block
    contributes <= m of the true top-k."""
    flat_vals = vals.reshape(-1)
    flat_idxs = idxs.reshape(-1)
    top_vals, pos = jax.lax.top_k(flat_vals, k)
    return top_vals, flat_idxs[pos]


def two_stage_topk(x: Array, k: int, block_size: int = 4096,
                   m: Optional[int] = None, mode: Optional[str] = None
                   ) -> Tuple[Array, Array]:
    """Scalable |x| top-k: per-block candidates -> global threshold.

    ``m`` defaults to a pool ~4x oversampled relative to a uniform spread
    of the top-k across blocks (keeps the approximation error negligible;
    exactness is guaranteed when no block holds more than m winners)."""
    nb = x.shape[0] // block_size
    if m is None:
        m = min(block_size, max(4, (4 * k + nb - 1) // nb))
    vals, idxs = block_topk(x, block_size, m, mode=mode)
    return global_topk_from_candidates(vals, idxs, k)


# trace-time counter: how many fused fairk_update passes a program traces.
# The packed-server bench smoke asserts packed == 1 vs per-leaf == n_leaves.
FAIRK_UPDATE_CALLS = 0


def fairk_update(g: Array, g_prev: Array, age: Array, theta_m, theta_a,
                 mode: Optional[str] = None,
                 block_size: int = 65536,
                 sanitize: bool = False) -> Tuple[Array, Array]:
    """Fused threshold-FAIR-k server update (see kernels.fairk_update) —
    the degenerate (no residual, no decoupled fresh) case of
    ``fairk_ef_update`` below; one fused launch either way."""
    g_t, age_out, _ = fairk_ef_update(g, g_prev, age, theta_m, theta_a,
                                      mode=mode, block_size=block_size,
                                      sanitize=sanitize)
    return g_t, age_out


def fairk_ef_update(g: Array, g_prev: Array, age: Array, theta_m, theta_a,
                    residual: Optional[Array] = None,
                    fresh: Optional[Array] = None,
                    mode: Optional[str] = None,
                    block_size: int = 65536,
                    sanitize: bool = False
                    ) -> Tuple[Array, Array, Optional[Array]]:
    """Fused FAIR-k server update, optionally with the residual
    (error-feedback) stage and/or decoupled ``fresh`` values — always ONE
    pass over HBM.

    ``residual``: selection scores ``g + residual`` (unsent mass folds back
    pre-selection) and the updated accumulator ``residual' = score -
    mask * sent`` comes back as the third output (None when no residual).
    ``fresh``: merged fresh values when they differ from the score source
    (the one-bit FSK-MV sign vector from ``sign_mv``).

    Accepts any length: non-block-aligned inputs (e.g. arbitrary parameter
    leaves routed through the SelectionEngine) are padded to the block grid
    (age pad = PAD_AGE sentinel, so padding can never select) and sliced
    back.  Interior pads of packed buffers (core.packing) use the same
    sentinel and pass through untouched (incl. their residual)."""
    global FAIRK_UPDATE_CALLS
    FAIRK_UPDATE_CALLS += 1
    packing.G_READS += 1
    mode = mode or ("pallas" if _on_tpu() else "ref")
    tm = jnp.asarray(theta_m, jnp.float32)
    ta = jnp.asarray(theta_a, jnp.float32)
    if mode == "ref":
        return ref.fairk_ef_update_ref(g, g_prev, age, tm, ta,
                                       residual=residual, fresh=fresh,
                                       sanitize=sanitize)
    g, g_prev, age, residual, fresh, block, d = _block_pad(
        g, g_prev, age, residual, fresh, block_size)
    g_t, age_out, res_out = fairk_ef_update_pallas(
        g, g_prev, age, tm, ta, residual=residual, fresh=fresh,
        block_size=block, interpret=(mode == "interpret"),
        sanitize=sanitize)
    if g.shape[0] != d:
        return (g_t[:d], age_out[:d],
                res_out[:d] if res_out is not None else None)
    return g_t, age_out, res_out


def _block_pad(g, g_prev, age, residual, fresh, block_size):
    """Lane-align the block (multiple of 256) so small/odd leaves don't
    hand Mosaic an unaligned 1-D tile; size it from the trip count so
    padding stays < 256 * nb instead of block-1 (d = block_size + 1 must
    not double the HBM traffic of this bandwidth-bound pass).  Pads carry
    the PAD_AGE sentinel, so they can neither select nor count."""
    d = g.shape[0]
    nb = -(-d // block_size)              # trip count at the requested block
    per_block = -(-d // nb)
    block = -(-per_block // 256) * 256    # lane-aligned actual block
    pad = nb * block - d
    if pad:
        g, g_prev = (jnp.pad(x, (0, pad)) for x in (g, g_prev))
        age = jnp.pad(age, (0, pad), constant_values=PAD_AGE)
        if residual is not None:
            residual = jnp.pad(residual, (0, pad))
        if fresh is not None:
            fresh = jnp.pad(fresh, (0, pad))
    return g, g_prev, age, residual, fresh, block, d


def fairk_stats_update(g: Array, g_prev: Array, age: Array, theta_m,
                       theta_a, residual: Optional[Array] = None,
                       fresh: Optional[Array] = None,
                       mode: Optional[str] = None,
                       block_size: int = 65536,
                       sanitize: bool = False
                       ) -> Tuple[Array, Array, Optional[Array], dict]:
    """``fairk_ef_update`` that ALSO emits the selection statistics from
    the same pass: (g_t, age', residual' | None, stats) where stats holds
    the pad-aware exact counts ``n_sel`` / ``n_sel_m`` and the strided
    ``mag_hist`` / ``age_hist`` (bin spec: ``core.packing``) — everything
    the warm-start threshold controller consumes, with NO additional read
    of the gradient buffer (the legacy accounting paid a masked count
    pass over ``(g, residual)`` plus, on re-estimation rounds, the
    sampled-quantile bootstrap pass).

    The histogram sample stride derives from the ORIGINAL d (pre
    block-alignment padding) so kernel and ref modes sample identical
    positions; the counts are full (not sampled)."""
    global FAIRK_UPDATE_CALLS
    FAIRK_UPDATE_CALLS += 1
    packing.G_READS += 1
    mode = mode or ("pallas" if _on_tpu() else "ref")
    tm = jnp.asarray(theta_m, jnp.float32)
    ta = jnp.asarray(theta_a, jnp.float32)
    stride = packing.hist_stride(g.shape[0])
    if mode == "ref":
        return ref.fairk_stats_update_ref(g, g_prev, age, tm, ta,
                                          residual=residual, fresh=fresh,
                                          stats_stride=stride,
                                          sanitize=sanitize)
    g, g_prev, age, residual, fresh, block, d = _block_pad(
        g, g_prev, age, residual, fresh, block_size)
    g_t, age_out, res_out, rows = fairk_stats_update_pallas(
        g, g_prev, age, tm, ta, residual=residual, fresh=fresh,
        block_size=block, interpret=(mode == "interpret"),
        stats_stride=stride, sanitize=sanitize)
    vec = rows.sum(axis=0)                 # one tiny (nb, 384) reduction
    stats = {"n_sel": vec[STATS_N_SEL], "n_sel_m": vec[STATS_N_SEL_M],
             "mag_hist": vec[STATS_MAG_OFF:STATS_MAG_OFF
                             + packing.STATS_MAG_BINS],
             "age_hist": vec[STATS_AGE_OFF:STATS_AGE_OFF
                             + packing.STATS_AGE_BINS]}
    if g.shape[0] != d:
        return (g_t[:d], age_out[:d],
                res_out[:d] if res_out is not None else None, stats)
    return g_t, age_out, res_out, stats
