"""Pallas kernel: FSK majority-vote aggregation (prototype path, Sec. V-B).

votes (N, k) one-bit client values -> (k,) majority signs PLUS the (k,)
superposed vote energy they were detected from.  Each grid step loads a
(N, block_k) tile into VMEM, reduces over the client axis on the VPU once
and writes both outputs — the energy used to be recomputed by callers as
a second full reduction over the vote matrix (the selection score of the
one-bit route is the consensus strength |energy|), which doubled the HBM
traffic of the uplink.  N is small (clients), so the tile is tall-thin;
block_k a multiple of 128 keeps lanes full.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _sign_mv_kernel(votes_ref, out_ref, energy_ref):
    v = votes_ref[...]                            # (N, block_k)
    s = jnp.where(v >= 0, 1.0, -1.0).sum(axis=0)
    energy_ref[...] = s
    out_ref[...] = jnp.where(s >= 0, 1.0, -1.0)


def _sign_mv_noise_kernel(votes_ref, noise_ref, out_ref, energy_ref):
    """Noisy variant: channel noise perturbs the superposed FSK energy
    (the vote sum) before the sign — Sec. V-B's non-coherent detection."""
    v = votes_ref[...]                            # (N, block_k)
    s = jnp.where(v >= 0, 1.0, -1.0).sum(axis=0) + noise_ref[...]
    energy_ref[...] = s
    out_ref[...] = jnp.where(s >= 0, 1.0, -1.0)


def _sign_from_energy_kernel(energy_ref, out_ref, energy_out_ref):
    s = energy_ref[...]                           # (block_k,)
    energy_out_ref[...] = s
    out_ref[...] = jnp.where(s >= 0, 1.0, -1.0)


def _sign_from_energy_noise_kernel(energy_ref, noise_ref, out_ref,
                                   energy_out_ref):
    s = energy_ref[...] + noise_ref[...]
    energy_out_ref[...] = s
    out_ref[...] = jnp.where(s >= 0, 1.0, -1.0)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def sign_from_energy_pallas(energy: Array, noise: Optional[Array] = None,
                            block_k: int = 2048,
                            interpret: bool = False) -> Tuple[Array, Array]:
    """Majority stage only, for a PRE-REDUCED (k,) vote-energy row.

    The streaming client fold accumulates per-chunk partial vote sums into
    one (k,) buffer (the (N, k) matrix is never live); this kernel applies
    the channel-noise perturbation and the non-coherent sign detection —
    one elementwise pass, same tiling as ``sign_mv_pallas``."""
    k = energy.shape[0]
    block_k = min(block_k, k)
    if k % block_k:
        raise ValueError(f"k={k} not divisible by block_k={block_k}")
    nb = k // block_k
    vec_spec = pl.BlockSpec((block_k,), lambda i: (i,))
    kernel = (_sign_from_energy_kernel if noise is None
              else _sign_from_energy_noise_kernel)
    in_specs = [vec_spec] if noise is None else [vec_spec, vec_spec]
    args = ((energy.astype(jnp.float32),) if noise is None
            else (energy.astype(jnp.float32), noise.astype(jnp.float32)))
    signs, energy_out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=[vec_spec, vec_spec],
        out_shape=[jax.ShapeDtypeStruct((k,), jnp.float32)] * 2,
        interpret=interpret,
    )(*args)
    return signs, energy_out


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def sign_mv_pallas(votes: Array, noise: Optional[Array] = None,
                   block_k: int = 2048,
                   interpret: bool = False) -> Tuple[Array, Array]:
    n, k = votes.shape
    block_k = min(block_k, k)
    if k % block_k:
        raise ValueError(f"k={k} not divisible by block_k={block_k}")
    nb = k // block_k
    vote_spec = pl.BlockSpec((n, block_k), lambda i: (0, i))
    vec_spec = pl.BlockSpec((block_k,), lambda i: (i,))
    kernel = _sign_mv_kernel if noise is None else _sign_mv_noise_kernel
    in_specs = [vote_spec] if noise is None else [vote_spec, vec_spec]
    args = ((votes.astype(jnp.float32),) if noise is None
            else (votes.astype(jnp.float32), noise.astype(jnp.float32)))
    signs, energy = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=[vec_spec, vec_spec],
        out_shape=[jax.ShapeDtypeStruct((k,), jnp.float32)] * 2,
        interpret=interpret,
    )(*args)
    return signs, energy
