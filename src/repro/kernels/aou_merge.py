"""Pallas kernel: fused gradient reconstruction (Eq. 8) + AoU update (Eq. 10).

The server-side per-round state update touches four d-length vectors
(g_new, g_old, age, mask) and produces two.  Naively that is three separate
elementwise passes (select, merge, age-update) = 5 reads + 3 writes of HBM
per coordinate; fused it is 4 reads + 2 writes in a single pass — the
bandwidth-bound hot loop of the OAC server at d ~ 1e8.

Grid: 1-D over VMEM-sized blocks; pure VPU elementwise work.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _aou_merge_kernel(g_new_ref, g_old_ref, age_ref, mask_ref,
                      g_out_ref, age_out_ref):
    m = mask_ref[...]
    keep = 1.0 - m
    g_out_ref[...] = m * g_new_ref[...] + keep * g_old_ref[...]
    age_out_ref[...] = (age_ref[...] + 1.0) * keep


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def aou_merge_pallas(g_new: Array, g_old: Array, age: Array, mask: Array,
                     block_size: int = 65536, interpret: bool = False
                     ) -> Tuple[Array, Array]:
    d = g_new.shape[0]
    block_size = min(block_size, d)
    if d % block_size:
        raise ValueError(f"d={d} not divisible by block_size={block_size}")
    nb = d // block_size
    spec = pl.BlockSpec((block_size,), lambda i: (i,))
    g_out, age_out = pl.pallas_call(
        _aou_merge_kernel,
        grid=(nb,),
        in_specs=[spec] * 4,
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((d,), jnp.float32),
                   jax.ShapeDtypeStruct((d,), jnp.float32)],
        interpret=interpret,
    )(g_new.astype(jnp.float32), g_old.astype(jnp.float32),
      age.astype(jnp.float32), mask.astype(jnp.float32))
    return g_out, age_out
