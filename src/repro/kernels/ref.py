"""Pure-jnp oracles for every Pallas kernel (the correctness reference and
the XLA-native fallback used when not running on TPU)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def block_topk_ref(x: Array, block_size: int, m: int) -> Tuple[Array, Array]:
    """Per-block top-m magnitudes.

    x: (d,) with d % block_size == 0.  Returns (vals, idxs) of shape
    (d // block_size, m): the m largest |x| per contiguous block and their
    *global* indices."""
    d = x.shape[0]
    nb = d // block_size
    xb = jnp.abs(x).reshape(nb, block_size)
    vals, local_idx = jax.lax.top_k(xb, m)
    idxs = local_idx + (jnp.arange(nb) * block_size)[:, None]
    return vals, idxs.astype(jnp.int32)


def aou_merge_ref(g_new: Array, g_old: Array, age: Array, mask: Array
                  ) -> Tuple[Array, Array]:
    """Fused Eq. (8) merge + Eq. (10) AoU update (one pass over 4 vectors).

    g = mask*g_new + (1-mask)*g_old;  age' = (age+1)*(1-mask)."""
    g = mask * g_new + (1.0 - mask) * g_old
    age_next = (age + 1.0) * (1.0 - mask)
    return g, age_next


def sign_mv_ref(votes: Array, noise: Optional[Array] = None) -> Array:
    """FSK majority vote: votes (N, k) one-bit values -> (k,) signs.

    ``noise`` (optional, (k,)) is channel noise on the superposed FSK
    energies: the vote sum is perturbed *before* the sign (Sec. V-B)."""
    s = jnp.where(votes >= 0, 1.0, -1.0).sum(axis=0)
    if noise is not None:
        s = s + noise.astype(s.dtype)
    return jnp.where(s >= 0, 1.0, -1.0).astype(votes.dtype)


def fairk_update_ref(g: Array, g_prev: Array, age: Array, theta_m: Array,
                     theta_a: Array) -> Tuple[Array, Array]:
    """Oracle for the fused threshold-FAIR-k server update (one shard).

    Coordinates with ``age < 0`` are packing pads (core.packing.PAD_AGE):
    never selected, age passes through unchanged."""
    d = g.shape[0]
    g32 = g.astype(jnp.float32)
    age32 = age.astype(jnp.float32)
    idx = jnp.arange(d, dtype=jnp.uint32)
    jitter = (idx * jnp.uint32(2654435761) % jnp.uint32(1 << 24)
              ).astype(jnp.float32) / float(1 << 24)
    valid = age32 >= 0.0
    mask_m = valid & (jnp.abs(g32) >= theta_m)
    mask = (mask_m | (valid & (age32 + jitter >= theta_a) & (~mask_m))
            ).astype(jnp.float32)
    keep = 1.0 - mask
    g_t = mask * g32 + keep * g_prev.astype(jnp.float32)
    age_next = jnp.where(valid, jnp.minimum((age32 + 1.0) * keep, 120.0),
                         age32)
    return g_t, age_next


def fairk_ef_update_ref(g: Array, g_prev: Array, age: Array, theta_m: Array,
                        theta_a: Array, residual: Optional[Array] = None,
                        fresh: Optional[Array] = None
                        ) -> Tuple[Array, Array, Optional[Array]]:
    """Oracle for the fused pass with the residual (error-feedback) stage.

    ``score = g + residual`` drives both threshold stages; the merged fresh
    value is ``fresh`` when given (one-bit majority-vote signs) else the
    score itself; ``residual' = score - mask * sent`` — unsent mass on
    unselected coordinates, quantization error on selected ones.  Pads
    (``age < 0``) are never selected and pass ``(age, residual)`` through
    unchanged."""
    d = g.shape[0]
    g32 = g.astype(jnp.float32)
    age32 = age.astype(jnp.float32)
    res32 = residual.astype(jnp.float32) if residual is not None else None
    score = g32 + res32 if residual is not None else g32
    idx = jnp.arange(d, dtype=jnp.uint32)
    jitter = (idx * jnp.uint32(2654435761) % jnp.uint32(1 << 24)
              ).astype(jnp.float32) / float(1 << 24)
    valid = age32 >= 0.0
    mask_m = valid & (jnp.abs(score) >= theta_m)
    mask = (mask_m | (valid & (age32 + jitter >= theta_a) & (~mask_m))
            ).astype(jnp.float32)
    keep = 1.0 - mask
    sent = fresh.astype(jnp.float32) if fresh is not None else score
    g_t = mask * sent + keep * g_prev.astype(jnp.float32)
    age_next = jnp.where(valid, jnp.minimum((age32 + 1.0) * keep, 120.0),
                         age32)
    res_next = (jnp.where(valid, score - mask * sent, res32)
                if residual is not None else None)
    return g_t, age_next, res_next
