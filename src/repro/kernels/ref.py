"""Pure-jnp oracles for every Pallas kernel (the correctness reference and
the XLA-native fallback used when not running on TPU)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import packing

Array = jax.Array


def strided_hists_ref(score: Array, age_next: Array, valid: Array,
                      stride: int) -> Tuple[Array, Array]:
    """(mag_hist, age_hist) over the deterministic ``[::stride]`` sample —
    the single-pass mirror of the kernel's per-block partial histograms
    (identical sample positions because the kernel block size is a
    multiple of the stride; identical integer counts because f32 sums of
    small integers are exact in any order).

    ``age_next`` is the POST-update AoU (the next round's input age
    distribution — no staleness lag for θ_A re-estimation); pads weigh
    zero via ``valid``.  Implemented scatter-free: the sampled bin
    indices are sorted once and the counts read off with ``searchsorted``
    (XLA CPU scatter is ~70x slower at bench sizes)."""
    w = valid[::stride]
    m_bins = jnp.where(w, packing.mag_bin(jnp.abs(score[::stride])), -1.0)
    a_bins = jnp.where(w, packing.age_bin(age_next[::stride]), -1.0)
    return (_searchsorted_hist(m_bins, packing.STATS_MAG_BINS),
            _searchsorted_hist(a_bins, packing.STATS_AGE_BINS))


def _searchsorted_hist(bins: Array, n_bins: int) -> Array:
    """Exact integer counts of f32 integer bin indices (−1 = excluded)."""
    edges = jnp.arange(n_bins + 1, dtype=jnp.float32) - 0.5
    cuts = jnp.searchsorted(jnp.sort(bins), edges)
    return jnp.diff(cuts).astype(jnp.float32)


def block_topk_ref(x: Array, block_size: int, m: int) -> Tuple[Array, Array]:
    """Per-block top-m magnitudes.

    x: (d,) with d % block_size == 0.  Returns (vals, idxs) of shape
    (d // block_size, m): the m largest |x| per contiguous block and their
    *global* indices."""
    d = x.shape[0]
    nb = d // block_size
    xb = jnp.abs(x).reshape(nb, block_size)
    vals, local_idx = jax.lax.top_k(xb, m)
    idxs = local_idx + (jnp.arange(nb) * block_size)[:, None]
    return vals, idxs.astype(jnp.int32)


def aou_merge_ref(g_new: Array, g_old: Array, age: Array, mask: Array
                  ) -> Tuple[Array, Array]:
    """Fused Eq. (8) merge + Eq. (10) AoU update (one pass over 4 vectors).

    g = mask*g_new + (1-mask)*g_old;  age' = (age+1)*(1-mask)."""
    g = mask * g_new + (1.0 - mask) * g_old
    age_next = jnp.minimum((age + 1.0) * (1.0 - mask), packing.AGE_CAP)
    return g, age_next


def sign_mv_ref(votes: Array, noise: Optional[Array] = None
                ) -> Tuple[Array, Array]:
    """FSK majority vote: votes (N, k) one-bit values -> (signs, energy),
    both (k,).

    ``energy`` is the superposed vote sum (plus ``noise``, when given —
    channel noise perturbs the FSK energies *before* the sign, Sec. V-B)
    and ``signs`` its sign.  Returning the energy lets the one-bit route
    score selection on vote consensus strength without reducing the
    (N, k) vote matrix a second time."""
    s = jnp.where(votes >= 0, 1.0, -1.0).sum(axis=0)
    return sign_from_energy_ref(s, noise)


def sign_from_energy_ref(energy: Array, noise: Optional[Array] = None
                         ) -> Tuple[Array, Array]:
    """Majority stage of ``sign_mv_ref`` for a PRE-REDUCED (k,) vote-energy
    row: the streaming client fold (fl/trainer.py) accumulates each chunk's
    partial vote sum into one (k,) buffer — the full (N, k) vote matrix is
    never live — and hands the total here for the noise add + sign."""
    s = energy
    if noise is not None:
        s = s + noise.astype(s.dtype)
    return jnp.where(s >= 0, 1.0, -1.0).astype(energy.dtype), s


def fairk_update_ref(g: Array, g_prev: Array, age: Array, theta_m: Array,
                     theta_a: Array, sanitize: bool = False
                     ) -> Tuple[Array, Array]:
    """Oracle for the fused threshold-FAIR-k server update (one shard).

    Coordinates with ``age < 0`` are packing pads (core.packing.PAD_AGE):
    never selected, age passes through unchanged.  ``sanitize`` (static)
    additionally keeps non-finite coordinates out of both stages — see
    ``fairk_ef_update_ref``."""
    d = g.shape[0]
    g32 = g.astype(jnp.float32)
    age32 = age.astype(jnp.float32)
    idx = jnp.arange(d, dtype=jnp.uint32)
    jitter = (idx * jnp.uint32(2654435761) % jnp.uint32(1 << 24)
              ).astype(jnp.float32) / float(1 << 24)
    valid = age32 >= 0.0
    if sanitize:
        ok = valid & jnp.isfinite(g32)
        g32 = jnp.where(jnp.isfinite(g32), g32, 0.0)
    else:
        ok = valid
    mask_m = ok & (jnp.abs(g32) >= theta_m)
    mask = (mask_m | (ok & (age32 + jitter >= theta_a) & (~mask_m))
            ).astype(jnp.float32)
    keep = 1.0 - mask
    g_t = mask * g32 + keep * g_prev.astype(jnp.float32)
    age_next = jnp.where(valid,
                         jnp.minimum((age32 + 1.0) * keep, packing.AGE_CAP),
                         age32)
    return g_t, age_next


def fairk_ef_update_ref(g: Array, g_prev: Array, age: Array, theta_m: Array,
                        theta_a: Array, residual: Optional[Array] = None,
                        fresh: Optional[Array] = None,
                        sanitize: bool = False
                        ) -> Tuple[Array, Array, Optional[Array]]:
    """Oracle for the fused pass with the residual (error-feedback) stage.

    ``score = g + residual`` drives both threshold stages; the merged fresh
    value is ``fresh`` when given (one-bit majority-vote signs) else the
    score itself; ``residual' = score - mask * sent`` — unsent mass on
    unselected coordinates, quantization error on selected ones.  Pads
    (``age < 0``) are never selected and pass ``(age, residual)`` through
    unchanged.

    ``sanitize`` (static) masks non-finite score coordinates out of both
    stages: they are semantically "unsent" — age keeps climbing, residual
    passes through unchanged, and the cleaned (zeroed) score keeps
    ``0 * NaN`` out of the merge.  Off-mode is bit-identical to the
    historical graph (``ok`` IS ``valid``)."""
    d = g.shape[0]
    g32 = g.astype(jnp.float32)
    age32 = age.astype(jnp.float32)
    res32 = residual.astype(jnp.float32) if residual is not None else None
    score = g32 + res32 if residual is not None else g32
    idx = jnp.arange(d, dtype=jnp.uint32)
    jitter = (idx * jnp.uint32(2654435761) % jnp.uint32(1 << 24)
              ).astype(jnp.float32) / float(1 << 24)
    valid = age32 >= 0.0
    if sanitize:
        ok = valid & jnp.isfinite(score)
        score = jnp.where(jnp.isfinite(score), score, 0.0)
    else:
        ok = valid
    mask_m = ok & (jnp.abs(score) >= theta_m)
    mask = (mask_m | (ok & (age32 + jitter >= theta_a) & (~mask_m))
            ).astype(jnp.float32)
    keep = 1.0 - mask
    sent = fresh.astype(jnp.float32) if fresh is not None else score
    if sanitize and fresh is not None:
        sent = jnp.where(jnp.isfinite(sent), sent, 0.0)
    g_t = mask * sent + keep * g_prev.astype(jnp.float32)
    age_next = jnp.where(valid,
                         jnp.minimum((age32 + 1.0) * keep, packing.AGE_CAP),
                         age32)
    res_next = (jnp.where(ok, score - mask * sent, res32)
                if residual is not None else None)
    return g_t, age_next, res_next


def fairk_stats_update_ref(g: Array, g_prev: Array, age: Array,
                           theta_m: Array, theta_a: Array,
                           residual: Optional[Array] = None,
                           fresh: Optional[Array] = None,
                           stats_stride: int = 1,
                           sanitize: bool = False
                           ) -> Tuple[Array, Array, Optional[Array],
                                      "dict"]:
    """Oracle for the fused pass WITH the selection-statistics outputs:
    (g_t, age', residual' | None, stats dict).

    ``stats`` carries pad-aware exact counts ``n_sel`` (all selected) /
    ``n_sel_m`` (magnitude stage — identical to the legacy two-pass
    ``(age'==0) & (|score| >= θ_M)`` accounting because the age stage
    only admits coordinates with ``|score| < θ_M``) and the strided
    ``mag_hist`` / ``age_hist`` (see ``strided_hists_ref``).  Under
    ``sanitize`` non-finite coordinates weigh zero in the histograms and
    can never appear in the counts (they are excluded from selection)."""
    g_t, age_next, res_next = fairk_ef_update_ref(
        g, g_prev, age, theta_m, theta_a, residual=residual, fresh=fresh,
        sanitize=sanitize)
    d = g.shape[0]
    g32 = g.astype(jnp.float32)
    res32 = residual.astype(jnp.float32) if residual is not None else None
    score = g32 + res32 if residual is not None else g32
    # histogram pipeline recomputed on the strided INPUT samples: every op
    # is elementwise, so the sampled values are bit-identical to slicing
    # the full intermediates, while XLA only streams d/stride elements
    # (slicing the full `score`/`age_next` would anchor d-length temps)
    s = stats_stride
    score_s = score[::s]
    age_s = age.astype(jnp.float32)[::s]
    valid_s = age_s >= 0.0
    if sanitize:
        ok_s = valid_s & jnp.isfinite(score_s)
        score_s = jnp.where(jnp.isfinite(score_s), score_s, 0.0)
    else:
        ok_s = valid_s
    idx_s = jnp.arange(0, d, s, dtype=jnp.uint32)
    jitter_s = (idx_s * jnp.uint32(2654435761) % jnp.uint32(1 << 24)
                ).astype(jnp.float32) / float(1 << 24)
    mask_m_s = ok_s & (jnp.abs(score_s) >= theta_m)
    mask_s = (mask_m_s | (ok_s & (age_s + jitter_s >= theta_a)
                          & (~mask_m_s))).astype(jnp.float32)
    age_next_s = jnp.where(
        valid_s,
        jnp.minimum((age_s + 1.0) * (1.0 - mask_s), packing.AGE_CAP), age_s)
    m_bins = jnp.where(ok_s, packing.mag_bin(jnp.abs(score_s)), -1.0)
    a_bins = jnp.where(ok_s, packing.age_bin(age_next_s), -1.0)
    # counts derive from the materialized age output + one re-read of the
    # score inputs — identical integers to reducing the masks directly,
    # but XLA CPU then reuses the output buffer instead of materializing
    # two d-length bool temps (the pallas kernel reduces in-register and
    # has neither cost).  ``sel_b`` can never hit a sanitized-out
    # coordinate (it was excluded from the mask, so its age is >= 1), and
    # at selected coordinates the raw score is finite — the counts need
    # no sanitize branch of their own.
    sel_b = age_next == 0.0
    stats = {"n_sel": jnp.count_nonzero(sel_b).astype(jnp.float32),
             "n_sel_m": jnp.count_nonzero(
                 sel_b & (jnp.abs(score) >= theta_m)).astype(jnp.float32),
             "mag_hist": _searchsorted_hist(m_bins,
                                            packing.STATS_MAG_BINS),
             "age_hist": _searchsorted_hist(a_bins,
                                            packing.STATS_AGE_BINS)}
    return g_t, age_next, res_next, stats
