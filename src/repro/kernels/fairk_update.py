"""Pallas kernel: fused threshold-FAIR-k server update (production path).

The sharded trainer's per-shard server phase (launch.steps._leaf_server_update)
is a chain of d-length elementwise ops: magnitude mask (>= theta_M), age+
jitter mask (>= theta_A), Eq. (8) stale merge, Eq. (10) AoU update.  Left to
XLA that is ~6 HBM passes over the shard; fused it is one pass reading
(g, g_prev, age) and writing (g_t, age') — the bandwidth-bound server hot
loop at d/256 ~ 10^9 coordinates per device.

Thresholds are scalars estimated outside (sampled quantiles); the index
jitter for integer-age tie-breaking is regenerated inside the kernel from
the global coordinate index (identical to launch.steps._index_jitter).

Pad protocol (core.packing): coordinates with ``age < 0`` are padding in a
packed multi-leaf buffer.  They can never be selected (neither stage), and
their age passes through unchanged so the sentinel survives round trips —
this is what lets the packed server phase keep interior lane-alignment pads
inside the buffer across steps without them polluting the selection budget.

Residual (error-feedback) stage.  ``fairk_ef_update_pallas`` extends the
fused pass with two optional streams while staying ONE HBM round trip:

* ``residual`` — the error-feedback accumulator.  The selection score
  becomes ``score = g + residual`` (the unsent mass folds back
  pre-selection), the merged fresh value is ``score`` itself, and the
  kernel emits ``residual' = score - mask * sent`` from the same pass —
  the unsent mass on unselected coordinates, the quantization error on
  selected ones.  Pads pass their residual through unchanged.
* ``fresh`` — decoupled transmitted values for the one-bit FSK-MV route
  (kernels.sign_mv): selection scores ``g`` (+ residual) but the merged
  fresh value is ``fresh`` (the majority-vote signs).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _fairk_kernel(*refs, block_size: int, has_res: bool, has_fresh: bool):
    """Shared fused body.  Ref order: g, [fresh], g_prev, age, [res],
    thetas -> g_t, age', [res']."""
    it = iter(refs)
    g_ref = next(it)
    fresh_ref = next(it) if has_fresh else None
    gp_ref = next(it)
    age_ref = next(it)
    res_ref = next(it) if has_res else None
    thetas_ref = next(it)
    gt_ref = next(it)
    age_out_ref = next(it)
    res_out_ref = next(it) if has_res else None

    bid = pl.program_id(0)
    theta_m = thetas_ref[0]
    theta_a = thetas_ref[1]
    g = g_ref[...].astype(jnp.float32)
    age = age_ref[...].astype(jnp.float32)
    res = res_ref[...].astype(jnp.float32) if has_res else None
    score = g + res if has_res else g
    # deterministic per-coordinate jitter in [0, 1) (Knuth hash of index)
    idx = (bid * block_size + jax.lax.iota(jnp.uint32, block_size))
    jitter = (idx * jnp.uint32(2654435761) % jnp.uint32(1 << 24)
              ).astype(jnp.float32) / float(1 << 24)
    valid = age >= 0.0                      # age < 0 marks packing pads
    mask_m = valid & (jnp.abs(score) >= theta_m)
    mask = mask_m | (valid & (age + jitter >= theta_a) & (~mask_m))
    maskf = mask.astype(jnp.float32)
    keep = 1.0 - maskf
    sent = fresh_ref[...].astype(jnp.float32) if has_fresh else score
    gt_ref[...] = maskf * sent + keep * gp_ref[...].astype(jnp.float32)
    age_out_ref[...] = jnp.where(valid,
                                 jnp.minimum((age + 1.0) * keep, 120.0), age)
    if has_res:
        res_out_ref[...] = jnp.where(valid, score - maskf * sent, res)


_fairk_update_kernel = functools.partial(_fairk_kernel, has_res=False,
                                         has_fresh=False)


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def fairk_update_pallas(g: Array, g_prev: Array, age: Array, theta_m: Array,
                        theta_a: Array, block_size: int = 65536,
                        interpret: bool = False) -> Tuple[Array, Array]:
    """g/g_prev/age: (d,) -> (g_t (d,), age' (d,)), single fused pass."""
    g_t, age_out, _ = _fairk_call(g, g_prev, age, theta_m, theta_a,
                                  residual=None, fresh=None,
                                  block_size=block_size, interpret=interpret)
    return g_t, age_out


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def fairk_ef_update_pallas(g: Array, g_prev: Array, age: Array,
                           theta_m: Array, theta_a: Array,
                           residual: Optional[Array] = None,
                           fresh: Optional[Array] = None,
                           block_size: int = 65536,
                           interpret: bool = False
                           ) -> Tuple[Array, Array, Optional[Array]]:
    """Fused pass with the residual (error-feedback) stage and/or decoupled
    ``fresh`` values: (g_t, age', residual' | None) — see module docstring."""
    return _fairk_call(g, g_prev, age, theta_m, theta_a, residual=residual,
                       fresh=fresh, block_size=block_size,
                       interpret=interpret)


def _fairk_call(g, g_prev, age, theta_m, theta_a, *, residual, fresh,
                block_size, interpret):
    d = g.shape[0]
    block_size = min(block_size, d)
    if d % block_size:
        raise ValueError(f"d={d} not divisible by block_size={block_size}")
    nb = d // block_size
    has_res = residual is not None
    has_fresh = fresh is not None
    thetas = jnp.stack([theta_m.astype(jnp.float32),
                        theta_a.astype(jnp.float32)])
    spec = pl.BlockSpec((block_size,), lambda i: (i,))
    kernel = functools.partial(_fairk_kernel, block_size=block_size,
                               has_res=has_res, has_fresh=has_fresh)
    f32 = lambda x: x.astype(jnp.float32)
    inputs = [f32(g)]
    in_specs = [spec]
    if has_fresh:
        inputs.append(f32(fresh))
        in_specs.append(spec)
    inputs += [f32(g_prev), f32(age)]
    in_specs += [spec, spec]
    if has_res:
        inputs.append(f32(residual))
        in_specs.append(spec)
    inputs.append(thetas)
    in_specs.append(pl.BlockSpec((2,), lambda i: (0,)))
    n_out = 3 if has_res else 2
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=[spec] * n_out,
        out_shape=[jax.ShapeDtypeStruct((d,), jnp.float32)] * n_out,
        interpret=interpret,
    )(*inputs)
    return (out[0], out[1], out[2] if has_res else None)
