"""Pallas kernel: fused threshold-FAIR-k server update (production path).

The sharded trainer's per-shard server phase (launch.steps._leaf_server_update)
is a chain of d-length elementwise ops: magnitude mask (>= theta_M), age+
jitter mask (>= theta_A), Eq. (8) stale merge, Eq. (10) AoU update.  Left to
XLA that is ~6 HBM passes over the shard; fused it is one pass reading
(g, g_prev, age) and writing (g_t, age') — the bandwidth-bound server hot
loop at d/256 ~ 10^9 coordinates per device.

Thresholds are scalars estimated outside (sampled quantiles); the index
jitter for integer-age tie-breaking is regenerated inside the kernel from
the global coordinate index (identical to launch.steps._index_jitter).

Pad protocol (core.packing): coordinates with ``age < 0`` are padding in a
packed multi-leaf buffer.  They can never be selected (neither stage), and
their age passes through unchanged so the sentinel survives round trips —
this is what lets the packed server phase keep interior lane-alignment pads
inside the buffer across steps without them polluting the selection budget.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _fairk_update_kernel(g_ref, gp_ref, age_ref, thetas_ref,
                         gt_ref, age_out_ref, *, block_size: int):
    bid = pl.program_id(0)
    theta_m = thetas_ref[0]
    theta_a = thetas_ref[1]
    g = g_ref[...].astype(jnp.float32)
    age = age_ref[...].astype(jnp.float32)
    # deterministic per-coordinate jitter in [0, 1) (Knuth hash of index)
    idx = (bid * block_size + jax.lax.iota(jnp.uint32, block_size))
    jitter = (idx * jnp.uint32(2654435761) % jnp.uint32(1 << 24)
              ).astype(jnp.float32) / float(1 << 24)
    valid = age >= 0.0                      # age < 0 marks packing pads
    mask_m = valid & (jnp.abs(g) >= theta_m)
    mask = mask_m | (valid & (age + jitter >= theta_a) & (~mask_m))
    keep = 1.0 - mask.astype(jnp.float32)
    gt_ref[...] = (mask.astype(jnp.float32) * g
                   + keep * gp_ref[...].astype(jnp.float32))
    age_out_ref[...] = jnp.where(valid,
                                 jnp.minimum((age + 1.0) * keep, 120.0), age)


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def fairk_update_pallas(g: Array, g_prev: Array, age: Array, theta_m: Array,
                        theta_a: Array, block_size: int = 65536,
                        interpret: bool = False) -> Tuple[Array, Array]:
    """g/g_prev/age: (d,) -> (g_t (d,), age' (d,)), single fused pass."""
    d = g.shape[0]
    block_size = min(block_size, d)
    if d % block_size:
        raise ValueError(f"d={d} not divisible by block_size={block_size}")
    nb = d // block_size
    thetas = jnp.stack([theta_m.astype(jnp.float32),
                        theta_a.astype(jnp.float32)])
    spec = pl.BlockSpec((block_size,), lambda i: (i,))
    kernel = functools.partial(_fairk_update_kernel, block_size=block_size)
    g_t, age_out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[spec, spec, spec,
                  pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((d,), jnp.float32),
                   jax.ShapeDtypeStruct((d,), jnp.float32)],
        interpret=interpret,
    )(g.astype(jnp.float32), g_prev.astype(jnp.float32),
      age.astype(jnp.float32), thetas)
    return g_t, age_out
