"""Pallas kernel: fused threshold-FAIR-k server update (production path).

The sharded trainer's per-shard server phase (launch.steps._leaf_server_update)
is a chain of d-length elementwise ops: magnitude mask (>= theta_M), age+
jitter mask (>= theta_A), Eq. (8) stale merge, Eq. (10) AoU update.  Left to
XLA that is ~6 HBM passes over the shard; fused it is one pass reading
(g, g_prev, age) and writing (g_t, age') — the bandwidth-bound server hot
loop at d/256 ~ 10^9 coordinates per device.

Thresholds are scalars estimated outside (sampled quantiles); the index
jitter for integer-age tie-breaking is regenerated inside the kernel from
the global coordinate index (identical to launch.steps._index_jitter).

Pad protocol (core.packing): coordinates with ``age < 0`` are padding in a
packed multi-leaf buffer.  They can never be selected (neither stage), and
their age passes through unchanged so the sentinel survives round trips —
this is what lets the packed server phase keep interior lane-alignment pads
inside the buffer across steps without them polluting the selection budget.

Residual (error-feedback) stage.  ``fairk_ef_update_pallas`` extends the
fused pass with two optional streams while staying ONE HBM round trip:

* ``residual`` — the error-feedback accumulator.  The selection score
  becomes ``score = g + residual`` (the unsent mass folds back
  pre-selection), the merged fresh value is ``score`` itself, and the
  kernel emits ``residual' = score - mask * sent`` from the same pass —
  the unsent mass on unselected coordinates, the quantization error on
  selected ones.  Pads pass their residual through unchanged.
* ``fresh`` — decoupled transmitted values for the one-bit FSK-MV route
  (kernels.sign_mv): selection scores ``g`` (+ residual) but the merged
  fresh value is ``fresh`` (the majority-vote signs).

Fused selection statistics.  ``fairk_stats_update_pallas`` additionally
emits one small per-block accumulator row — pad-aware partial counts of
the selected (``n_sel``) and magnitude-stage (``n_sel_m``) coordinates
plus strided-sample log-magnitude / age histograms (bin spec:
``core.packing``) — reduced once over the grid after the launch.  This
makes the fused kernel the ONLY read of the gradient buffer per
steady-state server round: the counts that the warm-start controller
consumes used to be a separate masked pass over ``(g, residual)``, and
the histograms let thresholds be re-estimated without the
sampled-quantile bootstrap pass whenever the trust region trips.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.packing import (AGE_CAP, STATS_AGE_BINS, STATS_MAG_BINS,
                                age_bin, mag_bin)

Array = jax.Array

# layout of the per-block stats row (f32): [n_sel, n_sel_m,
# mag_hist(STATS_MAG_BINS), age_hist(STATS_AGE_BINS), zero pad].  The row
# is padded to a lane multiple so the (nb, STATS_WIDTH) output tiles
# cleanly on TPU.
STATS_N_SEL = 0
STATS_N_SEL_M = 1
STATS_MAG_OFF = 2
STATS_AGE_OFF = STATS_MAG_OFF + STATS_MAG_BINS
_STATS_USED = STATS_AGE_OFF + STATS_AGE_BINS
STATS_WIDTH = -(-_STATS_USED // 128) * 128

# per-chunk one-hot width bound for the in-kernel histogram accumulation:
# bounds the (chunk, bins) intermediate to ~1 MB of VMEM
_HIST_CHUNK = 2048


def _hist_accumulate(bins: Array, weights: Array, n_bins: int) -> Array:
    """Exact integer-count histogram of ``bins`` (f32 indices) with 0/1
    ``weights`` via chunked one-hot reduction — scatter-free, so it lowers
    on the TPU VPU and in interpret mode alike.  Counts are integers well
    below 2^24, so f32 accumulation is exact regardless of order."""
    n = bins.shape[0]
    ids = jax.lax.iota(jnp.float32, n_bins)
    acc = jnp.zeros((n_bins,), jnp.float32)
    for s in range(0, n, _HIST_CHUNK):
        b = bins[s:s + _HIST_CHUNK]
        w = weights[s:s + _HIST_CHUNK]
        acc = acc + jnp.sum(
            jnp.where(b[:, None] == ids[None, :], w[:, None], 0.0), axis=0)
    return acc


def _fairk_kernel(*refs, block_size: int, has_res: bool, has_fresh: bool,
                  stats_stride: int = 0, sanitize: bool = False):
    """Shared fused body.  Ref order: g, [fresh], g_prev, age, [res],
    thetas -> g_t, age', [res'], [stats row].

    ``sanitize`` (static): mask non-finite score coordinates out of BOTH
    selection stages — a corrupted or erased uplink is semantically
    "unsent": its age keeps climbing (the ordinary unselected age path),
    its residual passes through unchanged (the mass stays in EF), and it
    weighs zero in the stats row.  Off (the default) traces the exact
    historical graph — bit-identical, not merely equivalent."""
    emit_stats = stats_stride > 0
    it = iter(refs)
    g_ref = next(it)
    fresh_ref = next(it) if has_fresh else None
    gp_ref = next(it)
    age_ref = next(it)
    res_ref = next(it) if has_res else None
    thetas_ref = next(it)
    gt_ref = next(it)
    age_out_ref = next(it)
    res_out_ref = next(it) if has_res else None
    stats_ref = next(it) if emit_stats else None

    bid = pl.program_id(0)
    theta_m = thetas_ref[0]
    theta_a = thetas_ref[1]
    g = g_ref[...].astype(jnp.float32)
    age = age_ref[...].astype(jnp.float32)
    res = res_ref[...].astype(jnp.float32) if has_res else None
    score = g + res if has_res else g
    # deterministic per-coordinate jitter in [0, 1) (Knuth hash of index)
    idx = (bid * block_size + jax.lax.iota(jnp.uint32, block_size))
    jitter = (idx * jnp.uint32(2654435761) % jnp.uint32(1 << 24)
              ).astype(jnp.float32) / float(1 << 24)
    valid = age >= 0.0                      # age < 0 marks packing pads
    if sanitize:
        # non-finite score = corrupted/erased uplink: out of selection
        # (never "sent"), zeroed in the cleaned score so 0 * NaN can't
        # leak into the merge at unselected coordinates
        ok = valid & jnp.isfinite(score)
        score = jnp.where(jnp.isfinite(score), score, 0.0)
    else:
        ok = valid
    mask_m = ok & (jnp.abs(score) >= theta_m)
    mask = mask_m | (ok & (age + jitter >= theta_a) & (~mask_m))
    maskf = mask.astype(jnp.float32)
    keep = 1.0 - maskf
    sent = fresh_ref[...].astype(jnp.float32) if has_fresh else score
    if sanitize and has_fresh:
        sent = jnp.where(jnp.isfinite(sent), sent, 0.0)
    gt_ref[...] = maskf * sent + keep * gp_ref[...].astype(jnp.float32)
    age_next = jnp.where(valid, jnp.minimum((age + 1.0) * keep, AGE_CAP),
                         age)
    age_out_ref[...] = age_next
    if has_res:
        # bad coordinates keep their OLD residual: the blocked mass stays
        # in the accumulator, exactly like an unsent coordinate's
        res_out_ref[...] = jnp.where(ok, score - maskf * sent, res)
    if emit_stats:
        # strided histogram sample: block_size is a multiple of the
        # (power-of-two) stride, so per-block positions == the global
        # [::stride] sample and the partial rows sum bit-exactly to the
        # ref oracle's single-pass histograms.  Pads (and, under
        # sanitize, corrupted coordinates) weigh zero.
        w = ok[::stats_stride].astype(jnp.float32)
        m_bins = mag_bin(jnp.abs(score[::stats_stride]))
        a_bins = age_bin(age_next[::stats_stride])
        row = jnp.concatenate([
            jnp.stack([jnp.sum(maskf), jnp.sum(mask_m.astype(jnp.float32))]),
            _hist_accumulate(m_bins, w, STATS_MAG_BINS),
            _hist_accumulate(a_bins, w, STATS_AGE_BINS),
            jnp.zeros((STATS_WIDTH - _STATS_USED,), jnp.float32),
        ])
        stats_ref[...] = row.reshape(1, STATS_WIDTH)


_fairk_update_kernel = functools.partial(_fairk_kernel, has_res=False,
                                         has_fresh=False)


@functools.partial(jax.jit,
                   static_argnames=("block_size", "interpret", "sanitize"))
def fairk_update_pallas(g: Array, g_prev: Array, age: Array, theta_m: Array,
                        theta_a: Array, block_size: int = 65536,
                        interpret: bool = False,
                        sanitize: bool = False) -> Tuple[Array, Array]:
    """g/g_prev/age: (d,) -> (g_t (d,), age' (d,)), single fused pass."""
    g_t, age_out, _, _ = _fairk_call(g, g_prev, age, theta_m, theta_a,
                                     residual=None, fresh=None,
                                     block_size=block_size,
                                     interpret=interpret, stats_stride=0,
                                     sanitize=sanitize)
    return g_t, age_out


@functools.partial(jax.jit,
                   static_argnames=("block_size", "interpret", "sanitize"))
def fairk_ef_update_pallas(g: Array, g_prev: Array, age: Array,
                           theta_m: Array, theta_a: Array,
                           residual: Optional[Array] = None,
                           fresh: Optional[Array] = None,
                           block_size: int = 65536,
                           interpret: bool = False,
                           sanitize: bool = False
                           ) -> Tuple[Array, Array, Optional[Array]]:
    """Fused pass with the residual (error-feedback) stage and/or decoupled
    ``fresh`` values: (g_t, age', residual' | None) — see module docstring."""
    g_t, age_out, res_out, _ = _fairk_call(
        g, g_prev, age, theta_m, theta_a, residual=residual, fresh=fresh,
        block_size=block_size, interpret=interpret, stats_stride=0,
        sanitize=sanitize)
    return g_t, age_out, res_out


@functools.partial(jax.jit,
                   static_argnames=("block_size", "interpret",
                                    "stats_stride", "sanitize"))
def fairk_stats_update_pallas(g: Array, g_prev: Array, age: Array,
                              theta_m: Array, theta_a: Array,
                              residual: Optional[Array] = None,
                              fresh: Optional[Array] = None,
                              block_size: int = 65536,
                              interpret: bool = False,
                              stats_stride: int = 1,
                              sanitize: bool = False
                              ) -> Tuple[Array, Array, Optional[Array],
                                         Array]:
    """Fused pass that also emits the per-block selection-statistics rows:
    (g_t, age', residual' | None, stats (nb, STATS_WIDTH)).  Reduce the
    rows with ``stats.sum(0)`` — one tiny (nb, 384) reduction replaces the
    full extra read passes of the two-pass accounting."""
    return _fairk_call(g, g_prev, age, theta_m, theta_a, residual=residual,
                       fresh=fresh, block_size=block_size,
                       interpret=interpret, stats_stride=stats_stride,
                       sanitize=sanitize)


def _fairk_call(g, g_prev, age, theta_m, theta_a, *, residual, fresh,
                block_size, interpret, stats_stride=0, sanitize=False):
    d = g.shape[0]
    block_size = min(block_size, d)
    if d % block_size:
        raise ValueError(f"d={d} not divisible by block_size={block_size}")
    if stats_stride and block_size % stats_stride:
        raise ValueError(f"block_size={block_size} not divisible by "
                         f"stats_stride={stats_stride}")
    nb = d // block_size
    has_res = residual is not None
    has_fresh = fresh is not None
    thetas = jnp.stack([theta_m.astype(jnp.float32),
                        theta_a.astype(jnp.float32)])
    spec = pl.BlockSpec((block_size,), lambda i: (i,))
    kernel = functools.partial(_fairk_kernel, block_size=block_size,
                               has_res=has_res, has_fresh=has_fresh,
                               stats_stride=stats_stride, sanitize=sanitize)
    f32 = lambda x: x.astype(jnp.float32)
    inputs = [f32(g)]
    in_specs = [spec]
    if has_fresh:
        inputs.append(f32(fresh))
        in_specs.append(spec)
    inputs += [f32(g_prev), f32(age)]
    in_specs += [spec, spec]
    if has_res:
        inputs.append(f32(residual))
        in_specs.append(spec)
    inputs.append(thetas)
    in_specs.append(pl.BlockSpec((2,), lambda i: (0,)))
    out_specs = [spec] * (3 if has_res else 2)
    out_shape = [jax.ShapeDtypeStruct((d,), jnp.float32)] * len(out_specs)
    if stats_stride:
        out_specs.append(pl.BlockSpec((1, STATS_WIDTH), lambda i: (i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((nb, STATS_WIDTH),
                                              jnp.float32))
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*inputs)
    res_out = out[2] if has_res else None
    stats = out[-1] if stats_stride else None
    return out[0], out[1], res_out, stats
