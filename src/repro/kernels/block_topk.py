"""Pallas kernel: per-VMEM-block top-m magnitude candidates.

This is the TPU-scalable first stage of FAIR-k's magnitude selection for
models whose gradient does not fit a single ``lax.top_k`` (d ~ 1e8+): each
grid step streams one block of the flat gradient HBM->VMEM, computes its
top-m |.| entries with an iterative max-and-mask loop (m is small and
static), and writes the (value, global index) candidates.  The host-side
second stage (ops.global_topk_from_candidates) thresholds the candidate
pool — exact whenever no block holds more than m of the global top-k, a
standard two-stage selection guarantee.

Grid: 1-D over blocks.  VMEM working set per step = block_size * 4 B
(+ m * 8 B outputs), hardware-aligned to the 8x128 VPU lanes when
block_size is a multiple of 1024.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

NEG = -1.0  # |x| >= 0, so -1 can never be selected


def _block_topk_kernel(x_ref, vals_ref, idxs_ref, *, m: int,
                       block_size: int):
    bid = pl.program_id(0)
    x = jnp.abs(x_ref[...])                       # (block_size,)
    base = bid * block_size
    local_iota = jax.lax.iota(jnp.int32, block_size)

    def body(i, carry):
        x_masked, = carry
        top = jnp.max(x_masked)
        arg = jnp.argmax(x_masked).astype(jnp.int32)
        vals_ref[i] = top
        idxs_ref[i] = base + arg
        x_masked = jnp.where(local_iota == arg, NEG, x_masked)
        return (x_masked,)

    jax.lax.fori_loop(0, m, body, (x,))


@functools.partial(jax.jit, static_argnames=("block_size", "m", "interpret"))
def block_topk_pallas(x: Array, block_size: int, m: int,
                      interpret: bool = False) -> Tuple[Array, Array]:
    """x: (d,), d % block_size == 0 -> (vals, idxs) each (nblocks, m)."""
    d = x.shape[0]
    if d % block_size:
        raise ValueError(f"d={d} not divisible by block_size={block_size}")
    nb = d // block_size
    kernel = functools.partial(_block_topk_kernel, m=m, block_size=block_size)
    vals, idxs = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_size,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((m,), lambda i: (i,)),
                   pl.BlockSpec((m,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((nb * m,), jnp.float32),
                   jax.ShapeDtypeStruct((nb * m,), jnp.int32)],
        interpret=interpret,
    )(x.astype(jnp.float32))
    return vals.reshape(nb, m), idxs.reshape(nb, m)
