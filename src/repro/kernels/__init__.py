"""Pallas TPU kernels for the OAC-FL hot spots (see DESIGN.md §3):

* ``block_topk``  — streaming per-block magnitude candidates (stage 1 of
  scalable FAIR-k selection over ~1e8-coordinate gradients).
* ``aou_merge``   — fused Eq. (8) gradient merge + Eq. (10) AoU update
  (single HBM pass over the server's d-length state).
* ``sign_mv``     — FSK majority-vote aggregation (one-bit prototype path);
  returns ``(signs, energy)`` from ONE reduction over the vote matrix.
* ``fairk_update`` — fused threshold-FAIR-k server phase (mask + Eq. 8 merge
  + Eq. 10 age update in one HBM pass; the sharded trainer's hot loop).
  ``fairk_stats_update`` additionally emits the selection statistics
  (counts + magnitude/age histograms) from the same pass — the server
  round's ONLY read of the gradient buffer (DESIGN.md §11).

Each kernel has a pure-jnp oracle in ``ref.py`` and a dispatching wrapper in
``ops.py`` (pallas on TPU / interpret in kernel tests / XLA ref elsewhere).
"""

from repro.kernels import ops, ref
from repro.kernels.ops import (aou_merge, block_topk, fairk_ef_update,
                               fairk_stats_update, fairk_update, sign_mv,
                               two_stage_topk, global_topk_from_candidates)

__all__ = ["ops", "ref", "aou_merge", "block_topk", "fairk_ef_update",
           "fairk_stats_update", "fairk_update", "sign_mv",
           "two_stage_topk", "global_topk_from_candidates"]
