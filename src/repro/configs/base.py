"""Architecture/model configuration system.

``ModelConfig`` fully describes every assigned architecture (DESIGN.md §4)
plus the paper's own FL models.  Configs are declarative; the model builders
in ``repro.models`` and the step builders in ``repro.launch`` consume them.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                   # 0 => attention-free
    n_kv_heads: int
    d_ff: int                      # dense-FFN hidden size (0 => no dense FFN)
    vocab: int
    head_dim: int = 0              # 0 => d_model // n_heads
    qkv_bias: bool = False
    mlp_type: str = "swiglu"       # swiglu | gelu
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0              # expert hidden size (0 => d_ff)
    moe_every: int = 1             # MoE on layers with (i % moe_every == moe_every-1)
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    expert_shard_axis: str = ""    # set by launch.steps: wsc experts to this
                                   # mesh axis through fwd+bwd (SS Perf)
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0            # hybrid: attention on layers (i % attn_every == attn_every-1)
    # --- encoder-decoder (audio) ---
    encoder_layers: int = 0
    encoder_seq: int = 0           # stub-frontend output frames (whisper: 1500)
    # --- vlm ---
    n_patches: int = 0             # stub-frontend patch embeddings per image
    # --- misc ---
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm
    causal_skip: bool = False      # triangular block schedule (§Perf opt)
    embed_mode: str = "gather"     # gather | onehot (§Perf: onehot makes the
                                   # embedding gradient a shardable dot)
    tie_embeddings: bool = False
    sliding_window: int = 0        # decode long-context variant (0 => full)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    scan_block: int = 1            # layers per scan step (hybrid super-block)
    remat: bool = True
    optimizer: str = "adamw"
    source: str = ""               # provenance citation

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.n_heads and self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_experts and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.n_layers % self.scan_block:
            raise ValueError(f"{self.name}: n_layers {self.n_layers} not a "
                             f"multiple of scan_block {self.scan_block}")
        # the layer pattern must repeat with the scan-block period so that
        # stacked blocks are homogeneous (see models.transformer)
        for period in (self.attn_every, self.moe_every):
            if period > 1 and self.scan_block % period:
                raise ValueError(f"{self.name}: scan_block {self.scan_block} "
                                 f"must be a multiple of pattern period {period}")

    # --- layer-pattern helpers -----------------------------------------
    def layer_kind(self, i: int) -> str:
        """"attn" or "mamba" mixer for decoder layer ``i``."""
        if self.family in ("ssm",):
            return "mamba"
        if self.family == "hybrid":
            return "attn" if (i % self.attn_every == self.attn_every - 1) else "mamba"
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        return self.n_experts > 0 and (i % self.moe_every == self.moe_every - 1)

    @property
    def n_scan_blocks(self) -> int:
        return self.n_layers // self.scan_block

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode available (SSM/hybrid native; dense via
        sliding window)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    # --- analytic parameter count (validates configs vs published sizes) ---
    def _attn_params(self) -> int:
        qkv = self.d_model * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
        if self.qkv_bias:
            qkv += (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
        out = self.n_heads * self.head_dim * self.d_model
        return qkv + out

    def _dense_ffn_params(self, d_ff: int) -> int:
        mult = 3 if self.mlp_type == "swiglu" else 2
        return mult * self.d_model * d_ff

    def _moe_ffn_params(self) -> int:
        router = self.d_model * self.n_experts
        mult = 3 if self.mlp_type == "swiglu" else 2
        return router + self.n_experts * mult * self.d_model * self.moe_d_ff

    def _mamba_params(self) -> int:
        d_in, n, g, h = self.d_inner, self.ssm_state, self.ssm_groups, self.ssm_heads
        in_proj = self.d_model * (2 * d_in + 2 * g * n + h)
        conv = self.ssm_conv * (d_in + 2 * g * n)
        out_proj = d_in * self.d_model
        extras = 3 * h + d_in            # A, D, dt_bias, gated norm
        return in_proj + conv + out_proj + extras

    def param_count(self) -> int:
        """Analytic decoder(+encoder) parameter count, norms excluded
        (they are < 0.01% for all assigned configs)."""
        total = self.vocab * self.d_model          # embedding
        if not self.tie_embeddings:
            total += self.vocab * self.d_model     # unembedding
        for i in range(self.n_layers):
            if self.layer_kind(i) == "attn":
                total += self._attn_params()
            else:
                total += self._mamba_params()
            if self.layer_is_moe(i):
                total += self._moe_ffn_params()
                if self.dense_residual:
                    total += self._dense_ffn_params(self.d_ff)
            elif self.d_ff:
                total += self._dense_ffn_params(self.d_ff)
        if self.is_encdec:  # encoder self-attn + ffn, cross-attn in decoder
            total += self.encoder_layers * (self._attn_params()
                                            + self._dense_ffn_params(self.d_ff))
            total += self.n_layers * self._attn_params()   # cross-attention
            total += self.encoder_seq * self.d_model       # enc positional emb
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE uses experts_per_token of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        total = self.param_count()
        for i in range(self.n_layers):
            if self.layer_is_moe(i):
                mult = 3 if self.mlp_type == "swiglu" else 2
                inactive = ((self.n_experts - self.experts_per_token)
                            * mult * self.d_model * self.moe_d_ff)
                total -= inactive
        return total


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """The smoke-test variant: same family/pattern, tiny dimensions.

    2 scan-blocks of layers, d_model <= 512, <= 4 experts — per the assignment
    rules.  Ratios (GQA grouping, MoE top-k, attn:mamba interleave) are kept.
    """
    kv_ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1)) if cfg.n_heads else 1
    d_model = min(cfg.d_model, 256)
    n_heads = 4 if cfg.n_heads else 0
    small = dict(
        n_layers=2 * cfg.scan_block,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=max(1, n_heads // kv_ratio) if n_heads else 0,
        head_dim=d_model // n_heads if n_heads else 0,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab=min(cfg.vocab, 512),
        n_experts=min(cfg.n_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        moe_d_ff=min(cfg.moe_d_ff, 256) if cfg.n_experts else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=32 if cfg.ssm_state else 64,
        ssm_chunk=64,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=min(cfg.encoder_seq, 32) if cfg.encoder_seq else 0,
        n_patches=min(cfg.n_patches, 16) if cfg.n_patches else 0,
        name=cfg.name + "-smoke",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
