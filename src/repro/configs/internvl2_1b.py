"""internvl2-1b [vlm] — InternViT (stub) + InternLM2/Qwen2-arch decoder
[arXiv:2404.16821].

The vision encoder + projector are a STUB per the assignment carve-out:
``input_specs()`` supplies projected patch embeddings (B, n_patches, d_model)
which the decoder consumes ahead of the text tokens."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    qkv_bias=True,
    n_patches=256,             # stub ViT output tokens per image
    rope_theta=1e6,
    sliding_window=8192,
    source="arXiv:2404.16821",
)
