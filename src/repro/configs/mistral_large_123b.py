"""mistral-large-123b [dense] — [hf:mistralai/Mistral-Large-Instruct-2407]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32768,
    rope_theta=1e6,
    sliding_window=8192,       # long_500k decode variant (DESIGN.md §4)
    optimizer="sgdm",
    param_dtype="bfloat16",    # >60B: fp32 master state would exceed v5e HBM          # >50B: halve optimizer-state HBM vs adamw
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)
