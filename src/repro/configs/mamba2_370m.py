"""mamba2-370m [ssm] — attention-free SSD [arXiv:2405.21060]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,                 # attention-free
    n_kv_heads=0,
    d_ff=0,                    # mamba blocks only, no separate FFN
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
