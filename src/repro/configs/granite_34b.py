"""granite-34b [dense] — llama/GPTBigCode-arch code model, MQA (kv=1),
non-gated GeLU MLP [arXiv:2405.04324]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,              # multi-query attention
    d_ff=24576,
    vocab=49152,
    mlp_type="gelu",           # 2-matrix FFN (matches 34B total params)
    norm_type="layernorm",
    rope_theta=1e4,
    sliding_window=8192,
    source="arXiv:2405.04324",
)
