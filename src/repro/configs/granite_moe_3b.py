"""granite-moe-3b-a800m [moe] — MoE 40e top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base].

Note: the assignment line specifies "MoE 40e top-8" while its bracket
comment says "32 experts"; we follow the spec line (40 experts), which also
matches the 3B-total / 800M-active budget with d_ff=512 experts."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,                  # expert hidden size
    vocab=49155,
    n_experts=40,
    experts_per_token=8,
    moe_every=1,
    rope_theta=1e4,
    sliding_window=8192,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
