"""arctic-480b [moe] — 128 experts top-2 + dense residual FFN
[hf:Snowflake/snowflake-arctic-base]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,                 # dense-residual branch hidden size
    vocab=32000,
    n_experts=128,
    experts_per_token=2,
    moe_d_ff=4864,
    moe_every=1,
    dense_residual=True,       # arctic's dense+MoE parallel design
    rope_theta=1e4,
    sliding_window=8192,
    optimizer="sgdm",
    param_dtype="bfloat16",    # >60B: fp32 master state would exceed v5e HBM
    source="hf:Snowflake/snowflake-arctic-base",
)
