"""Config registry: the 10 assigned architectures + input shapes.

``get_config(name)`` returns the full published-size config;
``get_config(name, reduced=True)`` the smoke-test variant (2 scan blocks,
d_model <= 512, <= 4 experts) used by per-arch CPU smoke tests."""

from __future__ import annotations

from typing import Dict

from repro.configs import base
from repro.configs.base import (DECODE_32K, LONG_500K, PREFILL_32K, SHAPES,
                                TRAIN_4K, InputShape, ModelConfig, reduced)
from repro.configs.arctic_480b import CONFIG as ARCTIC_480B
from repro.configs.deepseek_67b import CONFIG as DEEPSEEK_67B
from repro.configs.granite_34b import CONFIG as GRANITE_34B
from repro.configs.granite_moe_3b import CONFIG as GRANITE_MOE_3B
from repro.configs.internvl2_1b import CONFIG as INTERNVL2_1B
from repro.configs.jamba_1_5_large_398b import CONFIG as JAMBA_1_5_LARGE
from repro.configs.mamba2_370m import CONFIG as MAMBA2_370M
from repro.configs.mistral_large_123b import CONFIG as MISTRAL_LARGE_123B
from repro.configs.qwen2_5_32b import CONFIG as QWEN2_5_32B
from repro.configs.whisper_base import CONFIG as WHISPER_BASE

ARCHS: Dict[str, ModelConfig] = {c.name: c for c in (
    MISTRAL_LARGE_123B, WHISPER_BASE, MAMBA2_370M, INTERNVL2_1B, DEEPSEEK_67B,
    GRANITE_34B, GRANITE_MOE_3B, QWEN2_5_32B, JAMBA_1_5_LARGE, ARCTIC_480B,
)}

# per-arch smoke-variant overrides (keep patterns but shrink periods)
REDUCED_OVERRIDES = {
    "jamba-1.5-large-398b": dict(attn_every=2, moe_every=2, scan_block=2,
                                 n_layers=4),
}


def get_config(name: str, reduced_variant: bool = False) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")
    cfg = ARCHS[name]
    if reduced_variant:
        return reduced(cfg, **REDUCED_OVERRIDES.get(name, {}))
    return cfg


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "InputShape", "get_config",
           "reduced", "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
           "base"]
