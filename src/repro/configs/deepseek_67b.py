"""deepseek-67b [dense] — llama-arch [arXiv:2401.02954]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=102400,
    rope_theta=1e4,
    sliding_window=8192,
    optimizer="sgdm",
    param_dtype="bfloat16",    # >60B: fp32 master state would exceed v5e HBM
    source="arXiv:2401.02954",
)
