"""whisper-base [audio] — enc-dec, conv frontend stubbed [arXiv:2212.04356].

``n_layers`` is the decoder depth; ``encoder_layers`` the encoder depth.
The mel-spectrogram + conv feature extractor is a STUB per the assignment
carve-out: ``input_specs()`` supplies precomputed frame embeddings
(B, encoder_seq, d_model)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    encoder_layers=6,
    encoder_seq=1500,          # 30 s of audio at 50 frames/s
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    mlp_type="gelu",
    norm_type="layernorm",
    qkv_bias=True,
    tie_embeddings=True,       # whisper ties decoder embed/unembed (74M total)
    sliding_window=8192,       # decoder self-attn window for long_500k
    source="arXiv:2212.04356",
)
