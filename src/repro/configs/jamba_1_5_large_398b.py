"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e
top-2 on alternate layers [arXiv:2403.19887].

Layer pattern (8-layer super-block, scanned 9x): layers 0-6 mamba, layer 7
attention; MoE FFN on odd layers, dense FFN on even layers."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    n_experts=16,
    experts_per_token=2,
    moe_every=2,
    attn_every=8,              # 1 attention per 8 layers (1:7)
    scan_block=8,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=128,
    ssm_groups=8,
    rope_theta=1e6,
    optimizer="sgdm",
    param_dtype="bfloat16",    # >60B: fp32 master state would exceed v5e HBM
    source="arXiv:2403.19887",
)
