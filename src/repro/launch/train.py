"""Training launcher.

Runs real steps (reduced configs on this host's devices) or, with
``--dryrun``, defers to ``repro.launch.dryrun`` for the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b --reduced \
      --steps 20 --policy fairk
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES, get_config
from repro.configs.base import InputShape
from repro.data.tokens import lm_batch
from repro.launch.steps import OacServerConfig, init_server_state, make_train_step
from repro.models import transformer as tr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen2.5-32b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--oac", action="store_true", default=True,
                    help="enable the FAIR-k OAC server phase")
    ap.add_argument("--no-oac", dest="oac", action="store_false")
    ap.add_argument("--rho", type=float, default=0.1)
    ap.add_argument("--per-leaf-server", action="store_true",
                    help="historical per-leaf OAC server phase (default: "
                         "persisted packed fused pass with in-kernel selection statistics, DESIGN.md §9-§11)")
    ap.add_argument("--ef", action="store_true",
                    help="error feedback: persist the unselected gradient "
                         "mass in a flat residual buffer and fold it back "
                         "next step (packed server phase only)")
    ap.add_argument("--one-bit", action="store_true",
                    help="one-bit server uplink: merge sign_mv-detected "
                         "signs of the effective gradient (combine with "
                         "--ef; packed server phase only)")
    ap.add_argument("--legacy-stats", action="store_true",
                    help="disable the fused in-kernel selection statistics "
                         "(restores the two-pass count accounting + "
                         "sampled-quantile bootstrap)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced_variant=args.reduced)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((1, n_dev), ("data", "model"))
    shape = InputShape("custom", args.seq, args.batch, "train")
    oac = (OacServerConfig(rho=args.rho, packed=not args.per_leaf_server,
                           error_feedback=args.ef, one_bit=args.one_bit,
                           fused_stats=not args.legacy_stats)
           if args.oac else None)
    bundle = make_train_step(cfg, shape, mesh, n_micro=1, oac=oac, lr=1e-3)

    key = jax.random.PRNGKey(args.seed)
    params = tr.init_lm(key, cfg)
    from repro.optim import make_optimizer
    opt = make_optimizer(bundle.meta["optimizer"], bundle.meta["lr"])
    opt_state = opt.init(params)
    server = init_server_state(params, mesh=mesh, cfg=cfg, oac=oac)

    # donate (params, opt_state, server): the persisted packed server
    # buffers (flat g_prev bf16 / age int8 / EF residual f32) are consumed
    # and rebuilt every step — donation makes the update fully in place
    step_fn = jax.jit(bundle.fn, donate_argnums=(0, 1, 2))
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M-param family "
          f"variant, {args.steps} steps, oac={'on' if args.oac else 'off'}")
    with mesh:
        for t in range(args.steps):
            toks, labels = lm_batch(args.seed * 1000 + t, args.batch,
                                    args.seq, cfg.vocab)
            batch = {"tokens": jnp.asarray(toks)[None],
                     "labels": jnp.asarray(labels)[None]}
            if cfg.family == "vlm":
                batch["embeds"] = jnp.zeros(
                    (1, args.batch, cfg.n_patches, cfg.d_model),
                    jnp.dtype(cfg.compute_dtype))
            if cfg.family == "audio":
                batch["frames"] = jnp.zeros(
                    (1, args.batch, cfg.encoder_seq, cfg.d_model),
                    jnp.dtype(cfg.compute_dtype))
            t0 = time.time()
            params, opt_state, server, loss = step_fn(
                params, opt_state, server, batch, jnp.asarray(t, jnp.int32))
            print(f"  step {t:3d} loss {float(loss):.4f} "
                  f"({time.time()-t0:.2f}s)", flush=True)
    print("[train] done")


if __name__ == "__main__":
    main()
