"""Training launcher.

Runs real steps (reduced configs on this host's devices) or, with
``--dryrun``, defers to ``repro.launch.dryrun`` for the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b --reduced \
      --steps 20 --policy fairk

Checkpointing (packed server phase): ``--ckpt-every N`` saves the
persisted flat server buffers (incl. the warm-start theta vector and the
adaptive-``k_M`` controller state) every N steps via
``repro.checkpoint.save_server_state``; a SIGTERM lands one final save
before the loop exits; ``--resume`` restores the latest checkpoint from
``--ckpt-dir`` and continues at the following step.
"""

from __future__ import annotations

import argparse
import os
import signal
import time
import zipfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.configs import ARCHS, SHAPES, get_config
from repro.configs.base import InputShape
from repro.data.tokens import lm_batch
from repro.launch import sharding as shlib
from repro.launch.steps import (OacServerConfig, init_server_state,
                                make_train_step, server_layout)
from repro.models import transformer as tr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen2.5-32b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--oac", action="store_true", default=True,
                    help="enable the FAIR-k OAC server phase")
    ap.add_argument("--no-oac", dest="oac", action="store_false")
    ap.add_argument("--rho", type=float, default=0.1)
    ap.add_argument("--per-leaf-server", action="store_true",
                    help="historical per-leaf OAC server phase (default: "
                         "persisted packed fused pass with in-kernel selection statistics, DESIGN.md §9-§11)")
    ap.add_argument("--ef", action="store_true",
                    help="error feedback: persist the unselected gradient "
                         "mass in a flat residual buffer and fold it back "
                         "next step (packed server phase only)")
    ap.add_argument("--one-bit", action="store_true",
                    help="one-bit server uplink: merge sign_mv-detected "
                         "signs of the effective gradient (combine with "
                         "--ef; packed server phase only)")
    ap.add_argument("--legacy-stats", action="store_true",
                    help="disable the fused in-kernel selection statistics "
                         "(restores the two-pass count accounting + "
                         "sampled-quantile bootstrap)")
    ap.add_argument("--async-agg", action="store_true",
                    help="asynchronous double-buffered server rounds "
                         "(DESIGN.md §13): the optimizer consumes the "
                         "previous round's merged gradient so the fused "
                         "pass overlaps the next round's compute; "
                         "straggler contributions defer one round via the "
                         "shadow buffer (packed server phase only)")
    ap.add_argument("--straggler-frac", type=float, default=0.25,
                    help="fraction of coordinates whose uplink arrives one "
                         "aggregation late under --async-agg")
    ap.add_argument("--adaptive-km", action="store_true",
                    help="adapt the k_M/k split online INSIDE the compiled "
                         "step (core/controller.py: the kernel-emitted age "
                         "histogram drives a traced split — zero host "
                         "syncs, zero recompiles; packed server phase "
                         "only)")
    ap.add_argument("--sanitize", action="store_true",
                    help="graceful degradation (DESIGN.md §14): mask "
                         "non-finite gradient coordinates out of the "
                         "fused selection — a crashed host's NaN/Inf "
                         "uplink is 'unsent' (age climbs, EF residual "
                         "rides through) instead of poisoning the model "
                         "(packed server phase only)")
    ap.add_argument("--fade", type=float, default=0.0,
                    help="per-round deep-fade erasure probability on the "
                         "aggregated uplink, at --fade-block granularity "
                         "(needs --sanitize)")
    ap.add_argument("--fade-block", type=int, default=128,
                    help="coordinates per deep-fade block (one OFDM "
                         "symbol group's worth)")
    ap.add_argument("--population", type=int, default=0,
                    help="virtual client-population size (DESIGN.md §15): "
                         "per-round availability, cohort participation, "
                         "mid-round churn erasures and (under --async-agg) "
                         "the traced straggler share all derive from a "
                         "stateless population of this many clients "
                         "(0 = off; needs --sanitize)")
    ap.add_argument("--cohorts", type=int, default=4096,
                    help="cohort batch size of the packed population "
                         "state (clients per packed row)")
    ap.add_argument("--participants", type=int, default=16,
                    help="clients the server samples per round from the "
                         "live population")
    ap.add_argument("--avail", type=float, default=0.9,
                    help="stationary per-client availability of the "
                         "population")
    ap.add_argument("--diurnal", action="store_true",
                    help="diurnal availability: the population's rate "
                         "rides a sinusoid (period --diurnal-period, "
                         "swing --diurnal-depth) whose time-average stays "
                         "at --avail")
    ap.add_argument("--diurnal-period", type=int, default=96,
                    help="rounds per diurnal cycle")
    ap.add_argument("--diurnal-depth", type=float, default=0.1,
                    help="relative swing of the diurnal availability rate")
    ap.add_argument("--channel", action="store_true",
                    help="geometric wireless channel (DESIGN.md §16): "
                         "per-block AR(1) Rayleigh fading with truncated "
                         "channel inversion — blocks in outage erase "
                         "through the sanitize path and the persisted "
                         "fading chain rides the server checkpoints "
                         "(needs --sanitize)")
    ap.add_argument("--pmax", type=float, default=10.0,
                    help="per-client transmit power budget of --channel "
                         "(inverting a gain below 1/pmax is infeasible)")
    ap.add_argument("--gmin", type=float, default=0.05,
                    help="designed truncation threshold of --channel on "
                         "the instantaneous gain")
    ap.add_argument("--csi-err", type=float, default=0.0,
                    help="residual channel-estimation error std of "
                         "--channel: multiplicative per-block "
                         "misalignment on the fresh aggregate")
    ap.add_argument("--fading-corr", type=float, default=0.5,
                    help="Gauss-Markov AR(1) fading correlation of "
                         "--channel in [0, 1) (0 = memoryless)")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="save the packed server state every N steps "
                         "(0 = off; a SIGTERM always lands one final "
                         "save when > 0)")
    ap.add_argument("--ckpt-dir", default="checkpoints",
                    help="directory for server_<step>.npz checkpoints")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest server checkpoint from "
                         "--ckpt-dir and continue at the next step")
    ap.add_argument("--client-chunk", type=int, default=0,
                    help="streaming client aggregation (DESIGN.md §17): "
                         "split the global batch into this many simulated "
                         "client microbatches and accumulate their "
                         "gradients chunk by chunk inside the compiled "
                         "step — gradient memory scales with the chunk, "
                         "not the client count (0 = one fused batch)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced_variant=args.reduced)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((1, n_dev), ("data", "model"))
    shape = InputShape("custom", args.seq, args.batch, "train")
    population = None
    if args.population > 0:
        from repro.core.population import PopulationConfig
        population = PopulationConfig(
            n_clients=args.population, cohort_size=args.cohorts,
            participants=args.participants, avail=args.avail,
            mode="diurnal" if args.diurnal else "iid",
            period=args.diurnal_period, depth=args.diurnal_depth,
            slow_frac=(args.straggler_frac if args.async_agg else 0.0))
    wireless = None
    if args.channel:
        from repro.core.channel import ChannelConfig
        wireless = ChannelConfig(pmax=args.pmax, gmin=args.gmin,
                                 csi_err=args.csi_err,
                                 rho_f=args.fading_corr,
                                 block=args.fade_block)
    oac = (OacServerConfig(rho=args.rho, packed=not args.per_leaf_server,
                           error_feedback=args.ef, one_bit=args.one_bit,
                           fused_stats=not args.legacy_stats,
                           adaptive_km=args.adaptive_km,
                           async_agg=args.async_agg,
                           straggler_frac=args.straggler_frac,
                           sanitize=args.sanitize, fade=args.fade,
                           fade_block=args.fade_block,
                           population=population, wireless=wireless)
           if args.oac else None)
    n_micro = args.client_chunk or 1
    if args.batch % n_micro:
        raise ValueError(f"--client-chunk {args.client_chunk} must divide "
                         f"--batch {args.batch}")
    bundle = make_train_step(cfg, shape, mesh, n_micro=n_micro,
                             client_chunk=(args.client_chunk or None),
                             oac=oac, lr=1e-3)

    key = jax.random.PRNGKey(args.seed)
    params = tr.init_lm(key, cfg)
    from repro.optim import make_optimizer
    opt = make_optimizer(bundle.meta["optimizer"], bundle.meta["lr"])
    opt_state = opt.init(params)
    server = init_server_state(params, mesh=mesh, cfg=cfg, oac=oac)

    # checkpointing (packed server state only: the flat persisted buffers
    # ARE the cross-step state worth resuming; params/opt ride the generic
    # repro.checkpoint.save when needed)
    ckpt_on = args.ckpt_every > 0 or args.resume
    if ckpt_on and (oac is None or not oac.packed):
        raise ValueError("--ckpt-every/--resume checkpoint the PACKED "
                         "server buffers — they need --oac and are "
                         "incompatible with --per-leaf-server")
    layout = (server_layout(params, shlib.param_pspecs(params, cfg, mesh),
                            mesh) if ckpt_on else None)
    start = 0
    if args.resume:
        candidates = checkpoint.server_steps(args.ckpt_dir)
        if not candidates:
            # legitimate on the FIRST launch of a preemptible job, but
            # never silent: a mistyped --ckpt-dir must not masquerade as
            # a continued trajectory
            print(f"[train] --resume: no server checkpoint under "
                  f"{args.ckpt_dir!r} — starting fresh at step 0",
                  flush=True)
        else:
            # newest first, walking back past corrupt checkpoints: the
            # content checksums (checkpoint.io) catch bit rot / torn
            # writes, and a server_<N>.npz without its params/opt
            # companion is the same torn-save species.  Config
            # mismatches (layout / field-set ValueErrors) still raise —
            # falling back cannot fix a wrong flag.
            restored = False
            for last in candidates:
                srv_path = os.path.join(args.ckpt_dir,
                                        f"server_{last:08d}.npz")
                step_path = os.path.join(args.ckpt_dir,
                                         f"step_{last:08d}.npz")
                try:
                    srv_np, _ = checkpoint.restore_server_state(
                        srv_path, layout=layout)
                    if not os.path.exists(step_path):
                        raise checkpoint.CorruptCheckpointError(
                            f"{srv_path} has no matching "
                            f"step_{last:08d}.npz (params/optimizer) — "
                            "torn save")
                    tree = checkpoint.restore(step_path,
                                              like={"params": params,
                                                    "opt": opt_state})
                except (checkpoint.CorruptCheckpointError,
                        zipfile.BadZipFile, OSError) as err:
                    print(f"[train] --resume: checkpoint step {last} "
                          f"failed validation ({err}); falling back to "
                          "the previous checkpoint", flush=True)
                    continue
                # reconcile the checkpoint field set with the configured
                # one: pre-async checkpoints migrate (cold zero
                # double-buffers) when resuming under --async-agg; any
                # other flag mismatch raises with the offending fields
                # named
                srv_np = checkpoint.migrate_server_state(srv_np,
                                                         like=server)
                server = {k: jnp.asarray(v) for k, v in srv_np.items()}
                # the server buffers describe the OLD model's gradient
                # stream — resuming them onto re-randomized weights would
                # merge a stale trajectory into a fresh one, so
                # params/opt ride the same checkpoint step
                params = jax.tree.map(jnp.asarray, tree["params"])
                opt_state = jax.tree.map(jnp.asarray, tree["opt"])
                start = last
                restored = True
                print(f"[train] resumed server + params/opt state from "
                      f"step {last} ({args.ckpt_dir})")
                break
            if not restored:
                raise ValueError(
                    f"--resume: every checkpoint under "
                    f"{args.ckpt_dir!r} failed validation "
                    f"(tried steps {candidates}) — refusing to silently "
                    "restart the trajectory from scratch")

    # a SIGTERM (preemption) finishes the in-flight step, saves once, and
    # exits the loop cleanly
    stop = {"sig": False}

    def _on_term(signum, frame):
        stop["sig"] = True

    signal.signal(signal.SIGTERM, _on_term)

    def save(step):
        path = checkpoint.save_server_state(args.ckpt_dir, server,
                                            layout=layout, step=step)
        # params/opt accompany every server checkpoint (closure reads the
        # loop's latest bindings) so --resume continues ONE trajectory
        checkpoint.save(args.ckpt_dir, {"params": params,
                                        "opt": opt_state}, step=step)
        print(f"  [ckpt] saved {path} (+ step_{step:08d}.npz)", flush=True)

    # donate (params, opt_state, server): the persisted packed server
    # buffers (flat g_prev bf16 / age int8 / EF residual f32 / controller
    # vec) are consumed and rebuilt every step — donation makes the
    # update fully in place
    step_fn = jax.jit(bundle.fn, donate_argnums=(0, 1, 2))
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M-param family "
          f"variant, {args.steps} steps, oac={'on' if args.oac else 'off'}")
    with mesh:
        for t in range(start, start + args.steps):
            toks, labels = lm_batch(args.seed * 1000 + t, args.batch,
                                    args.seq, cfg.vocab)
            mb = args.batch // n_micro
            batch = {"tokens": jnp.asarray(toks).reshape(
                         (n_micro, mb, args.seq)),
                     "labels": jnp.asarray(labels).reshape(
                         (n_micro, mb, args.seq))}
            if cfg.family == "vlm":
                batch["embeds"] = jnp.zeros(
                    (n_micro, mb, cfg.n_patches, cfg.d_model),
                    jnp.dtype(cfg.compute_dtype))
            if cfg.family == "audio":
                batch["frames"] = jnp.zeros(
                    (n_micro, mb, cfg.encoder_seq, cfg.d_model),
                    jnp.dtype(cfg.compute_dtype))
            t0 = time.time()
            params, opt_state, server, loss = step_fn(
                params, opt_state, server, batch, jnp.asarray(t, jnp.int32))
            print(f"  step {t:3d} loss {float(loss):.4f} "
                  f"({time.time()-t0:.2f}s)", flush=True)
            if ckpt_on and args.ckpt_every > 0 and (
                    (t + 1 - start) % args.ckpt_every == 0):
                save(t + 1)
            if stop["sig"]:
                if ckpt_on:
                    save(t + 1)
                print("[train] SIGTERM — state saved, exiting", flush=True)
                break
    print("[train] done")


if __name__ == "__main__":
    main()
