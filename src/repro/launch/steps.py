"""Sharded step builders: train / prefill / serve, plus the FL-OAC step.

Two integrations of the paper's technique (DESIGN.md §3, §5):

* ``make_train_step`` — the production trainer for the 10 assigned
  architectures.  Gradients flow through the standard 2-D FSDPxTP backward
  (XLA inserts the data-axis reduction = the multiple-access superposition);
  the OAC server phase then runs inside a fully-manual ``shard_map``.  By
  default (``OacServerConfig.packed``) each shard packs its local pytree
  into ONE lane-aligned flat buffer (core.packing) and runs a single fused
  threshold-FAIR-k pass with globally consistent (θ_M, θ_A) — pmean'd
  across shards, two scalars — and warm-start thresholds that skip the
  quantile pass on steady-state rounds.  ``packed=False`` keeps the
  historical per-leaf loop (one quantile estimation + kernel launch per
  leaf) for comparison; benchmarks/packed_bench.py measures the gap.

* ``make_fl_oac_step`` — the paper's own regime at its own scale: every mesh
  device is one FL client holding a full model replica; FAIR-k is applied at
  *waveform-group* (block) granularity — mirroring the prototype's OFDM
  symbol groups — and ONLY the selected blocks are all-reduced.  The
  collective volume drops from d to rho*d, which the roofline table
  measures directly (compare ``baseline=True``).

Every scan body is annotated via known_trip_count in the compiled HLO, which
``repro.roofline`` reads back for loop-aware FLOP/byte accounting.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from math import prod as np_prod
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import InputShape, ModelConfig
from repro.core import channel as chan
from repro.core import controller as budget
from repro.core import faults
from repro.core import packing
from repro.core import population as pop_mod
from repro.core.engine import (AGE_CAP, EngineConfig, SelectionEngine,
                               fair_k_masks_dynamic, index_jitter,
                               sampled_thresholds, threshold_mask,
                               traced_km)
from repro.launch import sharding as shlib
from repro.launch.mesh import axis_size, batch_axes
from repro.models import transformer as tr
from repro.optim import make_optimizer

Array = jax.Array
SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class OacServerConfig:
    """FAIR-k server-side compression settings for the big-model trainer."""
    rho: float = 0.1               # selection budget k/d
    k_m_frac: float = 0.75         # magnitude share of the budget
    noise_std: float = 0.0         # channel noise sigma_z (post-aggregation)
    n_clients: int = 16            # N in Eq. (7) (= data shards)
    sample_cap: int = 65536        # quantile sample size (per leaf when
                                   # packed=False, per shard when packed)
    packed: bool = True            # ONE fused FAIR-k pass over the whole
                                   # local pytree (core.packing) instead of
                                   # the historical per-leaf loop; server
                                   # state persists as flat lane-aligned
                                   # buffers across steps (no per-round
                                   # re-pack of g_prev / age)
    warm_start: bool = True        # carry (θ_M, θ_A) across rounds; skip
                                   # the quantile pass on steady-state
                                   # rounds (packed path only)
    fused_stats: bool = True       # emit the warm-start counts and the
                                   # threshold-re-estimation histograms
                                   # from INSIDE the fused kernel
                                   # (DESIGN.md §11): the kernel becomes
                                   # the round's only read of the packed
                                   # gradient buffer.  Step 0 transmits
                                   # everything once (no histogram yet).
                                   # False restores the legacy two-pass
                                   # accounting + quantile bootstrap.
    error_feedback: bool = False   # fold the unselected gradient mass back
                                   # next step (EF-SGD): a persisted flat
                                   # f32 residual buffer rides the fused
                                   # kernel's residual stage (packed only)
    adaptive_km: bool = False      # in-graph adaptive k_M/k split
                                   # (core/controller.py): the controller
                                   # state rides in the server state as a
                                   # replicated flat vector, the engine
                                   # consumes the split as a traced value,
                                   # and the update runs INSIDE the
                                   # compiled step off the kernel-emitted
                                   # age/magnitude histograms — zero host
                                   # syncs, zero recompiles across split
                                   # changes (packed + fused_stats only)
    async_agg: bool = False        # asynchronous double-buffered rounds
                                   # (DESIGN.md §13): the optimizer consumes
                                   # the PREVIOUS round's merged gradient
                                   # (persisted ``pending`` buffer) so round
                                   # t's pack -> fused kernel -> unpack
                                   # overlaps round t+1's client compute;
                                   # straggler OAC contributions land in the
                                   # NEXT round's merge via the persisted
                                   # ``shadow`` buffer, with their extra age
                                   # recorded in the carried age buffer
                                   # (engine ``age_lag``) so the adaptive
                                   # controller absorbs the staleness online
                                   # (packed only; off == bit-exact with the
                                   # synchronous trajectory)
    straggler_frac: float = 0.25   # fraction of coordinates whose uplink
                                   # contribution arrives one aggregation
                                   # late (deterministic Knuth-hash pattern
                                   # — reproducible, trace-static)
    straggler_lag: int = 1         # delivery lag (rounds) of the straggler
                                   # contributions; shifts the post-merge
                                   # age of every selected coordinate and
                                   # translates the Lemma-1 target by the
                                   # same amount (core.markov
                                   # shifted_aou_distribution)
    sanitize: bool = False         # graceful degradation (DESIGN.md §14):
                                   # the fused pass masks non-finite score
                                   # coordinates out of BOTH selection
                                   # stages — a crashed host's NaN/Inf
                                   # uplink garbage is semantically
                                   # "unsent" (age keeps climbing, EF
                                   # residual passes through) instead of
                                   # poisoning the merged gradient and the
                                   # optimizer state.  Off (default) keeps
                                   # the trace bit-exact with the
                                   # historical graph (packed only).
    fade: float = 0.0              # per-round deep-fade erasure
                                   # probability on the aggregated uplink,
                                   # at ``fade_block`` granularity (one
                                   # OFDM symbol group's worth of
                                   # coordinates per fade, paper Sec. II);
                                   # erased coordinates ride the same
                                   # sanitize path (needs ``sanitize``)
    fade_block: int = 128          # coordinates per fade block
    one_bit: bool = False          # one-bit uplink for the server phase:
                                   # the merged fresh values are the SIGNS
                                   # of the effective gradient, detected by
                                   # the sign_mv kernel from the (noisy)
                                   # energy (Sec. V-B).  Unlike the FL sim
                                   # (per-client votes) the trainer's
                                   # backward has already superposed the
                                   # data shards, so the vote matrix is the
                                   # single aggregate row; selection still
                                   # scores |g + residual| (the server has
                                   # the magnitudes).  Combine with
                                   # error_feedback so the quantization
                                   # error is re-injected (packed only).
    population: Optional[pop_mod.PopulationConfig] = None
                                   # population-scale churn for the
                                   # production trainer (DESIGN.md §15),
                                   # STATELESS: the memoryless modes (iid,
                                   # diurnal) recompute the round's
                                   # availability as a pure counter-based
                                   # function of (base key, round seed), so
                                   # no chain state rides the checkpointed
                                   # server buffers.  A total cohort outage
                                   # erases the round, mid-round churn
                                   # erases symbol blocks through the
                                   # sanitize path, and under ``async_agg``
                                   # the straggler pattern's threshold
                                   # becomes the round's TRACED population
                                   # slow-share instead of the fixed
                                   # ``straggler_frac``.  Needs packed +
                                   # sanitize; ``mode="ge"`` carries chain
                                   # state and is sim-trainer-only.
    wireless: Optional[chan.ChannelConfig] = None
                                   # geometric wireless channel (DESIGN.md
                                   # §16) in aggregate-equivalent form:
                                   # the pre-aggregated gradient has no
                                   # per-client axis, so one AR(1)
                                   # Rayleigh fading chain per
                                   # ``wireless.block`` symbol group
                                   # rides the persisted server state
                                   # (``fad`` — checkpoint-migratable,
                                   # the cold start is a deterministic
                                   # stationary draw) and each round
                                   # erases the blocks whose gain falls
                                   # below the threshold calibrated to
                                   # the truncation-outage rate
                                   # ``wireless.thin``; imperfect CSI
                                   # multiplies the fresh aggregate by a
                                   # per-block misalignment factor.
                                   # Elementwise only — the fused pass
                                   # stays the round's single read of
                                   # the packed gradient buffer.  Needs
                                   # packed + sanitize; composes with
                                   # fade / population / async_agg.


@dataclasses.dataclass
class StepBundle:
    """Everything the dry-run / launcher needs for one compiled step."""
    fn: Callable
    in_shardings: Any
    out_shardings: Any
    input_specs: Tuple          # SDS pytree, positional
    meta: Dict[str, Any]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(functools.partial(tr.init_lm, cfg=cfg), key)


def _batch_parts(cfg: ModelConfig, shape: InputShape, mesh,
                 n_micro: Optional[int]) -> Tuple[int, int, int]:
    b_axes = batch_axes(mesh)
    n_shards = axis_size(mesh, b_axes)
    gb = shape.global_batch
    if n_micro is None:
        n_micro = max(1, gb // n_shards)       # 1 sample / shard / microstep
    if gb % n_micro:
        raise ValueError(f"global batch {gb} not divisible by n_micro {n_micro}")
    return n_micro, gb // n_micro, n_shards


def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    return seq_len - cfg.n_patches if cfg.family == "vlm" else seq_len


def train_input_specs(cfg: ModelConfig, shape: InputShape, n_micro: int,
                      mb: int) -> Dict[str, SDS]:
    s_text = _text_len(cfg, shape.seq_len)
    specs = {
        "tokens": SDS((n_micro, mb, s_text), jnp.int32),
        "labels": SDS((n_micro, mb, s_text), jnp.int32),
    }
    if cfg.family == "vlm":
        specs["embeds"] = SDS((n_micro, mb, cfg.n_patches, cfg.d_model),
                              jnp.dtype(cfg.compute_dtype))
    if cfg.family == "audio":
        specs["frames"] = SDS((n_micro, mb, cfg.encoder_seq, cfg.d_model),
                              jnp.dtype(cfg.compute_dtype))
    return specs


def _batch_pspecs(cfg: ModelConfig, gb: int, mesh, micro: bool) -> Dict:
    mk = lambda extra: shlib.batch_pspec(gb, mesh, extra_dims=extra,
                                         leading_micro=micro)
    specs = {"tokens": mk(1), "labels": mk(1)}
    if cfg.family == "vlm":
        specs["embeds"] = mk(2)
    if cfg.family == "audio":
        specs["frames"] = mk(2)
    return specs


def fairk_threshold_masks(g_flat: Array, age_flat: Array,
                          oac: OacServerConfig, sample_cap: int
                          ) -> Tuple[Array, Array]:
    """Scalable FAIR-k: sampled-quantile thresholds instead of global sort.

    Stage M: |g| >= theta_M  (theta_M ~ (1 - rho*k_m_frac) quantile of |g|).
    Stage A: among the rest, age+jitter >= theta_A sized to rho*(1-k_m_frac).
    Returns (mask selected, mask_m).  Thin wrapper over the SelectionEngine
    threshold primitives (core.engine) — kept as the launch-facing name."""
    theta_m, theta_a = sampled_thresholds(
        g_flat, age_flat, rho=oac.rho, k_m_frac=oac.k_m_frac,
        sample_cap=sample_cap)
    return threshold_mask(g_flat, age_flat, theta_m, theta_a)


def _leaf_engine(oac: OacServerConfig, n: int) -> SelectionEngine:
    """Threshold-backend engine for one parameter leaf of ``n`` elements."""
    return SelectionEngine(
        EngineConfig(policy="fairk", backend="threshold", rho=oac.rho,
                     k_m_frac=oac.k_m_frac, sample_cap=oac.sample_cap,
                     noise_std=oac.noise_std, n_clients=oac.n_clients), n)


def _leaf_server_update(g: Array, g_prev: Array, age: Array, key: Array,
                        oac: OacServerConfig) -> Tuple[Array, Array, Array]:
    """Per-leaf (local shard) FAIR-k server phase.  Returns
    (reconstructed gradient g_t, new g_prev, new age)."""
    shape = g.shape
    gf = g.reshape(-1)
    eng = _leaf_engine(oac, gf.shape[0])
    g_t, age_next, _ = eng.select_and_merge(
        gf, g_prev.reshape(-1), age.reshape(-1),
        key=key if oac.noise_std > 0.0 else None)
    return (g_t.reshape(shape), g_t.astype(g_prev.dtype).reshape(shape),
            age_next.astype(jnp.int8).reshape(shape))


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def _local_shape(shape: Tuple[int, ...], spec, mesh) -> Tuple[int, ...]:
    """Per-shard shape of a global array under a PartitionSpec (dims that
    don't divide are never sharded — param_pspecs guarantees it)."""
    dims = list(shape)
    for i, entry in enumerate(tuple(spec)):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for ax in axes:
            n *= mesh.shape[ax]
        dims[i] //= n
    return tuple(dims)


def server_layout(params_abs: Any, p_specs: Any, mesh
                  ) -> packing.PackedLayout:
    """The per-shard ``PackedLayout`` of the persisted packed server state:
    identical to what ``PackedLayout.from_tree(local_grads)`` builds inside
    ``shard_map`` (same flatten order, local shard shapes)."""
    leaves, treedef = jax.tree_util.tree_flatten(params_abs)
    specs = treedef.flatten_up_to(p_specs)
    local = [SDS(_local_shape(l.shape, s, mesh), l.dtype)
             for l, s in zip(leaves, specs)]
    return packing.PackedLayout.from_tree(
        jax.tree_util.tree_unflatten(treedef, local))


def _mesh_devices(mesh) -> int:
    n = 1
    for ax in mesh.axis_names:
        n *= mesh.shape[ax]
    return n


def init_server_state(params: Any, mesh=None, cfg: ModelConfig = None,
                      oac: Optional[OacServerConfig] = OacServerConfig()
                      ) -> Dict:
    """OAC server state matching ``make_train_step``'s expectations.

    Packed flavour (``oac.packed``, the default — needs ``mesh`` + ``cfg``):
    the state IS the lane-aligned flat buffers, persisted end-to-end —
    ``g`` (d,) bf16, ``age`` (d,) int8 with the PAD_AGE sentinel in the
    lane-alignment pads, optionally ``res`` (d,) f32 (error feedback), and
    the replicated warm-start ``theta`` vector (DESIGN.md §9-§10), where
    d = n_devices * d_packed_per_shard.  Only the fresh gradients are
    packed each step; g_prev/age are never re-packed from trees.

    Per-leaf flavour (``oac is None`` or ``oac.packed=False``): the
    historical tree state — g_prev bf16 / age int8 per parameter leaf."""
    if oac is not None and oac.packed:
        if mesh is None or cfg is None:
            raise ValueError("packed server state needs (mesh, cfg) to "
                             "derive the per-shard layout — pass "
                             "init_server_state(params, mesh, cfg) or use "
                             "OacServerConfig(packed=False)")
        p_specs = shlib.param_pspecs(params, cfg, mesh)
        lay = server_layout(params, p_specs, mesh)
        n = _mesh_devices(mesh)
        age_local = np.asarray(lay.init_age(jnp.int8))
        state = {
            "g": jnp.zeros((n * lay.d_packed,), jnp.bfloat16),
            "age": jnp.asarray(np.tile(age_local, n)),
            "theta": jnp.zeros((packing.THRESHOLD_STATE_SIZE,),
                               jnp.float32),
        }
        if oac.error_feedback:
            state["res"] = jnp.zeros((n * lay.d_packed,), jnp.float32)
        if oac.adaptive_km:
            state["ctrl"] = budget.controller_state_to_vec(
                budget.init_controller_state(oac.k_m_frac))
        if oac.async_agg:
            # double-buffer lifecycle (DESIGN.md §13): ``pending`` holds the
            # merged gradient the NEXT optimizer step consumes; ``shadow``
            # holds the straggler contribution deferred into the next merge.
            # Both start cold (zeros): round 0 applies a zero update.
            state["shadow"] = jnp.zeros((n * lay.d_packed,), jnp.bfloat16)
            state["pending"] = jnp.zeros((n * lay.d_packed,), jnp.bfloat16)
        if oac.wireless is not None:
            # per-block AR(1) fading chains (DESIGN.md §16), 2 floats per
            # symbol block per shard.  The cold start is the DETERMINISTIC
            # stationary draw (a pure function of the global block count —
            # see channel.init_block_fading), so migrating a pre-channel
            # checkpoint re-synthesizes this exact state.
            state["fad"] = chan.init_block_fading(
                n * chan.n_blocks(lay.d_packed, oac.wireless))
        return state
    return {
        "g": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params),
        "age": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.int8), params),
        "theta": jnp.zeros((packing.THRESHOLD_STATE_SIZE,), jnp.float32),
    }


def abstract_server_state(params_abs: Any, mesh=None, p_specs: Any = None,
                          oac: Optional[OacServerConfig] = None) -> Dict:
    if oac is not None and oac.packed:
        lay = server_layout(params_abs, p_specs, mesh)
        d = _mesh_devices(mesh) * lay.d_packed
        state = {"g": SDS((d,), jnp.bfloat16), "age": SDS((d,), jnp.int8),
                 "theta": SDS((packing.THRESHOLD_STATE_SIZE,),
                              jnp.float32)}
        if oac.error_feedback:
            state["res"] = SDS((d,), jnp.float32)
        if oac.adaptive_km:
            state["ctrl"] = SDS((budget.CONTROLLER_STATE_SIZE,),
                                jnp.float32)
        if oac.async_agg:
            state["shadow"] = SDS((d,), jnp.bfloat16)
            state["pending"] = SDS((d,), jnp.bfloat16)
        if oac.wireless is not None:
            state["fad"] = SDS(
                (2 * _mesh_devices(mesh)
                 * chan.n_blocks(lay.d_packed, oac.wireless),), jnp.float32)
        return state
    return {
        "g": jax.tree.map(lambda p: SDS(p.shape, jnp.bfloat16), params_abs),
        "age": jax.tree.map(lambda p: SDS(p.shape, jnp.int8), params_abs),
        "theta": SDS((packing.THRESHOLD_STATE_SIZE,), jnp.float32),
    }


def _with_expert_axis(cfg: ModelConfig, mesh) -> ModelConfig:
    """Pin expert tensors to the model axis when E divides it (SS Perf)."""
    model_n = mesh.shape["model"]
    if (cfg.n_experts and not cfg.expert_shard_axis
            and cfg.n_experts % model_n == 0
            and cfg.n_experts >= 2 * model_n):
        # measured: helps when devices hold >= 2 experts (arctic: coll -43%,
        # mem -18%); REGRESSES at 1 expert/device (jamba: compute 4x) where
        # GSPMD's unpinned plan was already better -> gated.
        return dataclasses.replace(cfg, expert_shard_axis="model")
    return cfg


def make_train_step(cfg: ModelConfig, shape: InputShape, mesh, *,
                    n_micro: Optional[int] = None,
                    client_chunk: Optional[int] = None,
                    oac: Optional[OacServerConfig] = OacServerConfig(),
                    opt_name: Optional[str] = None,
                    lr=1e-3,
                    sequence_parallel: bool = True,
                    gather_dtype: Optional[str] = None) -> StepBundle:
    cfg = _with_expert_axis(cfg, mesh)
    n_micro, mb, n_shards = _batch_parts(cfg, shape, mesh, n_micro)
    if client_chunk is not None and (
            client_chunk < 1 or n_micro % client_chunk):
        raise ValueError(
            f"client_chunk must divide n_micro ({n_micro}), got "
            f"{client_chunk}")
    opt = make_optimizer(opt_name or cfg.optimizer, lr)

    params_abs = abstract_params(cfg)
    p_specs = shlib.param_pspecs(params_abs, cfg, mesh)
    opt_abs = jax.eval_shape(opt.init, params_abs)
    o_specs = shlib.opt_pspecs(opt_abs, p_specs)
    if oac is not None and oac.error_feedback and not oac.packed:
        raise ValueError("error_feedback needs the packed server phase "
                         "(the residual is a flat persisted buffer)")
    if oac is not None and oac.one_bit and not oac.packed:
        raise ValueError("one_bit needs the packed server phase (the sign "
                         "vector is detected on the flat packed buffer)")
    if oac is not None and oac.adaptive_km and not (oac.packed
                                                    and oac.fused_stats):
        raise ValueError("adaptive_km consumes the kernel-emitted age/"
                         "magnitude histograms — it needs the packed "
                         "server phase with fused_stats")
    if oac is not None and oac.sanitize and not oac.packed:
        raise ValueError("sanitize rides the fused kernel's masking stage "
                         "— it needs the packed server phase")
    if oac is not None and oac.fade > 0.0 and not oac.sanitize:
        raise ValueError("fade erasures degrade through the sanitize "
                         "path — set OacServerConfig(sanitize=True)")
    if oac is not None and oac.async_agg:
        if not oac.packed:
            raise ValueError("async_agg double-buffers the PACKED server "
                             "state (flat shadow/pending buffers) — it "
                             "needs the packed server phase")
        if not 0.0 <= oac.straggler_frac <= 1.0:
            raise ValueError(f"straggler_frac must be in [0, 1], got "
                             f"{oac.straggler_frac}")
        if oac.straggler_lag < 1:
            raise ValueError(f"straggler_lag must be >= 1, got "
                             f"{oac.straggler_lag}")
    if oac is not None and oac.population is not None:
        if not (oac.packed and oac.sanitize):
            raise ValueError("population churn erasures degrade through "
                             "the fused kernel's sanitize path — set "
                             "OacServerConfig(packed=True, sanitize=True)")
        if oac.one_bit:
            raise ValueError("population churn on the one-bit uplink is "
                             "not modelled — run population with "
                             "one_bit=False")
        if oac.population.mode == "ge":
            raise ValueError("the launch population is stateless (iid | "
                             "diurnal — recomputed per round from the "
                             "seed); Gilbert–Elliott bursts carry chain "
                             "state and run in the FL sim trainer only")
        if oac.population.slow_frac > 0.0 and not oac.async_agg:
            raise ValueError("population stragglers land through the "
                             "async shadow buffer — slow_frac > 0 needs "
                             "OacServerConfig(async_agg=True)")
    if oac is not None and oac.wireless is not None:
        if not (oac.packed and oac.sanitize):
            raise ValueError("wireless truncation outages degrade through "
                             "the fused kernel's sanitize path on the "
                             "packed buffers — set "
                             "OacServerConfig(packed=True, sanitize=True)")
    srv_abs = abstract_server_state(params_abs, mesh=mesh, p_specs=p_specs,
                                    oac=oac)
    srv_specs = shlib.server_pspecs(
        p_specs, mesh=mesh,
        packed=(oac is not None and oac.packed),
        error_feedback=(oac is not None and oac.error_feedback),
        adaptive_km=(oac is not None and oac.adaptive_km),
        async_agg=(oac is not None and oac.async_agg),
        wireless=(oac is not None and oac.wireless is not None))
    b_specs = _batch_pspecs(cfg, mb, mesh, micro=True)
    in_specs_batch = train_input_specs(cfg, shape, n_micro, mb)

    b_axes = batch_axes(mesh)
    seq_sp = _text_len(cfg, shape.seq_len) + (cfg.n_patches or 0)

    if sequence_parallel and seq_sp % mesh.shape["model"] == 0:
        sp_sharding = NamedSharding(
            mesh, P(b_axes if mb % n_shards == 0 else None, "model", None))

        def residual_fn(x):
            return jax.lax.with_sharding_constraint(x, sp_sharding)
    else:
        residual_fn = None

    def loss_micro(params, mbatch):
        return tr.loss_fn(params, cfg, mbatch, residual_fn=residual_fn)

    grad_fn = jax.value_and_grad(loss_micro, has_aux=True)

    if oac is not None:
        oac = dataclasses.replace(oac, n_clients=n_shards)
        if oac.wireless is not None:
            # the data shards ARE the radio clients: the deployment
            # geometry (path gains, outage rates, thin) follows the mesh
            oac = dataclasses.replace(
                oac, wireless=dataclasses.replace(oac.wireless,
                                                  n_clients=n_shards))
        mesh_axes = tuple(mesh.axis_names)
        # adaptive split: one controller per step builder — the Lemma-1
        # target table is static data baked at build time.  Under async
        # aggregation the stationary AoU pmf is the synchronous Lemma-1
        # pmf translated by the straggler lag (core.markov
        # shifted_aou_distribution), so the controller's target shifts by
        # the same constant — it absorbs the added staleness online with
        # no new host syncs.
        bctrl = (budget.BudgetController(
            rho=oac.rho,
            age_offset=(float(oac.straggler_lag) if oac.async_agg
                        else 0.0),
            # population churn and wireless truncation outage both thin
            # the refresh stream (DESIGN.md §15-§16): the controller's
            # Lemma-1 target absorbs the geometric mean shift
            # thin/(1-thin) as a constant offset; independent blockers'
            # rates add (to first order)
            thin=min(0.99, (oac.population.thin
                            if oac.population is not None else 0.0)
                     + (oac.wireless.thin
                        if oac.wireless is not None else 0.0)))
            if oac.adaptive_km else None)

        def _shard_noise_key(seed):
            """Per-shard channel-noise key: fold the round seed by the
            shard's linear index so the simulated noise is iid ACROSS
            shards (an un-folded key would repeat the same noise block on
            every shard — the global noise vector must not be periodic)."""
            my = 0
            for ax in mesh_axes:
                my = my * mesh.shape[ax] + jax.lax.axis_index(ax)
            return jax.random.fold_in(jax.random.PRNGKey(seed), my)

        def _packed_server_phase(server, grads, seed):
            """ONE fused FAIR-k pass over the whole local pytree, against
            PERSISTED flat server buffers: only the fresh gradients are
            packed (one tree copy); g_prev (bf16), age (int8, PAD_AGE
            sentinel in the lane pads) and the optional EF residual stay
            lane-aligned flat buffers across steps, so the step saves two
            tree packs + one tree unpack per round vs the PR-2 re-pack
            path and the buffer donation is fully in place.  (θ_M, θ_A)
            stay globally consistent (pmean across shards); with
            ``fused_stats`` (default) the warm-start counts and the
            threshold-re-estimation histograms come OUT of the fused
            kernel, so the steady-state round reads the packed gradient
            buffer exactly once — no separate count pass, no quantile
            bootstrap."""
            layout = packing.PackedLayout.from_tree(grads)
            eng = SelectionEngine(
                EngineConfig(policy="fairk", backend="packed", rho=oac.rho,
                             k_m_frac=oac.k_m_frac,
                             sample_cap=oac.sample_cap,
                             noise_std=(0.0 if oac.one_bit
                                        else oac.noise_std),
                             n_clients=oac.n_clients,
                             warm_start=oac.warm_start,
                             fused_stats=oac.fused_stats,
                             reduce_axes=mesh_axes),
                layout.d_packed, layout=layout)
            tstate = packing.threshold_state_from_vec(server["theta"])
            cstate = kmf = None
            if oac.adaptive_km:
                # the live split comes off the carried controller state —
                # replicated across shards (its inputs are the pmean'd
                # histograms, so every shard computes the same successor)
                cstate = budget.controller_state_from_vec(server["ctrl"])
                kmf = cstate["k_m_frac"]
            key = _shard_noise_key(seed) if oac.noise_std > 0.0 else None
            pop_stats = None
            if oac.population is not None:
                # stateless population round (DESIGN.md §15): iid/diurnal
                # chains are memoryless, so the round's availability grid
                # is a pure counter-based function of (base key, seed) —
                # no chain state rides the checkpointed server buffers,
                # and consecutive round seeds walk a lawful trajectory.
                # Replicated computation: no shard fold-in, so every
                # shard derives identical round stats (no collective).
                pop_stats = pop_mod.stateless_round(
                    jax.random.PRNGKey(0x509), seed, oac.population)
            g_flat = layout.pack(grads)            # the ONLY pack per step
            new_fad = wl_erase = None
            if oac.wireless is not None:
                # aggregate-equivalent wireless round (DESIGN.md §16):
                # advance this shard's per-block AR(1) fading chains and
                # mark the blocks whose gain misses the threshold
                # calibrated to the truncation-outage rate (the erasure
                # composes into the sanitize path below); imperfect CSI
                # multiplies the fresh aggregate by the per-block
                # misalignment factor.  Per-shard draws (disjoint
                # coordinate slices => the global pattern), decorrelated
                # from the noise/fade/churn streams by distinct fold-ins;
                # everything elementwise — G_READS stays 1.
                new_fad, wl_erase = chan.block_outage(
                    server["fad"],
                    jax.random.fold_in(_shard_noise_key(seed), 0xC4A),
                    layout.d_packed, oac.wireless)
                g_flat = g_flat * chan.csi_block_factor(
                    jax.random.fold_in(_shard_noise_key(seed), 0xC51),
                    layout.d_packed, oac.wireless)
            age_lag = None
            new_shadow = None
            if oac.async_agg:
                # straggler OAC contributions land one aggregation late: a
                # Knuth-hash pattern of coordinates defers its share of
                # THIS round's uplink into the shadow buffer while LAST
                # round's shadow joins the merge.  Elementwise mixing on
                # the packed buffer — not an extra instrumented read of
                # the persisted gradient state, so G_READS stays 1.  With
                # a population the threshold is the round's TRACED
                # straggler share (sampled from the live cohort) instead
                # of the fixed ``straggler_frac`` — same hash pattern,
                # data-dependent coverage, still zero recompiles.
                frac = (pop_stats["slow_share"]
                        if oac.population is not None
                        else oac.straggler_frac)
                strag = (index_jitter(layout.d_packed)
                         < frac).astype(jnp.float32)
                new_shadow = g_flat * strag
                g_flat = (g_flat * (1.0 - strag)
                          + server["shadow"].astype(jnp.float32))
                age_lag = oac.straggler_lag
            fresh = None
            if oac.one_bit:
                # one-bit uplink: the transmitted values are the SIGNS of
                # the effective gradient, detected by the sign_mv kernel
                # from the (noisy) energy — with EF the sign is taken on
                # score = g + residual, the same fold the fused kernel
                # applies, so residual' = score - mask*sign accumulates
                # the quantization error.  Channel noise rides the vote
                # energy (engine noise off), like the FL sim's route.
                from repro.kernels import ops
                eff = g_flat
                if "res" in server:
                    eff = eff + server["res"]
                # unscaled sigma_z on the superposed energy — the same
                # convention as the FL sim's one-bit route (the noise
                # perturbs the detection statistic once; it does NOT
                # average down over clients like the coherent channel)
                noise = (oac.noise_std
                         * jax.random.normal(_shard_noise_key(seed),
                                             g_flat.shape, jnp.float32)
                         if oac.noise_std > 0.0 else None)
                fresh, _ = ops.sign_mv(eff[None, :], noise=noise)
                key = None
            erase = None
            if oac.fade > 0.0:
                # deep-fade block erasures on the aggregated signal: a
                # per-shard draw (each shard owns a disjoint coordinate
                # slice, so independent per-shard masks ARE the global
                # mask), decorrelated from the channel-noise stream by a
                # fold-in.  The engine converts erased coordinates to NaN
                # and the sanitize stage keeps them out of selection.
                erase = faults.fade_mask(
                    jax.random.fold_in(_shard_noise_key(seed), 0xFADE),
                    layout.d_packed,
                    faults.FaultConfig(fade=oac.fade,
                                       fade_block=oac.fade_block))
            if oac.population is not None:
                # mid-round churn erasure (DESIGN.md §15): symbol blocks
                # lost to participants whose chain dropped mid-round, at
                # the round's traced churn rate; a TOTAL cohort outage
                # erases everything.  Per-shard draw (disjoint slices =>
                # the global mask), decorrelated from the fade stream.
                churn_er = faults.erase_with_outage(
                    pop_mod.churn_erase_mask(
                        jax.random.fold_in(_shard_noise_key(seed), 0x509),
                        layout.d_packed, pop_stats["churn"],
                        oac.population),
                    pop_stats["n_t"])
                erase = (churn_er if erase is None
                         else jnp.maximum(erase, churn_er))
            if wl_erase is not None:
                erase = (wl_erase if erase is None
                         else jnp.maximum(erase, wl_erase))
            g_t, age_next, stats = eng.select_and_merge(
                g_flat, server["g"], server["age"], key=key, tstate=tstate,
                residual=server.get("res"), fresh=fresh, k_m_frac=kmf,
                age_lag=age_lag, erase=erase, sanitize=oac.sanitize)
            new_server = {
                "g": g_t.astype(jnp.bfloat16),
                "age": age_next.astype(jnp.int8),
                "theta": packing.threshold_state_to_vec(stats["tstate"]),
            }
            if "res" in server:
                new_server["res"] = stats["residual"]
            if oac.wireless is not None:
                new_server["fad"] = new_fad
            if oac.adaptive_km:
                # in-graph controller step off the (pmean'd) kernel
                # histograms — the same compiled program at every split
                cstate = bctrl.update(cstate, stats["age_hist"],
                                      stats["mag_hist"])
                new_server["ctrl"] = budget.controller_state_to_vec(cstate)
            if oac.async_agg:
                # double-buffer swap: the optimizer consumes the PREVIOUS
                # round's merged gradient, so this round's fused pass has
                # no consumer inside the step — XLA overlaps it with the
                # next round's client compute.  Round 0's pending buffer
                # is zeros (a no-op update), matching the one-round
                # pipeline fill.
                new_server["shadow"] = new_shadow.astype(jnp.bfloat16)
                new_server["pending"] = g_t.astype(jnp.bfloat16)
                out = server["pending"].astype(jnp.float32)
            else:
                out = g_t
            # the optimizer consumes per-leaf trees: ONE unpack per step
            return layout.unpack(out, cast=False), new_server

        def _per_leaf_server_phase(server, grads, seed):
            """Historical per-leaf loop (oac.packed=False): one threshold
            estimation + one fused kernel per parameter leaf."""
            leaves_g, treedef = jax.tree_util.tree_flatten(grads)
            leaves_gp = treedef.flatten_up_to(server["g"])
            leaves_age = treedef.flatten_up_to(server["age"])
            key = _shard_noise_key(seed)
            g_t, new_gp, new_age = [], [], []
            for i, (g, gp, ag) in enumerate(zip(leaves_g, leaves_gp,
                                                leaves_age)):
                kk = jax.random.fold_in(key, i)
                a, b, c = _leaf_server_update(g, gp, ag, kk, oac)
                g_t.append(a)
                new_gp.append(b)
                new_age.append(c)
            g_t = jax.tree_util.tree_unflatten(treedef, g_t)
            new_server = {
                "g": jax.tree_util.tree_unflatten(treedef, new_gp),
                "age": jax.tree_util.tree_unflatten(treedef, new_age),
                "theta": server["theta"],
            }
            return g_t, new_server

        def update_phase(params, opt_state, server, grads, seed):
            """Runs under fully-manual shard_map: leaves are local shards."""
            phase = (_packed_server_phase if oac.packed
                     else _per_leaf_server_phase)
            g_t, new_server = phase(server, grads, seed)
            g_t = jax.tree.map(lambda gt, p: gt.astype(p.dtype), g_t, params)
            updates, new_opt = opt.update(g_t, opt_state, params)
            new_params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                                      params, updates)
            return new_params, new_opt, new_server

        update_sharded = compat.shard_map(
            update_phase, mesh,
            in_specs=(p_specs, o_specs, srv_specs, p_specs, P()),
            out_specs=(p_specs, o_specs, srv_specs))
    else:
        def update_sharded(params, opt_state, server, grads, seed):
            updates, new_opt = opt.update(grads, opt_state, params)
            new_params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                                      params, updates)
            return new_params, new_opt, server

    def train_step(params, opt_state, server, batch, seed):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        if gather_dtype is not None:
            # §Perf: compute-params cast once per step (sharded, local) so
            # the per-layer FSDP all-gathers carry 2-byte weights and the
            # backward reduce-scatters carry 2-byte cotangents
            gdt = jnp.dtype(gather_dtype)
            params_c = jax.tree.map(
                lambda p: p.astype(gdt) if p.ndim > 1 else p, params)
        else:
            params_c = params

        if client_chunk is None:
            def microbatch_body(carry, mbatch):
                loss_acc, g_acc = carry
                (loss, _), grads = grad_fn(params_c, mbatch)
                g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                     g_acc, grads)
                return (loss_acc + loss, g_acc), None

            (loss, grads), _ = jax.lax.scan(
                microbatch_body, (jnp.zeros((), jnp.float32), zeros), batch)
        else:
            # streaming chunked accumulation (DESIGN.md §17): the scan
            # walks n_micro / C chunks and each step vmaps the grad over
            # its C microbatches, folding the chunk's gradient sum into
            # the same (d,)-per-leaf accumulators the per-microbatch body
            # carries — memory scales with the chunk, not with n_micro.
            batch_c = jax.tree.map(
                lambda x: x.reshape((n_micro // client_chunk, client_chunk)
                                    + x.shape[1:]), batch)

            def chunk_body(carry, mchunk):
                loss_acc, g_acc = carry
                (loss, _), grads = jax.vmap(
                    lambda mb_: grad_fn(params_c, mb_))(mchunk)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32).sum(axis=0),
                    g_acc, grads)
                return (loss_acc + loss.sum(), g_acc), None

            (loss, grads), _ = jax.lax.scan(
                chunk_body, (jnp.zeros((), jnp.float32), zeros), batch_c)
        loss = loss / n_micro
        grads = jax.tree.map(lambda g, p: (g / n_micro).astype(p.dtype),
                             grads, params)
        new_params, new_opt, new_server = update_sharded(
            params, opt_state, server, grads, seed)
        return new_params, new_opt, new_server, loss

    named = lambda specs: shlib.to_named(specs, mesh)
    in_sh = (named(p_specs), named(o_specs), named(srv_specs),
             named(b_specs), NamedSharding(mesh, P()))
    out_sh = (named(p_specs), named(o_specs), named(srv_specs),
              NamedSharding(mesh, P()))
    input_specs = (params_abs, opt_abs, srv_abs, in_specs_batch,
                   SDS((), jnp.int32))
    meta = {
        "kind": "train", "n_micro": n_micro, "micro_batch": mb,
        "client_chunk": client_chunk,
        "seq_len": shape.seq_len, "oac": oac is not None,
        "oac_packed": bool(oac.packed) if oac is not None else False,
        "oac_warm_start": bool(oac.warm_start) if oac is not None else False,
        "oac_ef": bool(oac.error_feedback) if oac is not None else False,
        "oac_fused_stats": bool(oac.fused_stats) if oac is not None
        else False,
        "oac_one_bit": bool(oac.one_bit) if oac is not None else False,
        "oac_adaptive_km": bool(oac.adaptive_km) if oac is not None
        else False,
        "oac_async": bool(oac.async_agg) if oac is not None else False,
        "oac_sanitize": bool(oac.sanitize) if oac is not None else False,
        "oac_fade": float(oac.fade) if oac is not None else 0.0,
        "oac_population": (oac.population.n_clients
                           if oac is not None and oac.population is not None
                           else 0),
        "oac_wireless": bool(oac.wireless is not None) if oac is not None
        else False,
        "optimizer": opt_name or cfg.optimizer, "lr": lr,
        "gather_dtype": gather_dtype,
        "scans": {"microbatch": n_micro, "layers": cfg.n_scan_blocks},
    }
    return StepBundle(train_step, in_sh, out_sh, input_specs, meta)


# ---------------------------------------------------------------------------
# prefill / serve steps
# ---------------------------------------------------------------------------

def _serve_capacity(cfg: ModelConfig, shape: InputShape) -> Tuple[int, bool]:
    """(cache capacity, ring?) for decode shapes."""
    if shape.seq_len > 32768 and cfg.sliding_window and cfg.family not in (
            "ssm", "hybrid"):
        return cfg.sliding_window, True       # long-context sliding window
    return shape.seq_len, False


def make_prefill_step(cfg: ModelConfig, shape: InputShape, mesh) -> StepBundle:
    cfg = _with_expert_axis(cfg, mesh)
    gb = shape.global_batch
    s_text = _text_len(cfg, shape.seq_len)
    params_abs = abstract_params(cfg)
    p_specs = shlib.param_pspecs(params_abs, cfg, mesh)
    cache_abs = tr.cache_specs(cfg, gb, shape.seq_len)
    c_specs = shlib.cache_pspecs(cache_abs, cfg, mesh)

    def prefill_step(params, caches, batch):
        return tr.prefill(params, cfg, batch["tokens"], caches,
                          embeds=batch.get("embeds"),
                          frames=batch.get("frames"))

    batch_specs = {"tokens": SDS((gb, s_text), jnp.int32)}
    b_pspecs = {"tokens": shlib.batch_pspec(gb, mesh, 1, False)}
    if cfg.family == "vlm":
        batch_specs["embeds"] = SDS((gb, cfg.n_patches, cfg.d_model),
                                    jnp.dtype(cfg.compute_dtype))
        b_pspecs["embeds"] = shlib.batch_pspec(gb, mesh, 2, False)
    if cfg.family == "audio":
        batch_specs["frames"] = SDS((gb, cfg.encoder_seq, cfg.d_model),
                                    jnp.dtype(cfg.compute_dtype))
        b_pspecs["frames"] = shlib.batch_pspec(gb, mesh, 2, False)

    named = lambda s: shlib.to_named(s, mesh)
    logits_spec = P(batch_axes(mesh) if gb % axis_size(
        mesh, batch_axes(mesh)) == 0 else None, None, None)
    in_sh = (named(p_specs), named(c_specs), named(b_pspecs))
    out_sh = (NamedSharding(mesh, logits_spec), named(c_specs))
    meta = {"kind": "prefill", "seq_len": shape.seq_len,
            "global_batch": gb,
            "scans": {"layers": cfg.n_scan_blocks}}
    return StepBundle(prefill_step, in_sh, out_sh,
                      (params_abs, cache_abs, batch_specs), meta)


def make_serve_step(cfg: ModelConfig, shape: InputShape, mesh) -> StepBundle:
    cfg = _with_expert_axis(cfg, mesh)
    gb = shape.global_batch
    capacity, ring = _serve_capacity(cfg, shape)
    params_abs = abstract_params(cfg)
    p_specs = shlib.param_pspecs(params_abs, cfg, mesh)
    cache_abs = tr.cache_specs(cfg, gb, capacity, ring=ring)
    c_specs = shlib.cache_pspecs(cache_abs, cfg, mesh,
                                 shard_capacity=(gb == 1))
    window = cfg.sliding_window if ring else 0

    def serve_step(params, caches, token, pos):
        return tr.decode_step(params, cfg, token, pos, caches, window=window)

    named = lambda s: shlib.to_named(s, mesh)
    b_axes = batch_axes(mesh)
    tok_spec = P(b_axes if gb % axis_size(mesh, b_axes) == 0 else None, None)
    logits_spec = P(tok_spec[0], None, None)
    in_sh = (named(p_specs), named(c_specs), NamedSharding(mesh, tok_spec),
             NamedSharding(mesh, P()))
    out_sh = (NamedSharding(mesh, logits_spec), named(c_specs))
    input_specs = (params_abs, cache_abs, SDS((gb, 1), jnp.int32),
                   SDS((), jnp.int32))
    meta = {"kind": "decode", "seq_len": shape.seq_len, "global_batch": gb,
            "capacity": capacity, "ring": ring,
            "scans": {"layers": cfg.n_scan_blocks}}
    return StepBundle(serve_step, in_sh, out_sh, input_specs, meta)


# ---------------------------------------------------------------------------
# FL-OAC step: the paper's regime at its own scale (clients = devices)
# ---------------------------------------------------------------------------

def make_fl_oac_step(cfg: ModelConfig, mesh, *, seq_len: int = 1024,
                     local_batch: int = 1, rho: float = 0.1,
                     k_m_frac: float = 0.75, block: int = 4096,
                     noise_std: float = 1.0,
                     baseline: bool = False,
                     one_bit: bool = False,
                     adaptive_km: bool = False) -> StepBundle:
    """Every device = one OAC-FL client with a full model replica.

    FAIR-k runs at waveform-group granularity (``block`` coordinates per
    group, mirroring the prototype's OFDM symbol groups): blocks are scored
    by gradient L2 (stage M) and group AoU (stage A); only the selected
    rho-fraction of blocks is all-reduced -> the uplink collective carries
    rho*d values instead of d (``baseline=True`` all-reduces everything).

    The magnitude/age split is a TRACED value (the engine's rank-based
    ``fair_k_masks_dynamic`` — same coordinate set as the historical
    static ``top_k`` concatenation, incl. the toward-lower-index
    tie-break), so ``adaptive_km`` can close the loop at this scale too:
    the budget controller state rides the step as an extra replicated
    vector, re-derives the split from the block-AoU histogram every round,
    and never recompiles."""
    axes = tuple(mesh.axis_names)
    n_clients = axis_size(mesh, axes)
    bctrl = budget.BudgetController(rho=rho) if adaptive_km else None

    params_abs = abstract_params(cfg)
    leaves_abs, treedef = jax.tree_util.tree_flatten(params_abs)
    sizes = [int(np_prod(l.shape)) for l in leaves_abs]
    offsets = [0]
    for sz in sizes:
        offsets.append(offsets[-1] + sz)
    d = offsets[-1]

    def unravel(flat):
        out = [flat[offsets[i]:offsets[i + 1]].reshape(leaves_abs[i].shape)
               .astype(leaves_abs[i].dtype) for i in range(len(sizes))]
        return jax.tree_util.tree_unflatten(treedef, out)
    d_pad = -(-d // block) * block
    nb = d_pad // block
    kb = max(1, int(round(rho * nb)))

    def fl_oac_core(w_flat, g_prev, age_b, ctrl_vec, batch, seed):
        """w_flat/g_prev: (d,) replicated; age_b: (nb,) block AoU;
        ctrl_vec: replicated controller state (adaptive only, else None);
        batch: per-client {tokens, labels} (local_batch, seq)."""
        # --- local client update ------------------------------------------
        def local_loss(w):
            return tr.loss_fn(unravel(w), cfg, batch)[0]
        loss, grads = jax.value_and_grad(local_loss)(w_flat)
        gb_local = jnp.pad(grads, (0, d_pad - d)).reshape(nb, block)
        # --- shared selection (replicated inputs -> identical everywhere) --
        # The split ``kb_m`` is TRACED (the engine's rank-based machinery,
        # one rounding convention via traced_km): rank and top_k agree on
        # the selected set incl. the toward-lower-index tie-break, so the
        # static regime is value-identical to the historical concatenated
        # top_k form while the adaptive regime re-derives the split from
        # the carried controller state without recompiling.
        cstate = (budget.controller_state_from_vec(ctrl_vec)
                  if adaptive_km else None)
        kmf = cstate["k_m_frac"] if adaptive_km else jnp.float32(k_m_frac)
        gp = jnp.pad(g_prev, (0, d_pad - d)).reshape(nb, block)
        score = jnp.sum(gp.astype(jnp.float32) ** 2, axis=1)
        mask_sel, _ = fair_k_masks_dynamic(
            score, age_b.astype(jnp.float32), kb, traced_km(kb, kmf))
        # exactly kb ones in mask_sel; gather/scatter below are
        # order-insensitive (unique indices), so ascending order is fine
        idx = jnp.nonzero(mask_sel, size=kb, fill_value=0)[0]
        idx = idx.astype(jnp.int32)
        # --- OAC uplink: only the selected blocks ride the channel ---------
        key = jax.random.PRNGKey(seed)
        my = 0
        for ax in axes:
            my = my * mesh.shape[ax] + jax.lax.axis_index(ax)
        h = jax.random.rayleigh(
            jax.random.fold_in(key, 0), 1.0 / 1.2533141373155003,
            shape=(n_clients,), dtype=jnp.float32)[my]
        if baseline:
            # 1/N audit (DESIGN.md §14): n_clients is the static mesh size
            # — every device always contributes to the psum, so the
            # denominator can never be a traced zero.  Any rescale by a
            # REALIZED participation count must instead route through
            # faults.participation_scale (the guarded helper).
            agg = jax.lax.psum(h * gb_local, axes) / n_clients
            fresh_blocks = agg[idx]
        elif one_bit:
            # §Perf: prototype-style one-bit uplink (sign + FSK majority
            # vote, Sec. V-B) — votes ride the channel as int8 within the
            # model axis, widened to int16 across the remaining axes
            # (worst-case sum 512 < 2^15), then the server takes the sign.
            votes = jnp.where(gb_local[idx] >= 0, 1, -1).astype(jnp.int8)
            s1 = jax.lax.psum(votes, "model").astype(jnp.int16)
            rest = tuple(a for a in axes if a != "model")
            s2 = jax.lax.psum(s1, rest) if rest else s1
            fresh_blocks = jnp.where(s2 >= 0, 1.0, -1.0).astype(jnp.float32)
        else:
            compact = h * gb_local[idx]                    # (kb, block)
            # static mesh-size denominator — safe (see the 1/N audit note
            # on the baseline branch above)
            fresh_blocks = jax.lax.psum(compact, axes) / n_clients
        noise = noise_std / n_clients * jax.random.normal(
            jax.random.fold_in(key, 1), fresh_blocks.shape, jnp.float32)
        fresh_blocks = fresh_blocks + noise
        # --- Eq. (8)-(10) at block granularity ------------------------------
        g_new = gp.astype(jnp.float32).at[idx].set(fresh_blocks)
        # Eq. (10) with the engine's staleness clip: without it the block
        # AoU grows unbounded over a long run and breaks the int8-safety
        # invariant (DESIGN.md §5) the coordinate-level paths guarantee
        age_next = jnp.minimum((age_b + 1.0).at[idx].set(0.0), AGE_CAP)
        ctrl_next = None
        if adaptive_km:
            # close the loop at the device-as-client scale: the block-AoU
            # histogram drives the same in-graph controller the big-model
            # trainer carries (replicated inputs -> identical successor
            # state on every shard, no collective needed)
            from repro.kernels import ref
            _, age_hist = ref.strided_hists_ref(
                score, age_next, jnp.ones((nb,), bool),
                packing.hist_stride(nb))
            ctrl_next = budget.controller_state_to_vec(
                bctrl.update(cstate, age_hist))
        g_new_flat = g_new.reshape(-1)[:d]
        w_next = w_flat - 0.01 * g_new_flat.astype(w_flat.dtype)
        loss_mean = jax.lax.pmean(loss, axes)
        return (w_next, g_new_flat.astype(g_prev.dtype), age_next,
                ctrl_next, loss_mean)

    if adaptive_km:
        fl_oac_step = fl_oac_core
    else:
        def fl_oac_step(w_flat, g_prev, age_b, batch, seed):
            w, g, a, _, loss = fl_oac_core(w_flat, g_prev, age_b, None,
                                           batch, seed)
            return w, g, a, loss

    batch_specs = {
        "tokens": SDS((n_clients * local_batch, seq_len), jnp.int32),
        "labels": SDS((n_clients * local_batch, seq_len), jnp.int32),
    }
    b_pspec = {"tokens": P(axes, None), "labels": P(axes, None)}
    ctrl_in = (P(),) if adaptive_km else ()
    fn = compat.shard_map(fl_oac_step, mesh,
                          in_specs=(P(), P(), P(), *ctrl_in, b_pspec, P()),
                          out_specs=(P(), P(), P(), *ctrl_in, P()))
    named = lambda s: shlib.to_named(s, mesh)
    repl = NamedSharding(mesh, P())
    ctrl_sh = (repl,) if adaptive_km else ()
    ctrl_abs = ((SDS((budget.CONTROLLER_STATE_SIZE,), jnp.float32),)
                if adaptive_km else ())
    in_sh = (repl, repl, repl, *ctrl_sh, named(b_pspec), repl)
    out_sh = (repl, repl, repl, *ctrl_sh, repl)
    input_specs = (SDS((d,), jnp.float32), SDS((d,), jnp.float32),
                   SDS((nb,), jnp.float32), *ctrl_abs, batch_specs,
                   SDS((), jnp.int32))
    meta = {"kind": "fl_oac", "d": d, "blocks": nb, "kb": kb,
            "n_clients": n_clients, "rho": rho, "baseline": baseline,
            "one_bit": one_bit, "adaptive_km": adaptive_km,
            "scans": {"layers": cfg.n_scan_blocks}}
    return StepBundle(fn, in_sh, out_sh, input_specs, meta)
