"""Partition specs for parameters, optimizer state, caches and batches.

Scheme (DESIGN.md §5): 2-D "FSDP x TP" —
  * the TP dimension of every matmul weight lives on the ``model`` axis
    (attention heads / FFN hidden / experts / SSM heads / vocab),
  * the complementary major dimension is fully sharded across the
    data-parallel axes (``data``, plus ``pod`` when multi-pod) — XLA inserts
    the per-layer all-gather / reduce-scatter pairs of FSDP inside the layer
    scan,
  * dims that do not divide the axis size (odd vocabularies, kv-head counts
    smaller than the model axis) are replicated — checked explicitly since
    GSPMD rejects uneven shardings.

Everything is derived from the parameter tree *paths* produced by
``models.transformer.init_lm``, so new substrates inherit sharding by
following the same naming conventions.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import axis_size, batch_axes, fsdp_axes


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "/".join(parts)


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def param_pspecs(params: Any, cfg: ModelConfig, mesh) -> Any:
    """PartitionSpec pytree matching ``init_lm(params)``."""
    fsdp = fsdp_axes(mesh)
    fsdp_n = axis_size(mesh, fsdp)
    model_n = mesh.shape["model"]

    def spec_for(path, leaf) -> P:
        s = _path_str(path)
        shape = leaf.shape
        stacked = s.startswith("blocks") or s.startswith("enc_blocks")
        lead = (None,) if stacked else ()
        body = shape[1:] if stacked else shape

        def mk(*entries):
            return P(*(lead + entries))

        # --- embeddings / head -----------------------------------------
        if s == "embed":
            v, d = shape
            return P("model" if _div(v, model_n) else None,
                     fsdp if _div(d, fsdp_n) else None)
        if s.startswith("head"):
            if leaf.ndim == 2:
                d, v = shape
                return P(fsdp if _div(d, fsdp_n) else None,
                         "model" if _div(v, model_n) else None)
            return P()                                    # bias
        # --- norms / small vectors --------------------------------------
        if "norm" in s or leaf.ndim <= (2 if stacked else 1):
            # includes a_log / d_skip / dt_bias / conv_b / all biases
            if "conv_x_b" in s or any(t in s for t in ("a_log", "d_skip",
                                                       "dt_bias")):
                h = body[-1]
                return mk(*([None] * (len(body) - 1)),
                          "model" if _div(h, model_n) else None)
            return P()
        # --- MoE experts (stacked rank-4) --------------------------------
        if "/ffn/" in s and leaf.ndim == 4 and "router" not in s:
            e, d1, d2 = body
            if _div(e, model_n):
                return mk("model", fsdp if _div(d1, fsdp_n) else None, None)
            # expert count not divisible (granite-moe 40e): TP on hidden dim
            if s.endswith("wd/w"):                       # (E, F, D)
                return mk(None, "model" if _div(d1, model_n) else None,
                          fsdp if _div(d2, fsdp_n) else None)
            return mk(None, fsdp if _div(d1, fsdp_n) else None,
                      "model" if _div(d2, model_n) else None)
        if "router" in s:
            return mk(fsdp if _div(body[0], fsdp_n) else None, None)
        # --- projections: TP on the "wide" side ---------------------------
        if any(t in s for t in ("wk/w", "wv/w", "wbc/w")):
            # kv-head counts (1-8) never divide the model axis: replicating
            # the (small) kv projections avoids GSPMD mixed-tiling fallbacks;
            # the KV *cache* is sharded along its capacity dim instead.
            d_in, d_out = body
            return mk(fsdp if _div(d_in, fsdp_n) else None, None)
        if any(t in s for t in ("wq/w", "wg/w", "wu/w", "wz/w", "wx/w",
                                "wdt/w")):
            d_in, d_out = body
            return mk(fsdp if _div(d_in, fsdp_n) else None,
                      "model" if _div(d_out, model_n) else None)
        if any(t in s for t in ("wo/w", "wd/w", "out_proj/w")):
            d_in, d_out = body
            return mk("model" if _div(d_in, model_n) else None,
                      fsdp if _div(d_out, fsdp_n) else None)
        if "conv_x_w" in s:                              # (K, d_inner)
            return mk(None, "model" if _div(body[-1], model_n) else None)
        if "conv_bc_w" in s:
            return mk(None, None)
        return P()                                       # fallback: replicate

    return jax.tree_util.tree_map_with_path(spec_for, params)


def opt_pspecs(opt_state: Any, p_specs: Any) -> Any:
    """Optimizer-state specs: moment trees mirror the parameter specs."""
    out = {}
    for key, val in opt_state.items():
        if key == "step" or val is None:
            out[key] = P() if val is not None else None
        else:
            out[key] = p_specs
    return out


def server_pspecs(p_specs: Any, mesh=None, packed: bool = False,
                  error_feedback: bool = False,
                  adaptive_km: bool = False,
                  async_agg: bool = False,
                  wireless: bool = False) -> Any:
    """OAC server state specs.

    Packed flavour: the persisted lane-aligned flat buffers shard their
    single dimension across ALL mesh axes (each shard owns its local
    ``d_packed`` slice — exactly what ``shard_map`` hands the fused pass);
    the warm-start threshold state vector — and, with ``adaptive_km``,
    the budget-controller state vector — is replicated (pmean-consistent
    across shards).  With ``async_agg`` the double-buffer lane (the
    deferred-straggler ``shadow`` and the one-round-delayed ``pending``
    merge result) shards like the gradient buffer it mirrors.  With
    ``wireless`` the per-block AR(1) fading chain (``fad`` — 2 floats
    per symbol block, DESIGN.md §16) shards across the same axes: each
    shard owns the chains of its own coordinate slice.  Per-leaf
    flavour: {g, age} mirror parameter sharding."""
    if packed:
        vec = P(tuple(mesh.axis_names))
        out = {"g": vec, "age": vec, "theta": P()}
        if error_feedback:
            out["res"] = vec
        if adaptive_km:
            out["ctrl"] = P()
        if async_agg:
            out["shadow"] = vec
            out["pending"] = vec
        if wireless:
            out["fad"] = vec
        return out
    return {"g": p_specs, "age": p_specs, "theta": P()}


def cache_pspecs(caches: Any, cfg: ModelConfig, mesh,
                 shard_capacity: bool = False) -> Any:
    """KV/SSM cache specs.  Leading dim of every leaf is the scan-block dim.

    Attention k/v (n_blocks, B, L, KV, hd): batch on the data axes; heads on
    ``model`` when divisible, otherwise head_dim on ``model``; optionally the
    capacity dim on ``data`` (long-context single-sample decode)."""
    b_axes = batch_axes(mesh)
    b_n = axis_size(mesh, b_axes)
    model_n = mesh.shape["model"]

    def spec_for(path, leaf) -> P:
        s = _path_str(path)
        shape = leaf.shape
        if s.endswith("/k") or s.endswith("/v"):
            # caches shard along the *capacity* dim (always divisible):
            # scores/PV einsums then reduce over the sharded T dim with two
            # tiny collectives instead of resharding heads (kv never
            # divides the model axis).
            _, b, cap, kv, hd = shape
            bspec = b_axes if _div(b, b_n) else None
            cap_axes = (("data", "model") if bspec is None else ("model",))
            cap_axes = tuple(a for a in cap_axes
                             if a == "model" or shard_capacity)
            n_cap = axis_size(mesh, cap_axes)
            cap_spec = cap_axes if (cap_axes and _div(cap, n_cap)) else None
            return P(None, bspec, cap_spec, None, None)
        if s.endswith("ssm"):
            _, b, h, p_, n_ = shape
            return P(None, b_axes if _div(b, b_n) else None,
                     "model" if _div(h, model_n) else None, None, None)
        if s.endswith("conv_x"):
            _, b, k_, c = shape
            return P(None, b_axes if _div(b, b_n) else None, None,
                     "model" if _div(c, model_n) else None)
        if s.endswith("conv_bc"):
            _, b, k_, c = shape
            return P(None, b_axes if _div(b, b_n) else None, None, None)
        return P()                                       # pos / idx / ring

    return jax.tree_util.tree_map_with_path(spec_for, caches)


def batch_pspec(global_batch: int, mesh, extra_dims: int = 1,
                leading_micro: bool = False) -> P:
    """Spec for (micro?, batch, ...) input arrays."""
    b_axes = batch_axes(mesh)
    b = b_axes if _div(global_batch, axis_size(mesh, b_axes)) else None
    entries = ((None,) if leading_micro else ()) + (b,) + (None,) * extra_dims
    return P(*entries)


def to_named(tree_specs: Any, mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
