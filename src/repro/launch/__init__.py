"""Distributed launch layer: production mesh, sharding specs, step builders,
multi-pod dry-run and training CLI.

NOTE: ``repro.launch.dryrun`` must be the process entry point when the
512-device placeholder mesh is wanted — it sets XLA_FLAGS before any jax
import.  Do not import it from library code."""

from repro.launch import mesh, sharding, steps
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.launch.steps import (OacServerConfig, StepBundle,
                                init_server_state, make_fl_oac_step,
                                make_prefill_step, make_serve_step,
                                make_train_step)

__all__ = ["mesh", "sharding", "steps", "make_production_mesh",
           "make_test_mesh", "OacServerConfig", "StepBundle",
           "init_server_state", "make_fl_oac_step", "make_prefill_step",
           "make_serve_step", "make_train_step"]
