import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, prove memory/sharding coherence, and dump the roofline
artifacts (memory_analysis, cost_analysis, loop-aware parsed HLO metrics).

The XLA_FLAGS line above MUST precede every other import (jax locks the
device count at first init) — that is why it sits before the docstring's
siblings here and why nothing else in the repo sets it globally.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--fl-mode]
Artifacts land in benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json.
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (make_fl_oac_step, make_prefill_step,
                                make_serve_step, make_train_step)
from repro.roofline import (analyze_hlo, build_report, suggestion,
                            xla_cost_analysis)

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")


def make_step(cfg, shape, mesh, oac_packed: bool = True):
    if shape.kind == "train":
        from repro.launch.steps import OacServerConfig
        return make_train_step(cfg, shape, mesh,
                               oac=OacServerConfig(packed=oac_packed))
    if shape.kind == "prefill":
        return make_prefill_step(cfg, shape, mesh)
    return make_serve_step(cfg, shape, mesh)


def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: str, fl_mode: bool = False, fl_baseline: bool = False,
            fl_one_bit: bool = False, force: bool = False,
            oac_packed: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in
                         (mesh.devices.shape if hasattr(mesh, "devices")
                          else ()))
    mesh_name = ("2x16x16" if multi_pod else "16x16")
    tag = f"{arch}__{shape_name}__{mesh_name}" + (
        "__flbase" if fl_baseline else
        "__fl1bit" if fl_one_bit else "__fl" if fl_mode else "") + (
        "" if oac_packed else "__perleaf")
    out_path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            cached = json.load(f)
        # artifacts written before the packed server phase share the
        # default tag — only reuse a train artifact if it records the same
        # server-phase flavour (stale per-leaf stats must not masquerade
        # as the packed configuration)
        meta = cached.get("meta", {})
        if (meta.get("kind") != "train"
                or meta.get("oac_packed") == oac_packed):
            return cached

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    t0 = time.time()
    if fl_mode:
        bundle = make_fl_oac_step(cfg, mesh, baseline=fl_baseline,
                                  one_bit=fl_one_bit)
    else:
        bundle = make_step(cfg, shape, mesh, oac_packed=oac_packed)
    with mesh:
        lowered = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                          out_shardings=bundle.out_shardings
                          ).lower(*bundle.input_specs)
        compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    print(mem)                               # proves it fits
    cost = xla_cost_analysis(compiled)
    print({k: v for k, v in cost.items()
           if k in ("flops", "bytes accessed", "transcendentals")})
    parsed = analyze_hlo(compiled.as_text())
    chips = 512 if multi_pod else 256
    report = build_report(cfg, shape, mesh_name, chips, parsed)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "fl_mode": fl_mode, "fl_baseline": fl_baseline,
        "meta": {k: v for k, v in bundle.meta.items() if k != "scans"}
        | {"scans": bundle.meta.get("scans", {})},
        "compile_s": t_compile,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "per_device_total": (mem.argument_size_in_bytes
                                 + mem.temp_size_in_bytes),
        },
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "parsed": parsed,
        "roofline": report.as_dict(),
        "suggestion": suggestion(report),
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    print(f"[dryrun] {tag}: compile {t_compile:.1f}s, "
          f"dominant={report.dominant}, step={report.step_time_s*1e3:.2f}ms")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) on the chosen mesh")
    ap.add_argument("--fl-mode", action="store_true",
                    help="paper-technique FL-OAC step (clients = devices)")
    ap.add_argument("--fl-baseline", action="store_true",
                    help="FL-OAC without compression (full all-reduce)")
    ap.add_argument("--fl-onebit", action="store_true",
                    help="FL-OAC with the one-bit FSK-MV uplink (Sec. V-B, "
                         "sign_mv majority vote); the FL simulator's "
                         "FLConfig.one_bit likewise runs on every backend "
                         "(exact / threshold / packed)")
    ap.add_argument("--per-leaf-server", action="store_true",
                    help="historical per-leaf OAC server phase (default: "
                         "persisted packed fused pass with in-kernel selection statistics, DESIGN.md §9-§11)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(ART_DIR))
    args = ap.parse_args()

    combos = []
    if args.all:
        for arch in sorted(ARCHS):
            for shape in ("train_4k", "prefill_32k", "decode_32k",
                          "long_500k"):
                combos.append((arch, shape))
    else:
        combos.append((args.arch or "qwen2.5-32b",
                       args.shape or "train_4k"))

    failures = []
    for arch, shape in combos:
        try:
            run_one(arch, shape, args.multi_pod, args.out,
                    fl_mode=args.fl_mode, fl_baseline=args.fl_baseline,
                    fl_one_bit=args.fl_onebit, force=args.force,
                    oac_packed=not args.per_leaf_server)
        except Exception as e:
            failures.append((arch, shape, repr(e)))
            traceback.print_exc()
    if failures:
        print(f"\n[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"\n[dryrun] all {len(combos)} combination(s) compiled OK")


if __name__ == "__main__":
    main()
