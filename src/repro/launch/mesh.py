"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; everything else sees the real (single-CPU) device."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e target: 16x16 = 256 chips per pod; 2 pods = 512 chips.

    Axes: ``data`` (+ ``pod``) carry the batch / FL-client dimension,
    ``model`` carries tensor/expert parallelism."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4):
    """Small host-device mesh for unit tests (subprocess with 8 devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes across which the global batch (= FL clients) is sharded."""
    names = mesh.axis_names
    return tuple(a for a in names if a != "model")


def fsdp_axes(mesh) -> tuple:
    """Mesh axes used for fully-sharded parameter storage."""
    return batch_axes(mesh)


def axis_size(mesh, axes) -> int:
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s
