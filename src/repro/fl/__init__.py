"""Federated-learning substrate: the OAC-FL trainer (paper Alg. 1)."""

from repro.fl.trainer import FLConfig, ServerState, init_server, make_fl_step, train

__all__ = ["FLConfig", "ServerState", "init_server", "make_fl_step", "train"]
