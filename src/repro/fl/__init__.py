"""Federated-learning substrate: the OAC-FL trainer (paper Alg. 1) and the
vmapped (policy × k_m × seed) sweep driver."""

from repro.fl.trainer import FLConfig, ServerState, init_server, make_fl_step, train
from repro.fl.sweep import SweepConfig, fair_k_mask_dynamic, run_sweep, sweep_grid

__all__ = ["FLConfig", "ServerState", "init_server", "make_fl_step", "train",
           "SweepConfig", "fair_k_mask_dynamic", "run_sweep", "sweep_grid"]
