"""OAC-FL training loop (paper Algorithm 1), vectorized over clients.

The entire client population runs as one ``vmap``'d computation: every
client performs ``H`` local SGD steps (Eq. 4), the accumulated local
gradient (Eq. 5) is sparsified by the shared selection vector (Eq. 6),
superposed through the fading channel (Eq. 7), reconstructed with the stale
entries (Eq. 8), and applied to the global model (Eq. 9).  The AoU vector
evolves by Eq. (10) and the next selection vector by Eq. (11) — or by one of
the baseline policies.

Selection timing: ``S_{t+1} = SparseSelection(g_t, A_{t+1})`` — the
post-update age (DESIGN.md §1, algorithm-fidelity note).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree
import numpy as np

from repro.core import (channel as chan, controller as budget, faults,
                        keys as keys_mod, oac, packing, population,
                        quantize)
from repro.core.aou import update_age_by_indices
from repro.core.engine import (EngineConfig, SelectionEngine,
                               fair_k_masks_dynamic, index_jitter,
                               traced_km)
from repro.core.oac import ChannelConfig
from repro.kernels import ops, ref

Array = jax.Array
SDS = jax.ShapeDtypeStruct

# trace-time counter: how many streaming client folds a program traces.
# ``lax.scan`` traces its body ONCE regardless of the chunk count, so a
# round that streams its clients through one chunk scan traces exactly
# ONE fold — the client_bench smoke asserts this stays 1 (each client
# gradient is computed and reduced in a single pass; the retired path
# re-read the materialised (N, d) matrix through up to three einsums).
CLIENT_STREAM_PASSES = 0


@dataclasses.dataclass(frozen=True)
class FLConfig:
    n_clients: int = 50
    local_steps: int = 5            # H
    batch_size: int = 50            # B
    local_lr: float = 0.01          # eta_l
    global_lr: float = 0.01         # eta
    rounds: int = 200
    policy: str = "fairk"           # see core.selection.POLICIES
    backend: str = "exact"          # core.engine backend: "exact" keeps the
                                    # paper-faithful index path; "threshold"
                                    # runs the sampled-quantile fused-kernel
                                    # server phase (d >> 1e7 route);
                                    # "packed" adds warm-start thresholds on
                                    # top, re-estimated from the kernel's
                                    # fused statistics — the fused pass is
                                    # the round's ONLY read of the buffer
                                    # (round 0 transmits everything once).
                                    # one_bit and error_feedback run on ALL
                                    # of them.
    compression_ratio: float = 0.1  # rho = k / d
    k_m_frac: float = 0.75          # k_M / k (paper Sec. V-A)
    r_frac: float = 1.5             # AgeTop-k candidate ratio r / k
    channel: ChannelConfig = oac.PAPER_DEFAULT
    one_bit: bool = False           # FSK-MV prototype uplink (Sec. V-B):
                                    # clients send sign(ǧ), the server
                                    # majority-votes.  exact scores g_prev;
                                    # threshold/packed score the vote energy
                                    # and aggregate via the sign_mv kernel
    error_feedback: bool = False    # beyond-paper EF-SGD (Stich et al.):
                                    # the unsent gradient mass folds back
                                    # next round.  exact: client-side (the
                                    # residual rides the fading); threshold/
                                    # packed: server-side — the residual
                                    # stage of the fused fairk_ef_update
                                    # kernel, one HBM pass
    adaptive_km: bool = False       # in-graph budget controller
                                    # (core/controller.py): k_m_frac adapts
                                    # online from the kernel-emitted age
                                    # histogram INSIDE the compiled round —
                                    # zero host syncs, zero recompiles.
                                    # policy="fairk_auto" is an alias.
    async_lag: int = 0              # asynchronous aggregation (DESIGN.md
                                    # §13): selected contributions land
                                    # ``async_lag`` rounds late, so the
                                    # post-merge age of every refreshed
                                    # coordinate restarts at the lag
                                    # instead of 0 (engine ``age_lag``) and
                                    # the adaptive controller's Lemma-1
                                    # target shifts by the same constant.
                                    # 0 = synchronous (bit-exact with the
                                    # historical trajectory)
    scan_rounds: int = 0            # fuse up to this many rounds into ONE
                                    # ``lax.scan``'d compiled step (the sim
                                    # path's multi-round fusion; chunks cut
                                    # at eval boundaries).  0/1 = the
                                    # per-round Python loop
    controller: budget.ControllerConfig = budget.ControllerConfig()
    faults: faults.FaultConfig = faults.FaultConfig()
                                    # in-graph fault injection (DESIGN.md
                                    # §14): Gilbert–Elliott client dropout,
                                    # deep-fade block erasures on the OAC
                                    # aggregate, NaN/Inf gradient
                                    # corruption.  All rates 0 (default)
                                    # traces the historical program
                                    # bit-exactly; any rate > 0 turns on
                                    # the engine's sanitize stage and the
                                    # realised-participation rescale
    watchdog: Optional[faults.WatchdogConfig] = None
                                    # divergence watchdog: EMA'd loss /
                                    # update-norm guard that rolls params +
                                    # server state back to an in-graph
                                    # shadow snapshot on a spike and
                                    # tightens k_M for a cooldown window.
                                    # None (default) traces nothing extra
    population: Optional[population.PopulationConfig] = None
                                    # population-scale client churn
                                    # (DESIGN.md §15): the N compute
                                    # clients are the cohort the server
                                    # samples each round out of a
                                    # 1e5-1e6-strong virtual population
                                    # whose packed availability chains
                                    # ride the fault-state carry.  The
                                    # round gates the OAC superposition by
                                    # the realised participation (rescaled
                                    # via ``faults.participation_scale``)
                                    # and erases symbol blocks lost to
                                    # mid-round churn through the
                                    # sanitize path.  Requires
                                    # ``participants == n_clients``;
                                    # composes with fade/nan_rate faults
                                    # but not with ``faults.dropout``
                                    # (one availability process at a
                                    # time).  None (default) traces the
                                    # historical program bit-exactly
    wireless: Optional[chan.ChannelConfig] = None
                                    # geometric wireless channel
                                    # (DESIGN.md §16): per-client path
                                    # loss + AR(1) Rayleigh fading with
                                    # truncated channel inversion — the
                                    # per-client fading chain rides the
                                    # fault-state carry like the GE
                                    # availability chains; clients whose
                                    # gain misses max(gmin, 1/pmax) sit
                                    # the round out (survivors arrive
                                    # coherently inverted), a TOTAL
                                    # outage erases the round through
                                    # the sanitize path, and imperfect
                                    # CSI leaves a multiplicative
                                    # misalignment on each survivor.
                                    # Replaces the iid scalar
                                    # ``channel`` fading (its noise_std
                                    # still applies — receiver noise
                                    # survives inversion).  Composes
                                    # with faults AND population
                                    # (ordering: availability → channel
                                    # outage → corrupt → sanitize).
                                    # None (default) traces the
                                    # historical program bit-exactly
    client_chunk: Optional[int] = None
                                    # streaming client aggregation
                                    # (DESIGN.md §17): the client phase
                                    # runs as a ``lax.scan`` over cohort
                                    # chunks of this static size — each
                                    # chunk computes its vmapped H-step
                                    # local gradients, applies every
                                    # per-client gate (fading,
                                    # availability, participation,
                                    # channel survivorship, CSI, one-bit
                                    # quantizer) in registers and folds
                                    # into (d,)/(k,) accumulators, so the
                                    # (N, d) gradient and vote matrices
                                    # are never live: peak client-phase
                                    # memory is O(chunk · d), each
                                    # gradient is read exactly once.
                                    # Must divide n_clients.  None = one
                                    # chunk of N — bit-exact with the
                                    # historical materialise-then-einsum
                                    # trace (same program, chunk count 1)
    seed: int = 0

    @property
    def adaptive(self) -> bool:
        return self.adaptive_km or self.policy == "fairk_auto"

    @property
    def chaos(self) -> bool:
        return self.faults.enabled

    def budgets(self, d: int, k_m_frac: Optional[float] = None
                ) -> Tuple[int, int, int]:
        """(k, k_M, r) — delegated to the engine so the Remark-1 pinning
        and rounding rules live in exactly one place."""
        eng = SelectionEngine(EngineConfig(
            policy="fairk" if self.policy == "fairk_auto" else self.policy,
            rho=self.compression_ratio,
            k_m_frac=self.k_m_frac if k_m_frac is None else k_m_frac,
            r_frac=self.r_frac), d)
        return eng.budgets()


@dataclasses.dataclass
class ServerState:
    """Flat lane-aligned server buffers carried across rounds (the FL sim's
    single-leaf packed layout: lane=1, no pads).  ``residual`` is the
    error-feedback accumulator (zeros when EF is off) and ``theta`` the
    warm-start threshold state (packed backend)."""
    w: Array                        # flat global model (d,)
    g: Array                        # last reconstructed gradient (d,)
    age: Array                      # AoU vector (d,)
    sel_count: Array                # per-entry participation counter (Fig. 5b)
    residual: Array = None          # EF accumulator (d,)
    theta: Dict[str, Array] = None  # packing.init_threshold_state()
    ctrl: Dict[str, Array] = None   # budget.init_controller_state()
    round: int = 0


def make_fl_step(fl: FLConfig, unravel: Callable, loss_fn: Callable,
                 d: int, k_m_frac: Optional[float] = None):
    """Build the jitted one-round function.

    ``loss_fn(params, x, y) -> scalar`` is the per-client loss; client data
    arrives as stacked arrays (N, H, B, ...).

    With ``fl.adaptive`` (``adaptive_km=True`` or the ``fairk_auto``
    policy alias) the magnitude split rides as a traced value from the
    carried controller state, and the in-graph ``BudgetController``
    update runs at the end of the same compiled round — the historical
    host-side Gini path (full-gradient device sync every 10 rounds + one
    recompiled step per discrete k_M level) is gone."""
    k, k_m, r = fl.budgets(d, k_m_frac)
    grad_fn = jax.grad(loss_fn)
    if fl.backend not in ("exact", "threshold", "packed"):
        raise ValueError(f"FLConfig.backend must be exact|threshold|packed, "
                         f"got {fl.backend!r}")
    adaptive = fl.adaptive
    if adaptive and fl.policy not in ("fairk", "fairk_auto"):
        raise ValueError("adaptive_km moves the FAIR-k split — policy "
                         f"{fl.policy!r} pins or ignores it")
    if fl.async_lag < 0:
        raise ValueError(f"async_lag must be >= 0, got {fl.async_lag}")
    chaos = fl.chaos
    wdcfg = fl.watchdog
    pop = fl.population is not None
    wl = fl.wireless is not None
    if chaos and fl.one_bit:
        raise ValueError("fault injection on the one-bit FSK-MV uplink is "
                         "not modelled — run chaos with one_bit=False")
    if (chaos or pop or wl) and fl.policy not in ("fairk", "topk",
                                                  "roundrobin",
                                                  "fairk_auto"):
        raise ValueError("chaos/population/wireless rounds run selection "
                         f"in sanitized threshold/rank form — policy "
                         f"{fl.policy!r} needs index arithmetic")
    if wl and fl.wireless.n_clients != fl.n_clients:
        raise ValueError(
            "the wireless deployment covers the compute clients: "
            f"wireless.n_clients={fl.wireless.n_clients} must equal "
            f"n_clients={fl.n_clients}")
    if pop:
        if fl.population.participants != fl.n_clients:
            raise ValueError(
                "the FL sim's compute clients ARE the sampled cohort: "
                f"population.participants={fl.population.participants} "
                f"must equal n_clients={fl.n_clients}")
        if fl.faults.dropout > 0.0:
            raise ValueError(
                "population availability and FaultConfig.dropout are two "
                "availability processes gating the same superposition — "
                "run one at a time (fade/nan_rate compose fine)")
        if fl.one_bit:
            raise ValueError("population churn on the one-bit FSK-MV "
                             "uplink is not modelled — run population "
                             "with one_bit=False")
    if wdcfg is not None and fl.policy not in ("fairk", "fairk_auto"):
        raise ValueError("the watchdog tightens the FAIR-k split — policy "
                         f"{fl.policy!r} pins or ignores it")
    chunk = fl.client_chunk if fl.client_chunk is not None else fl.n_clients
    if not 1 <= chunk <= fl.n_clients or fl.n_clients % chunk:
        raise ValueError(
            f"client_chunk={fl.client_chunk} must be in [1, n_clients] and "
            f"divide n_clients={fl.n_clients} (the chunk scan needs a "
            f"static, uniform cohort shape)")
    n_chunks = fl.n_clients // chunk
    age_lag = fl.async_lag or None
    # controller setpoint thinning: fault channels, population churn and
    # channel-truncation outage all block refreshes independently per
    # round, so their rates add (to first order)
    thin_total = min(0.99, (fl.faults.thin if chaos else 0.0)
                     + (fl.population.thin if pop else 0.0)
                     + (fl.wireless.thin if wl else 0.0))
    bctrl = (budget.BudgetController(fl.controller,
                                     rho=fl.compression_ratio,
                                     age_offset=float(fl.async_lag),
                                     thin=thin_total)
             if adaptive else None)
    # the realised static split (Remark-1 policies pin it: topk -> 1,
    # roundrobin -> 0) — what the km_frac telemetry records
    frac_static = jnp.float32(k_m / k if k else 0.0)

    def client_update(w_flat: Array, xs: Array, ys: Array) -> Array:
        """H local SGD steps; returns the accumulated gradient (Eq. 5)."""
        def step(w, batch):
            x, y = batch
            g_tree = grad_fn(unravel(w), x, y)
            g_flat = ravel_pytree(g_tree)[0]
            return w - fl.local_lr * g_flat, None
        w_final, _ = jax.lax.scan(step, w_flat, (xs, ys))
        return (w_flat - w_final) / fl.local_lr   # = sum of local gradients

    clients = jax.vmap(client_update, in_axes=(None, 0, 0))

    def _stream(w_flat, xs, ys, rows, init, fold):
        """Streaming client aggregation (DESIGN.md §17): ``lax.scan`` over
        ``n_chunks`` client chunks of static size ``chunk``.  Each scan
        step runs the vmapped H-step local update for ONE chunk, then
        ``fold(acc, grads_chunk, *row_chunks)`` applies the per-client
        gates and reduces the (chunk, d) gradients into the (d,)/(k,)
        accumulator pytree ``init`` — the (N, d) matrix is never live and
        each client gradient is read exactly once.  ``rows`` are per-client
        (N,)-leading weight vectors sliced chunk-wise alongside the data.

        One chunk of N (``client_chunk=None``) is the historical
        materialise-then-reduce trace bit-exactly: the accumulators start
        at zeros (0 + x == x in f32 up to -0.0 -> +0.0, and every
        downstream consumer compares with >=/==), and the per-chunk gate +
        reduction is the identical expression the dense path evaluated."""
        global CLIENT_STREAM_PASSES
        CLIENT_STREAM_PASSES += 1
        xs_c = xs.reshape((n_chunks, chunk) + xs.shape[1:])
        ys_c = ys.reshape((n_chunks, chunk) + ys.shape[1:])
        rows_c = tuple(r.reshape((n_chunks, chunk) + r.shape[1:])
                       for r in rows)

        def body(acc, sliced):
            xc, yc = sliced[0], sliced[1]
            return fold(acc, clients(w_flat, xc, yc), *sliced[2:]), None

        acc, _ = jax.lax.scan(body, init, (xs_c, ys_c) + rows_c)
        return acc

    policy_name = "fairk" if fl.policy == "fairk_auto" else fl.policy
    # the flat (d,) server vector is a trivially packed single-leaf layout
    # (lane=1: no pads — ops.fairk_update handles trailing alignment) — the
    # packed backend rides it to get warm-start thresholds
    layout = (packing.PackedLayout.from_tree([SDS((d,), jnp.float32)], lane=1)
              if fl.backend == "packed" else None)
    engine = SelectionEngine(
        EngineConfig(policy=policy_name, backend=fl.backend,
                     k=k, k_m=k_m, r=r,
                     # one-bit: the channel perturbs the vote energy (inside
                     # sign_mv), not the merged values — engine noise off
                     noise_std=(fl.channel.noise_std
                                if (fl.backend != "exact" or chaos or pop
                                    or wl)
                                and not fl.one_bit
                                else 0.0),
                     n_clients=fl.n_clients,
                     # kernel-emitted counts/histograms on the kernel
                     # routes; on packed this also moves the warm-start
                     # re-estimation onto the carried histograms, making
                     # the fused pass the round's only read of the buffer.
                     # chaos/population rounds need them on exact too (the
                     # adaptive controller consumes them from the unified
                     # branch)
                     fused_stats=(fl.backend != "exact") or chaos or pop
                     or wl,
                     warm_start=(fl.backend == "packed")), d,
        layout=layout)

    def _round_metrics(age_next: Array, kmf) -> Dict[str, Array]:
        """On-device per-round telemetry — the trainer loop accumulates
        these WITHOUT materialising them (no per-round host sync)."""
        return {"mean_aou": age_next.mean(), "max_aou": age_next.max(),
                "km_frac": jnp.asarray(kmf, jnp.float32)}

    # key-split discipline: every chaos × population × wireless
    # combination keeps its historical split count (bit-exact
    # trajectories) — the ladder lives as data in core/keys.py
    key_names = keys_mod.round_key_names(base=("sel", "ch"), chaos=chaos,
                                         pop=pop, wl=wl)

    def _round(key: Array, w: Array, g_prev: Array, age: Array,
               sel_count: Array, xs: Array, ys: Array, residual: Array,
               tstate, cstate, fstate):
        ks = keys_mod.split_named(key, key_names)
        key_sel, key_ch = ks["sel"], ks["ch"]
        key_av, key_fd, key_nz = ks.get("av"), ks.get("fd"), ks.get("nz")
        key_pop, key_er = ks.get("pop"), ks.get("er")
        key_fad, key_csi = ks.get("fad"), ks.get("csi")
        kmf = cstate["k_m_frac"] if adaptive else None
        if wdcfg is not None:
            # cooldown tightening: for ``cooldown`` rounds after a trip
            # the magnitude split shrinks by ``tighten`` — traced data,
            # never a recompile
            k_scale = jnp.where(fstate["wd"]["cooldown"] > 0.0,
                                jnp.float32(wdcfg.tighten),
                                jnp.float32(1.0))
            kmf = (kmf if kmf is not None else frac_static) * k_scale

        def _guard(w_next, g_t, age_next, sel_count, residual, tstate,
                   cstate, fstate):
            """Divergence watchdog (DESIGN.md §14): observe this round's
            (loss, ‖g_t‖); a spike over the EMA — or any non-finite
            observation — rolls every carried buffer back to the in-graph
            shadow snapshot; healthy out-of-cooldown rounds refresh it."""
            if wdcfg is None:
                return (w_next, g_t, age_next, sel_count, residual, tstate,
                        cstate, fstate)
            loss = loss_fn(unravel(w_next), xs[0, 0], ys[0, 0])
            unorm = jnp.linalg.norm(g_t)
            wd, trip, _ = faults.watchdog_step(wdcfg, fstate["wd"], loss,
                                               unorm)
            live = (w_next, g_t, age_next, sel_count, residual, tstate,
                    cstate)
            rolled = faults.tree_select(trip, fstate["snap"], live)
            healthy = jnp.logical_not(trip) & (wd["cooldown"] <= 0.0)
            snap = faults.tree_select(healthy, rolled, fstate["snap"])
            return (*rolled, {**fstate, "wd": wd, "snap": snap})

        if fl.backend in ("threshold", "packed") or chaos or pop or wl:
            ts = tstate if fl.backend == "packed" else None
            if wl:
                # geometric channel round (DESIGN.md §16): advance the
                # carried per-client AR(1) Rayleigh fading chain and run
                # truncated channel inversion — ``sent`` gates which
                # clients clear ``max(gmin, 1/pmax)`` this round, and
                # ``w_csi`` is each survivor's residual multiplicative
                # misalignment from the imperfect channel estimate
                cnext, cps = chan.channel_round(fstate["chan"], key_fad,
                                                fl.wireless)
                fstate = {**fstate, "chan": cnext}
                w_csi = chan.csi_weights(key_csi, fl.n_clients,
                                         fl.wireless)
            if fl.one_bit:
                # FSK-MV uplink (Sec. V-B): clients transmit sign(ǧ_{n,t})
                # and the server recovers majority-vote signs; selection
                # scores the superposed vote ENERGY (consensus strength —
                # the server-observable magnitude statistic; stale sign
                # vectors are all-|1| and carry no magnitude information).
                # Each chunk quantizes, gates and reduces its votes in one
                # ``sign_mv`` launch; the partial energies fold into one
                # (d,) accumulator (the (N, d) vote matrix is never live)
                # and ``sign_from_energy`` runs the majority stage on the
                # total.  The wl vote weight rides the fold as a per-client
                # row: truncated clients cast a ±0.0 "vote" that sign_mv's
                # internal re-sign counts as +1 — the historical semantics,
                # preserved exactly by reducing per chunk through the same
                # kernel.
                vote_w = (cps["sent"] * w_csi,) if wl else ()

                def fold_votes(acc, g, *row):
                    eff = (g + residual[None, :] if fl.error_feedback
                           else g)
                    votes = quantize.one_bit(eff)        # (chunk, d) ±1
                    if wl:
                        votes = votes * row[0][:, None]
                    out = (acc[0] + ops.sign_mv(votes)[1],)
                    if fl.error_feedback:
                        out += (acc[1] + eff.sum(axis=0),)
                    return out

                init = ((jnp.zeros((d,), jnp.float32),)
                        * (2 if fl.error_feedback else 1))
                accs = _stream(w, xs, ys, vote_w, init, fold_votes)
                noise = (fl.channel.noise_std
                         * jax.random.normal(key_ch, (d,), jnp.float32)
                         if fl.channel.noise_std > 0.0 else None)
                fresh_sign, energy = ops.sign_from_energy(accs[0],
                                                          noise=noise)
                # noiseless energies are heavily TIED (even integers in
                # [-N, N]): a quantile threshold inside a tie level would
                # select the whole level and blow the k budget, so break
                # |energy| ties with the sub-unit index jitter (levels sit
                # 2 apart — ordering across levels is preserved; same
                # Knuth hash the kernels use)
                score = jnp.abs(energy) + index_jitter(d)
                # a total truncation outage leaves nothing but noise in
                # the vote energies — erase the round through the
                # sanitize path instead of merging noise-driven signs
                ob_erase = (faults.erase_with_outage(
                    jnp.zeros((d,), jnp.float32), cps["n_sent"])
                    if wl else None)
                g_t, age_next, stats = engine.select_and_merge(
                    score, g_prev, age, fresh=fresh_sign, tstate=ts,
                    k_m_frac=kmf, age_lag=age_lag, erase=ob_erase,
                    sanitize=wl)
                # async mode shifts the refreshed ages to the lag, so the
                # engine hands the selection mask back explicitly
                sel_mask = (stats["sel_mask"] if age_lag
                            else (age_next == 0.0).astype(jnp.float32))
                if fl.error_feedback:
                    # unsent mass of the mean effective gradient — the same
                    # accounting the exact one-bit path keeps (quantization
                    # error on sent coords is NOT tracked: the server only
                    # ever sees signs).  The fold accumulated Σ_n eff_n;
                    # sum/N is the mean the dense path took
                    residual = (accs[1] / fl.n_clients) * (1.0 - sel_mask)
            else:
                # production-scale server phase: faded aggregate, then one
                # fused threshold select+merge pass (selection scores the
                # fresh aggregate — the threshold route's operating
                # point).  EF is server-side: the residual folds into the
                # score/sent values INSIDE the fused kernel and its
                # successor comes back from the same pass.
                #
                # Every per-client gate composes into ONE (N,) weight row
                # ``wv`` BEFORE any gradient exists:
                #   wl:    w_csi · sent · (participation | availability) —
                #          truncated channel inversion (DESIGN.md §16):
                #          only clients clearing max(gmin, 1/pmax)
                #          transmit; survivors arrive coherently inverted
                #          up to the CSI misalignment, so the survivor
                #          gate REPLACES the iid scalar fading draw, and
                #          availability (GE chain or population churn)
                #          composes BEFORE the outage
                #   pop:   h · participation (DESIGN.md §15 cohort draw)
                #   chaos: h · availability (Gilbert–Elliott chain)
                #   plain: h (iid scalar fading)
                # and the superposition streams: each chunk's vmapped
                # local gradients contract against their weight slice
                # (``einsum("n,nd->d")`` on the chunk — the register-level
                # gate-and-accumulate) into one (d,) accumulator, so the
                # (N, d) matrix is never live and each gradient is read
                # exactly once (the retired path materialised it and
                # re-read it through three gated einsum variants).
                if not wl:
                    h = oac.sample_fading(key_sel, fl.n_clients,
                                          fl.channel)
                erase = None
                n_t = None
                if pop:
                    pnext, ps = population.population_round(
                        fstate["pop"], key_pop, fl.population)
                    fstate = {**fstate, "pop": pnext}
                elif chaos:
                    avail = faults.avail_step(fstate["avail"], key_av,
                                              fl.faults)
                    fstate = {**fstate, "avail": avail}
                if wl:
                    gate = cps["sent"]
                    if pop:
                        gate = ps["part"] * gate
                    elif chaos:
                        gate = avail * gate
                    n_t = gate.sum()
                    wv = w_csi * gate
                elif pop:
                    n_t = ps["n_t"]
                    wv = h * ps["part"]
                elif chaos:
                    n_t = avail.sum()
                    wv = h * avail
                else:
                    wv = h
                total = _stream(
                    w, xs, ys, (wv,), jnp.zeros((d,), jnp.float32),
                    lambda acc, g, wc: acc + jnp.einsum("n,nd->d", wc, g))
                # the realised-participation rescale (guarded 1/N_t) on
                # the gated combinations, the plain 1/N average otherwise;
                # rare non-finite corruption hits the aggregate itself
                fresh = (faults.participation_scale(total, n_t)
                         if n_t is not None else total / fl.n_clients)
                if chaos:
                    fresh = faults.corrupt(fresh, key_nz, fl.faults)
                if wl or pop or chaos:
                    # erase composition: churn block loss and deep fades
                    # stack (max — a block lost twice is still lost), and
                    # a TOTAL outage (n_t == 0: nothing superposed this
                    # round) erases the whole round through the sanitize
                    # path — coordinates stay semantically unsent, age
                    # climbing, exactly the Lemma-1 thinning model the
                    # validation suites check against
                    erase = jnp.zeros((d,), jnp.float32)
                    if pop:
                        erase = jnp.maximum(
                            erase, population.churn_erase_mask(
                                key_er, d, ps["churn"], fl.population))
                    if chaos:
                        erase = jnp.maximum(
                            erase, faults.fade_mask(key_fd, d, fl.faults))
                    erase = faults.erase_with_outage(erase, n_t)
                g_t, age_next, stats = engine.select_and_merge(
                    fresh, g_prev, age, key=key_ch, tstate=ts,
                    residual=residual if fl.error_feedback else None,
                    k_m_frac=kmf, age_lag=age_lag, erase=erase,
                    sanitize=chaos or pop or wl)
                sel_mask = (stats["sel_mask"] if age_lag
                            else (age_next == 0.0).astype(jnp.float32))
                if fl.error_feedback:
                    residual = stats["residual"]
            w_next = w - fl.global_lr * g_t              # Eq. (9)
            sel_count = sel_count + sel_mask
            if adaptive:
                # the controller consumes the histograms the fused pass
                # already emitted (fused_stats is on for these backends)
                cstate = bctrl.update(cstate, stats["age_hist"],
                                      stats["mag_hist"])
            (w_next, g_t, age_next, sel_count, residual, tstate, cstate,
             fstate) = _guard(w_next, g_t, age_next, sel_count, residual,
                              stats.get("tstate", tstate), cstate, fstate)
            return (w_next, g_t, age_next, sel_count, residual, sel_mask,
                    tstate, cstate,
                    _round_metrics(age_next,
                                   kmf if kmf is not None else frac_static),
                    fstate)
        if kmf is not None:
            # traced split on the exact path: rank-based FAIR-k (same
            # coordinate set as the index form, incl. the toward-lower-
            # index tie-break), indices recovered at the static size k
            mask_dyn, _ = fair_k_masks_dynamic(jnp.abs(g_prev), age, k,
                                               traced_km(k, kmf))
            idx = jnp.nonzero(mask_dyn, size=k, fill_value=0)[0]
            idx = idx.astype(jnp.int32)
        else:
            idx = engine.select(key_sel, g_prev, age)    # Eq. (11)
        sel_mask = jnp.zeros((d,), jnp.float32).at[idx].set(1.0)
        # paper-faithful streaming uplink: selection (Eq. 11) scores
        # (g_prev, age) — independent of this round's gradients — so the
        # client phase can stream straight into the compacted (k,)
        # accumulator: each chunk's vmapped local gradients are EF-shifted,
        # gathered at ``idx`` and reduced (faded contraction on the
        # coherent route, ±1 vote sum on the one-bit route) before the
        # next chunk computes.  The (N, d) matrix of the retired path —
        # and the (N, k) compacted/vote matrix inside oac_round /
        # one_bit_round — are never live; EF additionally folds Σ_n eff_n
        # into a (d,) row for the residual update (sum/N is the mean the
        # dense path took; the shared mask keeps the residual identical
        # across clients, so it lives server-side)
        ef = fl.error_feedback
        if fl.one_bit:
            def fold_votes(acc, g):
                eff = g + residual[None, :] if ef else g
                out = (acc[0] + quantize.one_bit(eff[:, idx]).sum(axis=0),)
                if ef:
                    out += (acc[1] + eff.sum(axis=0),)
                return out

            init = ((jnp.zeros((k,), jnp.float32),)
                    + ((jnp.zeros((d,), jnp.float32),) if ef else ()))
            accs = _stream(w, xs, ys, (), init, fold_votes)
            agg_sign = quantize.fsk_majority_from_energy(
                key_ch, accs[0], noise_std=fl.channel.noise_std)
            g_t = oac.reconstruct(g_prev, idx, agg_sign)
        else:
            # same key walk as oac.oac_aggregate: fading from the first
            # subkey, receiver noise from the second
            key_h, key_z = jax.random.split(key_ch)
            h = oac.sample_fading(key_h, fl.n_clients, fl.channel)

            def fold_faded(acc, g, hc):
                eff = g + residual[None, :] if ef else g
                out = (acc[0] + jnp.einsum("n,nk->k", hc, eff[:, idx]),)
                if ef:
                    out += (acc[1] + eff.sum(axis=0),)
                return out

            init = ((jnp.zeros((k,), jnp.float32),)
                    + ((jnp.zeros((d,), jnp.float32),) if ef else ()))
            accs = _stream(w, xs, ys, (h,), init, fold_faded)
            agg = oac.finish_aggregate(key_z, accs[0], fl.n_clients,
                                       fl.channel)                # Eq. (7)
            g_t = oac.reconstruct(g_prev, idx, agg)               # Eq. (8)
        if ef:
            residual = (accs[1] / fl.n_clients) * (1.0 - sel_mask)
        w_next = w - fl.global_lr * g_t                  # Eq. (9)
        age_next = update_age_by_indices(age, idx)       # Eq. (10)
        if age_lag:
            # exact-path async bookkeeping: the refreshed coordinates'
            # contribution lands age_lag rounds late — same shift the
            # engine backends apply
            age_next = packing.shift_selected_age(age_next, age_lag)
        sel_count = sel_count.at[idx].add(1.0)
        if adaptive:
            # the exact path has no kernel, so the staleness histogram
            # comes from the same jnp helper the kernel oracle uses (no
            # mag_hist: the controller's mag_ema tracks the KERNEL'S
            # |score| histogram only — see core/controller.py)
            _, age_hist = ref.strided_hists_ref(
                g_t, age_next, age >= 0.0, packing.hist_stride(d))
            cstate = bctrl.update(cstate, age_hist)
        (w_next, g_t, age_next, sel_count, residual, tstate, cstate,
         fstate) = _guard(w_next, g_t, age_next, sel_count, residual,
                          tstate, cstate, fstate)
        # sel_mask is the dense selection mask on ALL backends, so callers
        # can swap backends without changing what they consume
        return (w_next, g_t, age_next, sel_count, residual, sel_mask,
                tstate, cstate,
                _round_metrics(age_next,
                               kmf if kmf is not None else frac_static),
                fstate)

    if chaos or wdcfg is not None or pop or wl:
        # extended step: the chaos/watchdog/population/wireless carry
        # (``init_fault_state``) rides as an 11th argument and comes back
        # as a 10th output
        return jax.jit(_round)

    @jax.jit
    def fl_round(key: Array, w: Array, g_prev: Array, age: Array,
                 sel_count: Array, xs: Array, ys: Array, residual: Array,
                 tstate, cstate):
        return _round(key, w, g_prev, age, sel_count, xs, ys, residual,
                      tstate, cstate, None)[:9]

    return fl_round


def init_server(init_params: Any, fl: Optional[FLConfig] = None
                ) -> Tuple[ServerState, Callable]:
    flat, unravel = ravel_pytree(init_params)
    d = flat.shape[0]
    state = ServerState(
        w=flat,
        g=jnp.zeros((d,), flat.dtype),
        age=jnp.zeros((d,), jnp.float32),
        sel_count=jnp.zeros((d,), jnp.float32),
        residual=jnp.zeros((d,), jnp.float32),
        theta=packing.init_threshold_state(),
        ctrl=budget.init_controller_state(
            fl.k_m_frac if fl is not None else 0.75),
    )
    return state, unravel


def init_fault_state(fl: FLConfig, state: ServerState,
                     key: Optional[Array] = None) -> Dict[str, Any]:
    """Initial chaos/watchdog carry for the extended step returned by
    ``make_fl_step`` when ``fl.chaos`` or ``fl.watchdog`` is set:
    ``avail`` is the Gilbert–Elliott availability vector, ``wd`` the
    watchdog EMA state, ``snap`` the in-graph shadow snapshot the
    watchdog rolls back to (params + every carried server buffer),
    ``pop`` the packed virtual-population state (DESIGN.md §15) and
    ``chan`` the per-client AR(1) Rayleigh fading chain of the wireless
    channel (DESIGN.md §16) — a stationary draw, not zeros (zeros would
    be a dead channel, not the stationary law)."""
    fstate: Dict[str, Any] = {}
    if key is None:
        key = jax.random.PRNGKey(fl.seed + 0x5EED)
    if fl.chaos:
        fstate["avail"] = faults.init_avail_state(key, fl.n_clients,
                                                  fl.faults)
    if fl.population is not None:
        fstate["pop"] = population.init_population_state(
            jax.random.fold_in(key, 0x404), fl.population)
    if fl.wireless is not None:
        fstate["chan"] = chan.init_channel_state(
            jax.random.fold_in(key, 0xC4A), fl.wireless)
    if fl.watchdog is not None:
        fstate["wd"] = faults.init_watchdog_state()
        fstate["snap"] = (state.w, state.g, state.age, state.sel_count,
                          state.residual, state.theta, state.ctrl)
    return fstate


def train(fl: FLConfig, init_params: Any, loss_fn: Callable,
          sample_round: Callable[[int], Tuple[np.ndarray, np.ndarray]],
          eval_fn: Optional[Callable] = None, eval_every: int = 20,
          verbose: bool = False) -> Dict[str, Any]:
    """Run ``fl.rounds`` communication rounds.

    Args:
      loss_fn(params, x, y) -> scalar loss.
      sample_round(t) -> (xs, ys) stacked client batches (N, H, B, ...).
      eval_fn(params) -> dict of metrics (e.g. test accuracy).
    Returns a history dict (accuracy curve, mean AoU, selection counts...).
    """
    state, unravel = init_server(init_params, fl)
    d = state.w.shape[0]
    # ONE compiled step for the whole run: with fl.adaptive (incl. the
    # fairk_auto alias) the k_M split rides as traced controller state, so
    # adaptation never recompiles — the historical per-level step cache
    # and its host-side Gini sync are gone
    fl_step = make_fl_step(fl, unravel, loss_fn, d)
    key = jax.random.PRNGKey(fl.seed)
    has_fstate = (fl.chaos or fl.watchdog is not None
                  or fl.population is not None or fl.wireless is not None)
    fstate = init_fault_state(fl, state) if has_fstate else None

    history: Dict[str, Any] = {"round": [], "acc": [],
                               "k": fl.budgets(d)[0], "d": d}
    w, g, age, sel_count = state.w, state.g, state.age, state.sel_count
    residual, tstate, cstate = state.residual, state.theta, state.ctrl
    # per-round telemetry accumulates as DEVICE scalars and materialises
    # in one transfer after the loop — float(age.mean()) et al. used to
    # block on the device every round
    mean_aou, max_aou, km_frac = [], [], []

    def _is_eval_round(t: int) -> bool:
        return eval_fn is not None and ((t + 1) % eval_every == 0
                                        or t == 0 or t == fl.rounds - 1)

    def _do_eval(t: int, w, rm_mean) -> None:
        metrics = eval_fn(unravel(w))
        history["round"].append(t + 1)
        history["acc"].append(float(metrics.get("acc", np.nan)))
        if verbose:
            print(f"  round {t+1:4d}  acc={history['acc'][-1]:.4f}  "
                  f"meanAoU={float(rm_mean):.2f}", flush=True)

    if fl.scan_rounds > 1:
        # multi-round fusion: a chunk of rounds advances inside ONE
        # ``lax.scan``'d compiled program — chunk-many dispatches (and
        # their host round-trips) collapse into one.  The key splits
        # INSIDE the scan exactly as the loop path splits it on the host,
        # so both paths walk bit-identical trajectories; chunks are cut
        # at eval boundaries (eval reads w mid-run), so each distinct
        # chunk length compiles once.
        @jax.jit
        def fl_chunk(key, w, g, age, sel_count, xs, ys, residual, tstate,
                     cstate, fstate):
            def body(carry, batch):
                (key, w, g, age, sel_count, residual, tstate, cstate,
                 fs) = carry
                key, sub = jax.random.split(key)
                bx, by = batch
                if has_fstate:
                    (w, g, age, sel_count, residual, _, tstate, cstate,
                     rm, fs) = fl_step(sub, w, g, age, sel_count, bx, by,
                                       residual, tstate, cstate, fs)
                else:
                    (w, g, age, sel_count, residual, _, tstate, cstate,
                     rm) = fl_step(sub, w, g, age, sel_count, bx, by,
                                   residual, tstate, cstate)
                return (key, w, g, age, sel_count, residual, tstate,
                        cstate, fs), rm
            carry, rms = jax.lax.scan(
                body, (key, w, g, age, sel_count, residual, tstate,
                       cstate, fstate), (xs, ys))
            return carry, rms

        def _stage_chunk(t0: int, n: int):
            """Draw the chunk's host batches into ONE preallocated buffer
            pair and ship each as a single device transfer.  Same
            ``sample_round`` call order as the historical per-round list
            (bit-exact data stream); the list-of-arrays + ``np.stack``
            staging paid an extra full host copy of every chunk and a
            device transfer per unlucky layout."""
            bx, by = sample_round(t0)
            bx, by = np.asarray(bx), np.asarray(by)
            xs_h = np.empty((n,) + bx.shape, bx.dtype)
            ys_h = np.empty((n,) + by.shape, by.dtype)
            xs_h[0], ys_h[0] = bx, by
            for i in range(1, n):
                bx, by = sample_round(t0 + i)
                xs_h[i] = np.asarray(bx)
                ys_h[i] = np.asarray(by)
            return jnp.asarray(xs_h), jnp.asarray(ys_h)

        t = 0
        while t < fl.rounds:
            stop = fl.rounds
            if eval_fn is not None:
                for u in range(t, fl.rounds):
                    if _is_eval_round(u):
                        stop = u + 1
                        break
            chunk = min(fl.scan_rounds, stop - t)
            xs, ys = _stage_chunk(t, chunk)
            (key, w, g, age, sel_count, residual, tstate, cstate,
             fstate), rms = fl_chunk(key, w, g, age, sel_count, xs, ys,
                                     residual, tstate, cstate, fstate)
            mean_aou.append(rms["mean_aou"])
            max_aou.append(rms["max_aou"])
            km_frac.append(rms["km_frac"])
            t += chunk
            if _is_eval_round(t - 1):
                _do_eval(t - 1, w, rms["mean_aou"][-1])
    else:
        for t in range(fl.rounds):
            key, sub = jax.random.split(key)
            xs, ys = sample_round(t)
            args = (sub, w, g, age, sel_count, jnp.asarray(xs),
                    jnp.asarray(ys), residual, tstate, cstate)
            if has_fstate:
                (w, g, age, sel_count, residual, _, tstate, cstate, rm,
                 fstate) = fl_step(*args, fstate)
            else:
                (w, g, age, sel_count, residual, _, tstate, cstate,
                 rm) = fl_step(*args)
            mean_aou.append(rm["mean_aou"])
            max_aou.append(rm["max_aou"])
            km_frac.append(rm["km_frac"])
            if _is_eval_round(t):
                _do_eval(t, w, rm["mean_aou"])
    cat = lambda vals: (np.asarray(jnp.concatenate(
        [jnp.atleast_1d(v) for v in vals])).tolist() if vals else [])
    history["mean_aou"] = cat(mean_aou)
    history["max_aou"] = cat(max_aou)
    history["km_frac"] = cat(km_frac)
    history["sel_count"] = np.asarray(sel_count)
    history["final_age"] = np.asarray(age)
    history["params"] = unravel(w)
    if fl.watchdog is not None:
        history["wd_trips"] = float(fstate["wd"]["trips"])
    return history
