"""Vmapped sweep driver: many (policy × k_m × seed) OAC-FL simulations in
ONE compiled program.

The paper's figures sweep the k_M/k ratio, the selection policy and the
random seed — dozens of runs that the per-figure benchmarks execute
sequentially.  Scenario-diversity studies want hundreds.  This driver
batches the entire grid through ``jax.vmap``: every grid point is one
simulated OAC-FL server (quadratic heterogeneous clients, Rayleigh fading,
channel noise) and the whole grid advances round-by-round inside a single
``lax.scan``.

The trick that makes the grid vmappable is a *rank-based* FAIR-k
(``core.engine.fair_k_mask_dynamic`` — the same traced-``k_m`` stage the
SelectionEngine runs, promoted there so the sweep, the trainer and the
engine can never drift apart): the exact policies concatenate top-k index
vectors whose lengths are static (``k_m`` cannot be a traced value), so
instead we select by rank —

    mask_M = rank(|score|)      < k_m          (magnitude stage)
    mask_A = rank(age ⊙ ¬mask_M) < k − k_m     (age stage)

which picks the identical coordinate set (rank and top-k agree on tie-free
inputs; ties break toward lower index in both) while ``k_m`` rides in as a
traced per-lane scalar.  Policy identity also rides in as data: a policy id
switches the magnitude score between |g| (FAIR-k family) and uniform noise
(Rand-k family), so fairk / topk / roundrobin / randk all share one program.

``fairk_auto`` lanes close the loop: the in-graph ``BudgetController``
(core/controller.py) carries its state through the scan and re-derives the
lane's ``k_m`` every round from the lane's own staleness histogram — the
adaptive policy is just one more vmapped axis of the same compiled program.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel as chan_mod
from repro.core import controller as budget
from repro.core import faults as fault_mod
from repro.core import keys as keys_mod
from repro.core import packing
from repro.core import population as pop_mod
from repro.core.engine import (AGE_CAP, fair_k_mask_dynamic,  # noqa: F401
                               rank_desc, traced_km)
from repro.kernels import ref

Array = jax.Array

# policy ids for the traced policy axis (fairk covers topk at k_m=k and
# roundrobin at k_m=0 — Remark 1; fairk_auto is fairk with the adaptive
# flag raised on its lanes)
POLICY_FAIRK = 0
POLICY_RANDK = 1
SWEEP_POLICIES = {"fairk": POLICY_FAIRK, "topk": POLICY_FAIRK,
                  "roundrobin": POLICY_FAIRK, "randk": POLICY_RANDK,
                  "fairk_auto": POLICY_FAIRK}


@dataclasses.dataclass(frozen=True)
class SweepConfig:
    """One synthetic OAC-FL scenario (shared by every grid point)."""
    d: int = 1024                  # model dimension
    n_clients: int = 16            # N
    rho: float = 0.2               # budget k / d
    rounds: int = 100
    local_steps: int = 2           # H (closed-form local SGD on quadratics)
    local_lr: float = 0.1          # eta_l
    global_lr: float = 0.05        # eta (stale coordinates replay up to
                                   # ~1/rho rounds, so eta * H * 1/rho must
                                   # stay inside the quadratic stability
                                   # window — see Lemma 1's T bound)
    shared: float = 3.0            # scale of the common optimum component
    hetero: float = 1.0            # client-optimum spread (non-IID knob)
    fading_mean: float = 1.0       # mu_c (Rayleigh)
    noise_std: float = 0.5         # sigma_z
    error_feedback: bool = False   # server-side EF: unsent aggregate mass
                                   # folds back into the next merge (the
                                   # engine's residual stage, here in the
                                   # vmapped rank-based form)
    async_lag: int = 0             # asynchronous aggregation (DESIGN.md
                                   # §13): refreshed coordinates restart at
                                   # age ``async_lag`` instead of 0, and
                                   # adaptive lanes shift their Lemma-1
                                   # target by the same constant.  0 keeps
                                   # the synchronous trajectory bit-exact
    controller: budget.ControllerConfig = budget.ControllerConfig()
                                   # adaptive-lane control law (fairk_auto)
    faults: fault_mod.FaultConfig = fault_mod.FaultConfig()
                                   # in-graph fault injection shared by
                                   # every lane: iid client dropout (the
                                   # Gilbert–Elliott chain's burst=None
                                   # special case — the sweep carries no
                                   # per-lane channel state), deep-fade
                                   # block erasures and NaN corruption on
                                   # the aggregate.  All-zero rates trace
                                   # the historical program bit-exactly
    population: Optional[pop_mod.PopulationConfig] = None
                                   # population-scale churn (DESIGN.md §15)
                                   # shared by every lane: each grid point
                                   # carries its OWN packed virtual
                                   # population through the scan (vmapped
                                   # like the controller state), samples
                                   # its cohort per round and erases
                                   # mid-round-churned symbol blocks in
                                   # rank form.  Composes with fade/
                                   # nan_rate faults, not with dropout
    wireless: Optional[chan_mod.ChannelConfig] = None
                                   # geometric wireless channel (DESIGN.md
                                   # §16) shared by every lane: each grid
                                   # point carries its OWN per-client
                                   # AR(1) Rayleigh fading chain through
                                   # the scan and runs truncated channel
                                   # inversion per round — survivors
                                   # superpose coherently inverted (up to
                                   # the CSI misalignment), a total
                                   # outage erases the round in rank
                                   # form.  Replaces the iid scalar
                                   # Rayleigh draw on its lanes; None
                                   # traces the historical program
                                   # bit-exactly
    client_chunk: Optional[int] = None
                                   # streaming client aggregation
                                   # (DESIGN.md §17), inherited from the
                                   # trainer's FLConfig.client_chunk:
                                   # every lane superposes its clients
                                   # through a lax.scan over chunks of
                                   # this static size, so the per-lane
                                   # (N, d) closed-form gradient matrix
                                   # is never live — at grid sizes the
                                   # vmapped lanes multiply that matrix
                                   # by n_grid, which is where the sweep
                                   # used to hit peak memory.  Must
                                   # divide n_clients; None = one chunk
                                   # of N (bit-exact historical trace)

    def __post_init__(self):
        if self.client_chunk is not None:
            if (self.client_chunk < 1
                    or self.n_clients % self.client_chunk):
                raise ValueError(
                    f"client_chunk={self.client_chunk} must be in "
                    f"[1, n_clients] and divide "
                    f"n_clients={self.n_clients}")
        if self.wireless is not None:
            if self.wireless.n_clients != self.n_clients:
                raise ValueError(
                    "the wireless deployment covers the sweep's compute "
                    f"clients: wireless.n_clients="
                    f"{self.wireless.n_clients} must equal "
                    f"n_clients={self.n_clients}")
        if self.population is not None:
            if self.population.participants != self.n_clients:
                raise ValueError(
                    "the sweep's compute clients ARE the sampled cohort: "
                    f"population.participants="
                    f"{self.population.participants} must equal "
                    f"n_clients={self.n_clients}")
            if self.faults.dropout > 0.0:
                raise ValueError(
                    "population availability and FaultConfig.dropout are "
                    "two availability processes gating the same "
                    "superposition — run one at a time")

    @property
    def k(self) -> int:
        return max(1, int(round(self.rho * self.d)))


def _one_round(cfg: SweepConfig, ctrl: budget.BudgetController,
               any_adaptive: bool, carry, key, policy_id, k_m, adaptive):
    """One OAC-FL round for one grid point (pure, vmappable).

    ``any_adaptive`` is STATIC (does the grid contain fairk_auto lanes at
    all?): purely static grids trace no histogram/controller work.  The
    per-lane ``adaptive`` flag is data — within a mixed grid every lane
    runs the same program and static lanes gate the controller out."""
    has_pop = cfg.population is not None
    has_wl = cfg.wireless is not None
    w, g_prev, age, res, cs, w_stars = carry[:6]
    tail = list(carry[6:])
    pstate = tail.pop(0) if has_pop else None
    chstate = tail.pop(0) if has_wl else None
    # key-split discipline: every combination keeps its historical split
    # count (the ladder lives as data in core/keys.py; population lanes
    # replace the iid dropout draw, hence av_with_pop=False)
    ks = keys_mod.split_named(key, keys_mod.round_key_names(
        base=("pol", "h", "z"), chaos=cfg.faults.enabled, pop=has_pop,
        wl=has_wl, av_with_pop=False))
    key_pol, key_h, key_z = ks["pol"], ks["h"], ks["z"]
    key_av, key_fd, key_nz = ks.get("av"), ks.get("fd"), ks.get("nz")
    key_pop, key_er = ks.get("pop"), ks.get("er")
    key_fad, key_csi = ks.get("fad"), ks.get("csi")
    # adaptive lanes re-derive the split from their carried controller
    # state; static lanes keep the grid's k_m
    k_m_eff = (jnp.where(adaptive > 0, traced_km(cfg.k, cs["k_m_frac"]),
                         k_m)
               if any_adaptive else k_m)
    # H closed-form local SGD steps on f_n(w) = 0.5 ||w - w*_n||^2:
    #   w_H = w*_n + (1 - eta_l)^H (w - w*_n);  accumulated grad (Eq. 5)
    shrink = (1.0 - (1.0 - cfg.local_lr) ** cfg.local_steps) / cfg.local_lr
    chunk = (cfg.client_chunk if cfg.client_chunk is not None
             else cfg.n_clients)
    n_chunks = cfg.n_clients // chunk

    def superpose(wv):
        """Streaming Σ_n wv_n g_n (DESIGN.md §17): scan over client
        chunks, each materialising only its (chunk, d) closed-form
        gradients and contracting them against its weight slice — the
        per-lane (N, d) matrix is never live.  One chunk of N
        (client_chunk=None) is the historical dense einsum bit-exactly."""
        ws_c = w_stars.reshape((n_chunks, chunk, cfg.d))
        wv_c = wv.reshape((n_chunks, chunk))

        def body(acc, sliced):
            ws_chunk, wv_chunk = sliced
            g = shrink * (w[None, :] - ws_chunk)
            return acc + jnp.einsum("n,nd->d", wv_chunk, g), None

        acc, _ = jax.lax.scan(body, jnp.zeros((cfg.d,), jnp.float32),
                              (ws_c, wv_c))
        return acc
    # selection (Eq. 11) scored on the last reconstructed gradient
    score = jnp.where(policy_id == POLICY_RANDK,
                      jax.random.uniform(key_pol, (cfg.d,)),
                      jnp.abs(g_prev))
    mask = fair_k_mask_dynamic(score, age, cfg.k, k_m_eff)
    # OAC uplink (Eq. 7): fading superposition + channel noise on the
    # selected coordinates only
    if not has_wl:
        h = jax.random.rayleigh(key_h,
                                cfg.fading_mean / np.sqrt(np.pi / 2.0),
                                shape=(cfg.n_clients,), dtype=jnp.float32)
    if has_wl:
        # wireless lane (DESIGN.md §16): advance the lane's carried
        # AR(1) fading chain and run truncated channel inversion — the
        # survivor gate replaces the iid scalar fading draw (survivors
        # arrive coherently inverted up to the CSI misalignment).
        # Availability (population churn or iid dropout) composes
        # BEFORE the outage; a total outage erases the round in the
        # same rank form as the fault path
        chstate, cps = chan_mod.channel_round(chstate, key_fad,
                                              cfg.wireless)
        w_csi = chan_mod.csi_weights(key_csi, cfg.n_clients, cfg.wireless)
        gate = cps["sent"]
        if has_pop:
            pstate, ps = pop_mod.population_round(pstate, key_pop,
                                                  cfg.population)
            gate = ps["part"] * gate
        elif cfg.faults.enabled:
            avail = fault_mod.init_avail_state(key_av, cfg.n_clients,
                                               cfg.faults)
            gate = avail * gate
        n_t = gate.sum()
        agg = fault_mod.participation_scale(superpose(w_csi * gate), n_t)
        if cfg.faults.enabled:
            agg = fault_mod.corrupt(agg, key_nz, cfg.faults)
        erase = jnp.zeros((cfg.d,), jnp.float32)
        if has_pop:
            erase = jnp.maximum(erase, pop_mod.churn_erase_mask(
                key_er, cfg.d, ps["churn"], cfg.population))
        if cfg.faults.enabled:
            erase = jnp.maximum(
                erase, fault_mod.fade_mask(key_fd, cfg.d, cfg.faults))
        erase = fault_mod.erase_with_outage(erase, n_t)
        bad = (erase > 0.0) | jnp.logical_not(jnp.isfinite(agg))
        agg = jnp.where(bad, 0.0, agg)
        mask = mask * (1.0 - bad.astype(jnp.float32))
    elif has_pop:
        # population lane (DESIGN.md §15): the cohort is sampled from the
        # lane's own carried virtual population; the realised
        # participation rescales the superposition and mid-round churn
        # erases symbol blocks — the same "unsent" rank-form semantics as
        # the fault path below (stale value kept, age keeps climbing)
        pstate, ps = pop_mod.population_round(pstate, key_pop,
                                              cfg.population)
        n_t = ps["n_t"]
        agg = fault_mod.participation_scale(superpose(h * ps["part"]), n_t)
        if cfg.faults.enabled:
            agg = fault_mod.corrupt(agg, key_nz, cfg.faults)
        erase = pop_mod.churn_erase_mask(key_er, cfg.d, ps["churn"],
                                         cfg.population)
        if cfg.faults.enabled:
            erase = jnp.maximum(
                erase, fault_mod.fade_mask(key_fd, cfg.d, cfg.faults))
        erase = fault_mod.erase_with_outage(erase, n_t)
        bad = (erase > 0.0) | jnp.logical_not(jnp.isfinite(agg))
        agg = jnp.where(bad, 0.0, agg)
        mask = mask * (1.0 - bad.astype(jnp.float32))
    elif cfg.faults.enabled:
        # churn in rank form: iid dropout thins the superposition (the
        # aggregate rescales by the realised participation, guarded
        # against the all-out round), deep-fade erasures and non-finite
        # corruption knock their coordinates OUT of the selection mask —
        # the same "unsent" semantics the engine's sanitize stage applies
        # (stale value kept, age keeps climbing)
        avail = fault_mod.init_avail_state(key_av, cfg.n_clients,
                                           cfg.faults)
        n_t = avail.sum()
        agg = fault_mod.participation_scale(superpose(h * avail), n_t)
        agg = fault_mod.corrupt(agg, key_nz, cfg.faults)
        erase = fault_mod.erase_with_outage(
            fault_mod.fade_mask(key_fd, cfg.d, cfg.faults), n_t)
        bad = (erase > 0.0) | jnp.logical_not(jnp.isfinite(agg))
        agg = jnp.where(bad, 0.0, agg)
        mask = mask * (1.0 - bad.astype(jnp.float32))
    else:
        agg = superpose(h) / cfg.n_clients
    if cfg.error_feedback:
        # server-side EF (the engine's residual stage in vmapped form):
        # the unsent aggregate mass folds back pre-merge, its noise-free
        # successor is re-accumulated on the unselected coordinates
        agg = agg + res
        res = (1.0 - mask) * agg
    noise = cfg.noise_std / cfg.n_clients * jax.random.normal(
        key_z, (cfg.d,), jnp.float32)
    # Eq. (8) merge + Eq. (9) model step + Eq. (10) AoU
    g_t = mask * (agg + noise) + (1.0 - mask) * g_prev
    w_next = w - cfg.global_lr * g_t
    age_next = jnp.minimum((age + 1.0) * (1.0 - mask), AGE_CAP)
    if cfg.async_lag:
        # async lane: the selected contributions land async_lag rounds
        # late — same shift every engine backend applies under age_lag
        age_next = packing.shift_selected_age(age_next, cfg.async_lag)
    # controller step (adaptive lanes only — gated per field so static
    # lanes carry their state untouched through the scan; no mag_hist:
    # mag_ema tracks the kernel-emitted |score| histogram only)
    if any_adaptive:
        _, age_hist = ref.strided_hists_ref(
            g_t, age_next, jnp.ones((cfg.d,), bool), 1)
        cs_new = ctrl.update(cs, age_hist)
        cs = jax.tree.map(lambda new, old: jnp.where(adaptive > 0, new,
                                                     old), cs_new, cs)
    loss = 0.5 * jnp.mean(jnp.sum((w_next[None, :] - w_stars) ** 2, axis=1))
    metrics = {"loss": loss, "mean_age": age_next.mean(),
               "max_age": age_next.max(), "frac_fresh": mask.mean(),
               "res_norm": jnp.abs(res).mean(),
               "km_frac": k_m_eff.astype(jnp.float32) / cfg.k}
    if has_pop:
        metrics["n_t"] = n_t
        metrics["churn"] = ps["churn"]
    if has_wl:
        metrics["n_sent"] = cps["n_sent"]
    out = ((w_next, g_t, age_next, res, cs, w_stars)
           + ((pstate,) if has_pop else ())
           + ((chstate,) if has_wl else ()))
    return out, metrics


@functools.partial(jax.jit, static_argnames=("cfg", "any_adaptive"))
def _run_grid(cfg: SweepConfig, seeds: Array, policy_ids: Array,
              k_ms: Array, adaptives: Array, any_adaptive: bool = False
              ) -> Dict[str, Array]:
    """All grid points, one compiled program: scan over rounds, vmap over
    the flattened (policy, k_m, seed) grid."""
    # fault channels, population churn and channel-truncation outage all
    # block refreshes independently per round, so their thinning rates add
    thin = min(0.99, (cfg.faults.thin if cfg.faults.enabled else 0.0)
               + (cfg.population.thin if cfg.population is not None
                  else 0.0)
               + (cfg.wireless.thin if cfg.wireless is not None else 0.0))
    ctrl = budget.BudgetController(cfg.controller, rho=cfg.rho,
                                   age_offset=float(cfg.async_lag),
                                   thin=thin)

    def one_sim(seed, policy_id, k_m, adaptive):
        key0 = jax.random.PRNGKey(seed)
        key_shared, key_init, key_run = jax.random.split(key0, 3)
        # client optima = common signal (learnable from w_0 = 0) + non-IID
        # spread (the irreducible heterogeneity floor)
        w_stars = (cfg.shared * jax.random.normal(key_shared, (cfg.d,),
                                                  jnp.float32)[None, :]
                   + cfg.hetero * jax.random.normal(
                       key_init, (cfg.n_clients, cfg.d), jnp.float32))
        carry = (jnp.zeros((cfg.d,), jnp.float32),
                 jnp.zeros((cfg.d,), jnp.float32),
                 jnp.zeros((cfg.d,), jnp.float32),
                 jnp.zeros((cfg.d,), jnp.float32),
                 budget.init_controller_state(
                     k_m.astype(jnp.float32) / cfg.k),
                 w_stars)
        if cfg.population is not None:
            # every lane carries its own virtual population through the
            # scan, seeded from the lane key (vmapped like cs)
            carry = carry + (pop_mod.init_population_state(
                jax.random.fold_in(key0, 0x404), cfg.population),)
        if cfg.wireless is not None:
            # per-lane AR(1) fading chain, stationary cold start (zeros
            # would be a dead channel, not the stationary law)
            carry = carry + (chan_mod.init_channel_state(
                jax.random.fold_in(key0, 0xC4A), cfg.wireless),)

        def round_body(c, key):
            return _one_round(cfg, ctrl, any_adaptive, c, key, policy_id,
                              k_m, adaptive)

        _, metrics = jax.lax.scan(round_body, carry,
                                  jax.random.split(key_run, cfg.rounds))
        return metrics                                    # (rounds,) leaves

    return jax.vmap(one_sim)(seeds, policy_ids, k_ms, adaptives)


def sweep_grid(policies: Sequence[str], k_m_fracs: Sequence[float],
               n_seeds: int, cfg: SweepConfig
               ) -> Tuple[Array, Array, Array, Array, list]:
    """Flatten (policy × k_m_frac × seed) into the vmapped grid arrays.

    ``topk`` / ``roundrobin`` override the k_m axis to k / 0 (Remark 1);
    ``fairk_auto`` lanes raise the adaptive flag (their k_m axis is the
    controller's INITIAL split)."""
    combos = []
    for pol in policies:
        if pol not in SWEEP_POLICIES:
            raise ValueError(f"sweep supports {sorted(SWEEP_POLICIES)}, "
                             f"got {pol!r}")
        # Remark-1 policies pin k_m, collapsing their k_m axis to one point
        if pol == "topk" or pol == "randk":
            fracs = (1.0,)
        elif pol == "roundrobin":
            fracs = (0.0,)
        else:
            fracs = tuple(k_m_fracs)
        for frac in fracs:
            if (pol, frac) not in combos:
                combos.append((pol, frac))
    seeds, pids, kms, adaptives, labels = [], [], [], [], []
    for pol, frac in combos:
        for s in range(n_seeds):
            seeds.append(s)
            pids.append(SWEEP_POLICIES[pol])
            kms.append(int(round(frac * cfg.k)))
            adaptives.append(1 if pol == "fairk_auto" else 0)
            labels.append((pol, frac, s))
    return (jnp.asarray(seeds, jnp.int32), jnp.asarray(pids, jnp.int32),
            jnp.asarray(kms, jnp.int32), jnp.asarray(adaptives, jnp.int32),
            labels)


def run_sweep(cfg: SweepConfig, policies: Sequence[str] = ("fairk",),
              k_m_fracs: Sequence[float] = (0.75,), n_seeds: int = 4
              ) -> Dict[str, np.ndarray]:
    """Execute the grid; returns per-grid-point per-round metric arrays of
    shape (n_grid, rounds) plus the grid labels."""
    seeds, pids, kms, adaptives, labels = sweep_grid(policies, k_m_fracs,
                                                     n_seeds, cfg)
    metrics = _run_grid(cfg, seeds, pids, kms, adaptives,
                        any_adaptive=bool(int(adaptives.sum())))
    out = {name: np.asarray(v) for name, v in metrics.items()}
    out["labels"] = labels
    return out
