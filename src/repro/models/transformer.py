"""Decoder-only / encoder-decoder transformer assembly.

Layers are stacked into homogeneous *scan blocks* (``cfg.scan_block`` layers
per block — 1 for uniform stacks, 8 for jamba's attn:mamba super-block) and
iterated with ``lax.scan`` so HLO size is O(1) in depth.  Caches mirror the
block structure and are scanned alongside the parameters.

Public entry points:
  init_lm / init_caches / cache_specs
  forward_train(params, cfg, tokens, embeds/frames) -> logits
  loss_fn(params, cfg, batch) -> (loss, metrics)
  prefill(params, cfg, tokens, caches, ...) -> (last_logits, caches)
  decode_step(params, cfg, token, pos, caches, ...) -> (logits, caches)
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import mamba2
from repro.models.layers import (apply_rope, dense, dense_init, embed_init,
                                 layernorm, layernorm_init, rmsnorm,
                                 rmsnorm_init)
from repro.models.mlp import init_mlp, mlp
from repro.models.moe import init_moe, moe_ffn

Array = jax.Array
AUX_LOSS_WEIGHT = 0.01


def _norm_init(cfg: ModelConfig, dtype):
    return (layernorm_init(cfg.d_model, dtype) if cfg.norm_type == "layernorm"
            else rmsnorm_init(cfg.d_model, dtype))


def _norm(cfg: ModelConfig, p, x):
    return (layernorm(p, x, cfg.norm_eps) if cfg.norm_type == "layernorm"
            else rmsnorm(p, x, cfg.norm_eps))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key: Array, cfg: ModelConfig, i: int, dtype,
                cross: bool = False) -> Dict:
    ks = jax.random.split(key, 6)
    kind = cfg.layer_kind(i)
    p: Dict[str, Any] = {"norm1": _norm_init(cfg, dtype)}
    if kind == "attn":
        p["mixer"] = attn_lib.init_attention(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            dtype, cfg.qkv_bias)
    else:
        p["mixer"] = mamba2.init_mamba(ks[0], cfg, dtype)
    if cross:
        p["norm_x"] = _norm_init(cfg, dtype)
        p["cross"] = attn_lib.init_attention(
            ks[1], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            dtype)
    p["norm2"] = _norm_init(cfg, dtype)
    if cfg.layer_is_moe(i):
        p["ffn"] = init_moe(ks[2], cfg.d_model, cfg.moe_d_ff, cfg.n_experts,
                            cfg.mlp_type, dtype)
        if cfg.dense_residual:
            p["dense_ffn"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff,
                                      cfg.mlp_type, dtype)
    elif cfg.d_ff:
        p["ffn"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype)
    return p


def _init_block(key: Array, cfg: ModelConfig, block: int, dtype,
                cross: bool = False) -> list:
    ks = jax.random.split(key, cfg.scan_block)
    return [_init_layer(ks[j], cfg, block * cfg.scan_block + j, dtype, cross)
            for j in range(cfg.scan_block)]


def _stack_blocks(key: Array, cfg: ModelConfig, n_blocks: int, dtype,
                  cross: bool = False):
    keys = jax.random.split(key, n_blocks)
    blocks = [_init_block(k, cfg, 0, dtype, cross) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)


def init_lm(key: Array, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "blocks": _stack_blocks(ks[1], cfg, cfg.n_scan_blocks, dtype,
                                cross=cfg.is_encdec),
        "final_norm": _norm_init(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(ks[2], cfg.d_model, cfg.vocab, dtype)
    if cfg.is_encdec:
        enc_cfg = cfg  # same dims for encoder layers
        params["enc_blocks"] = _stack_blocks(ks[3], enc_cfg,
                                             cfg.encoder_layers, dtype)
        params["enc_norm"] = _norm_init(cfg, dtype)
    return params


# ---------------------------------------------------------------------------
# attention mixer wrapper (mode dispatch)
# ---------------------------------------------------------------------------

def _attn_mixer(p: Dict, x: Array, cfg: ModelConfig, *, mode: str,
                cache: Optional[Dict], pos: Array, window: int,
                causal: bool = True) -> Tuple[Array, Optional[Dict]]:
    cdt = jnp.dtype(cfg.compute_dtype)
    b, s, _ = x.shape
    q = dense(p["wq"], x, cdt).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = dense(p["wk"], x, cdt).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = dense(p["wv"], x, cdt).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if mode == "decode":
        q = apply_rope(q, pos[None, None], cfg.rope_theta)
        k = apply_rope(k, pos[None, None], cfg.rope_theta)
        new_cache = attn_lib.cache_write(cache, k, v, pos)
        out = attn_lib.decode_attend(q, new_cache, pos, window=window)
    else:
        positions = pos  # (s,) vector for train/prefill
        q = apply_rope(q, positions[None], cfg.rope_theta)
        k = apply_rope(k, positions[None], cfg.rope_theta)
        if mode == "train" and s <= 8192:
            # plain masked attention differentiates without saving per-chunk
            # softmax state (see attention.plain_attention)
            out = attn_lib.plain_attention(q, k, v, positions, positions,
                                           causal=causal, window=window)
        else:
            out = attn_lib.chunked_attention(
                q, k, v, positions, positions, causal=causal, window=window,
                causal_skip=cfg.causal_skip)
        new_cache = (attn_lib.cache_fill(cache, k, v, positions)
                     if cache is not None else None)
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return dense(p["wo"], out, cdt).astype(x.dtype), new_cache


def _cross_mixer(p: Dict, x: Array, cfg: ModelConfig, *,
                 enc_out: Optional[Array], cross_cache: Optional[Dict]
                 ) -> Tuple[Array, Optional[Dict]]:
    """Cross-attention: kv from encoder output (or its cached projection)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    b, s, _ = x.shape
    q = dense(p["wq"], x, cdt).reshape(b, s, cfg.n_heads, cfg.head_dim)
    if cross_cache is not None and enc_out is None:
        k, v = cross_cache["k"], cross_cache["v"]
        new_cache = cross_cache
    else:
        t = enc_out.shape[1]
        k = dense(p["wk"], enc_out, cdt).reshape(b, t, cfg.n_kv_heads,
                                                 cfg.head_dim)
        v = dense(p["wv"], enc_out, cdt).reshape(b, t, cfg.n_kv_heads,
                                                 cfg.head_dim)
        new_cache = {"k": k, "v": v} if cross_cache is not None else None
    t = k.shape[1]
    qpos = jnp.zeros((s,), jnp.int32)        # no mask: full cross attention
    kpos = jnp.zeros((t,), jnp.int32)
    out = attn_lib.chunked_attention(q, k, v, qpos, kpos, causal=False)
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return dense(p["wo"], out, cdt).astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# one layer / one scan block
# ---------------------------------------------------------------------------

def _apply_layer(p: Dict, x: Array, cfg: ModelConfig, i: int, *, mode: str,
                 cache: Optional[Dict], pos: Array, window: int,
                 enc_out: Optional[Array], causal: bool = True
                 ) -> Tuple[Array, Optional[Dict], Array]:
    kind = cfg.layer_kind(i)
    aux = jnp.zeros((), jnp.float32)
    h = _norm(cfg, p["norm1"], x)
    if kind == "attn":
        attn_cache = cache.get("attn") if cache else None
        mix, new_attn_cache = _attn_mixer(p["mixer"], h, cfg, mode=mode,
                                          cache=attn_cache, pos=pos,
                                          window=window, causal=causal)
        new_cache = dict(cache, attn=new_attn_cache) if cache else None
    else:
        m_cache = cache.get("mamba") if cache else None
        mix, new_m_cache = mamba2.mamba_layer(p["mixer"], h, cfg,
                                              cache=m_cache,
                                              decode=(mode == "decode"))
        new_cache = dict(cache, mamba=new_m_cache) if cache else None
    x = x + mix
    has_cross = "cross" in p and (enc_out is not None
                                  or (cache is not None and "cross" in cache))
    if has_cross:
        hc = _norm(cfg, p["norm_x"], x)
        cross_cache = cache.get("cross") if cache else None
        cx, new_cross = _cross_mixer(p["cross"], hc, cfg, enc_out=enc_out,
                                     cross_cache=cross_cache)
        x = x + cx
        if new_cache is not None:
            new_cache["cross"] = new_cross
    if "ffn" in p:
        h2 = _norm(cfg, p["norm2"], x)
        if cfg.layer_is_moe(i):
            f, aux = moe_ffn(p["ffn"], h2, top_k=cfg.experts_per_token,
                             capacity_factor=cfg.capacity_factor,
                             mlp_type=cfg.mlp_type,
                             compute_dtype=jnp.dtype(cfg.compute_dtype),
                             decode_mode=(mode == "decode"),
                             expert_shard_axis=cfg.expert_shard_axis)
            if cfg.dense_residual:
                f = f + mlp(p["dense_ffn"], h2, cfg.mlp_type,
                            jnp.dtype(cfg.compute_dtype))
        else:
            f = mlp(p["ffn"], h2, cfg.mlp_type, jnp.dtype(cfg.compute_dtype))
        x = x + f
    return x, new_cache, aux


def _apply_block(block_params: list, x: Array, cfg: ModelConfig, *, mode: str,
                 block_cache, pos: Array, window: int, enc_out, causal=True):
    """Apply one scan block (cfg.scan_block layers, unrolled).

    For multi-layer super-blocks (jamba) each layer is additionally
    rematted so the block's backward recompute peaks at ONE layer's
    intermediates instead of all ``scan_block`` of them."""
    new_caches = []
    aux_total = jnp.zeros((), jnp.float32)
    nest_remat = cfg.remat and mode == "train" and cfg.scan_block > 1
    for j in range(cfg.scan_block):
        lc = block_cache[j] if block_cache is not None else None

        def layer_fn(x_, lp_, j=j, lc=lc):
            return _apply_layer(lp_, x_, cfg, j, mode=mode, cache=lc,
                                pos=pos, window=window, enc_out=enc_out,
                                causal=causal)
        if nest_remat:
            layer_fn = jax.checkpoint(layer_fn)
        x, nc, aux = layer_fn(x, block_params[j])
        new_caches.append(nc)
        aux_total = aux_total + aux
    return x, (new_caches if block_cache is not None else None), aux_total


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------

def _run_stack(blocks, x: Array, cfg: ModelConfig, *, mode: str, caches,
               pos: Array, window: int, enc_out, causal: bool = True,
               remat: Optional[bool] = None, residual_fn=None):
    """Scan over the stacked blocks. ``caches`` is None or a pytree with a
    leading n_blocks dim.  Returns (x, new_caches, aux_sum)."""
    use_remat = (cfg.remat if remat is None else remat) and mode == "train"
    has_cache = caches is not None

    def body(carry, scanned):
        x, aux_acc = carry
        bp, bc = scanned if has_cache else (scanned, None)

        def inner(x_, bp_):
            return _apply_block(bp_, x_, cfg, mode=mode, block_cache=bc,
                                pos=pos, window=window, enc_out=enc_out,
                                causal=causal)
        if use_remat:
            inner = jax.checkpoint(inner)
        x, new_bc, aux = inner(x, bp)
        if residual_fn is not None:
            # sequence-parallel residual saves (Megatron SP): the per-layer
            # remat save is sharded over the model axis on the seq dim
            x = residual_fn(x)
        return (x, aux_acc + aux), (new_bc if has_cache else None)

    xs = (blocks, caches) if has_cache else blocks
    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, (new_caches if has_cache else None), aux


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _layer_cache_builder(cfg: ModelConfig, i: int, batch: int, capacity: int,
                         dtype, ring: bool, spec: bool,
                         cross_seq: int = 0) -> Dict:
    make_attn = attn_lib.cache_spec if spec else attn_lib.init_cache
    make_mamba = mamba2.mamba_cache_spec if spec else mamba2.mamba_cache_init
    c: Dict[str, Any] = {}
    if cfg.layer_kind(i) == "attn":
        c["attn"] = make_attn(batch, capacity, cfg.n_kv_heads, cfg.head_dim,
                              dtype, ring)
    else:
        c["mamba"] = make_mamba(batch, cfg, dtype)
    if cfg.is_encdec:
        if spec:
            sds = jax.ShapeDtypeStruct
            c["cross"] = {"k": sds((batch, cross_seq, cfg.n_kv_heads,
                                    cfg.head_dim), dtype),
                          "v": sds((batch, cross_seq, cfg.n_kv_heads,
                                    cfg.head_dim), dtype)}
        else:
            c["cross"] = {"k": jnp.zeros((batch, cross_seq, cfg.n_kv_heads,
                                          cfg.head_dim), dtype),
                          "v": jnp.zeros((batch, cross_seq, cfg.n_kv_heads,
                                          cfg.head_dim), dtype)}
    return c


def _build_caches(cfg: ModelConfig, batch: int, capacity: int, dtype,
                  ring: bool, spec: bool):
    """Stacked caches: per-scan-block list-of-layer-caches, leading n_blocks."""
    per_block = [_layer_cache_builder(cfg, j, batch, capacity, dtype, ring,
                                      spec, cross_seq=cfg.encoder_seq)
                 for j in range(cfg.scan_block)]
    n = cfg.n_scan_blocks
    if spec:
        return jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((n,) + l.shape, l.dtype), per_block)
    return jax.tree.map(lambda l: jnp.broadcast_to(l, (n,) + l.shape).copy(),
                        per_block)


def init_caches(cfg: ModelConfig, batch: int, capacity: int,
                dtype=None, ring: bool = False):
    dtype = jnp.dtype(cfg.compute_dtype) if dtype is None else dtype
    return _build_caches(cfg, batch, capacity, dtype, ring, spec=False)


def cache_specs(cfg: ModelConfig, batch: int, capacity: int,
                dtype=None, ring: bool = False):
    dtype = jnp.dtype(cfg.compute_dtype) if dtype is None else dtype
    return _build_caches(cfg, batch, capacity, dtype, ring, spec=True)


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def _embed(params, cfg: ModelConfig, tokens: Array) -> Array:
    # activations (the residual stream, and hence the per-layer remat saves)
    # live in compute dtype; only params/optimizer state stay higher precision
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.embed_mode == "onehot":
        # §Perf: the gather's backward is a scatter-add that GSPMD
        # replicates (full fp32 (V, D) grads per microbatch); as a one-hot
        # matmul both forward and backward are plain dots that partition
        # cleanly over (V: model, D: data) at +2·S·V·D flops
        oh = jax.nn.one_hot(tokens, params["embed"].shape[0], dtype=cdt)
        return jnp.einsum("bsv,vd->bsd", oh, params["embed"].astype(cdt))
    return params["embed"][tokens].astype(cdt)


def _logits(params, cfg: ModelConfig, x: Array) -> Array:
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x.astype(cdt),
                          params["embed"].astype(cdt))
    return dense(params["head"], x, cdt)


def _encode(params, cfg: ModelConfig, frames: Array) -> Array:
    """Whisper encoder over stub frame embeddings (B, enc_seq, D)."""
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    pos = jnp.arange(x.shape[1])
    x, _, _ = _run_stack(params["enc_blocks"], x, cfg, mode="train",
                         caches=None, pos=pos, window=0, enc_out=None,
                         causal=False)
    return _norm(cfg, params["enc_norm"], x)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def forward_train(params, cfg: ModelConfig, tokens: Array,
                  embeds: Optional[Array] = None,
                  frames: Optional[Array] = None,
                  residual_fn=None) -> Tuple[Array, Array]:
    """Teacher-forced forward. tokens: (B, S_text). ``embeds``: VLM patch
    embeddings (B, P, D) prepended; ``frames``: audio encoder stub input.
    Returns (logits over the text positions, aux_loss)."""
    x = _embed(params, cfg, tokens)
    n_prefix = 0
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
        n_prefix = embeds.shape[1]
    enc_out = _encode(params, cfg, frames) if frames is not None else None
    pos = jnp.arange(x.shape[1])
    x, _, aux = _run_stack(params["blocks"], x, cfg, mode="train",
                           caches=None, pos=pos, window=0, enc_out=enc_out,
                           residual_fn=residual_fn)
    x = _norm(cfg, params["final_norm"], x)
    if n_prefix:
        x = x[:, n_prefix:]
    return _logits(params, cfg, x), aux


def loss_fn(params, cfg: ModelConfig, batch: Dict,
            residual_fn=None) -> Tuple[Array, Dict]:
    logits, aux = forward_train(params, cfg, batch["tokens"],
                                embeds=batch.get("embeds"),
                                frames=batch.get("frames"),
                                residual_fn=residual_fn)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = nll.mean() + AUX_LOSS_WEIGHT * aux
    return loss, {"nll": nll.mean(), "aux": aux}


def prefill(params, cfg: ModelConfig, tokens: Array, caches,
            embeds: Optional[Array] = None,
            frames: Optional[Array] = None, window: int = 0):
    """Run the prompt through the stack, filling caches.
    Returns (last-position logits, caches)."""
    x = _embed(params, cfg, tokens)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    enc_out = _encode(params, cfg, frames) if frames is not None else None
    pos = jnp.arange(x.shape[1])
    x, caches, _ = _run_stack(params["blocks"], x, cfg, mode="prefill",
                              caches=caches, pos=pos, window=window,
                              enc_out=enc_out)
    x = _norm(cfg, params["final_norm"], x[:, -1:])
    return _logits(params, cfg, x), caches


def decode_step(params, cfg: ModelConfig, token: Array, pos: Array, caches,
                window: int = 0):
    """One-token decode. token: (B, 1) int32; pos: scalar global position.
    Returns (logits (B, 1, V), updated caches)."""
    x = _embed(params, cfg, token)
    x, caches, _ = _run_stack(params["blocks"], x, cfg, mode="decode",
                              caches=caches, pos=pos, window=window,
                              enc_out=None)
    x = _norm(cfg, params["final_norm"], x)
    return _logits(params, cfg, x), caches
