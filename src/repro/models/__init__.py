"""Model zoo: transformer stacks (dense / MoE / SSM / hybrid / enc-dec / VLM)
and the paper's small FL vision models."""

from repro.models import attention, cnn, layers, mamba2, mlp, moe, transformer
from repro.models.cnn import (accuracy, init_mlp_classifier, init_prototype_cnn,
                              mlp_classifier, param_count, prototype_cnn,
                              softmax_xent)
from repro.models.transformer import (cache_specs, decode_step, forward_train,
                                      init_caches, init_lm, loss_fn, prefill)

__all__ = [
    "attention", "cnn", "layers", "mamba2", "mlp", "moe", "transformer",
    "accuracy", "init_mlp_classifier", "init_prototype_cnn", "mlp_classifier",
    "param_count", "prototype_cnn", "softmax_xent",
    "cache_specs", "decode_step", "forward_train", "init_caches", "init_lm",
    "loss_fn", "prefill",
]
