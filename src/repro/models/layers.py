"""Shared neural-net building blocks (pure-functional JAX)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def dense_init(key: Array, d_in: int, d_out: int, dtype, scale: float = 1.0,
               bias: bool = False):
    w = (scale / (d_in ** 0.5)) * jax.random.normal(key, (d_in, d_out), jnp.float32)
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x: Array, compute_dtype) -> Array:
    y = jnp.einsum("...i,io->...o", x.astype(compute_dtype),
                   p["w"].astype(compute_dtype))
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


def embed_init(key: Array, vocab: int, d_model: int, dtype):
    return 0.02 * jax.random.normal(key, (vocab, d_model), jnp.float32).astype(dtype)


def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x: Array, eps: float) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x: Array, eps: float) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


# --- rotary position embedding ------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                       # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                          # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin
    y2 = x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)
