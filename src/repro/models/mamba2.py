"""Mamba-2 (SSD — state-space duality) mixer layer [arXiv:2405.21060].

TPU adaptation: the chunked SSD algorithm is the TPU-native form of the
selective scan — within a chunk the recurrence is re-expressed as dense
matmuls (MXU-friendly, quadratic only in the chunk length), and chunks are
linked by a tiny (H, P, N) state carried through ``lax.scan``.  Decode is the
exact O(1) recurrent step on the same state.

Layer layout (faithful to the reference implementation):
  in_proj: D -> [z (d_in), x (d_in), B (G*N), C (G*N), dt (H)]
  causal depthwise conv (kernel 4) over [x, B, C] channels
  SSD core with per-head scalar decay A, skip D, softplus dt (+ bias)
  gated RMSNorm(y * silu(z)) -> out_proj: d_in -> D
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense, dense_init, rmsnorm, rmsnorm_init

Array = jax.Array


def conv_channels(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def init_mamba(key: Array, cfg: ModelConfig, dtype) -> Dict:
    """Split projections (z/x/bc/dt separated) so every activation carries a
    cleanly sharded dim under TP: z/x on d_inner (= heads x head_dim), dt on
    heads, b/c small and replicated.  Total params identical to the fused
    in_proj formulation."""
    ks = jax.random.split(key, 8)
    h = cfg.ssm_heads
    gn = cfg.ssm_groups * cfg.ssm_state
    return {
        "wz": dense_init(ks[0], cfg.d_model, cfg.d_inner, dtype),
        "wx": dense_init(ks[1], cfg.d_model, cfg.d_inner, dtype),
        "wbc": dense_init(ks[2], cfg.d_model, 2 * gn, dtype),
        "wdt": dense_init(ks[3], cfg.d_model, h, dtype),
        "conv_x_w": (0.1 * jax.random.normal(
            ks[4], (cfg.ssm_conv, cfg.d_inner), jnp.float32)).astype(dtype),
        "conv_x_b": jnp.zeros((cfg.d_inner,), dtype),
        "conv_bc_w": (0.1 * jax.random.normal(
            ks[5], (cfg.ssm_conv, 2 * gn), jnp.float32)).astype(dtype),
        "conv_bc_b": jnp.zeros((2 * gn,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": rmsnorm_init(cfg.d_inner, dtype),
        "out_proj": dense_init(ks[6], cfg.d_inner, cfg.d_model, dtype, scale=0.5),
    }


def _expand_groups(t: Array, n_heads: int) -> Array:
    """(..., G, N) -> (..., H, N) by repeating each group."""
    g = t.shape[-2]
    rep = n_heads // g
    return jnp.repeat(t, rep, axis=-2)


def _causal_conv(x: Array, w: Array, b: Array, state: Optional[Array] = None
                 ) -> Tuple[Array, Array]:
    """Depthwise causal conv. x: (B, S, C); w: (K, C). Returns (y, new_state)
    where state is the trailing K-1 inputs (decode carry)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(k))
    y = jax.nn.silu(y + b[None, None, :])
    return y, xp[:, -(k - 1):]


def _segsum(a: Array) -> Array:
    """a: (..., Q, H) -> (..., H, Q, Q) with out[i,j] = sum_{j<k<=i} a_k."""
    q = a.shape[-2]
    cs = jnp.cumsum(a, axis=-2)                                # (..., Q, H)
    cs = jnp.moveaxis(cs, -1, -2)                              # (..., H, Q)
    diff = cs[..., :, None] - cs[..., None, :]                 # (..., H, Q, Q)
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: Array, dt: Array, a: Array, b: Array, c: Array,
                chunk: int, init_state: Optional[Array] = None
                ) -> Tuple[Array, Array]:
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H) (already softplus'ed); a: (H,) negative;
    b, c: (B, S, H, N).  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    s_orig = s
    if s % chunk:
        # pad to a chunk multiple; dt=0 on padding => zero state contribution
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nc = s // chunk

    da = dt * a[None, None, :]                                  # (B, S, H)
    xdt = x * dt[..., None]
    rs = lambda t: t.reshape((bsz, nc, chunk) + t.shape[2:])
    da_c, xdt_c, b_c, c_c = rs(da), rs(xdt), rs(b), rs(c)

    da_cs = jnp.cumsum(da_c, axis=2)                            # (B,C,Q,H)
    # intra-chunk (quadratic in Q, dense matmuls)
    l_mat = jnp.exp(_segsum(da_c))                              # (B,C,H,Q,Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", c_c, b_c)
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", scores * l_mat, xdt_c)

    # per-chunk input state contribution
    decay_end = jnp.exp(da_cs[:, :, -1:, :] - da_cs)            # (B,C,Q,H)
    chunk_states = jnp.einsum("bckhn,bckh,bckhp->bchpn",
                              b_c, decay_end, xdt_c)
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])                   # (B,C,H)

    def scan_body(state, inp):
        st_c, dec_c = inp                                       # (B,H,P,N),(B,H)
        out_state = state                                       # entering state
        new_state = state * dec_c[..., None, None] + st_c
        return new_state, out_state

    init = (jnp.zeros((bsz, h, p, n), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))
    final_state, entering = jax.lax.scan(
        scan_body, init,
        (chunk_states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    entering = entering.swapaxes(0, 1)                          # (B,C,H,P,N)

    # inter-chunk contribution
    in_decay = jnp.exp(da_cs)                                   # (B,C,Q,H)
    y_off = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp", c_c, in_decay, entering)
    y = (y_diag + y_off).reshape(bsz, s, h, p)[:, :s_orig]
    return y, final_state


def ssd_step(state: Array, x: Array, dt: Array, a: Array, b: Array, c: Array
             ) -> Tuple[Array, Array]:
    """Exact recurrent decode step.

    state: (B,H,P,N); x: (B,H,P); dt: (B,H); b,c: (B,H,N)."""
    da = jnp.exp(dt * a[None, :])                               # (B,H)
    state = (state * da[..., None, None]
             + jnp.einsum("bhp,bhn->bhpn", x * dt[..., None], b))
    y = jnp.einsum("bhn,bhpn->bhp", c, state)
    return y, state


def mamba_cache_init(batch: int, cfg: ModelConfig, dtype) -> Dict:
    gn2 = 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                          cfg.ssm_state), dtype),
        "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, gn2), dtype),
    }


def mamba_cache_spec(batch: int, cfg: ModelConfig, dtype) -> Dict:
    sds = jax.ShapeDtypeStruct
    gn2 = 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "ssm": sds((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                   dtype),
        "conv_x": sds((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "conv_bc": sds((batch, cfg.ssm_conv - 1, gn2), dtype),
    }


def mamba_layer(p: Dict, x: Array, cfg: ModelConfig, *,
                cache: Optional[Dict] = None, decode: bool = False
                ) -> Tuple[Array, Optional[Dict]]:
    """Full mixer. x: (B, S, D) -> (B, S, D). decode => S == 1 with cache."""
    cdt = jnp.dtype(cfg.compute_dtype)
    g_, n_ = cfg.ssm_groups, cfg.ssm_state
    z = dense(p["wz"], x, cdt)
    xc = dense(p["wx"], x, cdt)
    bc = dense(p["wbc"], x, cdt)
    dt = dense(p["wdt"], x, cdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])         # (B,S,H)
    a = -jnp.exp(p["a_log"])                                    # (H,)

    conv_x_state = cache["conv_x"] if cache is not None else None
    conv_bc_state = cache["conv_bc"] if cache is not None else None
    xc, new_conv_x = _causal_conv(xc, p["conv_x_w"].astype(cdt),
                                  p["conv_x_b"].astype(cdt), conv_x_state)
    bc, new_conv_bc = _causal_conv(bc, p["conv_bc_w"].astype(cdt),
                                   p["conv_bc_b"].astype(cdt), conv_bc_state)
    xh = xc.reshape(xc.shape[:-1] + (cfg.ssm_heads, cfg.ssm_head_dim))
    b = bc[..., :g_ * n_].reshape(bc.shape[:-1] + (g_, n_))
    c = bc[..., g_ * n_:].reshape(bc.shape[:-1] + (g_, n_))
    b = _expand_groups(b, cfg.ssm_heads)
    c = _expand_groups(c, cfg.ssm_heads)

    if decode:
        y1, new_ssm = ssd_step(cache["ssm"], xh[:, 0], dt[:, 0],
                               a, b[:, 0], c[:, 0])
        y = y1[:, None]
    else:
        init_state = cache["ssm"] if cache is not None else None
        y, new_ssm = ssd_chunked(xh, dt, a, b, c, cfg.ssm_chunk, init_state)

    y = y + p["d_skip"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(x.shape[0], x.shape[1], cfg.d_inner)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = dense(p["out_proj"], y, cdt)
    new_cache = ({"ssm": new_ssm.astype(x.dtype if cache is None else
                                        cache["ssm"].dtype),
                  "conv_x": new_conv_x,
                  "conv_bc": new_conv_bc}
                 if (cache is not None or decode) else None)
    return out.astype(x.dtype), new_cache
