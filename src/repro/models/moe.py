"""Mixture-of-Experts FFN with capacity-based, *grouped* einsum dispatch.

TPU adaptation: instead of data-dependent gather/scatter (GPU-idiomatic),
tokens are routed with dense one-hot dispatch/combine tensors (the
Mesh-TensorFlow / GShard formulation).  Under pjit with experts sharded on
the ``model`` axis and tokens on the ``data`` axis, XLA partitions the two
routing einsums into all-to-alls — the TPU-native expert-parallel pattern.

Tokens are grouped by batch row (GShard "groups"): capacity and the
dispatch tensors are per-row, so their size stays O(S · E · C_row) rather
than O(T_global² ) and the group dim shards cleanly on the data axis.

Router: softmax over experts, top-``k`` per token, re-normalized gates,
per-row capacity ``C = ceil(S * k * capacity_factor / E)``; overflow tokens
are dropped (standard) and the residual path carries them.  A Switch-style
load-balance auxiliary loss is returned.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Array = jax.Array


def init_moe(key: Array, d_model: int, d_ff: int, n_experts: int,
             mlp_type: str, dtype) -> Dict:
    ks = jax.random.split(key, 4)

    def expert_mat(k, d_in, d_out, scale=1.0):
        w = (scale / (d_in ** 0.5)) * jax.random.normal(
            k, (n_experts, d_in, d_out), jnp.float32)
        return w.astype(dtype)

    p = {"router": dense_init(ks[0], d_model, n_experts, jnp.float32),
         "wu": expert_mat(ks[1], d_model, d_ff),
         "wd": expert_mat(ks[2], d_ff, d_model, scale=0.5)}
    if mlp_type == "swiglu":
        p["wg"] = expert_mat(ks[3], d_model, d_ff)
    return p


def capacity_per_row(seq: int, n_experts: int, top_k: int, factor: float) -> int:
    return max(int(seq * top_k * factor / n_experts) + 1, 4)


def moe_ffn(p: Dict, x: Array, *, top_k: int, capacity_factor: float,
            mlp_type: str, compute_dtype,
            decode_mode: bool = False,
            expert_shard_axis: str = "") -> Tuple[Array, Array]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar).

    ``decode_mode`` (§Perf): at single-token decode the per-row dispatch
    would allocate E x C >= E x 4 capacity slots per *sample* while only
    top_k experts per token do useful work.  Merging the batch into one
    routing group and shrinking the capacity floor to 2 cuts the dense
    dispatch/expert compute by ~B x 2 without changing routing semantics
    (collision-drop probability stays negligible at B*K << E*C)."""
    orig_shape = x.shape
    if decode_mode and x.shape[1] == 1 and x.shape[0] > 1:
        x = x.reshape(1, orig_shape[0], orig_shape[2])
    b, s, d = x.shape
    n_experts = p["router"]["w"].shape[1]
    if decode_mode:
        cap = max(2, int(s * top_k * capacity_factor / n_experts) + 1)
    else:
        cap = capacity_per_row(s, n_experts, top_k, capacity_factor)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (B, S, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)         # (B, S, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert's per-row buffer
    onehot = jax.nn.one_hot(expert_idx, n_experts, dtype=jnp.int32)  # (B,S,K,E)
    flat = onehot.reshape(b, s * top_k, n_experts)
    pos = jnp.cumsum(flat, axis=1) - flat
    pos = (pos * flat).sum(-1).reshape(b, s, top_k)
    kept = pos < cap                                             # (B, S, K)

    # dispatch/combine tensors (B, S, E, C); one_hot(cap) rows vanish
    pos_oh = jax.nn.one_hot(jnp.where(kept, pos, cap), cap,
                            dtype=compute_dtype)                 # (B,S,K,C)
    oh = onehot.astype(compute_dtype)
    disp = jnp.einsum("bske,bskc->bsec", oh, pos_oh)
    comb = jnp.einsum("bsk,bske,bskc->bsec",
                      gate_vals.astype(compute_dtype), oh, pos_oh)

    def _pin(t):
        # SS Perf: keep expert tensors expert-sharded through fwd AND bwd —
        # without the pin, GSPMD's backward all-gathers the (B,F,E,C)
        # hidden activations across the expert axis (0.9 GiB x layers on
        # jamba/arctic trains)
        if expert_shard_axis:
            from jax.sharding import PartitionSpec as _P
            spec = _P(*([None] * (t.ndim - 3)), expert_shard_axis, None,
                      None)
            return jax.lax.with_sharding_constraint(t, spec)
        return t

    expert_in = _pin(jnp.einsum("bsec,bsd->becd", disp,
                                x.astype(compute_dtype)))
    if mlp_type == "swiglu":
        gate = jax.nn.silu(jnp.einsum("becd,edf->becf", expert_in,
                                      p["wg"].astype(compute_dtype)))
        up = jnp.einsum("becd,edf->becf", expert_in,
                        p["wu"].astype(compute_dtype))
        hidden = _pin(gate * up)
    else:
        hidden = _pin(jax.nn.gelu(jnp.einsum("becd,edf->becf", expert_in,
                                             p["wu"].astype(compute_dtype))))
    expert_out = _pin(jnp.einsum("becf,efd->becd", hidden,
                                 p["wd"].astype(compute_dtype)))
    out = jnp.einsum("bsec,becd->bsd", comb, expert_out)

    # Switch-transformer load-balance auxiliary loss
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], n_experts, dtype=jnp.float32),
        axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = n_experts * jnp.sum(frac_tokens * frac_probs)
    return out.reshape(orig_shape).astype(x.dtype), aux
