"""Feed-forward blocks: SwiGLU (llama family) and GeLU (whisper / bigcode)."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init

Array = jax.Array


def init_mlp(key: Array, d_model: int, d_ff: int, mlp_type: str, dtype) -> Dict:
    ks = jax.random.split(key, 3)
    if mlp_type == "swiglu":
        return {
            "wg": dense_init(ks[0], d_model, d_ff, dtype),
            "wu": dense_init(ks[1], d_model, d_ff, dtype),
            "wd": dense_init(ks[2], d_ff, d_model, dtype, scale=0.5),
        }
    if mlp_type == "gelu":
        return {
            "wu": dense_init(ks[0], d_model, d_ff, dtype, bias=True),
            "wd": dense_init(ks[1], d_ff, d_model, dtype, scale=0.5, bias=True),
        }
    raise ValueError(f"unknown mlp_type {mlp_type!r}")


def mlp(p: Dict, x: Array, mlp_type: str, compute_dtype) -> Array:
    if mlp_type == "swiglu":
        gate = jax.nn.silu(dense(p["wg"], x, compute_dtype))
        up = dense(p["wu"], x, compute_dtype)
        return dense(p["wd"], gate * up, compute_dtype)
    up = jax.nn.gelu(dense(p["wu"], x, compute_dtype))
    return dense(p["wd"], up, compute_dtype)
