"""Grouped-query attention with chunked (flash-style) softmax, KV caches
(full and sliding-window ring buffer), and cross-attention (enc-dec).

Memory-hierarchy note (TPU adaptation): full-sequence attention at 32k would
materialize S×S score tensors far beyond VMEM; we stream KV in chunks with an
online softmax (the TPU-idiomatic counterpart of flash attention) so the
working set per step is O(chunk²).  With ``causal_skip=True`` strictly-upper
query/key block pairs are not computed at all (triangular block schedule) —
this is a §Perf optimization kept off in the paper-faithful baseline.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense, dense_init

Array = jax.Array
NEG_INF = -1e30


def init_attention(key: Array, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype, qkv_bias: bool = False) -> Dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype, bias=qkv_bias),
        "wk": dense_init(ks[1], d_model, n_kv_heads * head_dim, dtype, bias=qkv_bias),
        "wv": dense_init(ks[2], d_model, n_kv_heads * head_dim, dtype, bias=qkv_bias),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype, scale=0.5),
    }


def _split_heads(x: Array, n: int, hd: int) -> Array:
    return x.reshape(x.shape[:-1] + (n, hd))


def _merge_heads(x: Array) -> Array:
    return x.reshape(x.shape[:-2] + (x.shape[-2] * x.shape[-1],))


# ---------------------------------------------------------------------------
# chunked full-sequence attention (train / prefill)
# ---------------------------------------------------------------------------

def _chunk_attend(q, k, v, qpos, kpos, *, causal: bool, window: int,
                  scale: float):
    """One (q-chunk, kv-chunk) tile with explicit position masking.

    q: (B, Sq, KV, G, hd);  k, v: (B, Sk, KV, hd);  positions: (Sq,), (Sk,).
    Returns un-normalized (out, row_max, row_sum) for online softmax."""
    s = jnp.einsum("bskgd,btkd->bkgst", q, k,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                   # (B,KV,G,Sq)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out, m, l


def plain_attention(q: Array, k: Array, v: Array, qpos: Array, kpos: Array,
                    *, causal: bool = True, window: int = 0) -> Array:
    """Single-tile masked attention.

    Preferred for TRAINING at moderate S: differentiating through the
    chunked online-softmax scan makes jax save every per-chunk probability
    tile (the reason real flash attention ships a custom VJP); one dense
    (B,KV,G,S,T) tensor sharded over heads is cheaper up to S ~ 8k."""
    b, s_len, n_heads, hd = q.shape
    n_kv = k.shape[2]
    g = n_heads // n_kv
    scale = 1.0 / (hd ** 0.5)
    qh = q.reshape(b, s_len, n_kv, g, hd)
    out, _, l = _chunk_attend(qh, k, v, qpos, kpos, causal=causal,
                              window=window, scale=scale)
    out = out / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return out.reshape(b, s_len, n_heads, hd).astype(q.dtype)


def chunked_attention(q: Array, k: Array, v: Array, qpos: Array, kpos: Array,
                      *, causal: bool = True, window: int = 0,
                      q_chunk: int = 1024, kv_chunk: int = 1024,
                      causal_skip: bool = False) -> Array:
    """Flash-style attention. q: (B,S,H,hd); k,v: (B,T,KV,hd) -> (B,S,H,hd)."""
    b, s_len, n_heads, hd = q.shape
    t_len, n_kv = k.shape[1], k.shape[2]
    g = n_heads // n_kv
    scale = 1.0 / (hd ** 0.5)
    q = q.reshape(b, s_len, n_kv, g, hd)

    q_chunk = min(q_chunk, s_len)
    kv_chunk = min(kv_chunk, t_len)
    if s_len % q_chunk or t_len % kv_chunk:
        # ragged sizes (smoke tests): single-tile fallback
        out, m, l = _chunk_attend(q, k, v, qpos, kpos, causal=causal,
                                  window=window, scale=scale)
        out = out / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return out.reshape(b, s_len, n_heads, hd).astype(q.dtype)

    nq, nk = s_len // q_chunk, t_len // kv_chunk
    qs = q.reshape(b, nq, q_chunk, n_kv, g, hd)
    qpos_b = qpos.reshape(nq, q_chunk)
    ks = k.reshape(b, nk, kv_chunk, n_kv, hd)
    vs = v.reshape(b, nk, kv_chunk, n_kv, hd)
    kpos_b = kpos.reshape(nk, kv_chunk)

    def one_q_block(iq: int, n_kv_blocks: int) -> Array:
        qi, qpi = qs[:, iq], qpos_b[iq]
        acc = jnp.zeros((b, q_chunk, n_kv, g, hd), jnp.float32)
        m_run = jnp.full((b, n_kv, g, q_chunk), NEG_INF, jnp.float32)
        l_run = jnp.zeros((b, n_kv, g, q_chunk), jnp.float32)

        def body(carry, inputs):
            acc, m_run, l_run = carry
            kj, vj, kpj = inputs
            out, m, l = _chunk_attend(qi, kj, vj, qpi, kpj, causal=causal,
                                      window=window, scale=scale)
            m_new = jnp.maximum(m_run, m)
            alpha = jnp.exp(m_run - m_new)              # rescale old
            beta = jnp.exp(m - m_new)                   # rescale new
            l_new = l_run * alpha + l * beta
            acc_new = (acc * alpha.transpose(0, 3, 1, 2)[..., None]
                       + out * beta.transpose(0, 3, 1, 2)[..., None])
            return (acc_new, m_new, l_new), None

        (acc, m_run, l_run), _ = jax.lax.scan(
            body, (acc, m_run, l_run),
            (ks[:, :n_kv_blocks].swapaxes(0, 1),
             vs[:, :n_kv_blocks].swapaxes(0, 1),
             kpos_b[:n_kv_blocks]))
        norm = jnp.maximum(l_run, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return (acc / norm).astype(q.dtype)

    if causal_skip and causal and s_len == t_len and not window:
        # triangular schedule: q block iq only visits kv blocks 0..iq
        outs = [one_q_block(iq, iq + 1) for iq in range(nq)]
        out = jnp.stack(outs, axis=1)
    else:
        # scan over q blocks: bounds live tile buffers to O(1) blocks
        def qblock_body(_, iq):
            return None, one_q_block(iq, nk)
        _, out = jax.lax.scan(qblock_body, None, jnp.arange(nq))
        out = out.swapaxes(0, 1)               # (b, nq, q_chunk, kv, g, hd)
    out = out.reshape(b, s_len, n_kv, g, hd)
    return out.reshape(b, s_len, n_heads, hd)


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------

def init_cache(batch: int, capacity: int, n_kv: int, head_dim: int, dtype,
               ring: bool = False) -> Dict:
    """``ring=True`` => sliding-window ring buffer of size ``capacity``."""
    return {
        "k": jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
        "pos": jnp.full((capacity,), -1, jnp.int32),   # global positions held
        "idx": jnp.zeros((), jnp.int32),               # next write offset
        "ring": jnp.asarray(ring),
    }


def cache_spec(batch: int, capacity: int, n_kv: int, head_dim: int, dtype,
               ring: bool = False) -> Dict:
    """ShapeDtypeStruct pytree mirroring ``init_cache`` (dry-run inputs)."""
    sds = jax.ShapeDtypeStruct
    return {
        "k": sds((batch, capacity, n_kv, head_dim), dtype),
        "v": sds((batch, capacity, n_kv, head_dim), dtype),
        "pos": sds((capacity,), jnp.int32),
        "idx": sds((), jnp.int32),
        "ring": sds((), jnp.bool_),
    }


def cache_write(cache: Dict, k_new: Array, v_new: Array, position: Array
                ) -> Dict:
    """Append one decode step (k_new/v_new: (B, 1, KV, hd), roped already)."""
    cap = cache["k"].shape[1]
    slot = jnp.where(cache["ring"], cache["idx"] % cap,
                     jnp.minimum(cache["idx"], cap - 1))
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    pos = jax.lax.dynamic_update_slice(cache["pos"],
                                       position[None].astype(jnp.int32), (slot,))
    return {"k": k, "v": v, "pos": pos, "idx": cache["idx"] + 1,
            "ring": cache["ring"]}


def cache_fill(cache: Dict, k_all: Array, v_all: Array, positions: Array
               ) -> Dict:
    """Prefill: write the whole (possibly truncated) sequence at once."""
    cap = cache["k"].shape[1]
    s = k_all.shape[1]
    if s >= cap:                       # keep the trailing window
        k_keep, v_keep = k_all[:, -cap:], v_all[:, -cap:]
        pos_keep = positions[-cap:]
    else:
        pad = cap - s
        k_keep = jnp.pad(k_all, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_keep = jnp.pad(v_all, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos_keep = jnp.pad(positions.astype(jnp.int32), (0, pad),
                           constant_values=-1)
    return {"k": k_keep, "v": v_keep, "pos": pos_keep.astype(jnp.int32),
            "idx": cache["idx"] + s, "ring": cache["ring"]}


def decode_attend(q: Array, cache: Dict, qpos: Array, *, window: int = 0
                  ) -> Array:
    """Single-token attention against the cache.

    q: (B, 1, H, hd); returns (B, 1, H, hd)."""
    b, _, n_heads, hd = q.shape
    n_kv = cache["k"].shape[2]
    g = n_heads // n_kv
    qh = q.reshape(b, 1, n_kv, g, hd)
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bskgd,btkd->bkgst", qh, cache["k"],
                   preferred_element_type=jnp.float32) * scale
    valid = cache["pos"] >= 0
    valid &= cache["pos"] <= qpos
    if window:
        valid &= cache["pos"] > qpos - window
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p.astype(cache["v"].dtype),
                     cache["v"], preferred_element_type=jnp.float32)
    return out.reshape(b, 1, n_heads, hd).astype(q.dtype)
