"""Small vision models for the FL experiments (paper Sec. V).

``prototype_cnn`` mirrors the paper's hardware-prototype CNN [48]-style
model: three conv layers + one fully-connected layer with ReLU.  With the
EMNIST input (28x28x1, 26 classes) our parameterization lands at d=109,210
parameters vs the paper's d=109,402 (0.2% off — the paper does not publish
exact channel widths).  ``mlp_classifier`` is a cheap stand-in for unit
tests and fast benchmark modes.
"""

from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _conv_init(key, kh, kw, cin, cout):
    scale = 1.0 / (kh * kw * cin) ** 0.5
    return {"w": scale * jax.random.normal(key, (kh, kw, cin, cout), jnp.float32),
            "b": jnp.zeros((cout,), jnp.float32)}


def _conv(p, x):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"][None, None, None, :]


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


def _fc_init(key, d_in, d_out):
    scale = 1.0 / d_in ** 0.5
    return {"w": scale * jax.random.normal(key, (d_in, d_out), jnp.float32),
            "b": jnp.zeros((d_out,), jnp.float32)}


def init_prototype_cnn(key: Array, image_shape=(28, 28, 1), n_classes: int = 26,
                       widths: Sequence[int] = (24, 32, 48), fc_width: int = 192
                       ) -> Dict:
    h, w, c = image_shape
    ks = jax.random.split(key, 5)
    params = {
        "conv1": _conv_init(ks[0], 3, 3, c, widths[0]),
        "conv2": _conv_init(ks[1], 3, 3, widths[0], widths[1]),
        "conv3": _conv_init(ks[2], 3, 3, widths[1], widths[2]),
    }
    feat = (h // 8) * (w // 8) * widths[2]
    params["fc"] = _fc_init(ks[3], feat, fc_width)
    params["head"] = _fc_init(ks[4], fc_width, n_classes)
    return params


def prototype_cnn(params: Dict, x: Array) -> Array:
    """x: (B, H, W, C) -> logits (B, n_classes)."""
    y = _pool(jax.nn.relu(_conv(params["conv1"], x)))
    y = _pool(jax.nn.relu(_conv(params["conv2"], y)))
    y = _pool(jax.nn.relu(_conv(params["conv3"], y)))
    y = y.reshape(y.shape[0], -1)
    y = jax.nn.relu(y @ params["fc"]["w"] + params["fc"]["b"])
    return y @ params["head"]["w"] + params["head"]["b"]


def init_mlp_classifier(key: Array, d_in: int, n_classes: int,
                        hidden: Sequence[int] = (128, 64)) -> Dict:
    dims = [d_in, *hidden, n_classes]
    ks = jax.random.split(key, len(dims) - 1)
    return {f"fc{i}": _fc_init(ks[i], dims[i], dims[i + 1])
            for i in range(len(dims) - 1)}


def mlp_classifier(params: Dict, x: Array) -> Array:
    y = x.reshape(x.shape[0], -1)
    n = len(params)
    for i in range(n):
        y = y @ params[f"fc{i}"]["w"] + params[f"fc{i}"]["b"]
        if i < n - 1:
            y = jax.nn.relu(y)
    return y


def softmax_xent(logits: Array, labels: Array) -> Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def accuracy(logits: Array, labels: Array) -> Array:
    return (logits.argmax(-1) == labels).mean()


def param_count(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))
