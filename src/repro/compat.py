"""JAX version-compat shims (pinned container: jax 0.4.37).

Two API seams moved across JAX releases and both sit on this repo's hot
paths:

* ``shard_map`` lived in ``jax.experimental.shard_map`` (<= 0.4.x, kwarg
  ``check_rep``), then graduated to ``jax.shard_map`` (kwarg ``check_vma``).
  ``shard_map`` below resolves whichever exists and normalises the
  rep/vma-check kwarg, so ``launch.steps`` and the engine's sharded backend
  run unchanged on either side of the move.
* ``Compiled.cost_analysis()`` returned a one-element ``list`` of dicts on
  JAX <= 0.4.x and a plain ``dict`` on newer releases.
  ``cost_analysis_dict`` flattens both to a dict.
"""

from __future__ import annotations

from typing import Any, Callable, Dict

import jax


def shard_map(f: Callable, mesh, in_specs, out_specs, *,
              check: bool = False) -> Callable:
    """Version-portable ``shard_map`` with replication checking disabled by
    default (``check=False`` maps to ``check_rep``/``check_vma`` as the
    installed JAX spells it)."""
    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
        for kwarg in ("check_vma", "check_rep"):
            try:
                return sm(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **{kwarg: check})
            except TypeError:
                continue
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=check)


def cost_analysis_dict(compiled: Any) -> Dict[str, Any]:
    """``compiled.cost_analysis()`` as a dict on every JAX version."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)
