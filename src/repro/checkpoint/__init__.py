from repro.checkpoint.io import (ASYNC_FIELDS, CorruptCheckpointError,
                                 latest_server_step, latest_step,
                                 migrate_server_state, restore,
                                 restore_server_state, save,
                                 save_server_state, server_steps)

__all__ = ["latest_step", "restore", "save", "save_server_state",
           "restore_server_state", "latest_server_step", "server_steps",
           "migrate_server_state", "ASYNC_FIELDS",
           "CorruptCheckpointError"]
