from repro.checkpoint.io import (latest_server_step, latest_step, restore,
                                 restore_server_state, save,
                                 save_server_state)

__all__ = ["latest_step", "restore", "save", "save_server_state",
           "restore_server_state", "latest_server_step"]
