from repro.checkpoint.io import (ASYNC_FIELDS, latest_server_step,
                                 latest_step, migrate_server_state, restore,
                                 restore_server_state, save,
                                 save_server_state)

__all__ = ["latest_step", "restore", "save", "save_server_state",
           "restore_server_state", "latest_server_step",
           "migrate_server_state", "ASYNC_FIELDS"]
