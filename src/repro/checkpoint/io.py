"""Checkpointing: pytrees <-> .npz files with structure-preserving keys.

Arrays are stored flat under path-encoded keys; structure (dict/list/tuple
nesting and scalar leaves) round-trips exactly.  Atomic via tmp+rename.
"""

from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np

_SEP = "/"


def _flatten(tree: Any, prefix: str = ""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}{_SEP}d:{k}")
    elif isinstance(tree, (list, tuple)):
        tag = "l" if isinstance(tree, list) else "t"
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}{_SEP}{tag}:{i}")
    elif tree is None:
        yield prefix + f"{_SEP}none", np.zeros((0,))
    else:
        yield prefix + f"{_SEP}a", np.asarray(tree)


def _insert(root, parts, value):
    key = parts[0]
    kind, _, name = key.partition(":")
    if kind == "a":
        return value
    if kind == "none":
        return None
    if kind == "d":
        node = root if isinstance(root, dict) else {}
        node[name] = _insert(node.get(name), parts[1:], value)
        return node
    if kind in ("l", "t"):
        node = root if isinstance(root, list) else []
        i = int(name)
        while len(node) <= i:
            node.append(None)
        node[i] = _insert(node[i], parts[1:], value)
        return node
    raise ValueError(f"bad checkpoint key part {key!r}")


def _fix_tuples(tree, spec):
    if isinstance(spec, dict):
        return {k: _fix_tuples(tree[k], spec[k]) for k in spec}
    if isinstance(spec, list):
        return [_fix_tuples(t, s) for t, s in zip(tree, spec)]
    if isinstance(spec, tuple):
        return tuple(_fix_tuples(t, s) for t, s in zip(tree, spec))
    return tree


def save(path: str, tree: Any, step: Optional[int] = None) -> str:
    """Save a pytree; if ``step`` given, writes ``<path>/step_<step>.npz``."""
    if step is not None:
        os.makedirs(path, exist_ok=True)
        path = os.path.join(path, f"step_{step:08d}.npz")
    tree = jax.device_get(tree)
    flat = dict(_flatten(tree))
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)) or ".",
                               suffix=".npz")
    os.close(fd)
    try:
        np.savez(tmp, **flat)  # tmp already ends in .npz -> no suffix append
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return path


def restore(path: str, like: Any = None) -> Any:
    """Load a pytree; ``like`` (optional) restores tuple-vs-list distinction."""
    data = np.load(path)
    root: Any = None
    for key in data.files:
        parts = key.split(_SEP)[1:]
        root = _insert(root, parts, data[key])
    if like is not None:
        root = _fix_tuples(root, like)
    return root


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)\.npz", f))]
    return max(steps) if steps else None
