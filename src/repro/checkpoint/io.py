"""Checkpointing: pytrees <-> .npz files with structure-preserving keys.

Arrays are stored flat under path-encoded keys; structure (dict/list/tuple
nesting and scalar leaves) round-trips exactly.  Atomic via tmp+rename.

``save_server_state`` / ``restore_server_state`` additionally checkpoint
the PERSISTED packed server buffers of the big-model trainer
(launch.steps: flat bf16 ``g`` / int8 ``age`` / f32 ``res`` + the
replicated ``theta`` vector) together with the ``PackedLayout`` block
table, so a restart resumes the server phase bit-exactly — bf16 has no
native numpy dtype, so those buffers ride as uint16 raw views with a
dtype tag in the JSON metadata record.
"""

from __future__ import annotations

import json
import os
import re
import tempfile
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing

_SEP = "/"


def _flatten(tree: Any, prefix: str = ""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}{_SEP}d:{k}")
    elif isinstance(tree, (list, tuple)):
        tag = "l" if isinstance(tree, list) else "t"
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}{_SEP}{tag}:{i}")
    elif tree is None:
        yield prefix + f"{_SEP}none", np.zeros((0,))
    else:
        yield prefix + f"{_SEP}a", np.asarray(tree)


def _insert(root, parts, value):
    key = parts[0]
    kind, _, name = key.partition(":")
    if kind == "a":
        return value
    if kind == "none":
        return None
    if kind == "d":
        node = root if isinstance(root, dict) else {}
        node[name] = _insert(node.get(name), parts[1:], value)
        return node
    if kind in ("l", "t"):
        node = root if isinstance(root, list) else []
        i = int(name)
        while len(node) <= i:
            node.append(None)
        node[i] = _insert(node[i], parts[1:], value)
        return node
    raise ValueError(f"bad checkpoint key part {key!r}")


def _fix_tuples(tree, spec):
    if isinstance(spec, dict):
        return {k: _fix_tuples(tree[k], spec[k]) for k in spec}
    if isinstance(spec, list):
        return [_fix_tuples(t, s) for t, s in zip(tree, spec)]
    if isinstance(spec, tuple):
        return tuple(_fix_tuples(t, s) for t, s in zip(tree, spec))
    return tree


def save(path: str, tree: Any, step: Optional[int] = None) -> str:
    """Save a pytree; if ``step`` given, writes ``<path>/step_<step>.npz``."""
    if step is not None:
        os.makedirs(path, exist_ok=True)
        path = os.path.join(path, f"step_{step:08d}.npz")
    tree = jax.device_get(tree)
    flat = dict(_flatten(tree))
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)) or ".",
                               suffix=".npz")
    os.close(fd)
    try:
        np.savez(tmp, **flat)  # tmp already ends in .npz -> no suffix append
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return path


def restore(path: str, like: Any = None) -> Any:
    """Load a pytree; ``like`` (optional) restores tuple-vs-list distinction."""
    data = np.load(path)
    root: Any = None
    for key in data.files:
        parts = key.split(_SEP)[1:]
        root = _insert(root, parts, data[key])
    if like is not None:
        root = _fix_tuples(root, like)
    return root


# ---------------------------------------------------------------------------
# packed server-state checkpoints (flat buffers + layout metadata)
# ---------------------------------------------------------------------------

_BF16 = "bfloat16"


class CorruptCheckpointError(ValueError):
    """A checkpoint's stored content checksum does not match its bytes
    (bit rot, a torn write that survived the rename, a truncated copy).
    Distinct from the layout/field mismatches that raise plain
    ``ValueError``: corruption is recoverable by falling back to the
    previous checkpoint, a config mismatch is not."""


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def save_server_state(path: str, server: Dict[str, Any],
                      layout: Optional["packing.PackedLayout"] = None,
                      step: Optional[int] = None) -> str:
    """Save a flat packed server-state dict (launch.steps flavour).

    ``server`` maps names to flat arrays (any mix of bf16/int8/f32 —
    bf16 is stored as a uint16 raw view and restored bit-exactly);
    ``layout`` (optional) records the ``PackedLayout`` block table so the
    restoring process can verify its freshly built layout addresses the
    same buffer geometry (``packing.layout_matches``).  If ``step`` is
    given, writes ``<path>/server_<step>.npz``.  Atomic via tmp+rename."""
    if step is not None:
        os.makedirs(path, exist_ok=True)
        path = os.path.join(path, f"server_{step:08d}.npz")
    arrays, dtypes, checksums = {}, {}, {}
    for name, val in server.items():
        arr = np.asarray(jax.device_get(val))
        if arr.dtype == jnp.bfloat16:
            dtypes[name] = _BF16
            arr = arr.view(np.uint16)
        else:
            dtypes[name] = str(arr.dtype)
        arrays[name] = arr
        # content checksum over the stored byte view (post bf16->uint16):
        # restore verifies the exact bytes it will hand back
        checksums[name] = _crc(arr)
    meta = {"dtypes": dtypes, "checksums": checksums,
            "layout": (packing.layout_to_meta(layout)
                       if layout is not None else None)}
    fd, tmp = tempfile.mkstemp(
        dir=os.path.dirname(os.path.abspath(path)) or ".", suffix=".npz")
    os.close(fd)
    try:
        np.savez(tmp, __server_meta__=np.asarray(json.dumps(meta)),
                 **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return path


def restore_server_state(path: str,
                         layout: Optional["packing.PackedLayout"] = None
                         ) -> Tuple[Dict[str, np.ndarray],
                                    Optional[Dict[str, Any]]]:
    """Load a ``save_server_state`` checkpoint: (server dict, layout meta).

    Dtypes (incl. bf16) restore bit-exactly.  Content checksums recorded
    at save time are verified against the loaded bytes —
    ``CorruptCheckpointError`` on any mismatch (callers fall back to the
    previous checkpoint; silently resuming from rotted buffers would
    poison the whole continued trajectory).  Pre-checksum checkpoints
    (no ``checksums`` record) load without verification.  If ``layout``
    is given, the saved block table must match it (``ValueError``
    otherwise — restoring flat buffers onto a different leaf layout
    would silently scramble every parameter)."""
    data = np.load(path)
    meta = json.loads(str(data["__server_meta__"][()]))
    crcs = meta.get("checksums")
    server = {}
    for name in data.files:
        if name == "__server_meta__":
            continue
        arr = data[name]
        if crcs is not None:
            if name not in crcs:
                raise CorruptCheckpointError(
                    f"{path}: array {name!r} has no recorded checksum")
            got = _crc(arr)
            if got != crcs[name]:
                raise CorruptCheckpointError(
                    f"{path}: array {name!r} fails its content checksum "
                    f"(stored {crcs[name]:#010x}, loaded {got:#010x}) — "
                    "checkpoint is corrupt")
        tag = meta["dtypes"][name]
        server[name] = (arr.view(jnp.bfloat16) if tag == _BF16
                        else arr.astype(np.dtype(tag), copy=False))
    lay_meta = meta.get("layout")
    if layout is not None:
        if lay_meta is None:
            raise ValueError(f"{path} was saved without layout metadata — "
                             "cannot verify buffer geometry")
        if not packing.layout_matches(layout, lay_meta):
            raise ValueError(f"{path} holds buffers for a different "
                             "PackedLayout (leaf shapes/offsets differ); "
                             "refusing to restore onto this model")
    return server, lay_meta


# fields a pre-async checkpoint may legitimately lack: the async
# double-buffer lane (launch.steps ``async_agg``) starts COLD anyway —
# zeros are its round-0 contents — so migrating a synchronous checkpoint
# into an async run is exact, not an approximation
ASYNC_FIELDS = ("shadow", "pending")

# the wireless fading chain (launch.steps ``wireless``) is also
# synthesizable, but VALUE-BEARING: its cold start is the deterministic
# stationary draw from the fixed channel.FADING_INIT_KEY (a pure function
# of the buffer size), NOT zeros — zeros would be a dead channel, every
# block in permanent outage.  Migrating a pre-channel checkpoint into a
# wireless run therefore reproduces exactly the state a cold start
# carries.
CHANNEL_FIELDS = ("fad",)


def migrate_server_state(server: Dict[str, np.ndarray],
                         like: Dict[str, Any]) -> Dict[str, np.ndarray]:
    """Reconcile a restored server dict with the configured field set.

    * checkpoint misses only ``ASYNC_FIELDS`` members → migrate: synthesize
      cold (zero) double-buffer lanes shaped/typed like the configured
      state.  A synchronous checkpoint resumed under ``--async-agg`` then
      continues exactly (the async buffers start at zero by definition).
    * checkpoint misses only ``CHANNEL_FIELDS`` members → migrate:
      re-synthesize the deterministic stationary fading draw
      (``channel.init_block_fading``) shaped like the configured state —
      a pre-channel checkpoint resumed under ``--channel`` continues
      exactly as a cold wireless start would.
    * any other mismatch — missing non-synthesizable fields (different
      --ef/--one-bit/--adaptive-km flags) or extra checkpoint fields the
      config does not expect (async checkpoint resumed without
      --async-agg, where silently dropping the pending merge would lose
      one round of gradient) → ``ValueError`` naming the offending fields
      and the flags to fix."""
    missing = sorted(set(like) - set(server))
    extra = sorted(set(server) - set(like))
    migratable = [f for f in missing
                  if f in ASYNC_FIELDS or f in CHANNEL_FIELDS]
    hard_missing = [f for f in missing
                    if f not in ASYNC_FIELDS and f not in CHANNEL_FIELDS]
    if hard_missing or extra:
        raise ValueError(
            f"checkpoint fields {sorted(server)} do not match the "
            f"configured server state {sorted(like)} "
            f"(missing: {hard_missing or 'none'}, "
            f"unexpected: {extra or 'none'}) — resume with the same "
            "--ef/--one-bit/--adaptive-km/--async-agg/--channel flags "
            f"(only the async fields {list(ASYNC_FIELDS)} and the fading "
            f"chain {list(CHANNEL_FIELDS)} can be synthesized, and only "
            "in the off -> on direction)")
    out = dict(server)
    for name in migratable:
        ref = like[name]
        if name in CHANNEL_FIELDS:
            from repro.core import channel as chan_mod
            out[name] = np.asarray(
                chan_mod.init_block_fading(int(ref.shape[0]) // 2))
        else:
            out[name] = np.zeros(ref.shape, jnp.bfloat16
                                 if ref.dtype == jnp.bfloat16
                                 else ref.dtype)
    return out


def server_steps(ckpt_dir: str) -> List[int]:
    """Every server checkpoint step under ``ckpt_dir``, newest first —
    the resume fallback order (try the latest, walk back on
    ``CorruptCheckpointError``)."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"server_(\d+)\.npz", f))]
    return sorted(steps, reverse=True)


def latest_server_step(ckpt_dir: str) -> Optional[int]:
    steps = server_steps(ckpt_dir)
    return steps[0] if steps else None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)\.npz", f))]
    return max(steps) if steps else None
