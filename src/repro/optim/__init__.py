"""Minimal optimizer library (optax is not available offline).

API mirrors optax: an optimizer is a pair ``(init_fn, update_fn)`` where
``update_fn(grads, state, params) -> (updates, state)`` and updates are
*added* to params (sign convention: updates already contain the minus)."""

from repro.optim.optimizers import (Optimizer, adamw, apply_updates, sgd,
                                    make_optimizer)
from repro.optim.schedule import constant, cosine_decay, linear_warmup_cosine

__all__ = ["Optimizer", "adamw", "apply_updates", "sgd", "make_optimizer",
           "constant", "cosine_decay", "linear_warmup_cosine"]
