"""SGD(+momentum) and AdamW in plain JAX, pytree-native."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any
Grads = Any
State = Any
Schedule = Callable[[jax.Array], jax.Array]


class Optimizer(NamedTuple):
    init: Callable[[Params], State]
    update: Callable[[Grads, State, Params], Tuple[Params, State]]


def _to_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


def sgd(lr, momentum: float = 0.0, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    lr_fn = _to_schedule(lr)

    def init(params):
        mu = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return {"step": jnp.zeros((), jnp.int32), "mu": mu}

    def update(grads, state, params):
        step = state["step"]
        lr_t = lr_fn(step)
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
            eff = (jax.tree.map(lambda m, g: momentum * m + g, mu, grads)
                   if nesterov else mu)
        else:
            mu, eff = None, grads
        updates = jax.tree.map(lambda g: -lr_t * g, eff)
        return updates, {"step": step + 1, "mu": mu}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    lr_fn = _to_schedule(lr)

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            mhat = m_ / bc1
            vhat = v_ / bc2
            u = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype)

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def apply_updates(params: Params, updates: Params) -> Params:
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def make_optimizer(name: str, lr, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(lr, **kw)
    if name == "sgdm":
        kw.setdefault("momentum", 0.9)
        return sgd(lr, **kw)
    if name == "adamw":
        return adamw(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
