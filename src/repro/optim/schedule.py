"""Learning-rate schedules."""

from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def cosine_decay(base: float, total_steps: int, floor: float = 0.0):
    def fn(step):
        frac = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        return floor + 0.5 * (base - floor) * (1 + jnp.cos(jnp.pi * frac))
    return fn


def linear_warmup_cosine(base: float, warmup: int, total_steps: int,
                         floor: float = 0.0):
    cos = cosine_decay(base, max(total_steps - warmup, 1), floor)
    def fn(step):
        step_f = step.astype(jnp.float32)
        warm = base * step_f / max(warmup, 1)
        return jnp.where(step_f < warmup, warm, cos(step_f - warmup))
    return fn
