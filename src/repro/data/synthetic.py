"""Synthetic stand-ins for the paper's datasets (offline container).

The container cannot download CIFAR-10/100 or EMNIST, so we generate
synthetic image-classification datasets with the same shapes and class
cardinalities: each class has a Gaussian prototype image and samples are
prototype + noise (+ a small shared nuisance subspace so the task is not
trivially linearly separable).  The paper's claims are *relative* orderings
of selection policies, which survive the substitution; absolute accuracies
are reported as synthetic.  See DESIGN.md §7 (data gate).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    image_shape: Tuple[int, int, int]   # (H, W, C)
    n_classes: int
    n_train: int
    n_test: int
    noise_std: float = 1.0
    prototype_scale: float = 1.0
    sparsity: float = 0.0               # >0: class signal concentrated on this
                                        # fraction of pixels (heavy-tailed
                                        # gradients, like real convnet tasks)


CIFAR10_LIKE = DatasetSpec("cifar10-like", (32, 32, 3), 10, 50_000, 10_000)
CIFAR100_LIKE = DatasetSpec("cifar100-like", (32, 32, 3), 100, 50_000, 10_000)
EMNIST_LIKE = DatasetSpec("emnist-letters-like", (28, 28, 1), 26, 124_800, 20_800)


def _make_split(rng: np.random.Generator, spec: DatasetSpec, protos: np.ndarray,
                nuisance: np.ndarray, n: int) -> Tuple[np.ndarray, np.ndarray]:
    labels = rng.integers(0, spec.n_classes, size=n)
    dim = int(np.prod(spec.image_shape))
    x = protos[labels] * spec.prototype_scale
    x = x + spec.noise_std * rng.normal(size=(n, dim)).astype(np.float32)
    # shared nuisance directions (class-independent structure)
    coef = rng.normal(size=(n, nuisance.shape[0])).astype(np.float32)
    x = x + coef @ nuisance
    return x.reshape((n,) + spec.image_shape).astype(np.float32), labels.astype(np.int32)


def make_dataset(spec: DatasetSpec, seed: int = 0, n_train: int | None = None,
                 n_test: int | None = None):
    """Returns ((x_train, y_train), (x_test, y_test)) as numpy arrays."""
    rng = np.random.default_rng(seed)
    dim = int(np.prod(spec.image_shape))
    protos = rng.normal(size=(spec.n_classes, dim)).astype(np.float32)
    if spec.sparsity > 0.0:
        keep = max(1, int(spec.sparsity * dim))
        for c in range(spec.n_classes):
            off = rng.permutation(dim)[keep:]
            protos[c, off] = 0.0
    protos /= np.linalg.norm(protos, axis=1, keepdims=True) / np.sqrt(dim) * 4.0
    nuisance = 0.3 * rng.normal(size=(8, dim)).astype(np.float32)
    train = _make_split(rng, spec, protos, nuisance, n_train or spec.n_train)
    test = _make_split(rng, spec, protos, nuisance, n_test or spec.n_test)
    return train, test
