"""Client data partitioning for federated learning.

Implements the symmetric Dirichlet partitioning of Hsu et al. [46] used by
the paper (Sec. V-A): per client, a Dirichlet(Dir)-distributed class mixture
controls heterogeneity (smaller Dir => stronger non-i.i.d.), and client
dataset sizes are also heterogeneous.  An ``iid`` mode shards uniformly.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0, min_size: int = 8,
                        max_retries: int = 100) -> List[np.ndarray]:
    """Partition sample indices across clients with Dirichlet class mixtures.

    Returns a list of index arrays, one per client (sizes vary).  Redraws
    until every client holds at least ``min_size`` samples; an infeasible
    request (``n_clients * min_size > n_samples``) or a pathological draw
    streak (small alpha concentrates whole classes on single clients)
    raises instead of spinning forever."""
    n_samples = len(labels)
    if n_clients * min_size > n_samples:
        raise ValueError(
            f"infeasible partition: {n_clients} clients x min_size="
            f"{min_size} needs {n_clients * min_size} samples, got "
            f"{n_samples}")
    n_classes = int(labels.max()) + 1
    for attempt in range(max_retries):
        rng = np.random.default_rng(seed + attempt)
        idx_per_client: List[List[int]] = [[] for _ in range(n_clients)]
        for c in range(n_classes):
            idx_c = np.flatnonzero(labels == c)
            rng.shuffle(idx_c)
            # proportions of class c going to each client
            props = rng.dirichlet(np.full(n_clients, alpha))
            cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
            for client, part in enumerate(np.split(idx_c, cuts)):
                idx_per_client[client].extend(part.tolist())
        sizes = [len(ix) for ix in idx_per_client]
        if min(sizes) >= min_size:
            break
    else:
        raise ValueError(
            f"no Dirichlet draw in {max_retries} attempts gave every "
            f"client >= {min_size} samples (smallest shard seen: "
            f"{min(sizes)} of {n_samples} over {n_clients} clients, "
            f"alpha={alpha}) — lower min_size or raise alpha")
    return [np.asarray(sorted(ix), dtype=np.int64) for ix in idx_per_client]


def iid_partition(n_samples: int, n_clients: int, seed: int = 0
                  ) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_samples)
    return [np.sort(p) for p in np.array_split(perm, n_clients)]


def client_batches(x: np.ndarray, y: np.ndarray, parts: List[np.ndarray],
                   batch_size: int, steps: int, seed: int = 0
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Pre-draw local mini-batches for every client: (N, steps, B, ...).

    Clients sample with replacement from their own shard (paper: random local
    mini-batches theta_n^{(s)}).  Returning stacked arrays lets the FL trainer
    vmap the entire client population.
    """
    rng = np.random.default_rng(seed)
    n = len(parts)
    xs = np.empty((n, steps, batch_size) + x.shape[1:], x.dtype)
    ys = np.empty((n, steps, batch_size), y.dtype)
    for ci, part in enumerate(parts):
        draw = rng.choice(part, size=(steps, batch_size), replace=True)
        xs[ci] = x[draw]
        ys[ci] = y[draw]
    return xs, ys
