"""Synthetic token streams for the LM architectures (offline container).

A fixed-transition Markov text source gives learnable (non-uniform-entropy)
sequences for the assigned-architecture training examples/smoke tests.
"""

from __future__ import annotations

import numpy as np


def markov_token_batch(rng: np.random.Generator, batch: int, seq_len: int,
                       vocab: int, order_states: int = 64) -> np.ndarray:
    """(batch, seq_len) int32 tokens from a random sparse Markov source."""
    states = min(order_states, vocab)
    # each state strongly prefers a handful of successor tokens
    prefs = rng.integers(0, vocab, size=(states, 4))
    toks = np.empty((batch, seq_len), np.int32)
    s = rng.integers(0, states, size=batch)
    for t in range(seq_len):
        explore = rng.random(batch) < 0.15
        pick = prefs[s, rng.integers(0, prefs.shape[1], size=batch)]
        rand = rng.integers(0, vocab, size=batch)
        toks[:, t] = np.where(explore, rand, pick)
        s = toks[:, t] % states
    return toks


def lm_batch(seed: int, batch: int, seq_len: int, vocab: int):
    """Returns (tokens, labels) where labels are next-token targets."""
    rng = np.random.default_rng(seed)
    toks = markov_token_batch(rng, batch, seq_len + 1, vocab)
    return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)
