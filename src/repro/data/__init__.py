"""Data pipeline: synthetic datasets, federated partitioning, token streams."""

from repro.data import partition, synthetic, tokens
from repro.data.partition import client_batches, dirichlet_partition, iid_partition
from repro.data.synthetic import (CIFAR10_LIKE, CIFAR100_LIKE, EMNIST_LIKE,
                                  DatasetSpec, make_dataset)
from repro.data.tokens import lm_batch, markov_token_batch

__all__ = [
    "partition", "synthetic", "tokens",
    "client_batches", "dirichlet_partition", "iid_partition",
    "CIFAR10_LIKE", "CIFAR100_LIKE", "EMNIST_LIKE", "DatasetSpec",
    "make_dataset", "lm_batch", "markov_token_batch",
]
