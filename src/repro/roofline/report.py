"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the
dry-run artifacts.

  PYTHONPATH=src python -m repro.roofline.report [--dir benchmarks/artifacts/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from collections import defaultdict

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def load(art_dir: str):
    arts = []
    for p in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(p) as f:
            arts.append(json.load(f))
    return arts


def fmt_bytes(b: float) -> str:
    return f"{b/2**30:.1f}"


def dryrun_table(arts) -> str:
    """§Dry-run: compile + memory + collective schedule, both meshes."""
    rows = ["| arch | shape | mesh | compile s | HBM GiB/dev | colls "
            "(AG/AR/RS/A2A/CP per step) | coll GiB/dev |",
            "|---|---|---|---|---|---|---|"]
    for a in arts:
        if a.get("fl_mode"):
            continue
        p = a["parsed"]
        cc = p.get("collective_counts", {})
        counts = "/".join(str(int(cc.get(k, 0))) for k in
                          ("all-gather", "all-reduce", "reduce-scatter",
                           "all-to-all", "collective-permute"))
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {a['compile_s']:.1f} "
            f"| {fmt_bytes(a['memory']['per_device_total'])} "
            f"| {counts} "
            f"| {p['collective_bytes_per_device']/2**30:.2f} |")
    return "\n".join(rows)


def roofline_table(arts, mesh: str = "16x16") -> str:
    """§Roofline: the three terms + dominant + usefulness, single pod."""
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | MODEL_GFLOPs | useful | next lever |",
            "|---|---|---|---|---|---|---|---|---|"]
    for a in arts:
        if a.get("fl_mode") or a["mesh"] != mesh:
            continue
        r = a["roofline"]
        rows.append(
            f"| {a['arch']} | {a['shape']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | **{r['dominant']}** "
            f"| {r['model_flops']/1e9:.0f} "
            f"| {min(r['usefulness'], 99.0):.2f} "
            f"| {a['suggestion'].split(':')[0]} |")
    return "\n".join(rows)


def fl_table(arts) -> str:
    rows = ["| mode | collective GiB/dev | collective s | memory s |",
            "|---|---|---|---|"]
    for a in arts:
        if not a.get("fl_mode"):
            continue
        r, p = a["roofline"], a["parsed"]
        name = ("baseline (full all-reduce)" if a.get("fl_baseline")
                else "FAIR-k (rho=0.1 blocks)")
        rows.append(f"| {name} | {p['collective_bytes_per_device']/2**30:.3f} "
                    f"| {r['collective_s']:.4f} | {r['memory_s']:.4f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    default_dir = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                               "benchmarks", "artifacts", "dryrun")
    ap.add_argument("--dir", default=os.path.abspath(default_dir))
    args = ap.parse_args()
    arts = load(args.dir)
    arts.sort(key=lambda a: (a["arch"], SHAPE_ORDER.index(a["shape"])
                             if a["shape"] in SHAPE_ORDER else 9, a["mesh"]))
    print("## Dry-run\n")
    print(dryrun_table(arts))
    print("\n## Roofline (single pod, 16x16 = 256 chips)\n")
    print(roofline_table(arts))
    print("\n## FL-OAC (paper technique at scale, mamba2-370m, 256 clients)\n")
    print(fl_table(arts))


if __name__ == "__main__":
    main()
