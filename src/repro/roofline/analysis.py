"""Three-term roofline model for TPU v5e (target hardware).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

(The parser works on the post-SPMD per-device program, so "/ chips" is
already applied.)  MODEL_FLOPS is the analytic useful work: 6*N_active*D for
training, 2*N_active*D for prefill, 2*N_active*B for one decode step; the
usefulness ratio MODEL_FLOPS / (HLO_FLOPs * chips) flags remat/redundancy
waste (remat legitimately pushes it below 1; values near 1/3 indicate a
full-recompute policy, ~0.7-0.75 a residual-only policy)."""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.configs.base import InputShape, ModelConfig

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_global: float
    usefulness: float
    dominant: str
    step_time_s: float

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """Analytic useful FLOPs per step (global, all chips)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def build_report(cfg: ModelConfig, shape: InputShape, mesh_name: str,
                 chips: int, parsed: Dict[str, float]) -> RooflineReport:
    compute_s = parsed["flops_per_device"] / PEAK_FLOPS
    memory_s = parsed["bytes_per_device"] / HBM_BW
    collective_s = parsed["collective_bytes_per_device"] / ICI_BW
    mf = model_flops(cfg, shape)
    hlo_global = parsed["flops_per_device"] * chips
    useful = mf / hlo_global if hlo_global > 0 else float("nan")
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    return RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_flops=mf, hlo_flops_global=hlo_global, usefulness=useful,
        dominant=dominant, step_time_s=max(terms.values()),
    )


def suggestion(report: RooflineReport) -> str:
    if report.dominant == "compute":
        if report.usefulness < 0.5:
            return ("compute-bound with low usefulness: reduce remat "
                    "recompute (save residuals) or cut redundant/causal "
                    "over-compute")
        return ("compute-bound near peak usefulness: only larger meshes or "
                "lower-precision matmuls move this")
    if report.dominant == "memory":
        return ("memory-bound: raise arithmetic intensity — larger "
                "microbatch, fused elementwise chains, weight-stationary "
                "layouts, or quantized (bf16/int8) state")
    return ("collective-bound: reshard to cut cross-device volume — "
            "bigger per-shard blocks, overlap collectives with compute, or "
            "compress the synchronized payload (FAIR-k rho)")
