"""Roofline analysis from compiled dry-run artifacts (DESIGN.md §6).

``xla_cost_analysis`` is the version-portable way to read XLA's own cost
model (``Compiled.cost_analysis()`` returns a list on JAX <= 0.4.x and a
dict on newer releases)."""

from repro.compat import cost_analysis_dict as xla_cost_analysis
from repro.roofline import analysis, hlo
from repro.roofline.analysis import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                     RooflineReport, build_report,
                                     model_flops, suggestion)
from repro.roofline.hlo import analyze_hlo, parse_computations

__all__ = ["analysis", "hlo", "HBM_BW", "ICI_BW", "PEAK_FLOPS",
           "RooflineReport", "build_report", "model_flops", "suggestion",
           "analyze_hlo", "parse_computations", "xla_cost_analysis"]
