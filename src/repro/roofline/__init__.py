"""Roofline analysis from compiled dry-run artifacts (DESIGN.md §6)."""

from repro.roofline import analysis, hlo
from repro.roofline.analysis import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                     RooflineReport, build_report,
                                     model_flops, suggestion)
from repro.roofline.hlo import analyze_hlo, parse_computations

__all__ = ["analysis", "hlo", "HBM_BW", "ICI_BW", "PEAK_FLOPS",
           "RooflineReport", "build_report", "model_flops", "suggestion",
           "analyze_hlo", "parse_computations"]
