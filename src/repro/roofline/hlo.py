"""Loop-aware post-SPMD HLO parser.

``compiled.cost_analysis()`` visits every computation once — ``while`` loop
bodies (our microbatch / layer scans) are not multiplied by trip count, so
its FLOP/byte numbers understate deep-stacked models by ~n_layers x.  XLA
embeds ``backend_config={"known_trip_count":{"n":...}}`` on every while it
can bound (all of ours: scans have static lengths), so we parse
``compiled.as_text()``, build the computation call graph with per-edge
multipliers, and accumulate:

* dot FLOPs (2 * prod(result) * prod(lhs contracting dims)),
* collective bytes per op kind (all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute) — post-SPMD shapes are *per-device*,
  which is exactly the roofline's unit,
* HBM traffic approximation: result + operand bytes of every instruction in
  non-fusion computations (fusion internals never touch HBM; the fusion
  call site's operands/results are counted instead).
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
# result type: either a tuple "(...)" (may contain /*index=N*/ comments but
# never parens) or "dtype[dims]{layout}"
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(\([^)]*\)|\w+\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instruction:
    name: str
    result_type: str
    op: str
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: List[Instruction]
    param_types: Dict[str, str]


def parse_computations(hlo_text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for line in hlo_text.splitlines():
        head = _COMP_HEAD_RE.match(line)
        if head and line.rstrip().endswith("{"):
            params = {}
            for p in re.findall(r"([\w.\-]+):\s*([^,)]+)", head.group(3)):
                params[p[0]] = p[1].strip()
            current = Computation(head.group(2), bool(head.group(1)), [],
                                  params)
            comps[current.name] = current
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            current.instrs.append(Instruction(m.group(1), m.group(2),
                                              m.group(3), m.group(4)))
    return comps


def _call_edges(comp: Computation) -> List[Tuple[str, float]]:
    """(callee computation name, multiplier) edges out of ``comp``."""
    edges: List[Tuple[str, float]] = []
    for ins in comp.instrs:
        if ins.op == "while":
            trip = 1.0
            tm = _TRIP_RE.search(ins.rest)
            if tm:
                trip = float(tm.group(1))
            for key in ("body", "condition"):
                km = re.search(key + r"=%?([\w.\-]+)", ins.rest)
                if km:
                    edges.append((km.group(1), trip))
        elif ins.op in ("fusion", "call", "custom-call", "map", "reduce",
                        "reduce-window", "sort", "scatter", "select-and-scatter"):
            for key in ("calls", "to_apply"):
                km = re.search(key + r"=%?([\w.\-]+)", ins.rest)
                if km:
                    edges.append((km.group(1), 1.0))
        elif ins.op == "conditional":
            bm = re.search(r"branch_computations=\{([^}]*)\}", ins.rest)
            if bm:
                for name in _OPERAND_RE.findall(bm.group(1)):
                    edges.append((name, 1.0))
    return edges


def _multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    mult: Dict[str, float] = defaultdict(float)
    entry = [c for c in comps.values() if c.is_entry]
    roots = entry or [next(iter(comps.values()))]
    for r in roots:
        mult[r.name] = 1.0
    # propagate (call graph is a DAG in HLO)
    order = list(comps)
    changed = True
    it = 0
    while changed and it < 100:
        changed = False
        it += 1
        snapshot = dict(mult)
        new = defaultdict(float)
        for r in roots:
            new[r.name] = 1.0
        for cname in order:
            if snapshot.get(cname, 0.0) <= 0.0:
                continue
            for callee, m in _call_edges(comps[cname]):
                if callee in comps:
                    new[callee] += snapshot[cname] * m
        for k, v in new.items():
            if abs(v - mult.get(k, 0.0)) > 1e-9:
                changed = True
        mult = new
    return dict(mult)


def _fusion_internal(comps: Dict[str, Computation]) -> set:
    """Computation names reached only via fusion ``calls=`` edges."""
    internal = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "fusion":
                km = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                if km:
                    internal.add(km.group(1))
    return internal


def _dot_flops(ins: Instruction, defs: Dict[str, str]) -> float:
    out_dims = _shape_dims(ins.result_type)
    n_out = 1
    for d in out_dims:
        n_out *= d
    cm = _CONTRACT_RE.search(ins.rest)
    k = 1
    if cm and cm.group(1):
        lhs_name_m = _OPERAND_RE.search(ins.rest)
        lhs_type = defs.get(lhs_name_m.group(1), "") if lhs_name_m else ""
        lhs_dims = _shape_dims(lhs_type)
        for idx in cm.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                k *= lhs_dims[i]
    return 2.0 * n_out * k


def analyze_hlo(hlo_text: str) -> Dict[str, float]:
    """Loop-multiplied per-device metrics from post-SPMD HLO text."""
    comps = parse_computations(hlo_text)
    mult = _multipliers(comps)
    fusion_internal = _fusion_internal(comps)

    flops = 0.0
    bytes_touched = 0.0
    coll: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    coll_count: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m <= 0.0:
            continue
        defs = dict(comp.param_types)
        for ins in comp.instrs:
            defs[ins.name] = ins.result_type
        in_fusion = comp.name in fusion_internal
        for ins in comp.instrs:
            res_bytes = _shape_bytes(ins.result_type)
            if ins.op == "dot":
                flops += m * _dot_flops(ins, defs)
            if ins.op in COLLECTIVES:
                operand_names = _OPERAND_RE.findall(
                    ins.rest.split(")", 1)[0])
                op_bytes = sum(_shape_bytes(defs.get(o, ""))
                               for o in operand_names)
                coll[ins.op] += m * max(res_bytes, op_bytes)
                coll_count[ins.op] += m
            if not in_fusion and ins.op not in ("parameter", "constant",
                                                "tuple", "get-tuple-element",
                                                "bitcast"):
                operand_names = _OPERAND_RE.findall(
                    ins.rest.split("),", 1)[0])
                op_bytes = sum(_shape_bytes(defs.get(o, ""))
                               for o in operand_names[:8])
                bytes_touched += m * (res_bytes + op_bytes)

    return {
        "flops_per_device": flops,
        "bytes_per_device": bytes_touched,
        "collective_bytes_per_device": sum(coll.values()),
        "collective_breakdown": coll,
        "collective_counts": coll_count,
        "n_computations": len(comps),
    }
