"""Streaming client aggregation (DESIGN.md §17).

Four pin families around the chunked client fold in ``fl/trainer.py`` and
``fl/sweep.py``:

* golden trajectory pins — ``client_chunk=None`` must stay BIT-EXACT with
  the pre-refactor materialise-then-einsum trace for every
  chaos x population x wireless x backend combination
  (``tests/golden/fl_trajectories.json``, captured before the refactor);
* the chunk-parity matrix (marked ``streaming``) — chunked runs
  (chunk in {1, 3, N}) match the dense trajectory within float tolerance,
  and chunk == N is bit-exact with ``None`` (same reshape, same trace);
* the named-key ladder (``core/keys.py``) — both historical split walks
  (trainer and sweep, which disagree on the availability key's position
  under population) are reproduced name for name;
* structural guarantees — one streaming fold per traced round
  (``trainer.CLIENT_STREAM_PASSES``), no live (N, d) gradient aval in the
  chunked jaxpr, and the divisibility validation on every entry point.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import flutil
from repro.core import keys as keys_mod
from repro.fl import sweep as sweep_mod
from repro.fl import trainer as fl_trainer

PARITY_TOL = 5e-5     # float reassociation over 3 rounds at D=32
GOLDENS = flutil.load_goldens()


# ---------------------------------------------------------------------------
# golden pins: client_chunk=None is the historical trace, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(flutil.combo_configs()))
def test_golden_pin_bitexact(name):
    w, g, age, res = flutil.run_rounds(flutil.combo_configs()[name])
    gold = GOLDENS[name]
    np.testing.assert_array_equal(w, np.asarray(gold["w"], np.float32))
    np.testing.assert_array_equal(g, np.asarray(gold["g"], np.float32))
    np.testing.assert_array_equal(age, np.asarray(gold["age"], age.dtype))
    np.testing.assert_array_equal(res, np.asarray(gold["res"], np.float32))


# ---------------------------------------------------------------------------
# chunk parity: the fold must not depend on the chunking
# ---------------------------------------------------------------------------

# exact and packed backends per the acceptance matrix, plus the uplink
# variants whose folds differ (one-bit votes, EF residual) and the fully
# composed gated round
PARITY_COMBOS = ("exact", "exact_onebit_ef", "packed", "packed_onebit",
                 "pop_chaos_wl")


@pytest.mark.streaming
@pytest.mark.parametrize("chunk", [1, 3, flutil.N_CLIENTS])
@pytest.mark.parametrize("name", PARITY_COMBOS)
def test_chunk_parity(name, chunk):
    fl = flutil.combo_configs()[name]
    dense = flutil.run_rounds(fl)
    chunked = flutil.run_rounds(
        dataclasses.replace(fl, client_chunk=chunk))
    if chunk == fl.n_clients:
        # one chunk IS the dense fold: same reshape, same trace
        for a, b in zip(dense, chunked):
            np.testing.assert_array_equal(a, b)
        return
    for a, b in zip(dense, chunked):
        np.testing.assert_allclose(a, b, atol=PARITY_TOL, rtol=PARITY_TOL)


@pytest.mark.streaming
@pytest.mark.parametrize("chunk", [2, 6])
def test_sweep_chunk_parity(chunk):
    cfg = sweep_mod.SweepConfig(d=64, n_clients=6, rounds=5,
                                error_feedback=True)
    dense = sweep_mod.run_sweep(cfg, policies=("fairk",), n_seeds=2)
    chunked = sweep_mod.run_sweep(
        dataclasses.replace(cfg, client_chunk=chunk),
        policies=("fairk",), n_seeds=2)
    for k, v in dense.items():
        if k == "labels":
            continue
        if chunk == cfg.n_clients:
            np.testing.assert_array_equal(v, chunked[k], err_msg=k)
        else:
            np.testing.assert_allclose(v, chunked[k], atol=1e-4, rtol=1e-4,
                                       err_msg=k)


@pytest.mark.streaming
def test_sweep_chunk_parity_wireless():
    cfg = sweep_mod.SweepConfig(d=64, n_clients=6, rounds=5,
                                wireless=flutil._WL)
    dense = sweep_mod.run_sweep(cfg, policies=("fairk",), n_seeds=2)
    chunked = sweep_mod.run_sweep(dataclasses.replace(cfg, client_chunk=3),
                                  policies=("fairk",), n_seeds=2)
    for k, v in dense.items():
        if k != "labels":
            np.testing.assert_allclose(v, chunked[k], atol=1e-4, rtol=1e-4,
                                       err_msg=k)


# ---------------------------------------------------------------------------
# named-key ladder: both historical split walks, name for name
# ---------------------------------------------------------------------------

def test_round_key_names_trainer_ladder():
    base = ("sel", "ch")
    f = lambda **kw: keys_mod.round_key_names(base=base, **kw)
    assert f() == ("sel", "ch")
    assert f(chaos=True) == ("sel", "ch", "av", "fd", "nz")
    assert f(pop=True) == ("sel", "ch", "pop", "er")
    assert f(wl=True) == ("sel", "ch", "fad", "csi")
    # trainer: the availability key is drawn under population too
    assert f(chaos=True, pop=True) == ("sel", "ch", "av", "fd", "nz",
                                       "pop", "er")
    assert f(chaos=True, pop=True, wl=True) == (
        "sel", "ch", "av", "fd", "nz", "pop", "er", "fad", "csi")


def test_round_key_names_sweep_ladder():
    base = ("pol", "h", "z")
    f = lambda **kw: keys_mod.round_key_names(base=base, av_with_pop=False,
                                              **kw)
    assert f() == ("pol", "h", "z")
    assert f(chaos=True) == ("pol", "h", "z", "av", "fd", "nz")
    # sweep: population REPLACES the availability draw
    assert f(chaos=True, pop=True) == ("pol", "h", "z", "fd", "nz",
                                       "pop", "er")
    assert f(pop=True, wl=True) == ("pol", "h", "z", "pop", "er",
                                    "fad", "csi")


def test_split_named_matches_raw_split():
    key = jax.random.PRNGKey(7)
    names = ("sel", "ch", "av", "fd", "nz")
    ks = keys_mod.split_named(key, names)
    raw = jax.random.split(key, len(names))
    for i, n in enumerate(names):
        np.testing.assert_array_equal(np.asarray(ks[n]),
                                      np.asarray(raw[i]))
    # the historical 2-way walk was jax.random.split(key) — identical to
    # split(key, 2), which the named ladder relies on for bit-exactness
    two = keys_mod.split_named(key, ("a", "b"))
    k0, k1 = jax.random.split(key)
    np.testing.assert_array_equal(np.asarray(two["a"]), np.asarray(k0))
    np.testing.assert_array_equal(np.asarray(two["b"]), np.asarray(k1))


# ---------------------------------------------------------------------------
# structural guarantees
# ---------------------------------------------------------------------------

def _walk_avals(jaxpr, out):
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                out.append(aval)
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (list, tuple)) else (p,)):
                inner = getattr(sub, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    _walk_avals(inner, out)
                elif hasattr(sub, "eqns"):
                    _walk_avals(sub, out)
    return out


def _step_avals(fl):
    params0, loss_fn, xs, ys = flutil.make_problem(fl.n_clients)
    state, unravel = fl_trainer.init_server(params0, fl)
    d = state.w.shape[0]
    step = fl_trainer.make_fl_step(fl, unravel, loss_fn, d)
    key = jax.random.PRNGKey(0)
    closed = jax.make_jaxpr(step)(key, state.w, state.g, state.age,
                                  state.sel_count, xs, ys, state.residual,
                                  state.theta, state.ctrl)
    return _walk_avals(closed.jaxpr, [])


@pytest.mark.streaming
def test_chunked_jaxpr_has_no_nd_gradient_buffer():
    """With chunk < N no (N, d) float32 intermediate may be live; the
    dense fold (client_chunk=None == one chunk of N) still carries one —
    the contrast proves the walk actually sees the client matrix."""
    fl = flutil.combo_configs()["exact"]
    nd = (flutil.N_CLIENTS, flutil.D)
    is_nd = lambda a: (tuple(a.shape) == nd
                       and a.dtype == jnp.float32)
    assert any(is_nd(a) for a in _step_avals(fl))
    chunked = _step_avals(dataclasses.replace(fl, client_chunk=2))
    assert not any(is_nd(a) for a in chunked)


@pytest.mark.streaming
@pytest.mark.parametrize("chunk", [None, 1, 3])
def test_one_stream_pass_per_trace(chunk):
    """The scan body traces once: one accumulation pass over the clients
    per traced round, whatever the chunk count."""
    fl = dataclasses.replace(flutil.combo_configs()["exact"],
                             client_chunk=chunk)
    before = fl_trainer.CLIENT_STREAM_PASSES
    _step_avals(fl)
    assert fl_trainer.CLIENT_STREAM_PASSES - before == 1


def test_trainer_chunk_validation():
    params0, loss_fn, _, _ = flutil.make_problem()
    for bad in (4, 0, 7):
        fl = dataclasses.replace(flutil.combo_configs()["exact"],
                                 client_chunk=bad)
        state, unravel = fl_trainer.init_server(params0, fl)
        with pytest.raises(ValueError, match="client_chunk"):
            fl_trainer.make_fl_step(fl, unravel, loss_fn,
                                    state.w.shape[0])


def test_sweep_chunk_validation():
    for bad in (5, 0):
        with pytest.raises(ValueError, match="client_chunk"):
            sweep_mod.SweepConfig(n_clients=16, client_chunk=bad)


def test_launch_chunk_validation():
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.launch.steps import make_train_step
    cfg = get_config("mamba2-370m", reduced_variant=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="client_chunk"):
        make_train_step(cfg, InputShape("t", 64, 4, "train"), mesh,
                        n_micro=4, client_chunk=3)
