"""One-bit FSK majority-vote transport (paper Sec. V-B prototype)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize


def test_one_bit_sign_with_zero_positive():
    x = jnp.asarray([-2.0, 0.0, 3.0])
    np.testing.assert_array_equal(np.asarray(quantize.one_bit(x)),
                                  [-1.0, 1.0, 1.0])


def test_majority_vote_noiseless():
    votes = jnp.asarray([[1.0, -1, -1], [1, -1, 1], [1, 1, -1]])
    out = quantize.fsk_majority_vote(jax.random.PRNGKey(0), votes)
    np.testing.assert_array_equal(np.asarray(out), [1.0, -1.0, -1.0])


def test_majority_vote_robust_to_moderate_noise():
    """With N=21 unanimous clients, sigma=1 noise flips (almost) nothing."""
    votes = jnp.ones((21, 512))
    out = quantize.fsk_majority_vote(jax.random.PRNGKey(1), votes,
                                     noise_std=1.0)
    assert float((out == 1.0).mean()) == 1.0


def test_one_bit_preserves_dtype():
    for dt in (jnp.float32, jnp.bfloat16, jnp.float16):
        x = jnp.asarray([-1.5, 0.0, 2.0], dt)
        out = quantize.one_bit(x)
        assert out.dtype == dt
        np.testing.assert_array_equal(np.asarray(out, np.float32),
                                      [-1.0, 1.0, 1.0])


def test_one_bit_output_is_fixed_magnitude():
    """The uplink carries SIGNS only: every output coordinate is exactly
    +-1 whatever the input scale (the server applies a fixed-magnitude
    update — no gradient magnitude survives the quantizer)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(scale=[1e-6, 1.0, 1e6], size=(64, 3))
                    .astype("f4"))
    out = np.asarray(quantize.one_bit(x))
    assert set(np.unique(out)) <= {-1.0, 1.0}
    np.testing.assert_array_equal(out, np.where(np.asarray(x) >= 0,
                                                1.0, -1.0))


def test_majority_from_energy_matches_vote_matrix():
    """The streaming fold reduces the (N, k) vote matrix to its energy row
    before detection — same key walk, bit-identical output."""
    rng = np.random.default_rng(5)
    votes = jnp.asarray(np.sign(rng.normal(size=(7, 33)) + 0.1)
                        .astype("f4"))
    key = jax.random.PRNGKey(11)
    for ns in (0.0, 0.7):
        dense = quantize.fsk_majority_vote(key, votes, noise_std=ns)
        streamed = quantize.fsk_majority_from_energy(
            key, votes.sum(axis=0), noise_std=ns)
        np.testing.assert_array_equal(np.asarray(dense),
                                      np.asarray(streamed))


def test_majority_from_energy_tie_is_positive():
    energy = jnp.asarray([0.0, -0.0, 2.0, -2.0])
    out = quantize.fsk_majority_from_energy(jax.random.PRNGKey(0), energy)
    np.testing.assert_array_equal(np.asarray(out), [1.0, 1.0, 1.0, -1.0])


def test_one_bit_round_stale_preserved():
    rng = np.random.default_rng(0)
    grads = jnp.asarray(rng.normal(size=(5, 32)).astype("f4"))
    g_prev = jnp.asarray(rng.normal(size=32).astype("f4"))
    idx = jnp.asarray([1, 5, 9], jnp.int32)
    g_t = quantize.one_bit_round(jax.random.PRNGKey(0), g_prev, idx, grads)
    g_t = np.asarray(g_t)
    assert set(np.unique(g_t[np.asarray(idx)])) <= {-1.0, 1.0}
    mask = np.ones(32, bool)
    mask[np.asarray(idx)] = False
    np.testing.assert_array_equal(g_t[mask], np.asarray(g_prev)[mask])
