"""One-bit FSK majority-vote transport (paper Sec. V-B prototype)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize


def test_one_bit_sign_with_zero_positive():
    x = jnp.asarray([-2.0, 0.0, 3.0])
    np.testing.assert_array_equal(np.asarray(quantize.one_bit(x)),
                                  [-1.0, 1.0, 1.0])


def test_majority_vote_noiseless():
    votes = jnp.asarray([[1.0, -1, -1], [1, -1, 1], [1, 1, -1]])
    out = quantize.fsk_majority_vote(jax.random.PRNGKey(0), votes)
    np.testing.assert_array_equal(np.asarray(out), [1.0, -1.0, -1.0])


def test_majority_vote_robust_to_moderate_noise():
    """With N=21 unanimous clients, sigma=1 noise flips (almost) nothing."""
    votes = jnp.ones((21, 512))
    out = quantize.fsk_majority_vote(jax.random.PRNGKey(1), votes,
                                     noise_std=1.0)
    assert float((out == 1.0).mean()) == 1.0


def test_one_bit_round_stale_preserved():
    rng = np.random.default_rng(0)
    grads = jnp.asarray(rng.normal(size=(5, 32)).astype("f4"))
    g_prev = jnp.asarray(rng.normal(size=32).astype("f4"))
    idx = jnp.asarray([1, 5, 9], jnp.int32)
    g_t = quantize.one_bit_round(jax.random.PRNGKey(0), g_prev, idx, grads)
    g_t = np.asarray(g_t)
    assert set(np.unique(g_t[np.asarray(idx)])) <= {-1.0, 1.0}
    mask = np.ones(32, bool)
    mask[np.asarray(idx)] = False
    np.testing.assert_array_equal(g_t[mask], np.asarray(g_prev)[mask])
