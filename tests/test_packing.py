"""Packed server state (core.packing + the engine's packed backend).

Pins the tentpole guarantees:
* pack -> unpack identity on multi-dtype pytrees (bf16 g_prev, int8 age);
* padding protocol: pads never selected, sentinel survives round trips,
  sampled thresholds exclude pad coordinates (incl. the exact
  block-boundary regression);
* bit-exact parity: packed backend == per-leaf application of the SAME
  global thresholds == exact top-k selection, on tie-free inputs with
  ``exact_theta=True``;
* warm-start thresholds: steady-state rounds skip the quantile pass while
  the realised count keeps tracking the budget.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packing
from repro.core.engine import (EngineConfig, SelectionEngine,
                               exact_thresholds, make_engine, masked_merge,
                               sampled_thresholds, threshold_mask)
from repro.kernels import ops


def transformer_tree(seed=0, n_layers=3, d_model=64, vocab=500,
                     dtype="f4"):
    """Multi-dtype transformer-ish pytree with odd + exactly-lane-aligned
    leaf sizes (vocab*d_model = 32000 is NOT lane aligned; d_model**2 =
    4096 IS — the block-boundary case)."""
    rng = np.random.default_rng(seed)
    tree = {"embed": rng.standard_normal((vocab, d_model)),
            "final_norm": rng.standard_normal((d_model,))}
    for i in range(n_layers):
        tree[f"layer_{i}"] = {
            "w": rng.standard_normal((d_model, d_model)),
            "norm": rng.standard_normal((d_model,)),
            "b": rng.standard_normal((7,)),                # odd leaf
        }
    return jax.tree.map(lambda x: jnp.asarray(x.astype(dtype)), tree)


def tie_free_state(tree, seed=1, int8_ages=True):
    """(g, g_prev bf16, age) trees with distinct |g|.

    ``int8_ages=True``: ages in int8 (0..119, int8-safe but TIED — valid for
    paths that share the index-jitter tie-break).  ``False``: globally
    distinct f32 ages (a permutation of the whole tree) — required when
    comparing against the exact backend, whose ``lax.top_k`` breaks ties by
    lowest index instead of the jitter hash."""
    rng = np.random.default_rng(seed)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sizes = [int(np.prod(l.shape)) for l in leaves]
    perm = rng.permutation(sum(sizes))
    if int8_ages:
        perm = perm % 120                                 # int8-safe
    g, gp, age, off = [], [], [], 0
    for leaf, n in zip(leaves, sizes):
        g.append(jnp.asarray(rng.normal(size=leaf.shape).astype("f4")))
        gp.append(jnp.asarray(
            rng.normal(size=leaf.shape).astype("f4")).astype(jnp.bfloat16))
        chunk = perm[off:off + n].reshape(leaf.shape)
        age.append(jnp.asarray(chunk.astype("i1") if int8_ages
                               else chunk.astype("f4")))
        off += n
    mk = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
    return mk(g), mk(gp), mk(age)


# ---------------------------------------------------------------------------
# layout / pack / unpack
# ---------------------------------------------------------------------------

class TestLayout:
    def test_block_table_lane_alignment(self):
        tree = transformer_tree()
        lay = packing.PackedLayout.from_tree(tree)
        for e in lay.table:
            assert e.offset % lay.lane == 0
            assert (e.size + e.pad) % lay.lane == 0
        assert lay.d_valid == sum(e.size for e in lay.table)
        assert lay.d_packed % lay.lane == 0

    def test_pack_unpack_identity_multi_dtype(self):
        """f32 grads, bf16 g_prev and int8 age all round-trip bitwise."""
        tree = transformer_tree()
        for t in tie_free_state(tree):
            lay = packing.PackedLayout.from_tree(t)   # records leaf dtypes
            back = lay.unpack(lay.pack(t))
            for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
                assert a.dtype == b.dtype
                np.testing.assert_array_equal(
                    np.asarray(a, np.float32), np.asarray(b, np.float32))

    def test_pack_age_sentinel_and_init_age(self):
        tree = transformer_tree()
        _, _, age = tie_free_state(tree)
        lay = packing.PackedLayout.from_tree(tree)
        buf = lay.pack_age(age)
        valid = np.asarray(lay.valid_mask())
        assert (np.asarray(buf)[~valid] == packing.PAD_AGE).all()
        assert (np.asarray(buf)[valid] >= 0).all()
        init = np.asarray(lay.init_age(jnp.int8))
        assert (init[valid] == 0).all() and (init[~valid] == -1).all()

    def test_exact_block_boundary_leaf_has_no_pad(self):
        """A leaf of exactly lane*k elements must get pad == 0 (off-by-one
        guard for the block table)."""
        lay = packing.PackedLayout.from_tree(
            [jnp.zeros((256,)), jnp.zeros((512,)), jnp.zeros((100,))])
        assert [e.pad for e in lay.table] == [0, 0, 156]
        assert lay.d_packed == 256 + 512 + 256


# ---------------------------------------------------------------------------
# pad-excluding thresholds (satellite regression)
# ---------------------------------------------------------------------------

class TestPadExcludingThresholds:
    def test_sample_ids_hit_only_valid_coords(self):
        tree = transformer_tree()
        lay = packing.PackedLayout.from_tree(tree)
        ids = lay.sample_ids(1 << 14)
        valid = np.asarray(lay.valid_mask())
        assert valid[ids].all()

    def test_pad_zeros_would_bias_theta_m_low(self):
        """Regression: a heavily padded buffer (many small leaves) must
        produce the same θ_M as the unpadded flat vector; the naive strided
        sample over the padded buffer is biased low by the pad zeros."""
        rng = np.random.default_rng(3)
        # 64 leaves x 300 elements -> pad fraction 212/512 per leaf
        leaves = [jnp.asarray(rng.normal(size=300).astype("f4"))
                  for _ in range(64)]
        lay = packing.PackedLayout.from_tree(leaves)
        ages = [jnp.asarray(rng.integers(0, 40, 300).astype("f4"))
                for _ in range(64)]
        g_buf = lay.pack(leaves)
        age_buf = lay.pack_age(ages)
        kw = dict(rho=0.1, k_m_frac=0.75, sample_cap=lay.d_packed)
        tm_clean, _ = sampled_thresholds(g_buf, age_buf,
                                         sample_ids=lay.sample_ids(
                                             lay.d_valid), **kw)
        tm_naive, _ = sampled_thresholds(g_buf, age_buf, **kw)
        flat = jnp.concatenate([l for l in leaves])
        flat_age = jnp.concatenate(ages)
        tm_ref, _ = sampled_thresholds(flat, flat_age, rho=0.1,
                                       k_m_frac=0.75,
                                       sample_cap=flat.shape[0])
        assert abs(float(tm_clean) - float(tm_ref)) < 0.02
        assert float(tm_naive) < float(tm_ref) - 0.1   # the bias being fixed

    def test_exact_block_boundary_leaf_thresholds(self):
        """At an exactly lane-aligned leaf length there are no pads at all:
        pad-excluding ids must equal the plain strided sample."""
        rng = np.random.default_rng(4)
        leaves = [jnp.asarray(rng.normal(size=512).astype("f4")),
                  jnp.asarray(rng.normal(size=256).astype("f4"))]
        lay = packing.PackedLayout.from_tree(leaves)
        assert lay.d_packed == lay.d_valid == 768
        ids = lay.sample_ids(768)
        np.testing.assert_array_equal(ids, np.arange(768))


# ---------------------------------------------------------------------------
# parity: packed == per-leaf(same θ) == exact  (the acceptance criterion)
# ---------------------------------------------------------------------------

class TestPackedParity:
    def _packed_inputs(self, int8_ages=True):
        tree = transformer_tree()
        g, gp, age = tie_free_state(tree, int8_ages=int8_ages)
        lay = packing.PackedLayout.from_tree(g)
        return lay, g, gp, age

    def test_packed_matches_per_leaf_same_thresholds(self):
        """One fused pass over the packed buffer == the per-leaf loop
        applying the SAME global (θ_M, θ_A) leaf by leaf (index_offset
        aligns the jitter) — bit-exact, incl. the int8 age round-trip."""
        lay, g, gp, age = self._packed_inputs()
        g_buf, gp_buf = lay.pack(g), lay.pack(gp)
        age_buf = lay.pack_age(age)
        k = max(2, round(0.1 * lay.d_valid))
        k_m = int(round(0.75 * k))
        tm, ta = exact_thresholds(g_buf, age_buf, k=k, k_m=k_m)
        gt_buf, age_next = ops.fairk_update(g_buf, gp_buf, age_buf, tm, ta)
        gt_tree = lay.unpack(gt_buf, cast=False)
        age_tree = lay.unpack(age_next, cast=False)
        g_ls = lay.treedef.flatten_up_to(g)
        gp_ls = lay.treedef.flatten_up_to(gp)
        age_ls = lay.treedef.flatten_up_to(age)
        for e, gl, gpl, al, gt_l, an_l in zip(
                lay.table, g_ls, gp_ls, age_ls,
                jax.tree.leaves(gt_tree), jax.tree.leaves(age_tree)):
            mask, _ = threshold_mask(gl.reshape(-1),
                                     al.reshape(-1).astype(jnp.float32),
                                     tm, ta, index_offset=e.offset)
            ref_g, ref_age = masked_merge(
                gl.reshape(-1), gpl.reshape(-1).astype(jnp.float32),
                al.reshape(-1).astype(jnp.float32), mask)
            np.testing.assert_array_equal(np.asarray(gt_l).reshape(-1),
                                          np.asarray(ref_g))
            np.testing.assert_array_equal(np.asarray(an_l).reshape(-1),
                                          np.asarray(ref_age))
            # int8 server round trip is exact (ages <= AGE_CAP = 120)
            np.testing.assert_array_equal(
                np.asarray(an_l).astype(np.int8).astype(np.float32),
                np.asarray(an_l))

    def test_packed_matches_exact_backend(self):
        """Packed threshold backend (exact_theta) == exact lax.top_k
        backend run on the same packed buffer, bit-exact on the valid
        coordinates (tie-free inputs)."""
        lay, g, gp, age = self._packed_inputs(int8_ages=False)
        g_buf, gp_buf = lay.pack(g), lay.pack(gp)
        age_buf = lay.pack_age(age)
        pk = SelectionEngine(
            EngineConfig(policy="fairk", backend="packed", rho=0.1,
                         k_m_frac=0.75, exact_theta=True,
                         kernel_mode="interpret"),
            lay.d_packed, layout=lay)
        k, k_m, r = pk.budgets()
        assert k == max(2, round(0.1 * lay.d_valid))      # budgets on d_valid
        ex = SelectionEngine(
            EngineConfig(policy="fairk", backend="exact", k=k, k_m=k_m,
                         r=r), lay.d_packed)
        g1, a1, s1 = pk.select_and_merge(g_buf, gp_buf, age_buf)
        g2, a2, s2 = jax.jit(ex.select_and_merge)(g_buf, gp_buf, age_buf)
        valid = np.asarray(lay.valid_mask())
        np.testing.assert_array_equal(np.asarray(g1)[valid],
                                      np.asarray(g2)[valid])
        np.testing.assert_array_equal(np.asarray(a1)[valid],
                                      np.asarray(a2)[valid])
        assert float(s1["n_selected"]) == k               # pads never count
        # pads: sentinel survives, never selected, value = g_prev (= pad 0)
        assert (np.asarray(a1)[~valid] == packing.PAD_AGE).all()

    def test_select_and_merge_tree_facade(self):
        lay, g, gp, age = self._packed_inputs()
        eng = SelectionEngine(
            EngineConfig(policy="fairk", backend="packed", rho=0.1,
                         k_m_frac=0.75, exact_theta=True),
            lay.d_packed, layout=lay)
        gt_tree, age_tree, stats = eng.select_and_merge_tree(g, gp, age)
        g_buf, gp_buf, age_buf = (lay.pack(g), lay.pack(gp),
                                  lay.pack_age(age))
        gt_buf, age_next, _ = eng.select_and_merge(g_buf, gp_buf, age_buf)
        for a, b in zip(jax.tree.leaves(gt_tree),
                        jax.tree.leaves(lay.unpack(gt_buf, cast=False))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert jax.tree_util.tree_structure(
            gt_tree) == jax.tree_util.tree_structure(g)


# ---------------------------------------------------------------------------
# warm-start thresholds
# ---------------------------------------------------------------------------

class TestWarmStart:
    def test_steady_state_warms_and_tracks_budget(self):
        """After the cold-start transient the warm branch carries the
        thresholds (streak >= warm_streak) and the realised count stays
        inside the trust region; no round ever explodes past 2k."""
        rng = np.random.default_rng(0)
        shapes = {"a": (100, 100), "b": (999,), "c": (3, 7)}
        tree = {k: jnp.zeros(s, jnp.float32) for k, s in shapes.items()}
        lay = packing.PackedLayout.from_tree(tree)
        eng = make_engine("fairk", "packed", layout=lay, rho=0.1,
                          k_m_frac=0.75, sample_cap=8192, warm_start=True)
        k = eng.budgets()[0]
        gp = jnp.zeros((lay.d_packed,), jnp.float32)
        ag = lay.init_age(jnp.float32)
        ts = packing.init_threshold_state()
        step = jax.jit(lambda g, gp, ag, ts:
                       eng.select_and_merge(g, gp, ag, tstate=ts))
        warm, sels = [], []
        for r in range(150):
            g = lay.pack({kk: jnp.asarray(
                rng.normal(size=s).astype("f4"))
                for kk, s in shapes.items()})
            warm.append(float(ts["streak"]) >= eng.cfg.warm_streak)
            g_t, ag2, stats = step(g, gp, ag, ts)
            ts, gp, ag = stats["tstate"], g_t, ag2
            sels.append(float(stats["n_selected"]))
        assert np.mean(warm[100:]) > 0.7          # steady state mostly warm
        assert max(sels) < 2 * k                  # no cohort blow-ups
        assert abs(np.mean(sels[100:]) - k) < 0.15 * k

    def test_bootstrap_round_equals_plain_packed(self):
        """Round 0 (init=0) must take the bootstrap branch == the
        non-warm packed path, bit-exact."""
        tree = transformer_tree()
        g, gp, age = tie_free_state(tree)
        lay = packing.PackedLayout.from_tree(g)
        mk = lambda warm: make_engine("fairk", "packed", layout=lay,
                                      rho=0.1, warm_start=warm)
        bufs = (lay.pack(g), lay.pack(gp), lay.pack_age(age))
        g1, a1, s1 = mk(True).select_and_merge(
            *bufs, tstate=packing.init_threshold_state())
        g2, a2, _ = mk(False).select_and_merge(*bufs)
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
        assert float(s1["tstate"]["init"]) == 1.0

    def test_threshold_state_vec_round_trip(self):
        ts = packing.init_threshold_state()
        ts["theta_m"] = jnp.float32(1.5)
        ts["n_sel"] = jnp.float32(42.0)
        back = packing.threshold_state_from_vec(
            packing.threshold_state_to_vec(ts))
        for f in packing.THRESHOLD_STATE_FIELDS:
            assert float(back[f]) == float(ts[f])


# ---------------------------------------------------------------------------
# pad-aware kernel
# ---------------------------------------------------------------------------

class TestPadAwareKernel:
    @pytest.mark.parametrize("mode", ["ref", "interpret"])
    def test_pads_never_select_and_sentinel_survives(self, mode):
        rng = np.random.default_rng(7)
        d = 1024
        g = jnp.asarray(rng.normal(size=d).astype("f4"))
        gp = jnp.asarray(rng.normal(size=d).astype("f4"))
        age = jnp.asarray(rng.integers(0, 40, d).astype("f4"))
        pad = np.zeros(d, bool)
        pad[100:356] = True                      # interior pad block
        g = g.at[100:356].set(0.0)
        age = age.at[100:356].set(packing.PAD_AGE)
        # theta_a = -inf-like low would select everything valid; pads must
        # still refuse
        g_t, age_next = ops.fairk_update(g, gp, age, jnp.float32(0.05),
                                         jnp.float32(0.0), mode=mode,
                                         block_size=256)
        assert (np.asarray(age_next)[pad] == packing.PAD_AGE).all()
        np.testing.assert_array_equal(np.asarray(g_t)[pad],
                                      np.asarray(gp)[pad])
        assert (np.asarray(age_next)[~pad] == 0).all()   # all valid selected


# ---------------------------------------------------------------------------
# block-AoU clip in the FL-OAC step (satellite bugfix)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fl_oac_age_clipped_at_cap():
    """make_fl_oac_step must clip the block AoU at AGE_CAP (int8-safety
    invariant, DESIGN.md §5) — seeded at the cap, one round must not
    exceed it."""
    from repro.configs import get_config
    from repro.core.engine import AGE_CAP
    from repro.data.tokens import lm_batch
    from repro.launch.steps import make_fl_oac_step
    from repro.models import transformer as tr
    from jax.flatten_util import ravel_pytree

    mesh = jax.make_mesh((1,), ("data",))
    cfg = get_config("mamba2-370m", reduced_variant=True)
    b = make_fl_oac_step(cfg, mesh, seq_len=32, rho=0.05)
    params = tr.init_lm(jax.random.PRNGKey(0), cfg)
    w, _ = ravel_pytree(params)
    d, nb = b.meta["d"], b.meta["blocks"]
    g_prev = jnp.zeros((d,), jnp.float32)
    age = jnp.full((nb,), AGE_CAP, jnp.float32)   # already at the cap
    toks, labels = lm_batch(0, 1, 32, cfg.vocab)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
    with mesh:
        fn = jax.jit(b.fn, in_shardings=b.in_shardings,
                     out_shardings=b.out_shardings)
        _, _, age_next, _ = fn(w, g_prev, age, batch,
                               jnp.asarray(0, jnp.int32))
    assert float(jnp.max(age_next)) <= AGE_CAP
    assert float(jnp.min(age_next)) == 0.0        # selected blocks reset
