"""Unit + property tests for the selection policies (paper Sec. III-B)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import selection
from repro.core.aou import update_age_by_indices


def _rand(d, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=d).astype("f4"))


class TestFairK:
    def test_exact_k_unique(self):
        g, age = _rand(200), jnp.arange(200, dtype=jnp.float32)
        idx = selection.fair_k_indices(g, age, k=20, k_m=15)
        assert idx.shape == (20,)
        assert len(set(np.asarray(idx).tolist())) == 20

    def test_reduces_to_topk(self):
        """Remark 1: k_m = k  =>  Top-k."""
        g, age = _rand(300, 1), _rand(300, 2) ** 2
        i1 = np.sort(np.asarray(selection.fair_k_indices(g, age, k=30, k_m=30)))
        i2 = np.sort(np.asarray(selection.top_k_indices(g, k=30)))
        np.testing.assert_array_equal(i1, i2)

    def test_reduces_to_round_robin(self):
        """Remark 1: k_m = 0  =>  age-priority (round robin)."""
        g = _rand(300, 1)
        age = jnp.asarray(np.random.default_rng(3).permutation(300).astype("f4"))
        i1 = np.sort(np.asarray(selection.fair_k_indices(g, age, k=30, k_m=0)))
        i2 = np.sort(np.asarray(selection.round_robin_indices(age, k=30)))
        np.testing.assert_array_equal(i1, i2)

    def test_magnitude_stage_takes_top(self):
        g = jnp.zeros(100).at[7].set(100.0).at[42].set(-99.0)
        idx = selection.fair_k_indices(g, jnp.zeros(100), k=10, k_m=2)
        assert {7, 42} <= set(np.asarray(idx[:2]).tolist())

    def test_age_stage_excludes_magnitude_picks(self):
        # entry 0: huge magnitude AND huge age -> must appear exactly once
        g = jnp.zeros(64).at[0].set(50.0)
        age = jnp.zeros(64).at[0].set(1000.0)
        idx = np.asarray(selection.fair_k_indices(g, age, k=8, k_m=4))
        assert (idx == 0).sum() == 1

    def test_round_robin_cycles(self):
        """With equal ages the schedule must sweep all of [d] in d/k rounds."""
        d, k = 64, 8
        age = jnp.zeros(d)
        seen = set()
        for _ in range(d // k):
            idx = selection.round_robin_indices(age, k=k)
            seen.update(np.asarray(idx).tolist())
            age = update_age_by_indices(age, idx)
        assert seen == set(range(d))

    def test_max_staleness_bound(self):
        """Lemma 1: staleness never exceeds T = ceil((d-k_m)/k_a)."""
        d, k, k_m = 120, 12, 9
        T = -(-(d - k_m) // (k - k_m))
        rng = np.random.default_rng(0)
        g = jnp.zeros(d)
        age = jnp.zeros(d)
        for t in range(8 * T):
            g = jnp.asarray(rng.normal(size=d).astype("f4"))
            idx = selection.fair_k_indices(g, age, k=k, k_m=k_m)
            age = update_age_by_indices(age, idx)
            assert float(age.max()) <= T, f"round {t}: age {float(age.max())}"


class TestBaselines:
    def test_age_topk_subset_of_top_r(self):
        g, age = _rand(256, 5), _rand(256, 6) ** 2
        idx = np.asarray(selection.age_top_k_indices(g, age, k=16, r=24))
        top_r = set(np.asarray(selection.top_k_indices(g, k=24)).tolist())
        assert set(idx.tolist()) <= top_r
        assert len(set(idx.tolist())) == 16

    def test_top_rand_contains_top_m(self):
        key = jax.random.PRNGKey(0)
        g = _rand(256, 7)
        idx = np.asarray(selection.top_rand_indices(key, g, k=16, k_m=12))
        top_m = set(np.asarray(selection.top_k_indices(g, k=12)).tolist())
        assert top_m <= set(idx.tolist())
        assert len(set(idx.tolist())) == 16

    def test_rand_k_uniform_coverage(self):
        key = jax.random.PRNGKey(0)
        counts = np.zeros(64)
        for i in range(200):
            key, sub = jax.random.split(key)
            idx = np.asarray(selection.rand_k_indices(sub, 64, k=8))
            counts[idx] += 1
        # every entry selected at least once over 200 rounds (p_miss ~ 3e-12)
        assert (counts > 0).all()

    @pytest.mark.parametrize("policy", selection.POLICIES)
    def test_registry_all_policies(self, policy):
        key = jax.random.PRNGKey(1)
        g, age = _rand(128, 8), _rand(128, 9) ** 2
        idx = selection.select_indices(policy, key, g, age, k=16, k_m=12, r=24)
        assert idx.shape == (16,)
        assert len(set(np.asarray(idx).tolist())) == 16


@settings(max_examples=30, deadline=None)
@given(d=st.integers(10, 300), data=st.data())
def test_property_fairk_budget(d, data):
    """For any (d, k, k_m): exactly k unique indices, all in range."""
    k = data.draw(st.integers(1, d))
    k_m = data.draw(st.integers(0, k))
    rng = np.random.default_rng(d)
    g = jnp.asarray(rng.normal(size=d).astype("f4"))
    age = jnp.asarray(rng.integers(0, 50, d).astype("f4"))
    idx = np.asarray(selection.fair_k_indices(g, age, k=k, k_m=k_m))
    assert idx.shape == (k,)
    assert len(set(idx.tolist())) == k
    assert (0 <= idx).all() and (idx < d).all()


@settings(max_examples=20, deadline=None)
@given(d=st.integers(20, 200), data=st.data())
def test_property_age_stage_picks_oldest(d, data):
    """The age stage must pick the k_a oldest among non-magnitude-picked."""
    k = data.draw(st.integers(2, min(d, 20)))
    k_m = data.draw(st.integers(1, k - 1))
    rng = np.random.default_rng(d + 1)
    g = jnp.asarray(rng.normal(size=d).astype("f4"))
    age = jnp.asarray(rng.permutation(d).astype("f4"))  # unique ages
    idx = np.asarray(selection.fair_k_indices(g, age, k=k, k_m=k_m))
    mag_picks = set(idx[:k_m].tolist())
    age_np = np.asarray(age)
    rest = [i for i in range(d) if i not in mag_picks]
    expected = set(sorted(rest, key=lambda i: -age_np[i])[: k - k_m])
    assert set(idx[k_m:].tolist()) == expected
