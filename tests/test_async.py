"""Async double-buffered server rounds + the age-saturation bugfix sweep.

Covers (DESIGN.md §13):

* int8 wrap regression — every age-update site clips at ``AGE_CAP`` so the
  packed int8 buffer can never wrap past 127 into the ``age < 0`` pad
  sentinel, even under async lag shifts on top of saturated ages;
* ``shift_selected_age`` / ``shift_age_hist`` semantics (lag 0 identity,
  pad preservation, histogram/buffer consistency);
* engine ``age_lag`` parity: async off is bit-exact with the synchronous
  trajectory on every backend, async on shifts ONLY the selected ages;
* async staleness accounting: the stationary post-update AoU pmf under an
  injected lag matches the lag-shifted Lemma-1 prediction
  (``markov.shifted_aou_distribution``) within the existing TV tolerance.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import statutil
from repro.core import aou, markov, packing
from repro.core.engine import (AGE_CAP, EngineConfig, SelectionEngine,
                               fair_k_masks_dynamic, make_engine, traced_km)
from repro.kernels import ref

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# satellite 2: int8 age saturation / pad-sentinel wrap regression
# ---------------------------------------------------------------------------

def test_age_cap_is_int8_safe():
    # the whole point of the cap: age + a few rounds of async lag must
    # stay strictly below the int8 wrap point
    assert AGE_CAP == packing.AGE_CAP
    assert AGE_CAP + 6.0 < 127.0


def test_ref_oracle_age_clipped_at_cap():
    d = 512
    g = jnp.zeros((d,), jnp.float32)           # nothing selected by magnitude
    gp = jnp.zeros((d,), jnp.float32)
    age = jnp.full((d,), AGE_CAP, jnp.float32)
    theta_m = jnp.float32(jnp.inf)
    theta_a = jnp.float32(jnp.inf)             # nothing selected by age
    _, age_next = ref.fairk_update_ref(g, gp, age, theta_m, theta_a)
    assert float(age_next.max()) == AGE_CAP    # fixed point, no wrap
    # int8 round-trip survives (this is the buffer dtype in launch.steps)
    assert int(age_next.astype(jnp.int8).min()) == int(AGE_CAP)


def test_aou_merge_ref_clipped_at_cap():
    age = jnp.full((64,), AGE_CAP, jnp.float32)
    mask = jnp.zeros((64,), jnp.float32)
    _, age_next = ref.aou_merge_ref(jnp.zeros(64), jnp.zeros(64), age, mask)
    assert float(age_next.max()) == AGE_CAP


def test_aou_helpers_clipped_at_cap():
    age = jnp.full((64,), AGE_CAP, jnp.float32)
    assert float(aou.update_age(age, jnp.zeros(64)).max()) == AGE_CAP
    out = aou.update_age_by_indices(age, jnp.asarray([0], jnp.int32))
    assert float(out.max()) == AGE_CAP and float(out[0]) == 0.0


def test_int8_buffer_never_wraps_under_lag():
    """Regression: pre-fix, ages past AGE_CAP cast to int8 wrapped negative
    and collided with the PAD_AGE sentinel.  With the clamp the round-trip
    through the int8 server buffer is stable for any number of rounds plus
    any async lag shift."""
    d = 256
    age = jnp.concatenate([jnp.full((d - 8,), AGE_CAP - 1.0),
                           jnp.full((8,), packing.PAD_AGE)]).astype(jnp.int8)
    mask = jnp.zeros((d,), jnp.float32).at[0].set(1.0)
    a = age.astype(jnp.float32)
    for _ in range(10):                        # 10 rounds past saturation
        a = aou.update_age(a, mask)
        # pads would be destroyed by update_age; the production paths gate
        # on age >= 0 — emulate that here
        a = jnp.where(age.astype(jnp.float32) < 0.0,
                      age.astype(jnp.float32), a)
        a = packing.shift_selected_age(a, 3)   # async lag on the selected
        a8 = a.astype(jnp.int8)                # the persisted buffer dtype
        assert int(a8.max()) <= int(AGE_CAP)
        assert (np.asarray(a8)[-8:] == packing.PAD_AGE).all()
        assert (np.asarray(a8)[:-8] >= 0).all()        # no sentinel wrap
        a = a8.astype(jnp.float32)


# ---------------------------------------------------------------------------
# shift helpers
# ---------------------------------------------------------------------------

def test_shift_selected_age_semantics():
    age_next = jnp.asarray([0.0, 5.0, 0.0, packing.PAD_AGE, AGE_CAP])
    out = packing.shift_selected_age(age_next, 2)
    np.testing.assert_allclose(
        np.asarray(out), [2.0, 5.0, 2.0, packing.PAD_AGE, AGE_CAP])
    # lag 0 is the identity
    np.testing.assert_array_equal(
        np.asarray(packing.shift_selected_age(age_next, 0)),
        np.asarray(age_next))


def test_shift_age_hist_matches_shifted_buffer():
    rng = np.random.default_rng(0)
    age_next = jnp.asarray(
        rng.choice([0.0, 0.0, 1.0, 3.0, 7.0], size=4096).astype(np.float32))
    lag = 2
    valid = jnp.ones((4096,), bool)
    _, h_sync = ref.strided_hists_ref(jnp.zeros(4096), age_next, valid, 1)
    _, h_shifted = ref.strided_hists_ref(
        jnp.zeros(4096), packing.shift_selected_age(age_next, lag), valid, 1)
    np.testing.assert_array_equal(
        np.asarray(packing.shift_age_hist(h_sync, lag)),
        np.asarray(h_shifted))
    assert packing.shift_age_hist(h_sync, 0) is h_sync     # exact identity


# ---------------------------------------------------------------------------
# engine age_lag: async off ≡ sync bit-exact; async on shifts ONLY the
# selected ages (and the emitted histogram with them)
# ---------------------------------------------------------------------------

def _engine_and_kwargs(backend, d):
    if backend == "packed":
        layout = packing.PackedLayout.from_tree([jnp.zeros((d,))], lane=1)
        eng = make_engine("fairk", "packed", layout=layout, rho=0.125,
                          k_m_frac=0.75, fused_stats=True, warm_start=True)
        return eng, {"tstate": packing.init_threshold_state()}
    eng = make_engine("fairk", backend, d=d, rho=0.125, k_m_frac=0.75,
                      fused_stats=(backend != "exact"))
    return eng, {}


@pytest.mark.parametrize("backend", ["exact", "threshold", "packed"])
def test_engine_age_lag_parity(backend):
    d = 4096
    key = jax.random.PRNGKey(3)
    g = jax.random.normal(key, (d,), jnp.float32)
    gp = jax.random.normal(jax.random.fold_in(key, 1), (d,), jnp.float32)
    age = jnp.floor(10.0 * jax.random.uniform(jax.random.fold_in(key, 2),
                                              (d,), jnp.float32))
    lag = 2
    eng, kw = _engine_and_kwargs(backend, d)
    g_sync, age_sync, st_sync = eng.select_and_merge(g, gp, age, **kw)
    g_async, age_async, st_async = eng.select_and_merge(g, gp, age,
                                                        age_lag=lag, **kw)
    # the merge itself is untouched — only the age bookkeeping shifts
    np.testing.assert_array_equal(np.asarray(g_sync), np.asarray(g_async))
    np.testing.assert_array_equal(
        np.asarray(packing.shift_selected_age(age_sync, lag)),
        np.asarray(age_async))
    # async mode hands the selection mask back explicitly (the age_next==0
    # convention no longer identifies it)
    np.testing.assert_array_equal(
        np.asarray(st_async["sel_mask"]),
        np.asarray((age_sync == 0.0).astype(jnp.float32)))
    assert "sel_mask" not in st_sync
    # the emitted histogram bins the SHIFTED ages
    if "age_hist" in st_sync:
        np.testing.assert_array_equal(
            np.asarray(packing.shift_age_hist(st_sync["age_hist"], lag)),
            np.asarray(st_async["age_hist"]))
    # lag 0 normalizes to the synchronous trace — bit-exact, no sel_mask
    g_z, age_z, st_z = eng.select_and_merge(g, gp, age, age_lag=0, **kw)
    np.testing.assert_array_equal(np.asarray(g_z), np.asarray(g_sync))
    np.testing.assert_array_equal(np.asarray(age_z), np.asarray(age_sync))
    assert "sel_mask" not in st_z
    with pytest.raises(ValueError):
        eng.select_and_merge(g, gp, age, age_lag=-1, **kw)


# ---------------------------------------------------------------------------
# satellite 4: stationary post-update AoU pmf under injected stragglers ==
# the lag-shifted Lemma-1 prediction (exact + packed backends)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["exact", "packed"])
def test_empirical_pmf_matches_shifted_lemma1(backend):
    """Run FAIR-k with iid re-drawn scores (the well-mixed exchange regime,
    k0 = k_M(1 − k_M/d)) under an injected delivery lag and compare the
    time-averaged age_hist pmf against ``markov.shifted_aou_distribution``
    on the same chain — the existing TV tolerance (< 0.1)."""
    d, k, k_m, lag = 512, 64, 32, 3
    if backend == "packed":
        eng = make_engine("fairk", "packed",
                          layout=packing.PackedLayout.from_tree(
                              [jnp.zeros((d,))], lane=1),
                          k=k, k_m=k_m, fused_stats=True, warm_start=True)
        ts = packing.init_threshold_state()
    else:
        eng = make_engine("fairk", "exact", d=d, k=k, k_m=k_m,
                          fused_stats=True)
        ts = None
    acc = statutil.accumulate_age_hist(eng, d, tstate=ts, age_lag=lag)
    k0 = int(round(k_m * (1 - k_m / d)))
    support, pred = markov.shifted_aou_distribution(
        markov.FairKChain(d=d, k=k, k_m=k_m, k0=k0), lag)
    assert int(support[0]) == lag                     # translated support
    emp = statutil.assert_pmf_close(acc, support, pred)
    assert emp[:lag].sum() == 0.0                     # nothing younger than lag


def test_shifted_aou_distribution_validates():
    chain = markov.FairKChain(d=512, k=64, k_m=32, k0=30)
    with pytest.raises(ValueError):
        markov.shifted_aou_distribution(chain, -1)
    s0, p0 = markov.shifted_aou_distribution(chain, 0)
    s1, p1 = markov.aou_distribution(chain)
    np.testing.assert_array_equal(s0, s1)
    np.testing.assert_array_equal(p0, p1)


# ---------------------------------------------------------------------------
# satellite 1: traced k_M split in the FL-OAC step ≡ the static top_k
# concatenation (same selected set, incl. the toward-lower-index tie-break)
# ---------------------------------------------------------------------------

def test_fl_oac_traced_split_matches_static():
    nb, kb = 192, 24
    rng = np.random.default_rng(5)
    score = jnp.asarray(rng.normal(size=nb).astype("f4") ** 2)
    # INTEGER block ages — heavy ties, the regime where a tie-break
    # mismatch between rank and top_k would show
    age_b = jnp.asarray(rng.integers(0, 6, size=nb).astype("f4"))
    for kmf in (0.0, 0.25, 0.5, 0.75, 1.0):
        kb_m = int(round(kmf * kb))
        # the historical static-split selection (pre-traced form)
        _, idx_m = jax.lax.top_k(score, kb_m)
        age_masked = age_b.at[idx_m].set(-1.0)
        _, idx_a = jax.lax.top_k(age_masked, kb - kb_m)
        static_set = set(np.concatenate([np.asarray(idx_m),
                                         np.asarray(idx_a)]).tolist())
        # the traced split (what make_fl_oac_step now runs)
        km_t = traced_km(kb, jnp.float32(kmf))
        assert int(km_t) == kb_m                      # rounding parity
        mask, _ = fair_k_masks_dynamic(score, age_b, kb, km_t)
        idx = jnp.nonzero(mask, size=kb, fill_value=0)[0]
        traced_set = set(np.asarray(idx).tolist())
        assert traced_set == static_set, kmf
        assert len(traced_set) == kb


# ---------------------------------------------------------------------------
# FL trainer: lax.scan round fusion ≡ the per-round loop; async_lag floors
# the refreshed ages at the lag
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fl_task():
    from repro.data import partition, synthetic
    from repro.models import cnn
    spec = synthetic.DatasetSpec("t", (8, 8, 1), 4, 400, 100,
                                 noise_std=0.8, sparsity=0.1)
    (xtr, ytr), (xte, yte) = synthetic.make_dataset(spec, seed=0)
    parts = partition.dirichlet_partition(ytr, 4, 0.3, seed=0)
    params0 = cnn.init_mlp_classifier(jax.random.PRNGKey(0), 64, 4,
                                      hidden=(16,))

    def loss_fn(p, x, y):
        return cnn.softmax_xent(cnn.mlp_classifier(p, x), y)

    @jax.jit
    def eval_fn(p):
        return {"acc": cnn.accuracy(cnn.mlp_classifier(p, jnp.asarray(xte)),
                                    jnp.asarray(yte))}

    def sample_round(t):
        return partition.client_batches(xtr, ytr, parts, 8, 2, seed=100 + t)

    return params0, loss_fn, eval_fn, sample_round


def _fl_base(**kw):
    from repro.core.oac import ChannelConfig
    from repro.fl import FLConfig
    base = dict(n_clients=4, local_steps=2, batch_size=8, rounds=10,
                compression_ratio=0.1, local_lr=0.05, global_lr=0.05,
                channel=ChannelConfig(fading="rayleigh", mean=1.0,
                                      noise_std=0.1))
    base.update(kw)
    return FLConfig(**base)


def test_fl_scan_rounds_matches_loop(fl_task):
    """scan_rounds > 1 fuses rounds into one compiled lax.scan; the key
    splits inside the scan exactly as the loop splits it on the host, so
    both walk the same trajectory (same PRNG stream, same data order,
    same eval schedule)."""
    from jax.flatten_util import ravel_pytree
    from repro.fl import train
    params0, loss_fn, eval_fn, sample_round = fl_task
    h_loop = train(_fl_base(), params0, loss_fn, sample_round,
                   eval_fn=eval_fn, eval_every=5)
    h_scan = train(_fl_base(scan_rounds=4), params0, loss_fn, sample_round,
                   eval_fn=eval_fn, eval_every=5)
    assert h_loop["round"] == h_scan["round"]         # same eval schedule
    assert len(h_scan["mean_aou"]) == 10
    np.testing.assert_allclose(h_loop["mean_aou"], h_scan["mean_aou"],
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(h_loop["sel_count"], h_scan["sel_count"])
    w_loop = ravel_pytree(h_loop["params"])[0]
    w_scan = ravel_pytree(h_scan["params"])[0]
    np.testing.assert_allclose(np.asarray(w_loop), np.asarray(w_scan),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("backend", ["exact", "threshold"])
def test_fl_trainer_async_lag_age_floor(fl_task, backend):
    """With async_lag the refreshed coordinates restart at the lag, so
    once the run is past the initial ramp NO coordinate can sit at an age
    in [0, lag) — while the synchronous run always has fresh (age-0)
    coordinates after the last round."""
    from repro.fl import train
    params0, loss_fn, eval_fn, sample_round = fl_task
    lag = 3
    h_async = train(_fl_base(backend=backend, async_lag=lag, rounds=12),
                    params0, loss_fn, sample_round)
    h_sync = train(_fl_base(backend=backend, rounds=12),
                   params0, loss_fn, sample_round)
    assert float(h_async["final_age"].min()) >= lag
    assert float(h_sync["final_age"].min()) == 0.0


def test_fl_config_rejects_negative_lag(fl_task):
    from repro.fl.trainer import make_fl_step
    with pytest.raises(ValueError):
        make_fl_step(_fl_base(async_lag=-1), lambda w: w,
                     lambda p, x, y: 0.0, 64)


# ---------------------------------------------------------------------------
# FL-OAC step: the adaptive (traced-split) regime runs and carries the
# controller state
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fl_oac_adaptive_step_runs():
    from jax.flatten_util import ravel_pytree
    from repro.configs import get_config
    from repro.core import controller as budget
    from repro.data.tokens import lm_batch
    from repro.launch.steps import make_fl_oac_step
    from repro.models import transformer as tr

    mesh = jax.make_mesh((1,), ("data",))
    cfg = get_config("mamba2-370m", reduced_variant=True)
    b = make_fl_oac_step(cfg, mesh, seq_len=32, rho=0.05, adaptive_km=True)
    assert b.meta["adaptive_km"]
    params = tr.init_lm(jax.random.PRNGKey(0), cfg)
    w, _ = ravel_pytree(params)
    d, nb = b.meta["d"], b.meta["blocks"]
    g_prev = jnp.zeros((d,), jnp.float32)
    age = jnp.zeros((nb,), jnp.float32)
    ctrl = budget.controller_state_to_vec(
        budget.init_controller_state(0.75))
    toks, labels = lm_batch(0, 1, 32, cfg.vocab)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
    with mesh:
        fn = jax.jit(b.fn, in_shardings=b.in_shardings,
                     out_shardings=b.out_shardings)
        for t in range(2):
            w, g_prev, age, ctrl, loss = fn(w, g_prev, age, ctrl, batch,
                                            jnp.asarray(t, jnp.int32))
    assert np.isfinite(float(loss))
    assert ctrl.shape == (budget.CONTROLLER_STATE_SIZE,)
    cs = budget.controller_state_from_vec(ctrl)
    assert 0.0 <= float(cs["k_m_frac"]) <= 1.0
    assert float(jnp.max(age)) <= AGE_CAP
    assert float(jnp.min(age)) == 0.0                 # selected blocks reset
