"""Integration tests for the OAC-FL trainer (paper Algorithm 1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.oac import ChannelConfig
from repro.data import partition, synthetic
from repro.fl import FLConfig, train
from repro.models import cnn


@pytest.fixture(scope="module")
def task():
    spec = synthetic.DatasetSpec("t", (8, 8, 1), 4, 1200, 300,
                                 noise_std=0.8, sparsity=0.1)
    (xtr, ytr), (xte, yte) = synthetic.make_dataset(spec, seed=0)
    parts = partition.dirichlet_partition(ytr, 8, 0.3, seed=0)
    params0 = cnn.init_mlp_classifier(jax.random.PRNGKey(0), 64, 4,
                                      hidden=(32,))

    def loss_fn(p, x, y):
        return cnn.softmax_xent(cnn.mlp_classifier(p, x), y)

    @jax.jit
    def eval_fn(p):
        return {"acc": cnn.accuracy(cnn.mlp_classifier(p, jnp.asarray(xte)),
                                    jnp.asarray(yte))}

    def sample_round(t):
        return partition.client_batches(xtr, ytr, parts, 10, 3, seed=100 + t)

    return params0, loss_fn, eval_fn, sample_round


def _run(task, policy, rounds=80, **kw):
    params0, loss_fn, eval_fn, sample_round = task
    kw.setdefault("local_lr", 0.05)
    kw.setdefault("global_lr", 0.05)
    fl = FLConfig(n_clients=8, local_steps=3, batch_size=10, rounds=rounds,
                  policy=policy, compression_ratio=0.1,
                  channel=ChannelConfig(fading="rayleigh", mean=1.0,
                                        noise_std=0.1), **kw)
    return train(fl, params0, loss_fn, sample_round, eval_fn=eval_fn,
                 eval_every=rounds)


def test_fairk_learns(task):
    h = _run(task, "fairk")
    assert h["acc"][-1] > 0.45, h["acc"]          # chance = 0.25


def test_fairk_beats_topk(task):
    """Fig. 4's headline: FAIR-k converges much faster than Top-k."""
    h_fair = _run(task, "fairk")
    h_top = _run(task, "topk")
    assert h_fair["acc"][-1] > h_top["acc"][-1] + 0.1


def test_fairk_lower_staleness_than_toprand(task):
    """Fig. 5a: FAIR-k roughly halves the average AoU vs TopRand."""
    h_fair = _run(task, "fairk", rounds=80)
    h_rand = _run(task, "toprand", rounds=80)
    assert np.mean(h_fair["mean_aou"][40:]) < 0.75 * np.mean(
        h_rand["mean_aou"][40:])

def test_topk_starves_entries(task):
    """Fig. 5b: under Top-k most entries are never selected."""
    h = _run(task, "topk", rounds=40)
    frac_never = (h["sel_count"] == 0).mean()
    assert frac_never > 0.5


def test_fairk_covers_all_entries(task):
    """FAIR-k's age stage guarantees every entry is eventually refreshed."""
    d = len(_run(task, "fairk", rounds=2)["sel_count"])
    k, k_m, _ = FLConfig(compression_ratio=0.1).budgets(d)
    T = -(-(d - k_m) // (k - k_m))
    h = _run(task, "fairk", rounds=T + 5)
    assert (h["sel_count"] > 0).all()
    assert h["max_aou"][-1] <= T


def test_one_bit_mode_runs(task):
    h = _run(task, "fairk", rounds=40, one_bit=True,
             global_lr=0.002)
    assert np.isfinite(h["acc"][-1])
    assert h["acc"][-1] > 0.3


def test_budgets():
    fl = FLConfig(compression_ratio=0.1, k_m_frac=0.75)
    k, k_m, r = fl.budgets(1000)
    assert (k, k_m, r) == (100, 75, 150)
    assert FLConfig(policy="topk").budgets(1000)[1] == 100
    assert FLConfig(policy="roundrobin").budgets(1000)[1] == 0


def test_threshold_backend_learns(task):
    """FLConfig(backend="threshold") — the engine's fused d>>1e7 server
    route — must train, keep every coordinate participating, and track the
    rho budget (approximately: thresholds, not exact top-k)."""
    h = _run(task, "fairk", backend="threshold")
    assert np.isfinite(h["acc"][-1])
    assert h["acc"][-1] > 0.5
    assert (h["sel_count"] > 0).mean() > 0.95
    # per-round selected fraction ~ rho (sel_count sums dense masks)
    frac = h["sel_count"].sum() / (h["sel_count"].shape[0] * 80)
    assert 0.05 < frac < 0.2, frac


def test_all_backends_accept_onebit_and_ef():
    """Regression: one_bit / error_feedback used to raise on the
    threshold/packed backends (trainer.py hard gate) — now every backend
    builds; only unknown backends are rejected."""
    from repro.fl import make_fl_step
    for backend in ("exact", "threshold", "packed"):
        make_fl_step(FLConfig(backend=backend, one_bit=True,
                              error_feedback=True),
                     lambda w: w, lambda p, x, y: 0.0, 16)
    with pytest.raises(ValueError):
        make_fl_step(FLConfig(backend="sharded"), lambda w: w,
                     lambda p, x, y: 0.0, 16)


def test_threshold_backend_error_feedback_learns(task):
    """Server-side EF on the fused threshold route trains and keeps the
    rho budget (the residual folds back through the fused kernel pass)."""
    h = _run(task, "fairk", rounds=60, backend="threshold",
             error_feedback=True)
    assert np.isfinite(h["acc"][-1])
    assert h["acc"][-1] > 0.45
    frac = h["sel_count"].sum() / (h["sel_count"].shape[0] * 60)
    assert 0.05 < frac < 0.2, frac


def test_packed_backend_one_bit_learns(task):
    """FSK-MV one-bit uplink on the packed backend: sign_mv majority votes
    merge through the fused pass; vote-energy scoring keeps the budget."""
    h = _run(task, "fairk", rounds=40, backend="packed", one_bit=True,
             global_lr=0.002)
    assert np.isfinite(h["acc"][-1])
    assert h["acc"][-1] > 0.3
    frac = h["sel_count"].sum() / (h["sel_count"].shape[0] * 40)
    assert 0.04 < frac < 0.25, frac


def test_one_bit_threshold_noiseless_keeps_budget(task):
    """Regression: noiseless vote energies take ~N/2 discrete values, so a
    quantile threshold inside a tie level used to select the whole level
    and blow the rho budget — the index-jitter tie-break keeps it."""
    params0, loss_fn, eval_fn, sample_round = task
    fl = FLConfig(n_clients=8, local_steps=3, batch_size=10, rounds=20,
                  policy="fairk", compression_ratio=0.1,
                  backend="threshold", one_bit=True,
                  local_lr=0.05, global_lr=0.002,
                  channel=ChannelConfig(fading="none", mean=1.0,
                                        noise_std=0.0))
    h = train(fl, params0, loss_fn, sample_round, eval_fn=eval_fn,
              eval_every=20)
    frac = h["sel_count"].sum() / (h["sel_count"].shape[0] * 20)
    assert 0.05 < frac < 0.2, frac


def test_error_feedback_improves_fairk(task):
    """Beyond-paper: EF composes with FAIR-k (+acc) but cannot fix Top-k's
    selection starvation (EF changes what is sent, not what is selected)."""
    h_ef = _run(task, "fairk", error_feedback=True)
    h_no = _run(task, "fairk")
    assert h_ef["acc"][-1] >= h_no["acc"][-1] - 0.02
    h_topk_ef = _run(task, "topk", error_feedback=True)
    assert h_topk_ef["acc"][-1] < h_ef["acc"][-1] - 0.1
