"""End-to-end behaviour tests: the paper's headline claims reproduced on
small synthetic settings (relative orderings, not absolute accuracies —
DESIGN.md §7 data gate)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import markov
from repro.core.oac import ChannelConfig
from repro.data import partition, synthetic
from repro.fl import FLConfig, train
from repro.models import cnn

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def results():
    """One FL sweep over all headline policies, shared across asserts."""
    spec = synthetic.DatasetSpec("sys", (12, 12, 1), 6, 2400, 400,
                                 noise_std=0.8, sparsity=0.08)
    (xtr, ytr), (xte, yte) = synthetic.make_dataset(spec, seed=0)
    parts = partition.dirichlet_partition(ytr, 10, 0.3, seed=0)
    params0 = cnn.init_mlp_classifier(jax.random.PRNGKey(0), 144, 6,
                                      hidden=(48,))

    def loss_fn(p, x, y):
        return cnn.softmax_xent(cnn.mlp_classifier(p, x), y)

    @jax.jit
    def eval_fn(p):
        return {"acc": cnn.accuracy(cnn.mlp_classifier(p, jnp.asarray(xte)),
                                    jnp.asarray(yte))}

    def sample_round(t):
        return partition.client_batches(xtr, ytr, parts, 10, 3, seed=500 + t)

    out = {}
    for policy in ("fairk", "topk", "toprand", "agetopk"):
        fl = FLConfig(n_clients=10, local_steps=3, batch_size=10, rounds=80,
                      policy=policy, compression_ratio=0.1,
                      channel=ChannelConfig(fading="rayleigh", mean=1.0,
                                            noise_std=0.2))
        out[policy] = train(fl, params0, loss_fn, sample_round,
                            eval_fn=eval_fn, eval_every=80)
    return out


def test_fig4_policy_ordering(results):
    """FAIR-k beats Top-k and AgeTop-k decisively and >= TopRand (Fig. 4)."""
    acc = {p: h["acc"][-1] for p, h in results.items()}
    assert acc["fairk"] > acc["topk"] + 0.1, acc
    assert acc["fairk"] > acc["agetopk"] + 0.1, acc
    assert acc["fairk"] >= acc["toprand"] - 0.03, acc


def test_fig5a_aou_ordering(results):
    """Average AoU: FAIR-k < TopRand < Top-k (Fig. 5a)."""
    mean_aou = {p: np.mean(h["mean_aou"][40:]) for p, h in results.items()}
    assert mean_aou["fairk"] < mean_aou["toprand"] < mean_aou["topk"], mean_aou


def test_fig5b_participation(results):
    """FAIR-k broadens participation; Top-k starves most entries (Fig. 5b)."""
    assert (results["fairk"]["sel_count"] > 0).mean() > 0.95
    assert (results["topk"]["sel_count"] == 0).mean() > 0.5


def test_theorem1_staleness_term():
    """E[tau] from Lemma 1 falls as the age budget k_A grows — the residual
    error term eta*L_g*E[tau]*G^2*H^2 in Theorem 1 shrinks accordingly."""
    es = [markov.expected_staleness(markov.FairKChain(d=400, k=40, k_m=km,
                                                      k0=5))
          for km in (30, 20, 10)]
    assert es[0] > es[1] > es[2]
