"""Distributed tests: run in subprocesses with 8 placeholder host devices
(the main pytest process must keep the real single-device view)."""

import json
import os
import subprocess
import sys

import pytest

pytestmark = [pytest.mark.sharded, pytest.mark.slow]

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run_sub(code: str, timeout=560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_train_step_runs_and_learns_sharded():
    out = _run_sub(r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.launch.steps import make_train_step, init_server_state
from repro.models import transformer as tr
from repro.optim import make_optimizer
from repro.data.tokens import lm_batch

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("qwen2.5-32b", reduced_variant=True)
shape = InputShape("t", 128, 8, "train")
bundle = make_train_step(cfg, shape, mesh)
params = tr.init_lm(jax.random.PRNGKey(0), cfg)
opt = make_optimizer(bundle.meta["optimizer"], 3e-3)
opt_state = opt.init(params)
server = init_server_state(params, mesh=mesh, cfg=cfg)
step = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
               out_shardings=bundle.out_shardings)
nm = bundle.meta["n_micro"]
losses = []
with mesh:
    for t in range(25):
        toks, labels = lm_batch(t % 3, 8, 128, cfg.vocab)  # few repeated batches
        batch = {"tokens": jnp.asarray(toks).reshape(nm, 8 // nm, 128),
                 "labels": jnp.asarray(labels).reshape(nm, 8 // nm, 128)}
        params, opt_state, server, loss = step(params, opt_state, server,
                                               batch, jnp.asarray(t, jnp.int32))
        losses.append(float(loss))
# persisted packed server state: flat int8 age buffer, PAD_AGE (-1) pads
ages = np.concatenate([np.asarray(a).ravel()
                       for a in jax.tree.leaves(server["age"])])
valid = ages >= 0
print(json.dumps({"first": losses[0], "last": losses[-1],
                  "frac_fresh": float((ages[valid] == 0).mean()),
                  "max_age": int(ages.max())}))
""")
    res = json.loads(out.strip().splitlines()[-1])
    assert res["last"] < res["first"] - 0.05, res
    assert 0.05 < res["frac_fresh"] < 0.35, res   # rho = 0.1 target
    assert res["max_age"] <= 25, res


def test_decode_parity_sharded_vs_single():
    """serve_step on the mesh must match the unsharded decode."""
    out = _run_sub(r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.launch.steps import make_serve_step
from repro.models import transformer as tr

errs = {}
for name in ("qwen2.5-32b", "mamba2-370m", "granite-moe-3b-a800m"):
    cfg = get_config(name, reduced_variant=True)
    params = tr.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (8, 1)).astype("i4"))
    caches = tr.init_caches(cfg, 8, capacity=64)
    ref_logits, _ = tr.decode_step(params, cfg, toks, jnp.asarray(0), caches)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    bundle = make_serve_step(cfg, InputShape("d", 64, 8, "decode"), mesh)
    with mesh:
        step = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                       out_shardings=bundle.out_shardings)
        caches2 = tr.init_caches(cfg, 8, capacity=64)
        sh_logits, _ = step(params, caches2, toks, jnp.asarray(0, jnp.int32))
    errs[name] = float(np.abs(np.asarray(ref_logits, np.float32)
                              - np.asarray(sh_logits, np.float32)).max())
print(json.dumps(errs))
""")
    res = json.loads(out.strip().splitlines()[-1])
    assert res["qwen2.5-32b"] < 0.05, res
    assert res["mamba2-370m"] < 0.05, res
    # MoE: bf16 resharding can flip near-tie router top-k picks -> looser
    assert res["granite-moe-3b-a800m"] < 0.5, res


def test_fl_oac_collective_reduction():
    """The FL-OAC step's all-reduce volume must be ~rho of the baseline's
    (the paper's waveform-budget saving, measured in the compiled HLO)."""
    out = _run_sub(r"""
import jax, json
from repro.configs import get_config
from repro.launch.steps import make_fl_oac_step
from repro.roofline import analyze_hlo

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("mamba2-370m", reduced_variant=True)
res = {}
for base in (False, True):
    b = make_fl_oac_step(cfg, mesh, seq_len=64, rho=0.1, baseline=base)
    with mesh:
        c = jax.jit(b.fn, in_shardings=b.in_shardings,
                    out_shardings=b.out_shardings).lower(*b.input_specs).compile()
    res["base" if base else "fairk"] = analyze_hlo(
        c.as_text())["collective_bytes_per_device"]
print(json.dumps(res))
""")
    res = json.loads(out.strip().splitlines()[-1])
    ratio = res["fairk"] / res["base"]
    assert ratio < 0.2, res      # rho=0.1 plus small fixed overheads


def test_fl_oac_step_executes():
    """Run two FL-OAC rounds for real on the 8-device mesh."""
    out = _run_sub(r"""
import jax, jax.numpy as jnp, numpy as np, json
from jax.flatten_util import ravel_pytree
from repro.configs import get_config
from repro.launch.steps import make_fl_oac_step
from repro.models import transformer as tr
from repro.data.tokens import lm_batch

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("mamba2-370m", reduced_variant=True)
b = make_fl_oac_step(cfg, mesh, seq_len=64, rho=0.1)
params = tr.init_lm(jax.random.PRNGKey(0), cfg)
w, _ = ravel_pytree(params)
d = b.meta["d"]; nb = b.meta["blocks"]
g_prev = jnp.zeros((d,), jnp.float32)
age = jnp.zeros((nb,), jnp.float32)
with mesh:
    fn = jax.jit(b.fn, in_shardings=b.in_shardings,
                 out_shardings=b.out_shardings)
    losses = []
    for t in range(3):
        toks, labels = lm_batch(t, 8, 64, cfg.vocab)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        w, g_prev, age, loss = fn(w, g_prev, age, batch,
                                  jnp.asarray(t, jnp.int32))
        losses.append(float(loss))
frac_fresh = float((np.asarray(age) == 0).mean())
print(json.dumps({"losses": losses, "frac_fresh": frac_fresh,
                  "kb_over_nb": b.meta["kb"] / nb}))
""")
    res = json.loads(out.strip().splitlines()[-1])
    assert all(np.isfinite(l) for l in res["losses"])
    # after a round, ~rho of blocks are fresh (age 0)
    assert abs(res["frac_fresh"] - res["kb_over_nb"]) < 0.05


def test_engine_sharded_parity_multi_device():
    """SelectionEngine sharded backend on a REAL 8-device mesh: must match
    the exact backend on tie-free ages, and must match the single-device
    threshold backend bit-exactly even under heavy integer-age ties (the
    global-index jitter property a 1-device parity test cannot see)."""
    out = _run_sub(r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.core.engine import EngineConfig, SelectionEngine

d = 4096
rng = np.random.default_rng(0)
g = jnp.asarray(rng.normal(size=d).astype("f4"))
gp = jnp.asarray(rng.normal(size=d).astype("f4"))
common = dict(policy="fairk", rho=0.1, k_m_frac=0.75, exact_theta=True)
mesh = jax.make_mesh((8,), ("shard",))
ex = SelectionEngine(EngineConfig(backend="exact", **common), d)
th = SelectionEngine(EngineConfig(backend="threshold", **common), d)
sh = SelectionEngine(EngineConfig(backend="sharded", **common), d,
                     mesh=mesh)
out = {}
# (a) tie-free ages: sharded == exact (the documented parity guarantee)
age = jnp.asarray(rng.permutation(d).astype("f4"))
g1, a1, _ = jax.jit(ex.select_and_merge)(g, gp, age)
with mesh:
    g2, a2, _ = jax.jit(sh.select_and_merge)(g, gp, age)
out["exact_mismatch"] = int((np.asarray(g1) != np.asarray(g2)).sum()
                            + (np.asarray(a1) != np.asarray(a2)).sum())
# (b) heavy ties: sharded == threshold (same global-index jitter)
age_t = jnp.asarray(rng.integers(0, 8, d).astype("f4"))
g3, a3, s3 = th.select_and_merge(g, gp, age_t)
with mesh:
    g4, a4, s4 = jax.jit(sh.select_and_merge)(g, gp, age_t)
out["thresh_mismatch"] = int((np.asarray(g3) != np.asarray(g4)).sum()
                             + (np.asarray(a3) != np.asarray(a4)).sum())
out["n_thresh"] = float(s3["n_selected"])
out["n_sharded"] = float(s4["n_selected"])
print(json.dumps(out))
""")
    res = json.loads(out.strip().splitlines()[-1])
    assert res["exact_mismatch"] == 0, res
    assert res["thresh_mismatch"] == 0, res
    assert res["n_thresh"] == res["n_sharded"], res


import numpy as np  # noqa: E402  (used in asserts above)
