"""Pallas kernels: interpret-mode execution vs pure-jnp oracles, swept over
shapes and dtypes (per the kernel-validation requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


def _arr(shape, dtype, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32)).astype(dtype)


class TestBlockTopK:
    @pytest.mark.parametrize("d,block,m", [
        (4096, 512, 4), (8192, 1024, 8), (16384, 4096, 16), (2048, 2048, 32),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, d, block, m, dtype):
        x = _arr((d,), dtype, seed=d + m)
        v_ker, i_ker = ops.block_topk(x, block, m, mode="interpret")
        v_ref, i_ref = ref.block_topk_ref(x.astype(jnp.float32), block, m)
        np.testing.assert_allclose(np.asarray(v_ker), np.asarray(v_ref),
                                   rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(i_ker), np.asarray(i_ref))

    def test_two_stage_exact_when_pool_sufficient(self):
        x = _arr((8192,), jnp.float32, seed=7)
        tv, ti = ops.two_stage_topk(x, k=64, block_size=1024, mode="interpret")
        ev, _ = jax.lax.top_k(jnp.abs(x), 64)
        np.testing.assert_allclose(np.sort(np.asarray(tv)),
                                   np.sort(np.asarray(ev)), rtol=1e-6)

    def test_indices_point_at_values(self):
        x = _arr((4096,), jnp.float32, seed=9)
        vals, idxs = ops.block_topk(x, 512, 8, mode="interpret")
        np.testing.assert_allclose(
            np.asarray(vals).ravel(),
            np.abs(np.asarray(x))[np.asarray(idxs).ravel()], rtol=1e-6)


class TestAouMerge:
    @pytest.mark.parametrize("d,block", [(8192, 1024), (65536, 65536),
                                         (4096, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, d, block, dtype):
        rng = np.random.default_rng(d)
        g_new = _arr((d,), dtype, 1)
        g_old = _arr((d,), dtype, 2)
        age = jnp.asarray(rng.integers(0, 40, d).astype("f4"))
        mask = jnp.asarray((rng.random(d) < 0.1).astype("f4"))
        g_k, a_k = ops.aou_merge(g_new, g_old, age, mask, mode="interpret")
        g_r, a_r = ref.aou_merge_ref(g_new.astype(jnp.float32),
                                     g_old.astype(jnp.float32), age, mask)
        np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(a_k), np.asarray(a_r), rtol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(d=st.sampled_from([256, 1024, 4096]), seed=st.integers(0, 99))
    def test_property_merge_partition(self, d, seed):
        """Selected coords get g_new and age 0; others keep g_old, age+1."""
        rng = np.random.default_rng(seed)
        g_new = jnp.asarray(rng.normal(size=d).astype("f4"))
        g_old = jnp.asarray(rng.normal(size=d).astype("f4"))
        age = jnp.asarray(rng.integers(0, 30, d).astype("f4"))
        mask = jnp.asarray((rng.random(d) < 0.2).astype("f4"))
        g, a = ops.aou_merge(g_new, g_old, age, mask, mode="interpret")
        g, a, m = np.asarray(g), np.asarray(a), np.asarray(mask).astype(bool)
        np.testing.assert_allclose(g[m], np.asarray(g_new)[m], rtol=1e-6)
        np.testing.assert_allclose(g[~m], np.asarray(g_old)[~m], rtol=1e-6)
        np.testing.assert_allclose(a[m], 0.0)
        np.testing.assert_allclose(a[~m], np.asarray(age)[~m] + 1)


class TestSignMV:
    @pytest.mark.parametrize("n,k", [(5, 2048), (21, 4096), (50, 1024),
                                     (2, 8192)])
    def test_matches_oracle(self, n, k):
        rng = np.random.default_rng(n * k)
        votes = jnp.asarray(np.sign(rng.normal(size=(n, k))).astype("f4"))
        signs_k, energy_k = ops.sign_mv(votes, mode="interpret")
        signs_r, energy_r = ref.sign_mv_ref(votes)
        np.testing.assert_array_equal(np.asarray(signs_k),
                                      np.asarray(signs_r))
        np.testing.assert_array_equal(np.asarray(energy_k),
                                      np.asarray(energy_r))
        # the energy IS the superposed vote sum — no second reduction
        np.testing.assert_array_equal(np.asarray(energy_k),
                                      np.asarray(votes.sum(axis=0)))

    @pytest.mark.parametrize("mode", ["ref", "interpret"])
    def test_noisy_energy_consistency(self, mode):
        """With channel noise the energy is perturbed BEFORE the sign
        (Sec. V-B non-coherent detection): signs == sign(energy) and
        energy == clean vote sum + noise, kernel == oracle."""
        rng = np.random.default_rng(7)
        votes = jnp.asarray(np.sign(rng.normal(size=(9, 1024))).astype("f4"))
        noise = jnp.asarray((3.0 * rng.normal(size=1024)).astype("f4"))
        signs, energy = ops.sign_mv(votes, noise=noise, mode=mode)
        signs_r, energy_r = ref.sign_mv_ref(votes, noise)
        np.testing.assert_allclose(np.asarray(energy),
                                   np.asarray(votes.sum(0) + noise),
                                   rtol=1e-6)
        np.testing.assert_array_equal(
            np.asarray(signs), np.where(np.asarray(energy) >= 0, 1.0, -1.0))
        np.testing.assert_array_equal(np.asarray(signs),
                                      np.asarray(signs_r))
        np.testing.assert_allclose(np.asarray(energy),
                                   np.asarray(energy_r), rtol=1e-6)

    def test_majority_semantics(self):
        votes = jnp.asarray(np.vstack([np.ones((3, 128)),
                                       -np.ones((2, 128))]).astype("f4"))
        signs, energy = ops.sign_mv(votes, mode="interpret")
        np.testing.assert_array_equal(np.asarray(signs), 1.0)
        np.testing.assert_array_equal(np.asarray(energy), 1.0)  # 3 - 2

    @pytest.mark.parametrize("mode", ["ref", "interpret"])
    @pytest.mark.parametrize("noisy", [False, True])
    def test_sign_from_energy_matches_sign_mv(self, mode, noisy):
        """The streaming one-bit fold pre-reduces the votes chunk by chunk
        and detects on the (k,) energy row: sign_from_energy on the summed
        votes must match sign_mv on the full matrix bit for bit."""
        rng = np.random.default_rng(13)
        votes = jnp.asarray(np.sign(rng.normal(size=(9, 2048)) + 0.05)
                            .astype("f4"))
        noise = (jnp.asarray((2.0 * rng.normal(size=2048)).astype("f4"))
                 if noisy else None)
        signs_d, energy_d = ops.sign_mv(votes, noise=noise, mode=mode)
        signs_s, energy_s = ops.sign_from_energy(votes.sum(axis=0),
                                                 noise=noise, mode=mode)
        np.testing.assert_array_equal(np.asarray(signs_d),
                                      np.asarray(signs_s))
        np.testing.assert_array_equal(np.asarray(energy_d),
                                      np.asarray(energy_s))

    def test_sign_from_energy_odd_length_falls_back(self):
        # k with no aligned block divisor exercises the block_k == k path
        energy = jnp.asarray(np.linspace(-3, 3, 771).astype("f4"))
        signs, e = ops.sign_from_energy(energy, mode="interpret")
        signs_r, e_r = ref.sign_from_energy_ref(energy)
        np.testing.assert_array_equal(np.asarray(signs),
                                      np.asarray(signs_r))
        np.testing.assert_array_equal(np.asarray(e), np.asarray(e_r))


class TestFairKUpdate:
    @pytest.mark.parametrize("d,block", [(8192, 1024), (65536, 65536),
                                         (16384, 4096)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, d, block, dtype):
        rng = np.random.default_rng(d)
        g = _arr((d,), dtype, 11)
        gp = _arr((d,), dtype, 12)
        age = jnp.asarray(rng.integers(0, 40, d).astype("f4"))
        tm, ta = jnp.float32(1.2), jnp.float32(33.7)
        out_k = ops.fairk_update(g, gp, age, tm, ta, mode="interpret")
        out_r = ref.fairk_update_ref(g.astype(jnp.float32),
                                     gp.astype(jnp.float32), age, tm, ta)
        for a, b in zip(out_k, out_r):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6)

    def test_selected_fraction_tracks_thresholds(self):
        """With theta_M at the (1-rho_m) quantile and theta_A sized for the
        rest, the fused update refreshes ~rho of coordinates."""
        rng = np.random.default_rng(0)
        d = 1 << 16
        g = jnp.asarray(rng.normal(size=d).astype("f4"))
        gp = jnp.zeros((d,), jnp.float32)
        age = jnp.asarray(rng.integers(0, 40, d).astype("f4"))
        rho, km = 0.1, 0.75
        tm = jnp.quantile(jnp.abs(g), 1 - rho * km)
        ta = jnp.quantile(age + 0.5, 1 - rho * (1 - km) / (1 - rho * km))
        g_t, age_next = ops.fairk_update(g, gp, age, tm, ta,
                                         mode="interpret")
        frac_fresh = float((np.asarray(age_next) == 0).mean())
        assert abs(frac_fresh - rho) < 0.03
