"""Population-scale client simulator (core/population.py, DESIGN.md §15).

Pins the tentpole end to end:

* config validation and the derived chain/thinning algebra;
* stationarity of all three availability modes (iid, Gilbert–Elliott
  bursts with the right down-dwell, the diurnal wave pinned at the right
  time-average);
* cohort-layout determinism — the same seed produces bit-identical
  availability/participation/churn traces whatever ``cohort_size`` packs
  the grid;
* churn-erase-mask block semantics and the participation stats contract;
* the stateless launch-path round (memoryless modes only, reproducible,
  stationary);
* the Sec. IV validation suite: the empirical post-update staleness pmf
  of an engine fed population-churn erasures matches the
  participation-thinned Lemma-1 prediction
  (``markov.population_aou_distribution``) within TV < 0.1 on the exact
  AND packed backends (via ``tests/statutil.py``);
* FL-trainer and launch-config wiring (validation + a fused
  ``scan_rounds`` chaos-style run), and the ``population``-marked
  1e5-client compiled-scan smokes.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import statutil
from repro.core import faults, markov, packing, population
from repro.core.engine import make_engine
from repro.core.population import PAD, PopulationConfig


def _cfg(**kw):
    base = dict(n_clients=1000, cohort_size=256, participants=8, avail=0.9)
    base.update(kw)
    return PopulationConfig(**base)


# ---------------------------------------------------------------------------
# config validation + derived algebra
# ---------------------------------------------------------------------------

def test_population_config_validates():
    for bad in (dict(n_clients=0), dict(cohort_size=0),
                dict(participants=0), dict(participants=1001),
                dict(avail=0.0), dict(avail=1.2), dict(mode="lunar"),
                dict(mode="ge", burst=0.5),
                dict(mode="ge", avail=0.1, burst=2.0),   # needs burst >= 9
                dict(mode="diurnal", period=1),
                dict(mode="diurnal", depth=-0.1),
                dict(mode="diurnal", avail=0.95, depth=0.2),  # peak > 1
                dict(slow_frac=1.0), dict(exposure=0.0),
                dict(erase_block=0)):
        with pytest.raises(ValueError):
            _cfg(**bad)


def test_population_config_derived():
    cfg = _cfg(n_clients=1000, cohort_size=256)
    assert cfg.n_cohorts == 4 and cfg.n_padded == 1024
    assert _cfg(n_clients=1024, cohort_size=256).n_padded == 1024
    # iid vanish rate is the miss rate; bursts slow mid-round churn down
    assert _cfg(avail=0.8).vanish_rate == pytest.approx(0.2)
    assert _cfg(avail=0.8, mode="ge", burst=8.0).vanish_rate == \
        pytest.approx(0.2 / (0.8 * 8.0))
    cfg = _cfg(avail=0.75, participants=4, exposure=0.5)
    assert cfg.thin == pytest.approx(0.5 * 0.25 + 0.25 ** 4)
    assert cfg.thin == markov.population_thin(0.75, cfg.vanish_rate, 4, 0.5)


def test_transition_probs_stationary():
    cfg = _cfg(avail=0.8, mode="ge", burst=8.0)
    p_gb, p_bg = population.transition_probs(cfg)
    assert p_bg == pytest.approx(1.0 / 8.0)
    assert p_gb / (p_gb + p_bg) == pytest.approx(0.2)   # pi_down
    p_gb, p_bg = population.transition_probs(_cfg(avail=0.8))
    assert (p_gb, p_bg) == (pytest.approx(0.2), pytest.approx(0.8))


# ---------------------------------------------------------------------------
# packed state + chain stationarity
# ---------------------------------------------------------------------------

def test_init_state_pads_and_stationary_draw():
    cfg = _cfg(n_clients=100, cohort_size=64, avail=0.9)
    st = population.init_population_state(jax.random.PRNGKey(0), cfg)
    assert st["avail"].shape == (2, 64) and st["avail"].dtype == jnp.int8
    flat = np.asarray(st["avail"]).reshape(-1)
    assert (flat[100:] == PAD).all()
    assert set(np.unique(flat[:100])) <= {0, 1}


@pytest.mark.parametrize("mode", ["iid", "ge", "diurnal"])
def test_chain_stationarity(mode):
    """Each availability mode holds its stationary rate: the live-client
    fraction over a 300-round compiled scan stays within 2% of ``avail``
    (seeded run; the binomial noise floor at n=4096 is ~0.5%)."""
    kw = dict(burst=6.0) if mode == "ge" else {}
    cfg = _cfg(n_clients=4096, cohort_size=1024, avail=0.8, mode=mode, **kw)
    _, tr = population.population_scan_jit(cfg, 300, jax.random.PRNGKey(3))
    frac = np.asarray(tr["n_avail"]) / cfg.n_clients
    assert abs(float(frac.mean()) - 0.8) < 0.02
    if mode == "diurnal":
        # the wave actually swings (plus/minus depth around the mean)...
        assert float(frac.min()) < 0.8 - 0.05
        assert float(frac.max()) > 0.8 + 0.05
        rate = np.asarray(tr["rate"])
        assert float(rate.min()) == pytest.approx(0.8 * 0.9, abs=1e-3)
        assert float(rate.max()) == pytest.approx(0.8 * 1.1, abs=1e-3)


def test_ge_bursts_have_the_right_dwell():
    """Gilbert–Elliott memory: a down client stays down with probability
    1 - 1/burst, so the empirical down->down rate over many rounds pins
    the dwell (iid would give 1 - avail = 0.2 instead)."""
    cfg = _cfg(n_clients=2048, cohort_size=512, avail=0.8, mode="ge",
               burst=8.0)
    step = jax.jit(population.population_step, static_argnums=2)
    st = population.init_population_state(jax.random.PRNGKey(1), cfg)
    stay, downs = 0.0, 0.0
    for r in range(100):
        nxt = step(st, jax.random.fold_in(jax.random.PRNGKey(2), r), cfg)
        down = np.asarray(st["avail"]).reshape(-1)[:cfg.n_clients] == 0
        nxt_down = np.asarray(nxt["avail"]).reshape(-1)[:cfg.n_clients] == 0
        downs += down.sum()
        stay += (down & nxt_down).sum()
        st = nxt
    assert abs(stay / downs - (1.0 - 1.0 / 8.0)) < 0.02


def test_cohort_layout_determinism():
    """THE packing contract: bit-identical traces whatever cohort_size
    the host picked — availability, participation, churn, and the final
    per-client availability grid."""
    traces, finals = [], []
    for cs in (64, 333, 1024):
        cfg = _cfg(n_clients=1000, cohort_size=cs, avail=0.85,
                   participants=16)
        fin, tr = population.population_scan_jit(cfg, 50,
                                                 jax.random.PRNGKey(9))
        traces.append({k: np.asarray(v) for k, v in tr.items()})
        finals.append(np.asarray(fin["avail"]).reshape(-1)[:1000])
    for other, fin in zip(traces[1:], finals[1:]):
        for k in traces[0]:
            np.testing.assert_array_equal(traces[0][k], other[k], err_msg=k)
        np.testing.assert_array_equal(finals[0], fin)


def test_client_jitter_static_propensity():
    ids = jnp.arange(100_000)
    j = np.asarray(population.client_jitter(ids))
    assert ((0.0 <= j) & (j < 1.0)).all()
    np.testing.assert_array_equal(
        j, np.asarray(population.client_jitter(ids)))   # trace-static
    assert abs(float((j < 0.3).mean()) - 0.3) < 0.01    # uniform-ish hash


# ---------------------------------------------------------------------------
# round-level effects
# ---------------------------------------------------------------------------

def test_churn_erase_mask_block_semantics():
    cfg = _cfg(erase_block=16, exposure=1.0)
    key = jax.random.PRNGKey(4)
    zero = np.asarray(population.churn_erase_mask(key, 96, jnp.float32(0.0),
                                                  cfg))
    assert (zero == 0.0).all()
    one = np.asarray(population.churn_erase_mask(key, 96, jnp.float32(1.0),
                                                 cfg))
    assert (one == 1.0).all()
    # blocks erase as units; a ragged tail block still fills to d
    m = np.asarray(population.churn_erase_mask(key, 100, jnp.float32(0.5),
                                               cfg))
    assert m.shape == (100,)
    assert all(len(set(m[i:i + 16])) == 1 for i in range(0, 96, 16))


def test_population_round_stats_contract():
    cfg = _cfg(n_clients=2048, cohort_size=512, avail=0.75,
               participants=32, slow_frac=0.5)
    st = population.init_population_state(jax.random.PRNGKey(5), cfg)
    rnd = jax.jit(population.population_round, static_argnums=2)
    slow_seen = 0.0
    for r in range(20):
        st, ps = rnd(st, jax.random.fold_in(jax.random.PRNGKey(6), r), cfg)
        part = np.asarray(ps["part"])
        assert part.shape == (32,) and set(np.unique(part)) <= {0.0, 1.0}
        assert float(ps["n_t"]) == part.sum() <= 32
        assert 0.0 <= float(ps["churn"]) <= 1.0
        assert 0.0 <= float(ps["slow_share"]) <= 1.0
        slow_seen += float(ps["slow"].sum())
    assert slow_seen > 0.0                      # half the ids are slow


def test_stateless_round_contract():
    with pytest.raises(ValueError, match="stateless"):
        population.stateless_round(jax.random.PRNGKey(0), 3,
                                   _cfg(mode="ge", burst=8.0))
    cfg = _cfg(n_clients=4096, cohort_size=1024, avail=0.8,
               participants=16)
    key = jax.random.PRNGKey(7)
    a = population.stateless_round(key, 5, cfg)
    b = population.stateless_round(key, 5, cfg)
    np.testing.assert_array_equal(np.asarray(a["part"]),
                                  np.asarray(b["part"]))
    # stationary across the counter-based trajectory
    n_av = np.array([float(population.stateless_round(key, t, cfg)
                           ["n_avail"]) for t in range(60)])
    assert abs(n_av.mean() / cfg.n_clients - 0.8) < 0.02


# ---------------------------------------------------------------------------
# acceptance: empirical staleness pmf == participation-thinned Lemma 1
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["exact", "packed"])
def test_population_pmf_matches_thinned_lemma1(backend):
    """Sec. IV validation: drive FAIR-k with the erasure stream an actual
    population produces (per-round churn from a compiled availability
    scan, block erasures at ``exposure * churn``, whole-round outage when
    the sampled cohort is empty) and compare the stationary post-update
    age pmf against ``markov.population_aou_distribution`` — the same
    TV < 0.1 bar as the sync/async/thinned laws (seeded run, see
    tests/statutil.py)."""
    d, k, k_m = 512, 64, 32
    cfg = _cfg(n_clients=2048, cohort_size=512, participants=32,
               avail=0.75, exposure=0.5, erase_block=8)
    _, tr = population.population_scan_jit(cfg, 600, jax.random.PRNGKey(11))
    churn = np.asarray(tr["churn"])
    n_t = np.asarray(tr["n_t"])
    erng = np.random.default_rng(7)
    nb = -(-d // cfg.erase_block)

    def erase_fn(r):
        hit = (erng.random(nb) < cfg.exposure * churn[r]).astype("f4")
        mask = np.repeat(hit, cfg.erase_block)[:d]
        return np.ones(d, "f4") if n_t[r] == 0 else mask

    if backend == "packed":
        eng = make_engine("fairk", "packed",
                          layout=packing.PackedLayout.from_tree(
                              [jnp.zeros((d,))], lane=1),
                          k=k, k_m=k_m, fused_stats=True, warm_start=True)
        ts = packing.init_threshold_state()
    else:
        eng = make_engine("fairk", "exact", d=d, k=k, k_m=k_m,
                          fused_stats=True)
        ts = None
    acc = statutil.accumulate_age_hist(eng, d, tstate=ts,
                                       erase_fn=erase_fn, sanitize=True)
    k0 = int(round(k_m * (1 - k_m / d)))
    support, pred = markov.population_aou_distribution(
        markov.FairKChain(d=d, k=k, k_m=k_m, k0=k0),
        cfg.avail, cfg.vanish_rate, cfg.participants, cfg.exposure)
    statutil.assert_pmf_close(acc, support, pred)


# ---------------------------------------------------------------------------
# FL trainer + launch wiring
# ---------------------------------------------------------------------------

def _pop_task():
    from repro.models import cnn
    params0 = cnn.init_mlp_classifier(jax.random.PRNGKey(0), 16, 2,
                                      hidden=(8,))

    def loss_fn(p, x, y):
        return cnn.softmax_xent(cnn.mlp_classifier(p, x), y)

    rng = np.random.default_rng(0)
    w_true = rng.normal(size=(16,))

    def sample_round(t):
        r = np.random.default_rng(100 + t)
        xs = r.normal(size=(8, 3, 10, 16)).astype("f4")
        ys = (xs @ w_true > 0).astype("i4")
        return xs, ys

    return params0, loss_fn, sample_round


@pytest.mark.parametrize("backend", ["exact", "packed"])
def test_trainer_population_scan_completes_finite(backend):
    """A fused ``scan_rounds`` run where every round samples its cohort
    from a live 4096-client population (diurnal wave + stragglers)
    completes with finite weights and AoU accounting."""
    from repro.fl.trainer import FLConfig, train
    params0, loss_fn, sample_round = _pop_task()
    fl = FLConfig(n_clients=8, local_steps=3, batch_size=10, rounds=8,
                  policy="fairk", backend=backend, compression_ratio=0.1,
                  local_lr=0.05, global_lr=0.05, scan_rounds=4, seed=0,
                  population=PopulationConfig(
                      n_clients=4096, cohort_size=1024, participants=8,
                      avail=0.85, mode="diurnal", period=6, depth=0.1,
                      slow_frac=0.25))
    h = train(fl, params0, loss_fn, sample_round)
    w = np.asarray(jax.flatten_util.ravel_pytree(h["params"])[0])
    assert np.isfinite(w).all()
    assert np.isfinite(h["mean_aou"]).all()


def test_trainer_population_validation():
    from repro.fl.trainer import FLConfig, make_fl_step
    loss = lambda p, x, y: 0.0
    unravel = lambda w: w
    pop = PopulationConfig(n_clients=4096, participants=16, avail=0.9)
    with pytest.raises(ValueError, match="participants"):
        make_fl_step(FLConfig(n_clients=8, population=pop), unravel, loss,
                     64)
    pop8 = PopulationConfig(n_clients=4096, participants=8, avail=0.9)
    with pytest.raises(ValueError, match="availability"):
        make_fl_step(FLConfig(n_clients=8, population=pop8,
                              faults=faults.FaultConfig(dropout=0.2)),
                     unravel, loss, 64)
    with pytest.raises(ValueError, match="one_bit"):
        make_fl_step(FLConfig(n_clients=8, population=pop8, one_bit=True),
                     unravel, loss, 64)


def test_sweep_population_validation():
    from repro.fl.sweep import SweepConfig
    pop = PopulationConfig(n_clients=4096, participants=16, avail=0.9)
    with pytest.raises(ValueError, match="participants"):
        SweepConfig(n_clients=8, population=pop)
    pop8 = PopulationConfig(n_clients=4096, participants=8, avail=0.9)
    with pytest.raises(ValueError, match="dropout"):
        SweepConfig(n_clients=8, population=pop8,
                    faults=faults.FaultConfig(dropout=0.2))


def test_launch_population_validation():
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.launch.steps import OacServerConfig, make_train_step
    cfg = get_config("mamba2-370m", reduced_variant=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = InputShape("t", 64, 2, "train")
    pop = PopulationConfig(n_clients=4096, participants=16, avail=0.9)
    with pytest.raises(ValueError, match="sanitize"):
        make_train_step(cfg, shape, mesh,
                        oac=OacServerConfig(population=pop))
    with pytest.raises(ValueError, match="stateless"):
        make_train_step(cfg, shape, mesh,
                        oac=OacServerConfig(
                            sanitize=True,
                            population=PopulationConfig(
                                n_clients=4096, participants=16,
                                avail=0.9, mode="ge", burst=8.0)))
    with pytest.raises(ValueError, match="async"):
        make_train_step(cfg, shape, mesh,
                        oac=OacServerConfig(
                            sanitize=True,
                            population=PopulationConfig(
                                n_clients=4096, participants=16,
                                avail=0.9, slow_frac=0.25)))


# ---------------------------------------------------------------------------
# population-scale smokes (the 1e5-client acceptance runs)
# ---------------------------------------------------------------------------

@pytest.mark.population
def test_population_scan_1e5_smoke():
    """1e5 virtual clients advance through one compiled scan — no Python
    loop, stationarity intact."""
    cfg = PopulationConfig(n_clients=100_000, cohort_size=4096,
                           participants=16, avail=0.9)
    _, tr = population.population_scan_jit(cfg, 32, jax.random.PRNGKey(0))
    frac = np.asarray(tr["n_avail"]) / cfg.n_clients
    assert frac.shape == (32,) and np.isfinite(frac).all()
    assert abs(float(frac.mean()) - 0.9) < 0.01
    assert float(np.asarray(tr["n_t"]).mean()) > 12.0   # ~0.9 * 16


@pytest.mark.population
def test_trainer_scan_1e5_virtual_clients():
    """The acceptance run: a compiled ``scan_rounds`` trainer whose
    cohorts are sampled from a 1e5-client population completes finite."""
    from repro.fl.trainer import FLConfig, train
    params0, loss_fn, sample_round = _pop_task()
    fl = FLConfig(n_clients=8, local_steps=3, batch_size=10, rounds=8,
                  policy="fairk", backend="packed", compression_ratio=0.1,
                  local_lr=0.05, global_lr=0.05, scan_rounds=4, seed=0,
                  population=PopulationConfig(
                      n_clients=100_000, cohort_size=4096, participants=8,
                      avail=0.9))
    h = train(fl, params0, loss_fn, sample_round)
    w = np.asarray(jax.flatten_util.ravel_pytree(h["params"])[0])
    assert np.isfinite(w).all()
