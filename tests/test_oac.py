"""Over-the-air channel model + aggregation (paper Sec. III-A, Eq. 7-8)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import oac
from repro.core.oac import ChannelConfig


class TestFading:
    def test_rayleigh_moments(self):
        cfg = ChannelConfig(fading="rayleigh", mean=1.0)
        h = oac.sample_fading(jax.random.PRNGKey(0), 200_000, cfg)
        assert float(h.mean()) == pytest.approx(1.0, abs=0.01)
        assert float(h.var()) == pytest.approx(cfg.sigma_c2, rel=0.05)
        assert float(h.min()) >= 0.0

    def test_none_fading_is_constant(self):
        cfg = ChannelConfig(fading="none", mean=1.0)
        h = oac.sample_fading(jax.random.PRNGKey(0), 16, cfg)
        np.testing.assert_allclose(np.asarray(h), 1.0)

    @pytest.mark.parametrize("mode", ["rician", "", "RAYLEIGH", "None"])
    def test_rejects_unknown_fading_mode(self, mode):
        """Unknown modes used to fall through ``sigma_c2`` to 0.0 (a
        silently deterministic channel) and only blow up at sample time —
        they must be rejected at construction."""
        with pytest.raises(ValueError, match="fading"):
            ChannelConfig(fading=mode)

    def test_rejects_rayleigh_with_explicit_std(self):
        """Rayleigh derives sigma_c from the mean; an explicit std used to
        be silently ignored."""
        with pytest.raises(ValueError, match="sigma_c"):
            ChannelConfig(fading="rayleigh", std=0.3)
        # gaussian owns its std, rayleigh owns std=0 — both construct
        assert ChannelConfig(fading="gaussian", std=0.3).sigma_c2 \
            == pytest.approx(0.09)
        ChannelConfig(fading="rayleigh", std=0.0)


class TestAggregation:
    def test_noiseless_equals_fedavg(self):
        """With h=1 and no noise, OAC == plain client averaging on S_t."""
        rng = np.random.default_rng(0)
        grads = jnp.asarray(rng.normal(size=(8, 64)).astype("f4"))
        g_prev = jnp.asarray(rng.normal(size=64).astype("f4"))
        idx = jnp.asarray([3, 7, 11, 20, 33, 41], jnp.int32)
        g_t, agg = oac.oac_round(jax.random.PRNGKey(0), g_prev, idx, grads,
                                 oac.NOISELESS)
        np.testing.assert_allclose(np.asarray(agg),
                                   np.asarray(grads[:, idx].mean(0)),
                                   rtol=1e-6)
        # stale entries untouched (Eq. 8)
        mask = np.ones(64, bool)
        mask[np.asarray(idx)] = False
        np.testing.assert_array_equal(np.asarray(g_t)[mask],
                                      np.asarray(g_prev)[mask])

    def test_noise_scales_inverse_n(self):
        """Eq. (7): the noise term enters as xi / N."""
        cfg = ChannelConfig(fading="none", mean=1.0, noise_std=1.0)
        zeros = jnp.zeros((50, 4096))
        agg = oac.oac_aggregate(jax.random.PRNGKey(1), zeros, cfg)
        assert float(jnp.std(agg)) == pytest.approx(1.0 / 50, rel=0.1)

    def test_unbiased_under_fading(self):
        """E[h] = mu_c = 1 -> aggregated gradient unbiased (many clients)."""
        cfg = ChannelConfig(fading="rayleigh", mean=1.0, noise_std=0.0)
        vals = jnp.ones((4000, 8))
        agg = oac.oac_aggregate(jax.random.PRNGKey(2), vals, cfg)
        np.testing.assert_allclose(np.asarray(agg), 1.0, atol=0.05)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 30), d=st.integers(8, 100), data=st.data())
def test_property_reconstruction_partition(n, d, data):
    """Every coordinate of g_t is either freshly aggregated or stale — and
    the selected set is exactly S_t (Eq. 8 partition invariant)."""
    k = data.draw(st.integers(1, d))
    rng = np.random.default_rng(n * 1000 + d)
    idx = jnp.asarray(rng.permutation(d)[:k].astype("i4"))
    grads = jnp.asarray(rng.normal(size=(n, d)).astype("f4"))
    g_prev = jnp.asarray(rng.normal(size=d).astype("f4"))
    g_t, agg = oac.oac_round(jax.random.PRNGKey(0), g_prev, idx, grads,
                             oac.NOISELESS)
    g_t, g_prev_n = np.asarray(g_t), np.asarray(g_prev)
    fresh = np.zeros(d, bool)
    fresh[np.asarray(idx)] = True
    np.testing.assert_array_equal(g_t[~fresh], g_prev_n[~fresh])
    np.testing.assert_allclose(g_t[np.asarray(idx)], np.asarray(agg),
                               rtol=1e-6)
