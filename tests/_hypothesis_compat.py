"""``hypothesis`` facade for the property tests.

When the real ``hypothesis`` is installed (CI does), this module re-exports
it untouched.  When it is missing (the pinned jax_pallas container), a
minimal deterministic stand-in provides the same surface the test-suite
uses — ``given`` / ``settings`` / ``strategies.integers`` /
``strategies.sampled_from`` / ``strategies.data`` — driving each test with
``max_examples`` seeded draws instead of adaptive search.  No shrinking, no
database; coverage is fixed but reproducible, which is exactly what a
hermetic tier-1 run needs.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies
    st = strategies
    HAVE_HYPOTHESIS = True
except ImportError:                                        # pragma: no cover
    import functools
    import inspect
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

        def example_from(self, rng: random.Random):
            return self._draw(rng)

    class _DataObject:
        """Stand-in for hypothesis' interactive ``data()`` object."""

        def __init__(self, rng: random.Random):
            self._rng = rng

        def draw(self, strategy: _Strategy):
            return strategy.example_from(self._rng)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def sampled_from(options) -> _Strategy:
            opts = list(options)
            return _Strategy(lambda rng: rng.choice(opts))

        @staticmethod
        def data() -> _Strategy:
            return _Strategy(lambda rng: _DataObject(rng))

    strategies = st = _Strategies()

    _DEFAULT_EXAMPLES = 10

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                base = zlib.crc32(
                    f"{fn.__module__}.{fn.__qualname__}".encode())
                for i in range(n):
                    rng = random.Random(base + i)
                    drawn = {name: s.example_from(rng)
                             for name, s in strats.items()}
                    fn(*args, **drawn, **kwargs)
            # pytest must not see the strategy-filled parameters (it would
            # look for fixtures of the same name): hide the original
            # signature and expose only the remaining params (e.g. self)
            del wrapper.__wrapped__
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strats])
            return wrapper
        return deco

    def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
        """Accepts (and ignores) deadline / database / etc. kwargs."""
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco


__all__ = ["given", "settings", "strategies", "st", "HAVE_HYPOTHESIS"]
