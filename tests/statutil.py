"""Shared statistical assertion harness for the Sec. IV staleness tests.

The empirical-pmf-vs-Lemma-1 checks used to live three times over —
``tests/test_faults.py`` (participation-thinned law),
``tests/test_async.py`` (lag-shifted law) and ``tests/test_controller.py``
(synchronous stationary law) each reimplemented the same drive-the-engine
/ histogram-to-pmf / embed-the-prediction / TV-distance recipe by hand.
This module is the single implementation all of them (plus the
population-scale suite, ``tests/test_population.py``) route through.

Seeded tolerances
-----------------
Every test that calls ``assert_pmf_close`` runs a FIXED seed, so the
assertions are deterministic, not flaky-probabilistic: the tolerances
below were calibrated once against the seeded runs and hold with margin.

* ``tv_tol = 0.1`` — total variation between the time-averaged empirical
  pmf (600 rounds, 150 burn-in, iid re-drawn N(0, 1) scores: the
  well-mixed exchange regime with ``k0 = k_M (1 - k_M/d)``) and the
  analytic chain pmf.  The dominant error terms are the finite-run
  Monte-Carlo noise (~1/sqrt(450·d) per bin) and the exchange-model
  approximation itself; the seeded runs land around TV ~ 0.03-0.06.
* ``mean_rtol = 0.1`` — relative error of the mean staleness, the
  scalar the budget controller actually regulates.

Widening a tolerance to make a new configuration pass is a red flag:
the correct fix is more rounds or a thinner channel, never a looser law.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing


def hist_to_pmf(hist: np.ndarray) -> np.ndarray:
    """Normalize an accumulated histogram into a pmf (float64)."""
    hist = np.asarray(hist, np.float64)
    total = hist.sum()
    if total <= 0.0:
        raise ValueError("empty histogram — nothing was accumulated")
    return hist / total


def tv_distance(p: np.ndarray, q: np.ndarray) -> float:
    """Total-variation distance 0.5 * ||p - q||_1 between two pmfs of the
    same length."""
    p, q = np.asarray(p, np.float64), np.asarray(q, np.float64)
    if p.shape != q.shape:
        raise ValueError(f"pmf shapes differ: {p.shape} vs {q.shape}")
    return float(0.5 * np.abs(p - q).sum())


def embed_pmf(support: np.ndarray, pmf: np.ndarray,
              n_bins: int = packing.STATS_AGE_BINS) -> np.ndarray:
    """Embed an analytic (support, pmf) pair into the kernel's fixed
    ``n_bins``-long age-histogram binning (mass beyond the last bin is
    dropped — the predictions' tails there are ~1e-9 at the tested
    operating points)."""
    support = np.asarray(support)
    pmf = np.asarray(pmf, np.float64)
    full = np.zeros(n_bins, np.float64)
    sel = support < n_bins
    full[support[sel]] = pmf[sel]
    return full


def pmf_mean(pmf: np.ndarray) -> float:
    """Mean of a pmf over its 0-indexed bin support."""
    pmf = np.asarray(pmf, np.float64)
    return float((np.arange(len(pmf)) * pmf).sum())


def accumulate_age_hist(eng, d: int, *, rounds: int = 600,
                        burn_in: int = 150, seed: int = 0, tstate=None,
                        erase_thin: float = 0.0, erase_fn=None,
                        count_erased: bool = False,
                        **step_kwargs) -> np.ndarray:
    """Drive ``eng.select_and_merge`` with iid re-drawn N(0, 1) scores —
    the well-mixed exchange regime Lemma 1 models — and accumulate the
    kernel-emitted ``age_hist`` after burn-in.

    ``tstate`` (packed backend) is re-threaded through each round;
    ``erase_thin > 0`` draws an iid per-coordinate erasure mask each
    round (the participation-thinning channel); ``erase_fn(r)`` instead
    supplies an arbitrary per-round ``(d,)`` mask (or None) — the
    population suite feeds churn-driven block erasures through it; any
    extra ``step_kwargs`` (``sanitize=True``, ``age_lag=...``) are baked
    into the jitted step.  Fully deterministic for a fixed ``seed``.

    ``count_erased=True`` makes the accumulated histogram the
    UNCONDITIONAL post-update estimator under erasures: the kernel weighs
    erased coordinates zero (their magnitudes were never observed), but
    their post-update AGES are exact — erased means merged-stale and aged
    by one — so the harness bins them from the carried age vector at the
    kernel's own sample stride.  Without it, heavy round-correlated
    erasure channels (total wireless outages erase EVERY coordinate at
    once) leave the histogram conditioned on unblocked rounds, which
    skews it young by 1/(1 - thin).  Guarded against double counting: the
    correction only tops up rounds whose emitted histogram misses sampled
    valid coordinates (the packed engine already substitutes the exact
    shifted histogram on fully-erased rounds).
    """
    rng = np.random.default_rng(seed)
    gp = jnp.zeros((d,), jnp.float32)
    ag = jnp.zeros((d,), jnp.float32)
    step = jax.jit(functools.partial(eng.select_and_merge, **step_kwargs))
    acc = np.zeros(packing.STATS_AGE_BINS)
    stride = packing.hist_stride(d)
    for r in range(rounds):
        g = jnp.asarray(rng.normal(size=d).astype("f4"))
        kw = {}
        if erase_fn is not None:
            mask = erase_fn(r)
            if mask is not None:
                kw["erase"] = jnp.asarray(
                    np.asarray(mask).astype("f4"))
        elif erase_thin > 0.0:
            kw["erase"] = jnp.asarray(
                (rng.random(d) < erase_thin).astype("f4"))
        if tstate is not None:
            g_t, ag, stats = step(g, gp, ag, tstate=tstate, **kw)
            tstate = stats["tstate"]
        else:
            g_t, ag, stats = step(g, gp, ag, **kw)
        gp = g_t
        if r >= burn_in:
            h = np.asarray(stats["age_hist"], np.float64)
            if count_erased and "erase" in kw:
                samp = np.asarray(ag)[::stride]
                erased = np.asarray(kw["erase"])[::stride] > 0.0
                valid = samp >= 0.0
                if h.sum() < valid.sum() - 0.5:
                    bins = np.clip(samp[erased & valid], 0,
                                   packing.STATS_AGE_BINS - 1).astype(int)
                    h = h + np.bincount(bins,
                                        minlength=packing.STATS_AGE_BINS)
            acc += h
    return acc


def assert_pmf_close(hist: np.ndarray, support: np.ndarray,
                     pred: np.ndarray, *, tv_tol: float = 0.1,
                     mean_rtol: float = None) -> np.ndarray:
    """Assert an accumulated empirical age histogram matches an analytic
    (support, pmf) prediction: TV distance below ``tv_tol`` and — when
    ``mean_rtol`` is given — mean staleness within that relative error.
    Returns the normalized empirical pmf for any further suite-specific
    checks (quantile bins, truncated-support zeros, ...)."""
    emp = hist_to_pmf(hist)
    full = embed_pmf(support, pred, n_bins=len(emp))
    tv = tv_distance(emp, full)
    assert tv < tv_tol, (f"empirical pmf diverges from prediction: "
                         f"TV={tv:.4f} >= {tv_tol}")
    if mean_rtol is not None:
        m_emp, m_pred = pmf_mean(emp), float(
            (np.asarray(support) * np.asarray(pred)).sum())
        assert abs(m_emp - m_pred) < mean_rtol * m_pred, (
            f"mean staleness off: empirical {m_emp:.3f} vs predicted "
            f"{m_pred:.3f} (rtol {mean_rtol})")
    return emp
