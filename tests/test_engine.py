"""SelectionEngine: cross-backend parity + budget-accuracy properties.

The engine's whole value is the guarantee that the three execution paths —
exact lax.top_k, threshold kernel, sharded shard_map — implement the SAME
selection rule.  The parity tests pin that down bit-exactly on
dense-tie-free inputs (distinct |g| magnitudes, distinct integer ages) with
order-statistic thresholds; the property tests bound the sampled-quantile
budget error the production path actually runs with."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import selection
from repro.core.engine import (AGE_CAP, EngineConfig, SelectionEngine,
                               exact_thresholds, index_jitter, make_engine,
                               masked_merge, threshold_mask)
from repro.kernels import ops


def _tie_free(d, seed=0):
    """(g, g_prev, age): distinct |g| (generic normals), distinct int ages."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=d).astype("f4"))
    g_prev = jnp.asarray(rng.normal(size=d).astype("f4"))
    age = jnp.asarray(rng.permutation(d).astype("f4"))
    return g, g_prev, age


# ---------------------------------------------------------------------------
# cross-backend parity (the acceptance-criterion test)
# ---------------------------------------------------------------------------

class TestBackendParity:
    @pytest.mark.parametrize("policy,k_m_frac", [
        ("fairk", 0.75), ("fairk", 0.25), ("topk", 1.0), ("roundrobin", 0.0),
    ])
    def test_exact_threshold_sharded_identical(self, policy, k_m_frac):
        """All three backends reconstruct identical (g_t, age') on tie-free
        inputs when the threshold backends use order-statistic thetas."""
        d = 4096
        g, g_prev, age = _tie_free(d, seed=hash(policy) % 100)
        common = dict(policy=policy, rho=0.1, k_m_frac=k_m_frac,
                      exact_theta=True)
        ex = SelectionEngine(EngineConfig(backend="exact", **common), d)
        th = SelectionEngine(EngineConfig(backend="threshold",
                                          kernel_mode="interpret", **common),
                             d)
        mesh = jax.make_mesh((1,), ("shard",))
        sh = SelectionEngine(EngineConfig(backend="sharded", **common), d,
                             mesh=mesh)

        g1, a1, s1 = jax.jit(ex.select_and_merge)(g, g_prev, age)
        g2, a2, s2 = th.select_and_merge(g, g_prev, age)
        g3, a3, s3 = jax.jit(sh.select_and_merge)(g, g_prev, age)

        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g3))
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a3))
        k = ex.budgets()[0]
        assert float(s2["n_selected"]) == k
        assert float(s3["n_selected"]) == k

    def test_threshold_ref_equals_interpret_kernel(self):
        """The fused Pallas kernel (interpret) and the jnp oracle agree."""
        d = 4096
        g, g_prev, age = _tie_free(d, seed=7)
        tm, ta = exact_thresholds(g, age, k=409, k_m=306)
        out_ref = ops.fairk_update(g, g_prev, age, tm, ta, mode="ref")
        out_ker = ops.fairk_update(g, g_prev, age, tm, ta, mode="interpret")
        np.testing.assert_allclose(np.asarray(out_ref[0]),
                                   np.asarray(out_ker[0]), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(out_ref[1]),
                                      np.asarray(out_ker[1]))

    def test_kernel_pad_path_non_aligned(self):
        """fairk_update pads non-block-aligned d without leaking padding."""
        d = 1000  # not a multiple of any pow-2 block
        g, g_prev, age = _tie_free(d, seed=3)
        tm, ta = exact_thresholds(g, age, k=100, k_m=75)
        out_ref = ops.fairk_update(g, g_prev, age, tm, ta, mode="ref")
        out_ker = ops.fairk_update(g, g_prev, age, tm, ta, mode="interpret",
                                   block_size=256)
        assert out_ker[0].shape == (d,)
        np.testing.assert_allclose(np.asarray(out_ref[0]),
                                   np.asarray(out_ker[0]), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(out_ref[1]),
                                      np.asarray(out_ker[1]))

    def test_exact_matches_index_policy(self):
        """Exact backend == the raw core.selection policy + Eq. (8)/(10)."""
        d = 2048
        g, g_prev, age = _tie_free(d, seed=11)
        eng = make_engine("fairk", "exact", d=d, rho=0.1, k_m_frac=0.75)
        k, k_m, _ = eng.budgets()
        g_t, age_next, stats = eng.select_and_merge(g, g_prev, age)
        idx = selection.fair_k_indices(g, age, k=k, k_m=k_m)
        np.testing.assert_array_equal(np.asarray(stats["idx"]),
                                      np.asarray(idx))
        mask = np.zeros(d, np.float32)
        mask[np.asarray(idx)] = 1.0
        expect = mask * np.asarray(g) + (1 - mask) * np.asarray(g_prev)
        np.testing.assert_allclose(np.asarray(g_t), expect, rtol=1e-6)
        np.testing.assert_array_equal(
            np.asarray(age_next),
            np.minimum((np.asarray(age) + 1) * (1 - mask), AGE_CAP))


# ---------------------------------------------------------------------------
# threshold budget properties (the sampled-quantile production path)
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 1000), data=st.data())
def test_property_threshold_count_near_k(seed, data):
    """|selected| within 15% of k for the sampled-quantile thresholds over
    generic Gaussian gradients and bounded integer ages."""
    d = 1 << 14
    rho = data.draw(st.sampled_from([0.05, 0.1, 0.2]))
    k_m_frac = data.draw(st.sampled_from([0.25, 0.5, 0.75]))
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=d).astype("f4"))
    age = jnp.asarray(rng.integers(0, 40, d).astype("f4"))
    eng = make_engine("fairk", "threshold", d=d, rho=rho,
                      k_m_frac=k_m_frac, sample_cap=d)
    _, _, stats = eng.select_and_merge(g, jnp.zeros((d,), jnp.float32), age)
    k = eng.budgets()[0]
    assert abs(float(stats["n_selected"]) - k) <= 0.15 * k, (
        float(stats["n_selected"]), k)


def test_exact_theta_sits_between_order_stats():
    d = 512
    g, _, age = _tie_free(d, seed=5)
    k, k_m = 64, 48
    tm, ta = exact_thresholds(g, age, k=k, k_m=k_m)
    mag = np.sort(np.abs(np.asarray(g)))[::-1]
    assert mag[k_m - 1] >= float(tm) >= mag[k_m]
    mask, mask_m = threshold_mask(g, age, tm, ta)
    assert float(np.asarray(mask_m).sum()) == k_m
    assert float(np.asarray(mask).sum()) == k


def test_jitter_deterministic_and_bounded():
    j = np.asarray(index_jitter(1 << 16))
    assert (0.0 <= j).all() and (j < 1.0).all()
    np.testing.assert_array_equal(j, np.asarray(index_jitter(1 << 16)))


# ---------------------------------------------------------------------------
# engine API surface
# ---------------------------------------------------------------------------

class TestEngineApi:
    def test_all_policies_exact_backend(self):
        d = 512
        g, g_prev, age = _tie_free(d, seed=13)
        for policy in selection.POLICIES:
            eng = make_engine(policy, "exact", d=d, rho=0.05)
            g_t, age_next, stats = eng.select_and_merge(
                g, g_prev, age, key=jax.random.PRNGKey(0))
            k = eng.budgets()[0]
            idx = np.asarray(stats["idx"])
            assert idx.shape == (k,)
            assert len(set(idx.tolist())) == k
            assert float((np.asarray(age_next) == 0).sum()) == k

    def test_threshold_rejects_index_policies(self):
        for policy in ("toprand", "agetopk", "randk"):
            with pytest.raises(ValueError):
                make_engine(policy, "threshold", d=128)

    def test_sharded_needs_mesh_and_divisibility(self):
        with pytest.raises(ValueError):
            make_engine("fairk", "sharded", d=128)
        mesh = jax.make_mesh((1,), ("shard",))
        with pytest.raises(ValueError):
            SelectionEngine(EngineConfig(backend="fancy"), 128, mesh=mesh)

    def test_budgets_remark1(self):
        assert make_engine("topk", "exact", d=1000, rho=0.1).budgets()[1] == 100
        assert make_engine("roundrobin", "exact", d=1000,
                           rho=0.1).budgets()[1] == 0
        eng = make_engine("fairk", "exact", d=1000, k=64, k_m=16, r=96)
        assert eng.budgets() == (64, 16, 96)

    def test_noise_injection_only_on_selected(self):
        """With noise, unselected coordinates must stay exactly g_prev."""
        d = 1024
        g, g_prev, age = _tie_free(d, seed=17)
        eng = make_engine("fairk", "threshold", d=d, rho=0.1,
                          k_m_frac=0.75, exact_theta=True, noise_std=1.0,
                          n_clients=8)
        g_t, age_next, stats = eng.select_and_merge(
            g, g_prev, age, key=jax.random.PRNGKey(2))
        stale = np.asarray(age_next) > 0
        np.testing.assert_array_equal(np.asarray(g_t)[stale],
                                      np.asarray(g_prev)[stale])
        # fresh coords differ from the clean g (noise went in)
        fresh = ~stale
        assert (np.asarray(g_t)[fresh] != np.asarray(g)[fresh]).any()

    def test_masked_merge_age_cap(self):
        age = jnp.full((16,), AGE_CAP, jnp.float32)
        _, age_next = masked_merge(jnp.zeros(16), jnp.zeros(16), age,
                                   jnp.zeros(16))
        assert float(age_next.max()) == AGE_CAP
