"""In-graph fault injection + graceful degradation (DESIGN.md §14).

Covers the churn-tolerant round machinery end to end:

* ``core.faults`` units — Gilbert–Elliott availability chain (stationarity
  + burstiness), the guarded participation rescale, fade-block erasure
  masks, non-finite corruption species, outage folding;
* the divergence-watchdog state machine (warmup arming, immediate
  non-finite trips, spike trips, EMA poisoning protection, cooldown
  tightening) and the ``tree_select`` rollback primitive;
* engine sanitize semantics on every backend — non-finite coordinates are
  semantically "unsent" (kept out of selection, age climbing, EF residual
  through), pads untouched, kernel statistics excluding corrupted
  coordinates — and the off-mode bit-exactness guarantee;
* the post-churn staleness law: under per-coordinate erasures the
  stationary post-update AoU pmf tracks the participation-thinned Lemma-1
  prediction (``markov.thinned_aou_distribution``) on the exact AND
  packed backends;
* ``fl.trainer`` chaos rounds: a ``scan_rounds`` run under simultaneous
  dropout + deep fades + NaN corruption completes with finite loss, and
  the watchdog carry rides the scan.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import statutil
from repro.core import faults, markov, packing
from repro.core.engine import (AGE_CAP, EngineConfig, SelectionEngine,
                               make_engine)
from repro.kernels import ops, ref

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# FaultConfig + fault-channel units
# ---------------------------------------------------------------------------

def test_fault_config_validates():
    for bad in (dict(dropout=-0.1), dict(dropout=1.0), dict(fade=1.5),
                dict(nan_rate=-1e-3), dict(burst=0.5), dict(fade_block=0)):
        with pytest.raises(ValueError):
            faults.FaultConfig(**bad)
    assert not faults.FaultConfig().enabled
    assert faults.FaultConfig(dropout=0.1).enabled
    assert faults.FaultConfig(fade=0.1).enabled
    assert faults.FaultConfig(nan_rate=0.1).enabled


def test_thin_is_post_aggregation_rates():
    cfg = faults.FaultConfig(dropout=0.3, fade=0.05, nan_rate=0.01)
    assert cfg.thin == pytest.approx(0.06)    # dropout does NOT thin
    assert faults.FaultConfig().thin == 0.0


def test_ge_chain_stationarity_iid_and_bursty():
    """Both parameterizations must hold the stationary unavailability at
    ``dropout``; ``burst`` only reshapes the dwell times."""
    key = jax.random.PRNGKey(0)
    for burst in (None, 8.0):
        cfg = faults.FaultConfig(dropout=0.3, burst=burst)
        p_gb, p_bg = faults.ge_probs(cfg)
        # stationary bad mass p_gb / (p_gb + p_bg) == dropout
        assert p_gb / (p_gb + p_bg) == pytest.approx(0.3, abs=1e-6)
        avail = faults.init_avail_state(key, 512, cfg)
        down = []
        step = jax.jit(functools.partial(faults.avail_step, cfg=cfg))
        for t in range(300):
            avail = step(avail, jax.random.fold_in(key, t))
            down.append(1.0 - float(avail.mean()))
        assert np.mean(down[50:]) == pytest.approx(0.3, abs=0.05)


def test_ge_burst_lengthens_dwell():
    """With ``burst=B`` a bad client stays bad ~B rounds on average —
    consecutive-round availability must be visibly more correlated than
    the iid case."""
    key = jax.random.PRNGKey(1)

    def mean_flips(cfg):
        avail = faults.init_avail_state(key, 2048, cfg)
        flips = 0.0
        for t in range(100):
            nxt = faults.avail_step(avail, jax.random.fold_in(key, t), cfg)
            flips += float(jnp.abs(nxt - avail).mean())
            avail = nxt
        return flips / 100

    iid = mean_flips(faults.FaultConfig(dropout=0.3))
    bursty = mean_flips(faults.FaultConfig(dropout=0.3, burst=10.0))
    assert bursty < 0.5 * iid


def test_dropout_off_is_all_available():
    cfg = faults.FaultConfig(fade=0.1)          # enabled, but no dropout
    avail = faults.init_avail_state(jax.random.PRNGKey(0), 64, cfg)
    np.testing.assert_array_equal(np.asarray(avail), np.ones(64))
    nxt = faults.avail_step(avail, jax.random.PRNGKey(1), cfg)
    np.testing.assert_array_equal(np.asarray(nxt), np.ones(64))


def test_participation_scale_guards_zero():
    total = jnp.asarray([2.0, -4.0, 8.0])
    np.testing.assert_allclose(
        np.asarray(faults.participation_scale(total, jnp.float32(2.0))),
        [1.0, -2.0, 4.0])
    out = faults.participation_scale(total, jnp.float32(0.0))
    np.testing.assert_array_equal(np.asarray(out), np.zeros(3))
    assert np.isfinite(np.asarray(out)).all()


def test_erase_with_outage():
    erase = jnp.asarray([1.0, 0.0, 0.0])
    np.testing.assert_array_equal(
        np.asarray(faults.erase_with_outage(erase, jnp.float32(3.0))),
        [1.0, 0.0, 0.0])
    np.testing.assert_array_equal(
        np.asarray(faults.erase_with_outage(erase, jnp.float32(0.0))),
        np.ones(3))


def test_fade_mask_block_granularity():
    cfg = faults.FaultConfig(fade=0.3, fade_block=16)
    m = np.asarray(faults.fade_mask(jax.random.PRNGKey(0), 160, cfg))
    assert set(np.unique(m)) <= {0.0, 1.0}
    blocks = m.reshape(10, 16)
    # a fade takes out a whole block: each block is constant
    assert (blocks.min(axis=1) == blocks.max(axis=1)).all()
    assert 0 < blocks[:, 0].sum() < 10           # some faded, some not
    # off mode: exact zeros
    off = faults.fade_mask(jax.random.PRNGKey(0), 160,
                           faults.FaultConfig())
    assert float(jnp.abs(off).sum()) == 0.0


def test_corrupt_species_and_off_mode():
    g = jnp.ones((200_000,), jnp.float32)
    cfg = faults.FaultConfig(nan_rate=0.01)
    out = np.asarray(faults.corrupt(g, jax.random.PRNGKey(0), cfg))
    bad = ~np.isfinite(out)
    assert bad.mean() == pytest.approx(0.01, rel=0.3)
    assert np.isnan(out[bad]).any()              # all three species occur
    assert (out[bad] == np.inf).any()
    assert (out[bad] == -np.inf).any()
    assert (out[~bad] == 1.0).all()
    # off mode returns the input object itself (no traced ops)
    assert faults.corrupt(g, jax.random.PRNGKey(0),
                          faults.FaultConfig()) is g


# ---------------------------------------------------------------------------
# watchdog state machine + rollback primitive
# ---------------------------------------------------------------------------

def test_watchdog_config_validates():
    with pytest.raises(ValueError):
        faults.WatchdogConfig(spike=1.0)
    with pytest.raises(ValueError):
        faults.WatchdogConfig(tighten=0.0)
    with pytest.raises(ValueError):
        faults.WatchdogConfig(tighten=1.5)


def test_watchdog_warmup_then_spike_trip():
    cfg = faults.WatchdogConfig(spike=2.0, warmup=3, cooldown=4,
                                tighten=0.5)
    st = faults.init_watchdog_state()
    # warmup: a big observation during warmup must NOT trip
    for _ in range(3):
        st, trip, k_scale = faults.watchdog_step(cfg, st, 1.0, 1.0)
        assert not bool(trip) and float(k_scale) == 1.0
    # armed now: a 3x spike trips
    st, trip, k_scale = faults.watchdog_step(cfg, st, 3.0, 1.0)
    assert bool(trip)
    assert float(st["trips"]) == 1.0
    assert float(st["cooldown"]) == 4.0
    assert float(k_scale) == 0.5
    # the spike never entered the EMA baseline
    assert float(st["ema_loss"]) == pytest.approx(1.0)
    # cooldown counts down over healthy rounds, tightening while open
    for want in (3.0, 2.0, 1.0, 0.0):
        st, trip, k_scale = faults.watchdog_step(cfg, st, 1.0, 1.0)
        assert not bool(trip)
        assert float(st["cooldown"]) == want
        assert float(k_scale) == (0.5 if want > 0 else 1.0)


def test_watchdog_nonfinite_trips_immediately():
    cfg = faults.WatchdogConfig(warmup=5)
    st = faults.init_watchdog_state()
    st, trip, _ = faults.watchdog_step(cfg, st, jnp.float32(jnp.nan), 1.0)
    assert bool(trip)                            # even before warmup
    st, trip, _ = faults.watchdog_step(cfg, st, 1.0,
                                       jnp.float32(jnp.inf))
    assert bool(trip)
    assert float(st["trips"]) == 2.0
    assert float(st["obs"]) == 0.0               # tripped obs don't advance


def test_tree_select_rollback():
    snap = {"w": jnp.ones((4,)), "age": jnp.zeros((4,), jnp.int8)}
    live = {"w": jnp.full((4,), 7.0), "age": jnp.full((4,), 3,
                                                      jnp.int8)}
    rolled = faults.tree_select(jnp.bool_(True), snap, live)
    np.testing.assert_array_equal(np.asarray(rolled["w"]), np.ones(4))
    assert rolled["age"].dtype == jnp.int8
    kept = faults.tree_select(jnp.bool_(False), snap, live)
    np.testing.assert_array_equal(np.asarray(kept["w"]), np.full(4, 7.0))


# ---------------------------------------------------------------------------
# engine sanitize: non-finite propagation on every backend (satellite)
# ---------------------------------------------------------------------------

def _engine_and_kwargs(backend, d):
    if backend == "packed":
        layout = packing.PackedLayout.from_tree([jnp.zeros((d,))], lane=1)
        eng = make_engine("fairk", "packed", layout=layout, rho=0.125,
                          k_m_frac=0.75, fused_stats=True, warm_start=True)
        return eng, {"tstate": packing.init_threshold_state()}
    eng = make_engine("fairk", backend, d=d, rho=0.125, k_m_frac=0.75,
                      fused_stats=(backend != "exact"))
    return eng, {}


@pytest.mark.parametrize("backend", ["exact", "threshold", "packed"])
def test_sanitize_excludes_nonfinite(backend):
    """NaN/Inf coordinates are semantically "unsent" on every backend:
    never selected (g_prev kept, age climbs) and the EF residual passes
    through unchanged at exactly those coordinates."""
    d = 4096
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (d,), jnp.float32)
    bad_idx = np.asarray([3, 77, 1024, 4000])
    g = g.at[bad_idx[0]].set(jnp.nan).at[bad_idx[1]].set(jnp.inf)
    g = g.at[bad_idx[2]].set(-jnp.inf).at[bad_idx[3]].set(jnp.nan)
    gp = jax.random.normal(jax.random.fold_in(key, 1), (d,), jnp.float32)
    age = jnp.floor(8.0 * jax.random.uniform(jax.random.fold_in(key, 2),
                                             (d,), jnp.float32))
    res = 0.01 * jax.random.normal(jax.random.fold_in(key, 3), (d,),
                                   jnp.float32)
    eng, kw = _engine_and_kwargs(backend, d)
    g_t, age_next, stats = eng.select_and_merge(g, gp, age, residual=res,
                                                sanitize=True, **kw)
    gt = np.asarray(g_t)
    an = np.asarray(age_next)
    rn = np.asarray(stats["residual"])
    assert np.isfinite(gt).all()                 # corruption never merges
    np.testing.assert_array_equal(gt[bad_idx], np.asarray(gp)[bad_idx])
    np.testing.assert_array_equal(an[bad_idx],
                                  np.minimum(np.asarray(age)[bad_idx] + 1,
                                             AGE_CAP))
    np.testing.assert_array_equal(rn[bad_idx], np.asarray(res)[bad_idx])
    assert np.isfinite(rn).all()


@pytest.mark.parametrize("backend", ["exact", "threshold", "packed"])
def test_sanitize_off_mode_bit_exact(backend):
    """``sanitize=False`` (and finite inputs under ``sanitize=True``) must
    not perturb the historical trajectory."""
    d = 4096
    key = jax.random.PRNGKey(7)
    g = jax.random.normal(key, (d,), jnp.float32)
    gp = jax.random.normal(jax.random.fold_in(key, 1), (d,), jnp.float32)
    age = jnp.floor(8.0 * jax.random.uniform(jax.random.fold_in(key, 2),
                                             (d,), jnp.float32))
    eng, kw = _engine_and_kwargs(backend, d)
    g_ref, age_ref, _ = eng.select_and_merge(g, gp, age, **kw)
    g_off, age_off, _ = eng.select_and_merge(g, gp, age, sanitize=False,
                                             **kw)
    np.testing.assert_array_equal(np.asarray(g_ref), np.asarray(g_off))
    np.testing.assert_array_equal(np.asarray(age_ref), np.asarray(age_off))
    # sanitize=True on fully-finite input selects the identical set
    g_on, age_on, _ = eng.select_and_merge(g, gp, age, sanitize=True, **kw)
    np.testing.assert_array_equal(np.asarray(g_ref), np.asarray(g_on))
    np.testing.assert_array_equal(np.asarray(age_ref), np.asarray(age_on))


def test_erase_requires_sanitize_and_policy_gate():
    d = 512
    eng, _ = _engine_and_kwargs("exact", d)
    g = jnp.ones((d,), jnp.float32)
    z = jnp.zeros((d,), jnp.float32)
    with pytest.raises(ValueError, match="sanitize"):
        eng.select_and_merge(g, z, z, erase=jnp.zeros((d,)))
    eng_rank = make_engine("agetopk", "exact", d=d, rho=0.125)
    with pytest.raises(ValueError, match="agetopk"):
        eng_rank.select_and_merge(g, z, z, sanitize=True)


def test_erase_channel_degrades_like_nan():
    """An erasure and a NaN at the same coordinate must walk the same
    path: g_prev kept, age climbing."""
    d = 2048
    key = jax.random.PRNGKey(3)
    g = jax.random.normal(key, (d,), jnp.float32)
    gp = jax.random.normal(jax.random.fold_in(key, 1), (d,), jnp.float32)
    age = jnp.floor(5.0 * jax.random.uniform(jax.random.fold_in(key, 2),
                                             (d,), jnp.float32))
    erase = jnp.zeros((d,), jnp.float32).at[100:164].set(1.0)
    eng, _ = _engine_and_kwargs("exact", d)
    g_e, age_e, _ = eng.select_and_merge(g, gp, age, erase=erase,
                                         sanitize=True)
    g_n, age_n, _ = eng.select_and_merge(
        jnp.where(erase > 0, jnp.nan, g), gp, age, sanitize=True)
    np.testing.assert_array_equal(np.asarray(g_e), np.asarray(g_n))
    np.testing.assert_array_equal(np.asarray(age_e), np.asarray(age_n))
    np.testing.assert_array_equal(np.asarray(g_e)[100:164],
                                  np.asarray(gp)[100:164])


def test_sanitize_preserves_pads_and_kernel_stats():
    """Packed-layout pads (age < 0) stay untouched under sanitize, and the
    kernel-emitted histograms weigh corrupted coordinates zero."""
    d_leaf = 1000                               # forces lane pads
    layout = packing.PackedLayout.from_tree([jnp.zeros((d_leaf,))])
    d = layout.d_packed
    assert d > d_leaf
    g = layout.pack([jnp.ones((d_leaf,), jnp.float32)])
    g = g.at[5].set(jnp.nan)
    gp = jnp.zeros((d,), jnp.float32)
    age = layout.init_age(jnp.float32)
    tm, ta = jnp.float32(0.5), jnp.float32(jnp.inf)
    for mode in ("ref", "interpret"):
        g_t, age_next, _, stats = ops.fairk_stats_update(
            g, gp, age, tm, ta, mode=mode, sanitize=True)
        an = np.asarray(age_next)
        pads = np.asarray(age) < 0
        assert (an[pads] == np.asarray(age)[pads]).all()
        assert float(an[5]) == 1.0               # corrupted coord aged
        # every sampled valid+finite coordinate weighs 1, the corrupted
        # one (sampled at stride 1 for this size) weighs 0
        stride = packing.hist_stride(d)
        n_ok = int((~pads[::stride]).sum()) - int(5 % stride == 0)
        assert float(np.asarray(stats["mag_hist"]).sum()) == n_ok
        assert float(np.asarray(stats["age_hist"]).sum()) == n_ok
        # counts can't contain the corrupted coordinate
        assert float(stats["n_sel"]) == float((an == 0.0).sum())


def test_kernel_sanitize_ref_vs_interpret_parity():
    d = 1024
    key = jax.random.PRNGKey(9)
    g = jax.random.normal(key, (d,), jnp.float32)
    g = g.at[11].set(jnp.nan).at[500].set(jnp.inf)
    gp = jax.random.normal(jax.random.fold_in(key, 1), (d,), jnp.float32)
    age = jnp.floor(6.0 * jax.random.uniform(jax.random.fold_in(key, 2),
                                             (d,), jnp.float32))
    res = 0.1 * jax.random.normal(jax.random.fold_in(key, 3), (d,),
                                  jnp.float32)
    tm, ta = jnp.float32(1.2), jnp.float32(4.5)
    out_ref = ops.fairk_ef_update(g, gp, age, tm, ta, residual=res,
                                  mode="ref", sanitize=True)
    out_int = ops.fairk_ef_update(g, gp, age, tm, ta, residual=res,
                                  mode="interpret", sanitize=True)
    for a, b in zip(out_ref, out_int):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# post-churn staleness law: participation-thinned Lemma 1 (acceptance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["exact", "packed"])
def test_empirical_pmf_matches_thinned_lemma1(backend):
    """Per-coordinate erasures at rate ``thin`` block refreshes
    geometrically; the stationary post-update AoU pmf must track
    ``markov.thinned_aou_distribution`` within the TV tolerance the
    sync and async laws already meet (seeded run, see tests/statutil.py)."""
    d, k, k_m, thin = 512, 64, 32, 0.1
    if backend == "packed":
        eng = make_engine("fairk", "packed",
                          layout=packing.PackedLayout.from_tree(
                              [jnp.zeros((d,))], lane=1),
                          k=k, k_m=k_m, fused_stats=True, warm_start=True)
        ts = packing.init_threshold_state()
    else:
        eng = make_engine("fairk", "exact", d=d, k=k, k_m=k_m,
                          fused_stats=True)
        ts = None
    acc = statutil.accumulate_age_hist(eng, d, tstate=ts, erase_thin=thin,
                                       sanitize=True)
    k0 = int(round(k_m * (1 - k_m / d)))
    support, pred = markov.thinned_aou_distribution(
        markov.FairKChain(d=d, k=k, k_m=k_m, k0=k0), thin)
    statutil.assert_pmf_close(acc, support, pred)


def test_thinned_aou_distribution_validates():
    chain = markov.FairKChain(d=512, k=64, k_m=32, k0=30)
    for bad in (-0.1, 1.0):
        with pytest.raises(ValueError):
            markov.thinned_aou_distribution(chain, bad)
    s0, p0 = markov.thinned_aou_distribution(chain, 0.0)
    s1, p1 = markov.aou_distribution(chain)
    np.testing.assert_array_equal(s0, s1)
    np.testing.assert_allclose(p0, p1, atol=1e-12)
    # thinning strictly lengthens the mean AoU
    s, p = markov.thinned_aou_distribution(chain, 0.2)
    assert (s * p).sum() > (s1 * p1).sum()
    assert p.sum() == pytest.approx(1.0, abs=1e-6)


# ---------------------------------------------------------------------------
# trainer chaos rounds (acceptance) — marked ``chaos``: the CI fast lane
# runs these as the churn smoke
# ---------------------------------------------------------------------------

def _chaos_task():
    from repro.models import cnn
    params0 = cnn.init_mlp_classifier(jax.random.PRNGKey(0), 16, 2,
                                      hidden=(8,))

    def loss_fn(p, x, y):
        return cnn.softmax_xent(cnn.mlp_classifier(p, x), y)

    rng = np.random.default_rng(0)
    w_true = rng.normal(size=(16,))

    def sample_round(t):
        r = np.random.default_rng(100 + t)
        xs = r.normal(size=(8, 3, 10, 16)).astype("f4")
        ys = (xs @ w_true > 0).astype("i4")
        return xs, ys

    return params0, loss_fn, sample_round


@pytest.mark.chaos
@pytest.mark.parametrize("backend", ["exact", "packed"])
def test_chaos_scan_run_completes_finite(backend):
    """The acceptance scenario: dropout 0.3 + fade 0.05 + NaN 1e-4, fixed
    seed, rounds fused through ``lax.scan`` — the run completes, the
    model stays finite, and the watchdog carry survives the scan."""
    from repro.fl.trainer import FLConfig, train
    params0, loss_fn, sample_round = _chaos_task()
    fl = FLConfig(n_clients=8, local_steps=3, batch_size=10, rounds=12,
                  policy="fairk", backend=backend, compression_ratio=0.1,
                  local_lr=0.05, global_lr=0.05, scan_rounds=4,
                  faults=faults.FaultConfig(dropout=0.3, burst=4.0,
                                            fade=0.05, nan_rate=1e-4),
                  watchdog=faults.WatchdogConfig(), seed=0)
    h = train(fl, params0, loss_fn, sample_round)
    w = np.asarray(jax.flatten_util.ravel_pytree(h["params"])[0])
    assert np.isfinite(w).all()
    assert np.isfinite(h["mean_aou"]).all()
    assert "wd_trips" in h and h["wd_trips"] >= 0.0


@pytest.mark.chaos
def test_chaos_off_mode_is_legacy_step():
    """All-zero fault rates + no watchdog: ``make_fl_step`` hands back the
    historical 10-arg/9-output step and the trajectory is bit-exact with
    a config that never mentions faults."""
    from repro.fl.trainer import FLConfig, train
    params0, loss_fn, sample_round = _chaos_task()
    base = dict(n_clients=8, local_steps=3, batch_size=10, rounds=6,
                policy="fairk", compression_ratio=0.1, local_lr=0.05,
                global_lr=0.05, seed=0)
    h_plain = train(FLConfig(**base), params0, loss_fn, sample_round)
    h_zero = train(FLConfig(**base, faults=faults.FaultConfig()),
                   params0, loss_fn, sample_round)
    w_plain = np.asarray(jax.flatten_util.ravel_pytree(
        h_plain["params"])[0])
    w_zero = np.asarray(jax.flatten_util.ravel_pytree(h_zero["params"])[0])
    np.testing.assert_array_equal(w_plain, w_zero)


@pytest.mark.chaos
def test_watchdog_rolls_back_divergence():
    """A divergent global step (huge lr spike via corrupted rounds) trips
    the watchdog: trips > 0 and the model still ends finite."""
    from repro.fl.trainer import FLConfig, train
    params0, loss_fn, sample_round = _chaos_task()
    fl = FLConfig(n_clients=8, local_steps=3, batch_size=10, rounds=10,
                  policy="fairk", backend="exact", compression_ratio=0.1,
                  local_lr=0.05, global_lr=50.0,   # divergent on purpose
                  faults=faults.FaultConfig(nan_rate=0.01),
                  watchdog=faults.WatchdogConfig(warmup=2, cooldown=3),
                  seed=0)
    h = train(fl, params0, loss_fn, sample_round)
    w = np.asarray(jax.flatten_util.ravel_pytree(h["params"])[0])
    assert np.isfinite(w).all()
    assert h["wd_trips"] > 0.0


def test_make_fl_step_chaos_validation():
    from repro.fl.trainer import FLConfig, make_fl_step
    loss = lambda p, x, y: 0.0
    unravel = lambda w: w
    with pytest.raises(ValueError, match="one_bit"):
        make_fl_step(FLConfig(one_bit=True,
                              faults=faults.FaultConfig(dropout=0.1)),
                     unravel, loss, 64)
    with pytest.raises(ValueError, match="policy"):
        make_fl_step(FLConfig(policy="agetopk",
                              faults=faults.FaultConfig(dropout=0.1)),
                     unravel, loss, 64)
    with pytest.raises(ValueError, match="watchdog|split"):
        make_fl_step(FLConfig(policy="topk",
                              watchdog=faults.WatchdogConfig()),
                     unravel, loss, 64)


def test_init_fault_state_contents():
    from repro.fl.trainer import FLConfig, init_fault_state, init_server
    from repro.models import cnn
    params0 = cnn.init_mlp_classifier(jax.random.PRNGKey(0), 16, 2,
                                      hidden=(8,))
    state, _ = init_server(params0)
    fl = FLConfig(n_clients=8, faults=faults.FaultConfig(dropout=0.2),
                  watchdog=faults.WatchdogConfig())
    fs = init_fault_state(fl, state)
    assert fs["avail"].shape == (8,)
    assert set(fs["wd"]) == set(faults.WATCHDOG_FIELDS)
    assert len(fs["snap"]) == 7
    # watchdog-only flavour carries no availability chain
    fl2 = FLConfig(watchdog=faults.WatchdogConfig())
    fs2 = init_fault_state(fl2, state)
    assert "avail" not in fs2 and "wd" in fs2
