"""Deep correctness oracles for the model internals.

* ssd_chunked (the TPU-adapted chunked SSD) vs the exact token-by-token
  recurrence (ssd_step) — the state-space-duality identity itself.
* chunked (flash-style) attention vs single-tile plain attention, across
  causal/window/GQA configurations.
* causal conv decode-state consistency.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import attention as attn
from repro.models.mamba2 import _causal_conv, ssd_chunked, ssd_step


class TestSSD:
    @pytest.mark.parametrize("seq,chunk", [(32, 8), (64, 16), (48, 16),
                                           (17, 8)])
    def test_chunked_equals_recurrence(self, seq, chunk):
        """SSD chunked scan == exact recurrent scan (fp32, tight tol)."""
        rng = np.random.default_rng(seq * chunk)
        b, h, p, n = 2, 4, 8, 16
        x = jnp.asarray(rng.normal(size=(b, seq, h, p)).astype("f4"))
        dt = jnp.asarray(0.5 * rng.random((b, seq, h)).astype("f4") + 0.1)
        a = -jnp.asarray(np.linspace(0.5, 2.0, h).astype("f4"))
        bmat = jnp.asarray(rng.normal(size=(b, seq, h, n)).astype("f4"))
        cmat = jnp.asarray(rng.normal(size=(b, seq, h, n)).astype("f4"))

        y_chunk, state_chunk = ssd_chunked(x, dt, a, bmat, cmat, chunk)

        state = jnp.zeros((b, h, p, n), jnp.float32)
        ys = []
        for t in range(seq):
            y_t, state = ssd_step(state, x[:, t], dt[:, t], a,
                                  bmat[:, t], cmat[:, t])
            ys.append(y_t)
        y_rec = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(state_chunk),
                                   np.asarray(state), rtol=2e-4, atol=2e-4)

    def test_initial_state_carries(self):
        """Prefill with an initial state == recurrence from that state."""
        rng = np.random.default_rng(7)
        b, seq, h, p, n = 1, 16, 2, 4, 8
        x = jnp.asarray(rng.normal(size=(b, seq, h, p)).astype("f4"))
        dt = jnp.asarray(0.3 * np.ones((b, seq, h), "f4"))
        a = -jnp.ones((h,), jnp.float32)
        bm = jnp.asarray(rng.normal(size=(b, seq, h, n)).astype("f4"))
        cm = jnp.asarray(rng.normal(size=(b, seq, h, n)).astype("f4"))
        s0 = jnp.asarray(rng.normal(size=(b, h, p, n)).astype("f4"))
        y1, sf1 = ssd_chunked(x, dt, a, bm, cm, chunk=8, init_state=s0)
        state = s0
        for t in range(seq):
            y_t, state = ssd_step(state, x[:, t], dt[:, t], a, bm[:, t],
                                  cm[:, t])
        np.testing.assert_allclose(np.asarray(sf1), np.asarray(state),
                                   rtol=2e-4, atol=2e-4)


class TestAttentionEquivalence:
    @pytest.mark.parametrize("causal,window", [(True, 0), (True, 48),
                                               (False, 0)])
    @pytest.mark.parametrize("n_heads,n_kv", [(4, 4), (8, 2), (6, 1)])
    def test_chunked_equals_plain(self, causal, window, n_heads, n_kv):
        rng = np.random.default_rng(n_heads * 97 + n_kv)
        b, s, hd = 2, 128, 16
        q = jnp.asarray(rng.normal(size=(b, s, n_heads, hd)).astype("f4"))
        k = jnp.asarray(rng.normal(size=(b, s, n_kv, hd)).astype("f4"))
        v = jnp.asarray(rng.normal(size=(b, s, n_kv, hd)).astype("f4"))
        pos = jnp.arange(s)
        out_plain = attn.plain_attention(q, k, v, pos, pos, causal=causal,
                                         window=window)
        out_chunk = attn.chunked_attention(q, k, v, pos, pos, causal=causal,
                                           window=window, q_chunk=32,
                                           kv_chunk=32)
        np.testing.assert_allclose(np.asarray(out_plain),
                                   np.asarray(out_chunk), rtol=2e-4,
                                   atol=2e-4)

    def test_causal_skip_matches_full(self):
        rng = np.random.default_rng(3)
        b, s, h, hd = 1, 128, 4, 16
        q = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype("f4"))
        k = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype("f4"))
        v = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype("f4"))
        pos = jnp.arange(s)
        full = attn.chunked_attention(q, k, v, pos, pos, causal=True,
                                      q_chunk=32, kv_chunk=32,
                                      causal_skip=False)
        skip = attn.chunked_attention(q, k, v, pos, pos, causal=True,
                                      q_chunk=32, kv_chunk=32,
                                      causal_skip=True)
        np.testing.assert_allclose(np.asarray(full), np.asarray(skip),
                                   rtol=2e-4, atol=2e-4)

    def test_decode_attend_matches_plain_last_row(self):
        rng = np.random.default_rng(11)
        b, s, h, n_kv, hd = 2, 64, 8, 2, 16
        q_all = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype("f4"))
        k = jnp.asarray(rng.normal(size=(b, s, n_kv, hd)).astype("f4"))
        v = jnp.asarray(rng.normal(size=(b, s, n_kv, hd)).astype("f4"))
        pos = jnp.arange(s)
        ref = attn.plain_attention(q_all, k, v, pos, pos, causal=True)
        cache = attn.init_cache(b, s, n_kv, hd, jnp.float32)
        cache = attn.cache_fill(cache, k, v, pos)
        out = attn.decode_attend(q_all[:, -1:], cache, jnp.asarray(s - 1))
        np.testing.assert_allclose(np.asarray(out[:, 0]),
                                   np.asarray(ref[:, -1]), rtol=2e-4,
                                   atol=2e-4)


class TestCausalConv:
    @settings(max_examples=15, deadline=None)
    @given(seq=st.integers(4, 32), seed=st.integers(0, 50))
    def test_streaming_equals_full(self, seq, seed):
        """Running the conv one token at a time with the carried state must
        equal the full-sequence conv (decode-path correctness)."""
        rng = np.random.default_rng(seed)
        c, kk = 6, 4
        x = jnp.asarray(rng.normal(size=(1, seq, c)).astype("f4"))
        w = jnp.asarray(rng.normal(size=(kk, c)).astype("f4"))
        bias = jnp.asarray(rng.normal(size=(c,)).astype("f4"))
        y_full, _ = _causal_conv(x, w, bias)
        state = jnp.zeros((1, kk - 1, c), jnp.float32)
        ys = []
        for t in range(seq):
            y_t, state = _causal_conv(x[:, t:t + 1], w, bias, state)
            ys.append(y_t)
        y_stream = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_stream),
                                   rtol=1e-5, atol=1e-5)
