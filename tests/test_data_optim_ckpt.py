"""Substrate tests: data pipeline, optimizers, checkpointing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.data import partition, synthetic, tokens
from repro.optim import adamw, apply_updates, make_optimizer, sgd
from repro.optim.schedule import cosine_decay, linear_warmup_cosine


class TestData:
    def test_dirichlet_partition_covers_everything(self):
        spec = synthetic.DatasetSpec("t", (8, 8, 1), 10, 2000, 100)
        (x, y), _ = synthetic.make_dataset(spec, seed=0)
        parts = partition.dirichlet_partition(y, 10, 0.3, seed=0)
        all_idx = np.concatenate(parts)
        assert len(all_idx) == len(y)
        assert len(np.unique(all_idx)) == len(y)

    def test_dirichlet_more_skewed_than_iid(self):
        spec = synthetic.DatasetSpec("t", (8, 8, 1), 10, 4000, 100)
        (x, y), _ = synthetic.make_dataset(spec, seed=0)

        def class_skew(parts):
            dists = []
            for p in parts:
                h = np.bincount(y[p], minlength=10) / max(len(p), 1)
                dists.append(h)
            return np.std(np.asarray(dists), axis=0).mean()

        skew_dir = class_skew(partition.dirichlet_partition(y, 10, 0.1, 0))
        skew_iid = class_skew(partition.iid_partition(len(y), 10, 0))
        assert skew_dir > 3 * skew_iid

    def test_dirichlet_infeasible_min_size_raises(self):
        """Regression: an unattainable min_size used to spin the redraw
        loop forever — now it fails fast, naming the infeasible sizes."""
        y = np.arange(10) % 2                 # 10 samples, 2 classes
        with pytest.raises(ValueError, match="10 clients x min_size=8"):
            partition.dirichlet_partition(y, 10, 0.3, seed=0)

    def test_dirichlet_retry_exhaustion_raises(self):
        """Feasible in principle but so skewed no bounded draw streak
        delivers it: the loop must give up with a diagnosis instead of
        running unbounded."""
        y = np.zeros(40, dtype=np.int64)      # one class, 4 clients
        with pytest.raises(ValueError, match="attempts"):
            partition.dirichlet_partition(y, 4, 1e-4, seed=0, min_size=10,
                                          max_retries=5)

    def test_dirichlet_retry_still_succeeds(self):
        """The bounded loop keeps the redraw behavior: a tight-but-
        feasible min_size still resolves within the retry budget."""
        spec = synthetic.DatasetSpec("t", (8, 8, 1), 10, 2000, 100)
        (_, y), _ = synthetic.make_dataset(spec, seed=0)
        parts = partition.dirichlet_partition(y, 10, 0.3, seed=0,
                                              min_size=40)
        assert min(len(p) for p in parts) >= 40
        assert len(np.unique(np.concatenate(parts))) == len(y)

    def test_client_batches_shape_and_membership(self):
        spec = synthetic.DatasetSpec("t", (4, 4, 1), 5, 500, 50)
        (x, y), _ = synthetic.make_dataset(spec, seed=1)
        parts = partition.dirichlet_partition(y, 5, 0.5, seed=1)
        xs, ys = partition.client_batches(x, y, parts, batch_size=8, steps=3,
                                          seed=0)
        assert xs.shape == (5, 3, 8, 4, 4, 1) and ys.shape == (5, 3, 8)

    def test_synthetic_task_learnable(self):
        """A linear probe must beat chance on the synthetic dataset."""
        spec = synthetic.DatasetSpec("t", (8, 8, 1), 4, 2000, 400,
                                     noise_std=0.5)
        (xtr, ytr), (xte, yte) = synthetic.make_dataset(spec, seed=0)
        xtr_f = xtr.reshape(len(xtr), -1)
        xte_f = xte.reshape(len(xte), -1)
        w = np.linalg.lstsq(xtr_f, np.eye(4)[ytr], rcond=None)[0]
        acc = (xte_f @ w).argmax(1) == yte
        assert acc.mean() > 0.5   # chance = 0.25

    def test_lm_batch(self):
        toks, labels = tokens.lm_batch(0, 4, 32, vocab=100)
        assert toks.shape == (4, 32) and labels.shape == (4, 32)
        np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])
        assert toks.max() < 100 and toks.min() >= 0


class TestOptim:
    def _quad_losses(self, opt, steps=60):
        w = jnp.asarray([3.0, -2.0])
        state = opt.init(w)
        for _ in range(steps):
            g = 2 * w
            upd, state = opt.update(g, state, w)
            w = apply_updates(w, upd)
        return float(jnp.sum(w**2))

    def test_sgd_converges(self):
        assert self._quad_losses(sgd(0.1)) < 1e-4

    def test_sgd_momentum_converges(self):
        assert self._quad_losses(sgd(0.05, momentum=0.9), steps=150) < 1e-4

    def test_adamw_converges(self):
        assert self._quad_losses(adamw(0.2), steps=150) < 1e-4

    def test_weight_decay_shrinks(self):
        opt = sgd(0.1, weight_decay=0.5)
        w = jnp.asarray([1.0])
        state = opt.init(w)
        upd, _ = opt.update(jnp.asarray([0.0]), state, w)
        assert float(apply_updates(w, upd)[0]) < 1.0

    def test_schedules(self):
        s = cosine_decay(1.0, 100)
        assert float(s(jnp.asarray(0))) == pytest.approx(1.0)
        assert float(s(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)
        w = linear_warmup_cosine(1.0, 10, 110)
        assert float(w(jnp.asarray(5))) == pytest.approx(0.5)

    def test_registry(self):
        for name in ("sgd", "sgdm", "adamw"):
            make_optimizer(name, 0.1)
        with pytest.raises(ValueError):
            make_optimizer("lion", 0.1)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3),
                "b": [jnp.ones(4), {"c": jnp.asarray(2.5)}],
                "d": None}
        path = os.path.join(tmp_path, "ckpt.npz")
        checkpoint.save(path, tree)
        back = checkpoint.restore(path, like=tree)
        np.testing.assert_array_equal(np.asarray(back["a"]),
                                      np.asarray(tree["a"]))
        np.testing.assert_array_equal(np.asarray(back["b"][0]), 1.0)
        assert float(back["b"][1]["c"]) == 2.5
        assert back["d"] is None

    def test_step_naming_and_latest(self, tmp_path):
        d = str(tmp_path)
        checkpoint.save(d, {"w": jnp.zeros(3)}, step=10)
        checkpoint.save(d, {"w": jnp.ones(3)}, step=20)
        assert checkpoint.latest_step(d) == 20
        back = checkpoint.restore(os.path.join(d, "step_00000020.npz"))
        np.testing.assert_array_equal(np.asarray(back["w"]), 1.0)
