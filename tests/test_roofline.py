"""Roofline HLO parser: loop-aware FLOP/collective accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.roofline import (analyze_hlo, build_report, model_flops,
                            xla_cost_analysis)
from repro.roofline.hlo import _shape_bytes, parse_computations


def test_shape_bytes():
    assert _shape_bytes("f32[8,32]{1,0}") == 8 * 32 * 4
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(s32[], f32[5])") == 4 + 20
    assert _shape_bytes("pred[16]") == 16


def test_scan_flops_multiplied_by_trip_count():
    """cost_analysis counts a while body once; the parser must multiply."""
    trips, n, k, m = 7, 16, 32, 24

    def body(c, w):
        return c @ w, None

    def fn(ws, x):
        out, _ = jax.lax.scan(body, x, ws)
        return out

    compiled = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((trips, k, k), jnp.float32),
        jax.ShapeDtypeStruct((n, k), jnp.float32)).compile()
    parsed = analyze_hlo(compiled.as_text())
    expected = 2 * n * k * k * trips
    assert parsed["flops_per_device"] == pytest.approx(expected, rel=0.01)
    # and confirm the raw cost_analysis really does NOT multiply
    # (list on JAX <= 0.4.x, dict on newer -> go through the compat shim)
    raw = xla_cost_analysis(compiled)["flops"]
    assert raw < expected / 2


def test_dot_flops_unrolled():
    a = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    compiled = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    parsed = analyze_hlo(compiled.as_text())
    assert parsed["flops_per_device"] == pytest.approx(2 * 8 * 64 * 32,
                                                       rel=0.01)


def test_computation_parsing():
    compiled = jax.jit(lambda x: (x * 2).sum()).lower(
        jax.ShapeDtypeStruct((128,), jnp.float32)).compile()
    comps = parse_computations(compiled.as_text())
    assert any(c.is_entry for c in comps.values())


def test_model_flops_train_6nd():
    cfg = get_config("qwen2.5-32b")
    shape = SHAPES["train_4k"]
    mf = model_flops(cfg, shape)
    expected = 6 * cfg.param_count() * shape.global_batch * shape.seq_len
    assert mf == pytest.approx(expected)


def test_model_flops_moe_uses_active():
    cfg = get_config("arctic-480b")
    mf = model_flops(cfg, SHAPES["train_4k"])
    assert mf < 6 * cfg.param_count() * 256 * 4096 * 0.2


def test_report_structure():
    cfg = get_config("qwen2.5-32b")
    parsed = {"flops_per_device": 1e12, "bytes_per_device": 1e9,
              "collective_bytes_per_device": 1e8,
              "collective_breakdown": {}, "collective_counts": {},
              "n_computations": 3}
    rep = build_report(cfg, SHAPES["train_4k"], "16x16", 256, parsed)
    assert rep.dominant in ("compute", "memory", "collective")
    assert rep.step_time_s == max(rep.compute_s, rep.memory_s,
                                  rep.collective_s)
