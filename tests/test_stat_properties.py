"""Property tests for the analytic staleness machinery (satellite).

Runs under the real ``hypothesis`` when installed (CI does); the pinned
container falls back to ``tests/_hypothesis_compat.py``'s deterministic
seeded-draw stand-in, so tier-1 stays hermetic either way.

Pins, for ARBITRARY valid parameters (not just the hand-picked operating
points of the acceptance tests):

* Gilbert–Elliott chains hit their stationary targets exactly — both the
  fault chain (``pi_bad == dropout``) and the population availability
  chain (``pi_good == avail``) — whenever the feasibility validators
  admit the configuration;
* every pmf ``core/markov.py`` can emit (Lemma 1, lag-shifted, thinned,
  population-thinned) is nonnegative and sums to one;
* the shift (translation) and thin (geometric convolution) transforms
  commute with each other and shift composes additively — the algebra
  the composed async + churn predictions rely on.
"""

import numpy as np

from _hypothesis_compat import given, settings, st
from repro.core import faults, markov, population


def _chain(d: int, k_frac: float, km_frac: float) -> markov.FairKChain:
    """Map unconstrained draws onto a valid FairKChain parameterization
    (0 < k_m < k <= d/2, 0 < k0 < k_m)."""
    k = max(2, min(d // 2, int(round(k_frac * d / 2))))
    k_m = max(1, min(k - 1, int(round(km_frac * k))))
    k0 = max(1, min(k_m - 1, int(round(k_m * (1.0 - k_m / d))))) \
        if k_m > 1 else None
    if k0 is None:                       # k_m == 1 leaves no room for k0
        k_m, k = 2, max(3, k)
        k = min(k, d // 2)
        k0 = 1
    return markov.FairKChain(d=d, k=k, k_m=k_m, k0=k0)


# ---------------------------------------------------------------------------
# Gilbert–Elliott stationarity — fault chain and population chain
# ---------------------------------------------------------------------------

class TestGEStationarity:
    @settings(max_examples=25, deadline=None)
    @given(dropout=st.floats(min_value=0.01, max_value=0.6),
           burst_scale=st.floats(min_value=1.0, max_value=10.0))
    def test_fault_chain_hits_stationary_dropout(self, dropout, burst_scale):
        """For every (dropout, burst) the feasibility validator admits,
        ``ge_probs`` must deliver pi_bad = p_gb / (p_gb + p_bg) equal to
        the requested dropout — no silent clamping."""
        need = dropout / (1.0 - dropout)
        burst = max(1.0, need * burst_scale)
        cfg = faults.FaultConfig(dropout=dropout, burst=burst)
        p_gb, p_bg = faults.ge_probs(cfg)
        assert 0.0 < p_gb <= 1.0 and 0.0 < p_bg <= 1.0
        pi_bad = p_gb / (p_gb + p_bg)
        assert abs(pi_bad - dropout) < 1e-9
        assert abs(1.0 / p_bg - burst) < 1e-9     # mean bad dwell

    @settings(max_examples=25, deadline=None)
    @given(dropout=st.floats(min_value=0.01, max_value=0.6))
    def test_fault_chain_iid_special_case(self, dropout):
        """burst=None is the memoryless chain: next state independent of
        the current one, stationary mass still exactly ``dropout``."""
        p_gb, p_bg = faults.ge_probs(faults.FaultConfig(dropout=dropout))
        assert abs(p_gb - dropout) < 1e-12
        assert abs(p_gb + p_bg - 1.0) < 1e-12     # memoryless
        assert abs(p_gb / (p_gb + p_bg) - dropout) < 1e-9

    @settings(max_examples=25, deadline=None)
    @given(avail=st.floats(min_value=0.3, max_value=0.99),
           burst_scale=st.floats(min_value=1.0, max_value=10.0))
    def test_population_chain_hits_stationary_avail(self, avail, burst_scale):
        need = (1.0 - avail) / avail
        burst = max(1.0, need * burst_scale)
        cfg = population.PopulationConfig(
            n_clients=1024, cohort_size=256, participants=8,
            avail=avail, mode="ge", burst=burst)
        p_gb, p_bg = population.transition_probs(cfg)
        assert 0.0 < p_gb <= 1.0 and 0.0 < p_bg <= 1.0
        pi_good = p_bg / (p_gb + p_bg)
        assert abs(pi_good - avail) < 1e-9


# ---------------------------------------------------------------------------
# every markov pmf is a pmf
# ---------------------------------------------------------------------------

class TestPmfsNormalized:
    @settings(max_examples=10, deadline=None)
    @given(d=st.sampled_from([96, 128, 256]),
           k_frac=st.floats(min_value=0.2, max_value=1.0),
           km_frac=st.floats(min_value=0.1, max_value=0.9),
           lag=st.integers(min_value=0, max_value=7),
           thin=st.floats(min_value=0.0, max_value=0.7))
    def test_all_distributions(self, d, k_frac, km_frac, lag, thin):
        chain = _chain(d, k_frac, km_frac)
        for support, pmf in (
                markov.aou_distribution(chain),
                markov.shifted_aou_distribution(chain, lag),
                markov.thinned_aou_distribution(chain, thin)):
            assert (np.asarray(pmf) >= 0.0).all()
            assert abs(float(np.asarray(pmf).sum()) - 1.0) < 1e-6
            assert len(support) == len(pmf)

    @settings(max_examples=10, deadline=None)
    @given(d=st.sampled_from([96, 128, 256]),
           k_frac=st.floats(min_value=0.2, max_value=1.0),
           km_frac=st.floats(min_value=0.1, max_value=0.9),
           avail=st.floats(min_value=0.3, max_value=0.99),
           participants=st.integers(min_value=1, max_value=64))
    def test_population_distribution(self, d, k_frac, km_frac, avail,
                                     participants):
        chain = _chain(d, k_frac, km_frac)
        support, pmf = markov.population_aou_distribution(
            chain, avail, 1.0 - avail, participants)
        assert (np.asarray(pmf) >= 0.0).all()
        assert abs(float(np.asarray(pmf).sum()) - 1.0) < 1e-6
        # thinning only delays: population mean >= synchronous mean
        sync_s, sync_p = markov.aou_distribution(chain)
        assert float((support * pmf).sum()) >= \
            float((sync_s * sync_p).sum()) - 1e-9


# ---------------------------------------------------------------------------
# transform algebra: shift and thin compose
# ---------------------------------------------------------------------------

class TestTransformAlgebra:
    @settings(max_examples=10, deadline=None)
    @given(d=st.sampled_from([96, 128]),
           k_frac=st.floats(min_value=0.3, max_value=1.0),
           km_frac=st.floats(min_value=0.2, max_value=0.8),
           lag=st.integers(min_value=0, max_value=9),
           thin=st.floats(min_value=0.0, max_value=0.7))
    def test_shift_and_thin_commute(self, d, k_frac, km_frac, lag, thin):
        """A deterministic lag and an independent geometric delay add —
        the order of the transforms cannot matter."""
        base = markov.aou_distribution(_chain(d, k_frac, km_frac))
        s_a, p_a = markov.thin_pmf(*markov.shift_pmf(*base, lag), thin)
        s_b, p_b = markov.shift_pmf(*markov.thin_pmf(*base, thin), lag)
        assert int(s_a[0]) == int(s_b[0])
        n = min(len(p_a), len(p_b))
        np.testing.assert_allclose(p_a[:n], p_b[:n], atol=1e-12)
        assert float(np.abs(p_a[n:]).sum()) < 1e-9
        assert float(np.abs(p_b[n:]).sum()) < 1e-9

    @settings(max_examples=10, deadline=None)
    @given(lag1=st.integers(min_value=0, max_value=6),
           lag2=st.integers(min_value=0, max_value=6))
    def test_shift_composes_additively(self, lag1, lag2):
        base = markov.aou_distribution(
            markov.FairKChain(d=128, k=32, k_m=16, k0=14))
        s_ab, p_ab = markov.shift_pmf(*markov.shift_pmf(*base, lag1), lag2)
        s_sum, p_sum = markov.shift_pmf(*base, lag1 + lag2)
        np.testing.assert_array_equal(s_ab, s_sum)
        np.testing.assert_allclose(p_ab, p_sum, atol=0.0)

    @settings(max_examples=25, deadline=None)
    @given(avail=st.floats(min_value=0.3, max_value=0.99),
           participants=st.integers(min_value=1, max_value=64),
           exposure=st.floats(min_value=0.05, max_value=1.0))
    def test_population_thin_matches_config(self, avail, participants,
                                            exposure):
        """``markov.population_thin`` (numpy-side prediction) and
        ``PopulationConfig.thin`` (jax-side simulator) are the SAME
        number — the validation suite depends on that identity."""
        cfg = population.PopulationConfig(
            n_clients=1024, cohort_size=256, participants=participants,
            avail=avail, exposure=exposure)
        pred = markov.population_thin(avail, cfg.vanish_rate, participants,
                                      exposure)
        assert 0.0 <= pred <= 0.99
        assert abs(pred - cfg.thin) < 1e-12
