"""Property tests for the analytic staleness machinery (satellite).

Runs under the real ``hypothesis`` when installed (CI does); the pinned
container falls back to ``tests/_hypothesis_compat.py``'s deterministic
seeded-draw stand-in, so tier-1 stays hermetic either way.

Pins, for ARBITRARY valid parameters (not just the hand-picked operating
points of the acceptance tests):

* Gilbert–Elliott chains hit their stationary targets exactly — both the
  fault chain (``pi_bad == dropout``) and the population availability
  chain (``pi_good == avail``) — whenever the feasibility validators
  admit the configuration;
* every pmf ``core/markov.py`` can emit (Lemma 1, lag-shifted, thinned,
  population-thinned) is nonnegative and sums to one;
* the shift (translation) and thin (geometric convolution) transforms
  commute with each other and shift composes additively — the algebra
  the composed async + churn predictions rely on.
"""

import jax
import numpy as np

import statutil
from _hypothesis_compat import given, settings, st
from repro.core import channel as chan
from repro.core import faults, markov, population


def _chain(d: int, k_frac: float, km_frac: float) -> markov.FairKChain:
    """Map unconstrained draws onto a valid FairKChain parameterization
    (0 < k_m < k <= d/2, 0 < k0 < k_m)."""
    k = max(2, min(d // 2, int(round(k_frac * d / 2))))
    k_m = max(1, min(k - 1, int(round(km_frac * k))))
    k0 = max(1, min(k_m - 1, int(round(k_m * (1.0 - k_m / d))))) \
        if k_m > 1 else None
    if k0 is None:                       # k_m == 1 leaves no room for k0
        k_m, k = 2, max(3, k)
        k = min(k, d // 2)
        k0 = 1
    return markov.FairKChain(d=d, k=k, k_m=k_m, k0=k0)


# ---------------------------------------------------------------------------
# Gilbert–Elliott stationarity — fault chain and population chain
# ---------------------------------------------------------------------------

class TestGEStationarity:
    @settings(max_examples=25, deadline=None)
    @given(dropout=st.floats(min_value=0.01, max_value=0.6),
           burst_scale=st.floats(min_value=1.0, max_value=10.0))
    def test_fault_chain_hits_stationary_dropout(self, dropout, burst_scale):
        """For every (dropout, burst) the feasibility validator admits,
        ``ge_probs`` must deliver pi_bad = p_gb / (p_gb + p_bg) equal to
        the requested dropout — no silent clamping."""
        need = dropout / (1.0 - dropout)
        burst = max(1.0, need * burst_scale)
        cfg = faults.FaultConfig(dropout=dropout, burst=burst)
        p_gb, p_bg = faults.ge_probs(cfg)
        assert 0.0 < p_gb <= 1.0 and 0.0 < p_bg <= 1.0
        pi_bad = p_gb / (p_gb + p_bg)
        assert abs(pi_bad - dropout) < 1e-9
        assert abs(1.0 / p_bg - burst) < 1e-9     # mean bad dwell

    @settings(max_examples=25, deadline=None)
    @given(dropout=st.floats(min_value=0.01, max_value=0.6))
    def test_fault_chain_iid_special_case(self, dropout):
        """burst=None is the memoryless chain: next state independent of
        the current one, stationary mass still exactly ``dropout``."""
        p_gb, p_bg = faults.ge_probs(faults.FaultConfig(dropout=dropout))
        assert abs(p_gb - dropout) < 1e-12
        assert abs(p_gb + p_bg - 1.0) < 1e-12     # memoryless
        assert abs(p_gb / (p_gb + p_bg) - dropout) < 1e-9

    @settings(max_examples=25, deadline=None)
    @given(avail=st.floats(min_value=0.3, max_value=0.99),
           burst_scale=st.floats(min_value=1.0, max_value=10.0))
    def test_population_chain_hits_stationary_avail(self, avail, burst_scale):
        need = (1.0 - avail) / avail
        burst = max(1.0, need * burst_scale)
        cfg = population.PopulationConfig(
            n_clients=1024, cohort_size=256, participants=8,
            avail=avail, mode="ge", burst=burst)
        p_gb, p_bg = population.transition_probs(cfg)
        assert 0.0 < p_gb <= 1.0 and 0.0 < p_bg <= 1.0
        pi_good = p_bg / (p_gb + p_bg)
        assert abs(pi_good - avail) < 1e-9


# ---------------------------------------------------------------------------
# every markov pmf is a pmf
# ---------------------------------------------------------------------------

class TestPmfsNormalized:
    @settings(max_examples=10, deadline=None)
    @given(d=st.sampled_from([96, 128, 256]),
           k_frac=st.floats(min_value=0.2, max_value=1.0),
           km_frac=st.floats(min_value=0.1, max_value=0.9),
           lag=st.integers(min_value=0, max_value=7),
           thin=st.floats(min_value=0.0, max_value=0.7))
    def test_all_distributions(self, d, k_frac, km_frac, lag, thin):
        chain = _chain(d, k_frac, km_frac)
        for support, pmf in (
                markov.aou_distribution(chain),
                markov.shifted_aou_distribution(chain, lag),
                markov.thinned_aou_distribution(chain, thin)):
            assert (np.asarray(pmf) >= 0.0).all()
            assert abs(float(np.asarray(pmf).sum()) - 1.0) < 1e-6
            assert len(support) == len(pmf)

    @settings(max_examples=10, deadline=None)
    @given(d=st.sampled_from([96, 128, 256]),
           k_frac=st.floats(min_value=0.2, max_value=1.0),
           km_frac=st.floats(min_value=0.1, max_value=0.9),
           avail=st.floats(min_value=0.3, max_value=0.99),
           participants=st.integers(min_value=1, max_value=64))
    def test_population_distribution(self, d, k_frac, km_frac, avail,
                                     participants):
        chain = _chain(d, k_frac, km_frac)
        support, pmf = markov.population_aou_distribution(
            chain, avail, 1.0 - avail, participants)
        assert (np.asarray(pmf) >= 0.0).all()
        assert abs(float(np.asarray(pmf).sum()) - 1.0) < 1e-6
        # thinning only delays: population mean >= synchronous mean
        sync_s, sync_p = markov.aou_distribution(chain)
        assert float((support * pmf).sum()) >= \
            float((sync_s * sync_p).sum()) - 1e-9


# ---------------------------------------------------------------------------
# transform algebra: shift and thin compose
# ---------------------------------------------------------------------------

class TestTransformAlgebra:
    @settings(max_examples=10, deadline=None)
    @given(d=st.sampled_from([96, 128]),
           k_frac=st.floats(min_value=0.3, max_value=1.0),
           km_frac=st.floats(min_value=0.2, max_value=0.8),
           lag=st.integers(min_value=0, max_value=9),
           thin=st.floats(min_value=0.0, max_value=0.7))
    def test_shift_and_thin_commute(self, d, k_frac, km_frac, lag, thin):
        """A deterministic lag and an independent geometric delay add —
        the order of the transforms cannot matter."""
        base = markov.aou_distribution(_chain(d, k_frac, km_frac))
        s_a, p_a = markov.thin_pmf(*markov.shift_pmf(*base, lag), thin)
        s_b, p_b = markov.shift_pmf(*markov.thin_pmf(*base, thin), lag)
        assert int(s_a[0]) == int(s_b[0])
        n = min(len(p_a), len(p_b))
        np.testing.assert_allclose(p_a[:n], p_b[:n], atol=1e-12)
        assert float(np.abs(p_a[n:]).sum()) < 1e-9
        assert float(np.abs(p_b[n:]).sum()) < 1e-9

    @settings(max_examples=10, deadline=None)
    @given(lag1=st.integers(min_value=0, max_value=6),
           lag2=st.integers(min_value=0, max_value=6))
    def test_shift_composes_additively(self, lag1, lag2):
        base = markov.aou_distribution(
            markov.FairKChain(d=128, k=32, k_m=16, k0=14))
        s_ab, p_ab = markov.shift_pmf(*markov.shift_pmf(*base, lag1), lag2)
        s_sum, p_sum = markov.shift_pmf(*base, lag1 + lag2)
        np.testing.assert_array_equal(s_ab, s_sum)
        np.testing.assert_allclose(p_ab, p_sum, atol=0.0)

    @settings(max_examples=25, deadline=None)
    @given(avail=st.floats(min_value=0.3, max_value=0.99),
           participants=st.integers(min_value=1, max_value=64),
           exposure=st.floats(min_value=0.05, max_value=1.0))
    def test_population_thin_matches_config(self, avail, participants,
                                            exposure):
        """``markov.population_thin`` (numpy-side prediction) and
        ``PopulationConfig.thin`` (jax-side simulator) are the SAME
        number — the validation suite depends on that identity."""
        cfg = population.PopulationConfig(
            n_clients=1024, cohort_size=256, participants=participants,
            avail=avail, exposure=exposure)
        pred = markov.population_thin(avail, cfg.vanish_rate, participants,
                                      exposure)
        assert 0.0 <= pred <= 0.99
        assert abs(pred - cfg.thin) < 1e-12


# ---------------------------------------------------------------------------
# wireless channel: truncation law, composition, AR(1) fading
# ---------------------------------------------------------------------------

class TestChannelLaw:
    @settings(max_examples=20, deadline=None)
    @given(d=st.sampled_from([96, 128]),
           k_frac=st.floats(min_value=0.3, max_value=1.0),
           km_frac=st.floats(min_value=0.2, max_value=0.8),
           pmax=st.floats(min_value=0.5, max_value=100.0),
           gmin=st.floats(min_value=0.0, max_value=2.0),
           n=st.integers(min_value=1, max_value=16),
           pl=st.floats(min_value=0.0, max_value=4.0))
    def test_channel_pmf_is_pmf(self, d, k_frac, km_frac, pmax, gmin, n,
                                pl):
        """For ARBITRARY valid (pmax, gmin, gains) the truncated-inversion
        law stays a pmf, and its thinning rate stays inside [0, 0.99]."""
        gains = chan.ChannelConfig(n_clients=n, pmax=pmax, gmin=gmin,
                                   pl_exp=pl).gains
        t = markov.truncation_thin(pmax, gmin, gains)
        assert 0.0 <= t <= 0.99
        support, pmf = markov.channel_aou_distribution(
            _chain(d, k_frac, km_frac), pmax, gmin, gains)
        assert (np.asarray(pmf) >= 0.0).all()
        assert abs(float(np.asarray(pmf).sum()) - 1.0) < 1e-6
        assert len(support) == len(pmf)

    @settings(max_examples=20, deadline=None)
    @given(pmax=st.floats(min_value=1.0, max_value=50.0),
           gmin=st.floats(min_value=0.3, max_value=1.5),
           n=st.integers(min_value=1, max_value=8),
           extra=st.floats(min_value=0.0, max_value=0.7))
    def test_truncation_and_population_thin_commute(self, pmax, gmin, n,
                                                    extra):
        """Independent blocking channels compose symmetrically:
        1 - (1-t)(1-e) no matter which is folded in as ``extra_thin``."""
        chain = markov.FairKChain(d=128, k=32, k_m=16, k0=14)
        gains = chan.ChannelConfig(n_clients=n, pmax=pmax, gmin=gmin).gains
        t = markov.truncation_thin(pmax, gmin, gains)
        composed = min(0.99, 1.0 - (1.0 - t) * (1.0 - extra))
        s_a, p_a = markov.channel_aou_distribution(chain, pmax, gmin,
                                                   gains, extra_thin=extra)
        s_b, p_b = markov.thinned_aou_distribution(chain, composed)
        np.testing.assert_array_equal(s_a, s_b)
        np.testing.assert_allclose(p_a, p_b, atol=1e-12)

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(min_value=2, max_value=32),
           pmax=st.floats(min_value=1.0, max_value=50.0),
           gmin=st.floats(min_value=0.0, max_value=1.0),
           pl=st.floats(min_value=0.0, max_value=4.0),
           shadow=st.floats(min_value=0.0, max_value=6.0),
           seed=st.integers(min_value=0, max_value=999))
    def test_thin_identity_config_vs_markov(self, n, pmax, gmin, pl,
                                            shadow, seed):
        """``ChannelConfig.thin`` (simulator setpoint) and
        ``markov.truncation_thin`` (analysis law) are the SAME number for
        every deployment geometry — the controller absorbs exactly the
        rate the prediction assumes."""
        cfg = chan.ChannelConfig(n_clients=n, pmax=pmax, gmin=gmin,
                                 pl_exp=pl, shadow_db=shadow,
                                 geo_seed=seed)
        assert abs(cfg.thin
                   - markov.truncation_thin(pmax, gmin, cfg.gains)) < 1e-12


class TestFadingChain:
    @settings(max_examples=5, deadline=None)
    @given(rho=st.sampled_from([0.0, 0.5, 0.9]),
           seed=st.integers(min_value=0, max_value=99))
    def test_ar1_power_is_stationary_exp1(self, rho, seed):
        """|f|^2 of the complex AR(1) chain stays Exp(1)-distributed for
        every correlation: the innovation scaling sqrt(1 - rho^2)
        preserves the stationary Rayleigh marginal exactly.  Binned mass
        vs the analytic exponential via the statutil TV harness."""
        import jax.numpy as jnp
        cfg = chan.ChannelConfig(n_clients=512, rho_f=rho)
        st_ = chan.init_channel_state(jax.random.PRNGKey(seed), cfg)
        key = jax.random.PRNGKey(seed + 1)
        step = jax.jit(chan.fading_step, static_argnums=2)
        pows = []
        for r in range(60):
            key, sub = jax.random.split(key)
            st_ = {"fad": step(st_["fad"], sub, rho)}
            if r >= 20:
                f = np.asarray(st_["fad"])
                pows.append(f[:, 0] ** 2 + f[:, 1] ** 2)
        p = np.concatenate(pows)
        edges = np.linspace(0.0, 4.0, 17)
        emp_mass, _ = np.histogram(p, bins=edges)
        emp = np.concatenate([emp_mass / len(p),
                              [(p >= edges[-1]).mean()]])
        cdf = 1.0 - np.exp(-edges)
        pred = np.concatenate([np.diff(cdf), [np.exp(-edges[-1])]])
        # high rho_f correlates consecutive rounds (effective sample count
        # shrinks by the ~1/(1 - rho^2) mixing time), hence the tolerance
        assert statutil.tv_distance(emp, pred) < 0.05

    def test_fading_deterministic_in_state_and_key(self):
        cfg = chan.ChannelConfig(n_clients=64, rho_f=0.8)
        st0 = chan.init_channel_state(jax.random.PRNGKey(3), cfg)
        a = chan.fading_step(st0["fad"], jax.random.PRNGKey(4), cfg.rho_f)
        b = chan.fading_step(st0["fad"], jax.random.PRNGKey(4), cfg.rho_f)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        c = chan.fading_step(st0["fad"], jax.random.PRNGKey(5), cfg.rho_f)
        assert not np.array_equal(np.asarray(a), np.asarray(c))

    def test_rho_zero_is_memoryless(self):
        """At rho_f = 0 the next fading state is a pure function of the
        key — independent of the carried state."""
        key = jax.random.PRNGKey(7)
        s1 = chan.init_channel_state(jax.random.PRNGKey(0),
                                     chan.ChannelConfig(n_clients=32))
        s2 = chan.init_channel_state(jax.random.PRNGKey(1),
                                     chan.ChannelConfig(n_clients=32))
        a = chan.fading_step(s1["fad"], key, 0.0)
        b = chan.fading_step(s2["fad"], key, 0.0)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
