"""Markov staleness analysis (paper Sec. IV-B, Lemma 1, Fig. 3)."""

import numpy as np
import pytest

from repro.core import markov


@pytest.fixture(scope="module")
def chain():
    # Fig. 3 parameters: k=80, rho=0.1 (d=800), k_M/k=0.75, k_0/k_M=0.25
    return markov.FairKChain(d=800, k=80, k_m=60, k0=15)


class TestTransitionMatrix:
    def test_rows_sum_to_one(self, chain):
        P = markov.transition_matrix(chain)
        np.testing.assert_allclose(P.sum(axis=1), 1.0, atol=1e-12)

    def test_fresh_blocks_transitions(self, chain):
        P = markov.transition_matrix(chain)
        k_a = chain.k_a
        # AoU-selected entry: joins Top-k_M w.p. p2, else starts ageing
        assert P[0, k_a] == pytest.approx(chain.p2)
        assert P[0, chain.k] == pytest.approx(1 - chain.p2)
        # magnitude-selected entry: sticky w.p. 1 - p1
        assert P[k_a, k_a] == pytest.approx(1 - chain.p1)
        assert P[k_a, chain.k] == pytest.approx(chain.p1)

    def test_steady_state_is_stationary(self, chain):
        P = markov.transition_matrix(chain)
        pi = markov.steady_state(P)
        np.testing.assert_allclose(pi @ P, pi, atol=1e-8)
        assert pi.min() >= -1e-15

    def test_steady_state_fresh_mass(self, chain):
        """P(in I_M) should be ~ k_M/d; P(in I_A) ~ k_A/d."""
        P = markov.transition_matrix(chain)
        pi = markov.steady_state(P)
        # the collapsed-state approximation (footnote 2 truncation) shifts
        # the fresh-state masses by a few percent — order-of-magnitude check
        assert pi[chain.k_a] == pytest.approx(chain.k_m / chain.d, rel=0.2)
        assert pi[0] == pytest.approx(chain.k_a / chain.d, rel=0.2)


class TestLemma1:
    def test_pmf_valid(self, chain):
        support, pmf = markov.aou_distribution(chain)
        assert support[0] == 0 and support[-1] == chain.max_staleness
        assert pmf.min() >= 0
        np.testing.assert_allclose(pmf.sum(), 1.0, atol=1e-9)

    def test_matches_exchange_simulation(self, chain):
        """Fig. 3: analysis vs simulation under the exchange model."""
        _, pmf = markov.aou_distribution(chain)
        emp = markov.simulate_aou(chain, rounds=2500, seed=0, mode="exchange")
        tv = 0.5 * np.abs(pmf - emp).sum()
        assert tv < 0.06, f"TV distance {tv:.3f}"

    def test_matches_ar_simulation(self, chain):
        """Robustness to the simplified-exchange assumption (AR magnitudes)."""
        _, pmf = markov.aou_distribution(chain)
        emp = markov.simulate_aou(chain, rounds=2500, seed=1, mode="ar")
        tv = 0.5 * np.abs(pmf - emp).sum()
        assert tv < 0.10, f"TV distance {tv:.3f}"

    def test_expected_staleness_reasonable(self, chain):
        """E[tau] must lie strictly inside (0, T)."""
        e = markov.expected_staleness(chain)
        assert 0.0 < e < chain.max_staleness

    def test_more_age_budget_less_staleness(self):
        """Increasing k_A (lower k_m at fixed k) must reduce E[tau]."""
        base = dict(d=400, k=40, k0=7)
        e_hi_km = markov.expected_staleness(markov.FairKChain(k_m=30, **base))
        e_lo_km = markov.expected_staleness(markov.FairKChain(k_m=10, **base))
        assert e_lo_km < e_hi_km


def test_invalid_chain_params_rejected():
    with pytest.raises(ValueError):
        markov.FairKChain(d=100, k=60, k_m=30, k0=5)     # rho > 50%
    with pytest.raises(ValueError):
        markov.FairKChain(d=100, k=10, k_m=10, k0=5)     # k_a = 0
    with pytest.raises(ValueError):
        markov.FairKChain(d=100, k=10, k_m=5, k0=7)      # k0 >= k_m
