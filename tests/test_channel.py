"""Geometric wireless channel layer (core/channel.py, DESIGN.md §16).

Acceptance for the fading-channel robustness PR:

* config validation + the static geometry (gains / outage / thin) and its
  numerical identity with the analysis side (``markov.truncation_thin``);
* the post-update staleness pmf under truncated channel inversion matches
  ``markov.channel_aou_distribution`` within the suite-standard TV
  tolerance on the exact AND packed backends (memoryless ``rho_f = 0``
  runs — Lemma-1's geometric thinning is exact only for iid blocking; the
  AR(1)-correlated regime gets stationarity tests instead, see
  tests/test_stat_properties.py);
* the truncation × population-churn composition tracks the
  ``extra_thin``-composed law;
* ``faults.fade_mask`` stays bit-exact with the pre-channel inline draw
  after becoming an alias over ``channel.block_erase_mask``;
* trainer / sweep / launch integration: wireless rounds run finite and
  compose with one-bit, EF, watchdog, faults and population; the launch
  path persists + checkpoints the per-block fading chain and migrates
  pre-channel checkpoints by re-synthesizing the stationary draw.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import statutil
from repro.core import channel as chan
from repro.core import faults, markov, packing
from repro.core.engine import make_engine

pytestmark = pytest.mark.channel


# ---------------------------------------------------------------------------
# config validation + static geometry
# ---------------------------------------------------------------------------

class TestChannelConfig:
    def test_defaults_valid(self):
        cfg = chan.ChannelConfig()
        assert cfg.g_eff == pytest.approx(max(cfg.gmin, 1.0 / cfg.pmax))
        assert cfg.gains.shape == (cfg.n_clients,)
        assert np.all(cfg.gains > 0.0)
        assert np.all((cfg.outage > 0.0) & (cfg.outage < 1.0))
        assert 0.0 <= cfg.thin <= 0.99

    @pytest.mark.parametrize("kw", [
        dict(n_clients=0), dict(pmax=0.0), dict(pmax=-1.0),
        dict(pmax=float("inf")), dict(gmin=-0.1), dict(rho_f=-0.01),
        dict(rho_f=1.0), dict(csi_err=-0.5), dict(pl_exp=-1.0),
        dict(shadow_db=-2.0), dict(near=0.0), dict(near=1.5),
        dict(block=0),
    ])
    def test_rejects_bad_fields(self, kw):
        with pytest.raises(ValueError):
            chan.ChannelConfig(**kw)

    def test_gains_deterministic_and_ordered(self):
        """Same config -> same gains (pure function); nearer clients have
        the larger path gain when shadowing is off."""
        a = chan.ChannelConfig(n_clients=8, pl_exp=3.0, shadow_db=1.5,
                               geo_seed=7)
        np.testing.assert_array_equal(a.gains,
                                      chan.ChannelConfig(
                                          n_clients=8, pl_exp=3.0,
                                          shadow_db=1.5, geo_seed=7).gains)
        b = chan.ChannelConfig(n_clients=8, pl_exp=3.0)
        assert np.all(np.diff(b.gains) < 0.0)
        # different shadowing seed -> different deployment
        c = chan.ChannelConfig(n_clients=8, pl_exp=3.0, shadow_db=1.5,
                               geo_seed=8)
        assert not np.array_equal(a.gains, c.gains)

    def test_power_budget_floor_binds(self):
        """g_eff = max(gmin, 1/pmax): a tight power budget overrides a
        loose designed threshold."""
        assert chan.ChannelConfig(pmax=2.0, gmin=0.01).g_eff == 0.5
        assert chan.ChannelConfig(pmax=100.0, gmin=0.3).g_eff == 0.3

    def test_thin_matches_markov_truncation_thin(self):
        """The simulation's controller setpoint and the analysis law must
        be numerically IDENTICAL — same expm1/prod arithmetic."""
        for cfg in (chan.ChannelConfig(n_clients=4, near=1.0, pl_exp=0.0,
                                       gmin=1.0, pmax=10.0),
                    chan.ChannelConfig(n_clients=3, near=0.8, pl_exp=2.0,
                                       gmin=1.5, pmax=10.0),
                    chan.ChannelConfig(n_clients=16, shadow_db=4.0,
                                       geo_seed=3)):
            assert cfg.thin == markov.truncation_thin(cfg.pmax, cfg.gmin,
                                                      cfg.gains)


# ---------------------------------------------------------------------------
# fade_mask alias (satellite: one erasure code path)
# ---------------------------------------------------------------------------

def test_fade_mask_bit_exact_with_pre_channel_draw():
    """``faults.fade_mask`` is now a thin alias over
    ``channel.block_erase_mask`` — the draw must stay bit-exact with the
    pre-channel inline implementation (uniform-per-block + repeat)."""
    fcfg = faults.FaultConfig(fade=0.37, fade_block=96)
    d = 1000
    for s in range(3):
        key = jax.random.PRNGKey(s)
        nb = -(-d // fcfg.fade_block)
        hit = jax.random.uniform(key, (nb,)) < fcfg.fade
        want = jnp.repeat(hit.astype(jnp.float32), fcfg.fade_block)[:d]
        np.testing.assert_array_equal(
            np.asarray(faults.fade_mask(key, d, fcfg)), np.asarray(want))
    # fade = 0 short-circuits to exact zeros (no trace of the draw)
    z = faults.fade_mask(jax.random.PRNGKey(0), d,
                         faults.FaultConfig(fade=0.0))
    assert float(jnp.abs(z).sum()) == 0.0


# ---------------------------------------------------------------------------
# per-client chain semantics
# ---------------------------------------------------------------------------

class TestChannelRound:
    def test_deterministic_and_state_advances(self):
        cfg = chan.ChannelConfig(n_clients=6, rho_f=0.7)
        st = chan.init_channel_state(jax.random.PRNGKey(1), cfg)
        key = jax.random.PRNGKey(2)
        s1, r1 = chan.channel_round(st, key, cfg)
        s2, r2 = chan.channel_round(st, key, cfg)
        np.testing.assert_array_equal(np.asarray(s1["fad"]),
                                      np.asarray(s2["fad"]))
        np.testing.assert_array_equal(np.asarray(r1["sent"]),
                                      np.asarray(r2["sent"]))
        assert not np.array_equal(np.asarray(st["fad"]),
                                  np.asarray(s1["fad"]))
        assert float(r1["n_sent"]) == float(np.asarray(r1["sent"]).sum())

    def test_sent_iff_gain_clears_threshold(self):
        cfg = chan.ChannelConfig(n_clients=32, gmin=0.8, pmax=10.0)
        st = chan.init_channel_state(jax.random.PRNGKey(0), cfg)
        _, r = chan.channel_round(st, jax.random.PRNGKey(3), cfg)
        gain = np.asarray(r["gain"])
        np.testing.assert_array_equal(
            np.asarray(r["sent"]), (gain >= cfg.g_eff).astype(np.float32))

    def test_csi_weights(self):
        cfg0 = chan.ChannelConfig(n_clients=5, csi_err=0.0)
        np.testing.assert_array_equal(
            np.asarray(chan.csi_weights(jax.random.PRNGKey(0), 5, cfg0)),
            np.ones(5, np.float32))
        cfg = chan.ChannelConfig(n_clients=5, csi_err=0.1)
        w = np.asarray(chan.csi_weights(jax.random.PRNGKey(0), 5, cfg))
        assert w.shape == (5,) and not np.allclose(w, 1.0)
        assert np.all(np.abs(w - 1.0) < 1.0)       # 0.1 std: tiny misalign


# ---------------------------------------------------------------------------
# staleness law under truncated channel inversion (acceptance)
# ---------------------------------------------------------------------------

def _total_outage_masks(cfg: chan.ChannelConfig, d: int, rounds: int,
                        seed: int):
    """Per-round erase masks of the per-client chain: all-ones on a TOTAL
    truncation outage (nothing superposed -> round erased), None
    otherwise — exactly what the trainer's erase_with_outage produces."""
    step = jax.jit(chan.channel_round, static_argnums=2)
    st = chan.init_channel_state(jax.random.PRNGKey(seed), cfg)
    key = jax.random.PRNGKey(seed + 1)
    ones = np.ones((d,), np.float32)
    masks = []
    for _ in range(rounds):
        key, sub = jax.random.split(key)
        st, stats = step(st, sub, cfg)
        masks.append(ones if float(stats["n_sent"]) == 0.0 else None)
    return masks


def _pmf_engine(backend, d, k, k_m):
    if backend == "packed":
        eng = make_engine("fairk", "packed",
                          layout=packing.PackedLayout.from_tree(
                              [jnp.zeros((d,))], lane=1),
                          k=k, k_m=k_m, fused_stats=True, warm_start=True)
        return eng, packing.init_threshold_state()
    return make_engine("fairk", backend, d=d, k=k, k_m=k_m,
                       fused_stats=True), None


@pytest.mark.parametrize("backend", ["exact", "packed"])
@pytest.mark.parametrize("geo", ["homogeneous", "heterogeneous"])
def test_empirical_pmf_matches_channel_law(backend, geo):
    """Truncated channel inversion blocks a refresh exactly when every
    client is in outage at once; at ``rho_f = 0`` the blocking is iid
    across rounds, so the stationary post-update AoU pmf must track
    ``markov.channel_aou_distribution`` — the geometric thinning of
    Lemma 1 at rate ``truncation_thin`` — within the suite-standard TV
    tolerance, on the exact AND packed backends (seeded run,
    tests/statutil.py)."""
    d, k, k_m = 512, 64, 32
    # operating points chosen per the statutil doctrine (thin enough for
    # the geometric approximation, thick enough to test something: seeded
    # TVs land ~ 0.05-0.07 with the 0.1 tolerance)
    if geo == "homogeneous":
        cfg = chan.ChannelConfig(n_clients=4, near=1.0, pl_exp=0.0,
                                 gmin=0.9, pmax=10.0)       # thin ~ 0.124
    else:
        cfg = chan.ChannelConfig(n_clients=3, near=0.8, pl_exp=2.0,
                                 gmin=0.9, pmax=10.0)       # thin ~ 0.137
    rounds = 600
    masks = _total_outage_masks(cfg, d, rounds, seed=0)
    # the seeded empirical outage frequency must sit near the analytic
    # rate, or the pmf test below tests nothing
    frac = sum(m is not None for m in masks) / rounds
    assert abs(frac - cfg.thin) < 0.05
    eng, ts = _pmf_engine(backend, d, k, k_m)
    acc = statutil.accumulate_age_hist(
        eng, d, rounds=rounds, tstate=ts, sanitize=True,
        erase_fn=lambda r: masks[r], count_erased=True)
    k0 = int(round(k_m * (1 - k_m / d)))
    support, pred = markov.channel_aou_distribution(
        markov.FairKChain(d=d, k=k, k_m=k_m, k0=k0),
        cfg.pmax, cfg.gmin, cfg.gains)
    statutil.assert_pmf_close(acc, support, pred)


@pytest.mark.parametrize("backend", ["exact", "packed"])
def test_empirical_pmf_matches_composed_channel_churn_law(backend):
    """Truncation outage × an independent per-coordinate churn channel at
    rate ``extra_thin``: per-coordinate blocking composes as
    1 - (1-t)(1-e), which is exactly what
    ``channel_aou_distribution(..., extra_thin=e)`` folds into the
    thinned law."""
    d, k, k_m, extra = 512, 64, 32, 0.1
    cfg = chan.ChannelConfig(n_clients=4, near=1.0, pl_exp=0.0,
                             gmin=0.9, pmax=10.0)
    rounds = 600
    masks = _total_outage_masks(cfg, d, rounds, seed=1)
    rng = np.random.default_rng(2)

    def erase_fn(r):
        iid = (rng.random(d) < extra).astype(np.float32)
        return np.maximum(masks[r], iid) if masks[r] is not None else iid

    eng, ts = _pmf_engine(backend, d, k, k_m)
    acc = statutil.accumulate_age_hist(eng, d, rounds=rounds, tstate=ts,
                                       sanitize=True, erase_fn=erase_fn,
                                       count_erased=True)
    k0 = int(round(k_m * (1 - k_m / d)))
    support, pred = markov.channel_aou_distribution(
        markov.FairKChain(d=d, k=k, k_m=k_m, k0=k0),
        cfg.pmax, cfg.gmin, cfg.gains, extra_thin=extra)
    statutil.assert_pmf_close(acc, support, pred)


# ---------------------------------------------------------------------------
# analysis-side law (markov)
# ---------------------------------------------------------------------------

class TestMarkovChannelLaw:
    def test_truncation_thin_validates(self):
        gains = np.array([1.0, 0.5])
        for bad in (0.0, -1.0, float("inf"), float("nan")):
            with pytest.raises(ValueError):
                markov.truncation_thin(bad, 0.1, gains)
        with pytest.raises(ValueError):
            markov.truncation_thin(10.0, -0.1, gains)
        with pytest.raises(ValueError):
            markov.truncation_thin(10.0, 0.1, np.array([]))
        with pytest.raises(ValueError):
            markov.truncation_thin(10.0, 0.1, np.array([1.0, 0.0]))
        with pytest.raises(ValueError):
            markov.truncation_thin(10.0, 0.1, np.ones((2, 2)))

    def test_channel_aou_reduces_to_thinned_law(self):
        chain = markov.FairKChain(d=512, k=64, k_m=32, k0=30)
        cfg = chan.ChannelConfig(n_clients=4, near=1.0, pl_exp=0.0,
                                 gmin=1.0, pmax=10.0)
        s, p = markov.channel_aou_distribution(chain, cfg.pmax, cfg.gmin,
                                               cfg.gains)
        s2, p2 = markov.thinned_aou_distribution(chain, cfg.thin)
        np.testing.assert_array_equal(s, s2)
        np.testing.assert_allclose(p, p2, atol=1e-12)
        with pytest.raises(ValueError):
            markov.channel_aou_distribution(chain, cfg.pmax, cfg.gmin,
                                            cfg.gains, extra_thin=1.0)

    def test_extra_thin_composes_exactly(self):
        chain = markov.FairKChain(d=512, k=64, k_m=32, k0=30)
        cfg = chan.ChannelConfig(n_clients=4, near=1.0, pl_exp=0.0,
                                 gmin=1.0, pmax=10.0)
        e = 0.2
        s, p = markov.channel_aou_distribution(chain, cfg.pmax, cfg.gmin,
                                               cfg.gains, extra_thin=e)
        composed = 1.0 - (1.0 - cfg.thin) * (1.0 - e)
        s2, p2 = markov.thinned_aou_distribution(chain, composed)
        np.testing.assert_array_equal(s, s2)
        np.testing.assert_allclose(p, p2, atol=1e-12)


# ---------------------------------------------------------------------------
# trainer integration
# ---------------------------------------------------------------------------

def _toy_task(n_clients=4, local=2, batch=8):
    def loss_fn(params, x, y):
        pred = x @ params["w"] + params["b"]
        return jnp.mean((pred - y) ** 2)

    init = {"w": jnp.zeros((3,)), "b": jnp.zeros(())}

    def sample_round(t):
        r = np.random.default_rng(t)
        xs = r.normal(size=(n_clients, local, batch, 3)).astype(np.float32)
        ys = (xs @ np.array([1.0, -2.0, 0.5])).astype(np.float32)
        return xs, ys

    return init, loss_fn, sample_round


def _wcfg(n, **kw):
    base = dict(pmax=10.0, gmin=0.05, rho_f=0.6, csi_err=0.05,
                pl_exp=2.0, near=0.5)
    base.update(kw)
    return chan.ChannelConfig(n_clients=n, **base)


class TestTrainerWireless:
    N = 4

    def _run(self, **kw):
        from repro.fl import trainer
        init, loss_fn, sample_round = _toy_task(self.N)
        fl = trainer.FLConfig(n_clients=self.N, local_steps=2, batch_size=8,
                              rounds=6, compression_ratio=0.5, seed=3, **kw)
        hist = trainer.train(fl, init, loss_fn, sample_round)
        w = np.asarray(jax.flatten_util.ravel_pytree(hist["params"])[0])
        assert np.all(np.isfinite(w))
        return w

    @pytest.mark.parametrize("backend", ["exact", "threshold", "packed"])
    def test_wireless_round_runs_finite(self, backend):
        self._run(backend=backend, wireless=_wcfg(self.N))

    @pytest.mark.parametrize("backend", ["exact", "packed"])
    def test_one_bit_composes(self, backend):
        self._run(backend=backend, wireless=_wcfg(self.N), one_bit=True)

    def test_error_feedback_composes(self):
        self._run(backend="packed", wireless=_wcfg(self.N),
                  error_feedback=True)

    def test_watchdog_composes(self):
        self._run(backend="packed", wireless=_wcfg(self.N),
                  watchdog=faults.WatchdogConfig())

    def test_faults_and_population_compose(self):
        from repro.core import population
        pcfg = population.PopulationConfig(n_clients=1024, cohort_size=64,
                                           participants=self.N)
        self._run(backend="packed", wireless=_wcfg(self.N), population=pcfg,
                  faults=faults.FaultConfig(fade=0.05, nan_rate=0.01))
        self._run(backend="exact", wireless=_wcfg(self.N),
                  faults=faults.FaultConfig(dropout=0.2, fade=0.05))

    def test_scan_rounds_bit_exact(self):
        """The wireless fading carry must ride the lax.scan fusion on the
        same bit-exact trajectory as the per-round loop."""
        a = self._run(backend="packed", wireless=_wcfg(self.N))
        b = self._run(backend="packed", wireless=_wcfg(self.N),
                      scan_rounds=3)
        np.testing.assert_array_equal(a, b)

    def test_total_outage_round_merges_stale(self):
        """A config in permanent total outage (g_eff unreachable) must
        never refresh: ages climb every round, params never move, and no
        NaN reaches the merged state."""
        from repro.fl import trainer
        init, loss_fn, sample_round = _toy_task(self.N)
        # near=1, pl_exp=0 -> unit gains; gmin far above any Exp(1) draw
        wl = chan.ChannelConfig(n_clients=self.N, near=1.0, pl_exp=0.0,
                                gmin=60.0, pmax=1e6)
        fl = trainer.FLConfig(n_clients=self.N, local_steps=2, batch_size=8,
                              rounds=5, compression_ratio=0.5, backend="packed",
                              wireless=wl, seed=0)
        hist = trainer.train(fl, init, loss_fn, sample_round)
        w = np.asarray(jax.flatten_util.ravel_pytree(hist["params"])[0])
        np.testing.assert_array_equal(w, np.zeros_like(w))
        assert min(hist["mean_aou"]) > 0.0
        assert hist["mean_aou"][-1] == pytest.approx(5.0)

    def test_validation(self):
        from repro.fl import trainer
        init, loss_fn, _ = _toy_task(self.N)
        with pytest.raises(ValueError, match="n_clients"):
            trainer.make_fl_step(
                trainer.FLConfig(n_clients=self.N,
                                 wireless=_wcfg(self.N + 3)),
                lambda w: w, loss_fn, 4)
        with pytest.raises(ValueError, match="policy"):
            trainer.make_fl_step(
                trainer.FLConfig(n_clients=self.N, wireless=_wcfg(self.N),
                                 policy="randk"),
                lambda w: w, loss_fn, 4)


# ---------------------------------------------------------------------------
# sweep integration
# ---------------------------------------------------------------------------

class TestSweepWireless:
    def test_wireless_lanes_run_and_compose(self):
        from repro.core import population
        from repro.fl import sweep
        n = 8
        wl = _wcfg(n, gmin=0.2)
        base = dict(d=256, n_clients=n, rho=0.25, rounds=16)
        r = sweep.run_sweep(sweep.SweepConfig(wireless=wl, **base),
                            policies=("fairk", "fairk_auto"), n_seeds=2)
        assert np.all(np.isfinite(r["loss"]))
        assert "n_sent" in r and 0.0 <= r["n_sent"].mean() <= n
        pcfg = population.PopulationConfig(n_clients=1024, cohort_size=64,
                                           participants=n)
        r2 = sweep.run_sweep(
            sweep.SweepConfig(wireless=wl, population=pcfg,
                              faults=faults.FaultConfig(fade=0.05), **base),
            n_seeds=2)
        assert np.all(np.isfinite(r2["loss"]))

    def test_validation(self):
        from repro.fl import sweep
        with pytest.raises(ValueError, match="n_clients"):
            sweep.SweepConfig(n_clients=8, wireless=_wcfg(3))


# ---------------------------------------------------------------------------
# launch integration: persisted fading chain + checkpoint migration
# ---------------------------------------------------------------------------

def test_block_outage_calibration_and_determinism():
    """The aggregate-equivalent per-block chain: marginal erasure rate
    matches ``cfg.thin`` (the calibrated threshold on an Exp(1) gain) and
    the chain is deterministic in (state, key)."""
    cfg = chan.ChannelConfig(n_clients=2, near=1.0, pl_exp=0.0, gmin=1.0,
                             pmax=10.0, block=4)      # thin ~ 0.4
    d = 4096
    nb = chan.n_blocks(d, cfg)
    fad = chan.init_block_fading(nb)
    m1a, e1a = chan.block_outage(fad, jax.random.PRNGKey(5), d, cfg)
    m1b, e1b = chan.block_outage(fad, jax.random.PRNGKey(5), d, cfg)
    np.testing.assert_array_equal(np.asarray(m1a), np.asarray(m1b))
    np.testing.assert_array_equal(np.asarray(e1a), np.asarray(e1b))
    # long-run marginal erasure rate -> thin (memoryless rho_f = 0)
    hits, key = [], jax.random.PRNGKey(6)
    for _ in range(400):
        key, sub = jax.random.split(key)
        fad, er = chan.block_outage(fad, sub, d, cfg)
        hits.append(float(jnp.mean(er)))
    assert abs(np.mean(hits) - cfg.thin) < 0.03


def test_csi_block_factor_block_structure():
    cfg = chan.ChannelConfig(n_clients=16, csi_err=0.2, block=8)
    f = np.asarray(chan.csi_block_factor(jax.random.PRNGKey(0), 40, cfg))
    assert f.shape == (40,)
    blocks = f.reshape(5, 8)
    assert np.all(blocks == blocks[:, :1])     # constant within a block
    assert len(np.unique(blocks[:, 0])) == 5   # distinct across blocks
    z = chan.csi_block_factor(
        jax.random.PRNGKey(0), 40,
        chan.ChannelConfig(n_clients=16, csi_err=0.0, block=8))
    np.testing.assert_array_equal(np.asarray(z), np.ones(40, np.float32))


@pytest.mark.slow
class TestLaunchWireless:
    def _setup(self, oac):
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.launch import sharding as shlib
        from repro.launch.steps import (abstract_params,
                                        abstract_server_state,
                                        init_server_state, make_train_step)
        from repro.models import transformer as tr
        from repro.optim import make_optimizer
        cfg = get_config("mamba2-370m", reduced_variant=True)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        shape = InputShape("t", 64, 2, "train")
        bundle = make_train_step(cfg, shape, mesh, oac=oac)
        params = tr.init_lm(jax.random.PRNGKey(0), cfg)
        opt = make_optimizer(bundle.meta["optimizer"], 3e-3)
        opt_state = opt.init(params)
        server = init_server_state(params, mesh=mesh, cfg=cfg, oac=oac)
        params_abs = abstract_params(cfg)
        p_specs = shlib.param_pspecs(params_abs, cfg, mesh)
        srv_abs = abstract_server_state(params_abs, mesh=mesh,
                                        p_specs=p_specs, oac=oac)
        return cfg, mesh, bundle, params, opt_state, server, srv_abs

    def _steps(self, cfg, mesh, bundle, params, opt_state, server, n=2):
        from repro.data.tokens import lm_batch
        step = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                       out_shardings=bundle.out_shardings,
                       donate_argnums=(0, 1, 2))
        nm = bundle.meta["n_micro"]
        with mesh:
            for t in range(n):
                toks, labels = lm_batch(t, 2, 64, cfg.vocab)
                batch = {
                    "tokens": jnp.asarray(toks).reshape(nm, 2 // nm, 64),
                    "labels": jnp.asarray(labels).reshape(nm, 2 // nm, 64)}
                params, opt_state, server, loss = step(
                    params, opt_state, server, batch,
                    jnp.asarray(t, jnp.int32))
        return params, opt_state, server, loss

    def test_two_wireless_steps_and_persisted_fad(self):
        from repro.launch.steps import OacServerConfig
        oac = OacServerConfig(sanitize=True,
                              wireless=_wcfg(16, gmin=0.3, rho_f=0.5))
        (cfg, mesh, bundle, params, opt_state, server,
         srv_abs) = self._setup(oac)
        assert bundle.meta["oac_wireless"]
        assert set(server) == set(srv_abs) == {"g", "age", "theta", "fad"}
        fad0 = np.asarray(server["fad"]).copy()
        params, opt_state, server, loss = self._steps(
            cfg, mesh, bundle, params, opt_state, server)
        assert np.isfinite(float(loss))
        fad1 = np.asarray(server["fad"])
        assert fad1.shape == fad0.shape
        assert not np.array_equal(fad0, fad1)     # the chain advanced
        assert np.all(np.isfinite(fad1))
        ages = np.asarray(server["age"])
        assert (ages[ages < 0] == packing.PAD_AGE).all()

    def test_composes_with_fade_ef_async(self):
        from repro.launch.steps import OacServerConfig
        oac = OacServerConfig(sanitize=True, error_feedback=True,
                              async_agg=True, fade=0.05,
                              wireless=_wcfg(16, gmin=0.3))
        (cfg, mesh, bundle, params, opt_state, server,
         srv_abs) = self._setup(oac)
        assert set(server) == {"g", "age", "theta", "fad", "res",
                               "shadow", "pending"}
        *_, loss = self._steps(cfg, mesh, bundle, params, opt_state,
                               server)
        assert np.isfinite(float(loss))

    def test_requires_packed_sanitize(self):
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.launch.steps import OacServerConfig, make_train_step
        cfg = get_config("mamba2-370m", reduced_variant=True)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        shape = InputShape("t", 64, 2, "train")
        with pytest.raises(ValueError, match="sanitize"):
            make_train_step(cfg, shape, mesh,
                            oac=OacServerConfig(wireless=_wcfg(16)))
        with pytest.raises(ValueError, match="sanitize"):
            make_train_step(cfg, shape, mesh,
                            oac=OacServerConfig(packed=False, sanitize=True,
                                                wireless=_wcfg(16)))

    def test_checkpoint_roundtrip_and_migration(self, tmp_path):
        """A wireless checkpoint round-trips the fading chain bit-exactly;
        a PRE-channel checkpoint migrates by re-synthesizing the
        deterministic stationary draw (value-bearing — NOT zeros)."""
        from repro import checkpoint
        from repro.launch.steps import OacServerConfig
        oac = OacServerConfig(sanitize=True,
                              wireless=_wcfg(16, gmin=0.3, rho_f=0.5))
        (cfg, mesh, bundle, params, opt_state, server,
         srv_abs) = self._setup(oac)
        params, opt_state, server, _ = self._steps(
            cfg, mesh, bundle, params, opt_state, server)
        path = checkpoint.save_server_state(str(tmp_path / "w.npz"), server)
        back, _ = checkpoint.restore_server_state(path)
        np.testing.assert_array_equal(np.asarray(back["fad"]),
                                      np.asarray(server["fad"]))
        # pre-channel checkpoint: drop fad, migrate it back
        pre = {k: v for k, v in server.items() if k != "fad"}
        p2 = checkpoint.save_server_state(str(tmp_path / "pre.npz"), pre)
        srv_np, _ = checkpoint.restore_server_state(p2)
        out = checkpoint.migrate_server_state(srv_np, like=server)
        assert set(out) == set(server)
        np.testing.assert_array_equal(
            np.asarray(out["fad"]),
            np.asarray(chan.init_block_fading(
                int(server["fad"].shape[0]) // 2)))
        assert float(np.abs(np.asarray(out["fad"])).sum()) > 0.0
        # dropping the fading chain in the wireless -> plain direction
        # still rejects (it would silently lose the outage correlation)
        with pytest.raises(ValueError, match="fad"):
            checkpoint.migrate_server_state(dict(server), like=pre)
