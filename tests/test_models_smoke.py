"""Per-architecture smoke tests (assignment requirement): reduced variant of
each family (2 scan-blocks, d_model <= 256, <= 4 experts) runs one forward /
train step on CPU — asserting output shapes and no NaNs — plus prefill/decode
consistency against teacher forcing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import transformer as tr

ARCH_NAMES = sorted(ARCHS)


def _batch(cfg, b=2, s=48, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)).astype("i4"))
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["embeds"] = 0.1 * jnp.ones((b, cfg.n_patches, cfg.d_model),
                                         jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jnp.ones((b, cfg.encoder_seq, cfg.d_model),
                                         jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_config_limits(arch):
    cfg = get_config(arch, reduced_variant=True)
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4
    assert cfg.n_layers <= 2 * cfg.scan_block <= 16
    assert cfg.family == get_config(arch).family


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_train_step(arch):
    cfg = get_config(arch, reduced_variant=True)
    params = tr.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, aux = tr.forward_train(params, cfg, batch["tokens"],
                                   embeds=batch.get("embeds"),
                                   frames=batch.get("frames"))
    b, s = batch["tokens"].shape
    assert logits.shape == (b, s, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # one SGD train step must produce finite loss + grads and change params
    def loss(p):
        return tr.loss_fn(p, cfg, batch)[0]
    l0, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l0))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    new_params = jax.tree.map(lambda p, g: p - 0.05 * g.astype(p.dtype),
                              params, grads)
    l1 = float(loss(new_params))
    assert np.isfinite(l1)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_consistency(arch):
    """decode_step with a cache must agree with teacher forcing (bf16 tol).

    MoE archs use a no-drop capacity factor here: capacity-based routing
    drops overflow tokens under teacher forcing but never in single-token
    decode, so exact parity only holds without drops (standard MoE serving
    caveat)."""
    import dataclasses
    cfg = get_config(arch, reduced_variant=True)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = tr.init_lm(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg, b=1, s=33, seed=3)
    toks = batch["tokens"]
    kw = {k: batch[k] for k in ("embeds", "frames") if k in batch}
    full, _ = tr.forward_train(params, cfg, toks, **kw)
    caches = tr.init_caches(cfg, 1, capacity=34 + (cfg.n_patches or 0))
    lg, caches = tr.prefill(params, cfg, toks[:, :32], caches, **kw)
    scale = max(1.0, float(np.abs(np.asarray(full, np.float32)).max()))
    err = np.abs(np.asarray(lg[0, 0], np.float32)
                 - np.asarray(full[0, 31], np.float32)).max() / scale
    assert err < 0.03, f"prefill mismatch {err}"
    pos = 32 + (cfg.n_patches or 0)
    lg2, _ = tr.decode_step(params, cfg, toks[:, 32:33], jnp.asarray(pos),
                            caches)
    err2 = np.abs(np.asarray(lg2[0, 0], np.float32)
                  - np.asarray(full[0, 32], np.float32)).max() / scale
    assert err2 < 0.05, f"decode mismatch {err2}"


def test_full_configs_match_published_sizes():
    targets = {
        "mistral-large-123b": 123e9, "whisper-base": 74e6,
        "mamba2-370m": 370e6, "internvl2-1b": 0.63e9, "deepseek-67b": 67e9,
        "granite-34b": 34e9, "granite-moe-3b-a800m": 3.3e9,
        "qwen2.5-32b": 32e9, "jamba-1.5-large-398b": 398e9,
        "arctic-480b": 480e9,
    }
    for name, target in targets.items():
        n = get_config(name).param_count()
        assert abs(n - target) / target < 0.35, (name, n, target)


def test_moe_active_params_smaller():
    for name in ("granite-moe-3b-a800m", "arctic-480b",
                 "jamba-1.5-large-398b"):
        cfg = get_config(name)
        assert cfg.active_param_count() < 0.5 * cfg.param_count()


def test_long_context_support_flags():
    for name, cfg in ARCHS.items():
        assert cfg.supports_long_context, name  # via SSM/hybrid or window
