"""Fused selection statistics (DESIGN.md §11).

Pins the tentpole guarantees of the one-HBM-pass server round:

* the kernel-emitted counts are bit-exact vs the legacy two-pass
  accounting, and cross-backend consistent: exact ≡ threshold ≡ sharded ≡
  packed under ``exact_theta`` on tie-free inputs — for ``n_sel``,
  ``n_sel_m``, the magnitude/age histograms AND the thresholds derived
  from those histograms;
* pad coordinates (age = PAD_AGE sentinel) are excluded from every
  in-kernel counter and histogram;
* ``packing.hist_thresholds`` reproduces sampled-quantile-grade budget
  tracking from the histograms alone (incl. the degenerate-stage and
  empty-histogram fallbacks);
* the warm-start controller runs entirely on carried statistics: steady
  state keeps tracking the budget with ZERO trace-time reads of g beyond
  the fused kernel itself, on the packed AND the sharded backend;
* the packed server-state checkpoint (repro.checkpoint) round-trips the
  flat bf16/int8/f32 buffers + PackedLayout metadata bit-exactly and an
  exactly-restarted round reproduces the original.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.core import packing
from repro.core.engine import EngineConfig, SelectionEngine
from repro.kernels import ops, ref


def _tie_free(d, seed=0):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=d).astype("f4"))
    gp = jnp.asarray(rng.normal(size=d).astype("f4"))
    age = jnp.asarray(rng.permutation(d).astype("f4"))
    return g, gp, age


def _stats_of(stats):
    return (float(stats["n_selected"]) if "n_selected" in stats
            else float(stats["n_sel"]),
            float(stats["n_sel_m"]),
            np.asarray(stats["mag_hist"]),
            np.asarray(stats["age_hist"]))


# ---------------------------------------------------------------------------
# cross-backend parity of the fused statistics (the acceptance criterion)
# ---------------------------------------------------------------------------

class TestCrossBackendStatsParity:
    def test_exact_threshold_sharded_packed_agree(self):
        d = 4096
        g, gp, age = _tie_free(d)
        common = dict(policy="fairk", rho=0.1, k_m_frac=0.75,
                      exact_theta=True, fused_stats=True)
        ex = SelectionEngine(EngineConfig(backend="exact", **common), d)
        th = SelectionEngine(EngineConfig(backend="threshold", **common), d)
        mesh = jax.make_mesh((1,), ("shard",))
        sh = SelectionEngine(EngineConfig(backend="sharded", **common), d,
                             mesh=mesh)
        lay = packing.PackedLayout.from_tree([jnp.zeros((d,))])
        assert lay.d_packed == d                   # lane-aligned, no pads
        pk = SelectionEngine(EngineConfig(backend="packed", **common), d,
                             layout=lay)
        outs = [jax.jit(e.select_and_merge)(g, gp, age)
                for e in (ex, th, sh, pk)]
        n0, nm0, mh0, ah0 = _stats_of(outs[0][2])
        for g_t, age_next, stats in outs[1:]:
            np.testing.assert_array_equal(np.asarray(outs[0][0]),
                                          np.asarray(g_t))
            np.testing.assert_array_equal(np.asarray(outs[0][1]),
                                          np.asarray(age_next))
            n, nm, mh, ah = _stats_of(stats)
            assert n == n0 and nm == nm0
            np.testing.assert_array_equal(mh0, mh)
            np.testing.assert_array_equal(ah0, ah)
        # histogram-derived thresholds are a pure function of the (equal)
        # histograms -> equal across backends
        thetas = [packing.hist_thresholds(
            jnp.asarray(mh0), jnp.asarray(ah0), rho=0.1, k_m_frac=0.75)]
        for _, _, stats in outs[1:]:
            _, _, mh, ah = _stats_of(stats)
            tm, ta = packing.hist_thresholds(jnp.asarray(mh),
                                             jnp.asarray(ah),
                                             rho=0.1, k_m_frac=0.75)
            assert float(tm) == float(thetas[0][0])
            assert float(ta) == float(thetas[0][1])

    def test_counts_match_legacy_two_pass_accounting(self):
        """Bit-exact vs the accounting the fused path replaces:
        n_sel == (age'==0).sum(), n_sel_m == (sel & |score|>=θ_M).sum()."""
        d = 8192
        g, gp, age = _tie_free(d, seed=3)
        res = jnp.asarray(
            np.random.default_rng(4).normal(size=d).astype("f4"))
        for fused in (False, True):
            eng = SelectionEngine(
                EngineConfig(policy="fairk", backend="packed", rho=0.1,
                             k_m_frac=0.75, warm_start=True,
                             fused_stats=fused),
                d, layout=packing.PackedLayout.from_tree([jnp.zeros((d,))]))
            _, age_next, stats = eng.select_and_merge(
                g, gp, age, residual=res,
                tstate=packing.init_threshold_state())
            ts = stats["tstate"]
            sel = (np.asarray(age_next) == 0.0).astype(np.float32)
            score = np.asarray(g) + np.asarray(res)
            tm = float(stats["theta_m"])
            if fused:
                fused_counts = (float(ts["n_sel"]), float(ts["n_sel_m"]))
            assert float(ts["n_sel"]) == sel.sum()
            assert float(ts["n_sel_m"]) == (sel
                                            * (np.abs(score) >= tm)).sum()
        # and the two modes agree with each other (same θ bootstrap on
        # round 0 would differ: legacy samples quantiles, fused starts
        # from the empty histogram — so compare against the realised
        # masks, which is what the assertions above already did)
        assert fused_counts[0] > 0


# ---------------------------------------------------------------------------
# pad exclusion from every in-kernel counter
# ---------------------------------------------------------------------------

class TestPadExclusion:
    @pytest.mark.parametrize("mode", ["ref", "interpret"])
    def test_interior_pads_never_counted(self, mode):
        rng = np.random.default_rng(7)
        d = 2048
        g = jnp.asarray(rng.normal(size=d).astype("f4"))
        gp = jnp.asarray(rng.normal(size=d).astype("f4"))
        age = jnp.asarray(rng.integers(0, 40, d).astype("f4"))
        pad = np.zeros(d, bool)
        pad[300:812] = True                     # interior pad block
        g = g.at[300:812].set(7.7)              # huge |g|: would select
        age = age.at[300:812].set(packing.PAD_AGE)
        g_t, age_next, _, stats = ops.fairk_stats_update(
            g, gp, age, jnp.float32(0.5), jnp.float32(0.0), mode=mode,
            block_size=256)
        n_valid = int((~pad).sum())
        # θ_A = 0 selects every valid coordinate; pads select nothing
        assert float(stats["n_sel"]) == n_valid
        assert float(stats["n_sel_m"]) <= n_valid
        stride = packing.hist_stride(d)
        n_sampled = int((~pad)[::stride].sum())
        assert float(stats["mag_hist"].sum()) == n_sampled
        assert float(stats["age_hist"].sum()) == n_sampled
        # the pads' huge magnitude must not appear in the histogram: all
        # sampled |score| < 2 except the pad 7.7s
        top_bin = int(np.asarray(packing.mag_bin(jnp.float32(7.7))))
        assert float(stats["mag_hist"][top_bin]) == 0.0

    @pytest.mark.parametrize("mode", ["ref", "interpret"])
    def test_kernel_equals_oracle_with_pads(self, mode):
        rng = np.random.default_rng(9)
        d = 5000                                # odd: exercises tail pads
        g = jnp.asarray(rng.normal(size=d).astype("f4"))
        gp = jnp.asarray(rng.normal(size=d).astype("f4"))
        age = jnp.asarray((rng.permutation(d) % 120).astype("f4"))
        res = jnp.asarray(rng.normal(size=d).astype("f4"))
        fresh = jnp.where(g + res >= 0, 1.0, -1.0)
        out_r = ops.fairk_stats_update(g, gp, age, jnp.float32(1.1),
                                       jnp.float32(60.0), residual=res,
                                       fresh=fresh, mode="ref")
        out_k = ops.fairk_stats_update(g, gp, age, jnp.float32(1.1),
                                       jnp.float32(60.0), residual=res,
                                       fresh=fresh, mode=mode,
                                       block_size=512)
        for a, b in zip(out_r[:3], out_k[:3]):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6)
        for key in ("n_sel", "n_sel_m", "mag_hist", "age_hist"):
            np.testing.assert_array_equal(np.asarray(out_r[3][key]),
                                          np.asarray(out_k[3][key]))


# ---------------------------------------------------------------------------
# histogram-derived thresholds
# ---------------------------------------------------------------------------

class TestHistThresholds:
    def test_tracks_budget_like_sampled_quantiles(self):
        rng = np.random.default_rng(1)
        d = 1 << 16
        g = jnp.asarray(rng.normal(size=d).astype("f4"))
        age = jnp.asarray((rng.permutation(d) % 80).astype("f4"))
        _, _, _, stats = ops.fairk_stats_update(
            g, jnp.zeros((d,)), age, jnp.float32(jnp.inf),
            jnp.float32(jnp.inf), mode="ref")
        tm, ta = packing.hist_thresholds(stats["mag_hist"],
                                         stats["age_hist"],
                                         rho=0.1, k_m_frac=0.75)
        n_m = int((np.abs(np.asarray(g)) >= float(tm)).sum())
        assert abs(n_m - 0.075 * d) < 0.1 * 0.075 * d   # within 10%
        rho_a = 0.025 / (1 - 0.075)
        # age_hist is the POST-update distribution; with θ = inf nothing
        # selects, so ages advanced by one — θ_A targets that shifted
        # distribution, as next round's selection will see it
        n_a = int(((np.asarray(age) + 1.0) >= float(ta)).sum())
        assert abs(n_a - rho_a * d) < 0.35 * rho_a * d

    def test_degenerate_stage_budgets_are_inf(self):
        h = jnp.ones((packing.STATS_MAG_BINS,), jnp.float32)
        a = jnp.ones((packing.STATS_AGE_BINS,), jnp.float32)
        tm, ta = packing.hist_thresholds(h, a, rho=0.1, k_m_frac=1.0)
        assert np.isinf(float(ta)) and np.isfinite(float(tm))
        tm, ta = packing.hist_thresholds(h, a, rho=0.1, k_m_frac=0.0)
        assert np.isinf(float(tm)) and np.isfinite(float(ta))

    def test_empty_histogram_selects_everything(self):
        """Round 0 fallback: no histogram yet -> θ = 0 -> one full-refresh
        round (every valid coordinate transmits), then self-heals."""
        z = jnp.zeros((packing.STATS_MAG_BINS,), jnp.float32)
        tm, ta = packing.hist_thresholds(z, z, rho=0.1, k_m_frac=0.75)
        assert float(tm) == 0.0 and float(ta) == 0.0


# ---------------------------------------------------------------------------
# warm-start on carried statistics (packed + sharded)
# ---------------------------------------------------------------------------

class TestFusedWarmStart:
    def _run_rounds(self, eng, lay, rounds=120, seed=0):
        rng = np.random.default_rng(seed)
        d = lay.d_packed
        gp = jnp.zeros((d,), jnp.float32)
        ag = lay.init_age(jnp.float32)
        ts = packing.init_threshold_state()
        step = jax.jit(lambda g, gp, ag, ts:
                       eng.select_and_merge(g, gp, ag, tstate=ts))
        sels = []
        for r in range(rounds):
            g = lay.pack([jnp.asarray(
                rng.normal(size=(lay.d_valid,)).astype("f4"))])
            g_t, ag2, stats = step(g, gp, ag, ts)
            ts, gp, ag = stats["tstate"], g_t, ag2
            sels.append(float(stats["n_selected"]))
        return np.asarray(sels), ts

    def test_packed_steady_state_tracks_budget_without_bootstrap(self):
        lay = packing.PackedLayout.from_tree([jnp.zeros((20000,))])
        eng = SelectionEngine(
            EngineConfig(policy="fairk", backend="packed", rho=0.1,
                         k_m_frac=0.75, warm_start=True, fused_stats=True),
            lay.d_packed, layout=lay)
        k = eng.budgets()[0]
        sels, ts = self._run_rounds(eng, lay)
        assert sels[0] == lay.d_valid          # round-0 full refresh
        assert abs(np.mean(sels[60:]) - k) < 0.15 * k
        assert max(sels[10:]) < 2.5 * k        # no cohort blow-ups
        assert float(ts["mag_hist"].sum()) > 0

    def test_packed_round_traces_one_read(self):
        """The acceptance claim at engine level: a steady-state
        select_and_merge traces exactly ONE read of g."""
        lay = packing.PackedLayout.from_tree([jnp.zeros((4096,))])
        eng = SelectionEngine(
            EngineConfig(policy="fairk", backend="packed", rho=0.1,
                         k_m_frac=0.75, warm_start=True, fused_stats=True),
            lay.d_packed, layout=lay)
        g, gp, age = _tie_free(lay.d_packed, seed=5)
        ts = packing.init_threshold_state()
        before = packing.G_READS
        jax.eval_shape(lambda *a: eng.select_and_merge(
            a[0], a[1], a[2], tstate=ts), g, gp, age)
        assert packing.G_READS - before == 1

    def test_sharded_warm_start_from_reduced_stats(self):
        """The sharded backend accepts tstate and its steady state stops
        bootstrapping per-shard thresholds every round: counts keep
        tracking the GLOBAL budget from the psum'd statistics."""
        d = 16384
        mesh = jax.make_mesh((1,), ("shard",))
        eng = SelectionEngine(
            EngineConfig(policy="fairk", backend="sharded", rho=0.1,
                         k_m_frac=0.75, warm_start=True, fused_stats=True),
            d, mesh=mesh)
        k = eng.budgets()[0]
        rng = np.random.default_rng(11)
        gp = jnp.zeros((d,), jnp.float32)
        ag = jnp.zeros((d,), jnp.float32)
        ts = packing.init_threshold_state()
        step = jax.jit(lambda g, gp, ag, ts:
                       eng.select_and_merge(g, gp, ag, tstate=ts))
        sels = []
        for r in range(100):
            g = jnp.asarray(rng.normal(size=d).astype("f4"))
            g_t, ag2, stats = step(g, gp, ag, ts)
            ts, gp, ag = stats["tstate"], g_t, ag2
            sels.append(float(stats["n_selected"]))
        assert sels[0] == d                    # round-0 full refresh
        assert abs(np.mean(sels[60:]) - k) < 0.2 * k
        assert float(ts["n_sel_m"]) > 0

    def test_sharded_without_tstate_unchanged(self):
        """No tstate -> the historical per-shard bootstrap path (with the
        stats riding along when fused_stats is on)."""
        d = 8192
        mesh = jax.make_mesh((1,), ("shard",))
        g, gp, age = _tie_free(d, seed=13)
        eng = SelectionEngine(
            EngineConfig(policy="fairk", backend="sharded", rho=0.1,
                         k_m_frac=0.75, fused_stats=True), d, mesh=mesh)
        _, _, stats = jax.jit(eng.select_and_merge)(g, gp, age)
        k = eng.budgets()[0]
        assert abs(float(stats["n_selected"]) - k) < 0.2 * k
        assert float(stats["mag_hist"].sum()) > 0


# ---------------------------------------------------------------------------
# threshold-state vector round trip (now carries the histograms)
# ---------------------------------------------------------------------------

def test_threshold_state_vec_round_trips_histograms():
    ts = packing.init_threshold_state()
    ts["theta_m"] = jnp.float32(1.5)
    ts["mag_hist"] = ts["mag_hist"].at[7].set(42.0)
    ts["age_hist"] = ts["age_hist"].at[100].set(3.0)
    vec = packing.threshold_state_to_vec(ts)
    assert vec.shape == (packing.THRESHOLD_STATE_SIZE,)
    back = packing.threshold_state_from_vec(vec)
    for f in packing.THRESHOLD_STATE_FIELDS:
        assert float(back[f]) == float(ts[f])
    np.testing.assert_array_equal(np.asarray(back["mag_hist"]),
                                  np.asarray(ts["mag_hist"]))
    np.testing.assert_array_equal(np.asarray(back["age_hist"]),
                                  np.asarray(ts["age_hist"]))


# ---------------------------------------------------------------------------
# packed server-state checkpoint round trip (satellite)
# ---------------------------------------------------------------------------

class TestServerStateCheckpoint:
    def _server_and_layout(self, seed=0):
        rng = np.random.default_rng(seed)
        leaves = [jnp.zeros((300,)), jnp.zeros((512,)), jnp.zeros((77,))]
        lay = packing.PackedLayout.from_tree(leaves)
        d = lay.d_packed
        server = {
            "g": jnp.asarray(rng.normal(size=d).astype("f4")
                             ).astype(jnp.bfloat16),
            "age": jnp.asarray(rng.integers(-1, 100, d).astype("i1")),
            "res": jnp.asarray(rng.normal(size=d).astype("f4")),
            "theta": packing.threshold_state_to_vec(
                packing.init_threshold_state()),
        }
        return server, lay

    def test_round_trip_bit_exact(self, tmp_path):
        server, lay = self._server_and_layout()
        path = checkpoint.save_server_state(
            str(tmp_path / "srv.npz"), server, layout=lay)
        back, meta = checkpoint.restore_server_state(path, layout=lay)
        assert set(back) == set(server)
        for k2 in server:
            a = np.asarray(server[k2])
            b = back[k2]
            assert a.dtype == b.dtype, k2
            np.testing.assert_array_equal(
                a.view(np.uint8), np.asarray(b).view(np.uint8))
        assert packing.layout_matches(lay, meta)

    def test_restore_rejects_mismatched_layout(self, tmp_path):
        server, lay = self._server_and_layout()
        path = checkpoint.save_server_state(
            str(tmp_path / "srv.npz"), server, layout=lay)
        other = packing.PackedLayout.from_tree([jnp.zeros((1024,))])
        with pytest.raises(ValueError):
            checkpoint.restore_server_state(path, layout=other)

    def test_exact_restart_round(self, tmp_path):
        """The acceptance test: a server round run from restored buffers
        is bit-identical to the round run from the originals."""
        server, lay = self._server_and_layout(seed=2)
        eng = SelectionEngine(
            EngineConfig(policy="fairk", backend="packed", rho=0.1,
                         k_m_frac=0.75, warm_start=True, fused_stats=True),
            lay.d_packed, layout=lay)
        rng = np.random.default_rng(3)
        g = jnp.asarray(rng.normal(size=lay.d_packed).astype("f4"))
        path = checkpoint.save_server_state(
            str(tmp_path / "srv.npz"), server, layout=lay)
        back, _ = checkpoint.restore_server_state(path, layout=lay)

        def round_(srv):
            ts = packing.threshold_state_from_vec(jnp.asarray(srv["theta"]))
            g_t, age_next, stats = eng.select_and_merge(
                g, jnp.asarray(srv["g"]).astype(jnp.float32),
                jnp.asarray(srv["age"]).astype(jnp.float32),
                residual=jnp.asarray(srv["res"]), tstate=ts)
            return g_t, age_next, stats["residual"]

        for a, b in zip(round_(server), round_(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_server_step(self, tmp_path):
        server, lay = self._server_and_layout()
        assert checkpoint.latest_server_step(str(tmp_path)) is None
        checkpoint.save_server_state(str(tmp_path), server, layout=lay,
                                     step=3)
        checkpoint.save_server_state(str(tmp_path), server, layout=lay,
                                     step=11)
        assert checkpoint.latest_server_step(str(tmp_path)) == 11


# ---------------------------------------------------------------------------
# launch integration: fused stats + one-bit update_phase (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestLaunchIntegration:
    def _run_steps(self, oac, n=3):
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.data.tokens import lm_batch
        from repro.launch.steps import init_server_state, make_train_step
        from repro.models import transformer as tr
        from repro.optim import make_optimizer
        cfg = get_config("mamba2-370m", reduced_variant=True)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        shape = InputShape("t", 64, 2, "train")
        bundle = make_train_step(cfg, shape, mesh, oac=oac)
        params = tr.init_lm(jax.random.PRNGKey(0), cfg)
        opt = make_optimizer(bundle.meta["optimizer"], 3e-3)
        opt_state = opt.init(params)
        server = init_server_state(params, mesh=mesh, cfg=cfg, oac=oac)
        step = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                       out_shardings=bundle.out_shardings)
        nm = bundle.meta["n_micro"]
        with mesh:
            for t in range(n):
                toks, labels = lm_batch(t, 2, 64, cfg.vocab)
                batch = {
                    "tokens": jnp.asarray(toks).reshape(nm, 2 // nm, 64),
                    "labels": jnp.asarray(labels).reshape(nm, 2 // nm, 64)}
                params, opt_state, server, loss = step(
                    params, opt_state, server, batch,
                    jnp.asarray(t, jnp.int32))
        return server, float(loss)

    def test_fused_stats_update_phase(self):
        from repro.launch.steps import OacServerConfig
        server, loss = self._run_steps(OacServerConfig())
        assert np.isfinite(loss)
        ages = np.asarray(server["age"])
        valid = ages >= 0
        # step 0 is the full refresh; steps 1-2 run on hist thresholds —
        # the fresh fraction must be back near the rho = 0.1 budget
        frac = (ages[valid] == 0).mean()
        assert 0.02 < frac < 0.35, frac
        theta = np.asarray(server["theta"])
        assert theta.shape == (packing.THRESHOLD_STATE_SIZE,)
        assert theta[4] == 1.0                             # init flag
        assert theta[len(packing.THRESHOLD_STATE_FIELDS):].sum() > 0

    def test_one_bit_update_phase(self):
        from repro.launch.steps import OacServerConfig
        server, loss = self._run_steps(
            OacServerConfig(one_bit=True, error_feedback=True))
        assert np.isfinite(loss)
        g = np.asarray(server["g"]).astype(np.float32)
        ages = np.asarray(server["age"])
        sel = (ages == 0)
        # selected coordinates carry the ±1 sign vector
        assert set(np.unique(g[sel])) <= {-1.0, 1.0}
        assert float(np.abs(np.asarray(server["res"])).sum()) > 0.0

    def test_adaptive_km_update_phase(self):
        from repro.core import controller
        from repro.launch.steps import OacServerConfig
        server, loss = self._run_steps(OacServerConfig(adaptive_km=True),
                                       n=4)
        assert np.isfinite(loss)
        assert server["ctrl"].shape == (controller.CONTROLLER_STATE_SIZE,)
        cs = controller.controller_state_from_vec(
            jnp.asarray(server["ctrl"]))
        assert 0.05 <= float(cs["k_m_frac"]) <= 0.95
        assert float(cs["init"]) == 1.0           # controller has observed
        assert float(jnp.sum(cs["age_ema"])) > 0  # histogram EMA seeded

    def test_adaptive_km_requires_fused_packed(self):
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.launch.steps import OacServerConfig, make_train_step
        cfg = get_config("mamba2-370m", reduced_variant=True)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        for bad in (OacServerConfig(adaptive_km=True, packed=False),
                    OacServerConfig(adaptive_km=True, fused_stats=False)):
            with pytest.raises(ValueError):
                make_train_step(cfg, InputShape("t", 64, 2, "train"), mesh,
                                oac=bad)

    def test_one_bit_requires_packed(self):
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.launch.steps import OacServerConfig, make_train_step
        cfg = get_config("mamba2-370m", reduced_variant=True)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with pytest.raises(ValueError):
            make_train_step(cfg, InputShape("t", 64, 2, "train"), mesh,
                            oac=OacServerConfig(packed=False,
                                                one_bit=True))
