"""Error feedback & one-bit on the threshold/packed backends (PR 3).

Pins the tentpole guarantees:
* bit-exact parity: exact-EF == threshold-EF == packed-EF (and the sharded
  backend) under ``exact_theta`` on tie-free inputs — the residual stage of
  the fused kernel computes the SAME (g_t, age', residual') as the index
  path;
* residual conservation: selected mass + residual' == effective gradient
  (``mask * sent + residual' == g + residual``), bit-exact;
* pad protocol: packing pads are never selected and pass their residual
  through unchanged;
* the one-bit ``fresh`` decoupling (sign_mv majority votes merged while the
  vote energy is scored) agrees across backends;
* regression: ``FLConfig(backend="packed"/"threshold", error_feedback=True
  / one_bit=True)`` no longer raises and trains end-to-end.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packing
from repro.core.engine import EngineConfig, SelectionEngine
from repro.kernels import ops


def _tie_free(d, seed=0):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=d).astype("f4"))
    g_prev = jnp.asarray(rng.normal(size=d).astype("f4"))
    age = jnp.asarray(rng.permutation(d).astype("f4"))
    res = jnp.asarray(rng.normal(size=d).astype("f4"))
    return g, g_prev, age, res


def _engines(d, backend_kw=None, **common):
    common = dict(policy="fairk", rho=0.1, k_m_frac=0.75, exact_theta=True,
                  **common)
    ex = SelectionEngine(EngineConfig(backend="exact", **common), d)
    th = SelectionEngine(EngineConfig(backend="threshold", **common), d)
    return ex, th


# ---------------------------------------------------------------------------
# engine parity with residual / fresh (the acceptance criterion)
# ---------------------------------------------------------------------------

class TestEngineParityEF:
    def test_exact_vs_threshold_ef_bit_exact(self):
        d = 4096
        g, gp, age, res = _tie_free(d)
        ex, th = _engines(d)
        g1, a1, s1 = jax.jit(ex.select_and_merge)(g, gp, age, residual=res)
        g2, a2, s2 = th.select_and_merge(g, gp, age, residual=res)
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
        np.testing.assert_array_equal(np.asarray(s1["residual"]),
                                      np.asarray(s2["residual"]))

    def test_exact_vs_sharded_ef_bit_exact(self):
        d = 4096
        g, gp, age, res = _tie_free(d, seed=3)
        common = dict(policy="fairk", rho=0.1, k_m_frac=0.75,
                      exact_theta=True)
        ex = SelectionEngine(EngineConfig(backend="exact", **common), d)
        mesh = jax.make_mesh((1,), ("shard",))
        sh = SelectionEngine(EngineConfig(backend="sharded", **common), d,
                             mesh=mesh)
        g1, a1, s1 = jax.jit(ex.select_and_merge)(g, gp, age, residual=res)
        g2, a2, s2 = jax.jit(sh.select_and_merge)(g, gp, age, residual=res)
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
        np.testing.assert_array_equal(np.asarray(s1["residual"]),
                                      np.asarray(s2["residual"]))

    def test_exact_vs_packed_ef_bit_exact_on_packed_tree(self):
        """The headline claim: exact-EF == packed-EF bit-exact under
        exact_theta on a REAL multi-leaf packed layout (pads inside)."""
        rng = np.random.default_rng(5)
        leaves = [rng.normal(size=s).astype("f4")
                  for s in (300, 4096, 77, 1000)]
        lay = packing.PackedLayout.from_tree(
            [jnp.asarray(l) for l in leaves])
        d = lay.d_packed
        g_buf = lay.pack([jnp.asarray(l) for l in leaves])
        gp_buf = lay.pack([jnp.asarray(rng.normal(size=l.shape)
                                       .astype("f4")) for l in leaves])
        age_buf = lay.pack_age(
            [jnp.asarray(a.astype("f4")) for a in np.split(
                rng.permutation(lay.d_valid),
                np.cumsum([l.size for l in leaves])[:-1])])
        res_buf = lay.pack([jnp.asarray(rng.normal(size=l.shape)
                                        .astype("f4")) for l in leaves])
        pk = SelectionEngine(
            EngineConfig(policy="fairk", backend="packed", rho=0.1,
                         k_m_frac=0.75, exact_theta=True,
                         kernel_mode="interpret"), d, layout=lay)
        k, k_m, r = pk.budgets()
        ex = SelectionEngine(
            EngineConfig(policy="fairk", backend="exact", k=k, k_m=k_m,
                         r=r), d)
        g1, a1, s1 = pk.select_and_merge(g_buf, gp_buf, age_buf,
                                         residual=res_buf)
        g2, a2, s2 = jax.jit(ex.select_and_merge)(g_buf, gp_buf, age_buf,
                                                  residual=res_buf)
        valid = np.asarray(lay.valid_mask())
        np.testing.assert_array_equal(np.asarray(g1)[valid],
                                      np.asarray(g2)[valid])
        np.testing.assert_array_equal(np.asarray(a1)[valid],
                                      np.asarray(a2)[valid])
        np.testing.assert_array_equal(np.asarray(s1["residual"])[valid],
                                      np.asarray(s2["residual"])[valid])
        assert float(s1["n_selected"]) == k
        # pads: never selected, sentinel + residual pass through unchanged
        np.testing.assert_array_equal(np.asarray(a1)[~valid],
                                      packing.PAD_AGE)
        np.testing.assert_array_equal(np.asarray(s1["residual"])[~valid],
                                      np.asarray(res_buf)[~valid])

    def test_one_bit_fresh_parity_exact_vs_threshold(self):
        """Decoupled ``fresh`` (the one-bit majority-vote signs) merges the
        same values on the exact and threshold backends."""
        d = 4096
        g, gp, age, _ = _tie_free(d, seed=9)
        fresh = jnp.where(g >= 0, 1.0, -1.0).astype(jnp.float32)
        ex, th = _engines(d)
        g1, a1, _ = jax.jit(ex.select_and_merge)(g, gp, age, fresh=fresh)
        g2, a2, _ = th.select_and_merge(g, gp, age, fresh=fresh)
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
        # selected coords carry the ±1 signs, the rest stay stale
        sel = np.asarray(a1) == 0.0
        assert set(np.unique(np.asarray(g1)[sel])) <= {-1.0, 1.0}
        np.testing.assert_array_equal(np.asarray(g1)[~sel],
                                      np.asarray(gp)[~sel])

    def test_sharded_rejects_fresh(self):
        d = 256
        g, gp, age, _ = _tie_free(d)
        mesh = jax.make_mesh((1,), ("shard",))
        sh = SelectionEngine(
            EngineConfig(policy="fairk", backend="sharded", rho=0.1,
                         exact_theta=True), d, mesh=mesh)
        with pytest.raises(ValueError):
            sh.select_and_merge(g, gp, age, fresh=g)


# ---------------------------------------------------------------------------
# residual conservation (selected + residual mass accounting)
# ---------------------------------------------------------------------------

class TestResidualConservation:
    @pytest.mark.parametrize("backend", ["exact", "threshold"])
    def test_mass_accounting_bit_exact(self, backend):
        """mask * sent + residual' == g + residual, coordinate-wise exact:
        nothing is lost between the merge and the accumulator."""
        d = 2048
        g, gp, age, res = _tie_free(d, seed=11)
        eng = SelectionEngine(
            EngineConfig(policy="fairk", backend=backend, rho=0.15,
                         k_m_frac=0.75, exact_theta=True), d)
        g_t, age_next, stats = jax.jit(eng.select_and_merge)(
            g, gp, age, residual=res)
        sel = (np.asarray(age_next) == 0.0).astype(np.float32)
        score = np.asarray(g) + np.asarray(res)
        np.testing.assert_array_equal(
            sel * score + np.asarray(stats["residual"]), score)
        # unselected coordinates accumulate their full effective mass
        np.testing.assert_array_equal(
            np.asarray(stats["residual"])[sel == 0.0], score[sel == 0.0])
        # selected coordinates sent everything: residual resets to zero
        np.testing.assert_array_equal(
            np.asarray(stats["residual"])[sel == 1.0], 0.0)

    def test_sampled_thresholds_fold_residual(self):
        """The sampled-quantile estimate must see |g + residual|, not |g| —
        a residual that concentrates mass on low-|g| coordinates must move
        θ_M accordingly (no d-length temp needed for the estimate)."""
        from repro.core.engine import sampled_thresholds
        rng = np.random.default_rng(2)
        d = 1 << 14
        g = jnp.asarray(rng.normal(size=d).astype("f4"))
        res = jnp.asarray((10.0 * rng.normal(size=d)).astype("f4"))
        age = jnp.asarray(rng.permutation(d).astype("f4"))
        kw = dict(rho=0.1, k_m_frac=1.0, sample_cap=d)
        tm_plain, _ = sampled_thresholds(g, age, **kw)
        tm_ef, _ = sampled_thresholds(g, age, residual=res, **kw)
        tm_ref, _ = sampled_thresholds(g + res, age, **kw)
        assert float(tm_ef) == pytest.approx(float(tm_ref), rel=1e-6)
        assert float(tm_ef) > 2.0 * float(tm_plain)


# ---------------------------------------------------------------------------
# fused kernel: EF stage ref vs interpret, pad protocol
# ---------------------------------------------------------------------------

class TestEFKernel:
    def test_ref_equals_interpret(self):
        d = 4096
        g, gp, age, res = _tie_free(d, seed=21)
        age = age % 120.0
        fresh = jnp.where(g + res >= 0, 1.0, -1.0)
        tm, ta = jnp.float32(1.2), jnp.float32(100.0)
        for kw in (dict(residual=res), dict(fresh=fresh),
                   dict(residual=res, fresh=fresh)):
            out_r = ops.fairk_ef_update(g, gp, age, tm, ta, mode="ref",
                                        **kw)
            out_k = ops.fairk_ef_update(g, gp, age, tm, ta,
                                        mode="interpret", **kw)
            for a, b in zip(out_r, out_k):
                if a is None:
                    assert b is None
                    continue
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-6)

    @pytest.mark.parametrize("mode", ["ref", "interpret"])
    def test_pads_pass_residual_through(self, mode):
        rng = np.random.default_rng(7)
        d = 1024
        g = jnp.asarray(rng.normal(size=d).astype("f4"))
        gp = jnp.asarray(rng.normal(size=d).astype("f4"))
        res = jnp.asarray(rng.normal(size=d).astype("f4"))
        age = jnp.asarray(rng.integers(0, 40, d).astype("f4"))
        pad = np.zeros(d, bool)
        pad[100:356] = True                       # interior pad block
        g = g.at[100:356].set(0.0)
        res = res.at[100:356].set(0.123)          # nonzero sentinel check
        age = age.at[100:356].set(packing.PAD_AGE)
        g_t, age_next, res_next = ops.fairk_ef_update(
            g, gp, age, jnp.float32(0.05), jnp.float32(0.0),
            residual=res, mode=mode, block_size=256)
        assert (np.asarray(age_next)[pad] == packing.PAD_AGE).all()
        np.testing.assert_array_equal(np.asarray(g_t)[pad],
                                      np.asarray(gp)[pad])
        np.testing.assert_array_equal(np.asarray(res_next)[pad],
                                      np.float32(0.123))
        assert (np.asarray(age_next)[~pad] == 0).all()
        np.testing.assert_array_equal(np.asarray(res_next)[~pad], 0.0)


# ---------------------------------------------------------------------------
# FL trainer regression: threshold/packed accept one_bit / error_feedback
# ---------------------------------------------------------------------------

class TestFLRegression:
    def _tiny_task(self):
        from repro.models import cnn
        params0 = cnn.init_mlp_classifier(jax.random.PRNGKey(0), 16, 3,
                                          hidden=(8,))

        def loss_fn(p, x, y):
            return cnn.softmax_xent(cnn.mlp_classifier(p, x), y)

        rng = np.random.default_rng(0)
        xs = rng.normal(size=(6, 2, 4, 16)).astype("f4")
        ys = rng.integers(0, 3, size=(6, 2, 4)).astype("i4")
        return params0, loss_fn, (xs, ys)

    @pytest.mark.parametrize("backend", ["threshold", "packed"])
    @pytest.mark.parametrize("one_bit,ef", [(False, True), (True, False),
                                            (True, True)])
    def test_no_longer_raises_and_runs(self, backend, one_bit, ef):
        """The trainer.py gate that raised on non-exact one_bit /
        error_feedback is gone: the step builds AND executes a round."""
        from repro.fl import FLConfig, make_fl_step
        from repro.core import packing as pk
        from jax.flatten_util import ravel_pytree
        params0, loss_fn, (xs, ys) = self._tiny_task()
        flat, unravel = ravel_pytree(params0)
        d = flat.shape[0]
        fl = FLConfig(n_clients=6, local_steps=2, batch_size=4, rounds=1,
                      backend=backend, one_bit=one_bit, error_feedback=ef,
                      compression_ratio=0.2)
        from repro.core import controller as budget
        step = make_fl_step(fl, unravel, loss_fn, d)
        z = jnp.zeros((d,), jnp.float32)
        w, g, age, cnt, res, mask, ts, cs, rm = step(
            jax.random.PRNGKey(0), flat, z, z, z, jnp.asarray(xs),
            jnp.asarray(ys), z, pk.init_threshold_state(),
            budget.init_controller_state())
        assert np.isfinite(np.asarray(w)).all()
        assert float(mask.sum()) > 0
        if ef:
            assert np.isfinite(np.asarray(res)).all()

    def test_unknown_backend_still_rejected(self):
        from repro.fl import FLConfig, make_fl_step
        with pytest.raises(ValueError):
            make_fl_step(FLConfig(backend="bogus"), lambda w: w,
                         lambda p, x, y: 0.0, 16)


# ---------------------------------------------------------------------------
# vmapped sweep: EF knob
# ---------------------------------------------------------------------------

def test_sweep_error_feedback_runs_and_accumulates():
    from repro.fl.sweep import SweepConfig, run_sweep
    base = dict(d=256, n_clients=4, rounds=30, noise_std=0.1)
    out_ef = run_sweep(SweepConfig(error_feedback=True, **base),
                       policies=("fairk",), n_seeds=2)
    out_no = run_sweep(SweepConfig(**base), policies=("fairk",), n_seeds=2)
    assert np.isfinite(out_ef["loss"]).all()
    assert out_ef["res_norm"][:, -1].max() > 0.0
    assert (out_no["res_norm"] == 0.0).all()
