import os
import sys

# NOTE: do NOT set XLA_FLAGS / device-count overrides here — smoke tests and
# benches must see the real (single-CPU) device.  Only launch/dryrun.py (a
# process entry point) forces the 512-device placeholder mesh, and the
# distributed tests below spawn subprocesses with their own flags.

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
