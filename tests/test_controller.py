"""In-graph adaptive budget controller (core/controller.py, DESIGN.md §12).

Pins the tentpole guarantees of the traced-``k_m`` refactor:

* the controller's staleness pmf (derived from the kernel-emitted
  ``age_hist``) IS the empirical post-update age distribution, and it
  tracks ``core/markov.py``'s Lemma-1 stationary prediction on a small
  (d, k, k_m) chain;
* a traced ``k_m_frac`` reproduces the static-split engine BIT-EXACTLY on
  all four backends under ``exact_theta``;
* the control law: clipped, damped, deadbanded steps toward the Lemma-1
  setpoint, bounds respected, no step off a round-0 full-refresh
  histogram;
* adaptation is zero-recompile (one trace of the controller update across
  many ``k_m_frac`` operating points) and zero-extra-read (``G_READS`` of
  the adaptive packed round == 1);
* the controller state round-trips the flat-vector codec and the
  ``save/restore_server_state`` checkpoint;
* the FL trainer's ``fairk_auto`` alias / ``adaptive_km`` flag runs the
  controller in-graph and records the split trajectory on-device.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import statutil
from repro import checkpoint
from repro.core import controller, markov, packing
from repro.core.engine import EngineConfig, SelectionEngine


def _tie_free(d, seed=0):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=d).astype("f4"))
    gp = jnp.asarray(rng.normal(size=d).astype("f4"))
    age = jnp.asarray(rng.permutation(d).astype("f4"))
    return g, gp, age


# ---------------------------------------------------------------------------
# staleness pmf: empirical match + Lemma-1 tracking (satellite)
# ---------------------------------------------------------------------------

class TestStalenessPmf:
    def test_age_hist_pmf_is_empirical_pmf(self):
        """At stride 1 (d < 2·STATS_SAMPLE_CAP) the kernel-emitted
        age_hist is EXACTLY the histogram of the post-update age vector,
        so the controller's pmf equals the empirical staleness pmf."""
        d = 8192
        assert packing.hist_stride(d) == 1
        g, gp, age = _tie_free(d)
        eng = SelectionEngine(EngineConfig(policy="fairk", backend="packed",
                                           rho=0.1, k_m_frac=0.75,
                                           fused_stats=True, warm_start=True),
                              d, layout=packing.PackedLayout.from_tree(
                                  [jnp.zeros((d,))]))
        _, age_next, stats = eng.select_and_merge(
            g, gp, age % 100.0, tstate=packing.init_threshold_state())
        pmf = np.asarray(controller.staleness_pmf(stats["age_hist"]))
        emp, _ = np.histogram(np.asarray(age_next),
                              bins=np.arange(129) - 0.5)
        np.testing.assert_allclose(pmf, emp / emp.sum(), atol=1e-7)

    def test_pmf_tracks_lemma1_stationary_prediction(self):
        """Run the engine's FAIR-k with iid re-drawn scores (the
        well-mixed exchange regime: k0 = k_M(1 − k_M/d)) and compare the
        time-averaged age_hist pmf against Lemma 1's stationary π on the
        same small (d, k, k_m) chain — mean staleness within 10%, total
        variation < 0.1, same regulated quantile bin."""
        d, k, k_m = 512, 64, 32
        eng = SelectionEngine(EngineConfig(policy="fairk", backend="exact",
                                           k=k, k_m=k_m, fused_stats=True),
                              d)
        acc = statutil.accumulate_age_hist(eng, d)
        k0 = int(round(k_m * (1 - k_m / d)))
        support, pred = markov.aou_distribution(
            markov.FairKChain(d=d, k=k, k_m=k_m, k0=k0))
        emp = statutil.assert_pmf_close(acc, support, pred, mean_rtol=0.1)
        pred_full = statutil.embed_pmf(support, pred)
        q = controller.pmf_quantile
        assert abs(float(q(jnp.asarray(emp, jnp.float32), 0.9))
                   - float(q(jnp.asarray(pred_full, jnp.float32), 0.9))) < 1.5

    def test_lemma1_target_table_monotone_in_split(self):
        """More magnitude share = fewer age slots = staler tail: the
        Lemma-1 target table must increase with k_m_frac."""
        fracs, targets = controller.lemma1_target_table(
            controller.ControllerConfig(), rho=0.1)
        assert len(fracs) == len(targets)
        assert (np.diff(targets) >= -1e-6).all()
        assert targets[-1] > targets[0]

    def test_pmf_quantile_interpolates(self):
        pmf = jnp.zeros((128,), jnp.float32).at[4].set(0.5).at[10].set(0.5)
        assert abs(float(controller.pmf_quantile(pmf, 0.25)) - 4.5) < 1e-5
        assert float(controller.pmf_quantile(pmf, 0.75)) == pytest.approx(
            10.5, abs=1e-5)


# ---------------------------------------------------------------------------
# traced-k_m engine parity (satellite / acceptance)
# ---------------------------------------------------------------------------

class TestTracedKmParity:
    SEEDS = {"exact": 7, "threshold": 11, "sharded": 13, "packed": 17}

    @pytest.mark.parametrize("backend", ["exact", "threshold", "sharded",
                                         "packed"])
    def test_traced_equals_static_exact_theta(self, backend):
        """select_and_merge(k_m_frac=traced 0.75) ≡ the static-split
        engine, bit-exact, on tie-free inputs under exact_theta."""
        d = 4096
        g, gp, age = _tie_free(d, seed=self.SEEDS[backend])
        common = dict(policy="fairk", rho=0.1, k_m_frac=0.75,
                      exact_theta=True, fused_stats=True)
        kw = {}
        if backend == "sharded":
            kw["mesh"] = jax.make_mesh((1,), ("shard",))
        if backend == "packed":
            kw["layout"] = packing.PackedLayout.from_tree([jnp.zeros((d,))])
        eng = SelectionEngine(EngineConfig(backend=backend, **common), d,
                              **kw)
        out_s = jax.jit(eng.select_and_merge)(g, gp, age)
        out_t = jax.jit(lambda g, gp, age, f: eng.select_and_merge(
            g, gp, age, k_m_frac=f))(g, gp, age, jnp.float32(0.75))
        np.testing.assert_array_equal(np.asarray(out_s[0]),
                                      np.asarray(out_t[0]))
        np.testing.assert_array_equal(np.asarray(out_s[1]),
                                      np.asarray(out_t[1]))
        assert float(out_s[2]["n_selected"]) == float(out_t[2]["n_selected"])

    def test_traced_split_actually_moves_the_split(self):
        """Different traced fracs through ONE jitted function change the
        magnitude-stage share (trace reuse, different data)."""
        d = 4096
        g, gp, age = _tie_free(d, seed=3)
        eng = SelectionEngine(EngineConfig(policy="fairk", backend="exact",
                                           rho=0.1, fused_stats=True), d)
        fn = jax.jit(lambda f: eng.select_and_merge(g, gp, age,
                                                    k_m_frac=f))
        n_lo = float(fn(jnp.float32(0.25))[2]["n_sel_m"])
        n_hi = float(fn(jnp.float32(0.75))[2]["n_sel_m"])
        k = eng.budgets()[0]
        assert n_lo == round(0.25 * k) and n_hi == round(0.75 * k)

    def test_non_fairk_policy_rejected(self):
        d = 256
        g, gp, age = _tie_free(d)
        eng = SelectionEngine(EngineConfig(policy="topk", backend="exact"),
                              d)
        with pytest.raises(ValueError):
            eng.select_and_merge(g, gp, age, k_m_frac=jnp.float32(0.5))


# ---------------------------------------------------------------------------
# control law
# ---------------------------------------------------------------------------

class TestControlLaw:
    def _hist_at(self, age):
        return jnp.zeros((packing.STATS_AGE_BINS,),
                         jnp.float32).at[age].set(1000.0)

    def _settled(self, bc, cs, hist, rounds=12):
        for _ in range(rounds):
            cs = bc.update(cs, hist)
        return cs

    def test_stale_population_lowers_split(self):
        """Measured quantile far above the setpoint -> budget shifts to
        the age stage (k_m_frac decreases), bounded per actuation."""
        bc = controller.BudgetController(rho=0.1)
        cs = self._settled(bc, bc.init_state(0.75), self._hist_at(110))
        assert float(cs["k_m_frac"]) < 0.75
        assert abs(float(cs["prev_step"])) <= bc.cfg.max_step + 1e-6

    def test_fresh_population_raises_split(self):
        bc = controller.BudgetController(rho=0.1)
        cs = self._settled(bc, bc.init_state(0.5), self._hist_at(2))
        assert float(cs["k_m_frac"]) > 0.5

    def test_bounds_respected(self):
        bc = controller.BudgetController(rho=0.1)
        cs = self._settled(bc, bc.init_state(0.9), self._hist_at(2),
                           rounds=400)
        assert float(cs["k_m_frac"]) <= bc.cfg.max_frac + 1e-6
        cs = self._settled(bc, bc.init_state(0.1), self._hist_at(120),
                           rounds=400)
        assert float(cs["k_m_frac"]) >= bc.cfg.min_frac - 1e-6

    def test_first_observation_never_steps(self):
        """Round 0 emits a full-refresh histogram (everything at age 0);
        the controller must only seed its EMA off it."""
        bc = controller.BudgetController(rho=0.1)
        cs = bc.update(bc.init_state(0.5), self._hist_at(0))
        assert float(cs["k_m_frac"]) == 0.5
        assert float(cs["init"]) == 1.0

    def test_deadband_holds_at_setpoint(self):
        """A population sitting exactly at the Lemma-1 setpoint stays
        parked (the Sec. V-A plateau makes small moves pure noise)."""
        bc = controller.BudgetController(rho=0.1)
        cs0 = bc.init_state(0.5)
        tgt = int(round(float(bc.target_for(jnp.float32(0.5)))))
        cs = self._settled(bc, cs0, self._hist_at(tgt), rounds=50)
        assert abs(float(cs["k_m_frac"]) - 0.5) < 1e-6

    def test_fixed_target_mode(self):
        bc = controller.BudgetController(
            controller.ControllerConfig(target_age=7.0), rho=0.1)
        assert float(bc.target_for(jnp.float32(0.3))) == 7.0
        assert float(bc.target_for(jnp.float32(0.9))) == 7.0


# ---------------------------------------------------------------------------
# zero recompiles + one read (acceptance)
# ---------------------------------------------------------------------------

class TestNoRecompileOneRead:
    def test_one_trace_across_km_changes_and_one_g_read(self):
        """One jitted adaptive packed round executed at several controller
        operating points: the controller body traces ONCE (no recompile —
        the split is data) and the round reads g exactly once."""
        d = 4096
        lay = packing.PackedLayout.from_tree([jnp.zeros((d,))])
        eng = SelectionEngine(EngineConfig(policy="fairk", backend="packed",
                                           rho=0.1, warm_start=True,
                                           fused_stats=True),
                              d, layout=lay)
        bc = controller.BudgetController(rho=0.1)

        @jax.jit
        def round_(g, gp, age, ts, cs):
            g_t, age_next, stats = eng.select_and_merge(
                g, gp, age, tstate=ts, k_m_frac=cs["k_m_frac"])
            return g_t, age_next, stats["tstate"], bc.update(
                cs, stats["age_hist"], stats["mag_hist"])

        g, gp, age = _tie_free(d, seed=11)
        ts = packing.init_threshold_state()
        before_tr = controller.UPDATE_TRACES
        before_rd = packing.G_READS
        for frac in (0.25, 0.5, 0.75, 0.9):
            cs = controller.init_controller_state(frac)
            round_(g, gp, age, ts, cs)
        assert controller.UPDATE_TRACES - before_tr == 1
        assert packing.G_READS - before_rd == 1


# ---------------------------------------------------------------------------
# state codec + checkpoint round trip (acceptance)
# ---------------------------------------------------------------------------

class TestStateRoundTrip:
    def test_vec_codec(self):
        cs = controller.init_controller_state(0.37)
        cs["prev_step"] = jnp.float32(-0.01)
        cs["age_ema"] = cs["age_ema"].at[17].set(3.5)
        cs["mag_ema"] = cs["mag_ema"].at[99].set(2.5)
        vec = controller.controller_state_to_vec(cs)
        assert vec.shape == (controller.CONTROLLER_STATE_SIZE,)
        back = controller.controller_state_from_vec(vec)
        for f in controller.CTRL_SCALAR_FIELDS:
            assert float(back[f]) == float(cs[f])
        np.testing.assert_array_equal(np.asarray(back["age_ema"]),
                                      np.asarray(cs["age_ema"]))
        np.testing.assert_array_equal(np.asarray(back["mag_ema"]),
                                      np.asarray(cs["mag_ema"]))

    def test_controller_state_survives_server_checkpoint(self, tmp_path):
        """The acceptance criterion: controller state round-trips through
        save/restore_server_state next to the packed buffers, and the
        restored round reproduces the original bit-exactly."""
        rng = np.random.default_rng(5)
        lay = packing.PackedLayout.from_tree([jnp.zeros((300,)),
                                              jnp.zeros((512,))])
        d = lay.d_packed
        cs = controller.init_controller_state(0.6)
        cs["age_ema"] = cs["age_ema"].at[12].set(100.0)
        cs["init"] = jnp.float32(1.0)
        server = {
            "g": jnp.asarray(rng.normal(size=d).astype("f4")
                             ).astype(jnp.bfloat16),
            "age": jnp.asarray(rng.integers(-1, 100, d).astype("i1")),
            "theta": packing.threshold_state_to_vec(
                packing.init_threshold_state()),
            "ctrl": controller.controller_state_to_vec(cs),
        }
        path = checkpoint.save_server_state(str(tmp_path / "srv.npz"),
                                            server, layout=lay)
        back, _ = checkpoint.restore_server_state(path, layout=lay)
        np.testing.assert_array_equal(np.asarray(server["ctrl"]),
                                      back["ctrl"])

        eng = SelectionEngine(EngineConfig(policy="fairk", backend="packed",
                                           rho=0.1, warm_start=True,
                                           fused_stats=True),
                              d, layout=lay)
        bc = controller.BudgetController(rho=0.1)
        g = jnp.asarray(rng.normal(size=d).astype("f4"))

        def round_(srv):
            ts = packing.threshold_state_from_vec(jnp.asarray(srv["theta"]))
            c = controller.controller_state_from_vec(
                jnp.asarray(srv["ctrl"]))
            g_t, age_next, stats = eng.select_and_merge(
                g, jnp.asarray(srv["g"]).astype(jnp.float32),
                jnp.asarray(srv["age"]).astype(jnp.float32),
                tstate=ts, k_m_frac=c["k_m_frac"])
            c = bc.update(c, stats["age_hist"], stats["mag_hist"])
            return g_t, age_next, controller.controller_state_to_vec(c)

        for a, b in zip(round_(server), round_(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# FL trainer integration (fairk_auto alias, adaptive_km flag)
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestTrainerIntegration:
    def _task(self):
        from repro.data import partition, synthetic
        from repro.models import cnn
        spec = synthetic.DatasetSpec("t", (8, 8, 1), 4, 600, 150,
                                     noise_std=0.8, sparsity=0.1)
        (xtr, ytr), _ = synthetic.make_dataset(spec, seed=0)
        parts = partition.dirichlet_partition(ytr, 6, 0.3, seed=0)
        params0 = cnn.init_mlp_classifier(jax.random.PRNGKey(0), 64, 4,
                                          hidden=(32,))

        def loss_fn(p, x, y):
            return cnn.softmax_xent(cnn.mlp_classifier(p, x), y)

        def sample(t):
            return partition.client_batches(xtr, ytr, parts, 10, 3,
                                            seed=100 + t)
        return params0, loss_fn, sample

    @pytest.mark.parametrize("backend", ["exact", "packed"])
    def test_adaptive_trains_and_logs_split(self, backend):
        from repro.core.oac import ChannelConfig
        from repro.fl import FLConfig, train
        params0, loss_fn, sample = self._task()
        fl = FLConfig(n_clients=6, local_steps=3, batch_size=10, rounds=30,
                      policy="fairk_auto", compression_ratio=0.1,
                      backend=backend, local_lr=0.05, global_lr=0.05,
                      channel=ChannelConfig(fading="rayleigh", mean=1.0,
                                            noise_std=0.1))
        h = train(fl, params0, loss_fn, sample)
        km = np.asarray(h["km_frac"])
        assert km.shape == (30,)
        assert km[0] == pytest.approx(fl.k_m_frac)
        assert (km >= fl.controller.min_frac - 1e-6).all()
        assert (km <= fl.controller.max_frac + 1e-6).all()
        assert len(h["mean_aou"]) == 30 and np.isfinite(h["mean_aou"]).all()

    def test_static_run_records_constant_split(self):
        from repro.core.oac import ChannelConfig
        from repro.fl import FLConfig, train
        params0, loss_fn, sample = self._task()
        fl = FLConfig(n_clients=6, local_steps=3, batch_size=10, rounds=5,
                      policy="fairk", compression_ratio=0.1,
                      local_lr=0.05, global_lr=0.05,
                      channel=ChannelConfig(fading="rayleigh", mean=1.0,
                                            noise_std=0.1))
        h = train(fl, params0, loss_fn, sample)
        km = np.asarray(h["km_frac"])
        assert (km == km[0]).all()            # constant: no controller
        # the realised split round(k_m_frac*k)/k, within rounding of 0.75
        assert abs(km[0] - fl.k_m_frac) < 0.01

    def test_adaptive_rejects_pinned_policies(self):
        from repro.fl import FLConfig, make_fl_step
        with pytest.raises(ValueError):
            make_fl_step(FLConfig(policy="topk", adaptive_km=True),
                         lambda w: w, lambda p, x, y: 0.0, 16)
