"""Shared harness for FL-trainer trajectory tests.

One tiny linear-regression FL problem plus a round-loop driver that walks
``make_fl_step`` exactly the way ``trainer.train`` does (same key-split
discipline, same carry threading).  Used by

* the golden-trajectory pins (``test_streaming.py``): every
  chaos x population x wireless x backend combination is pinned bit-exact
  against ``tests/golden/fl_trajectories.json`` captured before the
  streaming-aggregation refactor, so ``client_chunk=None`` can never
  drift from the historical einsum trace, and
* the chunk-parity matrix: chunked runs (``client_chunk`` in {1, 3, N})
  must match the single-chunk trajectory within float tolerance.

Kept import-light (no fixtures) so benchmark code can reuse it.
"""

from __future__ import annotations

import json
import os
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel as chan_mod
from repro.core import faults as fault_mod
from repro.core import oac
from repro.core import population as pop_mod
from repro.fl import trainer as fl_trainer
from repro.fl.trainer import FLConfig

D = 32          # model dimension of the shared problem
N_CLIENTS = 6   # divisible by the parity chunks {1, 2, 3, 6}

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "fl_trajectories.json")

_OAC_CH = oac.ChannelConfig(fading="rayleigh", mean=1.0, noise_std=0.1)
_FAULTS = fault_mod.FaultConfig(dropout=0.3, fade=0.2, fade_block=8,
                                nan_rate=0.05)
# population composes with fade/nan_rate but not dropout
_FAULTS_NODROP = fault_mod.FaultConfig(fade=0.2, fade_block=8,
                                       nan_rate=0.05)
_POP = pop_mod.PopulationConfig(n_clients=64, cohort_size=16,
                                participants=N_CLIENTS, avail=0.8,
                                mode="ge", burst=4.0, erase_block=8)
_WL = chan_mod.ChannelConfig(n_clients=N_CLIENTS, pmax=10.0, gmin=0.05,
                             rho_f=0.5, csi_err=0.1, block=8)


def combo_configs() -> Dict[str, FLConfig]:
    """Name -> FLConfig for the full pin/parity matrix.  Every wireless-off
    x chaos x population combination appears, every backend, the one-bit
    and EF uplinks and the adaptive controller."""
    base = dict(n_clients=N_CLIENTS, local_steps=2, batch_size=3,
                local_lr=0.05, global_lr=0.05, rounds=3,
                compression_ratio=0.2, channel=_OAC_CH, seed=0)
    combos = {
        "exact": FLConfig(**base),
        "threshold": FLConfig(backend="threshold", **base),
        "packed": FLConfig(backend="packed", **base),
        "exact_onebit": FLConfig(one_bit=True, **base),
        "exact_ef": FLConfig(error_feedback=True, **base),
        "exact_onebit_ef": FLConfig(one_bit=True, error_feedback=True,
                                    **base),
        "exact_adaptive": FLConfig(adaptive_km=True, **base),
        "threshold_onebit": FLConfig(backend="threshold", one_bit=True,
                                     **base),
        "threshold_ef": FLConfig(backend="threshold", error_feedback=True,
                                 **base),
        "packed_onebit": FLConfig(backend="packed", one_bit=True, **base),
        "chaos": FLConfig(faults=_FAULTS, **base),
        "chaos_packed": FLConfig(backend="packed", faults=_FAULTS, **base),
        "pop": FLConfig(population=_POP, **base),
        "wl": FLConfig(wireless=_WL, **base),
        "wl_onebit": FLConfig(wireless=_WL, one_bit=True, **base),
        "chaos_wl": FLConfig(faults=_FAULTS, wireless=_WL, **base),
        "pop_chaos": FLConfig(population=_POP, faults=_FAULTS_NODROP,
                              **base),
        "pop_wl": FLConfig(population=_POP, wireless=_WL, **base),
        "pop_chaos_wl": FLConfig(population=_POP, faults=_FAULTS_NODROP,
                                 wireless=_WL, **base),
    }
    return combos


def make_problem(n_clients: int = N_CLIENTS, d: int = D, h: int = 2,
                 b: int = 3, seed: int = 0):
    """(params0, loss_fn, xs, ys): a tiny linear regression whose client
    batches are pre-drawn as stacked (N, H, B, ...) arrays."""
    rng = np.random.default_rng(seed)
    params0 = {"a": jnp.asarray(rng.normal(size=(d,)).astype("f4"))}
    xs = jnp.asarray(rng.normal(size=(n_clients, h, b, d)).astype("f4"))
    ys = jnp.asarray(rng.normal(size=(n_clients, h, b)).astype("f4"))

    def loss_fn(p, x, y):
        return 0.5 * jnp.mean((x @ p["a"] - y) ** 2)

    return params0, loss_fn, xs, ys


def run_rounds(fl: FLConfig, rounds: int = 3
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Walk ``make_fl_step`` like ``trainer.train`` (same per-round key
    split) and return the final (w, g, age, residual)."""
    params0, loss_fn, xs, ys = make_problem(fl.n_clients)
    state, unravel = fl_trainer.init_server(params0, fl)
    d = state.w.shape[0]
    step = fl_trainer.make_fl_step(fl, unravel, loss_fn, d)
    has_fstate = (fl.chaos or fl.watchdog is not None
                  or fl.population is not None or fl.wireless is not None)
    fstate = (fl_trainer.init_fault_state(fl, state) if has_fstate
              else None)
    key = jax.random.PRNGKey(fl.seed)
    w, g, age, sel = state.w, state.g, state.age, state.sel_count
    residual, tstate, cstate = state.residual, state.theta, state.ctrl
    for _ in range(rounds):
        key, sub = jax.random.split(key)
        args = (sub, w, g, age, sel, xs, ys, residual, tstate, cstate)
        if has_fstate:
            (w, g, age, sel, residual, _, tstate, cstate, _,
             fstate) = step(*args, fstate)
        else:
            w, g, age, sel, residual, _, tstate, cstate, _ = step(*args)
    return (np.asarray(w), np.asarray(g), np.asarray(age),
            np.asarray(residual))


def capture_goldens(path: str = GOLDEN_PATH) -> Dict[str, Dict]:
    """Run every combo and write the trajectory fingerprints (full final
    vectors — d is tiny) to ``path``."""
    out = {}
    for name, fl in combo_configs().items():
        w, g, age, res = run_rounds(fl)
        out[name] = {"w": w.tolist(), "g": g.tolist(),
                     "age": age.tolist(), "res": res.tolist()}
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    return out


def load_goldens(path: str = GOLDEN_PATH) -> Dict[str, Dict]:
    with open(path) as f:
        return json.load(f)


if __name__ == "__main__":
    capture_goldens()
    print(f"wrote {GOLDEN_PATH}")
