"""Links the production path to the paper-exact algorithm: the sampled-
quantile threshold FAIR-k used by the sharded trainer (launch.steps) must
statistically agree with the exact index-based FAIR-k (core.selection)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import selection
from repro.launch.steps import OacServerConfig, fairk_threshold_masks


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_threshold_mask_matches_exact_budget(seed):
    rng = np.random.default_rng(seed)
    d = 1 << 16
    g = jnp.asarray(rng.normal(size=d).astype("f4"))
    age = jnp.asarray(rng.integers(0, 40, d).astype("f4"))
    oac = OacServerConfig(rho=0.1, k_m_frac=0.75)
    mask, mask_m = fairk_threshold_masks(g, age, oac, sample_cap=d)
    frac = float(np.asarray(mask).mean())
    assert abs(frac - 0.1) < 0.01
    assert abs(float(np.asarray(mask_m).mean()) - 0.075) < 0.01


def test_threshold_magnitude_stage_overlaps_exact():
    """The threshold magnitude stage must select (almost exactly) the same
    coordinates as exact Top-k_M."""
    rng = np.random.default_rng(3)
    d = 1 << 15
    g = jnp.asarray(rng.normal(size=d).astype("f4"))
    age = jnp.zeros((d,), jnp.float32)
    oac = OacServerConfig(rho=0.1, k_m_frac=0.75)
    _, mask_m = fairk_threshold_masks(g, age, oac, sample_cap=d)
    k_m = int(round(0.075 * d))
    exact = set(np.asarray(selection.top_k_indices(g, k=k_m)).tolist())
    thresh = set(np.flatnonzero(np.asarray(mask_m)).tolist())
    overlap = len(exact & thresh) / k_m
    assert overlap > 0.98, overlap


def test_threshold_age_stage_prefers_oldest():
    """With distinct ages, the age-stage picks must dominate the age
    distribution's upper tail (matching exact FAIR-k's age stage)."""
    rng = np.random.default_rng(4)
    d = 1 << 14
    g = jnp.asarray(rng.normal(size=d).astype("f4"))
    age = jnp.asarray(rng.permutation(d).astype("f4") / 64.0)  # distinct
    oac = OacServerConfig(rho=0.1, k_m_frac=0.5)
    mask, mask_m = fairk_threshold_masks(g, age, oac, sample_cap=d)
    age_np = np.asarray(age)
    a_picks = np.flatnonzero(np.asarray(mask) * (1 - np.asarray(mask_m)))
    # the age picks should sit in the top ~6% of ages (rho_rest ~ 0.051)
    assert np.median(age_np[a_picks]) > np.quantile(age_np, 0.93)


def test_sampled_quantile_close_to_full():
    """The strided 64k-sample quantile threshold must track the full-data
    quantile (production uses sampling on 1e9-coordinate shards)."""
    rng = np.random.default_rng(5)
    d = 1 << 20
    g = jnp.asarray(rng.normal(size=d).astype("f4"))
    age = jnp.asarray(rng.integers(0, 40, d).astype("f4"))
    oac = OacServerConfig(rho=0.1, k_m_frac=0.75)
    m_full, _ = fairk_threshold_masks(g, age, oac, sample_cap=d)
    m_samp, _ = fairk_threshold_masks(g, age, oac, sample_cap=65536)
    f_full = float(np.asarray(m_full).mean())
    f_samp = float(np.asarray(m_samp).mean())
    assert abs(f_full - f_samp) < 0.01
