"""Vmapped sweep driver: dynamic-rank FAIR-k correctness + grid execution."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import selection
from repro.fl.sweep import (SweepConfig, fair_k_mask_dynamic, run_sweep,
                            sweep_grid)


@settings(max_examples=15, deadline=None)
@given(d=st.integers(32, 512), data=st.data())
def test_dynamic_mask_equals_exact_fairk(d, data):
    """Rank-based FAIR-k with traced k_m == exact index FAIR-k, for any
    (k, k_m), on tie-free inputs."""
    k = data.draw(st.integers(1, d))
    k_m = data.draw(st.integers(0, k))
    rng = np.random.default_rng(d + k)
    g = jnp.asarray(rng.normal(size=d).astype("f4"))
    age = jnp.asarray(rng.permutation(d).astype("f4"))
    m_dyn = np.asarray(fair_k_mask_dynamic(jnp.abs(g), age, k,
                                           jnp.int32(k_m)))
    idx = np.asarray(selection.fair_k_indices(g, age, k=k, k_m=k_m))
    m_exact = np.zeros(d, np.float32)
    m_exact[idx] = 1.0
    np.testing.assert_array_equal(m_dyn, m_exact)
    assert m_dyn.sum() == k


def test_grid_shapes_and_labels():
    cfg = SweepConfig(d=128, rounds=10, n_clients=4)
    seeds, pids, kms, adaptives, labels = sweep_grid(
        ("fairk", "topk"), (0.25, 0.75), 3, cfg)
    # topk pins k_m = k (Remark 1), so its k_m axis collapses to ONE point:
    # fairk contributes 2 fracs x 3 seeds, topk 1 x 3 — no duplicates
    assert seeds.shape == pids.shape == kms.shape == adaptives.shape == (9,)
    assert len(labels) == len(set(labels)) == 9
    assert int(adaptives.sum()) == 0              # no fairk_auto lanes
    topk_kms = [int(kms[i]) for i, l in enumerate(labels) if l[0] == "topk"]
    assert topk_kms == [cfg.k] * 3


def test_sweep_one_program_runs_and_converges():
    """The whole (policy x k_m x seed) grid runs in one compiled program;
    FAIR-k reaches the heterogeneity floor while pure Top-k starves."""
    cfg = SweepConfig(d=256, rounds=80, n_clients=8)
    out = run_sweep(cfg, policies=("fairk", "topk"), k_m_fracs=(0.75,),
                    n_seeds=2)
    assert out["loss"].shape == (4, 80)
    assert np.isfinite(out["loss"]).all()
    by_pol = {}
    for i, (pol, _, _) in enumerate(out["labels"]):
        by_pol.setdefault(pol, []).append(out["loss"][i, -1])
    # fairk converges (well below start), topk's stale coordinates never
    # refresh -> the paper's Fig. 4 ordering in miniature
    start = out["loss"][:, 0].mean()
    assert np.mean(by_pol["fairk"]) < 0.3 * start
    assert np.mean(by_pol["fairk"]) < 0.5 * np.mean(by_pol["topk"])


def test_sweep_budget_respected_every_round():
    cfg = SweepConfig(d=128, rounds=20, n_clients=4, rho=0.25)
    out = run_sweep(cfg, policies=("fairk",), k_m_fracs=(0.5,), n_seeds=1)
    np.testing.assert_allclose(out["frac_fresh"], cfg.k / cfg.d, rtol=1e-6)


def test_sweep_rejects_unknown_policy():
    with pytest.raises(ValueError):
        run_sweep(SweepConfig(d=64, rounds=2), policies=("agetopk",))
