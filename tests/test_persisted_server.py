"""Persisted packed server state (launch.steps, DESIGN.md §10).

The server state of the big-model trainer is now the lane-aligned flat
buffers themselves — g_prev bf16 / age int8 (PAD_AGE sentinel in the lane
pads) / optional EF residual f32 — carried across steps.  These tests pin:

* ``server_layout`` (built outside shard_map from abstract local shapes)
  matches the layout ``PackedLayout.from_tree(local_grads)`` builds inside;
* ``init_server_state`` / ``abstract_server_state`` agree with the input
  specs ``make_train_step`` expects, for all (packed, error_feedback)
  flavours;
* two real steps execute with finite loss, budget-tracking selection, the
  pad sentinel intact, and (EF) a live residual buffer.

The zero-re-pack-per-round structural claim is asserted by
``benchmarks/packed_bench.py --smoke`` (trace-time pack/unpack counters);
multi-device execution is covered by tests/test_sharded.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core import packing
from repro.launch import sharding as shlib
from repro.launch.steps import (OacServerConfig, abstract_params,
                                abstract_server_state, init_server_state,
                                make_train_step, server_layout)


class _FakeMesh:
    """Just enough mesh for the static local-shape math."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def test_server_layout_local_shapes():
    """The layout built from (params_abs, p_specs, mesh) must describe the
    per-shard leaves — dims sharded by a spec axis divide by its size."""
    from jax.sharding import PartitionSpec as P
    mesh = _FakeMesh({"data": 2, "model": 4})
    params = [jax.ShapeDtypeStruct((16, 8), jnp.float32),
              jax.ShapeDtypeStruct((100,), jnp.float32)]
    specs = [P("model", ("data",)), P()]
    lay = server_layout(params, specs, mesh)
    assert [e.shape for e in lay.table] == [(4, 4), (100,)]
    assert lay.d_valid == 16 + 100
    assert lay.d_packed % packing.LANE == 0


@pytest.mark.parametrize("ef,async_agg", [(False, False), (True, False),
                                          (False, True), (True, True)])
def test_init_matches_abstract_and_specs(ef, async_agg):
    cfg = get_config("mamba2-370m", reduced_variant=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    oac = OacServerConfig(error_feedback=ef, async_agg=async_agg)
    params_abs = abstract_params(cfg)
    p_specs = shlib.param_pspecs(params_abs, cfg, mesh)
    srv_abs = abstract_server_state(params_abs, mesh=mesh, p_specs=p_specs,
                                    oac=oac)
    params = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params_abs)
    srv = init_server_state(params, mesh=mesh, cfg=cfg, oac=oac)
    want = {"g", "age", "theta"}
    if ef:
        want |= {"res"}
    if async_agg:
        want |= {"shadow", "pending"}
    assert set(srv) == set(srv_abs) == want
    for k in srv:
        assert srv[k].shape == srv_abs[k].shape, k
        assert srv[k].dtype == srv_abs[k].dtype, k
    # age init: zeros on valid coords, PAD_AGE sentinel in the lane pads
    lay = server_layout(params_abs, p_specs, mesh)
    valid = np.asarray(lay.valid_mask())
    ages = np.asarray(srv["age"])
    assert (ages[valid] == 0).all() and (ages[~valid] == packing.PAD_AGE).all()
    if async_agg:
        # the double-buffer lane starts cold
        assert float(jnp.abs(srv["shadow"].astype(jnp.float32)).sum()) == 0.0
        assert float(jnp.abs(srv["pending"].astype(jnp.float32)).sum()) == 0.0


def test_packed_init_requires_mesh_and_cfg():
    params = {"w": jnp.zeros((8,), jnp.float32)}
    with pytest.raises(ValueError):
        init_server_state(params)                  # packed default needs mesh
    srv = init_server_state(params, oac=OacServerConfig(packed=False))
    assert srv["g"]["w"].shape == (8,)             # per-leaf tree flavour


def test_per_leaf_rejects_error_feedback():
    cfg = get_config("mamba2-370m", reduced_variant=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError):
        make_train_step(cfg, InputShape("t", 64, 2, "train"), mesh,
                        oac=OacServerConfig(packed=False,
                                            error_feedback=True))


def test_async_validation():
    cfg = get_config("mamba2-370m", reduced_variant=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = InputShape("t", 64, 2, "train")
    with pytest.raises(ValueError, match="packed"):
        make_train_step(cfg, shape, mesh,
                        oac=OacServerConfig(packed=False, async_agg=True))
    with pytest.raises(ValueError, match="straggler_frac"):
        make_train_step(cfg, shape, mesh,
                        oac=OacServerConfig(async_agg=True,
                                            straggler_frac=1.5))
    with pytest.raises(ValueError, match="straggler_lag"):
        make_train_step(cfg, shape, mesh,
                        oac=OacServerConfig(async_agg=True,
                                            straggler_lag=0))


# ---------------------------------------------------------------------------
# checkpoint compatibility across the async field-set change (satellite)
# ---------------------------------------------------------------------------

class TestCheckpointMigration:
    def _states(self):
        from repro import checkpoint
        d = 512
        sync = {"g": jnp.ones((d,), jnp.bfloat16),
                "age": jnp.ones((d,), jnp.int8),
                "theta": jnp.ones((packing.THRESHOLD_STATE_SIZE,),
                                  jnp.float32)}
        async_like = dict(sync,
                          shadow=jnp.zeros((d,), jnp.bfloat16),
                          pending=jnp.zeros((d,), jnp.bfloat16))
        return checkpoint, sync, async_like

    def test_migrates_pre_async_checkpoint_to_cold_buffers(self, tmp_path):
        """A synchronous checkpoint resumed under --async-agg gains cold
        (zero) shadow/pending buffers — exact, since zeros ARE the async
        round-0 contents — and survives the save/restore round trip."""
        checkpoint, sync, async_like = self._states()
        path = checkpoint.save_server_state(str(tmp_path / "s.npz"), sync)
        srv_np, _ = checkpoint.restore_server_state(path)
        out = checkpoint.migrate_server_state(srv_np, like=async_like)
        assert set(out) == set(async_like)
        for name in checkpoint.ASYNC_FIELDS:
            assert out[name].shape == async_like[name].shape
            assert jnp.asarray(out[name]).dtype == jnp.bfloat16
            assert float(jnp.abs(jnp.asarray(out[name], jnp.float32)
                                 ).sum()) == 0.0
        # the carried fields pass through untouched
        np.testing.assert_array_equal(np.asarray(out["age"]),
                                      np.asarray(sync["age"]))

    def test_identity_when_field_sets_match(self):
        checkpoint, sync, async_like = self._states()
        out = checkpoint.migrate_server_state(dict(async_like),
                                              like=async_like)
        assert set(out) == set(async_like)

    def test_rejects_async_checkpoint_on_sync_config(self):
        """Dropping a pending merge on the floor would lose one round of
        gradient — the async -> sync direction must REJECT, naming the
        unexpected fields."""
        checkpoint, sync, async_like = self._states()
        with pytest.raises(ValueError, match="pending"):
            checkpoint.migrate_server_state(dict(async_like), like=sync)

    def test_rejects_non_async_field_mismatch(self):
        """Only the async double-buffer lane is synthesizable: a missing
        EF residual (different --ef flag) still errors."""
        checkpoint, sync, async_like = self._states()
        like = dict(async_like, res=jnp.zeros((512,), jnp.float32))
        with pytest.raises(ValueError, match="res"):
            checkpoint.migrate_server_state(sync, like=like)


class TestCheckpointChecksums:
    """Content checksums on the packed server checkpoints (satellite):
    save records a CRC per stored array, restore verifies it, and a
    corrupt newest checkpoint makes --resume fall back to the previous
    one instead of resuming from rotted buffers."""

    def _save(self, tmp_path, step, seed=0):
        from repro import checkpoint
        rng = np.random.default_rng(seed)
        d = 512
        srv = {"g": jnp.asarray(rng.normal(size=d).astype("f4")
                                ).astype(jnp.bfloat16),
               "age": jnp.ones((d,), jnp.int8),
               "theta": jnp.ones((packing.THRESHOLD_STATE_SIZE,),
                                 jnp.float32)}
        path = checkpoint.save_server_state(str(tmp_path), srv, step=step)
        return checkpoint, srv, path

    def test_roundtrip_verifies(self, tmp_path):
        checkpoint, srv, path = self._save(tmp_path, 1)
        back, _ = checkpoint.restore_server_state(path)
        np.testing.assert_array_equal(
            np.asarray(back["g"], np.float32),
            np.asarray(srv["g"], np.float32))

    def test_corruption_raises_corrupt_error(self, tmp_path):
        checkpoint, _, path = self._save(tmp_path, 1)
        data = dict(np.load(path))
        g = data["g"].copy()
        g[17] ^= 0xFF                            # single-bit-ish flip
        data["g"] = g
        np.savez(path, **data)
        with pytest.raises(checkpoint.CorruptCheckpointError,
                           match="checksum"):
            checkpoint.restore_server_state(path)

    def test_pre_checksum_checkpoint_loads(self, tmp_path):
        import json
        checkpoint, _, path = self._save(tmp_path, 1)
        data = dict(np.load(path))
        meta = json.loads(str(data["__server_meta__"][()]))
        meta.pop("checksums")                    # a pre-checksum save
        data["__server_meta__"] = np.asarray(json.dumps(meta))
        np.savez(path, **data)
        back, _ = checkpoint.restore_server_state(path)
        assert set(back) == {"g", "age", "theta"}

    def test_server_steps_newest_first(self, tmp_path):
        checkpoint, _, _ = self._save(tmp_path, 3)
        self._save(tmp_path, 10)
        self._save(tmp_path, 7)
        assert checkpoint.server_steps(str(tmp_path)) == [10, 7, 3]
        assert checkpoint.latest_server_step(str(tmp_path)) == 10
        assert checkpoint.server_steps(str(tmp_path / "nope")) == []


@pytest.mark.slow
@pytest.mark.parametrize("ef", [False, True])
def test_two_steps_execute_with_persisted_buffers(ef):
    from repro.data.tokens import lm_batch
    from repro.models import transformer as tr
    from repro.optim import make_optimizer
    cfg = get_config("mamba2-370m", reduced_variant=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = InputShape("t", 64, 2, "train")
    oac = OacServerConfig(error_feedback=ef)
    bundle = make_train_step(cfg, shape, mesh, oac=oac)
    params = tr.init_lm(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer(bundle.meta["optimizer"], 3e-3)
    opt_state = opt.init(params)
    server = init_server_state(params, mesh=mesh, cfg=cfg, oac=oac)
    step = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                   out_shardings=bundle.out_shardings,
                   donate_argnums=(0, 1, 2))
    nm = bundle.meta["n_micro"]
    with mesh:
        for t in range(2):
            toks, labels = lm_batch(t, 2, 64, cfg.vocab)
            batch = {"tokens": jnp.asarray(toks).reshape(nm, 2 // nm, 64),
                     "labels": jnp.asarray(labels).reshape(nm, 2 // nm, 64)}
            params, opt_state, server, loss = step(
                params, opt_state, server, batch, jnp.asarray(t, jnp.int32))
    assert np.isfinite(float(loss))
    ages = np.asarray(server["age"])
    valid = ages >= 0
    frac_fresh = (ages[valid] == 0).mean()
    assert 0.03 < frac_fresh < 0.3                 # rho = 0.1 target
    assert (ages[~valid] == packing.PAD_AGE).all()
    assert float(np.asarray(server["theta"])[4]) == 1.0   # init flag set
    if ef:
        assert float(jnp.abs(server["res"]).sum()) > 0.0


@pytest.mark.slow
def test_two_async_steps_execute_with_double_buffers():
    """--async-agg flavour: two real steps with the shadow/pending
    double-buffer live.  The refreshed ages restart at the straggler lag
    (never 0), both buffers carry mass after the first round, and the pad
    sentinel survives."""
    from repro.data.tokens import lm_batch
    from repro.models import transformer as tr
    from repro.optim import make_optimizer
    cfg = get_config("mamba2-370m", reduced_variant=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = InputShape("t", 64, 2, "train")
    oac = OacServerConfig(async_agg=True, straggler_frac=0.25,
                          straggler_lag=1)
    bundle = make_train_step(cfg, shape, mesh, oac=oac)
    assert bundle.meta["oac_async"]
    params = tr.init_lm(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer(bundle.meta["optimizer"], 3e-3)
    opt_state = opt.init(params)
    server = init_server_state(params, mesh=mesh, cfg=cfg, oac=oac)
    step = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                   out_shardings=bundle.out_shardings,
                   donate_argnums=(0, 1, 2))
    nm = bundle.meta["n_micro"]
    with mesh:
        for t in range(2):
            toks, labels = lm_batch(t, 2, 64, cfg.vocab)
            batch = {"tokens": jnp.asarray(toks).reshape(nm, 2 // nm, 64),
                     "labels": jnp.asarray(labels).reshape(nm, 2 // nm, 64)}
            params, opt_state, server, loss = step(
                params, opt_state, server, batch, jnp.asarray(t, jnp.int32))
    assert np.isfinite(float(loss))
    ages = np.asarray(server["age"])
    valid = ages >= 0
    # async age bookkeeping: refreshed coordinates restart at the lag —
    # nothing can sit at age 0
    assert (ages[valid] == 0).sum() == 0
    frac_lagged = (ages[valid] == oac.straggler_lag).mean()
    assert 0.03 < frac_lagged < 0.3                # rho = 0.1 target
    assert (ages[~valid] == packing.PAD_AGE).all()
    # both halves of the double buffer carry mass after round 1
    assert float(jnp.abs(server["pending"].astype(jnp.float32)).sum()) > 0.0
    assert float(jnp.abs(server["shadow"].astype(jnp.float32)).sum()) > 0.0
