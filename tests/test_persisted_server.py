"""Persisted packed server state (launch.steps, DESIGN.md §10).

The server state of the big-model trainer is now the lane-aligned flat
buffers themselves — g_prev bf16 / age int8 (PAD_AGE sentinel in the lane
pads) / optional EF residual f32 — carried across steps.  These tests pin:

* ``server_layout`` (built outside shard_map from abstract local shapes)
  matches the layout ``PackedLayout.from_tree(local_grads)`` builds inside;
* ``init_server_state`` / ``abstract_server_state`` agree with the input
  specs ``make_train_step`` expects, for all (packed, error_feedback)
  flavours;
* two real steps execute with finite loss, budget-tracking selection, the
  pad sentinel intact, and (EF) a live residual buffer.

The zero-re-pack-per-round structural claim is asserted by
``benchmarks/packed_bench.py --smoke`` (trace-time pack/unpack counters);
multi-device execution is covered by tests/test_sharded.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core import packing
from repro.launch import sharding as shlib
from repro.launch.steps import (OacServerConfig, abstract_params,
                                abstract_server_state, init_server_state,
                                make_train_step, server_layout)


class _FakeMesh:
    """Just enough mesh for the static local-shape math."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def test_server_layout_local_shapes():
    """The layout built from (params_abs, p_specs, mesh) must describe the
    per-shard leaves — dims sharded by a spec axis divide by its size."""
    from jax.sharding import PartitionSpec as P
    mesh = _FakeMesh({"data": 2, "model": 4})
    params = [jax.ShapeDtypeStruct((16, 8), jnp.float32),
              jax.ShapeDtypeStruct((100,), jnp.float32)]
    specs = [P("model", ("data",)), P()]
    lay = server_layout(params, specs, mesh)
    assert [e.shape for e in lay.table] == [(4, 4), (100,)]
    assert lay.d_valid == 16 + 100
    assert lay.d_packed % packing.LANE == 0


@pytest.mark.parametrize("ef", [False, True])
def test_init_matches_abstract_and_specs(ef):
    cfg = get_config("mamba2-370m", reduced_variant=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    oac = OacServerConfig(error_feedback=ef)
    params_abs = abstract_params(cfg)
    p_specs = shlib.param_pspecs(params_abs, cfg, mesh)
    srv_abs = abstract_server_state(params_abs, mesh=mesh, p_specs=p_specs,
                                    oac=oac)
    params = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params_abs)
    srv = init_server_state(params, mesh=mesh, cfg=cfg, oac=oac)
    assert set(srv) == set(srv_abs) == (
        {"g", "age", "theta", "res"} if ef else {"g", "age", "theta"})
    for k in srv:
        assert srv[k].shape == srv_abs[k].shape, k
        assert srv[k].dtype == srv_abs[k].dtype, k
    # age init: zeros on valid coords, PAD_AGE sentinel in the lane pads
    lay = server_layout(params_abs, p_specs, mesh)
    valid = np.asarray(lay.valid_mask())
    ages = np.asarray(srv["age"])
    assert (ages[valid] == 0).all() and (ages[~valid] == packing.PAD_AGE).all()


def test_packed_init_requires_mesh_and_cfg():
    params = {"w": jnp.zeros((8,), jnp.float32)}
    with pytest.raises(ValueError):
        init_server_state(params)                  # packed default needs mesh
    srv = init_server_state(params, oac=OacServerConfig(packed=False))
    assert srv["g"]["w"].shape == (8,)             # per-leaf tree flavour


def test_per_leaf_rejects_error_feedback():
    cfg = get_config("mamba2-370m", reduced_variant=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError):
        make_train_step(cfg, InputShape("t", 64, 2, "train"), mesh,
                        oac=OacServerConfig(packed=False,
                                            error_feedback=True))


@pytest.mark.slow
@pytest.mark.parametrize("ef", [False, True])
def test_two_steps_execute_with_persisted_buffers(ef):
    from repro.data.tokens import lm_batch
    from repro.models import transformer as tr
    from repro.optim import make_optimizer
    cfg = get_config("mamba2-370m", reduced_variant=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = InputShape("t", 64, 2, "train")
    oac = OacServerConfig(error_feedback=ef)
    bundle = make_train_step(cfg, shape, mesh, oac=oac)
    params = tr.init_lm(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer(bundle.meta["optimizer"], 3e-3)
    opt_state = opt.init(params)
    server = init_server_state(params, mesh=mesh, cfg=cfg, oac=oac)
    step = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                   out_shardings=bundle.out_shardings,
                   donate_argnums=(0, 1, 2))
    nm = bundle.meta["n_micro"]
    with mesh:
        for t in range(2):
            toks, labels = lm_batch(t, 2, 64, cfg.vocab)
            batch = {"tokens": jnp.asarray(toks).reshape(nm, 2 // nm, 64),
                     "labels": jnp.asarray(labels).reshape(nm, 2 // nm, 64)}
            params, opt_state, server, loss = step(
                params, opt_state, server, batch, jnp.asarray(t, jnp.int32))
    assert np.isfinite(float(loss))
    ages = np.asarray(server["age"])
    valid = ages >= 0
    frac_fresh = (ages[valid] == 0).mean()
    assert 0.03 < frac_fresh < 0.3                 # rho = 0.1 target
    assert (ages[~valid] == packing.PAD_AGE).all()
    assert float(np.asarray(server["theta"])[4]) == 1.0   # init flag set
    if ef:
        assert float(jnp.abs(server["res"]).sum()) > 0.0
