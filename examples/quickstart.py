"""Quickstart: OAC-FL with FAIR-k in ~2 minutes on CPU.

Trains a small classifier federated across 16 clients over a simulated
Rayleigh-fading multiple-access channel, comparing FAIR-k with Top-k —
reproducing the paper's headline effect (Fig. 4): magnitude-only selection
starves coordinates and stalls; FAIR-k's age stage keeps every coordinate
fresh and converges.

  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core.oac import ChannelConfig
from repro.data import partition, synthetic
from repro.fl import FLConfig, train
from repro.models import cnn


def main():
    spec = synthetic.DatasetSpec("quickstart", (16, 16, 1), 10, 8000, 1000,
                                 noise_std=1.0, sparsity=0.08)
    (xtr, ytr), (xte, yte) = synthetic.make_dataset(spec, seed=0)
    parts = partition.dirichlet_partition(ytr, 16, alpha=0.3, seed=0)
    params0 = cnn.init_mlp_classifier(jax.random.PRNGKey(0), 256, 10,
                                      hidden=(64,))
    print(f"model d={cnn.param_count(params0)} parameters, "
          f"16 clients, Dir(0.3), rho=10% waveform budget\n")

    def loss_fn(p, x, y):
        return cnn.softmax_xent(cnn.mlp_classifier(p, x), y)

    xte_j, yte_j = jnp.asarray(xte), jnp.asarray(yte)

    @jax.jit
    def eval_fn(p):
        return {"acc": cnn.accuracy(cnn.mlp_classifier(p, xte_j), yte_j)}

    def sample_round(t):
        return partition.client_batches(xtr, ytr, parts, 20, 5, seed=t)

    for policy in ("fairk", "topk"):
        fl = FLConfig(n_clients=16, local_steps=5, batch_size=20,
                      local_lr=0.05, global_lr=0.05, rounds=100,
                      policy=policy, compression_ratio=0.1,
                      channel=ChannelConfig(fading="rayleigh", mean=1.0,
                                            noise_std=0.2))
        print(f"=== policy: {policy}")
        h = train(fl, params0, loss_fn, sample_round, eval_fn=eval_fn,
                  eval_every=25, verbose=True)
        print(f"    final acc {h['acc'][-1]:.3f}, "
              f"mean AoU {h['mean_aou'][-1]:.1f}, "
              f"entries never updated: "
              f"{(h['sel_count'] == 0).mean()*100:.0f}%\n")


if __name__ == "__main__":
    main()
