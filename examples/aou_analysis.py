"""Lemma 1 walkthrough: build the FAIR-k Markov chain, solve the steady
state, plot (ASCII) the AoU distribution against simulation, and show how
E[tau] — the staleness term in Theorem 1's residual error — moves with the
magnitude/freshness split k_M/k.

  PYTHONPATH=src python examples/aou_analysis.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import markov


def ascii_plot(support, series, width=60, height=12):
    top = max(max(s) for _, s in series)
    for name, s in series:
        print(f"  {name}:")
        for i in range(0, len(support), max(1, len(support) // height)):
            bar = "#" * int(s[i] / top * width)
            print(f"    tau={support[i]:3d} | {bar} {s[i]:.4f}")


def main():
    chain = markov.FairKChain(d=800, k=80, k_m=60, k0=15)   # Fig. 3 params
    support, pmf = markov.aou_distribution(chain)
    emp = markov.simulate_aou(chain, rounds=4000, seed=0, mode="exchange")
    print(f"FAIR-k chain d={chain.d} k={chain.k} k_m={chain.k_m} "
          f"k0={chain.k0}: T={chain.max_staleness}, "
          f"E[tau]={float((support*pmf).sum()):.2f}, "
          f"TV(analysis, sim)={0.5*np.abs(pmf-emp).sum():.4f}\n")
    ascii_plot(support, [("Lemma 1 analysis", pmf),
                         ("simulation (exchange model)", emp)])

    print("\nE[tau] vs magnitude share k_M/k (Theorem 1 staleness term):")
    for km_frac in (0.25, 0.5, 0.75, 0.9):
        km = int(80 * km_frac)
        e = markov.expected_staleness(
            markov.FairKChain(d=800, k=80, k_m=km, k0=max(2, km // 4)))
        print(f"  k_M/k={km_frac:.2f}: E[tau] = {e:6.2f}")
    print("  k_M/k=1.00: E[tau] unbounded (pure Top-k starves entries)")


if __name__ == "__main__":
    main()
